//! Differential test: the timing-wheel [`EventQueue`] must produce the
//! exact `(time, key, seq)` pop order of the reference `BinaryHeap`
//! queue on randomized interleaved push/pop schedules — including
//! same-instant bursts (keyed and unkeyed), zero-delay
//! (schedule-at-now) events, and far-future timers that land in every
//! wheel level and the overflow heap.
//!
//! Each scenario drives both queues with an identical operation
//! sequence generated from a seeded RNG (failures print the seed).

use inc_sim::sim::{EventQueue, ReferenceQueue, Time};
use inc_sim::util::SplitMix64;

/// Drive both queues with the same randomized schedule; compare pops.
fn run_case(seed: u64, ops: usize, horizon_weights: &[(u64, u32)]) {
    let mut rng = SplitMix64::new(seed);
    let mut wheel: EventQueue<u64> = EventQueue::new();
    let mut heap: ReferenceQueue<u64> = ReferenceQueue::new();
    // Pushes must never be in the past; track the last popped time.
    let mut now: Time = 0;
    let mut next_ev = 0u64;
    let total_weight: u32 = horizon_weights.iter().map(|(_, w)| w).sum();

    let mut delay = |rng: &mut SplitMix64| {
        let mut pick = rng.gen_range(total_weight as usize) as u32;
        for &(h, w) in horizon_weights {
            if pick < w {
                return if h == 0 { 0 } else { rng.next_u64() % h };
            }
            pick -= w;
        }
        unreachable!()
    };

    for _ in 0..ops {
        match rng.gen_range(100) {
            // 45%: push a single event (content key 0).
            0..=44 => {
                let t = now + delay(&mut rng);
                wheel.push(t, next_ev);
                heap.push(t, next_ev);
                next_ev += 1;
            }
            // 15%: push a single keyed event (small key space forces
            // same-(time, key) collisions too).
            45..=59 => {
                let t = now + delay(&mut rng);
                let key = rng.gen_range(4) as u64;
                wheel.push_keyed(t, key, next_ev);
                heap.push_keyed(t, key, next_ev);
                next_ev += 1;
            }
            // 10%: same-instant burst with mixed keys (time collisions
            // stress the (key, seq) order within a slot).
            60..=69 => {
                let t = now + delay(&mut rng);
                let burst = 2 + rng.gen_range(6);
                for _ in 0..burst {
                    let key = rng.gen_range(3) as u64;
                    wheel.push_keyed(t, key, next_ev);
                    heap.push_keyed(t, key, next_ev);
                    next_ev += 1;
                }
            }
            // 30%: pop and compare.
            _ => {
                let a = wheel.pop();
                let b = heap.pop();
                assert_eq!(a, b, "pop mismatch (seed {seed})");
                if let Some((t, _)) = a {
                    assert!(t >= now, "time regressed (seed {seed})");
                    now = t;
                }
            }
        }
        assert_eq!(wheel.len(), heap.len(), "len diverged (seed {seed})");
    }
    // Drain both completely.
    loop {
        let a = wheel.pop();
        let b = heap.pop();
        assert_eq!(a, b, "drain mismatch (seed {seed})");
        if a.is_none() {
            break;
        }
    }
    assert!(wheel.is_empty() && heap.is_empty());
}

#[test]
fn near_future_traffic_shapes() {
    // Fabric-like delays: sub-µs hops, occasional 100 µs timers.
    for seed in 0..30 {
        run_case(seed, 4000, &[(0, 5), (1_000, 60), (100_000, 35)]);
    }
}

#[test]
fn all_levels_and_overflow() {
    // Delays spanning every wheel level plus multi-second overflow
    // timers (level 2 covers ~1.07 s).
    for seed in 100..120 {
        run_case(
            seed,
            2500,
            &[(0, 5), (900, 30), (800_000, 30), (700_000_000, 20), (5_000_000_000, 15)],
        );
    }
}

#[test]
fn same_instant_heavy() {
    // Mostly zero-delay pushes: everything lands at the live instant.
    for seed in 200..215 {
        run_case(seed, 3000, &[(0, 70), (50, 20), (2_000_000, 10)]);
    }
}

#[test]
fn deep_backlog_then_drain() {
    // One huge backlog (the bench's depth-500k shape, scaled down),
    // drained in a single sweep.
    let mut rng = SplitMix64::new(42);
    let mut wheel: EventQueue<u64> = EventQueue::new();
    let mut heap: ReferenceQueue<u64> = ReferenceQueue::new();
    for i in 0..100_000u64 {
        let t = rng.next_u64() % 2_000_000;
        wheel.push(t, i);
        heap.push(t, i);
    }
    loop {
        let a = wheel.pop();
        let b = heap.pop();
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
}
