//! Property-style tests over randomized scenarios (seeded SplitMix64 —
//! the offline environment has no proptest, so cases are generated
//! explicitly; failures print the seed for reproduction).

use inc_sim::channels::ethernet::RxMode;
use inc_sim::channels::{CommMode, Endpoint, Message, ReliableParams, RELIABLE_HEADER_BYTES};
use inc_sim::config::{SystemConfig, SystemPreset};
use inc_sim::network::sharded::ShardedNetwork;
use inc_sim::network::{App, Domain, Fabric, Network, NullApp};
use inc_sim::router::{Packet, Payload, Proto};
use inc_sim::topology::{NodeId, Span, Topology};
use inc_sim::util::SplitMix64;
use inc_sim::workload::chaos::scenario::targeted_drop;
use inc_sim::workload::chaos::workloads::{run_workload, ChaosWorkload, WorkloadChaosConfig};
use inc_sim::workload::chaos::{self, ChaosConfig, FaultKind, Scenario};

const CASES: u64 = 40;

/// The shard-local state domains: for every preset and a sweep of shard
/// counts, each shard's global↔local maps are bijections between its
/// owned identifier set and a dense `0..count` range, and across shards
/// they cover the owner map exactly — every node once (by its owner),
/// every link once (by its transmit-side owner). On the mega presets
/// the maps must also stay O(owned): a shard of a 100k-node mesh may
/// not pay for the whole mesh.
#[test]
fn prop_domain_maps_are_bijections_covering_the_owner_map() {
    for (preset, shard_counts) in [
        (SystemPreset::Card, &[1u32, 2, 3, 4, 7, 16][..]),
        (SystemPreset::Inc3000, &[1, 2, 3, 4, 7, 16]),
        (SystemPreset::Inc9000, &[1, 2, 3, 4, 7, 16]),
        // Mega presets: restricted sweep (the full-scale figures live
        // in benches/sim_engine.rs); 64 > any core count here, the
        // work-stealing regime.
        (SystemPreset::Inc27000, &[1, 16, 64]),
        (SystemPreset::Inc100k, &[16, 64]),
    ] {
        let topo = Topology::preset(preset);
        for &shards in shard_counts {
            let (owner, s) = topo.partition(shards);
            let mut node_owner_seen = vec![false; topo.node_count()];
            let mut link_owner_seen = vec![false; topo.link_count()];
            for shard in 0..s {
                let d = Domain::owned(&topo, &owner, shard);
                let ctx = format!("{preset:?} shards={s} shard={shard}");
                // Injective + into the owned set: local → global → local
                // round-trips, each global owned by this shard, no global
                // claimed twice (across locals *or* shards).
                for li in 0..d.node_count() {
                    let g = d.node_at(li);
                    assert_eq!(owner[g.0 as usize], shard, "{ctx}: {g} not owned");
                    assert_eq!(d.node_index(g), li, "{ctx}: node map not inverse");
                    assert!(d.owns_node(g), "{ctx}");
                    assert!(!node_owner_seen[g.0 as usize], "{ctx}: {g} mapped twice");
                    node_owner_seen[g.0 as usize] = true;
                }
                for li in 0..d.link_count() {
                    let g = d.link_at(li);
                    let src = topo.link(g).src;
                    assert_eq!(owner[src.0 as usize], shard, "{ctx}: {g} tx not owned");
                    assert_eq!(d.link_index(g), li, "{ctx}: link map not inverse");
                    assert!(d.owns_link(g), "{ctx}");
                    assert!(!link_owner_seen[g.0 as usize], "{ctx}: {g} mapped twice");
                    link_owner_seen[g.0 as usize] = true;
                }
                // Surjective onto the owned counts.
                assert_eq!(
                    d.node_count(),
                    owner.iter().filter(|&&o| o == shard).count(),
                    "{ctx}: node count"
                );
                assert_eq!(
                    d.link_count(),
                    topo.links().iter().filter(|l| owner[l.src.0 as usize] == shard).count(),
                    "{ctx}: link count"
                );
                // O(owned) accounting: index bytes bounded by the
                // shard's own slice (generous constant for hash-map
                // capacity slack), never by the mesh.
                assert!(
                    d.index_bytes() <= 64 * (d.node_count() + d.link_count()) + 4096,
                    "{ctx}: index maps are not O(owned) ({} bytes for {} nodes + {} links)",
                    d.index_bytes(),
                    d.node_count(),
                    d.link_count()
                );
            }
            // Covering exactly: union over shards = the whole mesh.
            assert!(node_owner_seen.iter().all(|&b| b), "{preset:?} shards={s}: node gap");
            assert!(link_owner_seen.iter().all(|&b| b), "{preset:?} shards={s}: link gap");
        }
    }
}

/// Directed routing delivers every packet, and hop counts are minimal on
/// an idle mesh (per-packet hops ≤ min_hops can't be beaten; equality on
/// idle fabric).
#[test]
fn prop_directed_minimal_hops_idle() {
    struct Check {
        topo: std::sync::Arc<Topology>,
        got: Vec<(NodeId, NodeId, u32)>,
    }
    impl App for Check {
        fn on_raw(&mut self, _net: &mut Network, node: NodeId, packet: &Packet) {
            self.got.push((packet.src, node, packet.hops));
        }
    }
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed);
        let mut net = Network::inc3000();
        let n = net.topo.node_count();
        let src = NodeId(rng.gen_range(n) as u32);
        let mut dst = NodeId(rng.gen_range(n) as u32);
        if dst == src {
            dst = NodeId((dst.0 + 1) % n as u32);
        }
        net.send_directed(src, dst, Proto::Raw { tag: 1 }, Payload::Empty);
        let mut app = Check { topo: net.topo.clone(), got: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.got.len(), 1, "seed {seed}");
        let (s, d, hops) = app.got[0];
        assert_eq!((s, d), (src, dst), "seed {seed}");
        assert_eq!(hops, app.topo.min_hops(src, dst), "seed {seed}: non-minimal path");
    }
}

/// Broadcast delivers exactly one copy everywhere from random sources on
/// all three presets (the §2.4 guarantee).
#[test]
fn prop_broadcast_exactly_once() {
    struct Count {
        copies: Vec<u32>,
    }
    impl App for Count {
        fn on_raw(&mut self, _net: &mut Network, node: NodeId, _p: &Packet) {
            self.copies[node.0 as usize] += 1;
        }
    }
    for preset in [SystemPreset::Card, SystemPreset::Inc3000, SystemPreset::Inc9000] {
        for seed in 0..8 {
            let mut rng = SplitMix64::new(seed ^ 0xB0);
            let mut net = Network::new(inc_sim::config::SystemConfig::new(preset));
            let n = net.topo.node_count();
            let src = NodeId(rng.gen_range(n) as u32);
            net.send_broadcast(src, Proto::Raw { tag: 2 }, Payload::Empty);
            let mut app = Count { copies: vec![0; n] };
            net.run_to_quiescence(&mut app);
            for (i, &c) in app.copies.iter().enumerate() {
                assert_eq!(c, 1, "{preset:?} seed {seed}: node {i} got {c} copies");
            }
        }
    }
}

/// Credit conservation: after quiescence every link's credits return to
/// the full buffer (no lost or duplicated credit), under random bursts.
#[test]
fn prop_credits_conserved() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xC4ED17);
        let mut net = Network::card();
        let n = net.topo.node_count();
        for _ in 0..100 {
            let src = NodeId(rng.gen_range(n) as u32);
            let mut dst = NodeId(rng.gen_range(n) as u32);
            if dst == src {
                dst = NodeId((dst.0 + 1) % n as u32);
            }
            let len = 1 + rng.gen_range(2000);
            net.send_directed(
                src,
                dst,
                Proto::Raw { tag: 3 },
                Payload::bytes(vec![0u8; len]),
            );
        }
        net.run_to_quiescence(&mut NullApp);
        let cap = net.cfg.link.credit_buffer_bytes;
        for (i, l) in net.links.iter().enumerate() {
            assert_eq!(l.credits(), cap, "seed {seed}: link {i} leaked credits");
            assert_eq!(l.queue_len(), 0, "seed {seed}: link {i} stuck queue");
        }
    }
}

/// Bridge FIFO: words always arrive complete and in order, under random
/// burst sizes and multiple channels.
#[test]
fn prop_fifo_order_and_completeness() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xF1F0);
        let mut net = Network::card();
        let n = net.topo.node_count();
        let src = NodeId(rng.gen_range(n) as u32);
        let mut dst = NodeId(rng.gen_range(n) as u32);
        if dst == src {
            dst = NodeId((dst.0 + 1) % n as u32);
        }
        let channels = 1 + rng.gen_range(4) as u8;
        for ch in 0..channels {
            net.fifo_connect(src, dst, ch, 64);
        }
        let mut sent: Vec<Vec<u64>> = vec![vec![]; channels as usize];
        for _ in 0..30 {
            let ch = rng.gen_range(channels as usize) as u8;
            let burst = 1 + rng.gen_range(100);
            let words: Vec<u64> = (0..burst)
                .map(|i| sent[ch as usize].len() as u64 + i as u64)
                .collect();
            sent[ch as usize].extend(&words);
            net.fifo_send(src, ch, &words);
        }
        net.run_to_quiescence(&mut NullApp);
        for ch in 0..channels {
            let got = net.fifo_read(dst, ch, usize::MAX);
            assert_eq!(got, sent[ch as usize], "seed {seed} channel {ch}");
        }
    }
}

/// Postmaster contiguity under random many-to-one traffic: every stored
/// record is byte-identical to a record its initiator sent (records are
/// never torn or merged). NOTE: arrival *order* is deliberately NOT
/// asserted per initiator — §2.4 says directed routing may deliver out
/// of order, and Postmaster stores in DMA-completion order.
#[test]
fn prop_postmaster_contiguity_and_order() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x90057);
        let mut net = Network::card();
        let n = net.topo.node_count();
        let target = NodeId(rng.gen_range(n) as u32);
        net.pm_open(target, 0);
        let mut sent: Vec<Vec<(u8, usize)>> = vec![vec![]; n]; // (tag, len)
        for k in 0..120 {
            let mut src = NodeId(rng.gen_range(n) as u32);
            if src == target {
                src = NodeId((src.0 + 1) % n as u32);
            }
            let len = 1 + rng.gen_range(200);
            let tag = (k % 251) as u8;
            sent[src.0 as usize].push((tag, len));
            net.pm_send(src, target, 0, vec![tag; len]);
        }
        net.run_to_quiescence(&mut NullApp);
        let recs = net.pm_read(target, 0);
        assert_eq!(recs.len(), 120, "seed {seed}");
        // Multiset match per initiator: every stored record is whole and
        // corresponds to exactly one sent record.
        let mut outstanding: Vec<Vec<(u8, usize)>> = sent.clone();
        for r in &recs {
            let idx = r.initiator.0 as usize;
            assert!(
                r.data.iter().all(|&b| b == r.data[0]),
                "seed {seed}: torn record {:?}",
                &r.data[..r.data.len().min(8)]
            );
            let key = (r.data[0], r.data.len());
            let pos = outstanding[idx]
                .iter()
                .position(|&k| k == key)
                .unwrap_or_else(|| panic!("seed {seed}: unknown record {key:?}"));
            outstanding[idx].remove(pos);
        }
        assert!(outstanding.iter().all(|v| v.is_empty()), "seed {seed}: lost records");
    }
}

/// Topology invariants under all presets: link symmetry (every link has
/// a reverse twin), degree bounds, span correctness.
#[test]
fn prop_topology_invariants() {
    for preset in [SystemPreset::Card, SystemPreset::Inc3000, SystemPreset::Inc9000] {
        let t = Topology::preset(preset);
        for l in t.links() {
            // Reverse link exists.
            assert!(
                t.links()
                    .iter()
                    .any(|r| r.src == l.dst && r.dst == l.src && r.span == l.span),
                "{preset:?}: link {l:?} has no reverse twin"
            );
            // Span matches geometric distance.
            let (a, b) = (t.coord(l.src), t.coord(l.dst));
            let d = a.x.abs_diff(b.x) + a.y.abs_diff(b.y) + a.z.abs_diff(b.z);
            assert_eq!(d, l.span.distance(), "{preset:?}");
        }
        for n in t.nodes() {
            let singles =
                t.out_links(n).iter().filter(|&&l| t.link(l).span == Span::Single).count();
            let multis =
                t.out_links(n).iter().filter(|&&l| t.link(l).span == Span::Multi).count();
            assert!(singles <= 6, "{preset:?}: {n} has {singles} single-span");
            assert!(multis <= 6, "{preset:?}: {n} has {multis} multi-span");
        }
    }
}

/// Determinism: identical seeds give identical event counts and clocks
/// across full random workloads.
#[test]
fn prop_deterministic_replay() {
    let run = |seed: u64| {
        let mut rng = SplitMix64::new(seed);
        let mut net = Network::card();
        let n = net.topo.node_count();
        net.pm_open(NodeId(0), 0);
        for _ in 0..200 {
            let src = NodeId(rng.gen_range(n) as u32);
            match rng.gen_range(3) {
                0 => {
                    let mut dst = NodeId(rng.gen_range(n) as u32);
                    if dst == src {
                        dst = NodeId((dst.0 + 1) % n as u32);
                    }
                    net.send_directed(src, dst, Proto::Raw { tag: 9 }, Payload::Empty);
                }
                1 => {
                    net.send_broadcast(src, Proto::Raw { tag: 9 }, Payload::Empty);
                }
                _ => {
                    if src != NodeId(0) {
                        net.pm_send(src, NodeId(0), 0, vec![1, 2, 3]);
                    }
                }
            }
        }
        let events = net.run_to_quiescence(&mut NullApp);
        (events, net.now(), net.metrics.packets_delivered)
    };
    for seed in 0..10 {
        assert_eq!(run(seed), run(seed), "seed {seed} not deterministic");
    }
}

/// Chaos storm resilience (E13): any scripted `fail_link` storm leaves
/// the mesh connected by construction, and under the *union* of every
/// scripted failure (the worst instant any overlap of burst windows can
/// produce) every sampled node pair still delivers — on the serial
/// engine and on 4- and 16-shard engines alike.
#[test]
fn prop_storm_degraded_mesh_still_delivers_every_pair() {
    fn deliver_all<F: Fabric>(net: &mut F, pairs: &[(NodeId, NodeId)], ctx: &str) {
        for &(s, d) in pairs {
            net.send_directed(s, d, Proto::Raw { tag: 13 }, Payload::Empty);
        }
        net.run(&mut NullApp);
        assert_eq!(
            net.metrics().packets_delivered,
            pairs.len() as u64,
            "{ctx}: a pair failed to deliver through the degraded mesh"
        );
    }
    for preset in [SystemPreset::Card, SystemPreset::Inc3000] {
        let topo = Topology::preset(preset);
        for seed in 0..6u64 {
            let script = Scenario::Storm.script(&std::sync::Arc::new(topo.clone()), seed, 30, 50_000);
            // Union of every scripted failure, repairs ignored: the
            // worst mesh any instant of the storm can reach.
            let mut failed = vec![false; topo.link_count()];
            for e in &script.events {
                if let FaultKind::Fail(l) = e.kind {
                    failed[l.0 as usize] = true;
                }
            }
            assert!(
                chaos::scenario::connected(&topo, &failed, &[]),
                "{preset:?} seed {seed}: storm union disconnected the mesh"
            );
            // Seeded pair sample (every pair on Card is overkill; the
            // sample crosses cards and the failure clusters).
            let mut rng = SplitMix64::new(seed ^ 0x57AB);
            let n = topo.node_count();
            let mut pairs = Vec::new();
            while pairs.len() < 48 {
                let s = NodeId(rng.gen_range(n) as u32);
                let mut d = NodeId(rng.gen_range(n) as u32);
                if d == s {
                    d = NodeId((d.0 + 1) % n as u32);
                }
                pairs.push((s, d));
            }
            for shards in [1u32, 4, 16] {
                let ctx = format!("{preset:?} seed {seed} shards={shards}");
                if shards == 1 {
                    let mut net = Network::new(SystemConfig::new(preset));
                    for (i, f) in failed.iter().enumerate() {
                        if *f {
                            Fabric::fail_link(&mut net, inc_sim::topology::LinkId(i as u32));
                        }
                    }
                    deliver_all(&mut net, &pairs, &ctx);
                } else {
                    let mut net = ShardedNetwork::new(SystemConfig::new(preset), shards);
                    for (i, f) in failed.iter().enumerate() {
                        if *f {
                            Fabric::fail_link(&mut net, inc_sim::topology::LinkId(i as u32));
                        }
                    }
                    deliver_all(&mut net, &pairs, &ctx);
                }
            }
        }
    }
}

/// The full storm harness converges within its SLO bound on every
/// engine: presets × shards {1, 4, 16}, several seeds — delivered
/// ratio 1.0 and reroute convergence under `max_convergence_ns`.
#[test]
fn prop_storm_harness_meets_slo_across_engines() {
    for preset in [SystemPreset::Card, SystemPreset::Inc3000] {
        for seed in [3u64, 17] {
            let ccfg = ChaosConfig::new(Scenario::Storm, seed);
            for shards in [1u32, 4, 16] {
                let mut sys = SystemConfig::new(preset);
                sys.rx_capacity = ccfg.suggested_rx_capacity();
                let report = if shards == 1 {
                    let mut net = Network::new(sys);
                    chaos::run(&mut net, &ccfg, 1)
                } else {
                    let mut net = ShardedNetwork::new(sys, shards);
                    let k = net.shard_count();
                    chaos::run(&mut net, &ccfg, k)
                };
                let ctx = format!("{preset:?} seed {seed} shards={shards}");
                assert_eq!(report.delivered, report.sent, "{ctx}: app-level loss");
                assert!(
                    report.convergence_ns <= report.slo.max_convergence_ns,
                    "{ctx}: convergence {}ns breaks SLO {}ns",
                    report.convergence_ns,
                    report.slo.max_convergence_ns
                );
                assert!(report.passed(), "{ctx}: {:?}", report.violations());
            }
        }
    }
}

// ---------------------------------------------------------------------
// Reliable transport properties (E14): exactly-once-or-peer-down.
// ---------------------------------------------------------------------

/// Counts app-level arrivals by (sender, tick) key and collects what the
/// transport hands back when a peer is declared down.
#[derive(Default)]
struct ExactlyOnce {
    got: std::collections::BTreeMap<(u8, u8), u32>,
    recovered: Vec<(u8, u8)>,
    downs: u32,
}

impl App for ExactlyOnce {
    fn on_message(&mut self, _net: &mut Network, _ep: Endpoint, msg: &Message) -> bool {
        *self.got.entry((msg.data[0], msg.data[1])).or_insert(0) += 1;
        true
    }
    fn on_peer_down(&mut self, net: &mut Network, ep: Endpoint, peer: NodeId) {
        self.downs += 1;
        for m in net.reliable_take_unacked(&ep, peer) {
            self.recovered.push((m.data[0], m.data[1]));
        }
    }
}

/// Under seeded storm scripts (link bursts, connectivity-preserving by
/// construction) the reliable transport delivers every record **exactly
/// once** — the retransmit path may engage, the duplicate-suppression
/// path absorbs the races, and nobody is ever declared down.
#[test]
fn prop_reliable_exactly_once_under_storm() {
    const TICK: u64 = 50_000;
    const TICKS: u64 = 30;
    let participants = [0u32, 4, 8, 13, 17, 21, 24, 26].map(NodeId);
    let mut total_acks = 0u64;
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(seed ^ 0xE1);
        let mut sys = SystemConfig::card();
        sys.drop_unroutable = true;
        let mut net = Network::new(sys);
        let script = Scenario::Storm.script(&net.topo.clone(), seed, TICKS, TICK);
        let eps: Vec<Endpoint> = participants
            .iter()
            .map(|&n| {
                net.reliable_open(n, CommMode::Postmaster { queue: 0 }, ReliableParams::default())
            })
            .collect();
        let mut app = ExactlyOnce::default();
        let mut sent = std::collections::BTreeSet::new();
        let mut next = 0usize;
        for tick in 0..TICKS {
            let t0 = tick * TICK;
            while next < script.events.len() && script.events[next].at <= t0 {
                match script.events[next].kind {
                    FaultKind::Fail(l) => net.fail_link(l),
                    FaultKind::Repair(l) => net.repair_link(l),
                }
                next += 1;
            }
            for (i, ep) in eps.iter().enumerate() {
                let mut d = rng.gen_range(participants.len());
                if d == i {
                    d = (d + 1) % participants.len();
                }
                let key = (i as u8, tick as u8);
                net.reliable_send_at(t0, ep, participants[d], Message::new(vec![key.0, key.1]));
                sent.insert(key);
            }
            Fabric::run_until(&mut net, &mut app, t0 + TICK);
        }
        net.run_to_quiescence(&mut app);
        for &key in &sent {
            assert_eq!(
                app.got.get(&key).copied().unwrap_or(0),
                1,
                "seed {seed}: record {key:?} not delivered exactly once"
            );
        }
        assert_eq!(app.got.len(), sent.len(), "seed {seed}: phantom records arrived");
        assert_eq!(app.downs, 0, "seed {seed}: storm falsely declared a peer down");
        assert_eq!(net.metrics.peers_declared_down, 0, "seed {seed}");
        total_acks += net.metrics.acks;
    }
    assert!(total_acks > 0, "the reliable transport never engaged");
}

/// Seeded fabric-level packet loss (`drop_probability`): the reliable
/// transport turns a lossy best-effort channel back into exactly-once.
/// Every record is delivered once, nobody is falsely declared down, and
/// both the loss and retransmit paths demonstrably engage.
#[test]
fn prop_reliable_exactly_once_under_seeded_loss() {
    const TICK: u64 = 50_000;
    const TICKS: u64 = 30;
    let participants = [0u32, 4, 8, 13, 17, 21, 24, 26].map(NodeId);
    let mut total_loss = 0u64;
    let mut total_retx = 0u64;
    for seed in 0..4u64 {
        let mut rng = SplitMix64::new(seed ^ 0x1055);
        let mut sys = SystemConfig::card();
        sys.seed = seed; // varies the loss hash run to run
        sys.drop_probability = 0.01;
        let mut net = Network::new(sys);
        // Best-effort Ethernet under the transport: a dropped frame is
        // simply gone, exactly what the retransmit path exists for.
        // Generous retry budget: at 1% per hand-off a record's loss odds
        // per attempt are a few percent, so 10 tries make a delivery
        // failure astronomically unlikely (and the run is deterministic).
        let eth = CommMode::Ethernet { rx: RxMode::Interrupt };
        let params = ReliableParams { max_retries: 10, ..ReliableParams::default() };
        let eps: Vec<Endpoint> =
            participants.iter().map(|&n| net.reliable_open(n, eth, params)).collect();
        let mut app = ExactlyOnce::default();
        let mut sent = std::collections::BTreeSet::new();
        for tick in 0..TICKS {
            let t0 = tick * TICK;
            for (i, ep) in eps.iter().enumerate() {
                let mut d = rng.gen_range(participants.len());
                if d == i {
                    d = (d + 1) % participants.len();
                }
                let key = (i as u8, tick as u8);
                net.reliable_send_at(t0, ep, participants[d], Message::new(vec![key.0, key.1]));
                sent.insert(key);
            }
            Fabric::run_until(&mut net, &mut app, t0 + TICK);
        }
        net.run_to_quiescence(&mut app);
        for &key in &sent {
            assert_eq!(
                app.got.get(&key).copied().unwrap_or(0),
                1,
                "seed {seed}: record {key:?} not delivered exactly once under loss"
            );
        }
        assert_eq!(app.got.len(), sent.len(), "seed {seed}: phantom records arrived");
        assert_eq!(app.downs, 0, "seed {seed}: seeded loss falsely declared a peer down");
        total_loss += net.metrics.link_loss;
        total_retx += net.metrics.retransmits;
    }
    assert!(total_loss > 0, "1% seeded loss never dropped a packet");
    assert!(total_retx > 0, "the retransmit path never engaged under loss");
}

/// Selective repeat strictly beats go-back-all: the same seeded-loss
/// workload run twice, once per retransmit policy
/// ([`ReliableParams::sack`]), must (a) deliver every record exactly
/// once under **both** policies and (b) put strictly fewer
/// retransmitted bytes on the wire with SACK — a random loss punches
/// a gap, and only the gap should go back out, not everything the
/// receiver already buffered behind it.
#[test]
fn prop_sack_retransmits_strictly_fewer_bytes_than_go_back_all() {
    const TICK: u64 = 50_000;
    const TICKS: u64 = 30;
    const PAYLOAD: u64 = 2; // (sender, tick) key bytes
    let participants = [0u32, 4, 8, 13, 17, 21, 24, 26].map(NodeId);
    let run = |seed: u64, sack: bool| -> u64 {
        let mut rng = SplitMix64::new(seed ^ 0x5ac1);
        let mut sys = SystemConfig::card();
        sys.seed = seed;
        sys.drop_probability = 0.01;
        let mut net = Network::new(sys);
        let eth = CommMode::Ethernet { rx: RxMode::Interrupt };
        let params = ReliableParams { max_retries: 10, sack, ..ReliableParams::default() };
        let eps: Vec<Endpoint> =
            participants.iter().map(|&n| net.reliable_open(n, eth, params)).collect();
        let mut app = ExactlyOnce::default();
        let mut sent = std::collections::BTreeSet::new();
        for tick in 0..TICKS {
            let t0 = tick * TICK;
            for (i, ep) in eps.iter().enumerate() {
                let mut d = rng.gen_range(participants.len());
                if d == i {
                    d = (d + 1) % participants.len();
                }
                let key = (i as u8, tick as u8);
                net.reliable_send_at(t0, ep, participants[d], Message::new(vec![key.0, key.1]));
                sent.insert(key);
            }
            Fabric::run_until(&mut net, &mut app, t0 + TICK);
        }
        net.run_to_quiescence(&mut app);
        let policy = if sack { "sack" } else { "go-back-all" };
        for &key in &sent {
            assert_eq!(
                app.got.get(&key).copied().unwrap_or(0),
                1,
                "seed {seed} ({policy}): record {key:?} not delivered exactly once"
            );
        }
        assert_eq!(app.got.len(), sent.len(), "seed {seed} ({policy}): phantom records");
        assert_eq!(app.downs, 0, "seed {seed} ({policy}): loss falsely declared a peer down");
        net.metrics.retransmits * (PAYLOAD + RELIABLE_HEADER_BYTES as u64)
    };
    // Per-seed the retransmit packets themselves draw different loss
    // hashes, so the comparison is aggregated across seeds; exactly-once
    // is asserted per seed per policy inside `run`.
    let mut gba_bytes = 0u64;
    let mut sack_bytes = 0u64;
    for seed in 0..4u64 {
        gba_bytes += run(seed, false);
        sack_bytes += run(seed, true);
    }
    assert!(gba_bytes > 0, "go-back-all never retransmitted — loss path idle");
    assert!(
        sack_bytes < gba_bytes,
        "selective repeat must retransmit strictly fewer bytes \
         (sack {sack_bytes} vs go-back-all {gba_bytes})"
    );
}

/// With a targeted two-phase death mid-run, every record a live sender
/// produced is **either** delivered exactly once **or** handed back by
/// `reliable_take_unacked` after the peer-down declaration — each record
/// exactly one of the two, no record neither. (The two-phase death is
/// what makes the dichotomy exact: inbound links die first, so every
/// delivered record's ack still returns and unacked ⟺ undelivered.)
#[test]
fn prop_reliable_exactly_once_or_peer_down_under_targeted_death() {
    const TICK: u64 = 50_000;
    const TICKS: u64 = 30;
    const DEATH_TICK: u64 = 6;
    for seed in 0..6u64 {
        let mut rng = SplitMix64::new(seed ^ 0xDEAD);
        let mut sys = SystemConfig::card();
        sys.drop_unroutable = true;
        let mut net = Network::new(sys);
        let n = net.topo.node_count();
        let victim = NodeId(13);
        let mut senders = std::collections::BTreeSet::new();
        while senders.len() < 6 {
            let c = NodeId(rng.gen_range(n) as u32);
            if c != victim {
                senders.insert(c);
            }
        }
        let senders: Vec<NodeId> = senders.into_iter().collect();
        let script = targeted_drop(&net.topo.clone(), &[victim], DEATH_TICK * TICK, TICK);
        assert_eq!(script.excluded, vec![victim], "seed {seed}: victim not severable");
        let params = ReliableParams {
            rto_ns: 30_000,
            max_retries: 4,
            heartbeat_ns: 50_000,
            liveness_ns: 300_000,
            ..ReliableParams::default()
        };
        let pm = CommMode::Postmaster { queue: 0 };
        net.reliable_open(victim, pm, params);
        let eps: Vec<Endpoint> =
            senders.iter().map(|&s| net.reliable_open(s, pm, params)).collect();
        let mut app = ExactlyOnce::default();
        let mut sent = std::collections::BTreeSet::new();
        let mut next = 0usize;
        for tick in 0..TICKS {
            let t0 = tick * TICK;
            while next < script.events.len() && script.events[next].at <= t0 {
                match script.events[next].kind {
                    FaultKind::Fail(l) => net.fail_link(l),
                    FaultKind::Repair(l) => net.repair_link(l),
                }
                next += 1;
            }
            for (i, ep) in eps.iter().enumerate() {
                // A sender stops once it has declared the victim down
                // (the send API refuses dead peers by contract).
                if !net.reliable_is_down(ep, victim) {
                    let key = (i as u8, tick as u8);
                    net.reliable_send_at(t0, ep, victim, Message::new(vec![key.0, key.1]));
                    sent.insert(key);
                }
            }
            Fabric::run_until(&mut net, &mut app, t0 + TICK);
        }
        net.run_to_quiescence(&mut app);
        // Every sender kept sending into the dead inbox, so every sender
        // must eventually exhaust its retry budget and declare.
        assert_eq!(app.downs as usize, senders.len(), "seed {seed}: missing declarations");
        assert!(net.metrics.retransmits > 0, "seed {seed}: the death forced no retransmits");
        let recovered: std::collections::BTreeSet<(u8, u8)> =
            app.recovered.iter().copied().collect();
        assert_eq!(recovered.len(), app.recovered.len(), "seed {seed}: duplicate recovery");
        for &key in &sent {
            let delivered = app.got.get(&key).copied().unwrap_or(0);
            assert!(delivered <= 1, "seed {seed}: record {key:?} duplicated to the app");
            assert!(
                (delivered == 1) ^ recovered.contains(&key),
                "seed {seed}: record {key:?} violated exactly-once-or-peer-down \
                 (delivered={delivered}, recovered={})",
                recovered.contains(&key)
            );
        }
        assert_eq!(app.got.len(), sent.len().min(app.got.len()), "seed {seed}: phantom records");
        assert!(app.got.keys().all(|k| sent.contains(k)), "seed {seed}: unknown record");
    }
}

/// The workload-chaos harness holds the same guarantee end-to-end: the
/// learner grid over seeded storms delivers every scheduled record
/// exactly once with zero failure declarations, for every storm seed.
#[test]
fn prop_reliable_learners_exactly_once_across_storm_seeds() {
    for seed in 0..6u64 {
        let cfg = WorkloadChaosConfig::new(ChaosWorkload::Learners, Scenario::Storm, seed);
        let mut net = Network::new(cfg.system_config());
        let r = run_workload(&mut net, &cfg, 1);
        assert_eq!(r.delivered, r.expected, "seed {seed}: exactly-once violated");
        assert_eq!(r.peers_declared_down, 0, "seed {seed}: false death under storm");
        assert!(r.passed(), "seed {seed}: {:?}", r.violations());
    }
}

/// SNN conservation law (E16): across random seeds, spike rates,
/// fan-outs and inhibition fractions — and over both transports — every
/// emitted spike produces exactly `fanout` synaptic deliveries, every
/// delivery lands as exactly one syn event, and every population node
/// runs every tick. No spike is lost, duplicated or conjured.
#[test]
fn prop_snn_spike_conservation() {
    use inc_sim::workload::snn::{Snn, SnnConfig};
    for case in 0..CASES {
        let mut rng = SplitMix64::new(0x5EED_0E16 ^ case);
        let cfg = SnnConfig {
            nodes: 2 + rng.gen_range(10),
            neurons_per_node: 1 + rng.gen_range(8) as u32,
            fanout: 1 + rng.gen_range(6) as u32,
            ticks: 4 + rng.gen_range(12) as u32,
            rate_ppm: 50_000 + rng.gen_range(400_000) as u64,
            inhibit_ppm: rng.gen_range(400_000) as u64,
            refractory_ticks: rng.gen_range(4) as u32,
            comm: if case % 3 == 2 { Some(CommMode::Raw) } else { None },
            ..Default::default()
        };
        let mut sys = SystemConfig::new(SystemPreset::Card);
        sys.seed = case.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xE16;
        let mut net = Network::new(sys);
        let snn = Snn::setup(&mut net, cfg);
        let mut app = snn.app();
        Fabric::run(&mut net, &mut app);
        assert_eq!(
            app.expected_deliveries,
            app.spikes_emitted * cfg.fanout as u64,
            "case {case}: fan-out accounting"
        );
        assert_eq!(
            app.spikes_delivered, app.expected_deliveries,
            "case {case}: spikes lost or duplicated ({} emitted, fanout {})",
            app.spikes_emitted, cfg.fanout
        );
        assert_eq!(app.syn_events, app.spikes_delivered, "case {case}: syn event accounting");
        assert_eq!(
            app.tick_events,
            cfg.nodes as u64 * cfg.ticks as u64,
            "case {case}: missing membrane updates"
        );
    }
}

/// Refractory contract (E16): after a neuron fires it stays silent for
/// `1 + refractory_ticks` ticks, at every rate and seed — even when the
/// background process and synaptic input push the membrane well past
/// threshold inside the window.
#[test]
fn prop_snn_refractory_respected() {
    use inc_sim::workload::snn::{Snn, SnnConfig};
    for case in 0..CASES {
        let mut rng = SplitMix64::new(0x5EED_F1AE ^ case);
        let refractory = rng.gen_range(5) as u32;
        let cfg = SnnConfig {
            nodes: 2 + rng.gen_range(6),
            neurons_per_node: 1 + rng.gen_range(6) as u32,
            ticks: 16,
            // Drive hard so the window is actually contested.
            rate_ppm: 400_000 + rng.gen_range(500_000) as u64,
            input_q16: 120 << 16,
            refractory_ticks: refractory,
            record_fires: true,
            ..Default::default()
        };
        let mut sys = SystemConfig::new(SystemPreset::Card);
        sys.seed = case.wrapping_mul(0xD134_2543_DE82_EF95) ^ 0xF1AE;
        let mut net = Network::new(sys);
        let snn = Snn::setup(&mut net, cfg);
        let mut app = snn.app();
        Fabric::run(&mut net, &mut app);
        assert!(app.spikes_emitted > 0, "case {case}: hard drive produced no fires");
        let mut fires: Vec<(u32, u32, u32)> =
            app.fires.iter().map(|&(t, n, i)| (n, i, t)).collect();
        fires.sort_unstable();
        for w in fires.windows(2) {
            let ((n0, i0, t0), (n1, i1, t1)) = (w[0], w[1]);
            if (n0, i0) == (n1, i1) {
                assert!(
                    t1 - t0 >= 1 + refractory,
                    "case {case}: neuron ({n0},{i0}) refired after {} ticks \
                     (refractory {refractory})",
                    t1 - t0
                );
            }
        }
    }
}
