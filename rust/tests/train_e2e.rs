//! End-to-end: data-parallel training over the simulated INC card with
//! real numerics through PJRT (requires `make artifacts`).
//!
//! This is the integration behind `examples/train_distributed.rs` (E10),
//! kept short here: 30 steps must show a clearly decreasing loss and a
//! sane virtual-time split.
//!
//! Compiled only with the `pjrt` cargo feature (the default offline
//! build has no PJRT backend).
#![cfg(feature = "pjrt")]

use inc_sim::coordinator::Placement;
use inc_sim::network::Network;
use inc_sim::workload::training::{train, TrainConfig};

#[test]
fn thirty_steps_reduce_loss_and_account_time() {
    let rt = inc_sim::runtime::load_default().expect("run `make artifacts` first");
    let mut net = Network::card();
    let cfg = TrainConfig {
        ranks: 4,
        steps: 30,
        lr: 0.25,
        seed: 7,
        placement: Placement::Block,
        log_every: 5,
        ..Default::default()
    };
    let report = train(&mut net, &rt, &cfg).unwrap();
    assert!(
        report.final_loss < report.first_loss * 0.8,
        "loss {} -> {} after 30 steps",
        report.first_loss,
        report.final_loss
    );
    assert!(report.vtime_compute > 0 && report.vtime_comm > 0);
    assert_eq!(
        report.vtime_total,
        net.now(),
        "all virtual time must be accounted on the fabric clock"
    );
    assert!(report.params > 100_000, "model has {} params", report.params);
}

#[test]
fn single_rank_trains_without_collectives() {
    let rt = inc_sim::runtime::load_default().expect("run `make artifacts` first");
    let mut net = Network::card();
    let cfg = TrainConfig { ranks: 1, steps: 10, log_every: 5, ..Default::default() };
    let report = train(&mut net, &rt, &cfg).unwrap();
    assert!(report.final_loss < report.first_loss);
    assert_eq!(report.vtime_comm, 0);
}
