//! Capability-contract property tests: every communication mode must
//! obey its declared [`ChannelCaps`] — on both engines.
//!
//! * per-pair FIFO ordering where `ordering == PerPairFifo`;
//! * no loss under random traffic with link fail/repair mid-flight
//!   (`reliability == Guaranteed`: §2.4 defect avoidance reroutes, the
//!   credit protocol never drops);
//! * payload-limit rejection where `max_payload` is bounded.
//!
//! Randomized cases are seeded SplitMix64 (no proptest offline);
//! failures print the seed.

use inc_sim::channels::ethernet::RxMode;
use inc_sim::channels::{CommMode, Endpoint, Message, MsgOrdering};
use inc_sim::config::SystemConfig;
use inc_sim::network::sharded::ShardedNetwork;
use inc_sim::network::{Fabric, Network, NullApp};
use inc_sim::topology::{LinkId, NodeId};
use inc_sim::util::SplitMix64;

/// Ordered-mode contract: messages between one pair arrive complete,
/// uncorrupted and in send order, under random message sizes (multiple
/// packets per message included).
fn fifo_ordering_case<F: Fabric>(net: &mut F, seed: u64) {
    let mode = CommMode::BridgeFifo { width_bits: 64 };
    assert_eq!(net.caps(mode).ordering, MsgOrdering::PerPairFifo);
    let n = net.topo().node_count() as u32;
    let mut rng = SplitMix64::new(seed ^ 0xF1F0);
    let a = NodeId(rng.gen_range(n as usize) as u32);
    let mut b = NodeId(rng.gen_range(n as usize) as u32);
    if b == a {
        b = NodeId((b.0 + n / 2 + 1) % n);
    }
    let ea = net.open(a, mode);
    let eb = net.open(b, mode);
    net.connect(&ea, b);
    let mut sent = Vec::new();
    for i in 0..40u32 {
        // Sizes from sub-word to multi-packet (> MTU worth of words).
        let len = 1 + rng.gen_range(4000);
        let payload: Vec<u8> = (0..len).map(|j| (i as usize + j) as u8).collect();
        sent.push(payload.clone());
        net.send(&ea, b, Message::new(payload));
    }
    net.run(&mut NullApp);
    let got = net.recv(&eb);
    assert_eq!(got.len(), sent.len(), "seed {seed}: message count");
    for (k, (g, s)) in got.iter().zip(&sent).enumerate() {
        assert_eq!(*g.data, *s, "seed {seed}: message {k} torn or out of order");
        assert_eq!(g.from, a, "seed {seed}: wrong sender");
    }
}

#[test]
fn prop_fifo_mode_per_pair_ordering_both_engines() {
    for seed in 0..8 {
        let mut serial = Network::inc3000();
        fifo_ordering_case(&mut serial, seed);
        let mut sharded = ShardedNetwork::new(SystemConfig::inc3000(), 16);
        fifo_ordering_case(&mut sharded, seed);
    }
}

/// Reliability contract: random many-to-many traffic with links failed
/// mid-flight (and later repaired) loses nothing — defect avoidance
/// reroutes, the credit protocol never drops. Returns (sent, received).
fn no_loss_case<F: Fabric>(net: &mut F, mode: CommMode, seed: u64) -> (u64, u64) {
    let mut rng = SplitMix64::new(seed ^ 0x10C5);
    let n = net.topo().node_count() as u32;
    // A handful of endpoints spread over the mesh.
    let k = 8usize;
    let nodes: Vec<NodeId> = (0..k as u32).map(|i| NodeId(i * (n / k as u32))).collect();
    let eps: Vec<Endpoint> = nodes.iter().map(|&nd| net.open(nd, mode)).collect();
    if net.caps(mode).pair_setup {
        for (i, ep) in eps.iter().enumerate() {
            for (j, &dst) in nodes.iter().enumerate() {
                if i != j {
                    net.connect(ep, dst);
                }
            }
        }
    }
    let send_burst = |net: &mut F, rng: &mut SplitMix64, count: u32| -> u64 {
        let mut sent = 0;
        for _ in 0..count {
            let i = rng.gen_range(k);
            let mut j = rng.gen_range(k);
            if j == i {
                j = (j + 1) % k;
            }
            let len = 1 + rng.gen_range(600);
            net.send(&eps[i], nodes[j], Message::new(vec![0x5A; len]));
            sent += 1;
        }
        sent
    };
    let mut sent = send_burst(net, &mut rng, 60);
    // Let the first burst get airborne, then fail two random links.
    let mid_flight = net.now() + 2_000;
    net.run_until(&mut NullApp, mid_flight);
    let links = net.topo().link_count();
    let l1 = LinkId(rng.gen_range(links) as u32);
    let l2 = LinkId(rng.gen_range(links) as u32);
    net.fail_link(l1);
    net.fail_link(l2);
    sent += send_burst(net, &mut rng, 60);
    let after_failures = net.now() + 50_000;
    net.run_until(&mut NullApp, after_failures);
    // Repair and send a final wave.
    net.repair_link(l1);
    net.repair_link(l2);
    sent += send_burst(net, &mut rng, 40);
    net.run(&mut NullApp);
    let received: u64 = {
        let mut total = 0;
        for ep in &eps {
            total += net.recv(ep).len() as u64;
        }
        total
    };
    (sent, received)
}

#[test]
fn prop_no_loss_under_link_failures_every_mode_both_engines() {
    for (seed, mode) in [
        (1u64, CommMode::Postmaster { queue: 0 }),
        (2, CommMode::Postmaster { queue: 0 }),
        (3, CommMode::Ethernet { rx: RxMode::Interrupt }),
        (4, CommMode::Ethernet { rx: RxMode::Polling { interval: 20_000 } }),
        (5, CommMode::BridgeFifo { width_bits: 64 }),
        (6, CommMode::BridgeFifo { width_bits: 64 }),
    ] {
        let (s, r) = no_loss_case(&mut Network::inc3000(), mode, seed);
        assert_eq!(s, r, "serial {} seed {seed}: lost messages", mode.name());
        let mut sharded = ShardedNetwork::new(SystemConfig::inc3000(), 16);
        let (s2, r2) = no_loss_case(&mut sharded, mode, seed);
        assert_eq!(s2, r2, "sharded {} seed {seed}: lost messages", mode.name());
        assert_eq!(s, s2, "engines saw different schedules");
    }
}

#[test]
#[should_panic(expected = "exceeds the mode's max payload")]
fn prop_postmaster_payload_limit_rejected() {
    let mut net = Network::card();
    let mode = CommMode::Postmaster { queue: 0 };
    let max = net.caps(mode).max_payload.unwrap() as usize;
    let ea = net.open(NodeId(0), mode);
    net.open(NodeId(1), mode);
    net.send(&ea, NodeId(1), Message::new(vec![0; max + 1]));
}

#[test]
#[should_panic(expected = "exceeds the mode's max payload")]
fn prop_tunnel_payload_limit_rejected() {
    let mut net = Network::card();
    let mode = CommMode::Tunnel { addr: inc_sim::node::regs::SCRATCH0 };
    let ea = net.open(NodeId(0), mode);
    net.open(NodeId(1), mode);
    net.send(&ea, NodeId(1), Message::new(vec![0; 9]));
}

#[test]
fn caps_are_engine_agnostic_and_mode_accurate() {
    let serial = Network::inc3000();
    let sharded = ShardedNetwork::new(SystemConfig::inc3000(), 4);
    for mode in [
        CommMode::Postmaster { queue: 0 },
        CommMode::Ethernet { rx: RxMode::Interrupt },
        CommMode::BridgeFifo { width_bits: 64 },
        CommMode::Nfs,
        CommMode::Tunnel { addr: 0 },
        CommMode::Raw,
    ] {
        assert_eq!(Fabric::caps(&serial, mode), Fabric::caps(&sharded, mode), "{}", mode.name());
        let caps = Fabric::caps(&serial, mode);
        assert_eq!(
            caps.pair_setup,
            matches!(mode, CommMode::BridgeFifo { .. }),
            "only Bridge FIFO needs per-pair setup"
        );
        assert_eq!(
            caps.ordering == MsgOrdering::PerPairFifo,
            matches!(mode, CommMode::BridgeFifo { .. }),
            "only Bridge FIFO orders per pair"
        );
    }
}
