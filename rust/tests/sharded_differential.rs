//! Serial ↔ sharded equivalence: the bounded-lag per-cage parallel
//! engine must be **byte-identical** to the serial engine — same
//! delivery trace, same metrics (fabric view; engine-level counters
//! like `windows_merged` are excluded by definition), same final clock
//! — on randomized seeded traffic mixes that include broadcast and
//! multicast crossing cage boundaries, Bridge FIFO, Postmaster,
//! NetTunnel **and internal Ethernet** traffic, on all three presets.
//!
//! Since the engine-agnostic [`Fabric`] refactor the same contract
//! extends to *workloads*: distributed learners, MCTS, the ring
//! all-reduce and the training communication shape run unmodified on
//! either engine and must produce identical app-level results on top
//! of identical traces.
//!
//! The serial engine is the oracle; failures print the (preset, seed).
//!
//! The optimistic (Time Warp) runner is held to the *same* contract:
//! the `timewarp_*` tests below replay mixed traffic, chaos scenarios,
//! the SNN workload and the reliable all-reduce under speculative
//! epochs with checkpoint/rollback — byte-identical traces, fabric-view
//! metrics and clocks, with the engine-level `rollbacks` /
//! `events_replayed` / `checkpoints_bytes` counters excluded from the
//! contract but asserted non-trivial where the scenario forces them.

use inc_sim::channels::ethernet::RxMode;
use inc_sim::channels::reliable::ReliableParams;
use inc_sim::channels::{CommMode, Message};
use inc_sim::config::{SystemConfig, SystemPreset};
use inc_sim::coordinator::{Placement, RingAllreduce};
use inc_sim::network::sharded::ShardedNetwork;
use inc_sim::network::{Delivery, Fabric, Network, NullApp};
use inc_sim::router::{Payload, Proto};
use inc_sim::topology::NodeId;
use inc_sim::util::SplitMix64;
use inc_sim::workload::chaos::scenario::targeted_drop;
use inc_sim::workload::chaos::workloads::{run_workload, ChaosWorkload, WorkloadChaosConfig};
use inc_sim::workload::chaos::{self, ChaosConfig, FaultKind, Scenario};
use inc_sim::workload::learners::{self, LearnerConfig, SendStrategy};
use inc_sim::workload::mcts::{DistributedMcts, Game};
use inc_sim::workload::serving::{self, ArrivalProcess, ServingConfig};
use inc_sim::workload::snn::{self, SnnConfig};
use inc_sim::workload::training::{train_comm, CommShape};

/// Inject a seeded mixed workload: directed packets of varied sizes,
/// broadcasts and sprawling multicasts (both cross cage boundaries on
/// Inc9000), FIFO streams, Postmaster records, tunnel writes, Ethernet
/// frames. One generic generator drives both engines through the
/// [`Fabric`] trait with an identical call sequence — no engine
/// special-casing anywhere.
fn inject_mix<F: Fabric>(d: &mut F, nodes: u32, seed: u64, count: u32) {
    let mut rng = SplitMix64::new(seed);
    let node = |rng: &mut SplitMix64| NodeId(rng.gen_range(nodes as usize) as u32);
    let far_pair = |rng: &mut SplitMix64| {
        let src = NodeId(rng.gen_range(nodes as usize) as u32);
        let mut dst = NodeId(rng.gen_range(nodes as usize) as u32);
        if dst == src {
            dst = NodeId((dst.0 + nodes / 2 + 1) % nodes);
        }
        (src, dst)
    };
    // A FIFO channel and a Postmaster queue spanning the mesh diagonal
    // (guaranteed cross-shard on every sharded preset).
    let fifo_src = NodeId(0);
    let fifo_dst = NodeId(nodes - 1);
    d.fifo_connect(fifo_src, fifo_dst, 0, 64);
    d.pm_open(NodeId(nodes / 2), 0);

    for i in 0..count {
        match rng.gen_range(100) {
            0..=54 => {
                let (src, dst) = far_pair(&mut rng);
                let payload = match rng.gen_range(3) {
                    0 => Payload::Empty,
                    1 => Payload::Synthetic(16 + rng.gen_range(1000) as u32),
                    _ => Payload::bytes(vec![i as u8; 1 + rng.gen_range(512)]),
                };
                d.send_directed(src, dst, Proto::Raw { tag: 0 }, payload);
            }
            55..=64 => {
                let words: Vec<u64> = (0..1 + rng.gen_range(40)).map(|w| w as u64).collect();
                d.fifo_send(fifo_src, 0, &words);
            }
            65..=74 => {
                let src = node(&mut rng);
                if src != NodeId(nodes / 2) {
                    d.pm_send(src, NodeId(nodes / 2), 0, vec![i as u8; 1 + rng.gen_range(100)]);
                }
            }
            75..=84 => {
                let dsts: Vec<NodeId> = (0..2 + rng.gen_range(6))
                    .map(|_| node(&mut rng))
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .collect();
                let src = node(&mut rng);
                d.send_multicast(src, &dsts, Proto::Raw { tag: 2 }, Payload::Synthetic(64));
            }
            85..=89 => {
                let (src, dst) = far_pair(&mut rng);
                d.tunnel_write(src, dst, 0xF000_0100 + 8 * rng.gen_range(16) as u64, i as u64);
            }
            90..=95 => {
                // Internal Ethernet, including cross-shard frames (the
                // frame rides inside its packet since the Fabric
                // refactor).
                let (src, dst) = far_pair(&mut rng);
                d.eth_send(src, dst, 64 + rng.gen_range(1400) as u32, i as u64);
            }
            _ => {
                d.send_broadcast(node(&mut rng), Proto::Raw { tag: 1 }, Payload::Synthetic(128));
            }
        }
    }
}

/// Assert every observable of two finished engines matches: sorted
/// delivery trace, fabric-view metrics, final clock.
fn assert_same_outcome<A: Fabric, B: Fabric>(serial: &mut A, sharded: &mut B, ctx: &str) {
    let st: Vec<Delivery> = serial.take_trace();
    let sh = sharded.take_trace();
    assert_eq!(st.len(), sh.len(), "{ctx}: delivery counts differ");
    assert_eq!(st, sh, "{ctx}: delivery traces differ");
    assert_eq!(
        serial.metrics().fabric_view(),
        sharded.metrics().fabric_view(),
        "{ctx}: metrics differ"
    );
    assert_eq!(serial.now(), sharded.now(), "{ctx}: final clocks differ");
}

/// Build a sharded engine, optionally in speculative (Time Warp) mode.
fn sharded_engine(sys: SystemConfig, shards: u32, optimistic: bool) -> ShardedNetwork {
    let mut net = ShardedNetwork::new(sys, shards);
    net.set_optimistic(optimistic);
    net
}

/// Run the same mix through both engines and compare everything.
fn assert_equivalent(preset: SystemPreset, shards: u32, seed: u64, count: u32) {
    let nodes = preset.node_count();

    let mut serial = Network::new(SystemConfig::new(preset));
    Fabric::enable_trace(&mut serial);
    inject_mix(&mut serial, nodes, seed, count);
    serial.run_to_quiescence(&mut NullApp);

    let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), shards);
    sharded.enable_trace();
    inject_mix(&mut sharded, nodes, seed, count);
    sharded.run_to_quiescence();

    let ctx = format!("{preset:?} shards={} seed={seed}", sharded.shard_count());
    assert_same_outcome(&mut serial, &mut sharded, &ctx);
    assert_eq!(sharded.live_packets(), 0, "{ctx}: arena leak");
}

#[test]
fn inc9000_four_cages_byte_identical() {
    for seed in [1u64, 2, 3] {
        assert_equivalent(SystemPreset::Inc9000, 4, seed, 400);
    }
}

#[test]
fn inc9000_two_shards_byte_identical() {
    assert_equivalent(SystemPreset::Inc9000, 2, 5, 300);
}

#[test]
fn inc3000_per_card_sharding_byte_identical() {
    // Natural (16-way, per-card) and coarse (4-way) partitions.
    assert_equivalent(SystemPreset::Inc3000, 16, 7, 400);
    assert_equivalent(SystemPreset::Inc3000, 4, 8, 400);
}

#[test]
fn card_single_shard_byte_identical() {
    assert_equivalent(SystemPreset::Card, 1, 9, 300);
}

#[test]
fn injection_between_runs_matches_serial() {
    // The wrapper APIs may be used between runs; shard clocks must sit
    // at the *global* quiescence instant afterwards, or packets
    // injected into a laggard shard would be stamped/scheduled earlier
    // than the serial oracle stamps them.
    let preset = SystemPreset::Inc9000;
    let nodes = preset.node_count();

    let mut serial = Network::new(SystemConfig::new(preset));
    Fabric::enable_trace(&mut serial);
    let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), 4);
    sharded.enable_trace();

    inject_mix(&mut serial, nodes, 21, 150);
    serial.run_to_quiescence(&mut NullApp);
    inject_mix(&mut sharded, nodes, 21, 150);
    sharded.run_to_quiescence();

    // Second wave, injected after quiescence from every cage.
    for i in 0..40u32 {
        let src = NodeId((i * 433) % nodes);
        let dst = NodeId((i * 997 + 7) % nodes);
        if src != dst {
            serial.send_directed(src, dst, Proto::Raw { tag: 3 }, Payload::Synthetic(96));
            sharded.send_directed(src, dst, Proto::Raw { tag: 3 }, Payload::Synthetic(96));
        }
    }
    serial.run_to_quiescence(&mut NullApp);
    sharded.run_to_quiescence();

    assert_same_outcome(&mut serial, &mut sharded, "two-phase");
}

#[test]
fn sharded_runs_are_reproducible_across_thread_schedules() {
    // Two sharded runs of the same mix: identical traces (the mailbox
    // merge order is canonical, so OS scheduling cannot leak in).
    let run = || {
        let mut net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        net.enable_trace();
        inject_mix(&mut net, 1728, 42, 300);
        let events = net.run_to_quiescence();
        (events, net.now(), net.take_trace(), net.metrics())
    };
    let (e1, t1, tr1, m1) = run();
    let (e2, t2, tr2, m2) = run();
    assert_eq!(e1, e2);
    assert_eq!(t1, t2);
    assert_eq!(tr1, tr2);
    // Including the engine-level counters: window merging is itself
    // deterministic.
    assert_eq!(m1, m2);
}

#[test]
fn fifo_words_arrive_in_order_across_cage_boundary() {
    // End-to-end channel correctness through the sharded engine: FIFO
    // reorder logic spans shards (tx unit in one, rx unit in another).
    let mut net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
    let src = NodeId(0); // cage 0
    let dst = NodeId(1727); // cage 3
    assert_ne!(net.shard_of(src), net.shard_of(dst));
    net.fifo_connect(src, dst, 0, 64);
    let words: Vec<u64> = (0..500).collect();
    for chunk in words.chunks(23) {
        net.fifo_send(src, 0, chunk);
    }
    net.run_to_quiescence();
    assert_eq!(net.fifo_read(dst, 0, 1000), words);
    assert_eq!(net.live_packets(), 0);
}

// ---------------------------------------------------------------------
// run_until / run_window parity: drivers step either engine through
// identical deadlines without special-casing.
// ---------------------------------------------------------------------

#[test]
fn stepped_run_until_matches_serial_at_every_deadline() {
    let preset = SystemPreset::Inc9000;
    let nodes = preset.node_count();
    let mut serial = Network::new(SystemConfig::new(preset));
    Fabric::enable_trace(&mut serial);
    let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), 4);
    sharded.enable_trace();
    inject_mix(&mut serial, nodes, 31, 200);
    inject_mix(&mut sharded, nodes, 31, 200);

    let mut deadline = 0u64;
    loop {
        deadline += 7_919; // deliberately not window-aligned
        let es = Fabric::run_until(&mut serial, &mut NullApp, deadline);
        let eh = Fabric::run_until(&mut sharded, &mut NullApp, deadline);
        assert_eq!(es, eh, "event counts diverged at deadline {deadline}");
        assert_eq!(serial.now(), deadline, "serial clock lands on the deadline");
        assert_eq!(sharded.now(), deadline, "sharded clock lands on the deadline");
        if es == 0 && eh == 0 && deadline > 1_000_000 {
            break;
        }
        assert!(deadline < 1_000_000_000, "runaway");
    }
    assert_same_outcome(&mut serial, &mut sharded, "stepped run_until");
}

#[test]
fn run_window_stops_both_engines_at_the_last_event() {
    let preset = SystemPreset::Inc9000;
    let mut serial = Network::new(SystemConfig::new(preset));
    let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), 4);
    serial.send_directed(NodeId(3), NodeId(1700), Proto::Raw { tag: 0 }, Payload::Synthetic(64));
    sharded.send_directed(NodeId(3), NodeId(1700), Proto::Raw { tag: 0 }, Payload::Synthetic(64));
    let deadline = 3_000; // mid-flight
    Fabric::run_window(&mut serial, &mut NullApp, deadline);
    Fabric::run_window(&mut sharded, &mut NullApp, deadline);
    assert_eq!(serial.now(), sharded.now(), "window clocks differ");
    assert!(serial.now() <= deadline);
    // Finish the flight; everything still matches.
    serial.run_to_quiescence(&mut NullApp);
    sharded.run_to_quiescence();
    assert_eq!(serial.now(), sharded.now());
}

// ---------------------------------------------------------------------
// Workload differentials: the same workload code (via the Fabric
// trait) on both engines, compared on app-level results *and* fabric
// observables.
// ---------------------------------------------------------------------

#[test]
fn learners_overlap_identical_on_sharded_engine() {
    // Learner grid strided across all 16 cards of Inc3000: every
    // neighbor exchange crosses a shard boundary on the per-card
    // partition.
    let cfg = LearnerConfig {
        learners: 32,
        outputs_per_step: 8,
        record_bytes: 48,
        compute_ns: 30_000,
        steps: 2,
        stride: 13,
        ..LearnerConfig::default()
    };
    for strategy in [SendStrategy::Streamed, SendStrategy::Aggregated] {
        let mut serial = Network::inc3000();
        Fabric::enable_trace(&mut serial);
        let mut sharded = ShardedNetwork::new(SystemConfig::inc3000(), 16);
        sharded.enable_trace();
        let ss = learners::run(&mut serial, cfg, strategy);
        let sh = learners::run(&mut sharded, cfg, strategy);
        assert_eq!(ss, sh, "per-step stats differ ({strategy:?})");
        assert_same_outcome(&mut serial, &mut sharded, &format!("learners {strategy:?}"));
    }
}

#[test]
fn learners_comm_modes_identical_on_sharded_engine() {
    // The acceptance differential for first-class communication modes:
    // the identical workload over Postmaster, internal Ethernet and
    // Bridge FIFO — byte-identical traces, fabric-view metrics
    // (including the per-mode traffic totals) and per-step stats across
    // the serial engine and 1- and 16-shard sharded engines.
    for comm in [
        CommMode::Postmaster { queue: 0 },
        CommMode::Ethernet { rx: RxMode::Interrupt },
        CommMode::BridgeFifo { width_bits: 64 },
    ] {
        let cfg = LearnerConfig {
            learners: 16,
            outputs_per_step: 6,
            record_bytes: 48,
            compute_ns: 25_000,
            steps: 2,
            stride: 13,
            comm,
            reliable: None,
        };
        let mut serial = Network::inc3000();
        Fabric::enable_trace(&mut serial);
        let ss = learners::run(&mut serial, cfg, SendStrategy::Streamed);
        for shards in [1u32, 16] {
            let mut sharded = ShardedNetwork::new(SystemConfig::inc3000(), shards);
            sharded.enable_trace();
            let sh = learners::run(&mut sharded, cfg, SendStrategy::Streamed);
            let ctx = format!("learners comm={} shards={shards}", comm.name());
            assert_eq!(ss, sh, "{ctx}: per-step stats differ");
            // Sorted traces: take_trace() on the serial side is
            // consumed by the first comparison, so re-compare metrics
            // and clock per shard count and the trace once below.
            assert_eq!(
                serial.metrics().fabric_view(),
                sharded.metrics().fabric_view(),
                "{ctx}: metrics differ"
            );
            assert!(
                serial
                    .metrics()
                    .mode_traffic
                    .get(comm.name())
                    .is_some_and(|t| t.messages == 16 * 6 * 2),
                "{ctx}: per-mode accounting missing"
            );
            assert_eq!(serial.now(), sharded.now(), "{ctx}: final clocks differ");
            if shards == 16 {
                assert_same_outcome(&mut serial, &mut sharded, &ctx);
            }
        }
    }
}

#[test]
fn mcts_fifo_mode_identical_on_sharded_engine() {
    // The lowest-latency mode under the control-heavy workload: task
    // and result messages ride Bridge-FIFO channels (per-pair setup,
    // word framing) across card-shard boundaries.
    let mode = CommMode::BridgeFifo { width_bits: 64 };
    let game = Game { depth: 5, branching: 3, seed: 11 };
    let leader = NodeId(0);
    let workers: Vec<NodeId> = (0..5u32).map(|i| NodeId(31 + i * 67)).collect();

    let mut serial = Network::inc3000();
    Fabric::enable_trace(&mut serial);
    let s = DistributedMcts::with_mode(&mut serial, game, leader, workers.clone(), mode);
    let rs = s.search(&mut serial, 400);

    let mut sharded = ShardedNetwork::new(SystemConfig::inc3000(), 16);
    sharded.enable_trace();
    let p = DistributedMcts::with_mode(&mut sharded, game, leader, workers, mode);
    let rp = p.search(&mut sharded, 400);

    assert_eq!(rs.best_path, rp.best_path, "fifo-mode search results differ");
    assert_eq!(rs.makespan, rp.makespan);
    assert_same_outcome(&mut serial, &mut sharded, "mcts fifo mode");
}

#[test]
fn mcts_identical_on_sharded_engine() {
    // Leader in card 0, workers spread across the Inc3000 mesh: task
    // and result records cross card-shard boundaries continuously.
    let game = Game { depth: 5, branching: 3, seed: 11 };
    let leader = NodeId(0);
    let workers: Vec<NodeId> = (0..6u32).map(|i| NodeId(31 + i * 67)).collect();

    let mut serial = Network::inc3000();
    Fabric::enable_trace(&mut serial);
    let s = DistributedMcts::new(&mut serial, game, leader, workers.clone());
    let rs = s.search(&mut serial, 500);

    let mut sharded = ShardedNetwork::new(SystemConfig::inc3000(), 16);
    sharded.enable_trace();
    let p = DistributedMcts::new(&mut sharded, game, leader, workers);
    let rp = p.search(&mut sharded, 500);

    assert_eq!(rs.best_path, rp.best_path, "search results differ");
    assert_eq!(rs.best_value, rp.best_value);
    assert_eq!(rs.rollouts, rp.rollouts);
    assert_eq!(rs.makespan, rp.makespan);
    assert_same_outcome(&mut serial, &mut sharded, "mcts");
}

#[test]
fn ring_allreduce_identical_across_cages() {
    // Ranks scattered across all four Inc9000 cages; every ring step
    // crosses a cage boundary somewhere.
    let bytes = 256 * 1024;
    let mut serial = Network::new(SystemConfig::inc9000());
    Fabric::enable_trace(&mut serial);
    let ranks = Placement::Scattered.select(&serial.topo, 8);
    let ss = RingAllreduce::new(&mut serial, ranks.clone(), bytes).run(&mut serial);

    let mut sharded = ShardedNetwork::new(SystemConfig::inc9000(), 4);
    sharded.enable_trace();
    let sh = RingAllreduce::new(&mut sharded, ranks, bytes).run(&mut sharded);

    assert_eq!(ss, sh, "collective stats differ");
    assert_same_outcome(&mut serial, &mut sharded, "ring all-reduce");
}

#[test]
fn training_comm_shape_identical_on_sharded_engine_per_mode() {
    // The training loop's fabric side (compute windows + per-step ring
    // all-reduce) under the stub runtime, ranks scattered across cages
    // — over every gradient transport (`TrainConfig`/`CommShape` carry
    // a `CommMode`: `repro train --comm pm|eth|fifo`).
    for comm in [
        CommMode::Postmaster { queue: 0 },
        CommMode::BridgeFifo { width_bits: 64 },
        CommMode::Ethernet { rx: RxMode::Interrupt },
    ] {
        let shape = CommShape {
            ranks: 8,
            steps: 2,
            grad_bytes: if matches!(comm, CommMode::Ethernet { .. }) { 16 * 1024 } else { 64 * 1024 },
            compute_ns: 100_000,
            placement: Placement::Scattered,
            comm,
        };
        let mut serial = Network::new(SystemConfig::inc9000());
        Fabric::enable_trace(&mut serial);
        let rs = train_comm(&mut serial, &shape);

        let mut sharded = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        sharded.enable_trace();
        let rp = train_comm(&mut sharded, &shape);

        let ctx = format!("train_comm comm={}", comm.name());
        assert_eq!(rs, rp, "{ctx}: reports differ");
        assert!(rs.vtime_comm > 0, "{ctx}");
        assert!(
            serial.metrics().mode_traffic.get(comm.name()).is_some_and(|t| t.messages > 0),
            "{ctx}: gradient traffic missing from the mode's bucket"
        );
        assert_same_outcome(&mut serial, &mut sharded, &ctx);
    }
}

/// Generate sparse, time-staggered traffic: short Postmaster bursts
/// local to far-apart corners of the mesh, produced in disjoint time
/// phases, plus one cross-mesh record at the end. Engine-agnostic —
/// identical call sequence on both engines.
fn inject_sparse_staggered<F: Fabric>(d: &mut F) {
    let nodes = d.topo().node_count() as u32;
    let pm = CommMode::Postmaster { queue: 0 };
    // Two pairs in opposite corners of the mesh (far-apart shards under
    // every partition of this test).
    let (a0, a1) = (NodeId(0), NodeId(1));
    let (b0, b1) = (NodeId(nodes - 2), NodeId(nodes - 1));
    let eps: Vec<_> = [a0, a1, b0, b1].iter().map(|&n| d.open(n, pm)).collect();
    // Phase 1 (t ≈ 0): a few records inside the low corner.
    for i in 0..4u64 {
        d.send_at(i * 2_000, &eps[0], a1, Message::new(vec![i as u8; 32]));
    }
    // Phase 2 (t ≈ 300 µs): records inside the high corner.
    for i in 0..4u64 {
        d.send_at(300_000 + i * 2_000, &eps[2], b1, Message::new(vec![i as u8; 32]));
    }
    // Phase 3 (t ≈ 600 µs): the low corner again.
    for i in 0..3u64 {
        d.send_at(600_000 + i * 2_000, &eps[1], a0, Message::new(vec![i as u8; 32]));
    }
    // Phase 4 (t ≈ 900 µs): *both* corners at the same instants. With
    // the corners several link-hops apart, both owning shards' horizons
    // clear the window at once, so they sprint in the *same* epochs —
    // the genuinely multi-shard case no alternating-solo scheme covers
    // (at 2 shards the corners are 1 hop apart and this phase simply
    // runs in lockstep; the staggered phases above still sprint).
    for i in 0..4u64 {
        d.send_at(900_000 + i * 2_000, &eps[0], a1, Message::new(vec![i as u8; 32]));
        d.send_at(900_000 + i * 2_000, &eps[2], b1, Message::new(vec![i as u8; 32]));
    }
    // Finally one record all the way across — a sprint must stop at its
    // first boundary export and re-enter lockstep byte-identically.
    d.send_at(1_000_000, &eps[0], b1, Message::new(vec![9; 32]));
}

#[test]
fn multi_shard_batching_sparse_traffic_byte_identical() {
    // The distance-aware generalization of the solo sprint: with sparse
    // traffic confined to far-apart corners in disjoint time phases,
    // *both* active shards must coalesce windows (the old solo rule
    // allowed only a shard that was alone in having pending events),
    // and the result must stay byte-identical to the serial oracle —
    // across coarse and natural partitions.
    for shards in [2u32, 4, 16] {
        let mut serial = Network::inc3000();
        Fabric::enable_trace(&mut serial);
        inject_sparse_staggered(&mut serial);
        serial.run_to_quiescence(&mut NullApp);

        let mut sharded = ShardedNetwork::new(SystemConfig::inc3000(), shards);
        sharded.enable_trace();
        inject_sparse_staggered(&mut sharded);
        sharded.run_to_quiescence();

        let ctx = format!("sparse staggered shards={}", sharded.shard_count());
        assert_same_outcome(&mut serial, &mut sharded, &ctx);
        assert_eq!(sharded.live_packets(), 0, "{ctx}: arena leak");
        let merging: Vec<u64> = sharded
            .shards()
            .iter()
            .map(|s| s.metrics.windows_merged)
            .filter(|&w| w > 0)
            .collect();
        assert!(
            merging.len() >= 2,
            "{ctx}: expected >= 2 shards to merge windows simultaneously, got {merging:?}"
        );
    }
}

#[test]
fn ethernet_and_nfs_cross_shard_identical() {
    // Cross-cage internal Ethernet (frames ride inside packets) plus an
    // NFS put from the far cage through the cage-0 gateway.
    let mut serial = Network::new(SystemConfig::inc9000());
    Fabric::enable_trace(&mut serial);
    let mut sharded = ShardedNetwork::new(SystemConfig::inc9000(), 4);
    sharded.enable_trace();
    let far = NodeId(1700); // cage 3
    assert_ne!(sharded.shard_of(far), sharded.shard_of(sharded.gateway()));

    // Identical call sequence on both engines.
    let (a, b) = (NodeId(5), NodeId(1650));
    serial.eth_send_message(a, b, 100_000, 1);
    serial.nfs_put(far, "ckpt.bin", 50_000);
    serial.run_to_quiescence(&mut NullApp);
    sharded.eth_send_message(a, b, 100_000, 1);
    sharded.nfs_put(far, "ckpt.bin", 50_000);
    sharded.run_to_quiescence();

    let fs = serial.eth_read(NodeId(1650));
    let fh = Fabric::eth_read(&mut sharded, NodeId(1650));
    assert_eq!(fs, fh, "delivered frames differ");
    assert_eq!(fs.iter().map(|f| f.bytes as u64).sum::<u64>(), 100_000);
    assert_eq!(
        serial.eth.external.files.get("ckpt.bin"),
        sharded.eth_external().files.get("ckpt.bin"),
    );
    assert_eq!(sharded.eth_external().files.get("ckpt.bin"), Some(&50_000));
    assert_same_outcome(&mut serial, &mut sharded, "ethernet/nfs");
}

// ---------------------------------------------------------------------
// Chaos differentials (E13): a seeded fault script + background traffic
// is one deterministic experiment — the serial and sharded engines must
// replay it byte-identically *including* the graded SLO report, the
// reroute-convergence figure and the bounded-buffer drop/stall counts.
// ---------------------------------------------------------------------

/// Run one chaos scenario on both engines with identical configs and
/// compare the full outcome: SLO report (`==`), sorted trace, fabric
/// metrics, final clock. Returns the (identical) report plus the
/// sharded engine's rollback count (always 0 conservatively).
fn chaos_equivalent(
    preset: SystemPreset,
    shards: u32,
    scenario: Scenario,
    seed: u64,
    optimistic: bool,
) -> (chaos::SloReport, u64) {
    let ccfg = ChaosConfig::new(scenario, seed);
    let mut sys = SystemConfig::new(preset);
    sys.rx_capacity = ccfg.suggested_rx_capacity();

    let mut serial = Network::new(sys.clone());
    Fabric::enable_trace(&mut serial);
    let rs = chaos::run(&mut serial, &ccfg, 1);

    let mut sharded = sharded_engine(sys, shards, optimistic);
    sharded.enable_trace();
    let k = sharded.shard_count();
    let mut rp = chaos::run(&mut sharded, &ccfg, k);

    let engine = if optimistic { "optimistic" } else { "sharded" };
    let ctx = format!("chaos {} {preset:?} {engine} shards={k} seed={seed}", scenario.name());
    // The shard count is presentation metadata, not an observable.
    rp.shards = 1;
    assert_eq!(rs, rp, "{ctx}: SLO reports differ");
    assert_same_outcome(&mut serial, &mut sharded, &ctx);
    assert!(rs.passed(), "{ctx}: SLO violations {:?}", rs.violations());
    (rs, sharded.metrics().rollbacks)
}

fn assert_chaos_equivalent(
    preset: SystemPreset,
    shards: u32,
    scenario: Scenario,
    seed: u64,
) -> chaos::SloReport {
    chaos_equivalent(preset, shards, scenario, seed, false).0
}

#[test]
fn chaos_storm_byte_identical_across_shard_counts() {
    // The acceptance gate: identical delivery traces, SLO metrics and
    // drop/stall counts at shards {2, 4, 16}.
    let r2 = assert_chaos_equivalent(SystemPreset::Inc9000, 2, Scenario::Storm, 42);
    let r4 = assert_chaos_equivalent(SystemPreset::Inc9000, 4, Scenario::Storm, 42);
    assert_eq!(r2, r4, "storm outcome depends on the shard count");
    assert_chaos_equivalent(SystemPreset::Inc3000, 16, Scenario::Storm, 42);
}

#[test]
fn chaos_flap_and_partition_byte_identical() {
    assert_chaos_equivalent(SystemPreset::Inc3000, 16, Scenario::Flap, 7);
    let r = assert_chaos_equivalent(SystemPreset::Inc3000, 16, Scenario::Partition, 7);
    assert!(r.convergence_ns > 0, "partition scripted no measurable fault");
    assert_chaos_equivalent(SystemPreset::Inc9000, 4, Scenario::Partition, 3);
}

#[test]
fn chaos_drop_byte_identical() {
    let r = assert_chaos_equivalent(SystemPreset::Inc3000, 16, Scenario::Drop, 9);
    assert_eq!(r.delivered, r.sent, "drop scenario lost surviving-pair traffic");
}

#[test]
fn chaos_hotspot_backpressure_byte_identical() {
    // The bounded receive buffers must *change behavior* (non-zero
    // stall accounting under Postmaster) and still match byte-for-byte
    // across engines — stalls are destination-local accounting, so
    // owner-shard enforcement keeps them identical.
    let pm = assert_chaos_equivalent(SystemPreset::Inc3000, 16, Scenario::Hotspot, 5);
    assert!(pm.stalled_ns > 0, "hotspot never tripped credit-withhold backpressure");
    assert_eq!(pm.dropped, 0, "guaranteed mode dropped");

    // Same storm over best-effort Ethernet: drops instead of stalls.
    let mut ccfg = ChaosConfig::new(Scenario::Hotspot, 5);
    ccfg.comm = CommMode::Ethernet { rx: RxMode::Interrupt };
    let mut sys = SystemConfig::new(SystemPreset::Inc3000);
    sys.rx_capacity = ccfg.suggested_rx_capacity();
    let mut serial = Network::new(sys.clone());
    Fabric::enable_trace(&mut serial);
    let rs = chaos::run(&mut serial, &ccfg, 1);
    let mut sharded = ShardedNetwork::new(sys, 16);
    sharded.enable_trace();
    let k = sharded.shard_count();
    let mut rp = chaos::run(&mut sharded, &ccfg, k);
    rp.shards = 1;
    assert_eq!(rs, rp, "hotspot(eth) SLO reports differ");
    assert_same_outcome(&mut serial, &mut sharded, "chaos hotspot eth");
    assert!(rs.dropped > 0, "bounded Ethernet inbox never dropped");
    assert_eq!(rs.stalled_ns, 0, "best-effort mode stalled");
}

// ---------------------------------------------------------------------
// Reliable-transport differentials (E14): ack/retransmit endpoints,
// targeted deaths and the workload-chaos harness are part of the same
// byte-identity contract — retransmit timers, duplicate suppression,
// liveness declarations and ring-shrink restarts must all replay
// identically on the sharded engine.
// ---------------------------------------------------------------------

/// A reliable ring all-reduce with ranks scattered across the mesh and
/// a targeted mid-transfer death, driven tick-by-tick on either engine.
/// Returns every app-level observable (completion, surviving
/// membership, the survivors' sum, every rank's reduced value).
fn reliable_allreduce_under_drop<F: Fabric>(
    net: &mut F,
    victim_idx: usize,
) -> (bool, u64, u64, Vec<u64>) {
    let tick_ns = 50_000u64;
    let topo = net.topo().clone();
    let ranks = Placement::Scattered.select(&topo, 8);
    let victim = ranks[victim_idx];
    // Tight detection: the retry budget (30+60+120+240 µs of backoff)
    // and the liveness threshold land the declaration mid-run.
    let params = ReliableParams {
        rto_ns: 30_000,
        max_retries: 4,
        heartbeat_ns: 50_000,
        liveness_ns: 300_000,
        ..ReliableParams::default()
    };
    let mut ar = RingAllreduce::with_mode_reliable(
        net,
        ranks.clone(),
        256 * 1024,
        CommMode::Postmaster { queue: 0 },
        params,
        5_000_000,
    );
    let script = targeted_drop(&topo, &[victim], tick_ns, tick_ns);
    assert_eq!(script.excluded, vec![victim], "victim not severable");
    ar.kickoff(net);
    let mut next = 0usize;
    for tick in 0..8u64 {
        let t0 = tick * tick_ns;
        while next < script.events.len() && script.events[next].at <= t0 {
            match script.events[next].kind {
                FaultKind::Fail(l) => net.fail_link(l),
                FaultKind::Repair(l) => net.repair_link(l),
            }
            next += 1;
        }
        net.run_until(&mut ar, t0 + tick_ns);
    }
    net.run(&mut ar);
    let dead = ar.dead_union();
    (
        ar.is_complete(),
        dead,
        ar.expected_sum(),
        (0..ranks.len()).map(|i| ar.reduced(i)).collect(),
    )
}

#[test]
fn reliable_allreduce_under_drop_byte_identical_across_shard_counts() {
    // The acceptance gate for the reliable transport: a mid-transfer
    // rank death — retransmit storms, a liveness declaration, a
    // shrink-restart — replays byte-identically at shards {2, 4, 16}.
    for (preset, shard_counts) in [
        (SystemPreset::Inc9000, &[2u32, 4][..]),
        (SystemPreset::Inc3000, &[16u32][..]),
    ] {
        for victim_idx in [2usize, 5] {
            let mut sys = SystemConfig::new(preset);
            sys.drop_unroutable = true;
            let mut serial = Network::new(sys.clone());
            Fabric::enable_trace(&mut serial);
            let os = reliable_allreduce_under_drop(&mut serial, victim_idx);
            let base = format!("{preset:?} victim={victim_idx}");
            assert!(os.0, "{base}: all-reduce did not complete on the survivors");
            assert_eq!(os.1, 1 << victim_idx, "{base}: wrong surviving membership");
            for (i, &v) in os.3.iter().enumerate() {
                if os.1 & (1 << i) == 0 {
                    assert_eq!(v, os.2, "{base}: rank {i} missed the survivors' sum");
                }
            }
            let sm = serial.metrics();
            assert!(sm.retransmits > 0, "{base}: the death forced no retransmits");
            assert!(sm.peers_declared_down > 0, "{base}: the death was never declared");
            let mut first = true;
            for &shards in shard_counts {
                let mut sharded = ShardedNetwork::new(sys.clone(), shards);
                sharded.enable_trace();
                let oh = reliable_allreduce_under_drop(&mut sharded, victim_idx);
                let ctx = format!("{base} shards={}", sharded.shard_count());
                assert_eq!(os, oh, "{ctx}: app-level outcomes differ");
                assert_eq!(
                    serial.metrics().fabric_view(),
                    sharded.metrics().fabric_view(),
                    "{ctx}: metrics differ"
                );
                assert_eq!(serial.now(), sharded.now(), "{ctx}: final clocks differ");
                if first {
                    assert_same_outcome(&mut serial, &mut sharded, &ctx);
                    first = false;
                }
            }
        }
    }
}

#[test]
fn seeded_loss_byte_identical_across_engines() {
    // Fabric-level seeded packet loss is part of the byte-identity
    // contract: the drop decision is a pure hash of (seed, packet id,
    // link), and packet ids are already engine-identical, so both
    // engines must lose exactly the same packets at the same hand-offs
    // under the full mixed workload.
    let mut sys = SystemConfig::new(SystemPreset::Inc3000);
    sys.drop_probability = 0.01;
    let mut serial = Network::new(sys.clone());
    Fabric::enable_trace(&mut serial);
    inject_mix(&mut serial, 432, 17, 300);
    serial.run_to_quiescence(&mut NullApp);

    let mut sharded = ShardedNetwork::new(sys, 16);
    sharded.enable_trace();
    inject_mix(&mut sharded, 432, 17, 300);
    sharded.run_to_quiescence();

    assert!(serial.metrics().link_loss > 0, "1% loss never dropped a packet");
    assert_same_outcome(&mut serial, &mut sharded, "seeded loss");
    assert_eq!(sharded.live_packets(), 0, "seeded loss leaked arena packets");
}

// ---------------------------------------------------------------------
// Serving differentials (E15): the open-loop inference workload —
// gateway-NAT ingress, frontend fan-out, worker replies, latency
// accounting — must replay byte-identically on the sharded engine,
// including shard counts far beyond the host's core count (the epoch
// work-stealing regime) and on the Inc27000 mega preset.
// ---------------------------------------------------------------------

/// Run the identical serving experiment serially and at each shard
/// count; compare the report, delivery trace, fabric metrics and clock.
fn assert_serving_equivalent(preset: SystemPreset, shard_counts: &[u32], cfg: ServingConfig) {
    let mut serial = Network::new(SystemConfig::new(preset));
    Fabric::enable_trace(&mut serial);
    let rs = serving::run(&mut serial, cfg);
    assert_eq!(rs.completed, rs.issued, "{preset:?}: serial serving run lost requests");
    let serial_trace: Vec<Delivery> = serial.take_trace();
    assert!(!serial_trace.is_empty(), "{preset:?}: serving produced no deliveries");
    for &shards in shard_counts {
        let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), shards);
        sharded.enable_trace();
        let rp = serving::run(&mut sharded, cfg);
        let ctx = format!(
            "serving {preset:?} shards={} arrivals={}",
            sharded.shard_count(),
            cfg.arrivals.name()
        );
        assert_eq!(rs, rp, "{ctx}: serving reports differ");
        assert_eq!(serial_trace, sharded.take_trace(), "{ctx}: delivery traces differ");
        assert_eq!(
            serial.metrics().fabric_view(),
            sharded.metrics().fabric_view(),
            "{ctx}: metrics differ"
        );
        assert_eq!(serial.now(), sharded.now(), "{ctx}: final clocks differ");
        assert_eq!(sharded.live_packets(), 0, "{ctx}: arena leak");
    }
}

#[test]
fn serving_byte_identical_across_shard_counts_beyond_cores() {
    // Shards {4, 16, 64} on Inc9000 — 64 card-shards exceeds any CI
    // host's core count, so epoch work-stealing is exercised for real.
    let cfg = ServingConfig {
        requests: 48,
        rate_per_s: 200_000.0,
        stride: 61, // pools spread across cards and cages
        ..Default::default()
    };
    assert_serving_equivalent(SystemPreset::Inc9000, &[4, 16, 64], cfg);
}

#[test]
fn serving_burst_arrivals_byte_identical() {
    // Bursts land many NAT-ingress frames at the same instant: the
    // gateway's physical-port serialization and the same-instant event
    // ordering must both replay identically.
    let cfg = ServingConfig {
        requests: 36,
        arrivals: ArrivalProcess::Bursty { burst: 12 },
        rate_per_s: 150_000.0,
        stride: 19,
        ..Default::default()
    };
    assert_serving_equivalent(SystemPreset::Inc3000, &[16], cfg);
}

#[test]
fn serving_on_inc27000_mega_mesh_byte_identical() {
    // Small-N acceptance run on the 27k-node mega preset: 64 shards
    // (far beyond cores) vs the serial oracle. The full-scale serving
    // figures and the O(owned) index-map assertion live in
    // benches/sim_engine.rs.
    let cfg = ServingConfig {
        frontends: 2,
        workers: 6,
        fanout: 2,
        requests: 10,
        rate_per_s: 100_000.0,
        stride: 997,
        ..Default::default()
    };
    assert_serving_equivalent(SystemPreset::Inc27000, &[64], cfg);
}

#[test]
fn workload_chaos_reports_byte_identical_on_sharded_engine() {
    // The E14 harness end-to-end on both engines: all three workloads
    // under storm and targeted drop — the graded report compares with
    // `==`, and the trace/metrics/clock must match underneath it.
    for workload in ChaosWorkload::ALL {
        for scenario in [Scenario::Storm, Scenario::Drop] {
            let wcfg = WorkloadChaosConfig::new(workload, scenario, 7);
            let mut sys = SystemConfig::new(SystemPreset::Inc3000);
            sys.drop_unroutable = true;
            let mut serial = Network::new(sys.clone());
            Fabric::enable_trace(&mut serial);
            let rs = run_workload(&mut serial, &wcfg, 1);
            let mut sharded = ShardedNetwork::new(sys, 16);
            sharded.enable_trace();
            let k = sharded.shard_count();
            let mut rp = run_workload(&mut sharded, &wcfg, k);
            let ctx = format!("{}/{} shards=16", workload.name(), scenario.name());
            // The shard count is presentation metadata, not an observable.
            rp.shards = 1;
            assert_eq!(rs, rp, "{ctx}: workload reports differ");
            assert_same_outcome(&mut serial, &mut sharded, &ctx);
            assert!(rs.passed(), "{ctx}: violations {:?}", rs.violations());
        }
    }
}

// ---------------------------------------------------------------------
// SNN differentials (E16): the spiking workload — fixed-point LIF
// dynamics at tick timers, re-derived synapse tables, spike multicast
// through the spanning-tree router (or unicast datagrams), per-synapse
// delays on the timing wheel — must replay byte-identically on the
// sharded engine at every shard count.
// ---------------------------------------------------------------------

/// Run the identical SNN experiment serially and at each shard count
/// (conservative or optimistic engine); compare the (normalized)
/// report, delivery trace, metrics and clock.
fn assert_snn_equivalent(
    preset: SystemPreset,
    shard_counts: &[u32],
    cfg: SnnConfig,
    optimistic: bool,
) {
    let mut serial = Network::new(SystemConfig::new(preset));
    Fabric::enable_trace(&mut serial);
    let rs = snn::run(&mut serial, cfg);
    assert!(rs.spikes_emitted > 0, "{preset:?}: snn config produced no spikes");
    let serial_trace: Vec<Delivery> = serial.take_trace();
    assert!(!serial_trace.is_empty(), "{preset:?}: snn produced no deliveries");
    for &shards in shard_counts {
        let mut sharded = sharded_engine(SystemConfig::new(preset), shards, optimistic);
        sharded.enable_trace();
        let rp = snn::run(&mut sharded, cfg);
        let engine = if optimistic { "optimistic" } else { "sharded" };
        let ctx = format!("snn {preset:?} {engine} shards={}", sharded.shard_count());
        // wheel_peak / events_dispatched are engine-level (per-shard
        // wheels); everything else in the report must match exactly.
        assert_eq!(rs.normalized(), rp.normalized(), "{ctx}: snn reports differ");
        assert_eq!(serial_trace, sharded.take_trace(), "{ctx}: delivery traces differ");
        assert_eq!(
            serial.metrics().fabric_view(),
            sharded.metrics().fabric_view(),
            "{ctx}: metrics differ"
        );
        assert_eq!(serial.now(), sharded.now(), "{ctx}: final clocks differ");
        assert_eq!(sharded.live_packets(), 0, "{ctx}: arena leak");
    }
}

#[test]
fn snn_byte_identical_across_engines() {
    // The acceptance matrix: shards {2, 4, 16} on Inc3000 and Inc9000.
    // Population strided across cards and cages so spike fan-out and
    // syn timers cross shard boundaries constantly.
    let cfg = SnnConfig {
        nodes: 12,
        neurons_per_node: 6,
        ticks: 12,
        rate_ppm: 200_000,
        stride: 13,
        ..Default::default()
    };
    assert_snn_equivalent(SystemPreset::Inc3000, &[2, 4, 16], cfg, false);
    let cfg9 = SnnConfig { stride: 61, ..cfg };
    assert_snn_equivalent(SystemPreset::Inc9000, &[2, 4, 16], cfg9, false);
}

#[test]
fn snn_unicast_raw_byte_identical() {
    // The unicast ablation arm: spikes as header-free CommMode::Raw
    // datagrams through the endpoint layer instead of multicast.
    let cfg = SnnConfig {
        nodes: 10,
        neurons_per_node: 5,
        ticks: 10,
        rate_ppm: 250_000,
        comm: Some(CommMode::Raw),
        stride: 17,
        ..Default::default()
    };
    assert_snn_equivalent(SystemPreset::Inc3000, &[4, 16], cfg, false);
}

// ---------------------------------------------------------------------
// Optimistic (Time Warp) differentials (E17): the speculative runner —
// per-shard checkpoints, epoch-ahead execution, straggler rollback and
// replay, GVT-gated export release — must be byte-identical to the
// serial oracle on the same matrix the conservative engine passes:
// dense mixed traffic, chaos scenarios, the SNN workload and the
// reliable all-reduce with a mid-transfer death, at shards {2, 4, 16}.
// ---------------------------------------------------------------------

#[test]
fn timewarp_mixed_traffic_byte_identical_with_rollbacks() {
    // Dense cross-shard traffic is the rollback generator: every shard
    // speculates a full epoch per GVT round while its imports sit
    // withheld upstream, so released stragglers routinely land behind
    // the destination's clock. Aggregated across the matrix, at least
    // one run must actually roll back and replay — otherwise the
    // speculative path was never exercised beyond its fast path.
    let mut rollbacks = 0u64;
    let mut replayed = 0u64;
    for (preset, shards, seed, count) in [
        (SystemPreset::Inc3000, 2u32, 11u64, 400u32),
        (SystemPreset::Inc3000, 4, 12, 400),
        (SystemPreset::Inc3000, 16, 13, 400),
        (SystemPreset::Inc9000, 4, 14, 300),
    ] {
        let nodes = preset.node_count();
        let mut serial = Network::new(SystemConfig::new(preset));
        Fabric::enable_trace(&mut serial);
        inject_mix(&mut serial, nodes, seed, count);
        serial.run_to_quiescence(&mut NullApp);

        let mut opt = sharded_engine(SystemConfig::new(preset), shards, true);
        opt.enable_trace();
        inject_mix(&mut opt, nodes, seed, count);
        opt.run_to_quiescence();

        let ctx = format!("timewarp mix {preset:?} shards={} seed={seed}", opt.shard_count());
        assert_same_outcome(&mut serial, &mut opt, &ctx);
        assert_eq!(opt.live_packets(), 0, "{ctx}: arena leak");
        let m = opt.metrics();
        assert!(m.checkpoints_bytes > 0, "{ctx}: optimistic run never checkpointed");
        // Engine counters stay out of the byte-identity contract (the
        // fabric-view comparison above already enforces this; restate
        // the invariant explicitly).
        assert_eq!(m.fabric_view().rollbacks, 0, "{ctx}: rollbacks leaked into fabric view");
        rollbacks += m.rollbacks;
        replayed += m.events_replayed;
    }
    assert!(rollbacks > 0, "dense mixed traffic never forced a rollback");
    assert!(replayed > 0, "rollbacks recorded but nothing replayed");
}

#[test]
fn timewarp_chaos_storm_byte_identical_across_shard_counts() {
    // The storm scenario under speculation at shards {2, 4, 16}: link
    // faults, reroutes and bounded-buffer pressure replay identically,
    // and the graded SLO report is independent of the shard count.
    let (r2, _) = chaos_equivalent(SystemPreset::Inc9000, 2, Scenario::Storm, 42, true);
    let (r4, _) = chaos_equivalent(SystemPreset::Inc9000, 4, Scenario::Storm, 42, true);
    assert_eq!(r2, r4, "storm outcome depends on the shard count under speculation");
    chaos_equivalent(SystemPreset::Inc3000, 16, Scenario::Storm, 42, true);
}

#[test]
fn timewarp_chaos_hotspot_byte_identical_across_shard_counts() {
    // Hotspot backpressure (credit-withhold stalls) is destination-
    // local state — exactly what a rollback must restore faithfully.
    for shards in [2u32, 4, 16] {
        let (r, _) = chaos_equivalent(SystemPreset::Inc3000, shards, Scenario::Hotspot, 5, true);
        assert!(r.stalled_ns > 0, "hotspot never tripped backpressure (shards={shards})");
        assert_eq!(r.dropped, 0, "guaranteed mode dropped (shards={shards})");
    }
}

#[test]
fn timewarp_snn_multicast_byte_identical() {
    // The spiking workload: LIF tick timers, spanning-tree spike
    // multicast and per-synapse wheel delays under speculative epochs,
    // at shards {2, 4, 16}.
    let cfg = SnnConfig {
        nodes: 12,
        neurons_per_node: 6,
        ticks: 12,
        rate_ppm: 200_000,
        stride: 13,
        ..Default::default()
    };
    assert_snn_equivalent(SystemPreset::Inc3000, &[2, 4, 16], cfg, true);
}

#[test]
fn timewarp_reliable_allreduce_byte_identical() {
    // The reliable transport's hardest replay — retransmit timers, a
    // liveness declaration, a shrink-restart after a targeted death —
    // under speculation at shards {2, 4, 16}. Timer-heavy endpoint
    // state (RTO backoff, heartbeat schedules) must survive rollback.
    for (preset, shard_counts) in [
        (SystemPreset::Inc9000, &[2u32, 4][..]),
        (SystemPreset::Inc3000, &[16u32][..]),
    ] {
        let victim_idx = 2usize;
        let mut sys = SystemConfig::new(preset);
        sys.drop_unroutable = true;
        let mut serial = Network::new(sys.clone());
        Fabric::enable_trace(&mut serial);
        let os = reliable_allreduce_under_drop(&mut serial, victim_idx);
        let base = format!("timewarp {preset:?} victim={victim_idx}");
        assert!(os.0, "{base}: all-reduce did not complete on the survivors");
        assert_eq!(os.1, 1 << victim_idx, "{base}: wrong surviving membership");
        let mut first = true;
        for &shards in shard_counts {
            let mut opt = sharded_engine(sys.clone(), shards, true);
            opt.enable_trace();
            let oh = reliable_allreduce_under_drop(&mut opt, victim_idx);
            let ctx = format!("{base} shards={}", opt.shard_count());
            assert_eq!(os, oh, "{ctx}: app-level outcomes differ");
            assert_eq!(
                serial.metrics().fabric_view(),
                opt.metrics().fabric_view(),
                "{ctx}: metrics differ"
            );
            assert_eq!(serial.now(), opt.now(), "{ctx}: final clocks differ");
            if first {
                assert_same_outcome(&mut serial, &mut opt, &ctx);
                first = false;
            }
        }
    }
}
