//! Serial ↔ sharded equivalence: the bounded-lag per-cage parallel
//! engine must be **byte-identical** to the serial engine — same
//! delivery trace, same metrics (including latency histograms), same
//! final clock — on randomized seeded traffic mixes that include
//! broadcast and multicast crossing cage boundaries, Bridge FIFO,
//! Postmaster and NetTunnel traffic, on all three presets.
//!
//! The serial engine is the oracle; failures print the (preset, seed).

use inc_sim::config::{SystemConfig, SystemPreset};
use inc_sim::network::sharded::ShardedNetwork;
use inc_sim::network::{Delivery, Network, NullApp};
use inc_sim::router::{Payload, Proto};
use inc_sim::topology::NodeId;
use inc_sim::util::SplitMix64;

/// The injection surface shared by both engines, so one generator
/// drives both with an identical call sequence.
trait Driver {
    fn directed(&mut self, src: NodeId, dst: NodeId, payload: Payload);
    fn broadcast(&mut self, src: NodeId, payload: Payload);
    fn multicast(&mut self, src: NodeId, dsts: &[NodeId], payload: Payload);
    fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8);
    fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]);
    fn pm_open(&mut self, target: NodeId, queue: u8);
    fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>);
    fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64);
}

impl Driver for Network {
    fn directed(&mut self, src: NodeId, dst: NodeId, payload: Payload) {
        self.send_directed(src, dst, Proto::Raw { tag: 0 }, payload);
    }
    fn broadcast(&mut self, src: NodeId, payload: Payload) {
        self.send_broadcast(src, Proto::Raw { tag: 1 }, payload);
    }
    fn multicast(&mut self, src: NodeId, dsts: &[NodeId], payload: Payload) {
        self.send_multicast(src, dsts, Proto::Raw { tag: 2 }, payload);
    }
    fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8) {
        Network::fifo_connect(self, src, dst, channel, 64);
    }
    fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]) {
        Network::fifo_send(self, src, channel, words);
    }
    fn pm_open(&mut self, target: NodeId, queue: u8) {
        Network::pm_open(self, target, queue);
    }
    fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>) {
        Network::pm_send(self, src, target, queue, data);
    }
    fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64) {
        Network::tunnel_write(self, src, dst, addr, value);
    }
}

impl Driver for ShardedNetwork {
    fn directed(&mut self, src: NodeId, dst: NodeId, payload: Payload) {
        self.send_directed(src, dst, Proto::Raw { tag: 0 }, payload);
    }
    fn broadcast(&mut self, src: NodeId, payload: Payload) {
        self.send_broadcast(src, Proto::Raw { tag: 1 }, payload);
    }
    fn multicast(&mut self, src: NodeId, dsts: &[NodeId], payload: Payload) {
        self.send_multicast(src, dsts, Proto::Raw { tag: 2 }, payload);
    }
    fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8) {
        ShardedNetwork::fifo_connect(self, src, dst, channel, 64);
    }
    fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]) {
        ShardedNetwork::fifo_send(self, src, channel, words);
    }
    fn pm_open(&mut self, target: NodeId, queue: u8) {
        ShardedNetwork::pm_open(self, target, queue);
    }
    fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>) {
        ShardedNetwork::pm_send(self, src, target, queue, data);
    }
    fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64) {
        ShardedNetwork::tunnel_write(self, src, dst, addr, value);
    }
}

/// Inject a seeded mixed workload: directed packets of varied sizes,
/// broadcasts and sprawling multicasts (both cross cage boundaries on
/// Inc9000), FIFO streams, Postmaster records, tunnel writes.
fn inject_mix(d: &mut dyn Driver, nodes: u32, seed: u64, count: u32) {
    let mut rng = SplitMix64::new(seed);
    let node = |rng: &mut SplitMix64| NodeId(rng.gen_range(nodes as usize) as u32);
    let far_pair = |rng: &mut SplitMix64| {
        let src = NodeId(rng.gen_range(nodes as usize) as u32);
        let mut dst = NodeId(rng.gen_range(nodes as usize) as u32);
        if dst == src {
            dst = NodeId((dst.0 + nodes / 2 + 1) % nodes);
        }
        (src, dst)
    };
    // A FIFO channel and a Postmaster queue spanning the mesh diagonal
    // (guaranteed cross-shard on every sharded preset).
    let fifo_src = NodeId(0);
    let fifo_dst = NodeId(nodes - 1);
    d.fifo_connect(fifo_src, fifo_dst, 0);
    d.pm_open(NodeId(nodes / 2), 0);

    for i in 0..count {
        match rng.gen_range(100) {
            0..=59 => {
                let (src, dst) = far_pair(&mut rng);
                let payload = match rng.gen_range(3) {
                    0 => Payload::Empty,
                    1 => Payload::Synthetic(16 + rng.gen_range(1000) as u32),
                    _ => Payload::bytes(vec![i as u8; 1 + rng.gen_range(512)]),
                };
                d.directed(src, dst, payload);
            }
            60..=69 => {
                let words: Vec<u64> = (0..1 + rng.gen_range(40)).map(|w| w as u64).collect();
                d.fifo_send(fifo_src, 0, &words);
            }
            70..=79 => {
                let src = node(&mut rng);
                if src != NodeId(nodes / 2) {
                    d.pm_send(src, NodeId(nodes / 2), 0, vec![i as u8; 1 + rng.gen_range(100)]);
                }
            }
            80..=89 => {
                let dsts: Vec<NodeId> = (0..2 + rng.gen_range(6))
                    .map(|_| node(&mut rng))
                    .collect::<std::collections::BTreeSet<_>>()
                    .into_iter()
                    .collect();
                d.multicast(node(&mut rng), &dsts, Payload::Synthetic(64));
            }
            90..=95 => {
                let (src, dst) = far_pair(&mut rng);
                d.tunnel_write(src, dst, 0xF000_0100 + 8 * rng.gen_range(16) as u64, i as u64);
            }
            _ => {
                d.broadcast(node(&mut rng), Payload::Synthetic(128));
            }
        }
    }
}

/// Run the same mix through both engines and compare everything.
fn assert_equivalent(preset: SystemPreset, shards: u32, seed: u64, count: u32) {
    let nodes = preset.node_count();

    let mut serial = Network::new(SystemConfig::new(preset));
    serial.enable_trace();
    inject_mix(&mut serial, nodes, seed, count);
    serial.run_to_quiescence(&mut NullApp);
    let mut serial_trace: Vec<Delivery> = serial.take_trace();
    serial_trace.sort_unstable();

    let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), shards);
    sharded.enable_trace();
    inject_mix(&mut sharded, nodes, seed, count);
    sharded.run_to_quiescence();
    let sharded_trace = sharded.take_trace();

    let ctx = format!("{preset:?} shards={} seed={seed}", sharded.shard_count());
    assert_eq!(
        serial_trace.len(),
        sharded_trace.len(),
        "{ctx}: delivery counts differ"
    );
    assert_eq!(serial_trace, sharded_trace, "{ctx}: delivery traces differ");
    assert_eq!(serial.metrics, sharded.metrics(), "{ctx}: metrics differ");
    assert_eq!(serial.now(), sharded.now(), "{ctx}: final clocks differ");
    assert_eq!(sharded.live_packets(), 0, "{ctx}: arena leak");
}

#[test]
fn inc9000_four_cages_byte_identical() {
    for seed in [1u64, 2, 3] {
        assert_equivalent(SystemPreset::Inc9000, 4, seed, 400);
    }
}

#[test]
fn inc9000_two_shards_byte_identical() {
    assert_equivalent(SystemPreset::Inc9000, 2, 5, 300);
}

#[test]
fn inc3000_per_card_sharding_byte_identical() {
    // Natural (16-way, per-card) and coarse (4-way) partitions.
    assert_equivalent(SystemPreset::Inc3000, 16, 7, 400);
    assert_equivalent(SystemPreset::Inc3000, 4, 8, 400);
}

#[test]
fn card_single_shard_byte_identical() {
    assert_equivalent(SystemPreset::Card, 1, 9, 300);
}

#[test]
fn injection_between_runs_matches_serial() {
    // The wrapper APIs may be used between runs; shard clocks must sit
    // at the *global* quiescence instant afterwards, or packets
    // injected into a laggard shard would be stamped/scheduled earlier
    // than the serial oracle stamps them.
    let preset = SystemPreset::Inc9000;
    let nodes = preset.node_count();

    let mut serial = Network::new(SystemConfig::new(preset));
    serial.enable_trace();
    let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), 4);
    sharded.enable_trace();

    inject_mix(&mut serial, nodes, 21, 150);
    serial.run_to_quiescence(&mut NullApp);
    inject_mix(&mut sharded, nodes, 21, 150);
    sharded.run_to_quiescence();

    // Second wave, injected after quiescence from every cage.
    for i in 0..40u32 {
        let src = NodeId((i * 433) % nodes);
        let dst = NodeId((i * 997 + 7) % nodes);
        if src != dst {
            serial.send_directed(src, dst, Proto::Raw { tag: 3 }, Payload::Synthetic(96));
            sharded.send_directed(src, dst, Proto::Raw { tag: 3 }, Payload::Synthetic(96));
        }
    }
    serial.run_to_quiescence(&mut NullApp);
    sharded.run_to_quiescence();

    let mut st = serial.take_trace();
    st.sort_unstable();
    assert_eq!(st, sharded.take_trace(), "two-phase traces differ");
    assert_eq!(serial.metrics, sharded.metrics(), "two-phase metrics differ");
    assert_eq!(serial.now(), sharded.now(), "two-phase clocks differ");
}

#[test]
fn sharded_runs_are_reproducible_across_thread_schedules() {
    // Two sharded runs of the same mix: identical traces (the mailbox
    // merge order is canonical, so OS scheduling cannot leak in).
    let run = || {
        let mut net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        net.enable_trace();
        inject_mix(&mut net, 1728, 42, 300);
        let events = net.run_to_quiescence();
        (events, net.now(), net.take_trace())
    };
    let (e1, t1, tr1) = run();
    let (e2, t2, tr2) = run();
    assert_eq!(e1, e2);
    assert_eq!(t1, t2);
    assert_eq!(tr1, tr2);
}

#[test]
fn fifo_words_arrive_in_order_across_cage_boundary() {
    // End-to-end channel correctness through the sharded engine: FIFO
    // reorder logic spans shards (tx unit in one, rx unit in another).
    let mut net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
    let src = NodeId(0); // cage 0
    let dst = NodeId(1727); // cage 3
    assert_ne!(net.shard_of(src), net.shard_of(dst));
    net.fifo_connect(src, dst, 0, 64);
    let words: Vec<u64> = (0..500).collect();
    for chunk in words.chunks(23) {
        net.fifo_send(src, 0, chunk);
    }
    net.run_to_quiescence();
    assert_eq!(net.fifo_read(dst, 0, 1000), words);
    assert_eq!(net.live_packets(), 0);
}
