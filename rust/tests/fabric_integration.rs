//! Cross-module integration: channels + diagnostics + workloads over the
//! real routed fabric (no artifacts needed).

use inc_sim::channels::ethernet::RxMode;
use inc_sim::config::SystemPreset;
use inc_sim::diag::sandbox::PcieSandbox;
use inc_sim::network::{Network, NullApp};
use inc_sim::node::regs;
use inc_sim::topology::{Coord, NodeId};

/// The full §4.3 bring-up story: load kernel images over PCIe, broadcast
/// boot, verify every node comes up, then use the running system.
#[test]
fn full_bringup_then_traffic() {
    let mut net = Network::inc3000();
    let mut sb = PcieSandbox::attach((0, 0, 0));

    // Program all 432 FPGAs (fast path) and verify build ids via readall.
    let out = sb.exec(&mut net, "program fpga 0x77 4194304");
    assert!(out.text.contains("432 FPGAs"), "{}", out.text);
    let out = sb.exec(&mut net, "buildids");
    assert!(out.text.contains("0x77"));

    // Load a kernel image everywhere + boot.
    sb.exec(&mut net, "loadall 0x8000 65536");
    sb.exec(&mut net, "boot");
    let t = net.now() + 3 * inc_sim::sim::SEC;
    for n in 0..net.topo.node_count() {
        net.nodes[n].tick_boot(t);
        assert_eq!(net.nodes[n].read_addr(regs::BOOT_STATUS, t), 2, "node {n}");
    }

    // With Linux up, internal Ethernet works across cards.
    let a = net.topo.id(Coord { x: 0, y: 0, z: 0 });
    let b = net.topo.id(Coord { x: 11, y: 11, z: 2 });
    net.eth_send_message(a, b, 100_000, 1);
    net.run_to_quiescence(&mut NullApp);
    let frames = net.eth_read(b);
    assert_eq!(frames.iter().map(|f| f.bytes as u64).sum::<u64>(), 100_000);
}

/// All three virtual channels coexist on the same links (Packet Mux,
/// Fig 5) without crosstalk.
#[test]
fn channels_coexist_on_shared_links() {
    let mut net = Network::card();
    let (a, b) = (NodeId(0), NodeId(1));
    net.fifo_connect(a, b, 0, 64);
    net.pm_open(b, 0);
    for i in 0..50u64 {
        net.fifo_send(a, 0, &[i]);
        net.pm_send(a, b, 0, vec![i as u8; 32]);
        net.eth_send(a, b, 256, i);
    }
    net.run_to_quiescence(&mut NullApp);
    assert_eq!(net.fifo_read(b, 0, 100), (0..50).collect::<Vec<u64>>());
    assert_eq!(net.pm_read(b, 0).len(), 50);
    assert_eq!(net.eth_read(b).len(), 50);
}

/// Paper §3.1 ordering claim: per-channel overhead ordering
/// bridge FIFO < postmaster < ethernet for small transfers.
#[test]
fn channel_overhead_ordering() {
    // Compare end-to-end *delivery* latencies (quiescence time also
    // includes the credit-return tail, which is not user-visible).
    let fifo = {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(1));
        net.fifo_connect(a, b, 0, 64);
        net.fifo_send(a, 0, &[1, 2, 3, 4, 5, 6, 7, 8]);
        net.run_to_quiescence(&mut NullApp);
        net.metrics.latency("bridge_fifo").unwrap().max()
    };
    let pm = {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(1));
        net.pm_open(b, 0);
        net.pm_send(a, b, 0, vec![0; 64]);
        net.run_to_quiescence(&mut NullApp);
        let recs = net.pm_read(b, 0);
        recs[0].t_stored - recs[0].t_enqueued
    };
    let eth = {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(1));
        net.eth_send(a, b, 64, 0);
        net.run_to_quiescence(&mut NullApp);
        net.metrics.packet_latency["eth_frame"].max()
    };
    assert!(fifo < pm, "fifo {fifo} < postmaster {pm}");
    assert!(pm < eth / 4, "postmaster {pm} ≪ ethernet {eth}");
}

/// NetTunnel and Ring Bus agree on register contents.
#[test]
fn tunnel_and_ringbus_agree() {
    let mut net = Network::card();
    let target = NodeId(17);
    net.ring_write((0, 0, 0), NodeId(0), target, regs::SCRATCH0, 0xCAFE);
    let req = net.tunnel_read(NodeId(0), target, regs::SCRATCH0);
    net.run_to_quiescence(&mut NullApp);
    assert_eq!(net.tunnel_result(req), Some(0xCAFE));
    let (v, _) = net.ring_read((0, 0, 0), NodeId(0), target, regs::SCRATCH0);
    assert_eq!(v, 0xCAFE);
}

/// Polling vs interrupt CPU-efficiency claim holds at INC 3000 scale too.
#[test]
fn polling_efficiency_at_scale() {
    let run = |mode: RxMode| {
        let mut net = Network::new(inc_sim::config::SystemConfig::new(SystemPreset::Inc3000));
        let dst = net.topo.id(Coord { x: 6, y: 6, z: 1 });
        net.eth_set_mode(dst, mode);
        for i in 0..64u32 {
            let src = NodeId(i);
            if src != dst {
                for _ in 0..4 {
                    net.eth_send(src, dst, 1024, 0);
                }
            }
        }
        net.run_to_quiescence(&mut NullApp);
        net.nodes[dst.0 as usize].cpu_busy_ns
    };
    let irq = run(RxMode::Interrupt);
    let poll = run(RxMode::Polling { interval: 20_000 });
    assert!(poll < irq, "polling {poll} should use less CPU than IRQ {irq}");
}

/// NFS save path (§3.1): node data reaches external storage via the
/// (100) gateway.
#[test]
fn nfs_checkpoint_roundtrip() {
    let mut net = Network::card();
    let node = net.topo.id(Coord { x: 2, y: 1, z: 2 });
    net.nfs_put(node, "weights.ckpt", 200_000);
    net.run_to_quiescence(&mut NullApp);
    assert_eq!(net.eth.external.files.get("weights.ckpt"), Some(&200_000));
}

/// §2.4 extension: multicast delivers exactly one copy to each listed
/// destination, sharing tree prefixes (fewer link traversals than the
/// equivalent directed sends).
#[test]
fn multicast_exactly_once_and_cheaper_than_unicast() {
    use inc_sim::router::{Packet, Payload, Proto};

    struct Count(std::collections::HashMap<u32, u32>);
    impl inc_sim::network::App for Count {
        fn on_raw(&mut self, _net: &mut Network, node: NodeId, _p: &Packet) {
            *self.0.entry(node.0).or_insert(0) += 1;
        }
    }

    let mut net = Network::card();
    let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
    let dsts: Vec<NodeId> = [(2, 0, 0), (2, 1, 0), (2, 2, 0), (2, 2, 1), (0, 0, 1)]
        .iter()
        .map(|&(x, y, z)| net.topo.id(Coord { x, y, z }))
        .collect();
    net.send_multicast(src, &dsts, Proto::Raw { tag: 5 }, Payload::bytes(vec![1; 512]));
    let mut app = Count(Default::default());
    net.run_to_quiescence(&mut app);
    assert_eq!(app.0.len(), dsts.len());
    for d in &dsts {
        assert_eq!(app.0[&d.0], 1, "node {d} copies");
    }
    let mcast_bytes: u64 = net.links.iter().map(|l| l.sent_bytes).sum();

    // Same delivery via directed sends costs strictly more wire bytes.
    let mut net2 = Network::card();
    for d in &dsts {
        net2.send_directed(src, *d, Proto::Raw { tag: 5 }, Payload::bytes(vec![1; 512]));
    }
    net2.run_to_quiescence(&mut NullApp);
    let unicast_bytes: u64 = net2.links.iter().map(|l| l.sent_bytes).sum();
    assert!(
        mcast_bytes < unicast_bytes,
        "multicast {mcast_bytes} B should beat unicast {unicast_bytes} B"
    );
}

/// §2.4 extension: defect avoidance — packets still deliver with links
/// failed, at a bounded hop penalty.
#[test]
fn defect_avoidance_routes_around_failed_links() {
    use inc_sim::router::{Packet, Payload, Proto};

    struct Got(Vec<u32>);
    impl inc_sim::network::App for Got {
        fn on_raw(&mut self, _net: &mut Network, _node: NodeId, p: &Packet) {
            self.0.push(p.hops);
        }
    }

    let mut net = Network::card();
    let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
    let dst = net.topo.id(Coord { x: 2, y: 0, z: 0 });
    // Fail every +x link out of the source column's first hop.
    let to_fail: Vec<_> = net
        .topo
        .out_links(src)
        .iter()
        .copied()
        .filter(|&l| net.topo.link(l).dir == inc_sim::topology::Dir::XPlus)
        .collect();
    for l in to_fail {
        net.fail_link(l);
    }
    net.send_directed(src, dst, Proto::Raw { tag: 6 }, Payload::Empty);
    let mut app = Got(vec![]);
    net.run_to_quiescence(&mut app);
    assert_eq!(app.0.len(), 1, "packet must still deliver");
    let hops = app.0[0];
    assert!(hops > 2, "must have detoured (min is 2), took {hops}");
    // Adaptive escape may bounce between the blocked column and its
    // neighbors a few times before the RNG picks a forward link; the
    // hop budget (4×min + 64) bounds it, and in practice it stays small.
    assert!(hops <= 20, "detour should be bounded, took {hops}");
}
