//! Integration: PJRT runtime loads the AOT artifacts and the numeric
//! contract holds end to end (requires `make artifacts`).
//!
//! Compiled only with the `pjrt` cargo feature (the default offline
//! build has no PJRT backend).
#![cfg(feature = "pjrt")]

use inc_sim::runtime::{self, Runtime};

fn rt() -> Runtime {
    runtime::load_default().expect("run `make artifacts` before cargo test")
}

#[test]
fn artifacts_load_and_compile() {
    let rt = rt();
    assert!(["cpu", "host"].contains(&rt.platform().to_lowercase().as_str()));
    for name in ["init", "grad", "apply", "fwd"] {
        assert!(rt.entry(name).is_ok(), "missing entry point {name}");
    }
}

#[test]
fn init_params_are_deterministic_and_shaped() {
    let rt = rt();
    let a = rt.execute_f32("init", &[]).unwrap();
    let b = rt.execute_f32("init", &[]).unwrap();
    assert_eq!(a.len(), rt.entry("init").unwrap().outputs.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x, y, "init must be deterministic");
    }
    // RMS-norm gains initialize to ones.
    let specs = &rt.entry("init").unwrap().outputs;
    let lnf_idx = specs.iter().position(|s| s.name == "p:lnf").unwrap();
    assert!(a[lnf_idx].iter().all(|&v| v == 1.0));
}

#[test]
fn grad_returns_loss_near_uniform_and_nonzero_grads() {
    let rt = rt();
    let params = rt.execute_f32("init", &[]).unwrap();
    let ep = rt.entry("grad").unwrap().clone();
    let x_spec = &ep.inputs[ep.inputs.len() - 2];
    let (b, t) = (x_spec.shape[0], x_spec.shape[1]);
    let (x, y) = inc_sim::workload::training::gen_batch(64, b, t, 42);
    let mut inputs = params.clone();
    inputs.push(x);
    inputs.push(y);
    let out = rt.execute_f32("grad", &inputs).unwrap();
    let loss = out[0][0];
    // ln(64) ≈ 4.16 at (near-uniform) init.
    assert!((loss - 64f32.ln()).abs() < 0.5, "loss {loss}");
    let grad_norm: f32 = out[1..].iter().flatten().map(|g| g * g).sum::<f32>().sqrt();
    assert!(grad_norm > 1e-3, "gradients should be nonzero, got {grad_norm}");
    assert!(grad_norm.is_finite());
}

#[test]
fn apply_moves_params_against_gradient() {
    let rt = rt();
    let params = rt.execute_f32("init", &[]).unwrap();
    let n = params.len();
    // grads = params (so p' = (1 - lr) p).
    let mut inputs = params.clone();
    inputs.extend(params.clone());
    inputs.push(vec![0.5f32]);
    let out = rt.execute_f32("apply", &inputs).unwrap();
    assert_eq!(out.len(), n);
    for (p, p2) in params.iter().zip(&out) {
        for (a, b) in p.iter().zip(p2) {
            assert!((b - 0.5 * a).abs() < 1e-6);
        }
    }
}

#[test]
fn input_validation_errors_are_helpful() {
    let rt = rt();
    let err = rt.execute_f32("grad", &[]).unwrap_err().to_string();
    assert!(err.contains("expected"), "{err}");
    let err = rt.execute_f32("nope", &[]).unwrap_err().to_string();
    assert!(err.contains("no entry point"), "{err}");
}
