//! # INC-Sim
//!
//! A reproduction of the **IBM Neural Computer** (INC) architecture
//! (Narayanan et al., *Overview of the IBM Neural Computer Architecture*,
//! CS.DC 2020) as a deterministic, nanosecond-resolution discrete-event
//! simulation, together with the machine-intelligence workload stack the
//! paper motivates (Rust coordinator + JAX/Pallas compute AOT-compiled to
//! XLA and executed through PJRT).
//!
//! The INC is a 3D mesh of up to 1728 Zynq (ARM + FPGA) nodes connected by
//! 1 GB/s SERDES links with hardware credit flow control. On top of the
//! packet router, three virtual channels are provided — Internal Ethernet,
//! Postmaster DMA and Bridge FIFO — plus a family of diagnostic fabrics
//! (JTAG, Ring Bus, NetTunnel, PCIe Sandbox). This crate models all of
//! them; see `DESIGN.md` for the subsystem inventory and the calibration
//! of simulated time against the paper's measurements (Table 1 etc.).
//!
//! ## Layering
//!
//! * [`sim`] — deterministic discrete-event engine (virtual time).
//! * [`topology`] — cards, cages, systems; single-span and multi-span links.
//! * [`link`] — SERDES link model with byte-credit flow control.
//! * [`router`] — adaptive directed routing + exactly-once broadcast.
//! * [`network`] — the assembled fabric: nodes × routers × links; both
//!   the serial engine and the bounded-lag per-cage parallel engine
//!   ([`network::sharded`]) live here, unified behind the
//!   engine-agnostic [`network::Fabric`] trait that workloads and
//!   coordinators are written against.
//! * [`channels`] — Internal Ethernet, Postmaster DMA, Bridge FIFO,
//!   unified behind the first-class [`channels::CommMode`] /
//!   [`channels::Endpoint`] API (open/send/recv over any mode).
//! * [`diag`] — JTAG, Ring Bus, NetTunnel, PCIe Sandbox.
//! * [`node`] — per-node model: ARM costs, DRAM, registers, boot.
//! * [`runtime`] — PJRT executable loading (AOT artifacts from JAX).
//! * [`coordinator`] — job placement, collectives, timestep scheduling.
//! * [`workload`] — distributed training, MCTS, distributed learners.
//! * [`metrics`] — counters and latency histograms.
//! * [`config`] — calibrated timing/size constants and system presets.

pub mod channels;
pub mod config;
pub mod coordinator;
pub mod diag;
pub mod link;
pub mod metrics;
pub mod network;
pub mod node;
pub mod router;
pub mod runtime;
pub mod sim;
pub mod topology;
pub mod util;
pub mod workload;

pub use channels::{ChannelCaps, CommMode, Endpoint, Message, MsgId};
pub use config::{LinkTiming, SystemConfig, SystemPreset};
pub use network::sharded::ShardedNetwork;
pub use network::{App, Delivery, Domain, Fabric, Network, NullApp, ShardableApp};
pub use sim::{Sim, Time};
pub use topology::{Coord, NodeId, Topology};
