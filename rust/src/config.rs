//! Calibrated timing / sizing constants and system presets.
//!
//! All virtual-time constants are calibrated against the numbers the paper
//! reports (see DESIGN.md §3):
//!
//! * Links run at 1 GB/s (§2.3) ⇒ serialization delay of **1 ns per byte**.
//! * Table 1 (Bridge FIFO latency vs hops {0: 0.25 µs, 1: 1.1 µs,
//!   3: 2.5 µs, 6: 4.7 µs}) is fit by
//!   `t(h) = FIFO_LOGIC + INJECT + h * (ROUTER_LATENCY + ser(len))`
//!   with `FIFO_LOGIC = 250 ns`, `INJECT = 150 ns`,
//!   `ROUTER_LATENCY = 684 ns` (a 16-byte Bridge-FIFO packet serializes in
//!   16 ns, giving a 700 ns effective hop). Fit error ≤ 2.2 % on the four
//!   published points.
//! * JTAG / FLASH programming constants are calibrated to §4.3's reported
//!   times (27 FPGAs ≈ 15 min over JTAG vs seconds over PCIe; 27 FLASH
//!   chips > 5 h over JTAG vs ≈ 2 min over PCIe).


use crate::sim::Time;

/// Link-level timing calibration (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct LinkTiming {
    /// Serialization bandwidth of one unidirectional SERDES connection,
    /// in bytes per nanosecond. The paper's links are 1 GB/s ⇒ 1.0.
    pub bytes_per_ns: f64,
    /// Fixed per-hop router pipeline latency (arbitration, crossbar,
    /// SERDES encode/decode, wire flight), excluding serialization.
    pub router_latency: Time,
    /// One-time injection overhead at the source node (packet mux +
    /// router ingress), paid once per packet regardless of hop count.
    pub inject_latency: Time,
    /// Receive-side credit buffer per incoming link, in bytes. The credit
    /// protocol never lets more than this many un-acknowledged bytes be in
    /// flight towards a receiver (§2.3).
    pub credit_buffer_bytes: u32,
    /// Maximum network packet payload (channels fragment above this).
    pub mtu: u32,
}

impl Default for LinkTiming {
    fn default() -> Self {
        LinkTiming {
            bytes_per_ns: 1.0,
            router_latency: 684,
            inject_latency: 150,
            credit_buffer_bytes: 4096,
            mtu: 2048,
        }
    }
}

impl LinkTiming {
    /// Serialization delay for `bytes` on one link.
    pub fn ser(&self, bytes: u32) -> Time {
        (bytes as f64 / self.bytes_per_ns).ceil() as Time
    }

    /// Effective per-hop latency for a packet of `bytes` total wire size.
    pub fn hop(&self, bytes: u32) -> Time {
        self.router_latency + self.ser(bytes)
    }
}

/// ARM-software-path cost model (Internal Ethernet, §3.1 / Fig 3).
///
/// These are *model* constants for the ARM Cortex-A9 at 667 MHz running
/// Linux; they are chosen so the qualitative ordering the paper asserts
/// holds (TCP/IP stack ≫ Postmaster ≳ Bridge FIFO; polling beats IRQ under
/// high traffic) and are in line with published Zynq-7000 measurements.
#[derive(Debug, Clone, Copy)]
pub struct ArmCosts {
    /// Kernel network-stack traversal per packet (tx or rx), ns.
    pub kernel_stack: Time,
    /// Ethernet device-driver work per packet (descriptor management), ns.
    pub driver: Time,
    /// DMA setup cost per descriptor (ARM side), ns.
    pub dma_setup: Time,
    /// AXI-HP DMA bandwidth between DRAM and FPGA fabric, bytes/ns.
    pub axi_bytes_per_ns: f64,
    /// Hardware-interrupt entry/exit + handler cost per interrupt, ns.
    pub irq_cost: Time,
    /// Polling-loop check cost per poll iteration, ns.
    pub poll_cost: Time,
    /// Postmaster queue write (memory-mapped store + fabric pickup), ns.
    pub postmaster_enqueue: Time,
    /// Postmaster target-side DMA engine setup per packet, ns.
    pub postmaster_dma: Time,
}

impl Default for ArmCosts {
    fn default() -> Self {
        ArmCosts {
            kernel_stack: 9_000,
            driver: 2_500,
            dma_setup: 900,
            axi_bytes_per_ns: 1.2,
            irq_cost: 4_000,
            poll_cost: 300,
            postmaster_enqueue: 60,
            postmaster_dma: 250,
        }
    }
}

/// Programming-path calibration (§4.3).
#[derive(Debug, Clone, Copy)]
pub struct ProgrammingModel {
    /// Zynq-7000 (XC7Z020-class) configuration bitstream size in bytes.
    pub bitstream_bytes: u64,
    /// Effective JTAG throughput in bits per second when configuring
    /// FPGAs through the daisy chain. Calibrated: 27 × 32 Mbit / 900 s
    /// ≈ 0.96 Mbit/s.
    pub jtag_fpga_bits_per_s: f64,
    /// Effective JTAG throughput when programming FLASH through the chain
    /// (indirect programming; erase + verify dominated). Calibrated:
    /// 27 × 32 Mbit / 5 h ≈ 48 kbit/s.
    pub jtag_flash_bits_per_s: f64,
    /// Local FLASH controller write bandwidth (erase+program), bytes/s.
    /// Calibrated so one 4 MB image programs in ≈ 2 min (§4.3).
    pub flash_write_bytes_per_s: f64,
    /// PCIe 2.0 x4 effective host→node(000) bandwidth, bytes/s.
    pub pcie_bytes_per_s: f64,
    /// FPGA configuration-port (PCAP) bandwidth for local configuration,
    /// bytes/s (≈145 MB/s on Zynq-7000).
    pub fpga_config_bytes_per_s: f64,
    /// Host-side orchestration overhead per programming operation, ns
    /// (PCIe Sandbox command setup, status polling, verification
    /// readbacks). Calibrated so FPGA programming over PCIe lands at the
    /// paper's "couple of seconds, including the data transfer".
    pub host_overhead_ns: u64,
}

impl Default for ProgrammingModel {
    fn default() -> Self {
        ProgrammingModel {
            bitstream_bytes: 4 * 1024 * 1024,
            jtag_fpga_bits_per_s: 0.96e6,
            jtag_flash_bits_per_s: 48.0e3,
            flash_write_bytes_per_s: 4.0 * 1024.0 * 1024.0 / 120.0, // 4 MiB in ≈120 s
            pcie_bytes_per_s: 1.6e9,
            fpga_config_bytes_per_s: 145.0e6,
            host_overhead_ns: 1_500_000_000,
        }
    }
}

/// Ring Bus timing (§4.2): 27 unidirectional point-to-point links.
#[derive(Debug, Clone, Copy)]
pub struct RingBusTiming {
    /// Per-ring-hop forward latency, ns.
    pub hop: Time,
    /// Ring payload word size, bytes (requests/responses are one word).
    pub word_bytes: u32,
}

impl Default for RingBusTiming {
    fn default() -> Self {
        RingBusTiming { hop: 120, word_bytes: 8 }
    }
}

/// Which machine to build (Fig 2). Every preset is a card grid — a
/// mesh is `cards × 3` nodes per axis — so `dims`, `node_count`,
/// `card_count` and cage structure stay closed-form for arbitrary
/// sizes: the named presets are fixed points in the same
/// [`SystemPreset::Custom`] parameter space (§2.1: the 3d mesh
/// "scales to hundreds of thousands of nodes").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemPreset {
    /// One card — the INC 300: 3×3×3 = 27 nodes (Fig 2c).
    Card,
    /// INC 3000: 16 cards on one backplane, 12×12×3 = 432 nodes (Fig 2b).
    Inc3000,
    /// INC 9000: four cages, 12×12×12 = 1728 nodes (Fig 2a, "not yet built").
    Inc9000,
    /// Synthetic mega mesh: 16 cages of 8×8 cards, 24×24×48 = 27 648
    /// nodes — one order of magnitude past INC 9000, following the
    /// paper's cage-stacking rules.
    Inc27000,
    /// Synthetic mega mesh: 16 cages of 16×16 cards, 48×48×48 =
    /// 110 592 nodes — the §2.1 "hundreds of thousands of nodes" scale.
    Inc100k,
    /// An arbitrary card grid (`cards` per axis; a card is 3×3×3
    /// nodes, a cage is one z layer of cards).
    Custom { cards: (u32, u32, u32) },
}

impl SystemPreset {
    /// Card-grid dimensions (cards per axis) — the shared closed form
    /// every named preset reduces to.
    pub fn cards_dims(self) -> (u32, u32, u32) {
        match self {
            SystemPreset::Card => (1, 1, 1),
            SystemPreset::Inc3000 => (4, 4, 1),
            SystemPreset::Inc9000 => (4, 4, 4),
            SystemPreset::Inc27000 => (8, 8, 16),
            SystemPreset::Inc100k => (16, 16, 16),
            SystemPreset::Custom { cards } => cards,
        }
    }

    /// Mesh dimensions (x, y, z).
    pub fn dims(self) -> (u32, u32, u32) {
        let (cx, cy, cz) = self.cards_dims();
        assert!(cx > 0 && cy > 0 && cz > 0, "degenerate card grid {:?}", (cx, cy, cz));
        (cx * 3, cy * 3, cz * 3)
    }

    pub fn node_count(self) -> u32 {
        let (x, y, z) = self.dims();
        x * y * z
    }

    pub fn card_count(self) -> u32 {
        self.node_count() / 27
    }

    /// Parse a preset name, a node count, or a `CXxCYxCZ` card grid
    /// (e.g. `8x8x16`). `inc300` is the single-card machine's product
    /// name (Fig 2c) — an alias of `card`, kept deliberately.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "card" | "inc300" | "27" => return Some(SystemPreset::Card),
            "inc3000" | "3000" | "432" => return Some(SystemPreset::Inc3000),
            "inc9000" | "9000" | "1728" => return Some(SystemPreset::Inc9000),
            "inc27000" | "27000" | "27648" => return Some(SystemPreset::Inc27000),
            "inc100k" | "100k" | "110592" => return Some(SystemPreset::Inc100k),
            _ => {}
        }
        let mut it = s.split('x').map(|p| p.parse::<u32>().ok());
        match (it.next(), it.next(), it.next(), it.next()) {
            (Some(Some(cx)), Some(Some(cy)), Some(Some(cz)), None)
                if cx > 0 && cy > 0 && cz > 0 =>
            {
                Some(SystemPreset::Custom { cards: (cx, cy, cz) })
            }
            _ => None,
        }
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub preset: SystemPreset,
    pub link: LinkTiming,
    pub arm: ArmCosts,
    pub programming: ProgrammingModel,
    pub ringbus: RingBusTiming,
    /// Seed folded into the per-packet adaptive-routing tie-break hash
    /// (a stateless [`crate::util::mix64`] of (seed, packet, node, hop);
    /// there is no RNG stream, so routing is independent of dispatch
    /// order — see [`crate::network::sharded`]).
    pub seed: u64,
    /// Bridge-FIFO logic latency (Table 1 hop-0 case), ns.
    pub bridge_fifo_logic: Time,
    /// NetTunnel execution latency, ns (§3.4): the time the tunnel
    /// logic in the fabric hardware takes to perform a register/memory
    /// access at the destination node once the packet leaves the Packet
    /// Demux. NetTunnel carries Ring-Bus semantics over the main
    /// fabric, so this is calibrated to the same order of magnitude as
    /// a [`RingBusTiming::hop`] — both are short FPGA-logic paths with
    /// no ARM involvement. Previously hardcoded in the demux.
    pub tunnel_exec_latency: Time,
    /// Worker threads for the sharded engine
    /// ([`crate::network::sharded::ShardedNetwork`]): 0 = one per shard,
    /// capped at the machine's available parallelism.
    pub sim_threads: usize,
    /// Per-endpoint receive-buffer bound, in queued messages
    /// ([`crate::channels::ChannelCaps::rx_capacity`]). When an inbox is
    /// at capacity the mode's full-buffer semantics apply: internal
    /// Ethernet drops the message ([`crate::metrics::Metrics::dropped`]),
    /// Postmaster and Bridge FIFO withhold receive credit and charge the
    /// sender ([`crate::metrics::Metrics::stalled_ns`]), NFS and
    /// NetTunnel reject loudly. The default is sized so ordinary
    /// workloads never hit it — chaos scenarios shrink it to study
    /// backpressure (`repro chaos --rx-cap N`).
    pub rx_capacity: u32,
    /// Virtual time a credit-withheld sender is charged per record that
    /// lands on a full guaranteed-delivery inbox: the receiver must
    /// drain one message slot before re-issuing credit. Accounting-only
    /// (the record is still delivered; packet timing is unchanged).
    pub rx_drain_ns: Time,
    /// Lossy-routing mode for chaos / reliable-transport studies. The
    /// router normally treats an unroutable packet as a programming
    /// error and panics (hop-budget livelock, fully disconnected node).
    /// With this flag set, such packets are *dropped* instead — counted
    /// in [`crate::metrics::Metrics::dropped`] — which is what a real
    /// mesh does when a destination dies mid-flight. Both drop
    /// decisions are local to the routing node (its own out-links and
    /// its own hop counter), so serial and sharded engines drop the
    /// same packets at the same instants and stay byte-identical.
    /// Default `false`: ordinary runs keep the loud-failure contract.
    pub drop_unroutable: bool,
    /// Per-link-transmission random loss probability (0.0 = lossless).
    /// When a packet is about to start serializing onto a link, a
    /// stateless [`crate::util::mix64`] of (seed, packet id, link) is
    /// compared against this threshold; on loss the link eats the
    /// packet before any credits are consumed, counted in
    /// [`crate::metrics::Metrics::link_loss`]. There is no RNG stream,
    /// so the drop decision is a pure function of packet identity —
    /// independent of dispatch order and of *when* the attempt happens
    /// (ready link vs later drain), keeping serial and sharded engines
    /// byte-identical. Pair with the reliable transport
    /// ([`crate::channels::reliable`]) to exercise retransmission
    /// without scripted chaos faults (`repro chaos --scenario loss`).
    pub drop_probability: f64,
    /// DRAM capacity per node, bytes (1 GB, §2).
    pub dram_bytes: u64,
}

impl SystemConfig {
    pub fn new(preset: SystemPreset) -> Self {
        SystemConfig {
            preset,
            link: LinkTiming::default(),
            arm: ArmCosts::default(),
            programming: ProgrammingModel::default(),
            ringbus: RingBusTiming::default(),
            seed: 0x1BC0FFEE,
            bridge_fifo_logic: 250,
            tunnel_exec_latency: 100,
            sim_threads: 0,
            rx_capacity: 65_536,
            rx_drain_ns: 500,
            drop_unroutable: false,
            drop_probability: 0.0,
            dram_bytes: 1 << 30,
        }
    }

    pub fn card() -> Self {
        Self::new(SystemPreset::Card)
    }

    pub fn inc3000() -> Self {
        Self::new(SystemPreset::Inc3000)
    }

    pub fn inc9000() -> Self {
        Self::new(SystemPreset::Inc9000)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_fit_within_published_tolerance() {
        // The paper's Table 1: latency vs hops for a 1-word Bridge FIFO
        // transfer. Wire size of a 1-word Bridge FIFO packet is 16 bytes
        // (8B header + 8B word).
        let cfg = SystemConfig::card();
        let t = |hops: u32| -> f64 {
            let mut ns = cfg.bridge_fifo_logic as f64;
            if hops > 0 {
                ns += cfg.link.inject_latency as f64;
                ns += hops as f64 * cfg.link.hop(16) as f64;
            }
            ns / 1000.0 // µs
        };
        let published = [(0u32, 0.25f64), (1, 1.1), (3, 2.5), (6, 4.7)];
        for (hops, us) in published {
            let got = t(hops);
            let err = (got - us).abs() / us;
            assert!(
                err < 0.03,
                "hops={hops}: model {got:.3} µs vs paper {us} µs (err {err:.3})"
            );
        }
    }

    #[test]
    fn presets() {
        assert_eq!(SystemPreset::Card.node_count(), 27);
        assert_eq!(SystemPreset::Inc3000.node_count(), 432);
        assert_eq!(SystemPreset::Inc9000.node_count(), 1728);
        assert_eq!(SystemPreset::Inc27000.node_count(), 27_648);
        assert_eq!(SystemPreset::Inc100k.node_count(), 110_592);
        assert_eq!(SystemPreset::Inc3000.card_count(), 16);
        assert_eq!(SystemPreset::Inc9000.card_count(), 64);
        assert_eq!(SystemPreset::Inc27000.card_count(), 1024);
        assert_eq!(SystemPreset::Inc100k.card_count(), 4096);
        assert_eq!(SystemPreset::parse("inc3000"), Some(SystemPreset::Inc3000));
        assert_eq!(SystemPreset::parse("CARD"), Some(SystemPreset::Card));
        assert_eq!(SystemPreset::parse("bogus"), None);
    }

    #[test]
    fn preset_parse_round_trips() {
        // Every named preset parses back from its canonical name, and
        // the mega presets are fixed points of the shared Custom card
        // grid (closed-form dims/card_count, no special cases).
        let named = [
            ("card", SystemPreset::Card),
            ("inc3000", SystemPreset::Inc3000),
            ("inc9000", SystemPreset::Inc9000),
            ("inc27000", SystemPreset::Inc27000),
            ("inc100k", SystemPreset::Inc100k),
        ];
        for (name, preset) in named {
            assert_eq!(SystemPreset::parse(name), Some(preset), "{name}");
            // Node-count aliases round-trip too.
            let count = preset.node_count().to_string();
            assert_eq!(SystemPreset::parse(&count), Some(preset), "{count}");
            // The equivalent Custom grid agrees on every closed form.
            let custom = SystemPreset::Custom { cards: preset.cards_dims() };
            assert_eq!(custom.dims(), preset.dims());
            assert_eq!(custom.node_count(), preset.node_count());
            assert_eq!(custom.card_count(), preset.card_count());
        }
        // `inc300` is the single-card machine's product name.
        assert_eq!(SystemPreset::parse("inc300"), Some(SystemPreset::Card));
        // Card-grid syntax.
        assert_eq!(
            SystemPreset::parse("8x8x16"),
            Some(SystemPreset::Custom { cards: (8, 8, 16) })
        );
        assert_eq!(SystemPreset::parse("8x8x16").unwrap().node_count(), 27_648);
        assert_eq!(SystemPreset::parse("0x2x2"), None, "degenerate grid");
        assert_eq!(SystemPreset::parse("2x2"), None, "missing axis");
        assert_eq!(SystemPreset::parse("2x2x2x2"), None, "extra axis");
    }

    #[test]
    fn serialization_delay_is_one_ns_per_byte() {
        let lt = LinkTiming::default();
        assert_eq!(lt.ser(1), 1);
        assert_eq!(lt.ser(2048), 2048);
    }

    #[test]
    fn programming_model_matches_reported_times() {
        let p = ProgrammingModel::default();
        // 27 FPGAs over JTAG ≈ 15 min (§4.3).
        let jtag_s =
            27.0 * p.bitstream_bytes as f64 * 8.0 / p.jtag_fpga_bits_per_s;
        assert!((jtag_s / 60.0 - 15.0).abs() < 1.5, "jtag = {} min", jtag_s / 60.0);
        // 27 FLASH over JTAG > 5 h.
        let jtag_flash_s =
            27.0 * p.bitstream_bytes as f64 * 8.0 / p.jtag_flash_bits_per_s;
        assert!(jtag_flash_s > 5.0 * 3600.0);
        // One FLASH locally ≈ 2 min (all program in parallel over PCIe).
        let flash_s = p.bitstream_bytes as f64 / p.flash_write_bytes_per_s;
        assert!((flash_s / 60.0 - 2.0).abs() < 0.5, "flash = {} min", flash_s / 60.0);
    }
}
