//! Sparse memory: word overlay + bulk regions.
//!
//! Nodes have 1 GB of DRAM each and an INC 3000 has 432 of them; backing
//! it all with real allocations would need hundreds of GB when the boot
//! broadcast loads multi-MB images everywhere. Bulk loads therefore store
//! `Arc` regions (shared across all nodes of a broadcast — O(1) per
//! node), while word writes (NetTunnel/RingBus debug pokes, checkpoints)
//! go to a sparse overlay that shadows the regions.

use std::collections::BTreeMap;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct SparseMem {
    size: u64,
    /// Word overlay (address → value); takes precedence over regions.
    words: BTreeMap<u64, u64>,
    /// Bulk regions: (offset, data), later entries shadow earlier ones.
    regions: Vec<(u64, Arc<Vec<u8>>)>,
    pub bytes_written: u64,
}

impl SparseMem {
    pub fn new(size: u64) -> Self {
        SparseMem { size, words: BTreeMap::new(), regions: Vec::new(), bytes_written: 0 }
    }

    pub fn size(&self) -> u64 {
        self.size
    }

    /// Install a bulk region (e.g. a kernel image at its load address).
    pub fn write_region(&mut self, offset: u64, data: Arc<Vec<u8>>) {
        assert!(
            offset + data.len() as u64 <= self.size,
            "region [{offset}, +{}) exceeds memory size {}",
            data.len(),
            self.size
        );
        self.bytes_written += data.len() as u64;
        self.regions.push((offset, data));
    }

    pub fn write_u64(&mut self, addr: u64, value: u64) {
        assert!(addr + 8 <= self.size);
        self.bytes_written += 8;
        self.words.insert(addr, value);
    }

    pub fn read_u64(&self, addr: u64) -> u64 {
        if let Some(v) = self.words.get(&addr) {
            return *v;
        }
        // Later regions shadow earlier ones.
        for (off, data) in self.regions.iter().rev() {
            if addr >= *off && addr + 8 <= *off + data.len() as u64 {
                let i = (addr - off) as usize;
                return u64::from_le_bytes(data[i..i + 8].try_into().unwrap());
            }
        }
        0
    }

    pub fn read_byte(&self, addr: u64) -> u8 {
        if let Some(v) = self.words.get(&(addr & !7)) {
            return v.to_le_bytes()[(addr & 7) as usize];
        }
        for (off, data) in self.regions.iter().rev() {
            if addr >= *off && addr < *off + data.len() as u64 {
                return data[(addr - off) as usize];
            }
        }
        0
    }

    pub fn region_count(&self) -> usize {
        self.regions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_roundtrip() {
        let mut m = SparseMem::new(1 << 20);
        m.write_u64(64, 0x1122334455667788);
        assert_eq!(m.read_u64(64), 0x1122334455667788);
        assert_eq!(m.read_byte(64), 0x88); // little-endian
        assert_eq!(m.read_u64(128), 0);
    }

    #[test]
    fn regions_shared_and_shadowed() {
        let mut m = SparseMem::new(1 << 20);
        let img = Arc::new((0..255u8).collect::<Vec<u8>>());
        m.write_region(0x1000, img.clone());
        assert_eq!(m.read_byte(0x1000), 0);
        assert_eq!(m.read_byte(0x1005), 5);
        // Word overlay shadows the region.
        m.write_u64(0x1000, u64::MAX);
        assert_eq!(m.read_byte(0x1000), 0xFF);
        // Later region shadows earlier (outside the word overlay).
        m.write_region(0x1009, Arc::new(vec![9, 9]));
        assert_eq!(m.read_byte(0x1009), 9);
        assert_eq!(m.read_byte(0x100B), 11);
    }

    #[test]
    #[should_panic(expected = "exceeds memory size")]
    fn oversized_region_rejected() {
        let mut m = SparseMem::new(1024);
        m.write_region(1000, Arc::new(vec![0; 100]));
    }

    #[test]
    fn read_u64_from_region() {
        let mut m = SparseMem::new(1 << 20);
        let bytes: Vec<u8> = 0x0102030405060708u64.to_le_bytes().to_vec();
        m.write_region(0, Arc::new(bytes));
        assert_eq!(m.read_u64(0), 0x0102030405060708);
    }
}
