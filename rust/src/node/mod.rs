//! Per-node model: ARM Cortex-A9 + FPGA fabric + 1 GB DRAM (§2, §4).
//!
//! We do not execute ARM instructions; the ARM is a *cost model*
//! (`cpu_busy_ns`, charged by the software paths in
//! [`crate::channels::ethernet`]) plus the register/memory state the
//! diagnostics need: a 4 GB address space (1 GB DRAM + hardware register
//! windows) reachable by Ring Bus / NetTunnel / PCIe Sandbox, a boot
//! state machine driven by a boot command register, FPGA bitstream and
//! FLASH images with programming-completion timestamps, a UART console
//! buffer, EEPROM contents (serial, MAC) and a temperature sensor.

mod mem;

pub use mem::SparseMem;

use std::sync::Arc;

use crate::config::SystemConfig;
use crate::sim::Time;
use crate::topology::NodeId;

/// Hardware register addresses (the 0xF000_0000 window).
pub mod regs {
    /// Writing a nonzero value initiates boot from the DRAM-loaded image.
    pub const BOOT_CMD: u64 = 0xF000_0000;
    /// 0 = idle, 1 = booting, 2 = Linux up.
    pub const BOOT_STATUS: u64 = 0xF000_0008;
    /// FPGA bitstream build id (set when configuration completes).
    pub const BUILD_ID: u64 = 0xF000_0010;
    /// Die temperature, milli-°C.
    pub const TEMP: u64 = 0xF000_0018;
    /// EEPROM: USB-UART serial number.
    pub const EEPROM_SERIAL: u64 = 0xF000_0020;
    /// EEPROM: MAC id of the gateway Ethernet interface.
    pub const EEPROM_MAC: u64 = 0xF000_0028;
    /// System configuration: number of cards present.
    pub const SYS_CARDS: u64 = 0xF000_0030;
    /// Router status (live): packets forwarded by this node.
    pub const ROUTER_PKTS: u64 = 0xF000_0038;
    /// Attach/detach the shared UART console (1 = attached).
    pub const UART_ATTACH: u64 = 0xF000_0040;
    /// General scratch registers for application debug (§4.2).
    pub const SCRATCH0: u64 = 0xF000_0100;
    pub const SCRATCH_COUNT: u64 = 64;
}

/// DRAM occupies the low 1 GB of the 4 GB address space.
pub const DRAM_BASE: u64 = 0x0000_0000;
pub const DRAM_SIZE: u64 = 1 << 30;

/// Boot state machine (driven through `regs::BOOT_CMD`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BootState {
    /// Powered, no kernel loaded/running.
    Idle,
    /// Kernel decompress + init underway; done at the contained time.
    Booting { done_at: Time },
    /// Linux up; software paths (Ethernet driver etc.) available.
    Linux,
}

/// Everything the simulator tracks per node.
#[derive(Debug, Clone)]
pub struct NodeState {
    pub id: NodeId,
    /// Program/data DRAM (sparse).
    pub dram: SparseMem,
    /// Hardware scratch/status registers (sparse overlay; addresses not
    /// listed in [`regs`] read as 0).
    regs: std::collections::BTreeMap<u64, u64>,
    pub boot: BootState,
    /// Cumulative ARM busy time (software-path cost accounting).
    pub cpu_busy_ns: Time,
    /// Next instant the ARM is free: software paths (kernel stack,
    /// driver work) serialize on the CPU, unlike the hardware fabric.
    pub cpu_free_at: Time,
    /// FPGA configuration: (build id, image), plus completion time of the
    /// most recent programming operation.
    pub fpga_image: Option<(u64, Arc<Vec<u8>>)>,
    pub fpga_done_at: Time,
    /// FLASH chip contents + programming completion time.
    pub flash_image: Option<Arc<Vec<u8>>>,
    pub flash_done_at: Time,
    /// UART console ring (visible when attached via the sandbox).
    pub uart: Vec<String>,
    /// Packets this node's router forwarded (diagnostics).
    pub forwarded: u64,
    temp_milli_c: u64,
    eeprom_serial: u64,
    eeprom_mac: u64,
    sys_cards: u64,
}

impl NodeState {
    pub fn new(id: NodeId, cfg: &SystemConfig) -> Self {
        NodeState {
            id,
            dram: SparseMem::new(DRAM_SIZE),
            regs: std::collections::BTreeMap::new(),
            boot: BootState::Idle,
            cpu_busy_ns: 0,
            cpu_free_at: 0,
            fpga_image: None,
            fpga_done_at: 0,
            flash_image: None,
            flash_done_at: 0,
            uart: Vec::new(),
            forwarded: 0,
            // Deterministic per-node "sensor" values.
            temp_milli_c: 42_000 + (id.0 as u64 * 137) % 8_000,
            eeprom_serial: 0x1BC0_0000 + id.0 as u64,
            eeprom_mac: 0x02_00_00_00_00_00 | id.0 as u64,
            sys_cards: cfg.preset.card_count() as u64,
        }
    }

    /// Read a word from the 4 GB address space (registers or DRAM).
    pub fn read_addr(&self, addr: u64, now: Time) -> u64 {
        match addr {
            regs::BOOT_STATUS => match self.boot {
                BootState::Idle => 0,
                BootState::Booting { done_at } if now < done_at => 1,
                _ => 2,
            },
            regs::BUILD_ID => {
                if now >= self.fpga_done_at {
                    self.fpga_image.as_ref().map(|(b, _)| *b).unwrap_or(0)
                } else {
                    0
                }
            }
            regs::TEMP => self.temp_milli_c,
            regs::EEPROM_SERIAL => self.eeprom_serial,
            regs::EEPROM_MAC => self.eeprom_mac,
            regs::SYS_CARDS => self.sys_cards,
            regs::ROUTER_PKTS => self.forwarded,
            a if a < DRAM_SIZE => self.dram.read_u64(a),
            a => self.regs.get(&a).copied().unwrap_or(0),
        }
    }

    /// Write a word into the address space. Writing `regs::BOOT_CMD`
    /// starts the boot state machine (`boot_latency` models kernel
    /// decompress + init, ~2 s on the A9).
    pub fn write_addr(&mut self, addr: u64, value: u64, now: Time) {
        match addr {
            regs::BOOT_CMD if value != 0 => {
                if matches!(self.boot, BootState::Idle) {
                    const BOOT_LATENCY: Time = 2 * crate::sim::SEC;
                    self.boot = BootState::Booting { done_at: now + BOOT_LATENCY };
                }
            }
            a if a < DRAM_SIZE => self.dram.write_u64(a, value),
            a => {
                self.regs.insert(a, value);
            }
        }
    }

    /// Promote `Booting` to `Linux` if the boot finished by `now`.
    pub fn tick_boot(&mut self, now: Time) {
        if let BootState::Booting { done_at } = self.boot {
            if now >= done_at {
                self.boot = BootState::Linux;
            }
        }
    }

    pub fn println(&mut self, line: impl Into<String>) {
        self.uart.push(line.into());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> NodeState {
        NodeState::new(NodeId(5), &SystemConfig::card())
    }

    #[test]
    fn register_reads_are_deterministic() {
        let a = node();
        let b = node();
        assert_eq!(a.read_addr(regs::TEMP, 0), b.read_addr(regs::TEMP, 0));
        assert_eq!(a.read_addr(regs::EEPROM_SERIAL, 0), 0x1BC0_0005);
        assert_eq!(a.read_addr(regs::SYS_CARDS, 0), 1);
    }

    #[test]
    fn boot_state_machine() {
        let mut n = node();
        assert_eq!(n.read_addr(regs::BOOT_STATUS, 0), 0);
        n.write_addr(regs::BOOT_CMD, 1, 1000);
        assert_eq!(n.read_addr(regs::BOOT_STATUS, 1001), 1);
        let after = 1000 + 2 * crate::sim::SEC;
        assert_eq!(n.read_addr(regs::BOOT_STATUS, after), 2);
        n.tick_boot(after);
        assert_eq!(n.boot, BootState::Linux);
    }

    #[test]
    fn dram_and_scratch_writes() {
        let mut n = node();
        n.write_addr(0x1000, 0xABCD, 0);
        assert_eq!(n.read_addr(0x1000, 0), 0xABCD);
        n.write_addr(regs::SCRATCH0, 7, 0);
        assert_eq!(n.read_addr(regs::SCRATCH0, 0), 7);
        // Unwritten addresses read 0.
        assert_eq!(n.read_addr(0x2000, 0), 0);
    }

    #[test]
    fn build_id_visible_only_after_programming_completes() {
        let mut n = node();
        n.fpga_image = Some((0x77, Arc::new(vec![])));
        n.fpga_done_at = 500;
        assert_eq!(n.read_addr(regs::BUILD_ID, 100), 0);
        assert_eq!(n.read_addr(regs::BUILD_ID, 500), 0x77);
    }
}
