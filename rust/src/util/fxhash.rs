//! Deterministic FxHash-style hashing (the Firefox / rustc hash).
//!
//! `std::collections::HashMap`'s default `RandomState` seeds itself per
//! process, which (a) costs a SipHash round per lookup on hot paths and
//! (b) makes iteration order vary across runs — poison for a simulator
//! whose selling point is bit-identical traces. [`FxHashMap`] swaps in
//! the multiply-rotate hash rustc itself uses: ~1 ns per small key,
//! fully deterministic. (We never iterate these maps on semantic paths,
//! but determinism-by-construction beats auditing.)

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// The 64-bit Fx mixing constant (π-derived, as in rustc-hash).
const K: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate word hasher. Not DoS-resistant — keys here are
/// internal ids, never attacker-controlled.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(K);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u16(&mut self, v: u16) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

/// A `HashMap` with deterministic, fast Fx hashing.
pub type FxHashMap<K2, V> = HashMap<K2, V, BuildHasherDefault<FxHasher>>;
/// A `HashSet` with deterministic, fast Fx hashing.
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_across_instances() {
        let h = |v: u64| {
            let mut f = FxHasher::default();
            f.write_u64(v);
            f.finish()
        };
        assert_eq!(h(42), h(42));
        assert_ne!(h(42), h(43));
    }

    #[test]
    fn map_roundtrip_with_tuple_keys() {
        let mut m: FxHashMap<(u32, u8), u64> = FxHashMap::default();
        for i in 0..100u32 {
            m.insert((i, (i % 7) as u8), i as u64 * 3);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m.get(&(41, 6)), Some(&123));
        assert_eq!(m.remove(&(0, 0)), Some(0));
    }

    #[test]
    fn byte_slices_hash_consistently() {
        let mut a = FxHasher::default();
        a.write(b"hello world!!");
        let mut b = FxHasher::default();
        b.write(b"hello world!!");
        assert_eq!(a.finish(), b.finish());
    }
}
