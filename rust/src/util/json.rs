//! Minimal JSON parser (subset) for the artifact manifest.
//!
//! Supports objects, arrays, strings (with `\"`/`\\`/`\n`/`\t`/`\uXXXX`
//! escapes), integers/floats, booleans and null — everything
//! `python/compile/aot.py` emits via `json.dump`. No serialization side;
//! Rust only reads the manifest.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking for {key:?})"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
            _ => bail!("not a non-negative integer: {self:?}"),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected , or ] found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Re-decode UTF-8 starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end])?);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "model": "tiny-lm-d64-l2-v64",
            "entries": [
                {"name": "grad", "file": "grad.hlo.txt",
                 "inputs": [{"name": "p:emb", "shape": [64, 64], "dtype": "f32"}],
                 "outputs": []}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "tiny-lm-d64-l2-v64");
        let entries = j.get("entries").unwrap().as_arr().unwrap();
        let ins = entries[0].get("inputs").unwrap().as_arr().unwrap();
        let shape = ins[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 64);
    }

    #[test]
    fn escapes_and_numbers() {
        let j = Json::parse(r#"{"s": "a\nbA", "f": -1.5e2, "b": true, "n": null}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "a\nbA");
        assert_eq!(j.get("f").unwrap(), &Json::Num(-150.0));
        assert_eq!(j.get("b").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("n").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_usize().unwrap(), 3);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
