//! Deterministic PRNG (SplitMix64 + xoshiro-style mixing).
//!
//! The only randomness in the simulator is adaptive-routing tie-breaks
//! (§2.4: "each node may make a routing decision based on which links
//! happen to be idle"); runs are reproducible given the config seed.

/// Stateless SplitMix64 finalizer: a well-mixed 64-bit hash of `x`.
///
/// Used for adaptive-routing tie-breaks keyed on `(seed, packet, node,
/// hop)` instead of a stateful RNG stream: the decision depends only on
/// what is being routed, never on how many decisions happened before it,
/// so serial and sharded execution make identical choices
/// ([`crate::network::sharded`]).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// SplitMix64: tiny, fast, passes BigCrush for this use.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for our n (≤ 6).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_matches_splitmix_stream() {
        // mix64 is the SplitMix64 finalizer applied to a raw state, so
        // seeding a generator with `x` and drawing once must agree.
        for x in [0u64, 1, 42, u64::MAX, 0xDEADBEEF] {
            assert_eq!(mix64(x), SplitMix64::new(x).next_u64());
        }
    }

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_bounds_and_coverage() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 6];
        for _ in 0..1000 {
            let v = r.gen_range(6);
            assert!(v < 6);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range should occur");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
