//! Small self-contained utilities (the build environment is offline, so
//! these replace the usual crates.io dependencies).

pub mod fxhash;
pub mod json;
pub mod rng;

pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::{mix64, SplitMix64};
