//! Small self-contained utilities (the build environment is offline, so
//! these replace the usual crates.io dependencies).

pub mod json;
pub mod rng;

pub use rng::SplitMix64;
