//! Stub runtime used when the `pjrt` cargo feature is disabled.
//!
//! The offline build environment cannot vendor the `xla` crate, so the
//! default build replaces the PJRT-backed [`Runtime`] with this stub:
//! identical API, but `load` always fails with an explanation. The
//! simulator, coordinator and CLI compile and run unchanged; only the
//! paths that need real numerics (`repro train`, the PJRT e2e tests)
//! report the missing feature.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use super::manifest::{EntryPoint, Manifest};

/// Stub stand-in for the PJRT-backed runtime (see module docs).
#[derive(Debug)]
pub struct Runtime {
    pub manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Always fails: built without the `pjrt` feature.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir.as_ref();
        Err(anyhow!(
            "inc_sim was built without the `pjrt` feature; to execute AOT \
             artifacts, add the `xla` crate to rust/Cargo.toml (it cannot \
             be vendored in the offline build) and rebuild with \
             `--features pjrt`"
        ))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn entry(&self, name: &str) -> Result<&EntryPoint> {
        self.manifest
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no entry point {name} in manifest"))
    }

    /// Always fails: there is no compiled executable behind the stub.
    pub fn execute_f32(&self, name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!("cannot execute {name}: built without the `pjrt` feature"))
    }
}
