//! Real PJRT backend (requires the `xla` crate; `pjrt` cargo feature).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow as eyre, Result};

use super::manifest::{EntryPoint, Manifest};

/// A compiled, executable artifact set.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Load `dir/manifest.json` and compile every entry point on the
    /// PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| eyre!("pjrt client: {e:?}"))?;
        let mut exes = HashMap::new();
        for ep in &manifest.entries {
            let path = dir.join(&ep.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| eyre!("non-utf8 path"))?,
            )
            .map_err(|e| eyre!("load {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| eyre!("compile {}: {e:?}", ep.name))?;
            exes.insert(ep.name.clone(), exe);
        }
        Ok(Runtime { client, exes, manifest, dir })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn entry(&self, name: &str) -> Result<&EntryPoint> {
        self.manifest
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| eyre!("no entry point {name} in manifest"))
    }

    /// Execute entry point `name` with f32 input tensors (flat, row
    /// major, shapes per the manifest). Returns the flat f32 outputs.
    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let ep = self.entry(name)?;
        if inputs.len() != ep.inputs.len() {
            return Err(eyre!(
                "{name}: expected {} inputs, got {}",
                ep.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (spec, data) in ep.inputs.iter().zip(inputs) {
            if spec.element_count() != data.len() {
                return Err(eyre!(
                    "{name}/{}: expected {} elements for shape {:?}, got {}",
                    spec.name,
                    spec.element_count(),
                    spec.shape,
                    data.len()
                ));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| eyre!("reshape {:?}: {e:?}", spec.shape))?;
            literals.push(lit);
        }
        let exe = self.exes.get(name).ok_or_else(|| eyre!("not compiled: {name}"))?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| eyre!("execute {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| eyre!("fetch {name}: {e:?}"))?;
        // Entry points are lowered with return_tuple=True.
        let parts = out.to_tuple().map_err(|e| eyre!("untuple {name}: {e:?}"))?;
        if parts.len() != ep.outputs.len() {
            return Err(eyre!(
                "{name}: manifest declares {} outputs, module returned {}",
                ep.outputs.len(),
                parts.len()
            ));
        }
        let mut vecs = Vec::with_capacity(parts.len());
        for (spec, lit) in ep.outputs.iter().zip(parts) {
            let v: Vec<f32> =
                lit.to_vec().map_err(|e| eyre!("read output {}: {e:?}", spec.name))?;
            if v.len() != spec.element_count() {
                return Err(eyre!(
                    "{name}/{}: output element count {} != manifest {}",
                    spec.name,
                    v.len(),
                    spec.element_count()
                ));
            }
            vecs.push(v);
        }
        Ok(vecs)
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("entries", &self.manifest.entries.len())
            .field("dir", &self.dir)
            .finish()
    }
}

