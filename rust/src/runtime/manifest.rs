//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. JSON (parsed with the in-crate subset parser) so
//! both sides stay dependency-light in an offline build environment.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape/dtype of one tensor crossing the AOT boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_count(&self) -> usize {
        let elem = match self.dtype.as_str() {
            "f32" | "i32" | "u32" => 4,
            "f64" | "i64" | "u64" => 8,
            "bf16" | "f16" | "i16" => 2,
            "i8" | "u8" | "bool" => 1,
            other => panic!("unknown dtype {other}"),
        };
        self.element_count() * elem
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled entry point (an `*.hlo.txt` file).
#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model identifier (e.g. "tiny-lm-d64-l2-v64").
    pub model: String,
    pub entries: Vec<EntryPoint>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let model = j.get("model")?.as_str()?.to_string();
        let mut entries = Vec::new();
        for e in j.get("entries")?.as_arr()? {
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
            };
            entries.push(EntryPoint {
                name: e.get("name")?.as_str()?.to_string(),
                file: e.get("file")?.as_str()?.to_string(),
                inputs: specs("inputs")?,
                outputs: specs("outputs")?,
            });
        }
        if entries.is_empty() {
            return Err(anyhow!("manifest has no entry points"));
        }
        Ok(Manifest { model, entries })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parse {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": "tiny-lm-d64-l2-v64",
        "entries": [
            {"name": "grad", "file": "grad.hlo.txt",
             "inputs": [
                {"name": "p:emb", "shape": [64, 64], "dtype": "f32"},
                {"name": "x", "shape": [8, 16], "dtype": "f32"}],
             "outputs": [
                {"name": "loss", "shape": [1], "dtype": "f32"}]}
        ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model, "tiny-lm-d64-l2-v64");
        assert_eq!(m.entries.len(), 1);
        let e = &m.entries[0];
        assert_eq!(e.inputs[0].element_count(), 4096);
        assert_eq!(e.inputs[0].byte_count(), 16384);
        assert_eq!(e.outputs[0].shape, vec![1]);
    }

    #[test]
    fn spec_sizes() {
        let s = TensorSpec { name: "x".into(), shape: vec![4, 8], dtype: "f32".into() };
        assert_eq!(s.element_count(), 32);
        assert_eq!(s.byte_count(), 128);
        let b = TensorSpec { name: "m".into(), shape: vec![3], dtype: "bf16".into() };
        assert_eq!(b.byte_count(), 6);
    }

    #[test]
    fn empty_manifest_rejected() {
        assert!(Manifest::parse(r#"{"model": "m", "entries": []}"#).is_err());
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(Manifest::load("/nonexistent/manifest.json").is_err());
    }
}
