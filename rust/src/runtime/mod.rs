//! PJRT runtime: load and execute AOT-compiled XLA artifacts.
//!
//! The L2/L1 Python stack (`python/compile/`) lowers the JAX model —
//! including its Pallas kernels — **once** at build time to HLO *text*
//! (`artifacts/*.hlo.txt`; text rather than a serialized `HloModuleProto`
//! because jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects), plus a JSON manifest describing every entry point's
//! tensor signature. With the `pjrt` cargo feature enabled, this module
//! loads the artifacts through the `xla` crate's PJRT CPU client,
//! compiles each once, and executes them from the coordinator's hot
//! path. Python is never on that path.
//!
//! Without the feature (the default — the offline build environment
//! cannot vendor the `xla` crate), a stub [`Runtime`] with the same API
//! compiles instead; its `load` returns a descriptive error so the
//! simulator, workload plumbing and CLI still build and run everything
//! that does not need real numerics.

mod manifest;

pub use manifest::{EntryPoint, Manifest, TensorSpec};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Locate the artifacts directory: `$INC_SIM_ARTIFACTS`, else
/// `./artifacts` relative to the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("INC_SIM_ARTIFACTS") {
        return PathBuf::from(d);
    }
    for base in [".", "..", "../.."] {
        let p = Path::new(base).join("artifacts");
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// Load the default runtime, with a helpful error if `make artifacts`
/// has not been run.
pub fn load_default() -> Result<Runtime> {
    let dir = default_artifact_dir();
    Runtime::load(&dir).with_context(|| {
        format!(
            "failed to load artifacts from {dir:?}; run `make artifacts` first \
             (or set INC_SIM_ARTIFACTS)"
        )
    })
}
