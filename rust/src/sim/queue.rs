//! The priority queue underlying [`super::Sim`].
//!
//! A binary heap keyed on `(time, seq)`; `seq` is a monotone counter so
//! that same-instant events dispatch in insertion order. This is the
//! single hottest data structure in the simulator (see `benches/
//! sim_engine.rs`), so it is kept allocation-free per operation beyond the
//! heap's own growth.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sim::Time;

/// A scheduled entry: ordering key + payload.
#[derive(Debug)]
pub struct Scheduled<E> {
    pub time: Time,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Min-heap of scheduled events.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap), next_seq: 0 }
    }

    #[inline]
    pub fn push(&mut self, time: Time, event: E) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Scheduled { time, seq, event }));
    }

    #[inline]
    pub fn pop(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|Reverse(s)| (s.time, s.event))
    }

    #[inline]
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|Reverse(s)| s.time)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.push(3, 'c');
        q.push(1, 'a');
        q.push(3, 'd');
        q.push(2, 'b');
        let out: Vec<char> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(out, vec!['a', 'b', 'c', 'd']);
    }

    #[test]
    fn empty_behaviour() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        assert!(q.pop().is_none());
    }
}
