//! The priority queue underlying [`super::Sim`]: a hierarchical timing
//! wheel with a far-future overflow heap.
//!
//! This is the single hottest data structure in the simulator (see
//! `benches/sim_engine.rs`). The previous implementation was a
//! `BinaryHeap` keyed on `(time, seq)` — O(log n) sift per operation,
//! each sift moving whole events by value. The wheel gives O(1) pushes
//! and amortized O(1) pops while preserving an exact `(time, key, seq)`
//! dispatch order (see the determinism argument below and the
//! differential test in `tests/queue_differential.rs`).
//!
//! # Structure
//!
//! Three levels of 1024 slots each, indexed by bits of the *absolute*
//! timestamp (1 tick = 1 ns):
//!
//! * level 0 — 1 ns/slot, covers a 1 µs window: one slot per instant,
//! * level 1 — 1 µs/slot, covers a ~1 ms window,
//! * level 2 — ~1 ms/slot, covers a ~1.07 s window,
//! * overflow — a `(time, seq)` min-heap for anything beyond level 2
//!   (multi-second timers; rare by construction).
//!
//! A slot holds a `Vec` of entries; a per-level bitmap (one bit per
//! slot) lets `pop` find the next occupied slot with a handful of
//! `trailing_zeros` scans instead of walking empty slots. When a level
//! empties, the next occupied slot of the level above is *cascaded*:
//! its entries are redistributed one level down and the lower window
//! advances. Drained `Vec`s are recycled through a spare pool, so the
//! steady state allocates nothing.
//!
//! # Determinism
//!
//! Entries are popped in `(time, key, seq)` lexicographic order. `key`
//! is a caller-supplied *content key* (0 for plain pushes): same-instant
//! events dispatch in key order, and only events with equal keys fall
//! back to insertion (`seq`) order. Content keys are what makes the
//! sharded fabric byte-identical to the serial engine — each shard
//! assigns seqs locally, so insertion order is not comparable across
//! engines, but the `(time, key)` pair is derived from event *content*
//! (link id, packet id, …) and therefore is (see
//! [`crate::network::Network`]'s key scheme).
//!
//! A level-0 slot holds exactly one instant; its entries are sorted by
//! `(key, seq)` when the slot is drained into the current *run*.
//! Same-instant events pushed while the run is live (handlers
//! scheduling at `now`) are ordered-inserted into the not-yet-popped
//! remainder of the run. Cascades only move entries to strictly finer
//! slots and never reorder across instants, so the pop sequence is
//! exactly the `(time, key, seq)` lexicographic order over the pending
//! set — the order the reference heap produces
//! (`tests/queue_differential.rs`).
//!
//! The caller contract (upheld by [`super::Sim`], which clamps) is that
//! pushes are never in the past: `time >= ` the last popped time.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::sim::Time;

/// log2 of the slot count per wheel level.
const LEVEL_BITS: u32 = 10;
/// Slots per level.
const SLOTS: usize = 1 << LEVEL_BITS;
const SLOT_MASK: u64 = (SLOTS - 1) as u64;
/// Number of wheel levels (beyond them, the overflow heap).
const LEVELS: usize = 3;
/// u64 words per level bitmap.
const BITMAP_WORDS: usize = SLOTS / 64;

/// A scheduled entry: ordering fields + payload. Also the overflow-heap
/// element (kept public for the reference-queue API and tests).
#[derive(Debug, Clone)]
pub struct Scheduled<E> {
    pub time: Time,
    /// Content key: same-instant tie-break *before* insertion order.
    pub key: u64,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.key == other.key && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.key, self.seq).cmp(&(other.time, other.key, other.seq))
    }
}

/// One level of the wheel: slot buckets + occupancy bitmap.
#[derive(Debug, Clone)]
struct Level<E> {
    slots: Vec<Vec<Scheduled<E>>>,
    bitmap: [u64; BITMAP_WORDS],
    /// The window id this level currently covers: valid `time`s satisfy
    /// `time >> ((level + 1) * LEVEL_BITS) == epoch`.
    epoch: u64,
}

impl<E> Level<E> {
    fn new() -> Self {
        Level {
            slots: (0..SLOTS).map(|_| Vec::new()).collect(),
            bitmap: [0; BITMAP_WORDS],
            epoch: 0,
        }
    }

    #[inline]
    fn set_bit(&mut self, slot: usize) {
        self.bitmap[slot >> 6] |= 1u64 << (slot & 63);
    }

    #[inline]
    fn clear_bit(&mut self, slot: usize) {
        self.bitmap[slot >> 6] &= !(1u64 << (slot & 63));
    }

    /// Index of the first occupied slot, if any. Slots below the
    /// current scan position are always empty, so scanning from word 0
    /// is both correct and cheap (≤ 16 words).
    fn first_occupied(&self) -> Option<usize> {
        for (w, &word) in self.bitmap.iter().enumerate() {
            if word != 0 {
                return Some((w << 6) + word.trailing_zeros() as usize);
            }
        }
        None
    }

    /// Index of the first occupied slot at or after `from`, if any.
    fn next_occupied(&self, from: usize) -> Option<usize> {
        if from >= SLOTS {
            return None;
        }
        let mut w = from >> 6;
        let mut word = self.bitmap[w] & (!0u64 << (from & 63));
        loop {
            if word != 0 {
                return Some((w << 6) + word.trailing_zeros() as usize);
            }
            w += 1;
            if w >= BITMAP_WORDS {
                return None;
            }
            word = self.bitmap[w];
        }
    }
}

/// Hierarchical timing wheel ordered by `(time, key, seq)`.
///
/// `Clone` snapshots the whole pending set (including `next_seq`, so a
/// restored clone replays insertion-order ties identically) — the
/// optimistic engine's checkpoints ([`crate::network::timewarp`])
/// depend on that.
#[derive(Debug, Clone)]
pub struct EventQueue<E> {
    /// Time of the last popped event (the run's instant). All stored
    /// entries satisfy `time > cur_time`, except run appendees at
    /// exactly `cur_time`.
    cur_time: Time,
    /// Events at the current instant, in `(key, seq)` order, popped
    /// from the front.
    run: VecDeque<Scheduled<E>>,
    levels: [Level<E>; LEVELS],
    overflow: BinaryHeap<Reverse<Scheduled<E>>>,
    /// Recycled slot `Vec`s (bounds steady-state allocation).
    spare: Vec<Vec<Scheduled<E>>>,
    next_seq: u64,
    len: usize,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            cur_time: 0,
            run: VecDeque::new(),
            levels: [Level::new(), Level::new(), Level::new()],
            overflow: BinaryHeap::new(),
            spare: Vec::new(),
            next_seq: 0,
            len: 0,
        }
    }

    /// `cap` pre-sizes the same-instant run buffer (the wheel itself is
    /// fixed-size).
    pub fn with_capacity(cap: usize) -> Self {
        let mut q = Self::new();
        q.run.reserve(cap.min(4096));
        q
    }

    /// Schedule `event` at `time` with content key 0. `time` must be ≥
    /// the last popped time (the `Sim` wrapper clamps; direct users
    /// must respect it).
    #[inline]
    pub fn push(&mut self, time: Time, event: E) {
        self.push_keyed(time, 0, event);
    }

    /// Schedule `event` at `time` with an explicit content `key`:
    /// same-instant events dispatch in `(key, seq)` order.
    #[inline]
    pub fn push_keyed(&mut self, time: Time, key: u64, event: E) {
        debug_assert!(time >= self.cur_time, "push into the past");
        let time = time.max(self.cur_time);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.len += 1;
        let en = Scheduled { time, key, seq, event };
        if time == self.cur_time {
            // Same instant as the live run: ordered insert into the
            // not-yet-popped remainder. `seq` is larger than everything
            // already there, so equal keys append — the common key-0
            // case stays a straight push_back.
            let pos = self.run.partition_point(|e| (e.key, e.seq) <= (en.key, en.seq));
            if pos == self.run.len() {
                self.run.push_back(en);
            } else {
                self.run.insert(pos, en);
            }
        } else {
            self.place(en);
        }
    }

    /// File an entry into the wheel level whose window covers its time
    /// (or the overflow heap). Never called with `time <= cur_time`.
    fn place(&mut self, en: Scheduled<E>) {
        let t = en.time;
        for (l, level) in self.levels.iter_mut().enumerate() {
            let shift = (l as u32 + 1) * LEVEL_BITS;
            if t >> shift == level.epoch {
                let slot = ((t >> (l as u32 * LEVEL_BITS)) & SLOT_MASK) as usize;
                level.set_bit(slot);
                level.slots[slot].push(en);
                return;
            }
        }
        self.overflow.push(Reverse(en));
    }

    /// Take a slot's bucket, leaving a recycled empty `Vec` behind.
    fn take_bucket(&mut self, level: usize, slot: usize) -> Vec<Scheduled<E>> {
        let spare = self.spare.pop().unwrap_or_default();
        self.levels[level].clear_bit(slot);
        std::mem::replace(&mut self.levels[level].slots[slot], spare)
    }

    fn recycle(&mut self, mut bucket: Vec<Scheduled<E>>) {
        if self.spare.len() < 64 {
            bucket.clear();
            self.spare.push(bucket);
        }
    }

    /// Refill the run from the wheel. Returns false iff the queue is
    /// empty. Runs to completion between pops, so callers never observe
    /// a partially advanced wheel.
    fn next_run(&mut self) -> bool {
        debug_assert!(self.run.is_empty());
        loop {
            // Level 0: one slot == one instant; drain it as the run.
            if let Some(slot) = self.levels[0].first_occupied() {
                let mut bucket = self.take_bucket(0, slot);
                bucket.sort_unstable_by_key(|e| (e.key, e.seq));
                self.cur_time = bucket[0].time;
                debug_assert!(bucket.iter().all(|e| e.time == self.cur_time));
                self.run.extend(bucket.drain(..));
                self.recycle(bucket);
                return true;
            }
            // Cascade the next occupied slot of level 1 (or 2) down.
            let mut cascaded = false;
            for l in 1..LEVELS {
                if let Some(slot) = self.levels[l].first_occupied() {
                    let mut bucket = self.take_bucket(l, slot);
                    // The level below now covers exactly this block.
                    self.levels[l - 1].epoch = (self.levels[l].epoch << LEVEL_BITS) | slot as u64;
                    for en in bucket.drain(..) {
                        self.place(en);
                    }
                    self.recycle(bucket);
                    cascaded = true;
                    break;
                }
            }
            if cascaded {
                continue;
            }
            // Wheel fully empty: rebase every window at the overflow
            // minimum and pull the now-coverable entries in.
            let min_t = match self.overflow.peek() {
                Some(Reverse(en)) => en.time,
                None => return false,
            };
            for (l, level) in self.levels.iter_mut().enumerate() {
                level.epoch = min_t >> ((l as u32 + 1) * LEVEL_BITS);
            }
            let horizon_epoch = self.levels[LEVELS - 1].epoch;
            while let Some(Reverse(en)) = self.overflow.peek() {
                if en.time >> (LEVELS as u32 * LEVEL_BITS) != horizon_epoch {
                    break;
                }
                let Reverse(en) = self.overflow.pop().unwrap();
                self.place(en);
            }
        }
    }

    /// Pop the earliest `(time, key, seq)` entry.
    #[inline]
    pub fn pop(&mut self) -> Option<(Time, E)> {
        if self.run.is_empty() && !self.next_run() {
            return None;
        }
        let en = self.run.pop_front().expect("next_run guaranteed an entry");
        self.len -= 1;
        Some((en.time, en.event))
    }

    /// Earliest pending timestamp without popping.
    pub fn peek_time(&self) -> Option<Time> {
        if let Some(en) = self.run.front() {
            return Some(en.time);
        }
        self.wheel_min_time()
    }

    /// Earliest timestamp stored in the wheel/overflow, ignoring the
    /// live run.
    fn wheel_min_time(&self) -> Option<Time> {
        // Level 0 slots hold a single instant: the bit index IS the time.
        if let Some(slot) = self.levels[0].first_occupied() {
            return Some((self.levels[0].epoch << LEVEL_BITS) | slot as u64);
        }
        // Coarser levels: the first occupied slot contains the minimum,
        // but the slot itself is unordered — scan its entries.
        for level in &self.levels[1..] {
            if let Some(slot) = level.first_occupied() {
                return level.slots[slot].iter().map(|e| e.time).min();
            }
        }
        self.overflow.peek().map(|Reverse(en)| en.time)
    }

    /// Earliest pending `(time, key)` without popping — the entry `pop`
    /// would return next. The per-node horizon sharpening
    /// ([`crate::network::sharded`]) reads the head's content key to
    /// locate the event on the mesh.
    pub fn peek_head(&self) -> Option<(Time, u64)> {
        if let Some(en) = self.run.front() {
            return Some((en.time, en.key));
        }
        if let Some(slot) = self.levels[0].first_occupied() {
            let t = (self.levels[0].epoch << LEVEL_BITS) | slot as u64;
            let en = self.levels[0].slots[slot].iter().min_by_key(|e| (e.key, e.seq))?;
            return Some((t, en.key));
        }
        for level in &self.levels[1..] {
            if let Some(slot) = level.first_occupied() {
                let en = level.slots[slot].iter().min_by_key(|e| (e.time, e.key, e.seq))?;
                return Some((en.time, en.key));
            }
        }
        self.overflow.peek().map(|Reverse(en)| (en.time, en.key))
    }

    /// A lower bound on the timestamp of the *second*-earliest pending
    /// entry — exact in the common cases (live run, level-0 wheel), and
    /// conservatively equal to the head's own time when computing the
    /// true value would mean walking coarse slots. `None` when fewer
    /// than two entries are pending. Used by the per-node horizon
    /// bounds: everything behind the head is bounded by this time plus
    /// the pair lookahead.
    pub fn peek_second_time_lb(&self) -> Option<Time> {
        if self.len < 2 {
            return None;
        }
        if self.run.len() >= 2 {
            return Some(self.run[1].time);
        }
        if self.run.len() == 1 {
            // Everything else is in the wheel; its minimum is exact.
            return self.wheel_min_time().or(Some(self.cur_time));
        }
        if let Some(slot) = self.levels[0].first_occupied() {
            let head_t = (self.levels[0].epoch << LEVEL_BITS) | slot as u64;
            if self.levels[0].slots[slot].len() >= 2 {
                return Some(head_t);
            }
            if let Some(s2) = self.levels[0].next_occupied(slot + 1) {
                return Some((self.levels[0].epoch << LEVEL_BITS) | s2 as u64);
            }
            for level in &self.levels[1..] {
                if let Some(s) = level.first_occupied() {
                    return level.slots[s].iter().map(|e| e.time).min();
                }
            }
            return self.overflow.peek().map(|Reverse(en)| en.time);
        }
        // Head in a coarse level or the overflow: fall back to the head
        // time itself (a sound, if loose, bound — rare outside long
        // idle gaps).
        self.wheel_min_time()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// The pre-wheel implementation: a binary min-heap on `(time, key, seq)`.
/// Kept as the ordering oracle for the differential test
/// (`tests/queue_differential.rs`) and as the baseline the perf bench
/// (`benches/sim_engine.rs`) reports its speedup against.
#[derive(Debug)]
pub struct ReferenceQueue<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    next_seq: u64,
}

impl<E> Default for ReferenceQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> ReferenceQueue<E> {
    pub fn new() -> Self {
        ReferenceQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    #[inline]
    pub fn push(&mut self, time: Time, event: E) {
        self.push_keyed(time, 0, event);
    }

    #[inline]
    pub fn push_keyed(&mut self, time: Time, key: u64, event: E) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Scheduled { time, key, seq, event }));
    }

    #[inline]
    pub fn pop(&mut self) -> Option<(Time, E)> {
        self.heap.pop().map(|Reverse(s)| (s.time, s.event))
    }

    #[inline]
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|Reverse(s)| s.time)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.push(3, 'c');
        q.push(1, 'a');
        q.push(3, 'd');
        q.push(2, 'b');
        let out: Vec<char> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(out, vec!['a', 'b', 'c', 'd']);
    }

    #[test]
    fn empty_behaviour() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        assert!(q.pop().is_none());
    }

    #[test]
    fn crosses_level_boundaries() {
        let mut q = EventQueue::new();
        // One event per level + overflow, pushed out of order.
        q.push(1 << 30, "overflow"); // beyond level 2's first window
        q.push(5, "l0");
        q.push(70_000, "l1");
        q.push(3_000_000, "l2");
        let out: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(out, vec!["l0", "l1", "l2", "overflow"]);
    }

    #[test]
    fn same_instant_appends_after_pop() {
        let mut q = EventQueue::new();
        q.push(10, 1u32);
        q.push(10, 2);
        assert_eq!(q.pop(), Some((10, 1)));
        // Handler schedules at the instant being dispatched.
        q.push(10, 3);
        assert_eq!(q.pop(), Some((10, 2)));
        assert_eq!(q.pop(), Some((10, 3)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn reschedule_steady_state_stays_ordered() {
        // The bench's steady-state pattern: pop, reschedule slightly
        // ahead; times must be non-decreasing throughout.
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.push(i * 7 % 4096, i);
        }
        let mut last = 0;
        let mut popped = 0u64;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last, "time went backwards: {t} < {last}");
            last = t;
            popped += 1;
            if popped < 20_000 {
                q.push(t + 1 + popped % 97, popped);
            }
        }
        assert_eq!(popped, 20_000);
    }

    #[test]
    fn peek_matches_pop_across_levels() {
        let mut q = EventQueue::new();
        for t in [9u64, 1 << 12, 1 << 22, 1 << 31] {
            q.push(t, t);
        }
        while let Some(pt) = q.peek_time() {
            let (t, _) = q.pop().unwrap();
            assert_eq!(pt, t);
        }
    }

    #[test]
    fn far_future_bursts_keep_seq_order() {
        let mut q = EventQueue::new();
        let t = (1u64 << 31) + 123; // overflow territory
        for i in 0..50u64 {
            q.push(t, i);
        }
        for i in 0..50u64 {
            assert_eq!(q.pop(), Some((t, i)));
        }
    }

    #[test]
    fn keys_order_same_instant_before_seq() {
        let mut q = EventQueue::new();
        q.push_keyed(10, 3, 'c');
        q.push_keyed(10, 1, 'a');
        q.push_keyed(10, 2, 'b');
        q.push_keyed(5, 9, 'x'); // earlier time wins regardless of key
        let out: Vec<char> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(out, vec!['x', 'a', 'b', 'c']);
    }

    #[test]
    fn keyed_push_at_live_instant_inserts_in_key_order() {
        let mut q = EventQueue::new();
        q.push_keyed(10, 2, "b");
        q.push_keyed(10, 4, "d");
        assert_eq!(q.pop(), Some((10, "b")));
        // Scheduled at the live instant with a key between the popped
        // entry and the pending one: dispatches before the pending one.
        q.push_keyed(10, 3, "c");
        // ... and a key below anything remaining goes first.
        q.push_keyed(10, 1, "early");
        assert_eq!(q.pop(), Some((10, "early")));
        assert_eq!(q.pop(), Some((10, "c")));
        assert_eq!(q.pop(), Some((10, "d")));
    }

    #[test]
    fn equal_keys_fall_back_to_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..10u64 {
            q.push_keyed(7, 42, i);
        }
        for i in 0..10u64 {
            assert_eq!(q.pop(), Some((7, i)));
        }
    }

    #[test]
    fn peek_head_and_second_bound() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_head(), None);
        assert_eq!(q.peek_second_time_lb(), None);
        q.push_keyed(10, 7, 'a');
        assert_eq!(q.peek_head(), Some((10, 7)));
        assert_eq!(q.peek_second_time_lb(), None);
        q.push_keyed(40, 3, 'b');
        // Two level-0 slots: second bound is exact.
        assert_eq!(q.peek_head(), Some((10, 7)));
        assert_eq!(q.peek_second_time_lb(), Some(40));
        q.push_keyed(10, 2, 'c'); // lower key takes over the head
        assert_eq!(q.peek_head(), Some((10, 2)));
        assert_eq!(q.peek_second_time_lb(), Some(10));
        assert_eq!(q.pop(), Some((10, 'c')));
        // Live run of one entry + wheel remainder.
        assert_eq!(q.peek_head(), Some((10, 7)));
        assert_eq!(q.peek_second_time_lb(), Some(40));
        assert_eq!(q.pop(), Some((10, 'a')));
        assert_eq!(q.pop(), Some((40, 'b')));
        // The bound must never exceed the true second time, across
        // levels and the overflow.
        let mut q = EventQueue::new();
        for t in [3_000_000u64, 3_000_001, 1 << 31] {
            q.push(t, t);
        }
        let lb = q.peek_second_time_lb().unwrap();
        assert!(lb <= 3_000_001, "lb {lb} exceeds true second");
        let cloned = q.clone();
        let a: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        let mut c = cloned;
        let b: Vec<_> = std::iter::from_fn(|| c.pop()).collect();
        assert_eq!(a, b, "clone replays identically");
    }

    #[test]
    fn reference_queue_agrees_on_basics() {
        let mut q = ReferenceQueue::new();
        q.push(3, 'c');
        q.push(1, 'a');
        q.push(3, 'd');
        let out: Vec<char> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(out, vec!['a', 'c', 'd']);
    }
}
