//! Deterministic discrete-event simulation engine.
//!
//! Virtual time is a `u64` count of **nanoseconds**. With 1 GB/s links
//! this makes serialization delay exactly 1 ns/byte, so all calibration
//! constants in [`crate::config`] are integers.
//!
//! # Event core
//!
//! The pending-event set is a hierarchical timing wheel
//! ([`EventQueue`]): three levels of 1024 slots at 1 ns / 1 µs / ~1 ms
//! granularity (covering ~1.07 s of look-ahead) plus a far-future
//! overflow heap. Scheduling and dispatch are O(1) amortized — the old
//! `BinaryHeap` core paid an O(log n) sift moving events by value on
//! every operation, which dominated the fabric hot path at INC-3000
//! scale (`benches/sim_engine.rs` tracks the throughput; the heap
//! survives as [`ReferenceQueue`], the ordering oracle and bench
//! baseline).
//!
//! # Size budgets
//!
//! The queue moves events by value, so [`crate::network::Event`] is
//! kept to ≤ 32 bytes (asserted by the `event_size_budget` test): bulky
//! payloads live behind a slab handle
//! ([`crate::network::arena::PacketRef`], 4 bytes), a `Box`, or an
//! `Arc`. `Packet` itself (~100 bytes) sits in the
//! [`crate::network::arena::PacketArena`] and is recycled on delivery,
//! so steady-state traffic allocates nothing per hop.
//!
//! # Determinism
//!
//! Events are dispatched in `(time, key, seq)` order: the optional
//! *content key* ([`Sim::at_keyed`]) orders same-instant events by
//! event identity, and the monotone sequence number breaks the
//! remaining ties in insertion order. The wheel preserves the exact
//! lexicographic pop order of a binary heap (argued in [`queue`]'s
//! docs, enforced by `tests/queue_differential.rs`). The only
//! "randomness" in the system is a stateless per-packet hash for
//! adaptive-routing tie-breaks ([`crate::util::mix64`] over the config
//! seed and packet identity) — a deliberate design point: nothing in
//! the simulation depends on *dispatch order*, only on event content,
//! which is what lets the per-cage sharded engine
//! ([`crate::network::sharded`]) replay the exact serial trace. Two
//! runs with the same seed produce identical traces.
//!
//! Scheduling **into the past** ([`Sim::at`] with `at < now`) is
//! defined to clamp to `now` in every build profile — debug and release
//! behave identically (the seed's `debug_assert` panicked in debug but
//! silently clamped in release).

mod queue;

pub use queue::{EventQueue, ReferenceQueue, Scheduled};

/// Virtual time in nanoseconds.
pub type Time = u64;

/// One microsecond in [`Time`] units.
pub const US: Time = 1_000;
/// One millisecond in [`Time`] units.
pub const MS: Time = 1_000_000;
/// One second in [`Time`] units.
pub const SEC: Time = 1_000_000_000;

/// The simulation clock + event queue, generic over the event payload.
///
/// Components schedule `E` values at absolute or relative times; the
/// driver loop pops them in (time, seq) order and dispatches to the owning
/// world (see [`crate::network::Network::run_until`]).
#[derive(Debug, Clone)]
pub struct Sim<E> {
    now: Time,
    queue: EventQueue<E>,
    dispatched: u64,
}

impl<E> Default for Sim<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Sim<E> {
    pub fn new() -> Self {
        // Pre-size the heap for a typical fabric working set; avoids
        // re-allocation stalls on the first traffic burst.
        Sim { now: 0, queue: EventQueue::with_capacity(4096), dispatched: 0 }
    }

    /// Current virtual time.
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total number of events dispatched so far.
    #[inline]
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    /// Number of pending events.
    #[inline]
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule `ev` at absolute time `at`.
    ///
    /// An `at` in the past is clamped to `now`: the event dispatches at
    /// the current instant, after everything already scheduled there.
    /// This is deliberate and identical in debug and release builds
    /// (see the module docs), so components may schedule "no later than
    /// now" without checking the clock first.
    #[inline]
    pub fn at(&mut self, at: Time, ev: E) {
        self.queue.push(at.max(self.now), ev);
    }

    /// Schedule `ev` `delay` ns from now.
    #[inline]
    pub fn after(&mut self, delay: Time, ev: E) {
        self.queue.push(self.now + delay, ev);
    }

    /// Schedule `ev` at absolute time `at` with a content `key`:
    /// same-instant events dispatch in key order (insertion order only
    /// breaks key ties). Content keys derived from event identity — not
    /// from scheduling order — are what lets a partitioned simulation
    /// reproduce the serial engine's dispatch order exactly (see
    /// [`crate::network::sharded`]).
    #[inline]
    pub fn at_keyed(&mut self, at: Time, key: u64, ev: E) {
        self.queue.push_keyed(at.max(self.now), key, ev);
    }

    /// Keyed variant of [`Sim::after`]; see [`Sim::at_keyed`].
    #[inline]
    pub fn after_keyed(&mut self, delay: Time, key: u64, ev: E) {
        self.queue.push_keyed(self.now + delay, key, ev);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    #[inline]
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let (t, ev) = self.queue.pop()?;
        debug_assert!(t >= self.now);
        self.now = t;
        self.dispatched += 1;
        Some((t, ev))
    }

    /// Pop the next event only if it is scheduled at or before `deadline`.
    #[inline]
    pub fn pop_until(&mut self, deadline: Time) -> Option<(Time, E)> {
        match self.queue.peek_time() {
            Some(t) if t <= deadline => self.pop(),
            _ => None,
        }
    }

    /// Time of the next pending event, if any.
    #[inline]
    pub fn peek_time(&self) -> Option<Time> {
        self.queue.peek_time()
    }

    /// `(time, content key)` of the next pending event, if any — the
    /// entry [`Sim::pop`] would dispatch next.
    #[inline]
    pub fn peek_head(&self) -> Option<(Time, u64)> {
        self.queue.peek_head()
    }

    /// Lower bound on the timestamp of the second-earliest pending
    /// event (see [`EventQueue::peek_second_time_lb`]).
    #[inline]
    pub fn peek_second_time_lb(&self) -> Option<Time> {
        self.queue.peek_second_time_lb()
    }

    /// Advance the clock with no event (used when a deadline passes with
    /// an empty queue).
    #[inline]
    pub fn advance_to(&mut self, t: Time) {
        debug_assert!(t >= self.now);
        self.now = t;
    }

    /// Advance the clock to `t` if it is ahead; no-op otherwise. The
    /// tolerant form engine-agnostic drivers use
    /// ([`crate::network::Fabric::advance_to`]): a deadline that has
    /// already passed is not an error, unlike [`Sim::advance_to`].
    #[inline]
    pub fn catch_up_to(&mut self, t: Time) {
        if t > self.now {
            self.now = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_for_simultaneous_events() {
        let mut sim: Sim<u32> = Sim::new();
        sim.at(10, 1);
        sim.at(10, 2);
        sim.at(5, 0);
        sim.at(10, 3);
        let order: Vec<u32> = std::iter::from_fn(|| sim.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
        assert_eq!(sim.now(), 10);
        assert_eq!(sim.dispatched(), 4);
    }

    #[test]
    fn pop_until_respects_deadline() {
        let mut sim: Sim<&'static str> = Sim::new();
        sim.at(100, "a");
        sim.at(200, "b");
        assert_eq!(sim.pop_until(150).map(|(_, e)| e), Some("a"));
        assert_eq!(sim.pop_until(150), None);
        assert_eq!(sim.pop_until(200).map(|(_, e)| e), Some("b"));
    }

    #[test]
    fn after_is_relative_to_now() {
        let mut sim: Sim<u8> = Sim::new();
        sim.at(50, 1);
        sim.pop();
        sim.after(25, 2);
        assert_eq!(sim.pop(), Some((75, 2)));
    }

    #[test]
    fn at_in_the_past_clamps_to_now() {
        let mut sim: Sim<u8> = Sim::new();
        sim.at(100, 1);
        assert_eq!(sim.pop(), Some((100, 1)));
        // A past timestamp dispatches at the current instant, after
        // anything already scheduled there.
        sim.at(100, 2);
        sim.at(40, 3);
        assert_eq!(sim.pop(), Some((100, 2)));
        assert_eq!(sim.pop(), Some((100, 3)));
        assert_eq!(sim.now(), 100);
    }

    #[test]
    fn catch_up_to_never_rewinds() {
        let mut sim: Sim<u8> = Sim::new();
        sim.at(100, 1);
        sim.pop();
        sim.catch_up_to(50); // behind: no-op
        assert_eq!(sim.now(), 100);
        sim.catch_up_to(150);
        assert_eq!(sim.now(), 150);
    }

    #[test]
    fn deep_queue_spanning_all_wheel_levels() {
        let mut sim: Sim<u64> = Sim::new();
        // Mix of near, mid, far and multi-second timers.
        for i in 0..4000u64 {
            sim.at(i * 677 % 5_000_000, i);
        }
        sim.at(3 * SEC, 4000);
        let mut last = 0;
        let mut n = 0;
        while let Some((t, _)) = sim.pop() {
            assert!(t >= last);
            last = t;
            n += 1;
        }
        assert_eq!(n, 4001);
        assert_eq!(sim.now(), 3 * SEC);
    }
}
