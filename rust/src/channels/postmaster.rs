//! Postmaster DMA (§3.2, Fig 4): a tunneled queue for small messages.
//!
//! An initiator (CPU or FPGA module) writes data to a transmit queue at a
//! known fixed address; the data is carried to the target node, where a
//! DMA engine moves it into a pre-allocated buffer in system memory.
//! Multiple initiators may send to the same target; their packets
//! interleave in the single receive stream **but each packet's bytes are
//! stored contiguously** — the hardware guarantee the paper calls out.
//! System software is involved only in initialization and tear-down.
//!
//! This is the channel the paper recommends for distributed-learner
//! workloads: many small outputs per time step, sent as generated rather
//! than aggregated, so communication overlaps computation (benchmarked
//! in `benches/overlap_learners.rs`, experiment E8).

use std::sync::Arc;

use crate::network::{App, Event, Network};
use crate::router::{Packet, Payload, Proto, RouteKind};
use crate::sim::Time;
use crate::topology::NodeId;
use crate::util::FxHashMap;

/// One record in a target's receive stream.
///
/// `data` is reference-counted: the bytes are shared with the in-flight
/// packet payload and with every `pm_read` copy, so cloning a record is
/// O(1) (indexing/iteration is unchanged via `Deref`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PmRecord {
    pub initiator: NodeId,
    pub data: Arc<Vec<u8>>,
    /// When the initiator wrote the transmit queue.
    pub t_enqueued: Time,
    /// When the target DMA finished storing it.
    pub t_stored: Time,
}

/// Receive side of one Postmaster queue.
#[derive(Debug, Clone, Default)]
pub struct PmQueue {
    /// The linear receive stream, in storage-completion order.
    pub stream: Vec<PmRecord>,
    pub bytes: u64,
    /// Next unread index (for consumers that poll the stream).
    pub read_idx: usize,
}

/// All Postmaster queues in the system, keyed by (target node, queue id).
/// Looked up per record on the delivery path, hence Fx hashing.
#[derive(Debug, Clone, Default)]
pub struct PostmasterFabric {
    queues: FxHashMap<(u32, u8), PmQueue>,
    /// Target-side DMA engine occupancy per node.
    dma_busy_until: FxHashMap<u32, Time>,
}

impl PostmasterFabric {
    pub fn new(_nodes: usize) -> Self {
        PostmasterFabric::default()
    }

    pub fn queue(&self, node: NodeId, queue: u8) -> Option<&PmQueue> {
        self.queues.get(&(node.0, queue))
    }

    pub fn queue_mut(&mut self, node: NodeId, queue: u8) -> Option<&mut PmQueue> {
        self.queues.get_mut(&(node.0, queue))
    }
}

impl Network {
    /// Initialize a Postmaster receive queue on `target` (the only step
    /// that involves system software, per the paper).
    pub fn pm_open(&mut self, target: NodeId, queue: u8) {
        let prev = self.postmaster.queues.insert((target.0, queue), PmQueue::default());
        assert!(prev.is_none(), "postmaster queue {queue} already open at {target}");
    }

    /// Initiator-side write to the transmit queue at its fixed address.
    /// `data` must fit one network packet (larger transfers use several
    /// records — the contiguity guarantee is per record/packet).
    pub fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>) {
        let id = self.next_packet_id();
        let now = self.now();
        self.pm_send_record(id, now, src, target, queue, data);
    }

    /// Deferred [`Network::pm_send`] with an app-context packet id: the
    /// record is produced (written to the transmit queue) at absolute
    /// time `at ≥ now` and enters the fabric after the usual enqueue +
    /// injection overheads. This is the transmit the unified Endpoint
    /// API rides for `CommMode::Postmaster` — valid from driver context
    /// *and* from [`App`] callbacks at `src`, because the per-node id
    /// keeps serial and sharded id assignment identical (see
    /// [`Network::app_packet_id`]).
    pub fn pm_send_at(&mut self, at: Time, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>) {
        debug_assert!(at >= self.now(), "postmaster record produced in the past");
        let id = self.app_packet_id(src);
        self.pm_send_record(id, at, src, target, queue, data);
    }

    /// The one Postmaster transmit recipe behind [`Network::pm_send`]
    /// and [`Network::pm_send_at`]: validate, build the packet stamped
    /// at its production instant `at`, charge the memory-mapped queue
    /// write + injection overhead (tiny, no kernel involvement —
    /// contrast with the Ethernet path), account the injection.
    fn pm_send_record(
        &mut self,
        id: u64,
        at: Time,
        src: NodeId,
        target: NodeId,
        queue: u8,
        data: Vec<u8>,
    ) {
        let max = (self.cfg.link.mtu - crate::router::HEADER_BYTES) as usize;
        assert!(
            data.len() <= max,
            "postmaster record of {} bytes exceeds one packet ({} max)",
            data.len(),
            max
        );
        assert!(
            self.postmaster.queues.contains_key(&(target.0, queue)),
            "postmaster queue {queue} not open at {target}"
        );
        self.metrics.record_mode("postmaster", data.len() as u64);
        let pkt = Packet::new(
            id,
            src,
            target,
            RouteKind::Directed,
            Proto::Postmaster { queue },
            Payload::bytes(data),
            at, // injected_at: the production instant, for latency metrics
        );
        let delay = self.cfg.arm.postmaster_enqueue + self.cfg.link.inject_latency;
        self.metrics.packets_injected += 1;
        self.inject_at(at + delay, pkt);
    }

    /// Packet Demux handed us a Postmaster packet at its target: the DMA
    /// engine moves it into the receive buffer. One engine per node —
    /// concurrent arrivals serialize, which is exactly what keeps each
    /// record contiguous in the stream.
    pub(crate) fn pm_deliver(&mut self, node: NodeId, queue: u8, packet: Packet) {
        // The record shares the packet payload's bytes — no copy.
        let data = match packet.payload {
            Payload::Bytes(b) => b,
            _ => unreachable!("postmaster packet without bytes"),
        };
        let now = self.now();
        let busy = self.postmaster.dma_busy_until.entry(node.0).or_insert(0);
        let start = now.max(*busy);
        let xfer = (data.len() as f64 / self.cfg.arm.axi_bytes_per_ns).ceil() as Time;
        let done = start + self.cfg.arm.postmaster_dma + xfer;
        *busy = done;
        let record = PmRecord {
            initiator: packet.src,
            data,
            t_enqueued: packet.injected_at,
            t_stored: done,
        };
        self.sim.at_keyed(
            done,
            crate::network::key_pm_rx(node, queue),
            Event::PmRx { node, queue, record: Box::new(record) },
        );
    }

    /// DMA completion: append the record to the stream and notify.
    pub(crate) fn pm_rx(&mut self, node: NodeId, queue: u8, record: PmRecord, app: &mut dyn App) {
        {
            let q = self
                .postmaster
                .queues
                .get_mut(&(node.0, queue))
                .unwrap_or_else(|| panic!("postmaster queue {queue} not open at {node}"));
            q.bytes += record.data.len() as u64;
            q.stream.push(record.clone());
        }
        let captured = self.comm_capture_pm(node, queue, &record);
        self.app_scope(app, |net, app| {
            app.on_postmaster(net, node, queue, &record);
            if let Some((ep, msg)) = captured {
                net.comm_deliver(app, ep, msg);
            }
        });
    }

    /// Drain unread records from a queue's stream (polling consumer).
    pub fn pm_read(&mut self, node: NodeId, queue: u8) -> Vec<PmRecord> {
        match self.postmaster.queues.get_mut(&(node.0, queue)) {
            Some(q) => {
                let out = q.stream[q.read_idx..].to_vec();
                q.read_idx = q.stream.len();
                out
            }
            None => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::NullApp;
    use crate::topology::Coord;

    #[test]
    fn single_record_roundtrip() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 2, y: 1, z: 0 });
        net.pm_open(dst, 0);
        net.pm_send(src, dst, 0, vec![1, 2, 3, 4]);
        net.run_to_quiescence(&mut NullApp);
        let recs = net.pm_read(dst, 0);
        assert_eq!(recs.len(), 1);
        assert_eq!(*recs[0].data, vec![1, 2, 3, 4]);
        assert_eq!(recs[0].initiator, src);
        assert!(recs[0].t_stored > recs[0].t_enqueued);
    }

    #[test]
    fn many_initiators_interleave_but_records_stay_whole() {
        // The §3.2 guarantee: interleaving happens at record granularity.
        let mut net = Network::card();
        let target = net.topo.id(Coord { x: 1, y: 1, z: 1 });
        net.pm_open(target, 3);
        let initiators: Vec<NodeId> =
            net.topo.nodes().filter(|&n| n != target).collect();
        for (i, &ini) in initiators.iter().enumerate() {
            // Each initiator sends 4 records tagged with its identity.
            for k in 0..4u8 {
                net.pm_send(ini, target, 3, vec![i as u8; 8 + k as usize]);
            }
        }
        net.run_to_quiescence(&mut NullApp);
        let recs = net.pm_read(target, 3);
        assert_eq!(recs.len(), initiators.len() * 4);
        // Every record is contiguous/whole: its bytes are all the same
        // tag and match its initiator.
        for r in &recs {
            let idx = initiators.iter().position(|&n| n == r.initiator).unwrap();
            assert!(r.data.iter().all(|&b| b == idx as u8), "record torn: {r:?}");
        }
        // And the stream really is interleaved (not sorted by initiator).
        let first_of_each: Vec<usize> = initiators
            .iter()
            .map(|&ini| recs.iter().position(|r| r.initiator == ini).unwrap())
            .collect();
        let max_first = *first_of_each.iter().max().unwrap();
        assert!(max_first < recs.len() - 4, "no interleaving observed");
    }

    #[test]
    fn storage_order_matches_dma_completion_order() {
        let mut net = Network::card();
        let target = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        net.pm_open(target, 0);
        let near = net.topo.id(Coord { x: 1, y: 0, z: 0 });
        let far = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        net.pm_send(far, target, 0, vec![2; 16]);
        net.pm_send(near, target, 0, vec![1; 16]);
        net.run_to_quiescence(&mut NullApp);
        let recs = net.pm_read(target, 0);
        assert_eq!(recs.len(), 2);
        // The near initiator's record lands first despite being sent second.
        assert_eq!(recs[0].initiator, near);
        assert!(recs[0].t_stored <= recs[1].t_stored);
    }

    #[test]
    fn lower_overhead_than_ethernet() {
        // §3.2: "much lower overhead than going through the TCP/IP stack".
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 1, y: 0, z: 0 });
        net.pm_open(dst, 0);
        net.pm_send(src, dst, 0, vec![0; 64]);
        net.run_to_quiescence(&mut NullApp);
        let pm_time = net.now();

        let mut net2 = Network::card();
        net2.eth_send(src, dst, 64, 0);
        net2.run_to_quiescence(&mut NullApp);
        let eth_time = net2.now();
        assert!(
            pm_time * 5 < eth_time,
            "postmaster {pm_time} ns should be ≫ faster than ethernet {eth_time} ns"
        );
    }

    #[test]
    #[should_panic(expected = "exceeds one packet")]
    fn oversized_record_rejected() {
        let mut net = Network::card();
        net.pm_open(NodeId(1), 0);
        net.pm_send(NodeId(0), NodeId(1), 0, vec![0; 4096]);
    }

    #[test]
    fn pm_read_is_incremental() {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(1));
        net.pm_open(b, 0);
        net.pm_send(a, b, 0, vec![1]);
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.pm_read(b, 0).len(), 1);
        assert_eq!(net.pm_read(b, 0).len(), 0);
        net.pm_send(a, b, 0, vec![2]);
        net.run_to_quiescence(&mut NullApp);
        let recs = net.pm_read(b, 0);
        assert_eq!(recs.len(), 1);
        assert_eq!(*recs[0].data, vec![2]);
    }
}
