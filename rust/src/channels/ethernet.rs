//! Internal (virtual) Ethernet (§3.1, Fig 3).
//!
//! A hardware Ethernet-lookalike implemented on the FPGA fabric so that
//! unmodified IP software (ssh, MPI, NFS, iperf…) runs node-to-node. The
//! price is the full software path: kernel network stack + device driver
//! + DMA descriptor management on transmit, and on receive either a
//! hardware interrupt per frame or a polling loop that is "far more
//! efficient under high traffic conditions" (§3.1) — both are modeled,
//! with per-node CPU-time accounting so the efficiency claim is
//! measurable (bench E4).
//!
//! Node (100) of each card owns a *physical* Ethernet port and can act as
//! a gateway to the external world with NAT + port forwarding; an NFS
//! flavoured file service on the external host is included because the
//! paper calls it out as the immediate use ("save application data …
//! to a non-volatile external storage medium").

use std::collections::{HashMap, VecDeque};

use crate::network::{App, Event, Network};
use crate::router::{Packet, Payload, Proto, RouteKind};
use crate::sim::Time;
use crate::topology::NodeId;

/// Maximum Ethernet frame payload (standard MTU).
pub const ETH_MTU: u32 = 1500;
/// Frame overhead (MAC header + FCS, rounded).
pub const ETH_OVERHEAD: u32 = 18;

/// An internal-Ethernet frame. Legacy traffic models content by size
/// only (`data: None`); frames sent through the unified Endpoint API
/// ([`crate::channels::endpoint`]) additionally carry their payload
/// bytes, which is how byte [`crate::channels::Message`]s travel over
/// this mode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EthFrame {
    /// Packet id the frame's fabric packet will carry, assigned when
    /// the frame is created (at the driver API or from the per-node app
    /// id space, never inside an event handler from the global counter
    /// — see the dispatch-order notes in [`crate::network`]).
    pub id: u64,
    pub src: NodeId,
    pub dst: NodeId,
    /// Payload bytes (≤ [`ETH_MTU`]).
    pub bytes: u32,
    /// Application tag (models port numbers / message ids). Endpoint
    /// fragments encode `(msg seq, frag idx, frag count)` here.
    pub tag: u64,
    pub t_created: Time,
    /// Endpoint-message fragment content (`None` for legacy frames;
    /// presence is what marks a frame as endpoint traffic).
    pub data: Option<std::sync::Arc<Vec<u8>>>,
}

/// Receive notification mechanism (§3.1: interrupt or polling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RxMode {
    Interrupt,
    /// Poll every `interval` ns while traffic is pending (NAPI-style:
    /// idle ports schedule no ticks).
    Polling { interval: Time },
}

/// Per-node virtual NIC (ethX owned by the device driver).
#[derive(Debug, Clone)]
pub struct EthPort {
    pub mode: RxMode,
    /// Frames handed to the kernel, readable by the application.
    pub inbox: VecDeque<EthFrame>,
    /// Frames DMA'd to DRAM, awaiting a poll tick.
    pending_rx: VecDeque<EthFrame>,
    poll_scheduled: bool,
    /// Transmit DMA engine occupancy.
    tx_busy_until: Time,
    pub irqs_taken: u64,
    pub polls_taken: u64,
    pub frames_tx: u64,
    pub frames_rx: u64,
}

impl EthPort {
    fn new() -> Self {
        EthPort {
            mode: RxMode::Interrupt,
            inbox: VecDeque::new(),
            pending_rx: VecDeque::new(),
            poll_scheduled: false,
            tx_busy_until: 0,
            irqs_taken: 0,
            polls_taken: 0,
            frames_tx: 0,
            frames_rx: 0,
        }
    }
}

/// The external world behind the card's physical Ethernet port: an
/// NFS-flavoured file host plus the gateway's NAT state.
#[derive(Debug, Clone, Default)]
pub struct ExternalWorld {
    /// name → size of files saved over NFS.
    pub files: HashMap<String, u64>,
    /// Physical 1 GbE link occupancy (0.125 B/ns).
    pub ext_busy_until: Time,
    /// NAT port-forwarding table: external port → (node, internal port).
    pub nat: HashMap<u16, (NodeId, u16)>,
    /// Frames delivered to external observers (for tests).
    pub ext_rx_frames: u64,
    pub ext_rx_bytes: u64,
    /// In-flight NFS transfers: (node, tag) → (name, remaining, total).
    puts: HashMap<(u32, u64), (String, u64, u64)>,
}

/// Physical 1 GbE serialization: 8 ns per byte (125 MB/s).
const EXT_NS_PER_BYTE: u64 = 8;

/// All virtual NICs this engine owns, plus the (single) external world.
/// `ports` is sized by the engine's state [`Domain`] — the full mesh on
/// the serial engine, the owned subset on a shard — and indexed through
/// the domain's node map.
///
/// [`Domain`]: crate::network::Domain
#[derive(Debug, Clone)]
pub struct EthernetFabric {
    pub ports: Vec<EthPort>,
    domain: std::sync::Arc<crate::network::Domain>,
    pub external: ExternalWorld,
}

impl EthernetFabric {
    pub fn new(
        domain: std::sync::Arc<crate::network::Domain>,
        _cfg: &crate::config::SystemConfig,
    ) -> Self {
        EthernetFabric {
            ports: (0..domain.node_count()).map(|_| EthPort::new()).collect(),
            domain,
            external: ExternalWorld::default(),
        }
    }

    pub fn port(&self, n: NodeId) -> &EthPort {
        &self.ports[self.domain.node_index(n)]
    }

    pub fn port_mut(&mut self, n: NodeId) -> &mut EthPort {
        &mut self.ports[self.domain.node_index(n)]
    }
}

impl Network {
    /// Configure the receive notification mechanism of a node's NIC.
    pub fn eth_set_mode(&mut self, node: NodeId, mode: RxMode) {
        self.eth.port_mut(node).mode = mode;
    }

    /// The one internal-Ethernet transmit path (Fig 3's transmit
    /// operation: kernel stack → driver/descriptors → AXI-HP DMA into
    /// the fabric → router), for one frame produced at absolute time
    /// `at`. Everything else — the legacy [`Network::eth_send`] /
    /// [`Network::eth_send_message`] shims and the Endpoint API — is a
    /// thin wrapper over this: the single-frame send is literally the
    /// one-frame case of the message path.
    ///
    /// The software costs serialize on the source ARM from `at` (this
    /// is what makes internal Ethernet the slow path — §3.1 vs §3.2);
    /// `data` is the endpoint-message fragment, `None` for legacy
    /// size-only traffic.
    #[allow(clippy::too_many_arguments)] // one frame's full wire identity
    pub(crate) fn eth_frame_tx(
        &mut self,
        at: Time,
        id: u64,
        src: NodeId,
        dst: NodeId,
        bytes: u32,
        tag: u64,
        data: Option<std::sync::Arc<Vec<u8>>>,
    ) {
        assert!(bytes <= ETH_MTU, "frame payload {bytes} exceeds MTU {ETH_MTU}");
        let arm = self.cfg.arm;
        let sw = arm.kernel_stack + arm.driver + arm.dma_setup;
        let node = self.node_mut(src);
        let cpu_start = at.max(node.cpu_free_at);
        node.cpu_free_at = cpu_start + sw;
        node.cpu_busy_ns += sw;
        let port = self.eth.port_mut(src);
        port.frames_tx += 1;
        let dma_start = (cpu_start + sw).max(port.tx_busy_until);
        let wire = bytes + ETH_OVERHEAD;
        let dma = (wire as f64 / arm.axi_bytes_per_ns).ceil() as Time;
        port.tx_busy_until = dma_start + dma;
        let frame = Box::new(EthFrame { id, src, dst, bytes, tag, t_created: at, data });
        self.sim
            .at_keyed(dma_start + dma, crate::network::key_eth(src), Event::EthTx { frame });
    }

    /// Transmit one frame (≤ MTU payload) from `src` to `dst` over the
    /// internal Ethernet: the one-frame case of
    /// [`Network::eth_frame_tx`], with a driver-assigned packet id.
    pub fn eth_send(&mut self, src: NodeId, dst: NodeId, bytes: u32, tag: u64) {
        self.metrics.record_mode("ethernet", bytes as u64);
        let id = self.next_packet_id();
        let now = self.now();
        self.eth_frame_tx(now, id, src, dst, bytes, tag, None);
    }

    /// Send an arbitrary-size message: the kernel segments it into
    /// MTU-sized frames (models TCP segmentation), each going down the
    /// same path as a single-frame send.
    pub fn eth_send_message(&mut self, src: NodeId, dst: NodeId, bytes: u64, tag: u64) -> u32 {
        self.metrics.record_mode("ethernet", bytes);
        let mut left = bytes;
        let mut frames = 0;
        while left > 0 {
            let take = left.min(ETH_MTU as u64) as u32;
            let id = self.next_packet_id();
            let now = self.now();
            self.eth_frame_tx(now, id, src, dst, take, tag, None);
            left -= take as u64;
            frames += 1;
        }
        frames
    }

    /// Frame DMA into the fabric finished: inject as a network packet
    /// (the packet id was assigned when the frame was created). The
    /// frame itself travels *inside* the packet, so it follows the
    /// packet across shard boundaries (the receive side may live on a
    /// different shard than this transmit side).
    pub(crate) fn eth_tx_inject(&mut self, frame: EthFrame) {
        let id = frame.id;
        let wire = frame.bytes + ETH_OVERHEAD;
        let mut pkt = Packet::new(
            id,
            frame.src,
            frame.dst,
            RouteKind::Directed,
            Proto::Ethernet,
            Payload::Synthetic(wire),
            frame.t_created,
        );
        pkt.seq = frame.tag;
        pkt.eth_frame = Some(Box::new(frame));
        self.inject(pkt);
    }

    /// Packet Demux: an Ethernet packet reached its destination NIC. The
    /// device DMAs it into a DRAM buffer described by a buffer
    /// descriptor, then notifies the driver (interrupt or polling).
    pub(crate) fn eth_deliver(&mut self, node: NodeId, mut packet: Packet) {
        let frame = *packet
            .eth_frame
            .take()
            .expect("ethernet packet without embedded frame");
        let arm = self.cfg.arm;
        let wire = frame.bytes + ETH_OVERHEAD;
        let dma = (wire as f64 / arm.axi_bytes_per_ns).ceil() as Time;
        match self.eth.port(node).mode {
            RxMode::Interrupt => {
                // IRQ → driver → kernel stack, all on the ARM.
                let cost = arm.irq_cost + arm.driver + arm.kernel_stack;
                self.node_mut(node).cpu_busy_ns += cost;
                self.eth.port_mut(node).irqs_taken += 1;
                self.sim.after_keyed(
                    dma + cost,
                    crate::network::key_eth(node),
                    Event::EthRx { node, frame: Box::new(frame) },
                );
            }
            RxMode::Polling { interval } => {
                let deliver_at = self.now() + dma;
                let port = self.eth.port_mut(node);
                port.pending_rx.push_back(frame);
                if !port.poll_scheduled {
                    port.poll_scheduled = true;
                    let tick = deliver_at.div_ceil(interval).max(1) * interval;
                    self.sim.at_keyed(
                        tick.max(deliver_at),
                        crate::network::key_eth(node),
                        Event::EthPoll { node },
                    );
                }
            }
        }
    }

    /// Interrupt-path completion (or poll-path per-frame handoff): the
    /// frame is in the kernel; hand it to the application.
    pub(crate) fn eth_rx(&mut self, node: NodeId, frame: EthFrame, app: &mut dyn App) {
        let lat = self.now() - frame.t_created;
        self.metrics
            .packet_latency
            .entry("eth_frame")
            .or_insert_with(crate::metrics::LatencyHist::new)
            .record(lat);
        self.eth.port_mut(node).frames_rx += 1;
        self.eth.port_mut(node).inbox.push_back(frame.clone());
        if node == self.gateway() && frame.tag & (1 << 63) != 0 {
            self.nfs_progress(&frame);
        }
        let captured = self.comm_capture_eth(node, &frame);
        self.app_scope(app, |net, app| {
            app.on_eth(net, node, &frame);
            if let Some((ep, msg)) = captured {
                net.comm_deliver(app, ep, msg);
            }
        });
    }

    /// Polling tick: drain everything that has been DMA'd so far. One
    /// poll amortizes the notification cost over all pending frames —
    /// this is why polling wins under high traffic (§3.1).
    pub(crate) fn eth_poll(&mut self, node: NodeId, app: &mut dyn App) {
        let arm = self.cfg.arm;
        let drained: Vec<EthFrame> = {
            let port = self.eth.port_mut(node);
            port.polls_taken += 1;
            port.poll_scheduled = false;
            port.pending_rx.drain(..).collect()
        };
        let cost = arm.poll_cost + drained.len() as Time * (arm.driver + arm.kernel_stack);
        self.node_mut(node).cpu_busy_ns += cost;
        for frame in drained {
            self.eth_rx(node, frame, app);
        }
        // NAPI-style: if more frames raced in, keep polling.
        let more = !self.eth.port(node).pending_rx.is_empty();
        if more {
            if let RxMode::Polling { interval } = self.eth.port(node).mode {
                self.eth.port_mut(node).poll_scheduled = true;
                self.sim.after_keyed(
                    interval,
                    crate::network::key_eth(node),
                    Event::EthPoll { node },
                );
            }
        }
    }

    /// Read received frames at a node.
    pub fn eth_read(&mut self, node: NodeId) -> Vec<EthFrame> {
        self.eth.port_mut(node).inbox.drain(..).collect()
    }

    // ------------------------------------------------------------------
    // Gateway / NAT / NFS (§3.1 last paragraph)
    // ------------------------------------------------------------------

    /// The gateway node — (100) of card (0,0,0) — carries the physical
    /// Ethernet port.
    pub fn gateway(&self) -> NodeId {
        self.topo.gateway_node((0, 0, 0))
    }

    /// Install a NAT port-forwarding entry at the gateway.
    pub fn nat_forward(&mut self, external_port: u16, node: NodeId, internal_port: u16) {
        self.eth.external.nat.insert(external_port, (node, internal_port));
    }

    /// Register an in-flight NFS transfer with the gateway-side state
    /// (the shard that owns the gateway, in a sharded run — the
    /// arriving frames progress the transfer there).
    pub(crate) fn nfs_register_put(&mut self, node: NodeId, name: &str, size: u64) {
        // Mode accounting lives here because both engines pass every
        // put through this registration (the sharded wrapper calls it
        // on the gateway's shard).
        self.metrics.record_mode("nfs", size);
        let tag = nfs_tag(name);
        self.eth
            .external
            .puts
            .insert((node.0, tag), (name.to_string(), size, size));
    }

    /// Save `size` bytes from `node` to the external NFS host as `name`.
    /// The data travels over the internal Ethernet to the gateway, then
    /// over the physical 1 GbE port. Completion is visible when
    /// `external.files` contains the name (after quiescence).
    pub fn nfs_put(&mut self, node: NodeId, name: &str, size: u64) {
        let gw = self.gateway();
        let tag = nfs_tag(name);
        self.nfs_register_put(node, name, size);
        if node == gw {
            // Local: straight out of the physical port, no fabric hops.
            let mut left = size;
            while left > 0 {
                let take = left.min(ETH_MTU as u64) as u32;
                self.gateway_egress(node, take + ETH_OVERHEAD, tag);
                left -= take as u64;
            }
            self.eth.external.puts.remove(&(node.0, tag));
            self.eth.external.files.insert(name.to_string(), size);
            return;
        }
        self.eth_send_message(node, gw, size, tag);
    }

    /// Gateway-side handling of a frame destined for the external world:
    /// NAT translation + physical-port serialization.
    pub(crate) fn gateway_egress(&mut self, _from: NodeId, wire_bytes: u32, _tag: u64) {
        let now = self.now();
        let ext = &mut self.eth.external;
        let start = now.max(ext.ext_busy_until);
        ext.ext_busy_until = start + wire_bytes as u64 * EXT_NS_PER_BYTE;
        ext.ext_rx_frames += 1;
        ext.ext_rx_bytes += wire_bytes as u64;
    }

    /// Progress NFS transfers: invoked at the gateway for every arriving
    /// frame whose tag marks it as NFS traffic.
    pub(crate) fn nfs_progress(&mut self, frame: &EthFrame) {
        let key = (frame.src.0, frame.tag);
        if !self.eth.external.puts.contains_key(&key) {
            return;
        }
        self.gateway_egress(frame.src, frame.bytes + ETH_OVERHEAD, frame.tag);
        let (name, left, total) = self.eth.external.puts.get_mut(&key).unwrap();
        *left = left.saturating_sub(frame.bytes as u64);
        if *left == 0 {
            let (name, total) = (name.clone(), *total);
            self.eth.external.puts.remove(&key);
            self.eth.external.files.insert(name, total);
        }
    }

    /// Deliver an external frame to an internal node through NAT, the
    /// frame reaching the physical port at the current instant.
    pub fn external_ingress(&mut self, external_port: u16, bytes: u32, tag: u64) -> bool {
        let now = self.now();
        self.external_ingress_at(now, external_port, bytes, tag)
    }

    /// Deliver an external frame to an internal node through NAT, the
    /// frame reaching the physical port at absolute time `at` (≥ now).
    /// Open-loop workloads ([`crate::workload::serving`]) precompute an
    /// arrival schedule in driver context and feed it through here in
    /// ascending order — the physical 1 GbE port serializes arrivals
    /// from `max(at, port busy)`, so a burst queues on the wire exactly
    /// as it would at the real gateway. Returns `false` (frame dropped
    /// at the gateway) when no NAT entry maps `external_port`.
    pub fn external_ingress_at(
        &mut self,
        at: Time,
        external_port: u16,
        bytes: u32,
        tag: u64,
    ) -> bool {
        let Some(&(node, _iport)) = self.eth.external.nat.get(&external_port) else {
            return false; // no forwarding entry: dropped at the gateway
        };
        let gw = self.gateway();
        // Physical-port serialization first.
        let wire = bytes + ETH_OVERHEAD;
        let ext = &mut self.eth.external;
        let start = at.max(ext.ext_busy_until);
        ext.ext_busy_until = start + wire as u64 * EXT_NS_PER_BYTE;
        // Then the gateway forwards over the internal fabric.
        let deliver_at = ext.ext_busy_until;
        self.metrics.record_mode("ethernet", bytes as u64);
        let id = self.next_packet_id();
        let frame =
            Box::new(EthFrame { id, src: gw, dst: node, bytes, tag, t_created: at, data: None });
        self.sim.at_keyed(deliver_at, crate::network::key_eth(gw), Event::EthTx { frame });
        true
    }
}

/// Deterministic tag for an NFS transfer name.
pub fn nfs_tag(name: &str) -> u64 {
    // FNV-1a, stable across runs.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h | 1 << 63
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::NullApp;
    use crate::topology::Coord;

    #[test]
    fn frame_roundtrip_interrupt_mode() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 2, y: 0, z: 0 });
        net.eth_send(src, dst, 1000, 42);
        net.run_to_quiescence(&mut NullApp);
        let frames = net.eth_read(dst);
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].bytes, 1000);
        assert_eq!(frames[0].tag, 42);
        assert_eq!(net.eth.port(dst).irqs_taken, 1);
        // CPU was charged on both sides.
        assert!(net.nodes[src.0 as usize].cpu_busy_ns > 0);
        assert!(net.nodes[dst.0 as usize].cpu_busy_ns > 0);
    }

    #[test]
    fn message_segmentation() {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(3));
        let frames = net.eth_send_message(a, b, 4000, 7);
        assert_eq!(frames, 3); // 1500+1500+1000
        net.run_to_quiescence(&mut NullApp);
        let got = net.eth_read(b);
        assert_eq!(got.len(), 3);
        assert_eq!(got.iter().map(|f| f.bytes as u64).sum::<u64>(), 4000);
    }

    #[test]
    fn polling_beats_interrupts_on_cpu_under_load() {
        // §3.1: polling "is far more efficient under high traffic".
        let run = |mode: RxMode| {
            let mut net = Network::card();
            let dst = net.topo.id(Coord { x: 1, y: 1, z: 1 });
            net.eth_set_mode(dst, mode);
            for i in 0..26u32 {
                let src = NodeId(if i >= dst.0 { i + 1 } else { i });
                for _ in 0..8 {
                    net.eth_send(src, dst, 1400, 0);
                }
            }
            net.run_to_quiescence(&mut NullApp);
            assert_eq!(net.eth.port(dst).frames_rx, 26 * 8);
            net.nodes[dst.0 as usize].cpu_busy_ns
        };
        let irq_cpu = run(RxMode::Interrupt);
        let poll_cpu = run(RxMode::Polling { interval: 20_000 });
        assert!(
            poll_cpu < irq_cpu,
            "polling rx CPU {poll_cpu} should beat interrupt rx CPU {irq_cpu}"
        );
    }

    #[test]
    fn polling_adds_latency_under_light_load() {
        let one = |mode: RxMode| {
            let mut net = Network::card();
            let (a, b) = (NodeId(0), NodeId(1));
            net.eth_set_mode(b, mode);
            net.eth_send(a, b, 64, 0);
            net.run_to_quiescence(&mut NullApp);
            net.now()
        };
        let t_irq = one(RxMode::Interrupt);
        let t_poll = one(RxMode::Polling { interval: 100_000 });
        assert!(t_poll > t_irq, "poll {t_poll} vs irq {t_irq}");
    }

    #[test]
    fn nat_ingress_reaches_forwarded_node() {
        let mut net = Network::card();
        let inner = net.topo.id(Coord { x: 2, y: 2, z: 1 });
        net.nat_forward(2222, inner, 22);
        assert!(net.external_ingress(2222, 512, 99));
        assert!(!net.external_ingress(8080, 512, 99), "unmapped port must drop");
        net.run_to_quiescence(&mut NullApp);
        let frames = net.eth_read(inner);
        assert_eq!(frames.len(), 1);
        assert_eq!(frames[0].bytes, 512);
        assert_eq!(frames[0].src, net.gateway());
    }

    #[test]
    fn nfs_put_drains_to_external_host() {
        let mut net = Network::card();
        let node = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        net.nfs_put(node, "checkpoint.bin", 6000);
        net.run_to_quiescence(&mut NullApp);
        // All frames crossed the physical port.
        assert!(net.eth.external.ext_rx_bytes >= 6000);
        assert!(net.eth.external.ext_rx_frames >= 4);
    }
}
