//! Bridge FIFO (§3.3, Fig 5): hardware-to-hardware FIFO channels.
//!
//! A channel is a (transmit, receive) module pair: the write port lives
//! on the source node, the read port on the destination node. The
//! transmit unit converts words into network packets; up to 32 transmit
//! units share one Bridge FIFO Mux (more channels ⇒ more muxes, which the
//! fabric instantiates transparently: mux index = channel / 32). Widths
//! of 7..=64 bits are supported; wider data needs parallel FIFOs.
//!
//! The underlying network does not guarantee ordering (§2.4), so packets
//! carry a per-channel sequence number and the receive unit holds a
//! reorder buffer, releasing words strictly in FIFO order.
//!
//! Latency calibration (Table 1): the FIFO logic costs
//! [`crate::config::SystemConfig::bridge_fifo_logic`] ns end to end,
//! split evenly between transmit and receive halves; see config docs.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use crate::network::{App, Event, Network};
use crate::router::{Packet, Payload, Proto, RouteKind};
use crate::sim::Time;
use crate::topology::NodeId;
use crate::util::FxHashMap;

/// Max transmit/receive units per Bridge FIFO Mux/Demux (§3.3).
pub const CHANNELS_PER_MUX: u8 = 32;
/// Supported FIFO widths, bits (§3.3).
pub const MIN_WIDTH: u8 = 7;
pub const MAX_WIDTH: u8 = 64;

/// Transmit-unit state.
#[derive(Debug, Clone)]
pub struct TxUnit {
    pub dst: NodeId,
    pub width_bits: u8,
    next_seq: u64,
    pub words_sent: u64,
}

/// Receive-unit state.
#[derive(Debug, Clone)]
pub struct RxUnit {
    pub src: NodeId,
    pub width_bits: u8,
    expected_seq: u64,
    reorder: BTreeMap<u64, Vec<u64>>,
    /// The read port: words readable by FPGA logic / software.
    pub inbox: VecDeque<u64>,
    pub words_received: u64,
    /// Packets that arrived out of order (diagnostics).
    pub ooo_packets: u64,
}

/// All Bridge-FIFO endpoints in the system. Endpoint lookup is on the
/// per-packet path (`fifo_send` / `fifo_rx`), so the maps use
/// deterministic Fx hashing.
#[derive(Debug, Clone, Default)]
pub struct BridgeFifoFabric {
    tx: FxHashMap<(u32, u8), TxUnit>,
    rx: FxHashMap<(u32, u8), RxUnit>,
}

impl BridgeFifoFabric {
    pub fn new(_nodes: usize) -> Self {
        BridgeFifoFabric::default()
    }

    pub fn rx_unit(&self, node: NodeId, channel: u8) -> Option<&RxUnit> {
        self.rx.get(&(node.0, channel))
    }

    pub fn rx_unit_mut(&mut self, node: NodeId, channel: u8) -> Option<&mut RxUnit> {
        self.rx.get_mut(&(node.0, channel))
    }

    pub fn tx_unit(&self, node: NodeId, channel: u8) -> Option<&TxUnit> {
        self.tx.get(&(node.0, channel))
    }

    /// Number of muxes a node needs for its transmit units.
    pub fn mux_count(&self, node: NodeId) -> usize {
        let max_ch = self
            .tx
            .keys()
            .filter(|(n, _)| *n == node.0)
            .map(|(_, c)| *c)
            .max();
        match max_ch {
            None => 0,
            Some(c) => c as usize / CHANNELS_PER_MUX as usize + 1,
        }
    }
}

impl Network {
    /// Instantiate a Bridge FIFO channel: write port on `src`, read port
    /// on `dst` (§3.3: "always implemented in pairs").
    pub fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8, width_bits: u8) {
        assert!(
            (MIN_WIDTH..=MAX_WIDTH).contains(&width_bits),
            "Bridge FIFO width must be 7..=64 bits, got {width_bits}"
        );
        let prev_tx = self.fifos.tx.insert(
            (src.0, channel),
            TxUnit { dst, width_bits, next_seq: 0, words_sent: 0 },
        );
        assert!(prev_tx.is_none(), "channel {channel} already connected at {src}");
        let prev_rx = self.fifos.rx.insert(
            (dst.0, channel),
            RxUnit {
                src,
                width_bits,
                expected_seq: 0,
                reorder: BTreeMap::new(),
                inbox: VecDeque::new(),
                words_received: 0,
                ooo_packets: 0,
            },
        );
        assert!(prev_rx.is_none(), "channel {channel} already connected at {dst}");
    }

    /// Write words into the channel's transmit port now, with
    /// driver-assigned packet ids: the legacy shim over
    /// [`Network::fifo_send_impl`]. For a raw word stream the words
    /// *are* the payload, so mode accounting counts `words × 8` (the
    /// Endpoint API counts its byte payload instead, excluding its
    /// framing header).
    pub fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]) {
        self.metrics.record_mode("bridge_fifo", words.len() as u64 * 8);
        let now = self.now();
        self.fifo_send_impl(now, src, channel, words, false);
    }

    /// Endpoint-layer transmit ([`crate::channels::endpoint`]): words
    /// are produced at absolute time `at ≥ now` with per-node app
    /// packet ids, so it is valid from [`App`] callbacks on both
    /// engines.
    pub(crate) fn fifo_send_app(&mut self, at: Time, src: NodeId, channel: u8, words: &[u64]) {
        debug_assert!(at >= self.now(), "Bridge-FIFO words produced in the past");
        self.fifo_send_impl(at, src, channel, words, true);
    }

    /// The one Bridge-FIFO transmit recipe: mask words to the channel
    /// width, packetize (chunking at the network MTU, one per-channel
    /// sequence number per packet for the receive-side reorder buffer)
    /// and hand the packets to the Packet Mux at `at` + transmit logic
    /// + injection overhead. `app_ids` selects the packet-id space:
    /// driver-global (legacy [`Network::fifo_send`]) or per-node app
    /// ids (the Endpoint API).
    fn fifo_send_impl(&mut self, at: Time, src: NodeId, channel: u8, words: &[u64], app_ids: bool) {
        let (dst, width, seq0) = {
            let tx = self
                .fifos
                .tx
                .get_mut(&(src.0, channel))
                .unwrap_or_else(|| panic!("no Bridge FIFO tx {channel} at {src}"));
            tx.words_sent += words.len() as u64;
            let s = tx.next_seq;
            (tx.dst, tx.width_bits, s)
        };
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let tx_logic = self.cfg.bridge_fifo_logic / 2;

        if dst == src {
            // Hop-0 (Table 1 first column): transmit and receive units on
            // the same node; the full FIFO logic delay applies, nothing
            // touches the network.
            let masked: Vec<u64> = words.iter().map(|w| w & mask).collect();
            let logic = self.cfg.bridge_fifo_logic;
            self.sim.at_keyed(
                at + logic,
                crate::network::key_fifo_local(src, channel),
                Event::FifoLocal { node: src, channel, words: Arc::new(masked) },
            );
            return;
        }

        // Chunk words so each packet fits the MTU.
        let max_words = ((self.cfg.link.mtu - crate::router::HEADER_BYTES) / 8) as usize;
        let mut seq = seq0;
        for chunk in words.chunks(max_words.max(1)) {
            let masked: Vec<u64> = chunk.iter().map(|w| w & mask).collect();
            let id = if app_ids { self.app_packet_id(src) } else { self.next_packet_id() };
            let mut pkt = Packet::new(
                id,
                src,
                dst,
                RouteKind::Directed,
                Proto::BridgeFifo { channel },
                Payload::Words(std::sync::Arc::new(masked)),
                at,
            );
            pkt.seq = seq;
            seq += 1;
            // Transmit-unit logic runs before the packet reaches the
            // Packet Mux / router (injection overhead accounts for those).
            let delay = tx_logic + self.cfg.link.inject_latency;
            self.metrics.packets_injected += 1;
            let packet = self.packets.alloc(pkt);
            self.sim.at_keyed(at + delay, crate::network::key_inject(id), Event::Inject { packet });
        }
        self.fifos.tx.get_mut(&(src.0, channel)).unwrap().next_seq = seq;
    }

    /// Receive-unit logic completed for `packet` (scheduled by the Packet
    /// Demux on delivery): reorder and release words in FIFO order.
    pub(crate) fn fifo_rx(&mut self, node: NodeId, packet: Packet, app: &mut dyn App) {
        let channel = match packet.proto {
            Proto::BridgeFifo { channel } => channel,
            _ => unreachable!(),
        };
        // The packet owns its payload here, so the common (in-order,
        // refcount 1) case takes the words without copying.
        let words = match packet.payload {
            Payload::Words(w) => Arc::try_unwrap(w).unwrap_or_else(|a| (*a).clone()),
            _ => unreachable!("Bridge FIFO packet without words"),
        };
        let latency = self.now() - packet.injected_at;
        self.metrics.record_delivery("bridge_fifo", latency, packet.wire_bytes);
        let released: Vec<u64> = {
            let rx = self
                .fifos
                .rx
                .get_mut(&(node.0, channel))
                .unwrap_or_else(|| panic!("no Bridge FIFO rx {channel} at {node}"));
            if packet.seq != rx.expected_seq {
                rx.ooo_packets += 1;
                rx.reorder.insert(packet.seq, words);
                Vec::new()
            } else {
                let mut rel = words;
                rx.expected_seq += 1;
                while let Some(w) = rx.reorder.remove(&rx.expected_seq) {
                    rel.extend_from_slice(&w);
                    rx.expected_seq += 1;
                }
                rx.words_received += rel.len() as u64;
                rx.inbox.extend(rel.iter().copied());
                rel
            }
        };
        if !released.is_empty() {
            let captured = self.comm_capture_fifo(node, channel, &released);
            self.app_scope(app, |net, app| {
                app.on_fifo(net, node, channel, &released);
                for (ep, msg) in captured {
                    net.comm_deliver(app, ep, msg);
                }
            });
        }
    }

    /// Same-node delivery (see [`Network::fifo_send`]).
    pub(crate) fn fifo_local_rx(
        &mut self,
        node: NodeId,
        channel: u8,
        words: &[u64],
        app: &mut dyn App,
    ) {
        {
            let rx = self
                .fifos
                .rx
                .get_mut(&(node.0, channel))
                .unwrap_or_else(|| panic!("no Bridge FIFO rx {channel} at {node}"));
            rx.words_received += words.len() as u64;
            rx.inbox.extend(words.iter().copied());
        }
        self.metrics.record_delivery("bridge_fifo", self.cfg.bridge_fifo_logic, 0);
        let captured = self.comm_capture_fifo(node, channel, words);
        self.app_scope(app, |net, app| {
            app.on_fifo(net, node, channel, words);
            for (ep, msg) in captured {
                net.comm_deliver(app, ep, msg);
            }
        });
    }

    /// Read up to `max` words from a channel's read port.
    pub fn fifo_read(&mut self, node: NodeId, channel: u8, max: usize) -> Vec<u64> {
        let rx = match self.fifos.rx.get_mut(&(node.0, channel)) {
            Some(rx) => rx,
            None => return Vec::new(),
        };
        let take = max.min(rx.inbox.len());
        rx.inbox.drain(..take).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::NullApp;
    use crate::topology::Coord;

    #[test]
    fn table1_latencies_exact() {
        // The headline reproduction: Table 1 of the paper.
        // hops: 0 → 0.25 µs, 1 → 1.1 µs, 3 → 2.5 µs, 6 → 4.6 µs (paper 4.7).
        let cases = [
            (Coord { x: 0, y: 0, z: 0 }, 250u64),
            (Coord { x: 1, y: 0, z: 0 }, 1_100),
            (Coord { x: 1, y: 1, z: 1 }, 2_500),
            (Coord { x: 2, y: 2, z: 2 }, 4_600),
        ];
        for (dstc, expect_ns) in cases {
            let mut net = Network::card();
            let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
            let dst = net.topo.id(dstc);
            net.fifo_connect(src, dst, 0, 64);
            net.fifo_send(src, 0, &[0xDEADBEEF]);
            net.run_to_quiescence(&mut NullApp);
            let words = net.fifo_read(dst, 0, 16);
            assert_eq!(words, vec![0xDEADBEEF]);
            let lat = net.metrics.latency("bridge_fifo").unwrap().max();
            assert_eq!(lat, expect_ns, "dst {dstc}");
        }
    }

    #[test]
    fn width_masking() {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(1));
        net.fifo_connect(a, b, 3, 7);
        net.fifo_send(a, 3, &[0x1FF]); // 9 bits set, 7-bit channel
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.fifo_read(b, 3, 1), vec![0x7F]);
    }

    #[test]
    #[should_panic(expected = "width must be 7..=64")]
    fn width_out_of_range_rejected() {
        let mut net = Network::card();
        net.fifo_connect(NodeId(0), NodeId(1), 0, 6);
    }

    #[test]
    fn fifo_order_preserved_across_many_packets() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        net.fifo_connect(src, dst, 0, 64);
        let words: Vec<u64> = (0..2000).collect();
        // Send in small bursts to get many packets in flight (adaptive
        // routing may reorder them).
        for chunk in words.chunks(37) {
            net.fifo_send(src, 0, chunk);
        }
        net.run_to_quiescence(&mut NullApp);
        let got = net.fifo_read(dst, 0, 4000);
        assert_eq!(got, words, "FIFO order must survive out-of-order routing");
    }

    #[test]
    fn multiple_channels_do_not_cross() {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(13));
        net.fifo_connect(a, b, 0, 64);
        net.fifo_connect(a, b, 1, 64);
        net.fifo_send(a, 0, &[111]);
        net.fifo_send(a, 1, &[222]);
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.fifo_read(b, 0, 8), vec![111]);
        assert_eq!(net.fifo_read(b, 1, 8), vec![222]);
    }

    #[test]
    fn mux_count_grows_past_32_channels() {
        let mut net = Network::card();
        for ch in 0..40u8 {
            net.fifo_connect(NodeId(0), NodeId(1), ch, 64);
        }
        assert_eq!(net.fifos.mux_count(NodeId(0)), 2);
        assert_eq!(net.fifos.mux_count(NodeId(2)), 0);
    }

    #[test]
    fn bidirectional_needs_two_channels() {
        // tx/rx are a pair per direction; the reverse direction is its
        // own channel pair.
        let mut net = Network::card();
        net.fifo_connect(NodeId(0), NodeId(1), 0, 64);
        net.fifo_connect(NodeId(1), NodeId(0), 1, 64);
        net.fifo_send(NodeId(0), 0, &[1]);
        net.fifo_send(NodeId(1), 1, &[2]);
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.fifo_read(NodeId(1), 0, 8), vec![1]);
        assert_eq!(net.fifo_read(NodeId(0), 1, 8), vec![2]);
    }
}
