//! Virtual communication channels layered on the packet router (§3) —
//! and the unified, first-class API over them.
//!
//! "Multiple virtual channels can be designed to sit atop the underlying
//! packet router logic … to give the processor and FPGA logic different
//! virtual or logical interfaces to the communication network." The
//! paper's point is not any single channel but the *choice*: the three
//! it describes are interchangeable transports multiplexed onto the
//! same SERDES links through the Packet Mux/Demux (modeled by
//! [`crate::router::Proto`] dispatch in [`crate::network::Network`]),
//! each trading compatibility against overhead.
//!
//! That choice is a first-class value here: a [`CommMode`] names a
//! channel (with its per-mode parameters), [`ChannelCaps`] describes
//! what it guarantees — latency class, ordering, reliability, max
//! payload, setup requirements; the paper's Table 1 distinctions in
//! code — and the [`endpoint`] module implements one
//! `open`/`connect`/`send`/`recv` surface over every mode, on both
//! simulation engines (see [`crate::network::Fabric`]). Workloads take
//! a `CommMode` instead of hard-coding a method family; `repro
//! learners|mcts --comm pm|eth|fifo` switches the transport under an
//! unchanged workload.
//!
//! The channels, from most compatible to lowest latency:
//!
//! * [`ethernet`] — the virtual **Internal Ethernet** (§3.1, Fig 3): a
//!   standard-looking NIC so unmodified IP software (ssh, MPI, NFS) runs
//!   between nodes; the heaviest path (full kernel stack: [`ChannelCaps::cpu_on_path`])
//!   but the most compatible. [`CommMode::Ethernet`], and the transport
//!   behind [`CommMode::Nfs`]'s external-storage path.
//! * [`postmaster`] — **Postmaster DMA** (§3.2, Fig 4): a tunneled queue
//!   for small messages; initiator writes to a fixed address, data lands
//!   in a contiguous receive stream on the target; far lower overhead
//!   than TCP/IP. One atomic record per message
//!   ([`ChannelCaps::max_payload`]). [`CommMode::Postmaster`].
//! * [`bridge_fifo`] — **Bridge FIFO** (§3.3, Fig 5, Table 1): direct
//!   hardware-to-hardware FIFO between two FPGAs; lowest latency of all,
//!   and the only mode with per-pair FIFO ordering
//!   ([`crate::channels::endpoint::MsgOrdering::PerPairFifo`]) — at the
//!   price of per-pair setup ([`ChannelCaps::pair_setup`]).
//!   [`CommMode::BridgeFifo`].
//!
//! (NetTunnel register writes (§4.2) round out the set as
//! [`CommMode::Tunnel`] — one-word messages with no ARM involvement.)
//!
//! The capability contracts are property-tested on both engines in
//! `tests/comm_caps.rs`; the mode choice is benchmarked on identical
//! traffic in `benches/sim_engine.rs` (`comm_mode_sweep`,
//! EXPERIMENTS.md E11).

pub mod bridge_fifo;
pub mod endpoint;
pub mod ethernet;
pub mod postmaster;
pub mod reliable;

pub use endpoint::{
    ChannelCaps, CommMode, Endpoint, LatencyClass, Message, MsgId, MsgOrdering, Reliability,
};
pub use reliable::{ReliableParams, RELIABLE_HEADER_BYTES};
