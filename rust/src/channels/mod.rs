//! Virtual communication channels layered on the packet router (§3).
//!
//! "Multiple virtual channels can be designed to sit atop the underlying
//! packet router logic … to give the processor and FPGA logic different
//! virtual or logical interfaces to the communication network." The three
//! the paper describes — and we implement — are:
//!
//! * [`ethernet`] — the virtual **Internal Ethernet** (§3.1, Fig 3): a
//!   standard-looking NIC so unmodified IP software (ssh, MPI, NFS) runs
//!   between nodes; the heaviest path (full kernel stack) but the most
//!   compatible.
//! * [`postmaster`] — **Postmaster DMA** (§3.2, Fig 4): a tunneled queue
//!   for small messages; initiator writes to a fixed address, data lands
//!   in a contiguous receive stream on the target; far lower overhead
//!   than TCP/IP.
//! * [`bridge_fifo`] — **Bridge FIFO** (§3.3, Fig 5, Table 1): direct
//!   hardware-to-hardware FIFO between two FPGAs; lowest latency of all.
//!
//! All three multiplex onto the same SERDES links through the Packet
//! Mux/Demux (modeled by [`crate::router::Proto`] dispatch in
//! [`crate::network::Network`]).

pub mod bridge_fifo;
pub mod ethernet;
pub mod postmaster;
