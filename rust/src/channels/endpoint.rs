//! First-class communication modes: the unified `Endpoint` API.
//!
//! The paper's headline flexibility claim is the *choice* of
//! communication mode: Internal Ethernet (§3.1), Postmaster DMA (§3.2)
//! and Bridge FIFO (§3.3) are interchangeable virtual channels
//! multiplexed onto the same SERDES links. This module makes that
//! choice a first-class value instead of a method family:
//!
//! * [`CommMode`] — which channel, with its per-mode parameters;
//! * [`ChannelCaps`] — what the channel guarantees (latency class,
//!   ordering, reliability, max payload, setup requirements — the
//!   paper's Table 1 distinctions, in code);
//! * [`Endpoint`] — a node's attachment to one mode, returned by
//!   `open(node, mode)`;
//! * [`Message`] — a byte datagram sent with `send(ep, dst, msg)` and
//!   received with `recv(ep)` or the [`App::on_message`] callback.
//!
//! `open`/`connect`/`send`/`send_at`/`recv` are implemented by the
//! serial [`Network`] (here) and by the sharded engine (thin routing
//! wrappers), and are exposed engine-agnostically on the
//! [`Fabric`](crate::network::Fabric) trait — a workload written
//! against endpoints runs on either engine, byte-identically, on any
//! mode.
//!
//! # Transport mapping
//!
//! | mode | message = | framing |
//! |---|---|---|
//! | `Postmaster` | one record (≤ one packet) | none — records are atomic |
//! | `Ethernet` | any size | segmented into MTU frames; the frame tag carries `(msg seq, frag idx, frag count)` and the receive side reassembles |
//! | `BridgeFifo` | any size | a length+seq header word, then 8 bytes per word; the channel's per-pair FIFO order makes stream framing safe |
//! | `Tunnel` | ≤ 8 bytes | one register write to the mode's mailbox address |
//! | `Nfs` | any size | an NFS put of the payload size to external storage (no `recv`) |
//!
//! All sends draw packet ids from the per-node app id space
//! ([`Network::app_packet_id`]), so they are valid from driver context
//! *and* from [`App`] callbacks, on both engines — one code path serves
//! kickoff and reaction alike. (The exception is `Nfs`, whose gateway
//! path keeps the legacy driver-context recipe.)
//!
//! [`App`]: crate::network::App
//! [`App::on_message`]: crate::network::App::on_message

use std::collections::VecDeque;
use std::sync::Arc;

use crate::channels::ethernet::{EthFrame, RxMode, ETH_MTU};
use crate::channels::postmaster::PmRecord;
use crate::config::SystemConfig;
use crate::network::Network;
use crate::router::{Packet, Payload, Proto, RouteKind, HEADER_BYTES};
use crate::sim::Time;
use crate::topology::NodeId;
use crate::util::FxHashMap;

/// A communication mode: which virtual channel, with its per-mode
/// parameters. `Copy` so workloads can thread it through configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    /// Internal Ethernet (§3.1): full kernel/driver software path,
    /// receive notification per `rx`.
    Ethernet { rx: RxMode },
    /// Postmaster DMA (§3.2): record stream into `queue` at the target.
    Postmaster { queue: u8 },
    /// Bridge FIFO (§3.3): hardware FIFO pairs. Endpoint byte framing
    /// requires the full 64-bit word width.
    BridgeFifo { width_bits: u8 },
    /// NFS over the gateway's physical port (§3.1, last paragraph):
    /// payloads land on external storage. Send-only, driver context.
    Nfs,
    /// NetTunnel register writes (§4.2) to the mailbox register `addr`
    /// on the destination node. Payloads are one word (≤ 8 bytes).
    Tunnel { addr: u64 },
    /// Header-free datagrams straight on the router (§2.4): one
    /// [`Message`] = one `Proto::Raw` packet, nothing on the wire
    /// beyond the fixed packet header — no framing, no sequence
    /// numbers, no software on the path. Unordered and best-effort:
    /// a full receive buffer *drops* (counted in
    /// [`Metrics::dropped`](crate::metrics::Metrics::dropped)). The
    /// cheapest mode for tiny header-dominated traffic (SNN spikes).
    Raw,
}

/// How strongly a mode orders messages between one (src, dst) pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgOrdering {
    /// Delivered in send order (the Bridge-FIFO reorder buffer).
    PerPairFifo,
    /// Messages are atomic but may arrive out of order (§2.4: the
    /// router does not guarantee ordering).
    Unordered,
}

/// Delivery guarantee class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reliability {
    /// Hardware-guaranteed by credit flow control; the fabric never
    /// drops a packet.
    Guaranteed,
    /// Delivery leaves the fabric (gateway + external 1 GbE + NFS
    /// host): still lossless in the model, but outside the credit
    /// domain.
    External,
    /// No delivery guarantee at the endpoint layer: a full receive
    /// buffer drops the message (counted, never stalled). The fabric's
    /// credit domain below is still lossless — the loss point is the
    /// receiving endpoint, exactly like a NIC ring overflow.
    BestEffort,
}

/// Coarse end-to-end latency class (Table 1 ordering: Bridge FIFO <
/// Postmaster ≪ Ethernet; NFS additionally crosses the physical port).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LatencyClass {
    /// ~1 µs/hop-class: pure FPGA logic (Bridge FIFO).
    Lowest,
    /// Low single-digit µs: no ARM software on the data path
    /// (Postmaster, NetTunnel).
    Low,
    /// Tens of µs: kernel stack + driver + DMA on both ends (Ethernet).
    High,
    /// Leaves the machine through the gateway (NFS).
    External,
}

/// What a [`CommMode`] guarantees — the paper's Table 1 distinctions as
/// a capability descriptor. Obtain via [`CommMode::caps`] (or
/// `Fabric::caps`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelCaps {
    pub latency: LatencyClass,
    pub ordering: MsgOrdering,
    pub reliability: Reliability,
    /// Largest payload one [`Message`] may carry (`None` = unbounded;
    /// the mode segments natively). Oversized sends panic.
    pub max_payload: Option<u32>,
    /// Whether a per-(src, dst) `connect` is required before sending
    /// (Bridge FIFO channels are "always implemented in pairs", §3.3).
    pub pair_setup: bool,
    /// Whether ARM software runs on the data path (the §3.1-vs-§3.2
    /// distinction that makes Ethernet the slow, compatible mode).
    pub cpu_on_path: bool,
    /// Receive-buffer bound: how many complete messages the endpoint's
    /// inbox holds before the mode's full-buffer semantics kick in
    /// (Ethernet drops and counts; Postmaster/Bridge FIFO withhold
    /// receive credit and charge the sender; NetTunnel rejects loudly).
    /// `None` = not applicable (`Nfs` endpoints never receive). Fed
    /// from [`SystemConfig::rx_capacity`].
    pub rx_capacity: Option<u32>,
}

impl CommMode {
    /// Capability descriptor of this mode under `cfg`.
    pub fn caps(&self, cfg: &SystemConfig) -> ChannelCaps {
        match self {
            CommMode::Ethernet { .. } => ChannelCaps {
                latency: LatencyClass::High,
                ordering: MsgOrdering::Unordered,
                reliability: Reliability::Guaranteed,
                max_payload: None,
                pair_setup: false,
                cpu_on_path: true,
                rx_capacity: Some(cfg.rx_capacity),
            },
            CommMode::Postmaster { .. } => ChannelCaps {
                latency: LatencyClass::Low,
                ordering: MsgOrdering::Unordered,
                reliability: Reliability::Guaranteed,
                max_payload: Some(cfg.link.mtu - HEADER_BYTES),
                pair_setup: false,
                cpu_on_path: false,
                rx_capacity: Some(cfg.rx_capacity),
            },
            CommMode::BridgeFifo { .. } => ChannelCaps {
                latency: LatencyClass::Lowest,
                ordering: MsgOrdering::PerPairFifo,
                reliability: Reliability::Guaranteed,
                max_payload: None,
                pair_setup: true,
                cpu_on_path: false,
                rx_capacity: Some(cfg.rx_capacity),
            },
            CommMode::Nfs => ChannelCaps {
                latency: LatencyClass::External,
                ordering: MsgOrdering::Unordered,
                reliability: Reliability::External,
                max_payload: None,
                pair_setup: false,
                cpu_on_path: true,
                rx_capacity: None,
            },
            CommMode::Tunnel { .. } => ChannelCaps {
                latency: LatencyClass::Low,
                ordering: MsgOrdering::Unordered,
                reliability: Reliability::Guaranteed,
                max_payload: Some(8),
                pair_setup: false,
                cpu_on_path: false,
                rx_capacity: Some(cfg.rx_capacity),
            },
            CommMode::Raw => ChannelCaps {
                latency: LatencyClass::Low,
                ordering: MsgOrdering::Unordered,
                reliability: Reliability::BestEffort,
                max_payload: Some(cfg.link.mtu - HEADER_BYTES),
                pair_setup: false,
                cpu_on_path: false,
                rx_capacity: Some(cfg.rx_capacity),
            },
        }
    }

    /// Stable mode name (metrics key, CLI, reports).
    pub fn name(&self) -> &'static str {
        match self {
            CommMode::Ethernet { .. } => "ethernet",
            CommMode::Postmaster { .. } => "postmaster",
            CommMode::BridgeFifo { .. } => "bridge_fifo",
            CommMode::Nfs => "nfs",
            CommMode::Tunnel { .. } => "net_tunnel",
            CommMode::Raw => "raw",
        }
    }
}

/// A node's attachment to one communication mode. Lightweight handle
/// (`Copy`): the fabric owns all endpoint state, keyed by (node, mode
/// lane), so handles can be reconstructed freely — callbacks receive
/// one per delivered message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Endpoint {
    pub node: NodeId,
    pub mode: CommMode,
}

/// A unified datagram. `data` is reference-counted: single-fragment
/// sends and Postmaster deliveries share bytes with the in-flight
/// packet instead of copying.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    /// Sender node. Filled in by the fabric on delivery; senders leave
    /// the placeholder [`Message::new`] sets.
    pub from: NodeId,
    pub data: Arc<Vec<u8>>,
}

impl Message {
    pub fn new(data: Vec<u8>) -> Self {
        Message { from: NodeId(u32::MAX), data: Arc::new(data) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Send-side message handle: `(src node << 32) | per-node message seq`.
/// Purely a driver-side identifier — it is not transported.
pub type MsgId = u64;

/// The one [`MsgId`] layout (shared by the serial sends and the sharded
/// engine's `Nfs` wrapper, so the engines can never drift apart).
pub(crate) fn comm_msg_id(node: NodeId, seq: u32) -> MsgId {
    ((node.0 as u64) << 32) | seq as u64
}

/// The one external-file naming scheme for `Nfs` endpoint messages
/// (shared across engines for the same reason).
pub(crate) fn comm_nfs_name(node: NodeId, seq: u32) -> String {
    format!("ep-{}-{seq}", node.0)
}

// ---------------------------------------------------------------------
// Lane keys: one registry slot per (node, mode class [+ queue]). The
// per-mode parameters (rx mode, width, mailbox address) are properties
// of the open endpoint, not of its identity — except the Postmaster
// queue id, which selects a distinct receive stream.
// ---------------------------------------------------------------------

const LANE_ETH: u16 = 0x000;
const LANE_PM: u16 = 0x100; // | queue
const LANE_FIFO: u16 = 0x200;
const LANE_NFS: u16 = 0x300;
const LANE_TUNNEL: u16 = 0x400;
const LANE_RAW: u16 = 0x500;

pub(crate) fn lane(mode: &CommMode) -> u16 {
    match mode {
        CommMode::Ethernet { .. } => LANE_ETH,
        CommMode::Postmaster { queue } => LANE_PM | *queue as u16,
        CommMode::BridgeFifo { .. } => LANE_FIFO,
        CommMode::Nfs => LANE_NFS,
        CommMode::Tunnel { .. } => LANE_TUNNEL,
        CommMode::Raw => LANE_RAW,
    }
}

/// Ethernet fragment tag: `(frag idx << 48) | (frag count << 32) | msg
/// seq`. Only frames that carry endpoint `data` are parsed this way —
/// legacy frames keep free-form tags.
fn eth_tag(seq: u32, idx: u16, count: u16) -> u64 {
    (seq as u64) | ((count as u64) << 32) | ((idx as u64) << 48)
}

fn eth_tag_decode(tag: u64) -> (u32, u16, u16) {
    (tag as u32, (tag >> 48) as u16, (tag >> 32) as u16)
}

/// All endpoint-layer dynamic state of one [`Network`] (one per shard
/// on the sharded engine; every piece is keyed by the node that owns
/// it, so state never crosses a shard boundary).
#[derive(Debug, Clone, Default)]
pub(crate) struct CommState {
    /// Open endpoints: (node, lane) → registered mode.
    open: FxHashMap<(u32, u16), CommMode>,
    /// Per-endpoint receive-capacity overrides
    /// ([`Network::open_with_rx_capacity`]): (node, lane) → bound that
    /// replaces [`SystemConfig::rx_capacity`] for this endpoint only.
    rx_cap_override: FxHashMap<(u32, u16), u32>,
    /// Complete inbound messages per endpoint, in delivery order.
    inbox: FxHashMap<(u32, u16), VecDeque<Message>>,
    /// Per-node outbound message sequence (all modes share it).
    msg_seq: FxHashMap<u32, u32>,
    /// Bridge-FIFO channel allocated per (src, dst) endpoint pair.
    fifo_chan: FxHashMap<(u32, u32), u8>,
    /// Endpoint-owned FIFO read ports: (dst, channel) → src.
    fifo_ep_rx: FxHashMap<(u32, u8), u32>,
    /// Word-stream parse buffer per endpoint FIFO read port.
    fifo_buf: FxHashMap<(u32, u8), VecDeque<u64>>,
    /// Ethernet reassembly: (dst, src, msg seq) → fragments by index.
    eth_rx: FxHashMap<(u32, u32, u32), std::collections::BTreeMap<u16, Arc<Vec<u8>>>>,
    /// Credit-withhold chain per backpressured endpoint: the virtual
    /// time at which the receiver will have drained one more inbox slot
    /// and re-issued credit. Each further record landing on the full
    /// inbox queues behind this instant ([`SystemConfig::rx_drain_ns`]
    /// apart); `recv` clears the chain. Keyed like `inbox`.
    stall_release: FxHashMap<(u32, u16), Time>,
}

impl Network {
    /// Open `node`'s endpoint on `mode` (idempotent: re-opening with
    /// the same mode returns the same endpoint; a different mode on the
    /// same lane panics). Performs the mode's node-level setup — the
    /// Postmaster queue init, the Ethernet receive-mode configuration.
    pub fn open(&mut self, node: NodeId, mode: CommMode) -> Endpoint {
        let key = (node.0, lane(&mode));
        if let Some(prev) = self.comm.open.get(&key) {
            assert_eq!(
                *prev, mode,
                "endpoint lane at {node} already open with a different mode"
            );
            return Endpoint { node, mode };
        }
        match mode {
            CommMode::Postmaster { queue } => {
                if self.postmaster.queue(node, queue).is_none() {
                    self.pm_open(node, queue);
                }
            }
            // NIC state is shard-local (domain-sized vector): configure
            // it only where it exists. The mode registry below still
            // replicates everywhere, which is all the send-side checks
            // on other shards need.
            CommMode::Ethernet { rx } => {
                if self.domain.owns_node(node) {
                    self.eth_set_mode(node, rx);
                }
            }
            CommMode::BridgeFifo { width_bits } => {
                assert_eq!(
                    width_bits, 64,
                    "endpoint byte framing needs the full 64-bit FIFO width \
                     (narrow widths are for raw word streams via fifo_send)"
                );
            }
            CommMode::Nfs | CommMode::Tunnel { .. } | CommMode::Raw => {}
        }
        self.comm.open.insert(key, mode);
        Endpoint { node, mode }
    }

    /// [`Network::open`], with a receive-buffer bound overriding
    /// [`SystemConfig::rx_capacity`] **for this endpoint only** — a
    /// hotspot sink can run a tiny inbox to study backpressure without
    /// shrinking every other endpoint's buffer. Idempotent like `open`;
    /// re-opening with a different override panics (the bound is part
    /// of the endpoint's identity, like its mode).
    pub fn open_with_rx_capacity(&mut self, node: NodeId, mode: CommMode, cap: u32) -> Endpoint {
        let ep = self.open(node, mode);
        let key = (node.0, lane(&mode));
        if let Some(prev) = self.comm.rx_cap_override.insert(key, cap) {
            assert_eq!(
                prev, cap,
                "endpoint at {node} already open with a different rx_capacity override"
            );
        }
        ep
    }

    /// The receive-buffer bound in force at `ep`: its per-endpoint
    /// override if one was set, the global [`SystemConfig::rx_capacity`]
    /// otherwise (`None` for modes that never receive).
    pub fn rx_capacity_of(&self, ep: &Endpoint) -> Option<u32> {
        let base = ep.mode.caps(&self.cfg).rx_capacity?;
        Some(
            self.comm
                .rx_cap_override
                .get(&(ep.node.0, lane(&ep.mode)))
                .copied()
                .unwrap_or(base),
        )
    }

    /// Per-pair setup where [`ChannelCaps::pair_setup`] requires it:
    /// for Bridge FIFO, allocate a channel id (the smallest one free at
    /// both the transmit and the receive node — deterministic, so every
    /// shard of a sharded run agrees) and connect the pair. No-op for
    /// the other modes and for already-connected pairs.
    pub fn connect(&mut self, ep: &Endpoint, dst: NodeId) {
        let CommMode::BridgeFifo { width_bits } = ep.mode else { return };
        let key = (ep.node.0, dst.0);
        if self.comm.fifo_chan.contains_key(&key) {
            return;
        }
        let c = (0u16..256)
            .map(|c| c as u8)
            .find(|&c| {
                self.fifos.tx_unit(ep.node, c).is_none() && self.fifos.rx_unit(dst, c).is_none()
            })
            .expect("no free Bridge-FIFO channel between endpoint pair");
        self.fifo_connect(ep.node, dst, c, width_bits);
        self.comm.fifo_chan.insert(key, c);
        self.comm.fifo_ep_rx.insert((dst.0, c), ep.node.0);
    }

    /// Send `msg` from `ep` to `dst` now. See [`Network::send_at`].
    pub fn send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        let now = self.now();
        self.send_at(now, ep, dst, msg)
    }

    /// Send `msg` from `ep` to `dst`, produced at absolute time
    /// `at ≥ now` (deferred production is how workloads overlap
    /// communication with modeled compute). Valid from driver context
    /// and from [`App`](crate::network::App) callbacks at `ep.node`:
    /// packet ids come from the per-node app id space, so serial and
    /// sharded runs assign identical ids. Panics if the payload exceeds
    /// the mode's [`ChannelCaps::max_payload`].
    pub fn send_at(&mut self, at: Time, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        let src = ep.node;
        let data = msg.data;
        let len = data.len();
        if let Some(max) = ep.mode.caps(&self.cfg).max_payload {
            assert!(
                len as u64 <= max as u64,
                "{} message of {len} B exceeds the mode's max payload of {max} B",
                ep.mode.name()
            );
        }
        let seq = self.comm_next_msg_seq(src);
        match ep.mode {
            CommMode::Postmaster { queue } => {
                // One record per message; pm_send_record accounts the
                // mode traffic (shared with the legacy shims).
                self.pm_send_at(at, src, dst, queue, data.as_ref().clone());
            }
            CommMode::Ethernet { .. } => {
                // Like the Postmaster queue-open check: a message to a
                // node whose endpoint is not open would vanish at the
                // capture layer — fail loudly instead.
                assert!(
                    self.comm.open.contains_key(&(dst.0, LANE_ETH)),
                    "ethernet endpoint not open at {dst}"
                );
                self.metrics.record_mode("ethernet", len as u64);
                let count = len.div_ceil(ETH_MTU as usize).max(1);
                assert!(count <= u16::MAX as usize, "ethernet message needs too many frames");
                for idx in 0..count {
                    let lo = idx * ETH_MTU as usize;
                    let hi = (lo + ETH_MTU as usize).min(len);
                    let frag = if count == 1 {
                        data.clone()
                    } else {
                        Arc::new(data[lo..hi].to_vec())
                    };
                    let tag = eth_tag(seq, idx as u16, count as u16);
                    let id = self.app_packet_id(src);
                    self.eth_frame_tx(at, id, src, dst, (hi - lo) as u32, tag, Some(frag));
                }
            }
            CommMode::BridgeFifo { .. } => {
                // The word-stream framing is only parsed on open
                // endpoints; a late open would start mid-stream and
                // desync the channel, so require it up front.
                assert!(
                    self.comm.open.contains_key(&(dst.0, LANE_FIFO)),
                    "bridge_fifo endpoint not open at {dst}"
                );
                let chan = *self.comm.fifo_chan.get(&(src.0, dst.0)).unwrap_or_else(|| {
                    panic!("Bridge-FIFO endpoint {src} -> {dst} not connected (call connect)")
                });
                // Payload bytes only — the framing header word and the
                // 8-byte word padding are transport overhead, so the
                // per-mode byte totals stay comparable across modes.
                self.metrics.record_mode("bridge_fifo", len as u64);
                let mut words = Vec::with_capacity(1 + len.div_ceil(8));
                words.push(((len as u64) << 32) | seq as u64);
                for chunk in data.chunks(8) {
                    let mut w = [0u8; 8];
                    w[..chunk.len()].copy_from_slice(chunk);
                    words.push(u64::from_le_bytes(w));
                }
                self.fifo_send_app(at, src, chan, &words);
            }
            CommMode::Nfs => {
                // External sink; the gateway path is a driver-context
                // recipe, so `at` is ignored (sends are immediate).
                let name = comm_nfs_name(src, seq);
                self.nfs_put(src, &name, len as u64);
            }
            CommMode::Tunnel { addr } => {
                self.metrics.record_mode("net_tunnel", 8);
                let mut v = [0u8; 8];
                v[..len].copy_from_slice(&data);
                let payload = Payload::RegAccess {
                    addr,
                    value: u64::from_le_bytes(v),
                    write: true,
                    reply: false,
                    req_id: 0,
                };
                let id = self.app_packet_id(src);
                let pkt =
                    Packet::new(id, src, dst, RouteKind::Directed, Proto::NetTunnel, payload, at);
                self.metrics.packets_injected += 1;
                let inject = self.cfg.link.inject_latency;
                self.inject_at(at + inject, pkt);
            }
            CommMode::Raw => {
                // Header-free: the message rides as exactly one
                // `Proto::Raw` packet — `HEADER_BYTES` of router header
                // and the payload, no framing word, no sequence field
                // (the per-node `seq` above only forms the driver-side
                // MsgId). The open check mirrors Ethernet: a datagram
                // to a node without the lane open would vanish at the
                // capture layer.
                assert!(
                    self.comm.open.contains_key(&(dst.0, LANE_RAW)),
                    "raw endpoint not open at {dst}"
                );
                self.metrics.record_mode("raw", len as u64);
                let id = self.app_packet_id(src);
                let pkt = Packet::new(
                    id,
                    src,
                    dst,
                    RouteKind::Directed,
                    Proto::Raw { tag: 0 },
                    Payload::Bytes(data),
                    at,
                );
                self.metrics.packets_injected += 1;
                let inject = self.cfg.link.inject_latency;
                self.inject_at(at + inject, pkt);
            }
        }
        comm_msg_id(src, seq)
    }

    /// Drain the endpoint's inbox of complete messages, in delivery
    /// order. Messages an [`App::on_message`] callback consumed
    /// (returned `true` for) never enter the inbox. (`Nfs` endpoints
    /// never receive; their payloads appear in the external world's
    /// file table.)
    ///
    /// [`App::on_message`]: crate::network::App::on_message
    pub fn recv(&mut self, ep: &Endpoint) -> Vec<Message> {
        let key = (ep.node.0, lane(&ep.mode));
        // Draining the inbox re-issues receive credit: any
        // credit-withhold chain on this endpoint ends here.
        self.comm.stall_release.remove(&key);
        match self.comm.inbox.get_mut(&key) {
            Some(q) => q.drain(..).collect(),
            None => Vec::new(),
        }
    }

    /// Registered mode of the open endpoint on `(node, lane)`, if any
    /// (the reliable transport reconstructs `Endpoint` handles from its
    /// flow keys).
    pub(crate) fn comm_open_mode(&self, node: NodeId, lane: u16) -> Option<CommMode> {
        self.comm.open.get(&(node.0, lane)).copied()
    }

    /// Advance `node`'s outbound message sequence (shared by all of the
    /// node's endpoints; per-node, so both engines agree).
    pub(crate) fn comm_next_msg_seq(&mut self, node: NodeId) -> u32 {
        let s = self.comm.msg_seq.entry(node.0).or_insert(0);
        let v = *s;
        *s += 1;
        v
    }

    // -----------------------------------------------------------------
    // Delivery capture: the per-channel receive paths call these to
    // assemble complete messages on open endpoints and hand them to
    // `App::on_message`; a message the callback does not consume
    // (returns `false`) is queued for `recv` afterwards via
    // [`Network::comm_inbox_push`]. Legacy traffic on lanes without an
    // open endpoint is untouched.
    // -----------------------------------------------------------------

    /// Queue a delivered message for [`Network::recv`] (the
    /// not-consumed path of [`App::on_message`]).
    ///
    /// The inbox is bounded at [`ChannelCaps::rx_capacity`]; at
    /// capacity the mode's full-buffer semantics apply:
    ///
    /// * **Ethernet** — the NIC has nowhere to DMA the frame: the
    ///   message is discarded and counted in [`Metrics::dropped`]
    ///   (best-effort at the endpoint layer, exactly like a real NIC
    ///   ring overflow; the fabric's credit domain below is unaffected).
    /// * **Postmaster / Bridge FIFO** — delivery stays guaranteed: the
    ///   record is accepted, but the receiver withholds its next credit
    ///   until one slot drains, and the sender is charged the wait in
    ///   [`Metrics::stalled_ns`] ([`SystemConfig::rx_drain_ns`] per
    ///   queued-over record, chained). Accounting-only: packet timing is
    ///   untouched, so the serial↔sharded byte-identity contract holds
    ///   by construction.
    /// * **NetTunnel / Nfs** — a mailbox register has exactly one
    ///   producer slot and no flow control: overflowing it is a
    ///   programming error, rejected loudly.
    ///
    /// [`App::on_message`]: crate::network::App::on_message
    /// [`Metrics::dropped`]: crate::metrics::Metrics::dropped
    /// [`Metrics::stalled_ns`]: crate::metrics::Metrics::stalled_ns
    pub(crate) fn comm_inbox_push(&mut self, ep: &Endpoint, msg: Message) {
        let key = (ep.node.0, lane(&ep.mode));
        let cap = self.rx_capacity_of(ep).unwrap_or(u32::MAX) as usize;
        let q = self.comm.inbox.entry(key).or_default();
        if q.len() >= cap {
            match ep.mode {
                // Ethernet: the NIC has nowhere to DMA the frame. Raw:
                // best-effort by contract ([`Reliability::BestEffort`]).
                // Both discard and count.
                CommMode::Ethernet { .. } | CommMode::Raw => {
                    self.metrics.dropped += 1;
                    return;
                }
                CommMode::Postmaster { .. } | CommMode::BridgeFifo { .. } => {
                    debug_assert!(
                        q.len() < cap.saturating_mul(4).max(cap + 64),
                        "runaway rx backlog on node {} lane {:#x}: {} queued messages \
                         against rx_capacity {} — nothing is draining this endpoint",
                        ep.node.0,
                        key.1,
                        q.len(),
                        cap
                    );
                    q.push_back(msg);
                    let now = self.sim.now();
                    let rel = self.comm.stall_release.entry(key).or_insert(0);
                    let release = (*rel).max(now) + self.cfg.rx_drain_ns;
                    self.metrics.stalled_ns += release - now;
                    *rel = release;
                    return;
                }
                CommMode::Nfs | CommMode::Tunnel { .. } => panic!(
                    "rx buffer overflow on node {} lane {:#x}: {} mailbox at rx_capacity {} \
                     with no flow control — drain with recv or raise rx_capacity",
                    ep.node.0,
                    key.1,
                    ep.mode.name(),
                    cap
                ),
            }
        }
        q.push_back(msg);
    }

    pub(crate) fn comm_capture_pm(
        &mut self,
        node: NodeId,
        queue: u8,
        rec: &PmRecord,
    ) -> Option<(Endpoint, Message)> {
        let key = (node.0, LANE_PM | queue as u16);
        let mode = *self.comm.open.get(&key)?;
        let msg = Message { from: rec.initiator, data: rec.data.clone() };
        Some((Endpoint { node, mode }, msg))
    }

    pub(crate) fn comm_capture_eth(
        &mut self,
        node: NodeId,
        frame: &EthFrame,
    ) -> Option<(Endpoint, Message)> {
        let data = frame.data.as_ref()?;
        let key = (node.0, LANE_ETH);
        let mode = *self.comm.open.get(&key)?;
        let (seq, idx, count) = eth_tag_decode(frame.tag);
        let complete = if count <= 1 {
            data.clone()
        } else {
            let rkey = (node.0, frame.src.0, seq);
            let frags = self.comm.eth_rx.entry(rkey).or_default();
            frags.insert(idx, data.clone());
            if frags.len() < count as usize {
                return None;
            }
            let frags = self.comm.eth_rx.remove(&rkey).expect("reassembly entry vanished");
            let mut all = Vec::new();
            for f in frags.values() {
                all.extend_from_slice(f);
            }
            Arc::new(all)
        };
        let msg = Message { from: frame.src, data: complete };
        Some((Endpoint { node, mode }, msg))
    }

    pub(crate) fn comm_capture_fifo(
        &mut self,
        node: NodeId,
        channel: u8,
        words: &[u64],
    ) -> Vec<(Endpoint, Message)> {
        let Some(&src) = self.comm.fifo_ep_rx.get(&(node.0, channel)) else {
            return Vec::new();
        };
        let key = (node.0, LANE_FIFO);
        let Some(&mode) = self.comm.open.get(&key) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        {
            let buf = self.comm.fifo_buf.entry((node.0, channel)).or_default();
            buf.extend(words.iter().copied());
            loop {
                let Some(&header) = buf.front() else { break };
                let len = (header >> 32) as usize;
                let need = 1 + len.div_ceil(8);
                if buf.len() < need {
                    break;
                }
                buf.pop_front();
                let mut bytes = Vec::with_capacity(len.div_ceil(8) * 8);
                for _ in 0..len.div_ceil(8) {
                    let w = buf.pop_front().expect("length checked above");
                    bytes.extend_from_slice(&w.to_le_bytes());
                }
                bytes.truncate(len);
                let msg = Message { from: NodeId(src), data: Arc::new(bytes) };
                out.push((Endpoint { node, mode }, msg));
            }
        }
        out
    }

    /// Capture a *directed* `Proto::Raw` packet on an open Raw
    /// endpoint. Multicast/broadcast raw traffic and non-byte payloads
    /// are not endpoint datagrams — they stay on the legacy
    /// [`App::on_raw`](crate::network::App::on_raw) path (the SNN's
    /// multicast spikes, workloads built directly on the router).
    pub(crate) fn comm_capture_raw(
        &mut self,
        node: NodeId,
        src: NodeId,
        payload: &Payload,
    ) -> Option<(Endpoint, Message)> {
        let key = (node.0, LANE_RAW);
        let mode = *self.comm.open.get(&key)?;
        let Payload::Bytes(data) = payload else { return None };
        let msg = Message { from: src, data: data.clone() };
        Some((Endpoint { node, mode }, msg))
    }

    pub(crate) fn comm_capture_tunnel(
        &mut self,
        node: NodeId,
        src: NodeId,
        addr: u64,
        value: u64,
    ) -> Option<(Endpoint, Message)> {
        let key = (node.0, LANE_TUNNEL);
        let mode = *self.comm.open.get(&key)?;
        let CommMode::Tunnel { addr: mailbox } = mode else { return None };
        if addr != mailbox {
            return None;
        }
        // The original payload length is not transported; messages come
        // back as the full 8-byte register word, zero-padded.
        let msg = Message { from: src, data: Arc::new(value.to_le_bytes().to_vec()) };
        Some((Endpoint { node, mode }, msg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{App, NullApp};
    use crate::topology::Coord;

    fn card() -> Network {
        Network::card()
    }

    #[test]
    fn caps_encode_the_table1_distinctions() {
        let cfg = SystemConfig::card();
        let fifo = CommMode::BridgeFifo { width_bits: 64 }.caps(&cfg);
        let pm = CommMode::Postmaster { queue: 0 }.caps(&cfg);
        let eth = CommMode::Ethernet { rx: RxMode::Interrupt }.caps(&cfg);
        assert!(fifo.latency < pm.latency && pm.latency < eth.latency);
        assert_eq!(fifo.ordering, MsgOrdering::PerPairFifo);
        assert_eq!(pm.ordering, MsgOrdering::Unordered);
        assert!(fifo.pair_setup && !pm.pair_setup && !eth.pair_setup);
        assert!(eth.cpu_on_path && !pm.cpu_on_path && !fifo.cpu_on_path);
        assert_eq!(pm.max_payload, Some(cfg.link.mtu - HEADER_BYTES));
        assert_eq!(CommMode::Tunnel { addr: 0 }.caps(&cfg).max_payload, Some(8));
    }

    #[test]
    fn postmaster_endpoint_roundtrip() {
        let mut net = card();
        let (a, b) = (NodeId(0), NodeId(13));
        let mode = CommMode::Postmaster { queue: 3 };
        let ea = net.open(a, mode);
        let eb = net.open(b, mode);
        net.send(&ea, b, Message::new(vec![1, 2, 3]));
        net.run_to_quiescence(&mut NullApp);
        let got = net.recv(&eb);
        assert_eq!(got.len(), 1);
        assert_eq!(*got[0].data, vec![1, 2, 3]);
        assert_eq!(got[0].from, a);
        assert!(net.recv(&eb).is_empty(), "recv drains");
        let t = net.metrics.mode_traffic["postmaster"];
        assert_eq!((t.messages, t.bytes), (1, 3));
    }

    #[test]
    fn ethernet_endpoint_reassembles_multi_frame_messages() {
        let mut net = card();
        let a = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let b = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        let mode = CommMode::Ethernet { rx: RxMode::Interrupt };
        let ea = net.open(a, mode);
        let eb = net.open(b, mode);
        let payload: Vec<u8> = (0..4000u32).map(|i| i as u8).collect();
        net.send(&ea, b, Message::new(payload.clone()));
        net.run_to_quiescence(&mut NullApp);
        let got = net.recv(&eb);
        assert_eq!(got.len(), 1, "3 frames reassemble into one message");
        assert_eq!(*got[0].data, payload);
        assert_eq!(got[0].from, a);
        // The frames themselves still landed in the legacy inbox.
        assert_eq!(net.eth_read(b).len(), 3);
    }

    #[test]
    fn fifo_endpoint_frames_byte_messages_in_order() {
        let mut net = card();
        let (a, b) = (NodeId(0), NodeId(26));
        let mode = CommMode::BridgeFifo { width_bits: 64 };
        let ea = net.open(a, mode);
        let eb = net.open(b, mode);
        net.connect(&ea, b);
        let msgs: Vec<Vec<u8>> = (0..20u8).map(|i| vec![i; 1 + i as usize * 7]).collect();
        for m in &msgs {
            net.send(&ea, b, Message::new(m.clone()));
        }
        net.run_to_quiescence(&mut NullApp);
        let got = net.recv(&eb);
        assert_eq!(got.len(), msgs.len());
        for (g, m) in got.iter().zip(&msgs) {
            assert_eq!(*g.data, *m, "per-pair FIFO order must hold");
            assert_eq!(g.from, a);
        }
    }

    #[test]
    fn tunnel_endpoint_delivers_register_writes() {
        let mut net = card();
        let (a, b) = (NodeId(2), NodeId(19));
        let mode = CommMode::Tunnel { addr: crate::node::regs::SCRATCH0 };
        let ea = net.open(a, mode);
        let eb = net.open(b, mode);
        net.send(&ea, b, Message::new(vec![0xAB, 0xCD]));
        net.run_to_quiescence(&mut NullApp);
        let got = net.recv(&eb);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].data[..2], [0xAB, 0xCD]);
        assert_eq!(got[0].from, a);
        // The register itself holds the value too.
        let t = net.now();
        assert_eq!(
            net.nodes[b.0 as usize].read_addr(crate::node::regs::SCRATCH0, t),
            0xCDAB
        );
    }

    #[test]
    fn nfs_endpoint_lands_on_external_storage() {
        let mut net = card();
        let a = NodeId(14);
        let gw = net.gateway();
        let ea = net.open(a, CommMode::Nfs);
        net.send(&ea, gw, Message::new(vec![0; 5000]));
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.eth.external.files.get("ep-14-0"), Some(&5000));
        assert!(net.recv(&ea).is_empty());
    }

    #[test]
    fn on_message_fires_per_complete_message() {
        struct Count {
            seen: Vec<(u32, usize)>,
            consume: bool,
        }
        impl App for Count {
            fn on_message(&mut self, _net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
                self.seen.push((ep.node.0, msg.data.len()));
                self.consume
            }
        }
        for consume in [false, true] {
            let mut net = card();
            let (a, b) = (NodeId(0), NodeId(9));
            let mode = CommMode::Postmaster { queue: 0 };
            let ea = net.open(a, mode);
            let eb = net.open(b, mode);
            net.send(&ea, b, Message::new(vec![7; 48]));
            net.send(&ea, b, Message::new(vec![8; 12]));
            let mut app = Count { seen: Vec::new(), consume };
            net.run_to_quiescence(&mut app);
            assert_eq!(app.seen.len(), 2);
            assert!(app.seen.iter().all(|&(n, _)| n == b.0));
            assert_eq!(app.seen.iter().map(|&(_, l)| l).sum::<usize>(), 60);
            // The consumed flag decides whether recv still sees them.
            let left = net.recv(&eb);
            assert_eq!(left.len(), if consume { 0 } else { 2 });
        }
    }

    #[test]
    fn ethernet_full_inbox_drops_and_counts() {
        let mut cfg = SystemConfig::card();
        cfg.rx_capacity = 2;
        let mut net = Network::new(cfg);
        let (a, b) = (NodeId(0), NodeId(13));
        let mode = CommMode::Ethernet { rx: RxMode::Interrupt };
        let ea = net.open(a, mode);
        let eb = net.open(b, mode);
        for i in 0..5u8 {
            net.send(&ea, b, Message::new(vec![i; 64]));
        }
        net.run_to_quiescence(&mut NullApp);
        let got = net.recv(&eb);
        assert_eq!(got.len(), 2, "inbox bounded at rx_capacity");
        assert_eq!(net.metrics.dropped, 3, "overflow frames are counted, not lost silently");
        assert_eq!(net.metrics.stalled_ns, 0, "best-effort mode never stalls the sender");
    }

    #[test]
    fn postmaster_full_inbox_stalls_sender_but_delivers() {
        let mut cfg = SystemConfig::card();
        cfg.rx_capacity = 1;
        let drain = cfg.rx_drain_ns;
        let mut net = Network::new(cfg);
        let (a, b) = (NodeId(0), NodeId(9));
        let mode = CommMode::Postmaster { queue: 0 };
        let ea = net.open(a, mode);
        let eb = net.open(b, mode);
        for i in 0..4u8 {
            net.send(&ea, b, Message::new(vec![i; 16]));
        }
        net.run_to_quiescence(&mut NullApp);
        let got = net.recv(&eb);
        assert_eq!(got.len(), 4, "guaranteed mode never drops");
        assert!(
            net.metrics.stalled_ns >= 3 * drain,
            "3 over-capacity records chain at least one drain interval each \
             (stalled_ns={})",
            net.metrics.stalled_ns
        );
        assert_eq!(net.metrics.dropped, 0);
        // Credit was re-issued by recv: fresh traffic stalls afresh, it
        // does not extend the old chain.
        net.send(&ea, b, Message::new(vec![9; 16]));
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.recv(&eb).len(), 1);
    }

    #[test]
    #[should_panic(expected = "rx buffer overflow")]
    fn tunnel_full_inbox_rejects_loudly() {
        let mut cfg = SystemConfig::card();
        cfg.rx_capacity = 1;
        let mut net = Network::new(cfg);
        let (a, b) = (NodeId(2), NodeId(19));
        let mode = CommMode::Tunnel { addr: crate::node::regs::SCRATCH0 };
        let ea = net.open(a, mode);
        net.open(b, mode);
        net.send(&ea, b, Message::new(vec![1]));
        net.send(&ea, b, Message::new(vec![2]));
        net.run_to_quiescence(&mut NullApp);
    }

    #[test]
    #[should_panic(expected = "exceeds the mode's max payload")]
    fn oversized_postmaster_message_rejected() {
        let mut net = card();
        let mode = CommMode::Postmaster { queue: 0 };
        let ea = net.open(NodeId(0), mode);
        net.open(NodeId(1), mode);
        net.send(&ea, NodeId(1), Message::new(vec![0; 4096]));
    }

    #[test]
    fn per_endpoint_rx_capacity_override_is_local() {
        // Global capacity 2; one sink overridden down to 1. Only the
        // overridden endpoint's overflow semantics change.
        let mut cfg = SystemConfig::card();
        cfg.rx_capacity = 2;
        let mut net = Network::new(cfg);
        let (a, b, c) = (NodeId(0), NodeId(13), NodeId(26));
        let mode = CommMode::Ethernet { rx: RxMode::Interrupt };
        let ea = net.open(a, mode);
        let eb = net.open_with_rx_capacity(b, mode, 1);
        let ec = net.open(c, mode);
        assert_eq!(net.rx_capacity_of(&eb), Some(1));
        assert_eq!(net.rx_capacity_of(&ec), Some(2));
        for i in 0..3u8 {
            net.send(&ea, b, Message::new(vec![i; 16]));
            net.send(&ea, c, Message::new(vec![i; 16]));
        }
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.recv(&eb).len(), 1, "override bounds the sink at 1");
        assert_eq!(net.recv(&ec).len(), 2, "everyone else keeps the global bound");
        assert_eq!(net.metrics.dropped, 3, "2 dropped at b + 1 dropped at c");
    }

    #[test]
    fn open_is_idempotent_for_the_same_mode() {
        let mut net = card();
        let mode = CommMode::Postmaster { queue: 0 };
        let e1 = net.open(NodeId(5), mode);
        let e2 = net.open(NodeId(5), mode);
        assert_eq!(e1, e2);
    }

    #[test]
    fn raw_caps_are_header_free_best_effort() {
        let cfg = SystemConfig::card();
        let raw = CommMode::Raw.caps(&cfg);
        assert_eq!(raw.latency, LatencyClass::Low);
        assert_eq!(raw.ordering, MsgOrdering::Unordered);
        assert_eq!(raw.reliability, Reliability::BestEffort);
        assert_eq!(raw.max_payload, Some(cfg.link.mtu - HEADER_BYTES));
        assert!(!raw.pair_setup && !raw.cpu_on_path);
        assert_eq!(raw.rx_capacity, Some(cfg.rx_capacity));
        assert_eq!(CommMode::Raw.name(), "raw");
    }

    #[test]
    fn raw_endpoint_roundtrip_with_header_only_overhead() {
        // One message = one Proto::Raw packet: HEADER_BYTES of router
        // header plus the payload, nothing else — no framing word, no
        // fragment tags. The on_raw hook still sees the packet, so the
        // wire size is directly observable.
        struct Wire {
            sizes: Vec<u32>,
        }
        impl App for Wire {
            fn on_raw(
                &mut self,
                _net: &mut Network,
                _node: NodeId,
                packet: &crate::router::Packet,
            ) {
                self.sizes.push(packet.wire_bytes);
            }
        }
        let mut net = card();
        let (a, b) = (NodeId(0), NodeId(13));
        let ea = net.open(a, CommMode::Raw);
        let eb = net.open(b, CommMode::Raw);
        net.send(&ea, b, Message::new(vec![0xEE; 24]));
        let mut app = Wire { sizes: Vec::new() };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.sizes, vec![HEADER_BYTES + 24]);
        let got = net.recv(&eb);
        assert_eq!(got.len(), 1);
        assert_eq!(*got[0].data, vec![0xEE; 24]);
        assert_eq!(got[0].from, a);
        let t = net.metrics.mode_traffic["raw"];
        assert_eq!((t.messages, t.bytes), (1, 24));
    }

    #[test]
    fn raw_full_inbox_drops_and_counts() {
        let mut cfg = SystemConfig::card();
        cfg.rx_capacity = 2;
        let mut net = Network::new(cfg);
        let (a, b) = (NodeId(0), NodeId(13));
        let ea = net.open(a, CommMode::Raw);
        let eb = net.open(b, CommMode::Raw);
        for i in 0..5u8 {
            net.send(&ea, b, Message::new(vec![i; 32]));
        }
        net.run_to_quiescence(&mut NullApp);
        assert_eq!(net.recv(&eb).len(), 2, "inbox bounded at rx_capacity");
        assert_eq!(net.metrics.dropped, 3, "overflow datagrams counted, not lost silently");
        assert_eq!(net.metrics.stalled_ns, 0, "best-effort mode never stalls the sender");
    }
}
