//! Reliable delivery over lossy channels: ack/retransmit endpoints
//! with failure detection.
//!
//! The paper's Table 1 marks Internal Ethernet *unreliable* — the INC
//! software stack is expected to layer recovery on top of the raw
//! channels. This module is that layer, written once against the
//! [`Endpoint`] API so any unordered [`CommMode`] (Ethernet,
//! Postmaster) can carry it:
//!
//! * **Sequencing** — every data message on a (node, lane, peer) flow
//!   carries a per-flow sequence number; the receiver delivers in
//!   order, buffers out-of-order arrivals (bounded by the endpoint's
//!   receive capacity), and suppresses duplicates
//!   ([`Metrics::duplicates_dropped`]).
//! * **Cumulative + selective acks** — each data or heartbeat frame
//!   is answered with the receiver's next-expected sequence plus a
//!   64-sequence SACK bit window over its out-of-order buffer
//!   ([`Metrics::acks`]); everything below the cumulative point
//!   leaves the sender's retransmit queue, and SACKed sequences are
//!   pinned as received.
//! * **Selective-repeat retransmit** — a per-flow timer
//!   ([`ReliableParams::rto_ns`], exponential backoff to
//!   [`ReliableParams::rto_max_ns`]) re-sends the unacked window
//!   *minus* SACKed sequences ([`Metrics::retransmits`]) — under
//!   random loss only the gaps go back on the wire, not everything
//!   after them ([`ReliableParams::sack`] false restores go-back-all
//!   as a control). After [`ReliableParams::max_retries`] consecutive
//!   timeouts the peer is declared down instead of retrying forever.
//! * **Heartbeat liveness** — [`Network::reliable_watch`] monitors a
//!   peer with periodic heartbeats even when no data flows; silence
//!   past [`ReliableParams::liveness_ns`] declares the peer down.
//! * **`PeerDown`** — surfaces as [`App::on_peer_down`]
//!   ([`Metrics::peers_declared_down`]) exactly once per (endpoint,
//!   peer); the app re-places undelivered work with
//!   [`Network::reliable_take_unacked`] (learners move records to a
//!   live sink, the ring all-reduce shrinks the ring, MCTS re-issues
//!   rollouts).
//!
//! # Determinism
//!
//! Everything is scheduled through the fabric's keyed event queue:
//! retransmit and heartbeat timers ride [`Network::timer_at`] with a
//! reserved tag space ([`RELIABLE_TIMER_MARK`], intercepted before
//! [`App::on_timer`]), protocol sends draw per-node app packet ids,
//! and every piece of flow state is keyed by the node that owns it —
//! so the serial and sharded engines run the protocol byte-identically
//! (`tests/sharded_differential.rs`). Timers are never cancelled;
//! an armed-flag per flow makes stale firings no-ops, so the schedule
//! is a pure function of the flow's local history.
//!
//! # Wire framing
//!
//! Prepended to the underlying mode's payload; lanes carrying frames
//! the transport does not recognize pass them through to the app
//! untouched, so reliable and raw traffic coexist on one lane.
//!
//! | frame | bytes |
//! |---|---|
//! | data | `[0xD1][seq: u64 LE][payload…]` |
//! | ack | `[0xA1][next expected seq: u64 LE][sack bits: u64 LE]` |
//! | heartbeat | `[0xB1]` |
//!
//! SACK bit `i` asserts sequence `cum + 1 + i` sits in the receiver's
//! reorder buffer. The legacy 9-byte ack (no bit field) still parses —
//! it simply carries an empty window.
//!
//! [`Metrics::acks`]: crate::metrics::Metrics::acks
//! [`Metrics::retransmits`]: crate::metrics::Metrics::retransmits
//! [`Metrics::duplicates_dropped`]: crate::metrics::Metrics::duplicates_dropped
//! [`Metrics::peers_declared_down`]: crate::metrics::Metrics::peers_declared_down
//! [`App::on_timer`]: crate::network::App::on_timer
//! [`App::on_peer_down`]: crate::network::App::on_peer_down

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::channels::endpoint::{lane, CommMode, Endpoint, Message, MsgId, MsgOrdering};
use crate::network::{App, Network};
use crate::sim::Time;
use crate::topology::NodeId;
use crate::util::FxHashMap;

/// Reliable timers carry this mark in their tag; the fabric's `Timer`
/// handler routes marked tags to the transport instead of
/// [`App::on_timer`](crate::network::App::on_timer). App tags must stay
/// below it (they always have: workload tags are small integers).
pub const RELIABLE_TIMER_MARK: u64 = 1 << 63;

const KIND_RETX: u64 = 1;
const KIND_HEARTBEAT: u64 = 2;

/// Tag layout: `MARK | kind << 56 | lane << 40 | peer`. The event key
/// truncates tags to 24 bits (see `network::key_timer`) — colliding
/// same-instant timers at one node fall back to that node's schedule
/// order, which both engines share.
fn timer_tag(kind: u64, lane: u16, peer: u32) -> u64 {
    RELIABLE_TIMER_MARK | (kind << 56) | ((lane as u64) << 40) | peer as u64
}

fn timer_tag_decode(tag: u64) -> (u64, u16, u32) {
    ((tag >> 56) & 0x7F, (tag >> 40) as u16, tag as u32 & 0xFF_FFFF_FF)
}

const FRAME_DATA: u8 = 0xD1;
const FRAME_ACK: u8 = 0xA1;
const FRAME_HEARTBEAT: u8 = 0xB1;

/// Bytes the data-frame header adds on top of the app payload (callers
/// sizing messages against [`crate::channels::ChannelCaps::max_payload`]
/// subtract this).
pub const RELIABLE_HEADER_BYTES: u32 = 9;

/// Retransmit / liveness tuning of one reliable endpoint. All values
/// are virtual-time constants, so a parameter set is part of the
/// deterministic run definition — record it with the seed
/// (EXPERIMENTS.md §Reliable transport).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReliableParams {
    /// Initial retransmit timeout per flow, ns. Must exceed the mode's
    /// loaded round-trip time or every message is sent twice.
    pub rto_ns: Time,
    /// Exponential-backoff cap: the timeout doubles per consecutive
    /// timeout up to this, ns.
    pub rto_max_ns: Time,
    /// Consecutive timeouts on one flow before the peer is declared
    /// down. The retry budget bounds how long a flow can stay on fire:
    /// detection takes at most `Σ min(rto·2^i, rto_max)` over the
    /// budget.
    pub max_retries: u32,
    /// Heartbeat period for watched peers
    /// ([`Network::reliable_watch`]), ns.
    pub heartbeat_ns: Time,
    /// Silence threshold on a watched peer before it is declared down,
    /// ns. Must exceed the worst-case heartbeat round trip under the
    /// congestion being survived (and, for partition scenarios, the
    /// partition span — unless declaring a temporarily unreachable
    /// peer down is the intent).
    pub liveness_ns: Time,
    /// Honor SACK windows on retransmit (selective repeat). Off, the
    /// sender ignores the bit field and re-sends the whole unacked
    /// window (go-back-all) — kept as the experimental control for
    /// loss-recovery cost comparisons (`tests/properties.rs`).
    pub sack: bool,
}

impl Default for ReliableParams {
    fn default() -> Self {
        ReliableParams {
            rto_ns: 150_000,
            rto_max_ns: 1_200_000,
            max_retries: 10,
            heartbeat_ns: 100_000,
            liveness_ns: 600_000,
            sack: true,
        }
    }
}

/// Sender side of one (node, lane, peer) flow.
#[derive(Debug, Clone, Default)]
struct FlowTx {
    next_seq: u64,
    /// Sent, unacknowledged payloads by sequence (app payload, without
    /// the frame header — retransmits re-frame, take-unacked returns
    /// them as messages).
    unacked: BTreeMap<u64, Arc<Vec<u8>>>,
    /// Current timeout (backs off while timeouts are consecutive).
    rto: Time,
    timeouts: u32,
    armed: bool,
    /// Merged SACK knowledge: bit `i` of `sack_bits` asserts the
    /// receiver holds `sack_cum + 1 + i`. A SACK statement is forever
    /// true (reorder-buffer entries only leave by delivery), so stale
    /// and reordered acks fold in rather than overwrite.
    sack_cum: u64,
    sack_bits: u64,
}

/// Receiver side of one (node, lane, peer) flow.
#[derive(Debug, Clone, Default)]
struct FlowRx {
    /// Everything below this sequence has been delivered in order.
    next_expected: u64,
    /// Out-of-order buffer, bounded by the endpoint's receive capacity.
    ooo: BTreeMap<u64, Message>,
}

/// Liveness bookkeeping for one (node, lane, peer).
#[derive(Debug, Clone, Default)]
struct PeerMeta {
    last_heard: Time,
    down: bool,
    /// Heartbeat monitor re-arms while `now < watch_until`.
    watch_until: Time,
    hb_armed: bool,
}

/// All reliable-transport state of one [`Network`] (one per shard on
/// the sharded engine; every map is keyed by the owning node, so state
/// never crosses a shard boundary — except the registry, which is
/// replicated like the endpoint-mode registry).
#[derive(Debug, Clone, Default)]
pub(crate) struct ReliableState {
    /// Registered reliable endpoints: (node, lane) → params.
    /// Replicated on every shard (send-side asserts consult it).
    reg: FxHashMap<(u32, u16), ReliableParams>,
    tx: FxHashMap<(u32, u16, u32), FlowTx>,
    rx: FxHashMap<(u32, u16, u32), FlowRx>,
    peers: FxHashMap<(u32, u16, u32), PeerMeta>,
}

fn frame_data(seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut v = Vec::with_capacity(9 + payload.len());
    v.push(FRAME_DATA);
    v.extend_from_slice(&seq.to_le_bytes());
    v.extend_from_slice(payload);
    v
}

fn frame_ack(cum: u64, sack_bits: u64) -> Vec<u8> {
    let mut v = Vec::with_capacity(17);
    v.push(FRAME_ACK);
    v.extend_from_slice(&cum.to_le_bytes());
    v.extend_from_slice(&sack_bits.to_le_bytes());
    v
}

/// SACK window over the reorder buffer: bit `i` ⇒ `cum + 1 + i` held.
fn sack_window(ooo: &BTreeMap<u64, Message>, cum: u64) -> u64 {
    let mut bits = 0u64;
    for &seq in ooo.range(cum.saturating_add(1)..cum.saturating_add(65)).map(|(s, _)| s) {
        bits |= 1 << (seq - cum - 1);
    }
    bits
}

fn read_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().expect("length checked by caller"))
}

impl Network {
    /// Open `node`'s endpoint on `mode` and register it with the
    /// reliable transport under `params`. Both flow directions need the
    /// registration: senders frame and retransmit, receivers reorder,
    /// ack and deduplicate — so **every** party of a reliable
    /// conversation opens with this (a data frame landing on an
    /// unregistered endpoint would reach the app with its header
    /// bytes). Idempotent like [`Network::open`]; re-registering with
    /// different params panics.
    ///
    /// Only modes with unordered delivery and room for the frame header
    /// qualify: Bridge FIFO is already per-pair ordered and lossless
    /// end-to-end, NFS endpoints never receive, and NetTunnel's 8-byte
    /// mailbox cannot carry a header.
    pub fn reliable_open(
        &mut self,
        node: NodeId,
        mode: CommMode,
        params: ReliableParams,
    ) -> Endpoint {
        let caps = mode.caps(&self.cfg);
        assert!(
            caps.ordering == MsgOrdering::Unordered
                && caps.rx_capacity.is_some()
                && caps.max_payload.map_or(true, |m| m > RELIABLE_HEADER_BYTES),
            "{} cannot carry the reliable transport (needs unordered delivery, \
             a receive path, and room for the {RELIABLE_HEADER_BYTES}-byte header)",
            mode.name()
        );
        let ep = self.open(node, mode);
        let key = (node.0, lane(&mode));
        if let Some(prev) = self.rel.reg.insert(key, params) {
            assert_eq!(
                prev, params,
                "reliable endpoint at {node} already registered with different params"
            );
        }
        ep
    }

    /// Whether `(ep.node, ep-lane)` is registered with the transport.
    pub fn is_reliable(&self, ep: &Endpoint) -> bool {
        self.rel.reg.contains_key(&(ep.node.0, lane(&ep.mode)))
    }

    /// Whether the transport at `ep` has declared `peer` down.
    pub fn reliable_is_down(&self, ep: &Endpoint, peer: NodeId) -> bool {
        self.rel
            .peers
            .get(&(ep.node.0, lane(&ep.mode), peer.0))
            .is_some_and(|m| m.down)
    }

    /// Send `msg` from `ep` to `dst` reliably, now.
    pub fn reliable_send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        let now = self.now();
        self.reliable_send_at(now, ep, dst, msg)
    }

    /// Send `msg` from `ep` to `dst` reliably, produced at `at ≥ now`:
    /// the payload is framed with the flow's next sequence number,
    /// queued for retransmit until acknowledged, and the flow's
    /// retransmit timer is armed. Panics if either end is not
    /// registered ([`Network::reliable_open`]) or the peer is already
    /// declared down (re-place via
    /// [`Network::reliable_take_unacked`] instead).
    pub fn reliable_send_at(
        &mut self,
        at: Time,
        ep: &Endpoint,
        dst: NodeId,
        msg: Message,
    ) -> MsgId {
        let l = lane(&ep.mode);
        let params = *self
            .rel
            .reg
            .get(&(ep.node.0, l))
            .unwrap_or_else(|| panic!("reliable endpoint not open at {}", ep.node));
        assert!(
            self.rel.reg.contains_key(&(dst.0, l)),
            "reliable peer endpoint not open at {dst}"
        );
        assert!(
            !self.reliable_is_down(ep, dst),
            "reliable send from {} to {dst}, which is declared down",
            ep.node
        );
        let flow = self.rel.tx.entry((ep.node.0, l, dst.0)).or_default();
        let seq = flow.next_seq;
        flow.next_seq += 1;
        flow.unacked.insert(seq, msg.data.clone());
        let arm = if flow.armed {
            None
        } else {
            flow.armed = true;
            if flow.rto == 0 {
                flow.rto = params.rto_ns;
            }
            Some(at + flow.rto)
        };
        if let Some(deadline) = arm {
            self.timer_at(deadline, ep.node, timer_tag(KIND_RETX, l, dst.0));
        }
        self.send_at(at, ep, dst, Message::new(frame_data(seq, &msg.data)))
    }

    /// Monitor `peer`'s liveness from `ep` with periodic heartbeats
    /// until virtual time `until` (bounding the monitor keeps runs
    /// quiescing — pass the workload's horizon plus slack). Heartbeats
    /// elicit acks, so a live peer refreshes the monitor even with no
    /// data flowing; silence past [`ReliableParams::liveness_ns`]
    /// declares the peer down. Idempotent; re-watching extends the
    /// window.
    pub fn reliable_watch(&mut self, ep: &Endpoint, peer: NodeId, until: Time) {
        let l = lane(&ep.mode);
        let params = *self
            .rel
            .reg
            .get(&(ep.node.0, l))
            .unwrap_or_else(|| panic!("reliable endpoint not open at {}", ep.node));
        let now = self.now();
        let meta = self.rel.peers.entry((ep.node.0, l, peer.0)).or_default();
        meta.last_heard = meta.last_heard.max(now);
        meta.watch_until = meta.watch_until.max(until);
        if meta.down || meta.hb_armed {
            return;
        }
        meta.hb_armed = true;
        self.timer_at(
            now + params.heartbeat_ns,
            ep.node,
            timer_tag(KIND_HEARTBEAT, l, peer.0),
        );
    }

    /// Drain and return the payloads sent from `ep` to `peer` that were
    /// never acknowledged, in send order — the re-placement hook for
    /// [`App::on_peer_down`](crate::network::App::on_peer_down)
    /// (learners re-send them to a live sink; under the two-phase chaos
    /// node death, unacked ⟺ undelivered, so re-placement is exact).
    pub fn reliable_take_unacked(&mut self, ep: &Endpoint, peer: NodeId) -> Vec<Message> {
        match self.rel.tx.get_mut(&(ep.node.0, lane(&ep.mode), peer.0)) {
            Some(flow) => std::mem::take(&mut flow.unacked)
                .into_values()
                .map(|data| Message { from: NodeId(u32::MAX), data })
                .collect(),
            None => Vec::new(),
        }
    }

    /// A reliable timer fired at `node` (routed here by the fabric's
    /// `Timer` handler).
    pub(crate) fn reliable_timer(&mut self, node: NodeId, tag: u64, app: &mut dyn App) {
        let (kind, l, peer) = timer_tag_decode(tag);
        match kind {
            KIND_RETX => self.retx_timer(node, l, peer, app),
            KIND_HEARTBEAT => self.heartbeat_timer(node, l, peer, app),
            _ => panic!("unknown reliable timer kind {kind}"),
        }
    }

    fn retx_timer(&mut self, node: NodeId, l: u16, peer: u32, app: &mut dyn App) {
        let params = self.rel.reg[&(node.0, l)];
        let Some(flow) = self.rel.tx.get_mut(&(node.0, l, peer)) else { return };
        flow.armed = false;
        if flow.unacked.is_empty() {
            // Everything acked since this timer was armed: the flow
            // goes idle; the next send arms a fresh timer.
            flow.rto = params.rto_ns;
            flow.timeouts = 0;
            return;
        }
        if self.rel.peers.get(&(node.0, l, peer)).is_some_and(|m| m.down) {
            return;
        }
        flow.timeouts += 1;
        if flow.timeouts > params.max_retries {
            // Retry budget exhausted: stop retrying, surface PeerDown.
            // The unacked queue stays for reliable_take_unacked.
            self.declare_down(node, l, peer, app);
            return;
        }
        // Selective-repeat retransmit of the unacked window, oldest
        // first, skipping sequences the receiver has SACKed (the
        // receiver's duplicate suppression absorbs whatever the loss
        // didn't actually take), then back off and re-arm. If the
        // whole window is SACKed the cumulative ack itself was lost:
        // resend the oldest frame alone to elicit a fresh one.
        let (sack_cum, sack_bits) = (flow.sack_cum, flow.sack_bits);
        let sacked = |seq: u64| {
            params.sack
                && seq > sack_cum
                && seq - sack_cum - 1 < 64
                && sack_bits >> (seq - sack_cum - 1) & 1 == 1
        };
        let mut resend: Vec<(u64, Arc<Vec<u8>>)> = flow
            .unacked
            .iter()
            .filter(|(s, _)| !sacked(**s))
            .map(|(s, d)| (*s, d.clone()))
            .collect();
        if resend.is_empty() {
            let (s, d) = flow.unacked.iter().next().expect("unacked checked non-empty");
            resend.push((*s, d.clone()));
        }
        flow.rto = (flow.rto.saturating_mul(2)).min(params.rto_max_ns);
        flow.armed = true;
        let rto = flow.rto;
        let ep = self.reliable_ep(node, l);
        let now = self.now();
        for (seq, data) in resend {
            self.metrics.retransmits += 1;
            self.send_at(now, &ep, NodeId(peer), Message::new(frame_data(seq, &data)));
        }
        self.timer_at(now + rto, node, timer_tag(KIND_RETX, l, peer));
    }

    fn heartbeat_timer(&mut self, node: NodeId, l: u16, peer: u32, app: &mut dyn App) {
        let params = self.rel.reg[&(node.0, l)];
        let now = self.now();
        let Some(meta) = self.rel.peers.get_mut(&(node.0, l, peer)) else { return };
        meta.hb_armed = false;
        if meta.down || now >= meta.watch_until {
            return;
        }
        if now.saturating_sub(meta.last_heard) > params.liveness_ns {
            self.declare_down(node, l, peer, app);
            return;
        }
        meta.hb_armed = true;
        let ep = self.reliable_ep(node, l);
        self.send_at(now, &ep, NodeId(peer), Message::new(vec![FRAME_HEARTBEAT]));
        self.timer_at(now + params.heartbeat_ns, node, timer_tag(KIND_HEARTBEAT, l, peer));
    }

    fn declare_down(&mut self, node: NodeId, l: u16, peer: u32, app: &mut dyn App) {
        let meta = self.rel.peers.entry((node.0, l, peer)).or_default();
        if meta.down {
            return;
        }
        meta.down = true;
        self.metrics.peers_declared_down += 1;
        let ep = self.reliable_ep(node, l);
        self.app_scope(app, |net, app| app.on_peer_down(net, ep, NodeId(peer)));
    }

    fn reliable_ep(&self, node: NodeId, l: u16) -> Endpoint {
        let mode = self
            .comm_open_mode(node, l)
            .unwrap_or_else(|| panic!("reliable lane {l:#x} not open at {node}"));
        Endpoint { node, mode }
    }

    /// Unified delivery: every channel's capture path hands complete
    /// endpoint messages here. Reliable lanes run the protocol receive
    /// side; everything else (and frames the transport does not
    /// recognize) keeps the plain contract — `App::on_message`, then
    /// the recv inbox unless consumed.
    pub(crate) fn comm_deliver(&mut self, app: &mut dyn App, ep: Endpoint, msg: Message) {
        if self.is_reliable(&ep) {
            self.reliable_rx(app, ep, msg);
        } else if !app.on_message(self, ep, &msg) {
            self.comm_inbox_push(&ep, msg);
        }
    }

    fn reliable_rx(&mut self, app: &mut dyn App, ep: Endpoint, msg: Message) {
        let l = lane(&ep.mode);
        let peer = msg.from;
        let now = self.now();
        let kind = msg.data.first().copied();
        match kind {
            Some(FRAME_DATA) if msg.data.len() >= 9 => {
                self.touch_peer(ep.node, l, peer, now);
                let seq = read_u64(&msg.data[1..9]);
                let payload =
                    Message { from: peer, data: Arc::new(msg.data[9..].to_vec()) };
                let window = self.rx_capacity_of(&ep).unwrap_or(u32::MAX) as usize;
                let flow = self.rel.rx.entry((ep.node.0, l, peer.0)).or_default();
                if seq < flow.next_expected || flow.ooo.contains_key(&seq) {
                    // The retransmit raced the original (or our ack was
                    // lost): suppress, re-ack so the sender stops.
                    self.metrics.duplicates_dropped += 1;
                } else if seq == flow.next_expected {
                    flow.next_expected += 1;
                    // Release the in-order run the buffer was holding.
                    let mut run = vec![payload];
                    while let Some(m) = flow.ooo.remove(&flow.next_expected) {
                        flow.next_expected += 1;
                        run.push(m);
                    }
                    for m in run {
                        if !app.on_message(self, ep, &m) {
                            self.comm_inbox_push(&ep, m);
                        }
                    }
                } else if flow.ooo.len() >= window {
                    // Reorder buffer full: shed the segment (counted as
                    // a drop); the cumulative ack below keeps the
                    // sender retransmitting it.
                    self.metrics.dropped += 1;
                } else {
                    flow.ooo.insert(seq, payload);
                }
                self.send_ack(&ep, peer);
            }
            Some(FRAME_ACK) if msg.data.len() >= 9 => {
                self.touch_peer(ep.node, l, peer, now);
                let cum = read_u64(&msg.data[1..9]);
                let bits =
                    if msg.data.len() >= 17 { read_u64(&msg.data[9..17]) } else { 0 };
                if let Some(flow) = self.rel.tx.get_mut(&(ep.node.0, l, peer.0)) {
                    let before = flow.unacked.len();
                    flow.unacked = flow.unacked.split_off(&cum);
                    // Acks reorder on unordered modes; merge windows
                    // instead of overwriting so a stale ack can never
                    // retract a SACKed sequence. Re-basing shifts bit
                    // `i` (= base+1+i) by the base delta.
                    match cum.cmp(&flow.sack_cum) {
                        std::cmp::Ordering::Greater => {
                            let shift = cum - flow.sack_cum;
                            let old =
                                if shift >= 64 { 0 } else { flow.sack_bits >> shift };
                            flow.sack_cum = cum;
                            flow.sack_bits = bits | old;
                        }
                        std::cmp::Ordering::Equal => flow.sack_bits |= bits,
                        std::cmp::Ordering::Less => {
                            let shift = flow.sack_cum - cum;
                            if shift < 64 {
                                flow.sack_bits |= bits >> shift;
                            }
                        }
                    }
                    if flow.unacked.len() < before {
                        // Forward progress resets the backoff.
                        flow.timeouts = 0;
                        flow.rto = self.rel.reg[&(ep.node.0, l)].rto_ns;
                    }
                }
            }
            Some(FRAME_HEARTBEAT) => {
                self.touch_peer(ep.node, l, peer, now);
                self.send_ack(&ep, peer);
            }
            // Not a transport frame: raw traffic sharing the lane.
            _ => {
                if !app.on_message(self, ep, &msg) {
                    self.comm_inbox_push(&ep, msg);
                }
            }
        }
    }

    fn touch_peer(&mut self, node: NodeId, l: u16, peer: NodeId, now: Time) {
        let meta = self.rel.peers.entry((node.0, l, peer.0)).or_default();
        meta.last_heard = meta.last_heard.max(now);
    }

    fn send_ack(&mut self, ep: &Endpoint, peer: NodeId) {
        self.metrics.acks += 1;
        let (cum, bits) = self
            .rel
            .rx
            .get(&(ep.node.0, lane(&ep.mode), peer.0))
            .map_or((0, 0), |f| (f.next_expected, sack_window(&f.ooo, f.next_expected)));
        let now = self.now();
        self.send_at(now, ep, peer, Message::new(frame_ack(cum, bits)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ethernet::RxMode;
    use crate::config::SystemConfig;

    fn pm() -> CommMode {
        CommMode::Postmaster { queue: 5 }
    }

    struct Collect {
        got: Vec<(u32, Vec<u8>)>,
        downs: Vec<(u32, u32)>,
    }
    impl Collect {
        fn new() -> Self {
            Collect { got: Vec::new(), downs: Vec::new() }
        }
    }
    impl App for Collect {
        fn on_message(&mut self, _net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
            self.got.push((ep.node.0, msg.data.to_vec()));
            true
        }
        fn on_peer_down(&mut self, _net: &mut Network, ep: Endpoint, peer: NodeId) {
            self.downs.push((ep.node.0, peer.0));
        }
    }

    #[test]
    fn lossless_flow_delivers_in_order_with_acks() {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(26));
        let p = ReliableParams::default();
        let ea = net.reliable_open(a, pm(), p);
        net.reliable_open(b, pm(), p);
        for i in 0..10u8 {
            net.reliable_send(&ea, b, Message::new(vec![i; 8]));
        }
        let mut app = Collect::new();
        net.run_to_quiescence(&mut app);
        assert_eq!(app.got.len(), 10);
        for (i, (node, data)) in app.got.iter().enumerate() {
            assert_eq!(*node, b.0);
            assert_eq!(*data, vec![i as u8; 8], "in-order, header stripped");
        }
        assert_eq!(net.metrics.acks, 10, "one cumulative ack per data frame");
        assert_eq!(net.metrics.retransmits, 0, "nothing lost, nothing resent");
        assert_eq!(net.metrics.duplicates_dropped, 0);
        assert_eq!(net.metrics.peers_declared_down, 0);
        assert!(
            net.rel.tx[&(a.0, lane(&pm()), b.0)].unacked.is_empty(),
            "acks cleared the retransmit queue"
        );
    }

    #[test]
    fn spurious_timeout_is_absorbed_by_duplicate_suppression() {
        // An RTO shorter than the path's round trip forces retransmits
        // of frames that were never lost; the receiver must still
        // deliver exactly once.
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(26));
        let p = ReliableParams { rto_ns: 2_000, ..ReliableParams::default() };
        let ea = net.reliable_open(a, pm(), p);
        net.reliable_open(b, pm(), p);
        for i in 0..5u8 {
            net.reliable_send(&ea, b, Message::new(vec![i; 8]));
        }
        let mut app = Collect::new();
        net.run_to_quiescence(&mut app);
        assert_eq!(app.got.len(), 5, "exactly once despite retransmits");
        assert!(net.metrics.retransmits > 0, "the tiny RTO must have fired");
        assert_eq!(
            net.metrics.duplicates_dropped, net.metrics.retransmits,
            "every spurious retransmit was suppressed at the receiver"
        );
        assert_eq!(net.metrics.peers_declared_down, 0, "progress resets the budget");
    }

    #[test]
    fn lost_frames_are_retransmitted_until_delivered() {
        // Ethernet + a sink inbox of 0 would drop at the endpoint
        // layer, but reliable delivery happens above the inbox (the
        // callback consumes). Instead, force real loss: drop every
        // packet once via a dead destination... simplest deterministic
        // loss: drop_unroutable with the receiver's links failed for a
        // while, then repaired.
        let mut cfg = SystemConfig::card();
        cfg.drop_unroutable = true;
        let mut net = Network::new(cfg);
        let (a, b) = (NodeId(0), NodeId(26));
        let p = ReliableParams { rto_ns: 20_000, ..ReliableParams::default() };
        let ea = net.reliable_open(a, pm(), p);
        net.reliable_open(b, pm(), p);
        // Fail all of b's inbound links: frames to b wander and die.
        let dead = net.topo.in_links(b).to_vec();
        for &l in &dead {
            net.fail_link(l);
        }
        for i in 0..4u8 {
            net.reliable_send(&ea, b, Message::new(vec![i; 8]));
        }
        let mut app = Collect::new();
        net.run_until(&mut app, 60_000);
        assert!(app.got.is_empty(), "nothing can reach b yet");
        assert!(net.metrics.dropped > 0, "frames died at the hop budget");
        for &l in &dead {
            net.repair_link(l);
        }
        net.run_to_quiescence(&mut app);
        assert_eq!(app.got.len(), 4, "retransmits recovered every message, once");
        assert!(net.metrics.retransmits > 0);
        assert_eq!(net.metrics.peers_declared_down, 0);
    }

    #[test]
    fn retry_budget_exhaustion_declares_peer_down_and_surfaces_unacked() {
        let mut cfg = SystemConfig::card();
        cfg.drop_unroutable = true;
        let mut net = Network::new(cfg);
        let (a, b) = (NodeId(0), NodeId(26));
        let p = ReliableParams { rto_ns: 10_000, max_retries: 3, ..ReliableParams::default() };
        let ea = net.reliable_open(a, pm(), p);
        net.reliable_open(b, pm(), p);
        // b is gone entirely (all inbound links dead, permanently).
        let dead = net.topo.in_links(b).to_vec();
        for &l in &dead {
            net.fail_link(l);
        }
        net.reliable_send(&ea, b, Message::new(vec![7; 8]));
        net.reliable_send(&ea, b, Message::new(vec![8; 8]));
        let mut app = Collect::new();
        net.run_to_quiescence(&mut app);
        assert_eq!(app.downs, vec![(a.0, b.0)], "sender declared b down, once");
        assert_eq!(net.metrics.peers_declared_down, 1);
        assert!(net.reliable_is_down(&ea, b));
        let unacked = net.reliable_take_unacked(&ea, b);
        assert_eq!(unacked.len(), 2, "undelivered payloads surfaced for re-placement");
        assert_eq!(*unacked[0].data, vec![7; 8]);
        assert_eq!(*unacked[1].data, vec![8; 8]);
        assert!(net.reliable_take_unacked(&ea, b).is_empty(), "take drains");
    }

    #[test]
    fn heartbeat_watch_detects_a_silent_peer_without_data() {
        let mut cfg = SystemConfig::card();
        cfg.drop_unroutable = true;
        let mut net = Network::new(cfg);
        let (a, b) = (NodeId(0), NodeId(26));
        let p = ReliableParams {
            heartbeat_ns: 20_000,
            liveness_ns: 100_000,
            ..ReliableParams::default()
        };
        let ea = net.reliable_open(a, pm(), p);
        net.reliable_open(b, pm(), p);
        for l in net.topo.in_links(b).to_vec() {
            net.fail_link(l);
        }
        net.reliable_watch(&ea, b, 1_000_000);
        let mut app = Collect::new();
        net.run_to_quiescence(&mut app);
        assert_eq!(app.downs, vec![(a.0, b.0)], "silence past the threshold → down");
        // And the monitor stopped: the run quiesced (we got here).
    }

    #[test]
    fn heartbeat_watch_keeps_a_live_peer_up_and_quiesces_at_the_bound() {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(26));
        let p = ReliableParams {
            heartbeat_ns: 20_000,
            liveness_ns: 100_000,
            ..ReliableParams::default()
        };
        let ea = net.reliable_open(a, pm(), p);
        net.reliable_open(b, pm(), p);
        net.reliable_watch(&ea, b, 500_000);
        let mut app = Collect::new();
        net.run_to_quiescence(&mut app);
        assert!(app.downs.is_empty(), "acked heartbeats keep the peer alive");
        assert!(net.now() >= 500_000, "monitor ran to its bound");
        assert!(net.metrics.acks > 0, "heartbeats elicited acks");
    }

    #[test]
    fn ethernet_mode_carries_the_transport_too() {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(13));
        let p = ReliableParams::default();
        let mode = CommMode::Ethernet { rx: RxMode::Interrupt };
        let ea = net.reliable_open(a, mode, p);
        net.reliable_open(b, mode, p);
        // Multi-frame message: framing sits above reassembly.
        let payload: Vec<u8> = (0..4000u32).map(|i| i as u8).collect();
        net.reliable_send(&ea, b, Message::new(payload.clone()));
        let mut app = Collect::new();
        net.run_to_quiescence(&mut app);
        assert_eq!(app.got.len(), 1);
        assert_eq!(app.got[0].1, payload);
        assert_eq!(net.metrics.acks, 1);
    }

    #[test]
    fn raw_traffic_passes_through_a_reliable_lane() {
        let mut net = Network::card();
        let (a, b) = (NodeId(0), NodeId(9));
        let mode = pm();
        let ea = net.open(a, mode);
        net.reliable_open(b, mode, ReliableParams::default());
        // A plain (unframed) send into a reliable receiver: first byte
        // is not a frame marker, so it reaches the app untouched.
        net.send(&ea, b, Message::new(vec![1, 2, 3]));
        let mut app = Collect::new();
        net.run_to_quiescence(&mut app);
        assert_eq!(app.got, vec![(b.0, vec![1, 2, 3])]);
        assert_eq!(net.metrics.acks, 0);
    }

    #[test]
    fn sack_window_marks_reorder_buffer_relative_to_cum() {
        let mut ooo = BTreeMap::new();
        for seq in [6u64, 7, 9, 68, 69, 1000] {
            ooo.insert(seq, Message::new(vec![]));
        }
        // cum = 5: bit i ⇒ seq 6 + i; the window tops out at seq 69,
        // so 1000 falls past it.
        let bits = sack_window(&ooo, 5);
        assert_eq!(bits, 1 | 1 << 1 | 1 << 3 | 1 << 62 | 1 << 63);
        // Advancing cum re-bases the window and exposes the far entry.
        let bits = sack_window(&ooo, 9);
        assert_eq!(bits, 1 << (68 - 10) | 1 << (69 - 10));
        assert_eq!(sack_window(&BTreeMap::new(), 0), 0, "empty buffer, empty window");
        // The wire frame round-trips both fields.
        let f = frame_ack(5, bits);
        assert_eq!(f.len(), 17);
        assert_eq!(f[0], FRAME_ACK);
        assert_eq!(read_u64(&f[1..9]), 5);
        assert_eq!(read_u64(&f[9..17]), bits);
    }

    #[test]
    #[should_panic(expected = "cannot carry the reliable transport")]
    fn fifo_mode_is_rejected() {
        let mut net = Network::card();
        net.reliable_open(
            NodeId(0),
            CommMode::BridgeFifo { width_bits: 64 },
            ReliableParams::default(),
        );
    }
}
