//! Dynamic SERDES link state: occupancy + credit-based flow control.
//!
//! §2.3: links are pairs of unidirectional serial connections with no
//! sideband handshake wires. A receiver grants byte credits (sent over
//! the paired reverse connection); a transmitter decrements its credit
//! balance as it sends and never exceeds it, so overruns cannot occur and
//! no data is lost. The protocol runs entirely in the hardware fabric —
//! in the model, entirely inside the event handlers, with no involvement
//! of the simulated ARM.
//!
//! Packets are held as arena handles ([`PacketRef`]) paired with their
//! wire size, so a backed-up link queues 8 bytes per waiting packet
//! instead of the whole ~100-byte `Packet`.

use std::collections::VecDeque;

use crate::config::LinkTiming;
use crate::network::arena::PacketRef;
use crate::sim::Time;

/// Transmit-side dynamic state of one unidirectional link.
#[derive(Debug, Clone)]
pub struct LinkState {
    /// Credits (bytes) currently held by the transmitter.
    credits: u32,
    /// Time at which the link finishes serializing the current packet.
    busy_until: Time,
    /// Packets waiting for the link (either busy or out of credits),
    /// as (arena handle, wire bytes).
    queue: VecDeque<(PacketRef, u32)>,
    /// A `Drain` event is already scheduled for this link. An idle link
    /// with an empty queue schedules nothing — the event core only ever
    /// sees drains that can do work (suppressions are counted in
    /// [`crate::metrics::Metrics::drains_suppressed`]).
    drain_pending: bool,
    /// Lifetime counters.
    pub sent_packets: u64,
    pub sent_bytes: u64,
    /// High-water mark of the output queue (backpressure diagnostics).
    pub max_queue: usize,
}

impl LinkState {
    pub fn new(timing: &LinkTiming) -> Self {
        LinkState {
            credits: timing.credit_buffer_bytes,
            busy_until: 0,
            queue: VecDeque::new(),
            drain_pending: false,
            sent_packets: 0,
            sent_bytes: 0,
            max_queue: 0,
        }
    }

    #[inline]
    pub fn credits(&self) -> u32 {
        self.credits
    }

    #[inline]
    pub fn busy_until(&self) -> Time {
        self.busy_until
    }

    #[inline]
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Is the link able to take `bytes` right now?
    #[inline]
    pub fn ready(&self, now: Time, bytes: u32) -> bool {
        self.queue.is_empty() && self.busy_until <= now && self.credits >= bytes
    }

    /// Idle (for adaptive routing's "which links happen to be idle").
    #[inline]
    pub fn idle(&self, now: Time) -> bool {
        self.busy_until <= now && self.queue.is_empty()
    }

    /// Begin transmitting a packet of `wire_bytes` (caller checked
    /// credits + idleness; the queue may still hold packets behind this
    /// one on the drain path). Returns when serialization finishes.
    pub fn start_tx(&mut self, now: Time, wire_bytes: u32, timing: &LinkTiming) -> Time {
        debug_assert!(self.busy_until <= now && self.credits >= wire_bytes);
        self.credits -= wire_bytes;
        self.busy_until = now + timing.ser(wire_bytes);
        self.sent_packets += 1;
        self.sent_bytes += wire_bytes as u64;
        self.busy_until
    }

    /// Queue a packet that could not be sent immediately.
    pub fn enqueue(&mut self, pkt: PacketRef, wire_bytes: u32) {
        self.queue.push_back((pkt, wire_bytes));
        self.max_queue = self.max_queue.max(self.queue.len());
    }

    /// Mark that a `Drain` event is scheduled. Returns `false` if one
    /// was already pending (caller must not schedule a duplicate).
    #[inline]
    pub fn arm_drain(&mut self) -> bool {
        if self.drain_pending {
            false
        } else {
            self.drain_pending = true;
            true
        }
    }

    /// Clear the pending flag (invoked when the `Drain` event fires).
    #[inline]
    pub fn disarm_drain(&mut self) {
        self.drain_pending = false;
    }

    /// Return credits granted by the receiver (it freed buffer space).
    pub fn grant(&mut self, bytes: u32, cap: u32) {
        self.credits = (self.credits + bytes).min(cap);
    }

    /// Pop the head-of-line packet if the link can send it now.
    pub fn pop_sendable(&mut self, now: Time) -> Option<(PacketRef, u32)> {
        if self.busy_until > now {
            return None;
        }
        let (_, head_bytes) = *self.queue.front()?;
        if self.credits < head_bytes {
            return None;
        }
        self.queue.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::arena::PacketArena;
    use crate::router::{Packet, Payload, Proto, RouteKind};
    use crate::topology::NodeId;

    fn pkt(id: u64, bytes: usize) -> Packet {
        Packet::new(
            id,
            NodeId(0),
            NodeId(1),
            RouteKind::Directed,
            Proto::Raw { tag: 0 },
            Payload::bytes(vec![0u8; bytes]),
            0,
        )
    }

    #[test]
    fn credits_decrease_on_tx_and_recover_on_grant() {
        let timing = LinkTiming::default();
        let mut l = LinkState::new(&timing);
        let wire = pkt(0, 1000).wire_bytes;
        assert!(l.ready(0, wire));
        let done = l.start_tx(0, wire, &timing);
        assert_eq!(done, 1008);
        assert_eq!(l.credits(), 4096 - 1008);
        l.grant(1008, timing.credit_buffer_bytes);
        assert_eq!(l.credits(), 4096);
    }

    #[test]
    fn grant_never_exceeds_cap() {
        let timing = LinkTiming::default();
        let mut l = LinkState::new(&timing);
        l.grant(10_000, timing.credit_buffer_bytes);
        assert_eq!(l.credits(), timing.credit_buffer_bytes);
    }

    #[test]
    fn out_of_credit_blocks_tx() {
        let timing = LinkTiming::default();
        let mut l = LinkState::new(&timing);
        let mut arena = PacketArena::new();
        // Drain credits with 1400-byte packets (3×1408 > 4096).
        let wire = pkt(0, 1400).wire_bytes;
        l.start_tx(0, wire, &timing);
        l.grant(0, timing.credit_buffer_bytes);
        let mut now = l.busy_until();
        l.start_tx(now, wire, &timing);
        now = l.busy_until();
        assert!(!l.ready(now, wire), "should be out of credits");
        let r = arena.alloc(pkt(0, 1400));
        l.enqueue(r, wire);
        assert!(l.pop_sendable(now).is_none());
        l.grant(2 * 1408, timing.credit_buffer_bytes);
        assert_eq!(l.pop_sendable(now), Some((r, wire)));
    }

    #[test]
    fn busy_link_blocks_until_serialization_done() {
        let timing = LinkTiming::default();
        let mut l = LinkState::new(&timing);
        let wire = pkt(0, 500).wire_bytes;
        l.start_tx(0, wire, &timing);
        assert!(!l.ready(100, wire));
        assert!(l.ready(508, wire));
    }

    #[test]
    fn drain_arming_is_single_shot() {
        let timing = LinkTiming::default();
        let mut l = LinkState::new(&timing);
        assert!(l.arm_drain(), "first arm schedules");
        assert!(!l.arm_drain(), "second arm suppressed while pending");
        l.disarm_drain();
        assert!(l.arm_drain(), "re-arms after the event fired");
    }

    #[test]
    fn queue_is_fifo_and_tracks_high_water() {
        let timing = LinkTiming::default();
        let mut l = LinkState::new(&timing);
        let mut arena = PacketArena::new();
        let a = arena.alloc(pkt(1, 10));
        let b = arena.alloc(pkt(2, 10));
        l.enqueue(a, 18);
        l.enqueue(b, 18);
        assert_eq!(l.max_queue, 2);
        assert_eq!(l.pop_sendable(0).unwrap().0, a);
        assert_eq!(l.pop_sendable(0).unwrap().0, b);
    }
}
