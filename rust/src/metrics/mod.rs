//! Counters and latency histograms for the fabric and channels.

use std::collections::BTreeMap;

use crate::sim::Time;

/// Log-scaled latency histogram (ns), plus exact min/max/mean.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHist {
    count: u64,
    sum: u128,
    min: Time,
    max: Time,
    /// Power-of-two buckets: bucket i counts samples in [2^i, 2^(i+1)).
    buckets: [u64; 48],
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist { count: 0, sum: 0, min: Time::MAX, max: 0, buckets: [0; 48] }
    }

    #[inline]
    pub fn record(&mut self, ns: Time) {
        self.count += 1;
        self.sum += ns as u128;
        self.min = self.min.min(ns);
        self.max = self.max.max(ns);
        let b = (64 - ns.max(1).leading_zeros() - 1).min(47) as usize;
        self.buckets[b] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn min(&self) -> Time {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> Time {
        self.max
    }

    /// Fold `other` into this histogram (exact: counts, sums, extrema
    /// and buckets all add, so merged shard histograms equal the serial
    /// engine's histogram sample-for-sample).
    pub fn merge(&mut self, other: &LatencyHist) {
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        for (b, &c) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += c;
        }
    }

    /// Approximate percentile from the log buckets (upper bound of the
    /// bucket containing the p-quantile sample).
    pub fn percentile(&self, p: f64) -> Time {
        if self.count == 0 {
            return 0;
        }
        let target = (p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return 1u64 << (i + 1);
            }
        }
        self.max
    }
}

/// Traffic totals of one communication mode (messages handed to the
/// channel and the payload bytes they carried; framing overhead —
/// Ethernet frame headers, Bridge-FIFO header words and word padding —
/// is excluded, so per-mode byte totals are comparable on identical
/// traffic). Message granularity is the mode's natural unit: one
/// Postmaster record, one Ethernet message (`eth_send_message` call,
/// endpoint message however many frames it segments into, or one
/// NAT-ingress frame), one Bridge-FIFO burst, one NetTunnel access,
/// one NFS transfer.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModeTraffic {
    pub messages: u64,
    pub bytes: u64,
}

/// Fabric-wide metrics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Metrics {
    /// End-to-end packet latency by protocol name.
    pub packet_latency: BTreeMap<&'static str, LatencyHist>,
    /// Per-communication-mode traffic, keyed by
    /// [`crate::channels::CommMode::name`]. Counted at the transmit
    /// recipes, so the unified Endpoint API and the legacy per-channel
    /// shims land in the same buckets. Part of the cross-engine
    /// byte-identity contract ([`Metrics::fabric_view`] keeps it).
    pub mode_traffic: BTreeMap<&'static str, ModeTraffic>,
    pub packets_delivered: u64,
    pub packets_injected: u64,
    pub broadcast_copies: u64,
    /// Header copies made at multicast tree branch points (payload
    /// bytes are Arc-shared, never copied).
    pub multicast_copies: u64,
    pub bytes_delivered: u64,
    /// Events where a packet had to queue on a busy/credit-blocked link.
    pub link_stalls: u64,
    /// Messages discarded at a full bounded receive buffer
    /// ([`crate::channels::ChannelCaps::rx_capacity`]). Only modes with
    /// `Reliability::BestEffort` semantics at the inbox (internal
    /// Ethernet) drop; guaranteed modes stall instead. Fabric behavior:
    /// kept by [`Metrics::fabric_view`].
    pub dropped: u64,
    /// Virtual time senders spent withheld by receive-side credit
    /// backpressure (Postmaster / Bridge-FIFO inbox at `rx_capacity`).
    /// Fabric behavior: kept by [`Metrics::fabric_view`].
    pub stalled_ns: u64,
    /// Worst-case reroute convergence observed by a chaos scenario: the
    /// longest gap between a scripted fault and the first delivery
    /// routed after it ([`crate::workload::chaos`]). Merged by **max**
    /// (it is a fabric-wide worst case, not a per-shard sum), so the
    /// sharded aggregate equals the serial engine's figure. Kept by
    /// [`Metrics::fabric_view`].
    pub reroute_convergence_ns: u64,
    /// Data segments the reliable transport re-sent after a retransmit
    /// timeout ([`crate::channels::reliable`]). Fabric behavior: kept
    /// by [`Metrics::fabric_view`], like the other reliable counters.
    pub retransmits: u64,
    /// Cumulative-ack control messages the reliable transport sent.
    pub acks: u64,
    /// Duplicate data segments the reliable receiver suppressed (the
    /// retransmit raced the original, or an ack was lost).
    pub duplicates_dropped: u64,
    /// Packets dropped by the seeded per-link loss model
    /// ([`crate::config::SystemConfig::drop_probability`]): the
    /// transmit attempt was discarded before the wire, the packet
    /// freed. Deterministic (a pure hash of seed, packet id and link),
    /// so it is fabric behavior: kept by [`Metrics::fabric_view`] and
    /// covered by the serial↔sharded byte-identity contract.
    pub link_loss: u64,
    /// Peers a reliable endpoint's liveness monitor declared down
    /// (retry budget exhausted or heartbeat silence past the
    /// threshold). Surfaced to apps via `App::on_peer_down`.
    pub peers_declared_down: u64,
    /// No-op `Drain` events the pending-drain flag kept out of the event
    /// queue (an idle link with nothing queued schedules no drain).
    pub drains_suppressed: u64,
    /// **Engine-level** counter: lockstep windows the sharded engine's
    /// distance-aware epoch batching coalesced into barrier-free
    /// sprints (see `network::sharded`). Always 0 on the serial engine,
    /// so it is excluded from the serial↔sharded byte-identity contract
    /// — compare [`Metrics::fabric_view`]s, not raw blocks, across
    /// engines.
    pub windows_merged: u64,
    /// **Engine-level**: resident bytes of the engine's domain-sized
    /// dynamic state vectors (links, nodes, NIC ports, failure flags —
    /// see `Network::state_bytes`), set at construction. Merging sums
    /// the per-shard slices, which equal the serial engine's figure
    /// exactly (every node and link is owned by one shard); the
    /// headline is the *per-shard* value, cut ~shard-count× by the
    /// owned-subset domains (`inc9000_domain` bench rows). Excluded
    /// from [`Metrics::fabric_view`] like every engine-level field.
    pub state_bytes: u64,
    /// **Engine-level**: times a speculating shard of the optimistic
    /// (Time Warp) engine restored a checkpoint after a straggler
    /// import (see `network::timewarp`). Always 0 on the serial and
    /// conservative engines; excluded from [`Metrics::fabric_view`].
    pub rollbacks: u64,
    /// **Engine-level**: events re-dispatched during rollback replay
    /// (speculative work thrown away and redone). Excluded from
    /// [`Metrics::fabric_view`].
    pub events_replayed: u64,
    /// **Engine-level**: cumulative estimated bytes of the optimistic
    /// engine's state snapshots (domain-sized state + live packets +
    /// pending events, summed over every checkpoint taken). Excluded
    /// from [`Metrics::fabric_view`].
    pub checkpoints_bytes: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Fold another metrics block into this one (used to aggregate
    /// per-shard metrics; every field is a sum or an exact histogram
    /// merge, so the aggregate equals the serial engine's metrics).
    pub fn merge(&mut self, other: &Metrics) {
        for (proto, hist) in &other.packet_latency {
            self.packet_latency.entry(proto).or_insert_with(LatencyHist::new).merge(hist);
        }
        for (mode, t) in &other.mode_traffic {
            let e = self.mode_traffic.entry(mode).or_default();
            e.messages += t.messages;
            e.bytes += t.bytes;
        }
        self.packets_delivered += other.packets_delivered;
        self.packets_injected += other.packets_injected;
        self.broadcast_copies += other.broadcast_copies;
        self.multicast_copies += other.multicast_copies;
        self.bytes_delivered += other.bytes_delivered;
        self.link_stalls += other.link_stalls;
        self.dropped += other.dropped;
        self.stalled_ns += other.stalled_ns;
        self.reroute_convergence_ns = self.reroute_convergence_ns.max(other.reroute_convergence_ns);
        self.retransmits += other.retransmits;
        self.acks += other.acks;
        self.duplicates_dropped += other.duplicates_dropped;
        self.link_loss += other.link_loss;
        self.peers_declared_down += other.peers_declared_down;
        self.drains_suppressed += other.drains_suppressed;
        self.windows_merged += other.windows_merged;
        self.state_bytes += other.state_bytes;
        self.rollbacks += other.rollbacks;
        self.events_replayed += other.events_replayed;
        self.checkpoints_bytes += other.checkpoints_bytes;
    }

    /// The fabric-behavior view: engine-level fields
    /// ([`Metrics::windows_merged`], [`Metrics::state_bytes`]) zeroed.
    /// This is the block the serial↔sharded differential compares
    /// byte-for-byte — how an engine *schedules* its windows or *lays
    /// out* its state is not fabric behavior.
    pub fn fabric_view(&self) -> Metrics {
        let mut m = self.clone();
        m.windows_merged = 0;
        m.state_bytes = 0;
        m.rollbacks = 0;
        m.events_replayed = 0;
        m.checkpoints_bytes = 0;
        m
    }

    /// Count one message of `bytes` payload handed to communication
    /// mode `mode` (see [`ModeTraffic`]).
    pub fn record_mode(&mut self, mode: &'static str, bytes: u64) {
        let e = self.mode_traffic.entry(mode).or_default();
        e.messages += 1;
        e.bytes += bytes;
    }

    pub fn record_delivery(&mut self, proto: &'static str, latency: Time, bytes: u32) {
        self.packets_delivered += 1;
        self.bytes_delivered += bytes as u64;
        self.packet_latency.entry(proto).or_insert_with(LatencyHist::new).record(latency);
    }

    pub fn latency(&self, proto: &'static str) -> Option<&LatencyHist> {
        self.packet_latency.get(proto)
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "packets: injected={} delivered={} (broadcast copies={}, multicast copies={}), \
             bytes={}, link stalls={}, drains suppressed={}\n",
            self.packets_injected,
            self.packets_delivered,
            self.broadcast_copies,
            self.multicast_copies,
            self.bytes_delivered,
            self.link_stalls,
            self.drains_suppressed
        ));
        if self.dropped > 0 {
            s.push_str(&format!("  rx-buffer drops={}\n", self.dropped));
        }
        if self.stalled_ns > 0 {
            s.push_str(&format!("  sender stall (credit withhold)={}ns\n", self.stalled_ns));
        }
        if self.reroute_convergence_ns > 0 {
            s.push_str(&format!(
                "  reroute convergence={}ns\n",
                self.reroute_convergence_ns
            ));
        }
        if self.link_loss > 0 {
            s.push_str(&format!("  link loss (seeded)={}\n", self.link_loss));
        }
        if self.retransmits + self.acks + self.duplicates_dropped + self.peers_declared_down > 0 {
            s.push_str(&format!(
                "  reliable: retransmits={} acks={} duplicates dropped={} peers declared down={}\n",
                self.retransmits, self.acks, self.duplicates_dropped, self.peers_declared_down
            ));
        }
        if self.windows_merged > 0 {
            s.push_str(&format!("  lockstep windows merged={}\n", self.windows_merged));
        }
        if self.state_bytes > 0 {
            s.push_str(&format!("  resident state bytes={}\n", self.state_bytes));
        }
        if self.rollbacks + self.events_replayed > 0 {
            s.push_str(&format!(
                "  timewarp: rollbacks={} events replayed={}\n",
                self.rollbacks, self.events_replayed
            ));
        }
        if self.checkpoints_bytes > 0 {
            s.push_str(&format!("  checkpoint bytes={}\n", self.checkpoints_bytes));
        }
        for (mode, t) in &self.mode_traffic {
            s.push_str(&format!(
                "  mode {:<12} messages={:<8} bytes={}\n",
                mode, t.messages, t.bytes
            ));
        }
        for (proto, h) in &self.packet_latency {
            s.push_str(&format!(
                "  {:<12} n={:<8} mean={:.2}µs min={:.2}µs max={:.2}µs p99≈{:.2}µs\n",
                proto,
                h.count(),
                h.mean() / 1000.0,
                h.min() as f64 / 1000.0,
                h.max() as f64 / 1000.0,
                h.percentile(0.99) as f64 / 1000.0,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_basic_stats() {
        let mut h = LatencyHist::new();
        for v in [100, 200, 300, 400] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 100);
        assert_eq!(h.max(), 400);
        assert!((h.mean() - 250.0).abs() < 1e-9);
    }

    #[test]
    fn empty_hist_is_sane() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(0.5), 0);
    }

    #[test]
    fn percentile_upper_bounds() {
        let mut h = LatencyHist::new();
        for _ in 0..99 {
            h.record(1000);
        }
        h.record(1_000_000);
        assert!(h.percentile(0.5) <= 2048);
        assert!(h.percentile(1.0) >= 1_000_000 / 2);
    }

    #[test]
    fn merged_shard_metrics_equal_one_big_block() {
        // Record the same samples once into a single block and once
        // split across two blocks that are merged: byte-identical.
        let samples = [(100u64, 16u32), (5_000, 64), (90, 1024), (77, 8)];
        let mut whole = Metrics::new();
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        for (i, (lat, bytes)) in samples.iter().enumerate() {
            whole.record_delivery("raw", *lat, *bytes);
            let half = if i % 2 == 0 { &mut a } else { &mut b };
            half.record_delivery("raw", *lat, *bytes);
        }
        whole.link_stalls = 3;
        whole.drains_suppressed = 5;
        whole.dropped = 4;
        whole.stalled_ns = 900;
        a.link_stalls = 1;
        b.link_stalls = 2;
        a.drains_suppressed = 5;
        a.dropped = 1;
        b.dropped = 3;
        a.stalled_ns = 500;
        b.stalled_ns = 400;
        let mut merged = Metrics::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged, whole);
    }

    #[test]
    fn reroute_convergence_merges_by_max() {
        // A fabric-wide worst case: the aggregate of per-shard blocks
        // must equal the serial engine's single figure, which is the
        // maximum over faults — not a sum over shards.
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.reroute_convergence_ns = 12_000;
        b.reroute_convergence_ns = 48_000;
        let mut merged = Metrics::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.reroute_convergence_ns, 48_000);
        // And it is fabric behavior: the view keeps it.
        assert_eq!(merged.fabric_view().reroute_convergence_ns, 48_000);
    }

    #[test]
    fn backpressure_counters_are_fabric_behavior() {
        let mut m = Metrics::new();
        m.dropped = 2;
        m.stalled_ns = 1_500;
        let f = m.fabric_view();
        assert_eq!(f.dropped, 2);
        assert_eq!(f.stalled_ns, 1_500);
        let r = m.report();
        assert!(r.contains("rx-buffer drops=2"));
        assert!(r.contains("credit withhold)=1500ns"));
    }

    #[test]
    fn mode_traffic_merges_and_survives_fabric_view() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record_mode("postmaster", 64);
        a.record_mode("postmaster", 32);
        b.record_mode("postmaster", 8);
        b.record_mode("ethernet", 1500);
        let mut merged = Metrics::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.mode_traffic["postmaster"], ModeTraffic { messages: 3, bytes: 104 });
        assert_eq!(merged.mode_traffic["ethernet"], ModeTraffic { messages: 1, bytes: 1500 });
        // Per-mode totals are fabric behavior: the view keeps them, so
        // cross-engine equality covers them too.
        assert_eq!(merged.fabric_view().mode_traffic, merged.mode_traffic);
    }

    #[test]
    fn reliable_counters_merge_and_survive_fabric_view() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.retransmits = 3;
        a.acks = 40;
        a.link_loss = 5;
        b.acks = 2;
        b.duplicates_dropped = 1;
        b.peers_declared_down = 1;
        b.link_loss = 2;
        let mut merged = Metrics::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.retransmits, 3);
        assert_eq!(merged.acks, 42);
        assert_eq!(merged.duplicates_dropped, 1);
        assert_eq!(merged.peers_declared_down, 1);
        assert_eq!(merged.link_loss, 7);
        // Reliable-transport activity (and the seeded loss that drives
        // it) is fabric behavior: the cross-engine byte-identity
        // contract covers it.
        let f = merged.fabric_view();
        assert_eq!(
            (f.retransmits, f.acks, f.duplicates_dropped, f.peers_declared_down, f.link_loss),
            (3, 42, 1, 1, 7)
        );
        let r = merged.report();
        assert!(r.contains("retransmits=3"));
        assert!(r.contains("peers declared down=1"));
        assert!(r.contains("link loss (seeded)=7"));
    }

    #[test]
    fn fabric_view_zeroes_engine_counters() {
        let mut m = Metrics::new();
        m.record_delivery("raw", 10, 4);
        m.windows_merged = 7;
        m.state_bytes = 4096;
        m.rollbacks = 2;
        m.events_replayed = 99;
        m.checkpoints_bytes = 1 << 20;
        let f = m.fabric_view();
        assert_eq!(f.windows_merged, 0);
        assert_eq!(f.state_bytes, 0);
        assert_eq!(f.rollbacks, 0);
        assert_eq!(f.events_replayed, 0);
        assert_eq!(f.checkpoints_bytes, 0);
        assert_eq!(f.packets_delivered, 1);
        let mut other = m.clone();
        other.windows_merged = 3;
        other.state_bytes = 1024;
        other.rollbacks = 5;
        other.events_replayed = 1;
        other.checkpoints_bytes = 2048;
        assert_ne!(m, other, "raw blocks differ on engine counters");
        assert_eq!(m.fabric_view(), other.fabric_view(), "fabric views agree");
    }

    #[test]
    fn metrics_report_contains_protocols() {
        let mut m = Metrics::new();
        m.record_delivery("fifo", 1100, 16);
        m.record_delivery("eth", 20_000, 1500);
        let r = m.report();
        assert!(r.contains("fifo"));
        assert!(r.contains("eth"));
    }
}
