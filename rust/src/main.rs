//! `repro` — the INC-Sim launcher.
//!
//! Subcommands map one-to-one onto the paper's artifacts (DESIGN.md §4):
//! `topo` (Fig 1/2), `table1` (Table 1), `bisection` (§2.3), `programming`
//! (§4.3), `channels` (Figs 3–5), `sandbox` (§4.3 interactive utility),
//! `train` / `mcts` / `learners` / `serve` (the machine-intelligence
//! workloads).
//! Argument parsing is hand-rolled (offline build, no clap).

use anyhow::Result;

use inc_sim::channels::ethernet::RxMode;
use inc_sim::channels::{CommMode, ReliableParams};
use inc_sim::config::{SystemConfig, SystemPreset};
use inc_sim::diag::sandbox::PcieSandbox;
use inc_sim::network::sharded::ShardedNetwork;
use inc_sim::network::{Fabric, Network, NullApp};
use inc_sim::router::{Payload, Proto};
use inc_sim::topology::{Coord, NodeId, Topology};
use inc_sim::util::SplitMix64;
use inc_sim::workload::chaos::workloads;
use inc_sim::workload::{chaos, learners, mcts, serving, snn, training};

const USAGE: &str = "\
repro — INC-Sim: IBM Neural Computer reproduction

USAGE: repro <command> [options]

COMMANDS
  topo        [--preset card|inc3000|inc9000]   topology census (Fig 1/2)
  table1                                        Bridge FIFO latency vs hops (Table 1)
  bisection                                     bandwidth census (§2.3)
  programming                                   JTAG vs PCIe programming times (§4.3)
  channels                                      virtual-channel comparison (Figs 3-5)
  sandbox     [--preset P] [--script FILE]      PCIe Sandbox session (§4.3)
  traffic     [--preset P] [--packets N] [--bytes B] [--seed S] [--shards K]
              [--optimistic]
              uniform-random traffic soak; K>1 runs the bounded-lag
              per-cage parallel engine (K=0 picks the preset's natural
              shard count, 1 forces the serial engine)
  train       [--ranks N] [--steps N] [--lr F] [--preset P] [--shards K] [--comm M]
              [--reliable]
              data-parallel LM training (E10); --comm picks the channel
              the gradient all-reduce rides
  mcts        [--workers N] [--rollouts N] [--preset P] [--shards K] [--comm M]
              [--reliable]
              distributed MCTS (E9)
  learners    [--preset P] [--shards K] [--comm M] [--reliable]
              learner-overlap experiment (E8)
  serve       [--preset P] [--shards K] [--arrivals poisson|burst|diurnal]
              [--rate R] [--requests N] [--frontends N] [--workers N]
              [--fanout N] [--comm M] [--sweep]
              open-loop inference serving through the gateway NAT (E15):
              a precomputed Poisson/bursty/diurnal arrival schedule enters
              via external Ethernet, frontends fan each request out to
              workers, and p50/p99/p999 latency is measured from the
              scheduled arrival (no coordinated omission). --sweep runs an
              offered-rate sweep (x0.25..x4 of --rate) on fresh fabrics
              and reports saturation throughput. K>1 replays the same run
              on the serial engine and exits nonzero unless the delivery
              trace, metrics and clocks are byte-identical
  snn         [--preset P] [--shards K] [--nodes N] [--neurons N] [--rate PPM]
              [--ticks T] [--fanout F] [--comm M] [--seed S] [--sweep]
              event-driven spiking neural network (E16): leaky
              integrate-and-fire neurons in fixed-point integer math,
              seeded synapse fan-out, spikes as multicast raw packets
              through the spanning-tree router (default) or unicast
              datagrams over --comm raw|pm|eth|fifo, per-synapse delays
              on the timing wheel. --rate is the background input
              probability per neuron-tick in ppm. K>1 replays the run
              on the serial engine and exits nonzero unless trace,
              metrics, clocks and report are byte-identical. --sweep
              runs the spike-rate x mesh-size x shard-count ablation
  chaos       [--scenario storm|flap|partition|drop|hotspot|loss|all]
              [--seed S] [--loss P]
              [--preset P] [--shards K] [--comm M] [--ticks N] [--rx-cap N]
              [--workload learners|allreduce|mcts] [--out FILE]
              seeded chaos scenario graded against SLOs (E13): deterministic
              fault script + background traffic; reports delivered
              throughput, p50/p99 latency, reroute convergence, drop/stall
              counts; --out writes the SLO report JSON; --rx-cap bounds
              the per-endpoint receive buffers (default: tiny for hotspot).
              --workload rides a real workload (over the reliable
              transport) through the scenario instead of background
              traffic (E14; storm|partition|drop only). --scenario all
              sweeps every background scenario plus every workload x
              scenario pairing into one combined JSON report, exiting
              nonzero if anything violates its SLO. The loss scenario
              scripts no link faults: it raises the fabric's seeded
              per-(packet, link) drop probability instead (default 0.01;
              override with --loss P) and grades delivery >= 90%

The workload subcommands accept --shards like traffic does: every
workload runs on either engine through the Fabric trait, with
byte-identical results. Adding --optimistic switches the sharded
engine from conservative bounded-lag to speculative (Time Warp)
epochs — shards run ahead of the horizon and roll back to a
checkpoint when a straggler import arrives — still byte-identical to
the serial engine; it needs --shards K>1 (or 0 for the natural
count). --comm pm|eth|fifo picks the virtual channel
the workload's messages travel over (first-class communication modes;
default pm = Postmaster DMA, eth = internal Ethernet, fifo = Bridge
FIFO). --reliable runs the workload's traffic over the ack/retransmit
transport (EXPERIMENTS.md §Reliable transport) — same answer on a
healthy fabric, plus framing/ack overhead; needs pm or eth (the Bridge
FIFO is already ordered and lossless).
";

/// Tiny flag parser: `--key value` pairs after the subcommand; a
/// `--key` directly followed by another `--flag` (or nothing) is a
/// bare boolean flag.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(args: &[String]) -> Self {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < args.len() {
            if let Some(key) = args[i].strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), String::new());
                    i += 1;
                }
            } else {
                eprintln!("unexpected argument {:?}", args[i]);
                std::process::exit(2);
            }
        }
        Args { flags }
    }

    /// Bare boolean flag: present (alone or with a truthy value).
    fn flag(&self, key: &str) -> bool {
        match self.flags.get(key).map(String::as_str) {
            None => false,
            Some("" | "true" | "1" | "yes") => true,
            Some("false" | "0" | "no") => false,
            Some(v) => {
                eprintln!("bad value for --{key}: {v:?} (boolean flag)");
                std::process::exit(2);
            }
        }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.flags.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("bad value for --{key}: {v:?}");
                std::process::exit(2);
            }),
            None => default,
        }
    }

    fn get_opt(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    fn preset(&self, default: SystemPreset) -> SystemPreset {
        match self.flags.get("preset") {
            Some(s) => SystemPreset::parse(s).unwrap_or_else(|| {
                eprintln!(
                    "unknown preset {s}; use card | inc3000 | inc9000 | inc27000 | \
                     inc100k, or a CXxCYxCZ card grid (e.g. 4x4x8)"
                );
                std::process::exit(2);
            }),
            None => default,
        }
    }

    /// `--comm pm|eth|fifo` → the workload's communication mode.
    fn comm(&self) -> CommMode {
        match self.flags.get("comm").map(|s| s.to_ascii_lowercase()) {
            None => CommMode::Postmaster { queue: 0 },
            Some(s) => match s.as_str() {
                "pm" | "postmaster" => CommMode::Postmaster { queue: 0 },
                "eth" | "ethernet" => CommMode::Ethernet { rx: RxMode::Interrupt },
                "fifo" | "bridge_fifo" => CommMode::BridgeFifo { width_bits: 64 },
                "raw" => CommMode::Raw,
                other => {
                    eprintln!("unknown comm mode {other:?}; use pm | eth | fifo | raw");
                    std::process::exit(2);
                }
            },
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        std::process::exit(2);
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "topo" => topo(args.preset(SystemPreset::Inc3000)),
        "table1" => table1(),
        "bisection" => bisection(),
        "programming" => programming(),
        "channels" => channels(),
        "sandbox" => sandbox(args.preset(SystemPreset::Card), args.get_opt("script")),
        "traffic" => traffic(
            args.preset(SystemPreset::Inc9000),
            args.get("packets", 50_000u32),
            args.get("bytes", 256u32),
            args.get("seed", 7u64),
            EngineArgs::parse(&args, 0),
        ),
        "train" => train(
            args.get("ranks", 4usize),
            args.get("steps", 200u32),
            args.get("lr", 0.25f32),
            args.preset(SystemPreset::Card),
            EngineArgs::parse(&args, 1),
            args.comm(),
            reliable_params(&args),
        )?,
        "mcts" => run_mcts(
            args.get("workers", 8usize),
            args.get("rollouts", 3000u64),
            args.preset(SystemPreset::Card),
            EngineArgs::parse(&args, 1),
            args.comm(),
            reliable_params(&args),
        ),
        "learners" => run_learners(
            args.preset(SystemPreset::Card),
            EngineArgs::parse(&args, 1),
            args.comm(),
            reliable_params(&args),
        ),
        "serve" => run_serve(&args),
        "snn" => run_snn(&args),
        "chaos" => run_chaos(&args),
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn topo(p: SystemPreset) {
    let t = Topology::preset(p);
    let (x, y, z) = t.dims();
    println!(
        "preset: {p:?} — {x}x{y}x{z} mesh, {} nodes, {} cards",
        t.node_count(),
        t.cards().len()
    );
    println!("unidirectional links: {}", t.link_count());
    println!(
        "card port capacity: {} links = {} GB/s (paper: 432)",
        Topology::card_port_capacity(),
        Topology::card_port_capacity()
    );
    if t.dims().0 % 2 == 0 {
        println!("bisection: {} GB/s", t.bisection_gbps());
    }
    let card = (0, 0, 0);
    println!(
        "card {:?}: controller {} (000), gateway {} (100), pcie2 {} (200)",
        card,
        t.controller_node(card),
        t.gateway_node(card),
        t.pcie2_node(card)
    );
}

fn table1() {
    println!("Table 1 — Bridge FIFO latency between two nodes (single card)");
    println!("{:<10} {:>9} {:>12} {:>8}", "hops", "paper µs", "measured µs", "error");
    let paper = [(0u32, 0.25f64), (1, 1.1), (3, 2.5), (6, 4.7)];
    let dsts = [
        Coord { x: 0, y: 0, z: 0 },
        Coord { x: 1, y: 0, z: 0 },
        Coord { x: 1, y: 1, z: 1 },
        Coord { x: 2, y: 2, z: 2 },
    ];
    for ((hops, us), dst) in paper.iter().zip(dsts) {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let d = net.topo.id(dst);
        net.fifo_connect(src, d, 0, 64);
        net.fifo_send(src, 0, &[1]);
        net.run_to_quiescence(&mut NullApp);
        let got = net.now() as f64 / 1000.0;
        println!("{:<10} {:>9.2} {:>12.2} {:>7.1}%", hops, us, got, (got - us) / us * 100.0);
    }
}

fn bisection() {
    println!("§2.3 bandwidth census");
    println!(
        "card port capacity: {} unidirectional links = {} GB/s (paper: 432 GB/s)",
        Topology::card_port_capacity(),
        Topology::card_port_capacity()
    );
    for p in [SystemPreset::Inc3000, SystemPreset::Inc9000] {
        let t = Topology::preset(p);
        println!(
            "{p:?}: bisection {} GB/s (paper: {})",
            t.bisection_gbps(),
            if p == SystemPreset::Inc3000 { 288 } else { 864 }
        );
    }
}

fn programming() {
    use inc_sim::router::MemTarget;
    use std::sync::Arc;
    let img = Arc::new(vec![0u8; 4 * 1024 * 1024]);
    println!("§4.3 programming-time comparison (4 MiB bitstream)");
    let mut net = Network::card();
    let t = net.jtag_program_fpgas((0, 0, 0), img.clone(), 1);
    println!("JTAG,  27 FPGAs:  {:>9.1} min (paper ≈ 15 min)", t as f64 / 60e9);
    let mut net = Network::card();
    let t = net.jtag_program_flash((0, 0, 0), img.clone());
    println!("JTAG,  27 FLASH:  {:>9.1} h   (paper > 5 h)", t as f64 / 3600e9);
    let mut net = Network::card();
    let t = net.pcie_broadcast_program(MemTarget::Fpga, img.clone(), 1);
    println!("PCIe,  27 FPGAs:  {:>9.2} s   (paper: a couple of seconds)", t as f64 / 1e9);
    let mut net = Network::inc3000();
    let t = net.pcie_broadcast_program(MemTarget::Fpga, img.clone(), 1);
    println!(
        "PCIe, 432 FPGAs:  {:>9.2} s   (paper: nearly identical to one card)",
        t as f64 / 1e9
    );
    let mut net = Network::inc3000();
    let t = net.pcie_broadcast_program(MemTarget::Flash, img, 1);
    println!("PCIe, 432 FLASH:  {:>9.1} min (paper ≈ 2 min)", t as f64 / 60e9);
}

fn channels() {
    println!("one 64-byte transfer, adjacent nodes, per virtual channel:");
    let (src, dst) = (NodeId(0), NodeId(1));
    let mut net = Network::card();
    net.fifo_connect(src, dst, 0, 64);
    net.fifo_send(src, 0, &(0..8u64).collect::<Vec<_>>());
    net.run_to_quiescence(&mut NullApp);
    println!("  bridge fifo : {:>8.2} µs", net.now() as f64 / 1000.0);
    let mut net = Network::card();
    net.pm_open(dst, 0);
    net.pm_send(src, dst, 0, vec![0; 64]);
    net.run_to_quiescence(&mut NullApp);
    println!("  postmaster  : {:>8.2} µs", net.now() as f64 / 1000.0);
    let mut net = Network::card();
    net.eth_send(src, dst, 64, 0);
    net.run_to_quiescence(&mut NullApp);
    println!("  ethernet    : {:>8.2} µs", net.now() as f64 / 1000.0);
}

/// Uniform-random traffic soak: the serial engine (`--shards 1`) or the
/// bounded-lag per-cage parallel engine (EXPERIMENTS.md §Perf).
fn traffic(p: SystemPreset, packets: u32, bytes: u32, seed: u64, eng: EngineArgs) {
    let cfg = SystemConfig::new(p);
    let nn = p.node_count();
    let mut rng = SplitMix64::new(seed);
    let mut pairs = Vec::with_capacity(packets as usize);
    for _ in 0..packets {
        let src = rng.gen_range(nn as usize) as u32;
        let mut dst = rng.gen_range(nn as usize) as u32;
        if dst == src {
            dst = (dst + 1) % nn;
        }
        pairs.push((NodeId(src), NodeId(dst)));
    }
    let t0 = std::time::Instant::now();
    let (events, vtime, metrics, label) = if eng.serial() {
        let mut net = Network::new(cfg);
        for &(s, d) in &pairs {
            net.send_directed(s, d, Proto::Raw { tag: 0 }, Payload::Synthetic(bytes));
        }
        let ev = net.run_to_quiescence(&mut NullApp);
        (ev, net.now(), net.metrics.clone(), "serial".to_string())
    } else {
        let mut net = eng.sharded(cfg);
        for &(s, d) in &pairs {
            net.send_directed(s, d, Proto::Raw { tag: 0 }, Payload::Synthetic(bytes));
        }
        let ev = net.run_to_quiescence();
        let label = format!(
            "sharded ({} shards, {} workers, lookahead {} ns{})",
            net.shard_count(),
            net.worker_count(),
            net.lookahead(),
            if eng.optimistic { ", optimistic" } else { "" }
        );
        (ev, net.now(), net.metrics(), label)
    };
    let secs = t0.elapsed().as_secs_f64();
    println!("{p:?}: {packets} packets of {bytes} B, engine: {label}");
    println!(
        "{events} events in {secs:.3} s = {:.2} M events/s, {:.0} kpkt/s; \
         virtual time {:.3} ms",
        events as f64 / secs / 1e6,
        packets as f64 / secs / 1e3,
        vtime as f64 / 1e6
    );
    print!("{}", metrics.report());
}

fn sandbox(p: SystemPreset, script: Option<String>) {
    let mut net = Network::new(SystemConfig::new(p));
    let mut sb = PcieSandbox::attach((0, 0, 0));
    let exec_line = |net: &mut Network, sb: &mut PcieSandbox, line: &str| {
        let out = sb.exec(net, line);
        println!("{}", out.text);
        println!("  [{} µs]", out.elapsed / 1000);
    };
    match script {
        Some(path) => {
            let text = std::fs::read_to_string(&path).expect("read script");
            for line in text.lines().filter(|l| !l.trim().is_empty() && !l.starts_with('#')) {
                println!("> {line}");
                exec_line(&mut net, &mut sb, line);
            }
        }
        None => {
            use std::io::BufRead;
            println!("PCIe Sandbox (node (000), card (0,0,0)); 'help' for commands, 'quit' to exit");
            for line in std::io::stdin().lock().lines() {
                let line = line.unwrap();
                if line.trim() == "quit" {
                    break;
                }
                exec_line(&mut net, &mut sb, &line);
            }
        }
    }
}

/// `--reliable` → the transport parameters for a workload run, after
/// checking the channel can actually carry the transport.
fn reliable_params(args: &Args) -> Option<ReliableParams> {
    if !args.flag("reliable") {
        return None;
    }
    if matches!(args.comm(), CommMode::BridgeFifo { .. }) {
        eprintln!(
            "--reliable needs an unordered channel (pm | eth); the Bridge FIFO \
             is already ordered and lossless end-to-end"
        );
        std::process::exit(2);
    }
    Some(ReliableParams::default())
}

/// Engine selection shared by every workload subcommand: `--shards K`
/// (0 = the preset's natural shard count, 1 = the serial engine) plus
/// `--optimistic` (Time Warp speculative epochs on the sharded
/// engine). Parsed in one place so every subcommand gets the same
/// semantics and the same friendly errors.
#[derive(Clone, Copy)]
struct EngineArgs {
    shards: u32,
    optimistic: bool,
}

impl EngineArgs {
    fn parse(args: &Args, default_shards: u32) -> Self {
        let shards = args.get("shards", default_shards);
        let optimistic = args.flag("optimistic");
        if optimistic && shards == 1 {
            eprintln!(
                "--optimistic speculates across shards, so it needs the sharded \
                 engine: pass --shards K with K > 1 (or 0 for the preset's \
                 natural shard count)"
            );
            std::process::exit(2);
        }
        EngineArgs { shards, optimistic }
    }

    /// `--shards 1`: the serial reference engine.
    fn serial(&self) -> bool {
        self.shards == 1
    }

    /// Build the sharded engine (K=0 → natural shard count) with the
    /// selected execution mode applied.
    fn sharded(&self, sys: SystemConfig) -> ShardedNetwork {
        let mut net =
            ShardedNetwork::new(sys, if self.shards == 0 { u32::MAX } else { self.shards });
        net.set_optimistic(self.optimistic);
        net
    }

    fn label(&self, net: &ShardedNetwork) -> String {
        format!(
            "sharded x{}{}",
            net.shard_count(),
            if self.optimistic { " (optimistic)" } else { "" }
        )
    }
}

fn train(
    ranks: usize,
    steps: u32,
    lr: f32,
    preset: SystemPreset,
    eng: EngineArgs,
    comm: CommMode,
    reliable: Option<ReliableParams>,
) -> Result<()> {
    let rt = inc_sim::runtime::load_default()?;
    let cfg = training::TrainConfig { ranks, steps, lr, comm, reliable, ..Default::default() };
    let report = if eng.serial() {
        let mut net = Network::new(SystemConfig::new(preset));
        training::train(&mut net, &rt, &cfg)?
    } else {
        let mut net = eng.sharded(SystemConfig::new(preset));
        if net.shard_count() == 1 {
            eprintln!(
                "note: {preset:?} partitions into 1 shard — this run is effectively serial \
                 (pick --preset inc3000|inc9000 for a multi-shard engine)"
            );
        }
        training::train(&mut net, &rt, &cfg)?
    };
    println!(
        "model {} — {} params, {} ranks, {} steps, all-reduce over {}{}",
        rt.manifest.model,
        report.params,
        ranks,
        steps,
        comm.name(),
        if reliable.is_some() { " (reliable)" } else { "" }
    );
    println!("{:>6} {:>10} {:>12}", "step", "loss", "vtime ms");
    for p in &report.curve {
        println!("{:>6} {:>10.4} {:>12.3}", p.step, p.loss, p.vtime as f64 / 1e6);
    }
    println!(
        "loss {:.4} -> {:.4}; vtime {:.3} ms ({:.1}% compute / {:.1}% comm)",
        report.first_loss,
        report.final_loss,
        report.vtime_total as f64 / 1e6,
        report.vtime_compute as f64 / report.vtime_total as f64 * 100.0,
        report.vtime_comm as f64 / report.vtime_total as f64 * 100.0,
    );
    Ok(())
}

fn run_mcts(
    workers: usize,
    rollouts: u64,
    preset: SystemPreset,
    eng: EngineArgs,
    comm: CommMode,
    reliable: Option<ReliableParams>,
) {
    // Leader at node 0; workers strided across the node space so larger
    // presets (and the sharded engine) see cross-card/cage task traffic.
    fn go<F: Fabric>(
        net: &mut F,
        workers: usize,
        rollouts: u64,
        comm: CommMode,
        reliable: Option<ReliableParams>,
    ) -> mcts::MctsResult {
        let nn = net.topo().node_count() as u32;
        let stride = ((nn - 1) / (workers as u32).max(1)).max(1);
        let ws: Vec<NodeId> = (0..workers as u32).map(|i| NodeId(1 + i * stride)).collect();
        let game = mcts::Game { depth: 6, branching: 3, seed: 42 };
        // Liveness watching off (`watch_until` 0): no faults here, the
        // transport contributes framing/ack/retransmit cover only.
        let m = match reliable {
            Some(p) => mcts::DistributedMcts::with_mode_reliable(
                net,
                game,
                NodeId(0),
                ws,
                comm,
                p,
                0,
            ),
            None => mcts::DistributedMcts::with_mode(net, game, NodeId(0), ws, comm),
        };
        m.search(net, rollouts)
    }
    let (r, engine) = if eng.serial() {
        let mut net = Network::new(SystemConfig::new(preset));
        (go(&mut net, workers, rollouts, comm, reliable), "serial".to_string())
    } else {
        let mut net = eng.sharded(SystemConfig::new(preset));
        let label = eng.label(&net);
        (go(&mut net, workers, rollouts, comm, reliable), label)
    };
    println!(
        "mcts [{engine}, comm {}{}]: {} rollouts on {} workers -> best path {:?} (value {:.3})",
        comm.name(),
        if reliable.is_some() { ", reliable" } else { "" },
        r.rollouts,
        workers,
        r.best_path,
        r.best_value
    );
    println!(
        "makespan {:.3} ms, throughput {:.0} rollouts/s (virtual)",
        r.makespan as f64 / 1e6,
        r.throughput
    );
}

/// `repro serve` — the open-loop inference-serving workload (E15).
/// With `--shards K>1` the run doubles as a byte-identity gate: the
/// identical experiment replays on the serial engine and any
/// divergence in the delivery trace, fabric-view metrics, final clock
/// or serving report exits non-zero (CI smoke-tests exactly this).
fn run_serve(args: &Args) {
    let preset = args.preset(SystemPreset::Card);
    let eng = EngineArgs::parse(args, 1);
    let arrivals_s = args.get_opt("arrivals").unwrap_or_else(|| "poisson".into());
    let arrivals = serving::ArrivalProcess::parse(&arrivals_s.to_ascii_lowercase())
        .unwrap_or_else(|| {
            eprintln!("unknown arrival process {arrivals_s:?}; use poisson | burst | diurnal");
            std::process::exit(2);
        });
    let d = serving::ServingConfig::default();
    let nn = preset.node_count() as usize;
    let cfg = serving::ServingConfig {
        frontends: args.get("frontends", d.frontends),
        workers: args.get("workers", d.workers),
        fanout: args.get("fanout", d.fanout),
        requests: args.get("requests", d.requests),
        rate_per_s: args.get("rate", d.rate_per_s),
        arrivals,
        comm: args.comm(),
        // Spread the pools across the mesh (and across shard
        // boundaries) while leaving plenty of strided candidates.
        stride: (nn / 128).max(1),
        ..d
    };
    if args.flag("sweep") {
        let rates: Vec<f64> =
            [0.25, 0.5, 1.0, 2.0, 4.0].iter().map(|m| cfg.rate_per_s * m).collect();
        let (sat, reports) = if eng.serial() {
            serving::saturation_sweep(
                move || Network::new(SystemConfig::new(preset)),
                cfg,
                &rates,
            )
        } else {
            serving::saturation_sweep(
                move || eng.sharded(SystemConfig::new(preset)),
                cfg,
                &rates,
            )
        };
        println!(
            "serving sweep [{preset:?}, {} arrivals, {} requests/point]:",
            cfg.arrivals.name(),
            cfg.requests
        );
        println!(
            "{:>14} {:>15} {:>10} {:>10} {:>10}",
            "offered req/s", "achieved req/s", "p50 ns", "p99 ns", "p999 ns"
        );
        for r in &reports {
            println!(
                "{:>14.0} {:>15.0} {:>10} {:>10} {:>10}",
                r.offered_rps, r.throughput_rps, r.p50_ns, r.p99_ns, r.p999_ns
            );
        }
        println!("saturation throughput: {sat:.0} req/s");
        return;
    }
    let (report, engine) = if eng.serial() {
        let mut net = Network::new(SystemConfig::new(preset));
        (serving::run(&mut net, cfg), "serial".to_string())
    } else {
        let mut sharded = eng.sharded(SystemConfig::new(preset));
        sharded.enable_trace();
        let label = eng.label(&sharded);
        let rep = serving::run(&mut sharded, cfg);
        // Byte-identity oracle: the same experiment, serial.
        let mut serial = Network::new(SystemConfig::new(preset));
        Fabric::enable_trace(&mut serial);
        let srep = serving::run(&mut serial, cfg);
        let mut bad = false;
        let sh_trace = sharded.take_trace();
        if sh_trace != serial.take_trace() {
            eprintln!("BYTE-IDENTITY FAILURE: delivery traces differ");
            bad = true;
        }
        if sharded.metrics().fabric_view() != serial.metrics.fabric_view() {
            eprintln!("BYTE-IDENTITY FAILURE: fabric-view metrics differ");
            bad = true;
        }
        if sharded.now() != serial.now() {
            eprintln!("BYTE-IDENTITY FAILURE: final clocks differ");
            bad = true;
        }
        if srep != rep {
            eprintln!("BYTE-IDENTITY FAILURE: serving reports differ");
            bad = true;
        }
        if bad {
            std::process::exit(1);
        }
        (rep, label)
    };
    println!(
        "serving [{engine}, {preset:?}, comm {}] {} x {} B requests, {} arrivals \
         at {:.0} req/s:",
        cfg.comm.name(),
        report.issued,
        cfg.request_bytes,
        cfg.arrivals.name(),
        report.offered_rps
    );
    println!(
        "  completed {}/{}; latency p50 {} ns, p99 {} ns, p999 {} ns \
         (mean {:.0} ns, max {} ns)",
        report.completed,
        report.issued,
        report.p50_ns,
        report.p99_ns,
        report.p999_ns,
        report.mean_ns,
        report.max_ns
    );
    println!(
        "  makespan {:.3} ms, achieved throughput {:.0} req/s",
        report.makespan_ns as f64 / 1e6,
        report.throughput_rps
    );
    if !eng.serial() {
        println!("  byte-identity vs serial engine: OK");
    }
}

/// `repro snn` — the event-driven spiking-neural-network workload
/// (E16). With `--shards K>1` the run doubles as a byte-identity gate
/// like `serve`: the identical experiment replays on the serial engine
/// and any divergence in the delivery trace, fabric-view metrics, final
/// clock or (normalized) SNN report exits non-zero. `--sweep` runs the
/// spike-rate x mesh-size x shard-count ablation on fresh fabrics.
fn run_snn(args: &Args) {
    let preset = args.preset(SystemPreset::Card);
    let eng = EngineArgs::parse(args, 1);
    let seed = args.get("seed", 42u64);
    let d = snn::SnnConfig::default();
    let nn = preset.node_count() as usize;
    let cfg = snn::SnnConfig {
        nodes: args.get("nodes", d.nodes),
        neurons_per_node: args.get("neurons", d.neurons_per_node),
        fanout: args.get("fanout", d.fanout),
        ticks: args.get("ticks", d.ticks),
        rate_ppm: args.get("rate", d.rate_ppm),
        // Absent --comm means the spanning-tree multicast transport;
        // present, spikes go unicast over that endpoint mode.
        comm: args.get_opt("comm").map(|_| args.comm()),
        // Spread the population across cards/cages (and shard
        // boundaries): the widest stride that still leaves enough
        // candidates for the population plus the excluded gateway.
        stride: (nn / (args.get("nodes", d.nodes) + 2)).max(1),
        ..d
    };
    let sys = |p: SystemPreset| {
        let mut s = SystemConfig::new(p);
        s.seed = seed;
        s
    };
    if args.flag("sweep") {
        let rates: Vec<u64> = [1u64, 2, 4, 8, 16]
            .iter()
            .map(|m| (cfg.rate_ppm * m / 4).clamp(1, 1_000_000))
            .collect();
        let mut presets = vec![SystemPreset::Card];
        if preset != SystemPreset::Card {
            presets.push(preset);
        }
        let shard_axis = [1u32, if eng.shards > 1 { eng.shards } else { 0 }];
        println!(
            "snn ablation sweep [{} nodes x {} neurons, {} ticks]:",
            cfg.nodes, cfg.neurons_per_node, cfg.ticks
        );
        println!(
            "{:>10} {:>8} {:>10} {:>8} {:>10} {:>12} {:>10}",
            "preset", "shards", "rate ppm", "spikes", "delivered", "spikes/s", "wheel pk"
        );
        for &p in &presets {
            let pn = p.node_count() as usize;
            let pcfg = snn::SnnConfig { stride: (pn / (cfg.nodes + 2)).max(1), ..cfg };
            for &k in &shard_axis {
                for &r in &rates {
                    let c = snn::SnnConfig { rate_ppm: r, ..pcfg };
                    let (rep, label) = if k == 1 {
                        let mut net = Network::new(sys(p));
                        (snn::run(&mut net, c), "1".to_string())
                    } else {
                        let mut net =
                            EngineArgs { shards: k, optimistic: eng.optimistic }.sharded(sys(p));
                        let label = net.shard_count().to_string();
                        (snn::run(&mut net, c), label)
                    };
                    println!(
                        "{:>10} {:>8} {:>10} {:>8} {:>10} {:>12.0} {:>10}",
                        format!("{p:?}"),
                        label,
                        r,
                        rep.spikes_emitted,
                        rep.spikes_delivered,
                        rep.spikes_per_s,
                        rep.wheel_peak
                    );
                }
            }
        }
        return;
    }
    let (report, engine) = if eng.serial() {
        let mut net = Network::new(sys(preset));
        (snn::run(&mut net, cfg), "serial".to_string())
    } else {
        let mut sharded = eng.sharded(sys(preset));
        sharded.enable_trace();
        let label = eng.label(&sharded);
        let rep = snn::run(&mut sharded, cfg);
        // Byte-identity oracle: the same experiment, serial.
        let mut serial = Network::new(sys(preset));
        Fabric::enable_trace(&mut serial);
        let srep = snn::run(&mut serial, cfg);
        let mut bad = false;
        if sharded.take_trace() != serial.take_trace() {
            eprintln!("BYTE-IDENTITY FAILURE: delivery traces differ");
            bad = true;
        }
        if sharded.metrics().fabric_view() != serial.metrics.fabric_view() {
            eprintln!("BYTE-IDENTITY FAILURE: fabric-view metrics differ");
            bad = true;
        }
        if sharded.now() != serial.now() {
            eprintln!("BYTE-IDENTITY FAILURE: final clocks differ");
            bad = true;
        }
        if srep.normalized() != rep.normalized() {
            eprintln!("BYTE-IDENTITY FAILURE: snn reports differ");
            bad = true;
        }
        if bad {
            std::process::exit(1);
        }
        (rep, label)
    };
    let transport = match cfg.comm {
        None => "multicast".to_string(),
        Some(m) => format!("unicast/{}", m.name()),
    };
    println!(
        "snn [{engine}, {preset:?}, {transport}] {} neurons on {} nodes, {} ticks \
         at {} ppm background:",
        report.neurons, report.nodes, report.ticks, cfg.rate_ppm
    );
    println!(
        "  spikes {} emitted, {} synaptic deliveries ({} expected), {} syn events",
        report.spikes_emitted,
        report.spikes_delivered,
        report.spikes_emitted * cfg.fanout as u64,
        report.syn_events
    );
    println!(
        "  virtual {:.3} ms, {:.0} spikes/s, {} events dispatched, wheel peak {}",
        report.virtual_ns as f64 / 1e6,
        report.spikes_per_s,
        report.events_dispatched,
        report.wheel_peak
    );
    for (mode, msgs, bytes) in &report.mode_traffic {
        println!("  traffic[{mode}]: {msgs} msgs, {bytes} B payload");
    }
    if !eng.serial() {
        println!("  byte-identity vs serial engine: OK");
    }
}

/// `repro chaos` — one seeded chaos scenario, graded against its SLOs
/// (EXPERIMENTS.md E13), a real workload riding a scenario over the
/// reliable transport (`--workload`, E14), or the full combined sweep
/// (`--scenario all`). Exits non-zero on any violation so CI can gate
/// on it.
fn run_chaos(args: &Args) {
    let scen_s = args.get_opt("scenario").unwrap_or_else(|| "storm".into());
    if scen_s.eq_ignore_ascii_case("all") {
        return run_chaos_all(args);
    }
    let scenario = chaos::Scenario::parse(&scen_s).unwrap_or_else(|| {
        eprintln!(
            "unknown scenario {scen_s:?}; use storm | flap | partition | drop | \
             hotspot | loss | all"
        );
        std::process::exit(2);
    });
    if let Some(w) = args.get_opt("workload") {
        return run_chaos_workload(args, &w, scenario);
    }
    let report = run_background_scenario(args, scenario, true);
    if let Some(path) = args.get_opt("out") {
        std::fs::write(&path, report.to_json()).expect("write SLO report");
        println!("  SLO report -> {path}");
    }
    match report.violations().as_slice() {
        [] => println!("  SLO: PASS"),
        v => {
            for viol in v {
                eprintln!("  SLO VIOLATION: {viol}");
            }
            std::process::exit(1);
        }
    }
}

/// One background-traffic chaos run on the configured preset/engine.
fn run_background_scenario(
    args: &Args,
    scenario: chaos::Scenario,
    verbose: bool,
) -> chaos::SloReport {
    let preset = args.preset(SystemPreset::Card);
    let eng = EngineArgs::parse(args, 1);
    let mut ccfg = chaos::ChaosConfig::new(scenario, args.get("seed", 42u64));
    // Only override the scenario's channel when the user asked: loss
    // defaults to best-effort Ethernet, everything else to Postmaster.
    if args.get_opt("comm").is_some() {
        ccfg.comm = args.comm();
    }
    ccfg.ticks = args.get("ticks", ccfg.ticks);
    let mut sys = SystemConfig::new(preset);
    sys.rx_capacity = args.get("rx-cap", ccfg.suggested_rx_capacity());
    sys.drop_probability = args.get("loss", scenario.suggested_drop_probability());
    let (report, engine) = if eng.serial() {
        let mut net = Network::new(sys);
        (chaos::run(&mut net, &ccfg, 1), "serial".to_string())
    } else {
        let mut net = eng.sharded(sys.clone());
        let label = eng.label(&net);
        let k = net.shard_count();
        if eng.optimistic {
            // Speculative execution must stay byte-identical: replay
            // the identical experiment on the serial oracle and exit
            // non-zero on any divergence (CI smoke-tests exactly this).
            net.enable_trace();
            let rep = chaos::run(&mut net, &ccfg, k);
            let mut serial = Network::new(sys);
            Fabric::enable_trace(&mut serial);
            let srep = chaos::run(&mut serial, &ccfg, k);
            let mut bad = false;
            if net.take_trace() != serial.take_trace() {
                eprintln!("BYTE-IDENTITY FAILURE: delivery traces differ");
                bad = true;
            }
            if net.metrics().fabric_view() != serial.metrics.fabric_view() {
                eprintln!("BYTE-IDENTITY FAILURE: fabric-view metrics differ");
                bad = true;
            }
            if net.now() != serial.now() {
                eprintln!("BYTE-IDENTITY FAILURE: final clocks differ");
                bad = true;
            }
            if srep != rep {
                eprintln!("BYTE-IDENTITY FAILURE: SLO reports differ");
                bad = true;
            }
            if bad {
                std::process::exit(1);
            }
            println!("  byte-identity vs serial engine: OK");
            (rep, label)
        } else {
            (chaos::run(&mut net, &ccfg, k), label)
        }
    };
    println!(
        "chaos [{engine}, {preset:?}, comm {}] scenario {} seed {}:",
        ccfg.comm.name(),
        report.scenario,
        report.seed
    );
    if verbose {
        println!(
            "  delivered {}/{} msgs ({:.0} msg/s virtual), p50 {} ns, p99 {} ns",
            report.delivered,
            report.sent,
            report.throughput_msgs_per_s(),
            report.p50_ns,
            report.p99_ns
        );
        println!(
            "  reroute convergence {} ns, rx drops {}, sender stall {} ns",
            report.convergence_ns, report.dropped, report.stalled_ns
        );
    }
    report
}

/// One workload-chaos run (E14): the named workload rides the scenario
/// over the reliable transport on its own Card fabric.
fn run_chaos_workload(args: &Args, workload: &str, scenario: chaos::Scenario) {
    let w = workloads::ChaosWorkload::parse(workload).unwrap_or_else(|| {
        eprintln!("unknown workload {workload:?}; use learners | allreduce | mcts");
        std::process::exit(2);
    });
    if !workloads::WORKLOAD_SCENARIOS.contains(&scenario) {
        eprintln!(
            "workload chaos runs under storm | partition | drop, not {}",
            scenario.name()
        );
        std::process::exit(2);
    }
    let cfg = workloads::WorkloadChaosConfig::new(w, scenario, args.get("seed", 42u64));
    let (report, engine) = run_one_workload(&cfg, EngineArgs::parse(args, 1));
    println!(
        "chaos [{engine}] workload {} scenario {} seed {}:",
        report.workload, report.scenario, report.seed
    );
    println!(
        "  {}/{} units, {} replaced; retransmits {}, acks {}, dup-dropped {}, \
         peers down {}",
        report.delivered,
        report.expected,
        report.replaced,
        report.retransmits,
        report.acks,
        report.duplicates_dropped,
        report.peers_declared_down
    );
    if let Some(path) = args.get_opt("out") {
        std::fs::write(&path, report.to_json()).expect("write workload report");
        println!("  report -> {path}");
    }
    match report.violations().as_slice() {
        [] => println!("  verdict: PASS"),
        v => {
            for viol in v {
                eprintln!("  VIOLATION: {viol}");
            }
            std::process::exit(1);
        }
    }
}

/// Run one workload-chaos experiment on the requested engine.
fn run_one_workload(
    cfg: &workloads::WorkloadChaosConfig,
    eng: EngineArgs,
) -> (workloads::WorkloadReport, String) {
    if eng.serial() {
        let mut net = Network::new(cfg.system_config());
        (workloads::run_workload(&mut net, cfg, 1), "serial".to_string())
    } else {
        let mut net = eng.sharded(cfg.system_config());
        let label = eng.label(&net);
        let k = net.shard_count();
        (workloads::run_workload(&mut net, cfg, k), label)
    }
}

/// `repro chaos --scenario all` — the full E13+E14 sweep: every
/// background scenario, then every workload x scenario pairing, folded
/// into one combined JSON report (`--out`); exits non-zero if any run
/// violates its SLO.
fn run_chaos_all(args: &Args) {
    let seed = args.get("seed", 42u64);
    let eng = EngineArgs::parse(args, 1);
    let mut jsons: Vec<String> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    for sc in chaos::Scenario::ALL {
        let report = run_background_scenario(args, sc, false);
        for v in report.violations() {
            failures.push(format!("{}: {v}", sc.name()));
        }
        println!("  {}", if report.passed() { "PASS" } else { "FAIL" });
        jsons.push(report.to_json().trim_end().to_string());
    }
    for w in workloads::ChaosWorkload::ALL {
        for sc in workloads::WORKLOAD_SCENARIOS {
            let cfg = workloads::WorkloadChaosConfig::new(w, sc, seed);
            let (report, engine) = run_one_workload(&cfg, eng);
            let label = format!("{}/{}", report.workload, report.scenario);
            println!(
                "chaos [{engine}] workload {} seed {}: {}",
                label,
                seed,
                if report.passed() { "PASS" } else { "FAIL" }
            );
            for v in report.violations() {
                failures.push(format!("{label}: {v}"));
            }
            jsons.push(report.to_json().trim_end().to_string());
        }
    }
    let combined = format!(
        "{{\n\"runs\": [\n{}\n],\n\"passed\": {}\n}}\n",
        jsons.join(",\n"),
        failures.is_empty()
    );
    if let Some(path) = args.get_opt("out") {
        std::fs::write(&path, &combined).expect("write combined chaos report");
        println!("combined report -> {path}");
    }
    if failures.is_empty() {
        println!("chaos sweep: {} runs, all PASS", jsons.len());
    } else {
        for f in &failures {
            eprintln!("VIOLATION: {f}");
        }
        eprintln!(
            "chaos sweep: {} violation(s) across {} runs",
            failures.len(),
            jsons.len()
        );
        std::process::exit(1);
    }
}

fn run_learners(
    preset: SystemPreset,
    eng: EngineArgs,
    comm: CommMode,
    reliable: Option<ReliableParams>,
) {
    // Spread the learner grid across the whole mesh so cards/cages (and
    // shard boundaries) sit between neighbors.
    let nn = preset.node_count() as usize;
    let cfg = learners::LearnerConfig {
        stride: (nn / 27).max(1),
        comm,
        reliable,
        ..learners::LearnerConfig::default()
    };
    let (streamed, aggregated, engine) = if eng.serial() {
        let f = move || Network::new(SystemConfig::new(preset));
        let (s, a) = learners::overlap_advantage(f, cfg);
        (s, a, "serial".to_string())
    } else {
        let f = move || eng.sharded(SystemConfig::new(preset));
        let (s, a) = learners::overlap_advantage(f, cfg);
        let label =
            if eng.optimistic { "sharded (optimistic)" } else { "sharded" }.to_string();
        (s, a, label)
    };
    println!(
        "distributed learners [{engine}, comm {}{}], {} outputs/step/node of {} B:",
        comm.name(),
        if reliable.is_some() { ", reliable" } else { "" },
        cfg.outputs_per_step,
        cfg.record_bytes
    );
    println!("  send-as-generated             : {:>9.1} µs/step", streamed / 1000.0);
    println!("  aggregate-then-send           : {:>9.1} µs/step", aggregated / 1000.0);
    println!("  overlap advantage             : {:>9.2}x", aggregated / streamed);
}
