//! Slab-backed packet arena: in-flight [`Packet`]s live here, events
//! carry a 4-byte [`PacketRef`] handle.
//!
//! A `Packet` is ~100 bytes. The seed carried it *inside* every
//! `Event`, so each heap sift (and every event move) copied the whole
//! thing; with the timing wheel the event core moves events by value
//! too, so the payload had to leave the event. The arena gives each
//! in-flight packet a stable slot: `alloc` on injection (or per
//! broadcast/multicast copy), `free` at the terminal delivery point,
//! with freed slots recycled through a free list — steady-state traffic
//! performs zero packet allocations after warm-up.
//!
//! Handles are deliberately *not* generation-checked: the fabric's
//! event flow hands each ref to exactly one consumer (the type system
//! can't prove it, but the event graph is linear — every `alloc` has
//! one matching `free`). `get`/`free` panic on a stale ref, which turns
//! a lifecycle bug into a loud failure instead of aliased state.

use crate::router::Packet;

/// Handle to a packet slot in the [`PacketArena`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PacketRef(u32);

impl PacketRef {
    /// Raw slot index (diagnostics only).
    #[inline]
    pub fn index(self) -> u32 {
        self.0
    }
}

/// Slab of in-flight packets with slot recycling.
#[derive(Debug, Clone, Default)]
pub struct PacketArena {
    slots: Vec<Option<Packet>>,
    free: Vec<u32>,
    live: usize,
    high_water: usize,
}

impl PacketArena {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        PacketArena {
            slots: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
            live: 0,
            high_water: 0,
        }
    }

    /// Store `packet`, returning its handle.
    #[inline]
    pub fn alloc(&mut self, packet: Packet) -> PacketRef {
        self.live += 1;
        self.high_water = self.high_water.max(self.live);
        match self.free.pop() {
            Some(i) => {
                debug_assert!(self.slots[i as usize].is_none());
                self.slots[i as usize] = Some(packet);
                PacketRef(i)
            }
            None => {
                let i = self.slots.len() as u32;
                self.slots.push(Some(packet));
                PacketRef(i)
            }
        }
    }

    /// Borrow the packet behind `r`. Panics on a stale ref.
    #[inline]
    pub fn get(&self, r: PacketRef) -> &Packet {
        self.slots[r.0 as usize].as_ref().expect("stale PacketRef")
    }

    /// Mutably borrow the packet behind `r`. Panics on a stale ref.
    #[inline]
    pub fn get_mut(&mut self, r: PacketRef) -> &mut Packet {
        self.slots[r.0 as usize].as_mut().expect("stale PacketRef")
    }

    /// Take the packet out, recycling its slot. Panics on a stale ref.
    #[inline]
    pub fn free(&mut self, r: PacketRef) -> Packet {
        let p = self.slots[r.0 as usize].take().expect("stale PacketRef (double free?)");
        self.free.push(r.0);
        self.live -= 1;
        p
    }

    /// Packets currently in flight.
    #[inline]
    pub fn live(&self) -> usize {
        self.live
    }

    /// Most packets ever simultaneously in flight (capacity diagnostics;
    /// also the arena's resident slot count, since slots never shrink).
    #[inline]
    pub fn high_water(&self) -> usize {
        self.high_water
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::{Payload, Proto, RouteKind};
    use crate::topology::NodeId;

    fn pkt(id: u64) -> Packet {
        Packet::new(
            id,
            NodeId(0),
            NodeId(1),
            RouteKind::Directed,
            Proto::Raw { tag: 0 },
            Payload::Empty,
            0,
        )
    }

    #[test]
    fn alloc_get_free_roundtrip() {
        let mut a = PacketArena::new();
        let r1 = a.alloc(pkt(1));
        let r2 = a.alloc(pkt(2));
        assert_eq!(a.get(r1).id, 1);
        assert_eq!(a.get(r2).id, 2);
        assert_eq!(a.live(), 2);
        assert_eq!(a.free(r1).id, 1);
        assert_eq!(a.live(), 1);
        // Slot is recycled, handle stays unique to the new packet.
        let r3 = a.alloc(pkt(3));
        assert_eq!(r3.index(), r1.index());
        assert_eq!(a.get(r3).id, 3);
        assert_eq!(a.high_water(), 2);
    }

    #[test]
    #[should_panic(expected = "stale PacketRef")]
    fn double_free_is_loud() {
        let mut a = PacketArena::new();
        let r = a.alloc(pkt(9));
        a.free(r);
        a.free(r);
    }

    #[test]
    fn get_mut_edits_in_place() {
        let mut a = PacketArena::new();
        let r = a.alloc(pkt(5));
        a.get_mut(r).hops = 7;
        assert_eq!(a.get(r).hops, 7);
    }
}
