//! The assembled INC fabric: nodes × routers × links + virtual channels.
//!
//! [`Network`] owns all dynamic state (link occupancy/credits, per-node
//! registers and DRAM, channel endpoints) plus the event queue, and is
//! driven by [`Network::run_until`] / [`Network::run_to_quiescence`].
//! Workloads react to traffic through the [`App`] trait; every channel
//! also buffers delivered data in inboxes that can be read after a run,
//! so simple drivers need no callbacks at all. Drivers and workloads
//! that should run on *either* engine — this serial one or the
//! bounded-lag parallel [`sharded::ShardedNetwork`] — are written
//! against the [`Fabric`] trait instead of a concrete engine.
//!
//! # Hot-path layout
//!
//! The event core moves [`Event`]s by value, so the enum is kept to
//! ≤ 32 bytes (16 in practice; asserted by `event_size_budget`):
//! packets ride in the [`arena::PacketArena`] behind a 4-byte
//! [`arena::PacketRef`], Ethernet frames and Postmaster records are
//! boxed (they only cross the queue once per delivery), and Bridge-FIFO
//! word bursts are `Arc`-shared. Broadcast/multicast fan-out clones the
//! ~100-byte packet header per copy but shares the payload bytes
//! through `Arc` — a 2 KB broadcast at INC-3000 scale moves zero
//! payload bytes per hop. In-flight Ethernet frames ride inside their
//! packet (`Packet::eth_frame`, boxed) so they follow the packet across
//! shard boundaries; the remaining side tables (`tunnel_results`,
//! channel endpoint maps) use deterministic
//! [`crate::util::FxHashMap`]s: no SipHash on the per-packet path, no
//! per-process seed.

//! # Dispatch-order independence
//!
//! Nothing in the fabric depends on *when* an event was scheduled, only
//! on what it is:
//!
//! * every fabric event is pushed with a **content key** (event kind +
//!   link/packet/node identity, see the `key_*` helpers), so
//!   same-instant events dispatch in a content-determined order;
//! * adaptive-routing tie-breaks hash the packet's identity
//!   ([`crate::util::mix64`]) instead of drawing from an RNG stream;
//! * the seeded loss model
//!   ([`crate::config::SystemConfig::drop_probability`]) decides each
//!   drop as a pure hash of (seed, packet id, link) — again no RNG
//!   stream, so serial and sharded engines lose exactly the same
//!   transmissions;
//! * packet ids are assigned at the driver API (or derived from the
//!   originating packet, e.g. NetTunnel replies), never from a global
//!   counter inside an event handler. Traffic that [`App`] callbacks
//!   originate *is* produced inside event handlers, so it draws from
//!   **per-node** id counters instead ([`Network::app_packet_id`]):
//!   node `n`'s k-th app-originated packet has the same id in every
//!   engine, because `n`'s delivery sequence — and therefore its send
//!   sequence — is itself byte-identical across engines.
//!
//! Together these make the per-cage parallel engine ([`sharded`])
//! byte-identical to this serial one — the serial engine stays the
//! oracle the sharded engine is differential-tested against
//! (`tests/sharded_differential.rs`).

pub mod arena;
pub mod domain;
pub mod fabric;
pub mod sharded;
pub mod timewarp;

pub use domain::Domain;
pub use fabric::{Fabric, ShardableApp};

use std::sync::Arc;

use crate::channels::bridge_fifo::BridgeFifoFabric;
use crate::channels::endpoint::{CommState, Endpoint, Message};
use crate::channels::ethernet::{EthFrame, EthernetFabric};
use crate::channels::postmaster::{PmRecord, PostmasterFabric};
use crate::config::SystemConfig;
use crate::link::LinkState;
use crate::metrics::Metrics;
use crate::node::NodeState;
use crate::router::{
    broadcast_forwards, pick_adaptive, productive_links_buf, Packet, Payload, Proto, RouteKind,
    ZMode,
};
use crate::sim::{Sim, Time};
use crate::topology::{LinkId, NodeId, Topology};
use crate::util::{mix64, FxHashMap};

use arena::{PacketArena, PacketRef};

// ---------------------------------------------------------------------
// Event content keys: same-instant dispatch order (see module docs and
// `sim::queue`). Layout: 4-bit event-kind tag in the top bits, entity
// identity (link id / packet id / node) below. Two events can only share
// a `(time, key)` pair when their handlers commute (equal-key ties fall
// back to per-engine insertion order, which serial and sharded runs are
// free to disagree on).
// ---------------------------------------------------------------------

const KEY_ENTITY_BITS: u32 = 56;
const KEY_ENTITY_MASK: u64 = (1 << KEY_ENTITY_BITS) - 1;

#[inline]
fn ekey(tag: u64, entity: u64) -> u64 {
    (tag << KEY_ENTITY_BITS) | (entity & KEY_ENTITY_MASK)
}

#[inline]
pub(crate) fn key_inject(packet_id: u64) -> u64 {
    ekey(1, packet_id)
}
#[inline]
pub(crate) fn key_arrive(link: LinkId) -> u64 {
    ekey(2, link.0 as u64)
}
#[inline]
pub(crate) fn key_drain(link: LinkId) -> u64 {
    ekey(3, link.0 as u64)
}
#[inline]
pub(crate) fn key_credit(link: LinkId) -> u64 {
    ekey(4, link.0 as u64)
}
#[inline]
pub(crate) fn key_fifo_rx(packet_id: u64) -> u64 {
    ekey(5, packet_id)
}
#[inline]
pub(crate) fn key_fifo_local(node: NodeId, channel: u8) -> u64 {
    ekey(6, (node.0 as u64) << 8 | channel as u64)
}
#[inline]
pub(crate) fn key_pm_rx(node: NodeId, queue: u8) -> u64 {
    ekey(7, (node.0 as u64) << 8 | queue as u64)
}
#[inline]
pub(crate) fn key_eth(node: NodeId) -> u64 {
    ekey(8, node.0 as u64)
}
#[inline]
pub(crate) fn key_tunnel(packet_id: u64) -> u64 {
    ekey(9, packet_id)
}
#[inline]
pub(crate) fn key_timer(node: NodeId, tag: u64) -> u64 {
    // The tag is truncated to the key's entity space; two timers at the
    // same (node, instant) whose tags collide mod 2^24 fall back to
    // insertion order, which is the schedule order at the owning node —
    // identical in serial and sharded runs.
    ekey(10, (node.0 as u64) << 24 | (tag & 0xFF_FFFF))
}

// ---------------------------------------------------------------------
// App-originated packet ids ([`Network::app_packet_id`]): drawn from
// per-node counters so they are reproducible inside event handlers,
// where the global driver counter would depend on cross-node dispatch
// interleaving the sharded engine does not share with the serial one.
// Layout: bit 61 marks the app id space; bit 55 is the marker that
// survives the 56-bit event-key truncation (driver ids and NetTunnel
// request/reply ids are far below 2^55, so truncated keys never
// collide across spaces); node in bits 39..55, per-node seq below.
// ---------------------------------------------------------------------

const APP_ID_SPACE: u64 = 1 << 61;
const APP_ID_KEY_MARK: u64 = 1 << 55;
const APP_ID_NODE_SHIFT: u32 = 39;
const APP_ID_SEQ_MASK: u64 = (1 << APP_ID_NODE_SHIFT) - 1;

/// One line of the delivery trace: a packet reaching its destination's
/// Packet Demux. The derived `Ord` (time, node, packet, …) is the
/// canonical order traces are compared in — within one instant,
/// deliveries at distinct nodes are causally independent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Delivery {
    pub time: Time,
    pub node: u32,
    pub packet: u64,
    /// Discriminant of the packet's [`Proto`].
    pub proto: u8,
    pub wire_bytes: u32,
}

pub(crate) fn proto_tag(p: Proto) -> u8 {
    match p {
        Proto::Ethernet => 0,
        Proto::Postmaster { .. } => 1,
        Proto::BridgeFifo { .. } => 2,
        Proto::NetTunnel => 3,
        Proto::Boot => 4,
        Proto::Raw { .. } => 5,
    }
}

/// An event crossing a shard boundary (see [`sharded`]): the owning
/// shard of a link's transmit side differs from the owner of its
/// receive side, so `Arrive`s travel forward and `Credit`s travel back.
/// Packets move *by value* between per-shard arenas.
#[derive(Debug, Clone)]
pub(crate) enum BoundaryEvent {
    Arrive { link: LinkId, packet: Packet },
    Credit { link: LinkId, bytes: u32 },
}

/// A boundary event plus its absolute dispatch time.
#[derive(Debug, Clone)]
pub(crate) struct BoundaryMsg {
    pub at: Time,
    pub ev: BoundaryEvent,
}

/// Shard identity of a `Network` acting as one shard of a
/// [`sharded::ShardedNetwork`] (`None` for the ordinary serial engine).
#[derive(Debug, Clone)]
pub(crate) struct ShardCtx {
    /// This shard's index.
    pub shard: u32,
    /// Owner shard per node (shared, read-only).
    pub owner: Arc<Vec<u32>>,
    /// Cross-boundary events generated this window, as (destination
    /// shard, message), in generation order.
    pub outbox: Vec<(u32, BoundaryMsg)>,
}

/// Events dispatched by the fabric. Kept ≤ 32 bytes — see module docs.
#[derive(Debug, Clone)]
pub enum Event {
    /// Packet enters the source node's router (after injection overhead).
    Inject { packet: PacketRef },
    /// Packet fully received at the downstream end of `link`.
    Arrive { link: LinkId, packet: PacketRef },
    /// `link` may be able to transmit a queued packet now.
    Drain { link: LinkId },
    /// Receiver of `link` freed buffer space; credits return to its tx.
    Credit { link: LinkId, bytes: u32 },
    /// Bridge-FIFO receive logic finished for a packet (§3.3).
    FifoRx { node: NodeId, packet: PacketRef },
    /// Local (same-node) Bridge-FIFO delivery, bypassing the network.
    FifoLocal { node: NodeId, channel: u8, words: std::sync::Arc<Vec<u64>> },
    /// Postmaster target DMA completed for one record (§3.2).
    PmRx { node: NodeId, queue: u8, record: Box<PmRecord> },
    /// Ethernet frame DMA'd into destination DRAM; notify driver (§3.1).
    EthRx { node: NodeId, frame: Box<EthFrame> },
    /// Ethernet driver polling tick.
    EthPoll { node: NodeId },
    /// Ethernet frame ready for injection after tx-side software costs.
    EthTx { frame: Box<EthFrame> },
    /// NetTunnel / diagnostic register access executed at `node`.
    TunnelExec { node: NodeId, packet: PacketRef },
    /// Application timer ([`Network::timer_at`]).
    Timer { node: NodeId, tag: u64 },
}

/// Workload hook points. All methods have default empty bodies; override
/// the ones the workload cares about. Delivered data is *also* available
/// from channel inboxes after a run.
///
/// Mode-generic workloads need only [`App::on_message`]: it fires for
/// every complete [`Message`] arriving on an open [`Endpoint`],
/// whichever [`crate::channels::CommMode`] carries it. The
/// per-channel callbacks remain for code bound to one channel's native
/// units (frames, records, words).
///
/// # Per-node contract
///
/// Every callback names the node it fires at, and on the sharded engine
/// it runs on the partition owning that node (see [`ShardableApp`]).
/// Code inside a callback must therefore:
///
/// * mutate only state attributable to that node (or reduced
///   commutatively at the end of the run — see
///   [`ShardableApp::reduce`]);
/// * originate new traffic only *from* that node, and only through the
///   app-context send APIs — the Endpoint sends
///   ([`Network::send`] / [`Network::send_at`]) or a raw
///   [`Network::inject`] with a [`Network::app_packet_id`] id: the
///   global-counter driver APIs ([`Network::send_directed`] etc.) panic
///   inside callbacks on the sharded engine, where the global cursor is
///   not coherent mid-run.
#[allow(unused_variables)]
pub trait App {
    /// Any packet reached its destination's Packet Demux (all
    /// protocols; fires before the per-protocol handler, so channel
    /// logic delays have *not* elapsed yet). `d` is exactly the line
    /// the delivery tracer would record.
    fn on_deliver(&mut self, net: &mut Network, node: NodeId, d: &Delivery) {}
    /// A directed/broadcast `Proto::Raw` packet arrived at `node`.
    fn on_raw(&mut self, net: &mut Network, node: NodeId, packet: &Packet) {}
    /// Words became readable on a Bridge-FIFO read port.
    fn on_fifo(&mut self, net: &mut Network, node: NodeId, channel: u8, words: &[u64]) {}
    /// A Postmaster record landed in `node`'s receive stream.
    fn on_postmaster(&mut self, net: &mut Network, node: NodeId, queue: u8, rec: &PmRecord) {}
    /// An internal-Ethernet frame was handed to the kernel at `node`.
    fn on_eth(&mut self, net: &mut Network, node: NodeId, frame: &EthFrame) {}
    /// An application timer fired ([`Network::timer_at`]).
    fn on_timer(&mut self, net: &mut Network, node: NodeId, tag: u64) {}
    /// A complete [`Message`] arrived on the open endpoint `ep`
    /// (fires after the channel's native callback; `msg.from` is the
    /// sender). The mode-generic hook every endpoint workload uses.
    ///
    /// The return value is the **consumed flag**: return `true` and the
    /// message is done — it never enters the endpoint's recv inbox, so
    /// callback-driven apps no longer drain [`Network::recv`] per
    /// callback to keep the inbox from growing. The default `false`
    /// keeps the inbox-driven contract: the message is queued for
    /// [`Network::recv`] after the callback returns (during the
    /// callback the message is *not* yet in the inbox).
    fn on_message(&mut self, net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
        false
    }
    /// The reliable transport at `ep` declared `peer` down: its retry
    /// budget was exhausted or its heartbeat silence crossed the
    /// liveness threshold ([`crate::channels::reliable`]). Fires once
    /// per (endpoint, peer), at `ep.node`, under the usual per-node
    /// contract. Undelivered payloads are available for re-placement
    /// via [`Network::reliable_take_unacked`].
    fn on_peer_down(&mut self, net: &mut Network, ep: Endpoint, peer: NodeId) {}
}

/// An [`App`] that does nothing (inbox-driven workloads).
#[derive(Clone)]
pub struct NullApp;
impl App for NullApp {}

/// The assembled system.
///
/// `Clone` deep-copies the entire simulation state — clock, wheel,
/// arena, node/link/channel state, metrics — except the immutable
/// `Arc`-shared pieces (topology, domain, owner map). A clone is an
/// exact checkpoint: resuming it replays the identical event sequence.
/// The optimistic engine ([`timewarp`]) is built on this.
#[derive(Clone)]
pub struct Network {
    pub cfg: SystemConfig,
    /// Static topology, shared read-only (shards of a
    /// [`sharded::ShardedNetwork`] all reference one instance).
    pub topo: Arc<Topology>,
    /// Which slice of the mesh this engine holds state for, and the
    /// global↔local index maps for it: the identity full-mesh domain on
    /// the serial engine, a dense owned-subset domain per shard of a
    /// sharded run. `links`/`failed_links`/`nodes`/`app_seq` and the
    /// per-node NIC ports are all **domain-indexed** — a k-shard run
    /// holds ~1/k of the mesh state per shard instead of k full copies.
    pub domain: Arc<Domain>,
    /// Transmit-side link state, indexed by [`Domain::link_index`].
    pub links: Vec<LinkState>,
    pub sim: Sim<Event>,
    pub metrics: Metrics,
    /// Per-node state, indexed by [`Domain::node_index`] (prefer
    /// [`Network::node`] / [`Network::node_mut`]).
    pub nodes: Vec<NodeState>,
    pub fifos: BridgeFifoFabric,
    pub postmaster: PostmasterFabric,
    pub eth: EthernetFabric,
    /// In-flight packet storage; events reference it by [`PacketRef`].
    pub packets: PacketArena,
    /// NetTunnel read results, keyed by request id.
    pub tunnel_results: FxHashMap<u64, u64>,
    /// Links marked defective (§2.4 "network defect avoidance"),
    /// indexed by [`Domain::link_index`]. Routing only ever consults a
    /// link's failure flag at its transmit node, so the owned-subset
    /// slice is complete for a shard.
    pub failed_links: Vec<bool>,
    /// Delivery trace ([`Network::enable_trace`]): every packet handed
    /// to a destination Packet Demux. Off by default (hot-path lean).
    pub trace: Option<Vec<Delivery>>,
    /// Endpoint-layer state (open lanes, inboxes, reassembly; see
    /// [`crate::channels::endpoint`]).
    pub(crate) comm: CommState,
    /// Reliable-transport state (flow windows, retransmit queues, peer
    /// liveness; see [`crate::channels::reliable`]). Like `comm`, every
    /// piece is keyed by the node that owns it.
    pub(crate) rel: crate::channels::reliable::ReliableState,
    /// Set when this `Network` is one shard of a sharded run.
    pub(crate) shard_ctx: Option<ShardCtx>,
    /// Per-node counters behind [`Network::app_packet_id`]
    /// (domain-indexed).
    app_seq: Vec<u64>,
    /// True while an [`App`] callback is on the stack (enforces the
    /// app-context send contract on sharded shards).
    in_app: bool,
    next_packet_id: u64,
}

impl Network {
    pub fn new(cfg: SystemConfig) -> Self {
        let topo = Arc::new(Topology::preset(cfg.preset));
        Self::with_topology(cfg, topo)
    }

    /// Build a network over an existing (shared) topology with the
    /// full-mesh identity [`Domain`]. Used wherever a single engine
    /// simulates the whole mesh.
    pub fn with_topology(cfg: SystemConfig, topo: Arc<Topology>) -> Self {
        let domain = Arc::new(Domain::full(&topo));
        Self::with_domain(cfg, topo, domain)
    }

    /// Build a network holding state for exactly `domain`'s slice of
    /// the mesh. The sharded engine passes each shard its owned-subset
    /// domain; every state vector is sized by the domain's local counts
    /// (nothing full-mesh is allocated).
    pub(crate) fn with_domain(
        cfg: SystemConfig,
        topo: Arc<Topology>,
        domain: Arc<Domain>,
    ) -> Self {
        assert_eq!(
            topo.dims(),
            cfg.preset.dims(),
            "topology does not match the config preset"
        );
        let links = (0..domain.link_count()).map(|_| LinkState::new(&cfg.link)).collect();
        let nodes = (0..domain.node_count())
            .map(|i| NodeState::new(domain.node_at(i), &cfg))
            .collect();
        let mut net = Network {
            topo,
            links,
            sim: Sim::new(),
            metrics: Metrics::new(),
            nodes,
            fifos: BridgeFifoFabric::new(domain.node_count()),
            postmaster: PostmasterFabric::new(domain.node_count()),
            eth: EthernetFabric::new(domain.clone(), &cfg),
            packets: PacketArena::with_capacity(1024),
            tunnel_results: FxHashMap::default(),
            failed_links: vec![false; domain.link_count()],
            trace: None,
            comm: CommState::default(),
            rel: crate::channels::reliable::ReliableState::default(),
            shard_ctx: None,
            app_seq: vec![0; domain.node_count()],
            in_app: false,
            domain,
            cfg,
            next_packet_id: 0,
        };
        net.metrics.state_bytes = net.state_bytes();
        net
    }

    /// Resident bytes of the domain-sized dynamic state vectors (link
    /// state + failure flags, node state, NIC ports, app-id counters).
    /// An engine-level figure: the serial engine reports the full mesh,
    /// each shard its owned slice, and the slices sum to the serial
    /// value exactly (every node and link is owned once). The domain's
    /// own O(owned) index maps are *not* included — they are accounted
    /// separately by [`Domain::index_bytes`], which the `inc9000_domain`
    /// bench row reports alongside this. Tracked in
    /// [`Metrics::state_bytes`].
    pub fn state_bytes(&self) -> u64 {
        (self.links.len() * std::mem::size_of::<LinkState>()
            + self.failed_links.len() * std::mem::size_of::<bool>()
            + self.nodes.len() * std::mem::size_of::<NodeState>()
            + self.eth.ports.len()
                * std::mem::size_of::<crate::channels::ethernet::EthPort>()
            + self.app_seq.len() * std::mem::size_of::<u64>()) as u64
    }

    /// State of node `n` (domain-mapped; panics if this engine does not
    /// own `n` — see [`Domain::node_index`]).
    #[inline]
    pub fn node(&self, n: NodeId) -> &NodeState {
        &self.nodes[self.domain.node_index(n)]
    }

    /// Mutable state of node `n` (domain-mapped).
    #[inline]
    pub fn node_mut(&mut self, n: NodeId) -> &mut NodeState {
        let i = self.domain.node_index(n);
        &mut self.nodes[i]
    }

    /// Transmit-side state of link `l` (domain-mapped).
    #[inline]
    pub fn link_state(&self, l: LinkId) -> &LinkState {
        &self.links[self.domain.link_index(l)]
    }

    /// Mutable transmit-side state of link `l` (domain-mapped).
    #[inline]
    pub fn link_state_mut(&mut self, l: LinkId) -> &mut LinkState {
        let i = self.domain.link_index(l);
        &mut self.links[i]
    }

    pub fn card() -> Self {
        Self::new(SystemConfig::card())
    }

    pub fn inc3000() -> Self {
        Self::new(SystemConfig::inc3000())
    }

    #[inline]
    pub fn now(&self) -> Time {
        self.sim.now()
    }

    pub fn next_packet_id(&mut self) -> u64 {
        // On a shard of a sharded run the global cursor is only coherent
        // between runs (the wrapper APIs sync it around driver calls);
        // an App callback drawing from it would assign ids the serial
        // oracle never assigns. Fail loudly instead of diverging.
        assert!(
            !(self.in_app && self.shard_ctx.is_some()),
            "global packet-id counter used inside an App callback on a sharded \
             shard; use app_packet_id / the app-context send APIs instead"
        );
        let id = self.next_packet_id;
        self.next_packet_id += 1;
        id
    }

    /// Allocate a packet id for traffic originated *by an [`App`]
    /// callback at `node`* (or by engine-agnostic workload code that
    /// sends from a specific node). Drawn from a per-node counter, so
    /// the id depends only on the node's own send sequence — which is
    /// byte-identical across engines — never on global dispatch
    /// interleaving. The id space is disjoint from driver-assigned and
    /// NetTunnel-derived ids (see the module docs).
    pub fn app_packet_id(&mut self, node: NodeId) -> u64 {
        let i = self.domain.node_index(node);
        let seq = self.app_seq[i];
        self.app_seq[i] += 1;
        assert!(seq < APP_ID_SEQ_MASK, "app packet-id counter exhausted at {node}");
        APP_ID_SPACE | APP_ID_KEY_MARK | ((node.0 as u64) << APP_ID_NODE_SHIFT) | seq
    }

    /// Schedule an [`App::on_timer`] callback at `node` at absolute
    /// time `at`. Usable from driver context or from a callback at any
    /// node on the same shard; on the sharded engine the timer fires on
    /// the partition owning `node`.
    pub fn timer_at(&mut self, at: Time, node: NodeId, tag: u64) {
        self.debug_check_src_owned(node);
        self.sim.at_keyed(at, key_timer(node, tag), Event::Timer { node, tag });
    }

    /// Run `f` with the in-app flag raised (restores the previous value,
    /// so nested callback chains — e.g. a poll draining several frames —
    /// stay marked).
    #[inline]
    pub(crate) fn app_scope<R>(
        &mut self,
        app: &mut dyn App,
        f: impl FnOnce(&mut Network, &mut dyn App) -> R,
    ) -> R {
        let prev = self.in_app;
        self.in_app = true;
        let r = f(self, app);
        self.in_app = prev;
        r
    }

    /// Current value of the packet-id counter (not advancing it). The
    /// sharded engine keeps one global id space by syncing this cursor
    /// around driver calls, so ids match the serial engine exactly.
    pub fn packet_id_cursor(&self) -> u64 {
        self.next_packet_id
    }

    /// Set the packet-id counter (see [`Network::packet_id_cursor`]).
    pub fn set_packet_id_cursor(&mut self, v: u64) {
        self.next_packet_id = v;
    }

    /// Start recording the delivery trace (see [`Delivery`]).
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            self.trace = Some(Vec::new());
        }
    }

    /// Take the recorded delivery trace (empty if tracing is off).
    pub fn take_trace(&mut self) -> Vec<Delivery> {
        self.trace.take().unwrap_or_default()
    }

    /// Build and inject a directed packet from `src` (paying injection
    /// overhead). Returns the packet id.
    pub fn send_directed(
        &mut self,
        src: NodeId,
        dst: NodeId,
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        let id = self.next_packet_id();
        let pkt = Packet::new(id, src, dst, RouteKind::Directed, proto, payload, self.now());
        self.inject(pkt);
        id
    }

    /// Build and inject a broadcast packet from `src`.
    pub fn send_broadcast(&mut self, src: NodeId, proto: Proto, payload: Payload) -> u64 {
        let id = self.next_packet_id();
        let pkt = Packet::new(
            id,
            src,
            src,
            RouteKind::Broadcast { zmode: ZMode::Line },
            proto,
            payload,
            self.now(),
        );
        self.inject(pkt);
        id
    }

    /// Mark a link defective: directed/multicast routing avoids it
    /// (§2.4's "network defect avoidance" extension). On a shard, valid
    /// only for links whose transmit side the shard owns (the sharded
    /// wrapper routes here).
    pub fn fail_link(&mut self, l: LinkId) {
        let i = self.domain.link_index(l);
        self.failed_links[i] = true;
    }

    /// Bring a failed link back into service.
    pub fn repair_link(&mut self, l: LinkId) {
        let i = self.domain.link_index(l);
        self.failed_links[i] = false;
    }

    /// Spanning-tree multicast to `dsts` (§2.4 extension): shared path
    /// prefixes carry one copy. Returns the packet id.
    pub fn send_multicast(
        &mut self,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        assert!(!dsts.is_empty(), "multicast needs destinations");
        let id = self.next_packet_id();
        let mut pkt =
            Packet::new(id, src, src, RouteKind::Multicast, proto, payload, self.now());
        pkt.mcast = Some(std::sync::Arc::new(dsts.to_vec()));
        self.inject(pkt);
        id
    }

    /// Spanning-tree multicast originated *by an [`App`] callback at
    /// `src`* (or by engine-agnostic workload code sending from a
    /// specific node), produced at absolute time `at ≥ now`. The packet
    /// id comes from the per-node app id space ([`Network::app_packet_id`]),
    /// so both engines assign identical ids regardless of dispatch
    /// interleaving; injection latency and metrics are accounted exactly
    /// like [`Network::inject`]. This is how the SNN workload fans a
    /// spike out to its axon targets from inside `on_timer`. Returns the
    /// packet id.
    pub fn app_multicast_at(
        &mut self,
        at: Time,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        assert!(!dsts.is_empty(), "multicast needs destinations");
        let id = self.app_packet_id(src);
        let mut pkt = Packet::new(id, src, src, RouteKind::Multicast, proto, payload, at);
        pkt.mcast = Some(std::sync::Arc::new(dsts.to_vec()));
        self.metrics.packets_injected += 1;
        let inject = self.cfg.link.inject_latency;
        self.inject_at(at + inject, pkt);
        id
    }

    /// Inject an already-built packet at its source node.
    pub fn inject(&mut self, packet: Packet) {
        self.debug_check_src_owned(packet.src);
        self.metrics.packets_injected += 1;
        let delay = self.cfg.link.inject_latency;
        let key = key_inject(packet.id);
        let packet = self.packets.alloc(packet);
        self.sim.after_keyed(delay, key, Event::Inject { packet });
    }

    /// Schedule an already-built packet to enter the fabric at absolute
    /// time `at` (deferred-production workloads; the caller accounts
    /// metrics and any software costs itself).
    pub fn inject_at(&mut self, at: Time, packet: Packet) {
        self.debug_check_src_owned(packet.src);
        let key = key_inject(packet.id);
        let packet = self.packets.alloc(packet);
        self.sim.at_keyed(at, key, Event::Inject { packet });
    }

    /// A shard may only originate traffic from nodes it owns — anything
    /// else would schedule the injection on the wrong event wheel (and,
    /// since the domain refactor, index state the shard does not hold).
    /// App callbacks satisfy this by sending only from their callback
    /// node. Release builds stay loud too: the first domain-mapped
    /// state access for an un-owned source panics out of bounds.
    #[inline]
    fn debug_check_src_owned(&self, src: NodeId) {
        debug_assert!(
            self.domain.owns_node(src),
            "traffic originated from {src}, which shard {} does not own",
            self.domain.shard()
        );
    }

    /// Run until the event queue empties or `deadline` passes. Returns
    /// the number of events dispatched.
    pub fn run_until(&mut self, app: &mut dyn App, deadline: Time) -> u64 {
        let start = self.sim.dispatched();
        while let Some((_, ev)) = self.sim.pop_until(deadline) {
            self.handle(ev, app);
        }
        if self.sim.peek_time().map_or(true, |t| t > deadline) && self.sim.now() < deadline {
            self.sim.advance_to(deadline);
        }
        self.sim.dispatched() - start
    }

    /// Run until no events remain.
    pub fn run_to_quiescence(&mut self, app: &mut dyn App) -> u64 {
        let start = self.sim.dispatched();
        while let Some((_, ev)) = self.sim.pop() {
            self.handle(ev, app);
        }
        self.sim.dispatched() - start
    }

    /// Dispatch everything scheduled at or before `deadline` without
    /// advancing the clock past the last event (unlike
    /// [`Network::run_until`], which advances to the deadline). The
    /// sharded engine's bounded-lag window runner: the final clock is
    /// the last *event* time, matching the serial engine's quiescence
    /// clock.
    pub fn run_window(&mut self, app: &mut dyn App, deadline: Time) -> u64 {
        let start = self.sim.dispatched();
        while let Some((_, ev)) = self.sim.pop_until(deadline) {
            self.handle(ev, app);
        }
        self.sim.dispatched() - start
    }

    /// Dispatch events at or before `deadline`, shrinking the deadline
    /// as boundary messages are exported (exports stay in the outbox
    /// for the caller). The sharded engine's distance-aware epoch
    /// batching uses this to let a shard whose horizon clears the
    /// lockstep window sprint through many windows without barriers.
    ///
    /// An export does **not** end the sprint outright: every other
    /// shard's horizon already accounts for it (the export to shard `d`
    /// arrives no earlier than this shard's published peek plus the
    /// pair lookahead, which is exactly what their horizons assumed).
    /// The only party whose horizon misses it is *this* shard — the
    /// export could bounce back and influence us no earlier than its
    /// arrival time plus the return-trip lookahead. So each export to
    /// shard `d` at time `t` clamps the remaining sprint to
    /// `t + comeback[d] − 1`, where `comeback[d]` is the d→self pair
    /// lookahead, and the sprint continues on the export's own
    /// timestamp instead of dying at its first boundary crossing. On
    /// the serial engine (no shard context) the outbox never fills, so
    /// this equals [`Network::run_window`].
    pub(crate) fn run_exclusive(
        &mut self,
        app: &mut dyn App,
        mut deadline: Time,
        comeback: &[u64],
    ) -> u64 {
        let start = self.sim.dispatched();
        let mut seen = 0usize;
        while let Some((_, ev)) = self.sim.pop_until(deadline) {
            self.handle(ev, app);
            if let Some(ctx) = self.shard_ctx.as_ref() {
                while seen < ctx.outbox.len() {
                    let (dst, ref msg) = ctx.outbox[seen];
                    seen += 1;
                    let bounce = msg
                        .at
                        .saturating_add(comeback[dst as usize])
                        .saturating_sub(1);
                    deadline = deadline.min(bounce);
                }
            }
        }
        self.sim.dispatched() - start
    }

    /// The node whose state the head event will touch, when — and only
    /// when — its handler provably cannot reach application code.
    /// Per-node horizon bounds hinge on this: an app callback may call
    /// `timer_at` (or send) *at another owned node*, creating
    /// same-instant cross-node influence, so a head event that can run
    /// an app handler pins the bound to the whole-shard pair distance.
    /// Drain and Credit events touch only `LinkState` at the link's
    /// source router and never call into the app, so their influence
    /// radiates from that one node and a peer shard may safely use the
    /// (longer) node-to-shard distance instead. Everything else returns
    /// `None`.
    pub(crate) fn head_bound_node(&self) -> Option<NodeId> {
        let (_, key) = self.sim.peek_head()?;
        match key >> KEY_ENTITY_BITS {
            3 | 4 => {
                let link = LinkId((key & KEY_ENTITY_MASK) as u32);
                Some(self.topo.link(link).src)
            }
            _ => None,
        }
    }

    fn handle(&mut self, ev: Event, app: &mut dyn App) {
        match ev {
            Event::Inject { packet } => {
                let src = self.packets.get(packet).src;
                self.route_from(src, packet, None, app)
            }
            Event::Arrive { link, packet } => self.arrive(link, packet, app),
            Event::Drain { link } => {
                self.link_state_mut(link).disarm_drain();
                self.drain(link)
            }
            Event::Credit { link, bytes } => {
                let cap = self.cfg.link.credit_buffer_bytes;
                self.link_state_mut(link).grant(bytes, cap);
                self.drain(link);
            }
            Event::FifoRx { node, packet } => {
                let pkt = self.packets.free(packet);
                self.fifo_rx(node, pkt, app)
            }
            Event::FifoLocal { node, channel, words } => {
                self.fifo_local_rx(node, channel, &words, app)
            }
            Event::PmRx { node, queue, record } => self.pm_rx(node, queue, *record, app),
            Event::EthRx { node, frame } => self.eth_rx(node, *frame, app),
            Event::EthPoll { node } => self.eth_poll(node, app),
            Event::EthTx { frame } => self.eth_tx_inject(*frame),
            Event::TunnelExec { node, packet } => {
                let pkt = self.packets.free(packet);
                self.tunnel_exec(node, pkt, app)
            }
            Event::Timer { node, tag } => {
                // Reliable-transport timers (retransmit / heartbeat) are
                // fabric protocol machinery, not app timers: they carry a
                // reserved tag mark and are handled by the transport —
                // which may surface `on_peer_down` to the app.
                if tag & crate::channels::reliable::RELIABLE_TIMER_MARK != 0 {
                    self.reliable_timer(node, tag, app)
                } else {
                    self.app_scope(app, |net, app| app.on_timer(net, node, tag))
                }
            }
        }
    }

    /// A packet is at `here`'s router; forward it (or deliver).
    ///
    /// `arrived_via` is the link it came in on (None right after
    /// injection at the source).
    fn route_from(
        &mut self,
        here: NodeId,
        packet: PacketRef,
        arrived_via: Option<LinkId>,
        app: &mut dyn App,
    ) {
        let (route, dst, src, id, wire_bytes, hops) = {
            let p = self.packets.get(packet);
            (p.route, p.dst, p.src, p.id, p.wire_bytes, p.hops)
        };
        match route {
            RouteKind::Directed => {
                if here == dst {
                    self.deliver(here, packet, app);
                    return;
                }
                let mut buf = [crate::topology::LinkId(0); 6];
                let n = productive_links_buf(&self.topo, here, dst, &mut buf);
                // Defect avoidance: drop failed links from the set. All
                // candidates leave `here`, which this engine owns, so
                // the domain-mapped lookups stay inside the owned slice.
                let domain = &self.domain;
                let failed = &self.failed_links;
                let mut live = [crate::topology::LinkId(0); 6];
                let mut m = 0;
                for &l in &buf[..n] {
                    if !failed[domain.link_index(l)] {
                        live[m] = l;
                        m += 1;
                    }
                }
                let now = self.now();
                let links = &self.links;
                // Tie-break hash over (seed, packet, node, hop): a pure
                // function of what is being routed — identical in serial
                // and sharded execution (see module docs).
                let tie = mix64(
                    self.cfg.seed
                        ^ id.wrapping_mul(0x9E3779B97F4A7C15)
                        ^ ((here.0 as u64) << 32)
                        ^ (hops as u64),
                );
                let chosen = if m > 0 {
                    pick_adaptive(
                        &live[..m],
                        |l| links[domain.link_index(l)].ready(now, wire_bytes),
                        |l| links[domain.link_index(l)].busy_until(),
                        tie,
                    )
                } else {
                    // Every minimal link is dead: lateral escape over any
                    // live link that gets closest to the destination.
                    self.topo
                        .out_links(here)
                        .iter()
                        .copied()
                        .filter(|&l| !failed[domain.link_index(l)])
                        .min_by_key(|&l| self.topo.min_hops(self.topo.link(l).dst, dst))
                };
                // Livelock guard (misrouting around defects is bounded).
                // Both this check and the no-live-out-link case below are
                // decided from `here`'s own hop counter and out-links —
                // never from remote state — so under `drop_unroutable`
                // serial and sharded engines drop the same packets at
                // the same instants (the sharded failure flags are
                // domain-sized; only local decisions are possible).
                let budget = 4 * self.topo.min_hops(src, dst) + 64;
                if hops > budget {
                    if self.cfg.drop_unroutable {
                        self.metrics.dropped += 1;
                        self.packets.free(packet);
                        return;
                    }
                    panic!("packet {id} exceeded hop budget (defect livelock?)");
                }
                if let Some(l) = chosen {
                    self.link_send(l, packet);
                } else if self.cfg.drop_unroutable {
                    self.metrics.dropped += 1;
                    self.packets.free(packet);
                } else {
                    panic!("node {here} fully disconnected; cannot route {id}");
                }
            }
            RouteKind::Multicast => {
                let dsts =
                    self.packets.get(packet).mcast.clone().expect("multicast without targets");
                let (domain, failed) = (&self.domain, &self.failed_links);
                let (local, groups) = crate::router::multicast::multicast_partition(
                    &self.topo,
                    here,
                    &dsts,
                    &|l| failed[domain.link_index(l)],
                );
                for (link, subset) in groups {
                    // Header copy per branch; payload bytes stay shared
                    // behind their Arc.
                    let mut copy = self.packets.get(packet).clone();
                    copy.mcast = Some(std::sync::Arc::new(subset));
                    let copy = self.packets.alloc(copy);
                    self.metrics.multicast_copies += 1;
                    self.link_send(link, copy);
                }
                if local {
                    self.deliver(here, packet, app);
                } else {
                    // Forwarded-only node: this ref's journey ends here.
                    self.packets.free(packet);
                }
            }
            RouteKind::Broadcast { .. } => {
                let arrived = arrived_via.map(|l| {
                    let info = self.topo.link(l);
                    let zmode = match route {
                        RouteKind::Broadcast { zmode } => zmode,
                        _ => unreachable!(),
                    };
                    (info.dir, info.span, zmode)
                });
                let fwd = broadcast_forwards(&self.topo, here, arrived);
                for (lid, rk) in fwd {
                    let mut copy = self.packets.get(packet).clone();
                    copy.route = rk;
                    let copy = self.packets.alloc(copy);
                    self.link_send(lid, copy);
                }
                // Every node (including the source) receives one copy.
                self.metrics.broadcast_copies += 1;
                self.deliver(here, packet, app);
            }
        }
    }

    /// Seeded per-transmission loss ([`SystemConfig::drop_probability`]):
    /// is this (packet, link) hand-off lost? A pure hash of (seed,
    /// packet id, link) — no RNG stream, no state — so serial and
    /// sharded engines lose exactly the same transmissions, and a
    /// retransmitted segment (a fresh packet id) re-rolls the dice.
    #[inline]
    fn lossy_drop(&self, link: LinkId, packet_id: u64) -> bool {
        let p = self.cfg.drop_probability;
        if p <= 0.0 {
            return false;
        }
        // `as` saturates: p = 1.0 maps to u64::MAX (drop everything).
        let threshold = (p * u64::MAX as f64) as u64;
        mix64(
            self.cfg.seed
                ^ 0xD6E8_FEB8_6659_FD93
                ^ packet_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ link.0 as u64,
        ) <= threshold
    }

    /// Transmit `packet` on `link` now, or queue it if busy/out of credit.
    fn link_send(&mut self, link: LinkId, packet: PacketRef) {
        let (wire_bytes, id) = {
            let p = self.packets.get(packet);
            (p.wire_bytes, p.id)
        };
        // Loss is decided when the packet is handed to the link — before
        // any credits, queue slots or wire time are consumed, so a lost
        // transmission costs the fabric nothing downstream and the
        // receive side simply never hears of it.
        if self.lossy_drop(link, id) {
            self.metrics.link_loss += 1;
            self.packets.free(packet);
            return;
        }
        let now = self.now();
        let li = self.domain.link_index(link);
        let st = &mut self.links[li];
        if st.ready(now, wire_bytes) {
            st.start_tx(now, wire_bytes, &self.cfg.link);
            let arrive_at = now + self.cfg.link.hop(wire_bytes);
            // Nothing queued behind this packet (`ready` required an
            // empty queue), so the unconditional end-of-serialization
            // `Drain` would be a no-op: suppress it. A later enqueue
            // while the link is busy arms the drain itself.
            self.metrics.drains_suppressed += 1;
            self.sched_arrive(link, packet, arrive_at);
        } else {
            let busy = st.busy_until() > now;
            st.enqueue(packet, wire_bytes);
            self.metrics.link_stalls += 1;
            // Busy link: wake when serialization finishes. (If the link
            // is idle but out of credits, the `Credit` handler drains
            // directly — no event needed.)
            if busy {
                let at = self.links[li].busy_until();
                if self.links[li].arm_drain() {
                    self.sim.at_keyed(at, key_drain(link), Event::Drain { link });
                }
            }
        }
    }

    /// Serialization of a queued packet becomes possible.
    fn drain(&mut self, link: LinkId) {
        let now = self.now();
        let li = self.domain.link_index(link);
        if let Some((packet, wire_bytes)) = self.links[li].pop_sendable(now) {
            let busy_until = self.links[li].start_tx(now, wire_bytes, &self.cfg.link);
            let arrive_at = now + self.cfg.link.hop(wire_bytes);
            if self.links[li].queue_len() > 0 {
                if self.links[li].arm_drain() {
                    self.sim.at_keyed(busy_until, key_drain(link), Event::Drain { link });
                }
            } else {
                self.metrics.drains_suppressed += 1;
            }
            self.sched_arrive(link, packet, arrive_at);
        }
    }

    /// Schedule (or, across a shard boundary, export) an `Arrive`: the
    /// handler runs where the link's *receive* side lives.
    fn sched_arrive(&mut self, link: LinkId, packet: PacketRef, at: Time) {
        let dst = self.topo.link(link).dst;
        let export = self.shard_ctx.as_ref().and_then(|ctx| {
            let owner = ctx.owner[dst.0 as usize];
            (owner != ctx.shard).then_some(owner)
        });
        match export {
            Some(owner) => {
                // The packet leaves this shard's arena and rides the
                // mailbox by value; the receiving shard re-allocs it.
                let pkt = self.packets.free(packet);
                let msg = BoundaryMsg { at, ev: BoundaryEvent::Arrive { link, packet: pkt } };
                self.shard_ctx.as_mut().expect("checked above").outbox.push((owner, msg));
            }
            None => {
                self.sim.at_keyed(at, key_arrive(link), Event::Arrive { link, packet });
            }
        }
    }

    /// Schedule (or export) a `Credit`: the handler runs where the
    /// link's *transmit* side (its [`LinkState`]) lives.
    fn sched_credit(&mut self, link: LinkId, bytes: u32, at: Time) {
        let src = self.topo.link(link).src;
        let export = self.shard_ctx.as_ref().and_then(|ctx| {
            let owner = ctx.owner[src.0 as usize];
            (owner != ctx.shard).then_some(owner)
        });
        match export {
            Some(owner) => {
                let msg = BoundaryMsg { at, ev: BoundaryEvent::Credit { link, bytes } };
                self.shard_ctx.as_mut().expect("checked above").outbox.push((owner, msg));
            }
            None => {
                self.sim.at_keyed(at, key_credit(link), Event::Credit { link, bytes });
            }
        }
    }

    /// This network's shard index (0 for the serial engine).
    pub(crate) fn shard_id(&self) -> u32 {
        self.shard_ctx.as_ref().map_or(0, |c| c.shard)
    }

    /// Drain this shard's boundary outbox (sharded runs only).
    pub(crate) fn take_outbox(&mut self) -> Vec<(u32, BoundaryMsg)> {
        match &mut self.shard_ctx {
            Some(ctx) => std::mem::take(&mut ctx.outbox),
            None => Vec::new(),
        }
    }

    /// Insert boundary events received from other shards. The caller
    /// presents them in the canonical `(source shard, generation seq)`
    /// order; keys put them in their exact serial dispatch slot.
    pub(crate) fn import_boundary(&mut self, msgs: Vec<(u32, BoundaryMsg)>) {
        for (_src, msg) in msgs {
            match msg.ev {
                BoundaryEvent::Arrive { link, packet } => {
                    let r = self.packets.alloc(packet);
                    self.sim.at_keyed(msg.at, key_arrive(link), Event::Arrive { link, packet: r });
                }
                BoundaryEvent::Credit { link, bytes } => {
                    self.sim.at_keyed(msg.at, key_credit(link), Event::Credit { link, bytes });
                }
            }
        }
    }

    fn arrive(&mut self, link: LinkId, packet: PacketRef, app: &mut dyn App) {
        let wire_bytes = {
            let p = self.packets.get_mut(packet);
            p.hops += 1;
            p.wire_bytes
        };
        // Receiver frees its input buffer once the packet moves on; the
        // credit flight back to the transmitter takes one router latency.
        let credit_at = self.now() + self.cfg.link.router_latency;
        self.sched_credit(link, wire_bytes, credit_at);
        let here = self.topo.link(link).dst;
        self.route_from(here, packet, Some(link), app);
    }

    /// Packet reached its destination node: hand to the Packet Demux
    /// (Fig 5) which dispatches per protocol. Terminal protocols take
    /// the packet out of the arena; deferred ones (Bridge FIFO,
    /// NetTunnel) keep the ref alive across their logic delay.
    fn deliver(&mut self, node: NodeId, packet: PacketRef, app: &mut dyn App) {
        let (id, proto, injected_at, wire_bytes) = {
            let p = self.packets.get(packet);
            (p.id, p.proto, p.injected_at, p.wire_bytes)
        };
        let d = Delivery {
            time: self.sim.now(),
            node: node.0,
            packet: id,
            proto: proto_tag(proto),
            wire_bytes,
        };
        if let Some(tr) = &mut self.trace {
            tr.push(d);
        }
        self.app_scope(app, |net, app| app.on_deliver(net, node, &d));
        if !matches!(proto, Proto::BridgeFifo { .. }) {
            let latency = self.now() - injected_at;
            self.metrics.record_delivery(proto_name(proto), latency, wire_bytes);
        }
        match proto {
            Proto::BridgeFifo { .. } => {
                // Bridge-FIFO receive logic (half of the hop-0 FIFO
                // latency budget; see config::SystemConfig docs); the
                // end-to-end latency metric is recorded there, once the
                // words become readable.
                let delay = self.cfg.bridge_fifo_logic / 2;
                self.sim.after_keyed(delay, key_fifo_rx(id), Event::FifoRx { node, packet });
            }
            Proto::Postmaster { queue } => {
                let pkt = self.packets.free(packet);
                self.pm_deliver(node, queue, pkt);
            }
            Proto::Ethernet => {
                let pkt = self.packets.free(packet);
                self.eth_deliver(node, pkt);
            }
            Proto::NetTunnel => {
                // Tunnel logic executes the access in fabric hardware
                // (calibrated in SystemConfig::tunnel_exec_latency).
                self.sim.after_keyed(
                    self.cfg.tunnel_exec_latency,
                    key_tunnel(id),
                    Event::TunnelExec { node, packet },
                );
            }
            Proto::Boot => {
                let pkt = self.packets.free(packet);
                self.boot_deliver(node, pkt);
            }
            Proto::Raw { .. } => {
                let pkt = self.packets.free(packet);
                // Directed raw datagrams addressed to an open
                // `CommMode::Raw` endpoint are also surfaced as
                // endpoint messages (on_message / recv), like every
                // other channel's capture layer. Multicast/broadcast
                // raw traffic stays on_raw-only.
                let captured = match pkt.route {
                    RouteKind::Directed => self.comm_capture_raw(node, pkt.src, &pkt.payload),
                    _ => None,
                };
                self.app_scope(app, |net, app| {
                    app.on_raw(net, node, &pkt);
                    if let Some((ep, msg)) = captured {
                        net.comm_deliver(app, ep, msg);
                    }
                });
            }
        }
    }
}

pub(crate) fn proto_name(p: Proto) -> &'static str {
    match p {
        Proto::Ethernet => "ethernet",
        Proto::Postmaster { .. } => "postmaster",
        Proto::BridgeFifo { .. } => "bridge_fifo",
        Proto::NetTunnel => "net_tunnel",
        Proto::Boot => "boot",
        Proto::Raw { .. } => "raw",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Coord;

    #[test]
    fn event_size_budget() {
        // The timing wheel moves these by value on every push/pop; the
        // arena/Box/Arc layout keeps them at 16 bytes (budget: 32).
        eprintln!("size Event = {}", std::mem::size_of::<Event>());
        eprintln!("size Packet = {}", std::mem::size_of::<Packet>());
        assert!(std::mem::size_of::<Event>() <= 32);
    }

    struct Collect {
        raw: Vec<(NodeId, u64)>,
    }
    impl App for Collect {
        fn on_raw(&mut self, net: &mut Network, node: NodeId, packet: &Packet) {
            self.raw.push((node, net.now() - packet.injected_at));
        }
    }

    #[test]
    fn directed_packet_latency_matches_calibration() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        // 6 hops; Raw payload U64s = 32B + 8B header = 40B wire.
        net.send_directed(src, dst, Proto::Raw { tag: 1 }, Payload::U64s([1, 2, 3, 4]));
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), 1);
        let (node, lat) = app.raw[0];
        assert_eq!(node, dst);
        // inject 150 + 6 × (684 + 40) = 4494.
        assert_eq!(lat, 150 + 6 * (684 + 40));
    }

    #[test]
    fn broadcast_reaches_every_node_exactly_once() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 1, y: 1, z: 1 });
        net.send_broadcast(src, Proto::Raw { tag: 7 }, Payload::Empty);
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), 27);
        let mut nodes: Vec<u32> = app.raw.iter().map(|(n, _)| n.0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 27);
    }

    #[test]
    fn broadcast_inc3000_all_nodes() {
        let mut net = Network::inc3000();
        let src = net.topo.id(Coord { x: 5, y: 7, z: 1 });
        net.send_broadcast(src, Proto::Raw { tag: 7 }, Payload::Empty);
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), 432);
        assert_eq!(net.packets.live(), 0, "broadcast copies must be freed");
    }

    #[test]
    fn many_packets_conserve_count() {
        let mut net = Network::card();
        let n = net.topo.node_count() as u32;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    net.send_directed(
                        NodeId(i),
                        NodeId(j),
                        Proto::Raw { tag: 0 },
                        Payload::Empty,
                    );
                }
            }
        }
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), (n * (n - 1)) as usize);
        assert_eq!(net.metrics.packets_delivered as usize, app.raw.len());
        assert_eq!(net.packets.live(), 0, "arena leaked in-flight packets");
        assert!(net.packets.high_water() > 0);
    }

    #[test]
    fn determinism_same_seed_same_trace() {
        let run = || {
            let mut net = Network::card();
            for i in 0..27u32 {
                net.send_directed(
                    NodeId(i),
                    NodeId(26 - i),
                    Proto::Raw { tag: 0 },
                    Payload::bytes(vec![0u8; 256]),
                );
            }
            let mut app = Collect { raw: vec![] };
            net.run_to_quiescence(&mut app);
            (net.now(), app.raw)
        };
        let (t1, r1) = run();
        let (t2, r2) = run();
        assert_eq!(t1, t2);
        assert_eq!(r1, r2);
    }

    #[test]
    fn idle_links_schedule_no_drain_events() {
        // A single packet crossing an uncontended fabric never queues,
        // so every end-of-serialization drain is suppressed: the event
        // count is exactly inject + per-hop (arrive + credit).
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        net.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Empty);
        let events = net.run_to_quiescence(&mut Collect { raw: vec![] });
        assert_eq!(events, 1 + 6 * 2, "inject + 6 hops × (arrive + credit)");
        assert_eq!(net.metrics.drains_suppressed, 6);
        assert_eq!(net.metrics.link_stalls, 0);
    }

    #[test]
    fn tunnel_exec_latency_is_configurable() {
        let base = {
            let mut net = Network::card();
            net.tunnel_write(NodeId(0), NodeId(1), crate::node::regs::SCRATCH0, 1);
            net.run_to_quiescence(&mut NullApp);
            net.now()
        };
        let slow = {
            let mut cfg = SystemConfig::card();
            cfg.tunnel_exec_latency += 900;
            let mut net = Network::new(cfg);
            net.tunnel_write(NodeId(0), NodeId(1), crate::node::regs::SCRATCH0, 1);
            net.run_to_quiescence(&mut NullApp);
            net.now()
        };
        assert_eq!(slow, base + 900);
    }

    #[test]
    fn seeded_loss_is_deterministic_and_leak_free() {
        let run = |p: f64| {
            let mut cfg = SystemConfig::card();
            cfg.drop_probability = p;
            let mut net = Network::new(cfg);
            let n = net.topo.node_count() as u32;
            for i in 0..n {
                net.send_directed(
                    NodeId(i),
                    NodeId((i + 13) % n),
                    Proto::Raw { tag: 0 },
                    Payload::bytes(vec![0u8; 128]),
                );
            }
            net.run_to_quiescence(&mut NullApp);
            (net.metrics.packets_delivered, net.metrics.link_loss, net.packets.live())
        };
        let (_, l0, live0) = run(0.0);
        assert_eq!(l0, 0, "p=0 must be loss-free");
        assert_eq!(live0, 0);
        let (d1, l1, live1) = run(1.0);
        assert_eq!(d1, 0, "p=1 loses every first transmission attempt");
        assert!(l1 > 0);
        assert_eq!(live1, 0, "lost packets must be freed, not leaked");
        let (da, la, live_a) = run(0.3);
        let (db, lb, _) = run(0.3);
        assert_eq!((da, la), (db, lb), "loss is a pure function of seed, id and link");
        assert!(da > 0 && la > 0, "p=0.3 should lose some and deliver some");
        assert_eq!(live_a, 0);
    }

    #[test]
    fn congestion_stalls_are_counted_and_resolved() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 1, y: 0, z: 0 });
        // Hammer one link with more bytes than its credit buffer.
        for _ in 0..64 {
            net.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::bytes(vec![0u8; 1024]));
        }
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), 64);
        assert!(net.metrics.link_stalls > 0, "expected credit/busy stalls");
    }
}
