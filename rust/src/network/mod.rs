//! The assembled INC fabric: nodes × routers × links + virtual channels.
//!
//! [`Network`] owns all dynamic state (link occupancy/credits, per-node
//! registers and DRAM, channel endpoints) plus the event queue, and is
//! driven by [`Network::run_until`] / [`Network::run_to_quiescence`].
//! Workloads react to traffic through the [`App`] trait; every channel
//! also buffers delivered data in inboxes that can be read after a run,
//! so simple drivers need no callbacks at all.
//!
//! # Hot-path layout
//!
//! The event core moves [`Event`]s by value, so the enum is kept to
//! ≤ 32 bytes (16 in practice; asserted by `event_size_budget`):
//! packets ride in the [`arena::PacketArena`] behind a 4-byte
//! [`arena::PacketRef`], Ethernet frames and Postmaster records are
//! boxed (they only cross the queue once per delivery), and Bridge-FIFO
//! word bursts are `Arc`-shared. Broadcast/multicast fan-out clones the
//! ~100-byte packet header per copy but shares the payload bytes
//! through `Arc` — a 2 KB broadcast at INC-3000 scale moves zero
//! payload bytes per hop. The in-flight side tables (`eth_inflight`,
//! `tunnel_results`, channel endpoint maps) use deterministic
//! [`crate::util::FxHashMap`]s: no SipHash on the per-packet path, no
//! per-process seed.

pub mod arena;

use crate::channels::bridge_fifo::BridgeFifoFabric;
use crate::channels::ethernet::{EthFrame, EthernetFabric};
use crate::channels::postmaster::{PmRecord, PostmasterFabric};
use crate::config::SystemConfig;
use crate::link::LinkState;
use crate::metrics::Metrics;
use crate::node::NodeState;
use crate::router::{
    broadcast_forwards, pick_adaptive, productive_links_buf, Packet, Payload, Proto, RouteKind,
    ZMode,
};
use crate::sim::{Sim, Time};
use crate::topology::{LinkId, NodeId, Topology};
use crate::util::FxHashMap;

use arena::{PacketArena, PacketRef};

/// Events dispatched by the fabric. Kept ≤ 32 bytes — see module docs.
#[derive(Debug)]
pub enum Event {
    /// Packet enters the source node's router (after injection overhead).
    Inject { packet: PacketRef },
    /// Packet fully received at the downstream end of `link`.
    Arrive { link: LinkId, packet: PacketRef },
    /// `link` may be able to transmit a queued packet now.
    Drain { link: LinkId },
    /// Receiver of `link` freed buffer space; credits return to its tx.
    Credit { link: LinkId, bytes: u32 },
    /// Bridge-FIFO receive logic finished for a packet (§3.3).
    FifoRx { node: NodeId, packet: PacketRef },
    /// Local (same-node) Bridge-FIFO delivery, bypassing the network.
    FifoLocal { node: NodeId, channel: u8, words: std::sync::Arc<Vec<u64>> },
    /// Postmaster target DMA completed for one record (§3.2).
    PmRx { node: NodeId, queue: u8, record: Box<PmRecord> },
    /// Ethernet frame DMA'd into destination DRAM; notify driver (§3.1).
    EthRx { node: NodeId, frame: Box<EthFrame> },
    /// Ethernet driver polling tick.
    EthPoll { node: NodeId },
    /// Ethernet frame ready for injection after tx-side software costs.
    EthTx { frame: Box<EthFrame> },
    /// NetTunnel / diagnostic register access executed at `node`.
    TunnelExec { node: NodeId, packet: PacketRef },
    /// Application timer.
    Timer { node: NodeId, tag: u64 },
}

/// Workload hook points. All methods have default empty bodies; override
/// the ones the workload cares about. Delivered data is *also* available
/// from channel inboxes after a run.
#[allow(unused_variables)]
pub trait App {
    /// A directed/broadcast `Proto::Raw` packet arrived at `node`.
    fn on_raw(&mut self, net: &mut Network, node: NodeId, packet: &Packet) {}
    /// Words became readable on a Bridge-FIFO read port.
    fn on_fifo(&mut self, net: &mut Network, node: NodeId, channel: u8, words: &[u64]) {}
    /// A Postmaster record landed in `node`'s receive stream.
    fn on_postmaster(&mut self, net: &mut Network, node: NodeId, queue: u8, rec: &PmRecord) {}
    /// An internal-Ethernet frame was handed to the kernel at `node`.
    fn on_eth(&mut self, net: &mut Network, node: NodeId, frame: &EthFrame) {}
    /// An application timer fired.
    fn on_timer(&mut self, net: &mut Network, node: NodeId, tag: u64) {}
}

/// An [`App`] that does nothing (inbox-driven workloads).
pub struct NullApp;
impl App for NullApp {}

/// The assembled system.
pub struct Network {
    pub cfg: SystemConfig,
    pub topo: Topology,
    pub links: Vec<LinkState>,
    pub sim: Sim<Event>,
    pub rng: crate::util::SplitMix64,
    pub metrics: Metrics,
    pub nodes: Vec<NodeState>,
    pub fifos: BridgeFifoFabric,
    pub postmaster: PostmasterFabric,
    pub eth: EthernetFabric,
    /// In-flight packet storage; events reference it by [`PacketRef`].
    pub packets: PacketArena,
    /// Ethernet frames whose packet is in flight, keyed by packet id.
    pub(crate) eth_inflight: FxHashMap<u64, EthFrame>,
    /// NetTunnel read results, keyed by request id.
    pub tunnel_results: FxHashMap<u64, u64>,
    /// Links marked defective (§2.4 "network defect avoidance").
    pub failed_links: Vec<bool>,
    next_packet_id: u64,
}

impl Network {
    pub fn new(cfg: SystemConfig) -> Self {
        let topo = Topology::preset(cfg.preset);
        let topo_link_count = topo.link_count();
        let links = (0..topo_link_count).map(|_| LinkState::new(&cfg.link)).collect();
        let n = topo.node_count();
        let nodes = (0..n).map(|i| NodeState::new(NodeId(i as u32), &cfg)).collect();
        Network {
            rng: crate::util::SplitMix64::new(cfg.seed),
            topo,
            links,
            sim: Sim::new(),
            metrics: Metrics::new(),
            nodes,
            fifos: BridgeFifoFabric::new(n),
            postmaster: PostmasterFabric::new(n),
            eth: EthernetFabric::new(n, &cfg),
            packets: PacketArena::with_capacity(1024),
            eth_inflight: FxHashMap::default(),
            tunnel_results: FxHashMap::default(),
            failed_links: vec![false; topo_link_count],
            cfg,
            next_packet_id: 0,
        }
    }

    pub fn card() -> Self {
        Self::new(SystemConfig::card())
    }

    pub fn inc3000() -> Self {
        Self::new(SystemConfig::inc3000())
    }

    #[inline]
    pub fn now(&self) -> Time {
        self.sim.now()
    }

    pub fn next_packet_id(&mut self) -> u64 {
        let id = self.next_packet_id;
        self.next_packet_id += 1;
        id
    }

    /// Build and inject a directed packet from `src` (paying injection
    /// overhead). Returns the packet id.
    pub fn send_directed(
        &mut self,
        src: NodeId,
        dst: NodeId,
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        let id = self.next_packet_id();
        let pkt = Packet::new(id, src, dst, RouteKind::Directed, proto, payload, self.now());
        self.inject(pkt);
        id
    }

    /// Build and inject a broadcast packet from `src`.
    pub fn send_broadcast(&mut self, src: NodeId, proto: Proto, payload: Payload) -> u64 {
        let id = self.next_packet_id();
        let pkt = Packet::new(
            id,
            src,
            src,
            RouteKind::Broadcast { zmode: ZMode::Line },
            proto,
            payload,
            self.now(),
        );
        self.inject(pkt);
        id
    }

    /// Mark a link defective: directed/multicast routing avoids it
    /// (§2.4's "network defect avoidance" extension).
    pub fn fail_link(&mut self, l: LinkId) {
        self.failed_links[l.0 as usize] = true;
    }

    /// Bring a failed link back into service.
    pub fn repair_link(&mut self, l: LinkId) {
        self.failed_links[l.0 as usize] = false;
    }

    /// Spanning-tree multicast to `dsts` (§2.4 extension): shared path
    /// prefixes carry one copy. Returns the packet id.
    pub fn send_multicast(
        &mut self,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        assert!(!dsts.is_empty(), "multicast needs destinations");
        let id = self.next_packet_id();
        let mut pkt =
            Packet::new(id, src, src, RouteKind::Multicast, proto, payload, self.now());
        pkt.mcast = Some(std::sync::Arc::new(dsts.to_vec()));
        self.inject(pkt);
        id
    }

    /// Inject an already-built packet at its source node.
    pub fn inject(&mut self, packet: Packet) {
        self.metrics.packets_injected += 1;
        let delay = self.cfg.link.inject_latency;
        let packet = self.packets.alloc(packet);
        self.sim.after(delay, Event::Inject { packet });
    }

    /// Schedule an already-built packet to enter the fabric at absolute
    /// time `at` (deferred-production workloads; the caller accounts
    /// metrics and any software costs itself).
    pub fn inject_at(&mut self, at: Time, packet: Packet) {
        let packet = self.packets.alloc(packet);
        self.sim.at(at, Event::Inject { packet });
    }

    /// Run until the event queue empties or `deadline` passes. Returns
    /// the number of events dispatched.
    pub fn run_until(&mut self, app: &mut dyn App, deadline: Time) -> u64 {
        let start = self.sim.dispatched();
        while let Some((_, ev)) = self.sim.pop_until(deadline) {
            self.handle(ev, app);
        }
        if self.sim.peek_time().map_or(true, |t| t > deadline) && self.sim.now() < deadline {
            self.sim.advance_to(deadline);
        }
        self.sim.dispatched() - start
    }

    /// Run until no events remain.
    pub fn run_to_quiescence(&mut self, app: &mut dyn App) -> u64 {
        let start = self.sim.dispatched();
        while let Some((_, ev)) = self.sim.pop() {
            self.handle(ev, app);
        }
        self.sim.dispatched() - start
    }

    fn handle(&mut self, ev: Event, app: &mut dyn App) {
        match ev {
            Event::Inject { packet } => {
                let src = self.packets.get(packet).src;
                self.route_from(src, packet, None, app)
            }
            Event::Arrive { link, packet } => self.arrive(link, packet, app),
            Event::Drain { link } => self.drain(link),
            Event::Credit { link, bytes } => {
                self.links[link.0 as usize].grant(bytes, self.cfg.link.credit_buffer_bytes);
                self.drain(link);
            }
            Event::FifoRx { node, packet } => {
                let pkt = self.packets.free(packet);
                self.fifo_rx(node, pkt, app)
            }
            Event::FifoLocal { node, channel, words } => {
                self.fifo_local_rx(node, channel, &words, app)
            }
            Event::PmRx { node, queue, record } => self.pm_rx(node, queue, *record, app),
            Event::EthRx { node, frame } => self.eth_rx(node, *frame, app),
            Event::EthPoll { node } => self.eth_poll(node, app),
            Event::EthTx { frame } => self.eth_tx_inject(*frame),
            Event::TunnelExec { node, packet } => {
                let pkt = self.packets.free(packet);
                self.tunnel_exec(node, pkt)
            }
            Event::Timer { node, tag } => app.on_timer(self, node, tag),
        }
    }

    /// A packet is at `here`'s router; forward it (or deliver).
    ///
    /// `arrived_via` is the link it came in on (None right after
    /// injection at the source).
    fn route_from(
        &mut self,
        here: NodeId,
        packet: PacketRef,
        arrived_via: Option<LinkId>,
        app: &mut dyn App,
    ) {
        let (route, dst, src, id, wire_bytes, hops) = {
            let p = self.packets.get(packet);
            (p.route, p.dst, p.src, p.id, p.wire_bytes, p.hops)
        };
        match route {
            RouteKind::Directed => {
                if here == dst {
                    self.deliver(here, packet, app);
                    return;
                }
                let mut buf = [crate::topology::LinkId(0); 6];
                let n = productive_links_buf(&self.topo, here, dst, &mut buf);
                // Defect avoidance: drop failed links from the set.
                let failed = &self.failed_links;
                let mut live = [crate::topology::LinkId(0); 6];
                let mut m = 0;
                for &l in &buf[..n] {
                    if !failed[l.0 as usize] {
                        live[m] = l;
                        m += 1;
                    }
                }
                let now = self.now();
                let links = &self.links;
                let chosen = if m > 0 {
                    pick_adaptive(
                        &live[..m],
                        |l| links[l.0 as usize].ready(now, wire_bytes),
                        |l| links[l.0 as usize].busy_until(),
                        &mut self.rng,
                    )
                } else {
                    // Every minimal link is dead: lateral escape over any
                    // live link that gets closest to the destination.
                    self.topo
                        .out_links(here)
                        .iter()
                        .copied()
                        .filter(|&l| !failed[l.0 as usize])
                        .min_by_key(|&l| self.topo.min_hops(self.topo.link(l).dst, dst))
                };
                // Livelock guard (misrouting around defects is bounded).
                let budget = 4 * self.topo.min_hops(src, dst) + 64;
                if hops > budget {
                    panic!("packet {id} exceeded hop budget (defect livelock?)");
                }
                if let Some(l) = chosen {
                    self.link_send(l, packet);
                } else {
                    panic!("node {here} fully disconnected; cannot route {id}");
                }
            }
            RouteKind::Multicast => {
                let dsts =
                    self.packets.get(packet).mcast.clone().expect("multicast without targets");
                let (local, groups) = crate::router::multicast::multicast_partition(
                    &self.topo,
                    here,
                    &dsts,
                    &self.failed_links,
                );
                for (link, subset) in groups {
                    // Header copy per branch; payload bytes stay shared
                    // behind their Arc.
                    let mut copy = self.packets.get(packet).clone();
                    copy.mcast = Some(std::sync::Arc::new(subset));
                    let copy = self.packets.alloc(copy);
                    self.metrics.multicast_copies += 1;
                    self.link_send(link, copy);
                }
                if local {
                    self.deliver(here, packet, app);
                } else {
                    // Forwarded-only node: this ref's journey ends here.
                    self.packets.free(packet);
                }
            }
            RouteKind::Broadcast { .. } => {
                let arrived = arrived_via.map(|l| {
                    let info = self.topo.link(l);
                    let zmode = match route {
                        RouteKind::Broadcast { zmode } => zmode,
                        _ => unreachable!(),
                    };
                    (info.dir, info.span, zmode)
                });
                let fwd = broadcast_forwards(&self.topo, here, arrived);
                for (lid, rk) in fwd {
                    let mut copy = self.packets.get(packet).clone();
                    copy.route = rk;
                    let copy = self.packets.alloc(copy);
                    self.link_send(lid, copy);
                }
                // Every node (including the source) receives one copy.
                self.metrics.broadcast_copies += 1;
                self.deliver(here, packet, app);
            }
        }
    }

    /// Transmit `packet` on `link` now, or queue it if busy/out of credit.
    fn link_send(&mut self, link: LinkId, packet: PacketRef) {
        let wire_bytes = self.packets.get(packet).wire_bytes;
        let now = self.now();
        let st = &mut self.links[link.0 as usize];
        if st.ready(now, wire_bytes) {
            let busy_until = st.start_tx(now, wire_bytes, &self.cfg.link);
            let arrive_at = now + self.cfg.link.hop(wire_bytes);
            self.sim.at(busy_until, Event::Drain { link });
            self.sim.at(arrive_at, Event::Arrive { link, packet });
        } else {
            st.enqueue(packet, wire_bytes);
            self.metrics.link_stalls += 1;
        }
    }

    /// Serialization of a queued packet becomes possible.
    fn drain(&mut self, link: LinkId) {
        let now = self.now();
        if let Some((packet, wire_bytes)) = self.links[link.0 as usize].pop_sendable(now) {
            let busy_until =
                self.links[link.0 as usize].start_tx(now, wire_bytes, &self.cfg.link);
            let arrive_at = now + self.cfg.link.hop(wire_bytes);
            self.sim.at(busy_until, Event::Drain { link });
            self.sim.at(arrive_at, Event::Arrive { link, packet });
        }
    }

    fn arrive(&mut self, link: LinkId, packet: PacketRef, app: &mut dyn App) {
        let wire_bytes = {
            let p = self.packets.get_mut(packet);
            p.hops += 1;
            p.wire_bytes
        };
        // Receiver frees its input buffer once the packet moves on; the
        // credit flight back to the transmitter takes one router latency.
        self.sim.after(
            self.cfg.link.router_latency,
            Event::Credit { link, bytes: wire_bytes },
        );
        let here = self.topo.link(link).dst;
        self.route_from(here, packet, Some(link), app);
    }

    /// Packet reached its destination node: hand to the Packet Demux
    /// (Fig 5) which dispatches per protocol. Terminal protocols take
    /// the packet out of the arena; deferred ones (Bridge FIFO,
    /// NetTunnel) keep the ref alive across their logic delay.
    fn deliver(&mut self, node: NodeId, packet: PacketRef, app: &mut dyn App) {
        let (proto, injected_at, wire_bytes) = {
            let p = self.packets.get(packet);
            (p.proto, p.injected_at, p.wire_bytes)
        };
        if !matches!(proto, Proto::BridgeFifo { .. }) {
            let latency = self.now() - injected_at;
            self.metrics.record_delivery(proto_name(proto), latency, wire_bytes);
        }
        match proto {
            Proto::BridgeFifo { .. } => {
                // Bridge-FIFO receive logic (half of the hop-0 FIFO
                // latency budget; see config::SystemConfig docs); the
                // end-to-end latency metric is recorded there, once the
                // words become readable.
                let d = self.cfg.bridge_fifo_logic / 2;
                self.sim.after(d, Event::FifoRx { node, packet });
            }
            Proto::Postmaster { queue } => {
                let pkt = self.packets.free(packet);
                self.pm_deliver(node, queue, pkt);
            }
            Proto::Ethernet => {
                let pkt = self.packets.free(packet);
                self.eth_deliver(node, pkt);
            }
            Proto::NetTunnel => {
                // Tunnel logic executes the access in fabric hardware.
                self.sim.after(100, Event::TunnelExec { node, packet });
            }
            Proto::Boot => {
                let pkt = self.packets.free(packet);
                self.boot_deliver(node, pkt);
            }
            Proto::Raw { .. } => {
                let pkt = self.packets.free(packet);
                app.on_raw(self, node, &pkt);
            }
        }
    }
}

pub(crate) fn proto_name(p: Proto) -> &'static str {
    match p {
        Proto::Ethernet => "ethernet",
        Proto::Postmaster { .. } => "postmaster",
        Proto::BridgeFifo { .. } => "bridge_fifo",
        Proto::NetTunnel => "net_tunnel",
        Proto::Boot => "boot",
        Proto::Raw { .. } => "raw",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Coord;

    #[test]
    fn event_size_budget() {
        // The timing wheel moves these by value on every push/pop; the
        // arena/Box/Arc layout keeps them at 16 bytes (budget: 32).
        eprintln!("size Event = {}", std::mem::size_of::<Event>());
        eprintln!("size Packet = {}", std::mem::size_of::<Packet>());
        assert!(std::mem::size_of::<Event>() <= 32);
    }

    struct Collect {
        raw: Vec<(NodeId, u64)>,
    }
    impl App for Collect {
        fn on_raw(&mut self, net: &mut Network, node: NodeId, packet: &Packet) {
            self.raw.push((node, net.now() - packet.injected_at));
        }
    }

    #[test]
    fn directed_packet_latency_matches_calibration() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 2, y: 2, z: 2 });
        // 6 hops; Raw payload U64s = 32B + 8B header = 40B wire.
        net.send_directed(src, dst, Proto::Raw { tag: 1 }, Payload::U64s([1, 2, 3, 4]));
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), 1);
        let (node, lat) = app.raw[0];
        assert_eq!(node, dst);
        // inject 150 + 6 × (684 + 40) = 4494.
        assert_eq!(lat, 150 + 6 * (684 + 40));
    }

    #[test]
    fn broadcast_reaches_every_node_exactly_once() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 1, y: 1, z: 1 });
        net.send_broadcast(src, Proto::Raw { tag: 7 }, Payload::Empty);
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), 27);
        let mut nodes: Vec<u32> = app.raw.iter().map(|(n, _)| n.0).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 27);
    }

    #[test]
    fn broadcast_inc3000_all_nodes() {
        let mut net = Network::inc3000();
        let src = net.topo.id(Coord { x: 5, y: 7, z: 1 });
        net.send_broadcast(src, Proto::Raw { tag: 7 }, Payload::Empty);
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), 432);
        assert_eq!(net.packets.live(), 0, "broadcast copies must be freed");
    }

    #[test]
    fn many_packets_conserve_count() {
        let mut net = Network::card();
        let n = net.topo.node_count() as u32;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    net.send_directed(
                        NodeId(i),
                        NodeId(j),
                        Proto::Raw { tag: 0 },
                        Payload::Empty,
                    );
                }
            }
        }
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), (n * (n - 1)) as usize);
        assert_eq!(net.metrics.packets_delivered as usize, app.raw.len());
        assert_eq!(net.packets.live(), 0, "arena leaked in-flight packets");
        assert!(net.packets.high_water() > 0);
    }

    #[test]
    fn determinism_same_seed_same_trace() {
        let run = || {
            let mut net = Network::card();
            for i in 0..27u32 {
                net.send_directed(
                    NodeId(i),
                    NodeId(26 - i),
                    Proto::Raw { tag: 0 },
                    Payload::bytes(vec![0u8; 256]),
                );
            }
            let mut app = Collect { raw: vec![] };
            net.run_to_quiescence(&mut app);
            (net.now(), app.raw)
        };
        let (t1, r1) = run();
        let (t2, r2) = run();
        assert_eq!(t1, t2);
        assert_eq!(r1, r2);
    }

    #[test]
    fn congestion_stalls_are_counted_and_resolved() {
        let mut net = Network::card();
        let src = net.topo.id(Coord { x: 0, y: 0, z: 0 });
        let dst = net.topo.id(Coord { x: 1, y: 0, z: 0 });
        // Hammer one link with more bytes than its credit buffer.
        for _ in 0..64 {
            net.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::bytes(vec![0u8; 1024]));
        }
        let mut app = Collect { raw: vec![] };
        net.run_to_quiescence(&mut app);
        assert_eq!(app.raw.len(), 64);
        assert!(net.metrics.link_stalls > 0, "expected credit/busy stalls");
    }
}
