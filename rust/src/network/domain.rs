//! Shard-local state domains: O(owned) index remaps for per-shard state.
//!
//! A [`Domain`] describes which slice of the mesh a [`Network`] holds
//! dynamic state for, and how global identifiers map onto that state's
//! dense local indices:
//!
//! * the **full** domain (serial engine): every node and link, with the
//!   identity mapping — zero overhead on the classic hot path;
//! * an **owned-subset** domain (one per shard of a
//!   [`sharded::ShardedNetwork`]): exactly the nodes the shard owns per
//!   [`Topology::partition`]'s owner map, plus the links whose
//!   *transmit* side lives on an owned node (link state — credits,
//!   occupancy, queues — is transmit-side state; the receive side of a
//!   boundary link only ever sees the packet, never the `LinkState`).
//!
//! Before this existed, every shard allocated full-mesh `links`/`nodes`
//! vectors and mutated only its own slice — k shards held k copies of
//! the mesh. With owned-subset domains a k-shard run holds ~1/k of the
//! state per shard (the sum over shards equals the serial engine's
//! state exactly; asserted in `tests/properties.rs`).
//!
//! The global↔local maps are **bijections** between the owned
//! identifier set and `0..count` (property-tested in
//! `tests/properties.rs`), stored in **O(owned)** space: a sorted
//! local→global `Vec` per direction plus a deterministic
//! [`FxHashMap`] for global→local. (The first version kept dense
//! O(mesh) global→local vectors — ~4 B per mesh node and link,
//! *replicated per shard*, which at the 100k-node presets would
//! dominate every shard's actual dynamic state. Now a 64-shard
//! Inc100k run pays per shard only for what the shard owns; the
//! `inc9000_domain` / `serving` bench rows assert the scaling.)
//!
//! Indexing state for an identifier the domain does not own is a bug —
//! the shard would silently read idle state the owning shard is
//! mutating — so [`Domain::node_index`] / [`Domain::link_index`]
//! debug-assert ownership with a named-shard message, and in release
//! builds a missing map entry resolves to the `u32::MAX` sentinel,
//! which turns the mistake into an immediate out-of-bounds panic at
//! the state vector instead of a silent wrong read.
//!
//! [`Network`]: crate::network::Network
//! [`sharded::ShardedNetwork`]: crate::network::sharded::ShardedNetwork
//! [`Topology::partition`]: crate::topology::Topology::partition
//! [`FxHashMap`]: crate::util::FxHashMap

use crate::topology::{LinkId, NodeId, Topology};
use crate::util::FxHashMap;

/// Sentinel for "not owned by this domain" in the global→local maps.
const UNOWNED: u32 = u32::MAX;

/// Dense global↔local index maps for one engine's slice of the mesh.
/// See the module docs.
#[derive(Debug)]
pub struct Domain {
    /// `None` = full mesh, identity mapping (the serial engine).
    map: Option<DomainMap>,
    nodes_len: usize,
    links_len: usize,
    shard: u32,
}

#[derive(Debug)]
struct DomainMap {
    /// Global node id → local index; absent = not owned. O(owned)
    /// entries (the deterministic [`crate::util::FxHashMap`] — no
    /// RandomState, so iteration-free lookups cost the same on every
    /// engine and run).
    node_local: FxHashMap<u32, u32>,
    /// Local index → global node id.
    node_global: Vec<u32>,
    /// Global link id → local index; absent = not owned.
    link_local: FxHashMap<u32, u32>,
    /// Local index → global link id.
    link_global: Vec<u32>,
}

impl Domain {
    /// The full-mesh identity domain (serial engine / single shard of a
    /// trivial partition).
    pub fn full(topo: &Topology) -> Domain {
        Domain {
            map: None,
            nodes_len: topo.node_count(),
            links_len: topo.link_count(),
            shard: 0,
        }
    }

    /// The owned-subset domain of `shard` under `owner` (one entry per
    /// node, as returned by [`Topology::partition`]): nodes with
    /// `owner[n] == shard`, links whose transmit side (`src`) is owned.
    /// Local indices follow global order, so per-shard iteration order
    /// matches the serial engine's restriction to the owned set.
    ///
    /// [`Topology::partition`]: crate::topology::Topology::partition
    pub fn owned(topo: &Topology, owner: &[u32], shard: u32) -> Domain {
        assert_eq!(owner.len(), topo.node_count(), "owner map does not cover the mesh");
        let mut node_local = FxHashMap::default();
        let mut node_global = Vec::new();
        for n in 0..topo.node_count() {
            if owner[n] == shard {
                node_local.insert(n as u32, node_global.len() as u32);
                node_global.push(n as u32);
            }
        }
        let mut link_local = FxHashMap::default();
        let mut link_global = Vec::new();
        for l in topo.links() {
            if owner[l.src.0 as usize] == shard {
                link_local.insert(l.id.0, link_global.len() as u32);
                link_global.push(l.id.0);
            }
        }
        Domain {
            nodes_len: node_global.len(),
            links_len: link_global.len(),
            map: Some(DomainMap { node_local, node_global, link_local, link_global }),
            shard,
        }
    }

    /// Whether this is the identity (full-mesh) domain.
    #[inline]
    pub fn is_full(&self) -> bool {
        self.map.is_none()
    }

    /// The shard this domain belongs to (0 for the full domain).
    #[inline]
    pub fn shard(&self) -> u32 {
        self.shard
    }

    /// Number of nodes this domain holds state for.
    #[inline]
    pub fn node_count(&self) -> usize {
        self.nodes_len
    }

    /// Number of links this domain holds state for.
    #[inline]
    pub fn link_count(&self) -> usize {
        self.links_len
    }

    /// Does this domain own `n`'s state?
    #[inline]
    pub fn owns_node(&self, n: NodeId) -> bool {
        match &self.map {
            None => (n.0 as usize) < self.nodes_len,
            Some(m) => m.node_local.contains_key(&n.0),
        }
    }

    /// Does this domain own `l`'s (transmit-side) state?
    #[inline]
    pub fn owns_link(&self, l: LinkId) -> bool {
        match &self.map {
            None => (l.0 as usize) < self.links_len,
            Some(m) => m.link_local.contains_key(&l.0),
        }
    }

    /// Local state index of node `n`. Debug-asserts ownership; in
    /// release an un-owned node yields the `u32::MAX` sentinel, which
    /// panics at the state vector's bounds check (loud, never a silent
    /// read of idle state — see the module docs).
    #[inline]
    pub fn node_index(&self, n: NodeId) -> usize {
        match &self.map {
            None => n.0 as usize,
            Some(m) => {
                let local = m.node_local.get(&n.0).copied().unwrap_or(UNOWNED);
                debug_assert_ne!(
                    local, UNOWNED,
                    "state of {n} indexed on shard {}, which does not own it",
                    self.shard
                );
                local as usize
            }
        }
    }

    /// Local state index of link `l` (transmit-side state). Same
    /// ownership contract as [`Domain::node_index`].
    #[inline]
    pub fn link_index(&self, l: LinkId) -> usize {
        match &self.map {
            None => l.0 as usize,
            Some(m) => {
                let local = m.link_local.get(&l.0).copied().unwrap_or(UNOWNED);
                debug_assert_ne!(
                    local, UNOWNED,
                    "state of {l} indexed on shard {}, which does not own its transmit side",
                    self.shard
                );
                local as usize
            }
        }
    }

    /// Bookkeeping cost of the index maps themselves: **O(owned)** — 4
    /// bytes per owned id for each local→global vec plus ~9 bytes per
    /// hash slot (u32 key + u32 value + 1 control byte, counted at the
    /// maps' actual allocated capacity) for the global→local direction;
    /// nothing scales with the mesh (0 for the full domain, which maps
    /// by identity). This overhead is deliberately **not** part of
    /// `Network::state_bytes` (that figure is the dynamic fabric state,
    /// which partitions exactly across shards); the `inc9000_domain`
    /// and `serving` bench rows report it separately and assert it
    /// stays proportional to the owned counts — it is two orders of
    /// magnitude below the dynamic state it indexes
    /// (`LinkState`/`NodeState`/`EthPort` are hundreds of bytes each).
    pub fn index_bytes(&self) -> u64 {
        match &self.map {
            None => 0,
            Some(m) => {
                let vecs = (m.node_global.len() + m.link_global.len())
                    * std::mem::size_of::<u32>();
                let slots = (m.node_local.capacity() + m.link_local.capacity())
                    * (2 * std::mem::size_of::<u32>() + 1);
                (vecs + slots) as u64
            }
        }
    }

    /// Global node id at local index `i` (inverse of
    /// [`Domain::node_index`]).
    #[inline]
    pub fn node_at(&self, i: usize) -> NodeId {
        match &self.map {
            None => NodeId(i as u32),
            Some(m) => NodeId(m.node_global[i]),
        }
    }

    /// Global link id at local index `i` (inverse of
    /// [`Domain::link_index`]).
    #[inline]
    pub fn link_at(&self, i: usize) -> LinkId {
        match &self.map {
            None => LinkId(i as u32),
            Some(m) => LinkId(m.link_global[i]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemPreset;

    #[test]
    fn full_domain_is_identity() {
        let t = Topology::preset(SystemPreset::Card);
        let d = Domain::full(&t);
        assert!(d.is_full());
        assert_eq!(d.node_count(), t.node_count());
        assert_eq!(d.link_count(), t.link_count());
        for n in t.nodes() {
            assert!(d.owns_node(n));
            assert_eq!(d.node_index(n), n.0 as usize);
            assert_eq!(d.node_at(n.0 as usize), n);
        }
        for l in t.links() {
            assert!(d.owns_link(l.id));
            assert_eq!(d.link_index(l.id), l.id.0 as usize);
            assert_eq!(d.link_at(l.id.0 as usize), l.id);
        }
    }

    #[test]
    fn owned_domain_holds_exactly_the_owned_subset() {
        let t = Topology::preset(SystemPreset::Inc9000);
        let (owner, s) = t.partition(4);
        assert_eq!(s, 4);
        let mut nodes_total = 0;
        let mut links_total = 0;
        for shard in 0..s {
            let d = Domain::owned(&t, &owner, shard);
            assert!(!d.is_full());
            assert_eq!(d.shard(), shard);
            nodes_total += d.node_count();
            links_total += d.link_count();
            for n in t.nodes() {
                assert_eq!(d.owns_node(n), owner[n.0 as usize] == shard);
            }
            for l in t.links() {
                assert_eq!(d.owns_link(l.id), owner[l.src.0 as usize] == shard);
            }
        }
        // Every node and every link is owned by exactly one shard.
        assert_eq!(nodes_total, t.node_count());
        assert_eq!(links_total, t.link_count());
    }

    #[test]
    fn index_maps_scale_with_owned_count_not_mesh() {
        // One-card shards on a small mesh and on a mega mesh: the
        // per-shard index cost depends on what the shard owns, not on
        // how big the mesh around it is. (The dense-map version paid
        // ~4 B × (27 648 nodes + links) ≈ 1.4 MB per Inc27000 shard;
        // the O(owned) maps pay for 27 nodes + their links.)
        let small = Topology::preset(SystemPreset::Inc3000);
        let (owner_s, ss) = small.partition(16);
        assert_eq!(ss, 16);
        let mega = Topology::preset(SystemPreset::Inc27000);
        let (owner_m, sm) = mega.partition(1024);
        assert_eq!(sm, 1024, "one shard per card");
        let ds = Domain::owned(&small, &owner_s, 0);
        let dm = Domain::owned(&mega, &owner_m, 0);
        assert_eq!(ds.node_count(), 27);
        assert_eq!(dm.node_count(), 27);
        let bound =
            |d: &Domain| 32 * (d.node_count() + d.link_count()) as u64;
        assert!(ds.index_bytes() <= bound(&ds), "{}", ds.index_bytes());
        assert!(dm.index_bytes() <= bound(&dm), "{}", dm.index_bytes());
        // In particular: far below even one byte per mesh node.
        assert!(dm.index_bytes() < mega.node_count() as u64);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "does not own")]
    fn unowned_node_index_fails_loudly() {
        let t = Topology::preset(SystemPreset::Inc9000);
        let (owner, _) = t.partition(4);
        let d = Domain::owned(&t, &owner, 0);
        // Node 1727 sits in cage 3.
        d.node_index(NodeId(1727));
    }
}
