//! Optimistic (Time Warp) sharded execution: speculative epochs with
//! checkpoint/rollback, byte-identical to the serial oracle.
//!
//! The conservative engine ([`crate::network::sharded`]) never lets a
//! shard run past the instant another shard's pending work could reach
//! it — on dense traffic every shard's horizon is short and the run
//! pays two barriers per 684 ns window. This module trades that
//! pessimism for *speculation*: each shard checkpoints its whole state
//! at epoch boundaries, runs ahead of any horizon on the live state,
//! and repairs mis-speculation after the fact. The result is — by the
//! repo's non-negotiable gate — byte-identical to the serial engine:
//! same delivery trace, same fabric-view metrics, same final clock.
//!
//! # The protocol (risk-free Time Warp)
//!
//! Classic Time Warp sends speculative messages eagerly and cancels
//! them with *anti-messages* when a rollback invalidates them, which
//! can cascade. This implementation is the risk-free variant: a shard
//! **withholds** every boundary export until a global-virtual-time
//! (GVT) pass proves the generating event can no longer be rolled
//! back. Mis-speculation therefore never crosses a shard boundary — no
//! anti-messages, no cascades, and a rollback is always local to one
//! shard. The price is release latency (an export waits one GVT round);
//! the win is that correctness reasoning stays local.
//!
//! Each round has two barrier-separated phases:
//!
//! * **Phase 1 (speculate).** Each shard drains its mailbox (sorted by
//!   source shard — the same canonical `(round, source, generation)`
//!   merge order as the conservative engine). If any import's arrival
//!   time is at or below the shard's clock, the import is a
//!   *straggler*: the shard restores the newest checkpoint strictly
//!   older than the straggler, re-applies its import log from that
//!   point (the straggler merged in canonical order), and replays.
//!   Then it executes up to the next epoch boundary — at most
//!   `MAX_LAG_WINDOWS` windows past the committed horizon — draining
//!   its outbox after *every* event so each export is tagged with the
//!   generating event's time (`gen`) and its position in the shard's
//!   export stream (`pos`). Finally it publishes its **local minimum**:
//!   `min(next pending event time, min over withheld exports of their
//!   arrival time)`.
//!
//! * **Phase 2 (commit).** GVT = the minimum of all published local
//!   minima; `committed = max(committed, GVT)` (GVT itself can
//!   *regress* — a rollback re-publishes peeks from replay territory —
//!   so commitment keys on the running maximum, which is monotone).
//!   Each shard then releases the prefix of its withheld exports with
//!   `gen < committed` (strictly: an import at exactly `gen` could
//!   still reorder same-instant dispatch) into the destination
//!   mailboxes, and frees checkpoints older than the newest one below
//!   `committed` (that one must survive: it is the rollback target for
//!   any future straggler, every one of which arrives at or above
//!   `committed`).
//!
//! # Why replay is exact
//!
//! * An import's earliest effect at its receiver is its arrival time
//!   `at`, so replayed execution strictly below `at` is byte-identical
//!   to the rolled-back execution. Released exports all have
//!   `gen < committed ≤ at`, so the replays regenerate them —
//!   identically, and in the same stream order. The shard counts
//!   stream positions: checkpoints record `pos`, releases advance a
//!   `released` cursor, and a regenerated export with `pos < released`
//!   is simply dropped. No timestamp comparisons, no edge cases at
//!   equal instants.
//! * Exports still withheld at rollback with `pos` at or beyond the
//!   restored checkpoint's are dropped wholesale — the replay
//!   regenerates them (possibly differently, beyond the straggler).
//! * Same-`(time, key)` dispatch ties fall back to queue insertion
//!   order, which replay reproduces: the restored clone carries the
//!   event queue's sequence counter ([`crate::sim::EventQueue`] `Clone`
//!   docs), imports re-apply in log order, and handlers re-schedule in
//!   execution order.
//!
//! # Accounting
//!
//! [`Metrics::rollbacks`], [`Metrics::events_replayed`] and
//! [`Metrics::checkpoints_bytes`] are engine-level counters (zeroed by
//! [`Metrics::fabric_view`]) kept *outside* the per-shard [`Network`] —
//! state inside it rolls back, and replayed work must still be
//! counted. They fold into shard metrics when the run completes.
//!
//! [`Metrics::rollbacks`]: crate::metrics::Metrics::rollbacks
//! [`Metrics::events_replayed`]: crate::metrics::Metrics::events_replayed
//! [`Metrics::checkpoints_bytes`]: crate::metrics::Metrics::checkpoints_bytes
//! [`Metrics::fabric_view`]: crate::metrics::Metrics::fabric_view

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

use crate::network::{App, BoundaryMsg, Event, Network};
use crate::sim::Time;

/// Windows (of `lookahead` ns each) per speculative epoch: the
/// checkpoint cadence. Larger epochs amortize checkpoint cost but
/// lengthen replays.
const EPOCH_WINDOWS: u64 = 8;

/// Cap on how far (in windows) a shard may speculate past the committed
/// horizon. Bounds both wasted replay work and checkpoint memory: at
/// most `MAX_LAG_WINDOWS / EPOCH_WINDOWS + O(1)` checkpoints are live
/// per shard.
const MAX_LAG_WINDOWS: u64 = 32;

/// Per-shard inbox of released boundary events (source shard, message).
type Mailbox = Mutex<Vec<(u32, BoundaryMsg)>>;

/// A withheld boundary export.
struct Held {
    /// Position in the shard's export stream (see module docs).
    pos: u64,
    /// Time of the generating event (monotone in `pos`).
    gen: Time,
    /// Destination shard.
    dst: u32,
    msg: BoundaryMsg,
}

/// A full copy of one shard's simulation state plus the cursors needed
/// to resume its export stream and import log from this point.
struct Checkpoint<A> {
    /// Clock of the snapshot: every event at or below this time has
    /// executed, nothing above it has.
    time: Time,
    /// Export-stream position at snapshot time.
    pos: u64,
    /// Import-log entries applied at snapshot time (absolute index).
    applied: usize,
    /// Cumulative dispatch count at snapshot time (for replay
    /// accounting).
    dispatched: u64,
    net: Network,
    app: A,
}

/// Per-shard Time Warp bookkeeping, living *outside* the rolled-back
/// [`Network`] state.
struct TwState<A> {
    /// Live checkpoints, ascending in `time`.
    ckpts: Vec<Checkpoint<A>>,
    /// Every import ever applied, in canonical order; rollback replays
    /// a suffix. Pruned below the oldest live checkpoint's `applied`.
    log: Vec<(u32, BoundaryMsg)>,
    /// Absolute index of `log[0]`.
    log_base: usize,
    /// Absolute count of log entries applied to the live state.
    applied: usize,
    /// Withheld exports: exactly stream positions
    /// `[released, pos)` of the current execution line, front = oldest.
    held: VecDeque<Held>,
    /// Export-stream position of the next export to be generated.
    pos: u64,
    /// Exports released so far — a prefix of the stream.
    released: u64,
    rollbacks: u64,
    events_replayed: u64,
    checkpoints_bytes: u64,
}

impl<A: Clone> TwState<A> {
    fn new(net: &Network, app: &A) -> Self {
        // The initial checkpoint snapshots the entry state (clock =
        // the caller-synchronized entry clock, identical across
        // shards). Every import generated by this run arrives strictly
        // later, so it is always a valid rollback target — and the GC
        // rule keeps a below-`committed` checkpoint alive from here on.
        TwState {
            ckpts: vec![Checkpoint {
                time: net.sim.now(),
                pos: 0,
                applied: 0,
                dispatched: net.sim.dispatched(),
                net: net.clone(),
                app: app.clone(),
            }],
            log: Vec::new(),
            log_base: 0,
            applied: 0,
            held: VecDeque::new(),
            pos: 0,
            released: 0,
            rollbacks: 0,
            events_replayed: 0,
            checkpoints_bytes: 0,
        }
    }
}

/// One shard's worth of mutable state a worker claims per phase.
struct Slot<'a, A> {
    net: &'a mut Network,
    app: &'a mut A,
    tw: TwState<A>,
}

/// Rough resident size of one checkpoint: dense state vectors plus the
/// arena's live packets plus the pending event set. An estimate (heap
/// payloads inside packets and node state are not chased), tracked in
/// [`crate::metrics::Metrics::checkpoints_bytes`].
fn checkpoint_bytes(net: &Network) -> u64 {
    net.state_bytes()
        + net.packets.live() as u64 * std::mem::size_of::<crate::router::Packet>() as u64
        + net.sim.pending() as u64 * (std::mem::size_of::<Event>() as u64 + 24)
}

/// The optimistic epoch loop (see module docs). Drop-in replacement
/// for the conservative `run_epochs`: same shards, same apps, same
/// deadline semantics (events past `deadline` stay queued; clocks are
/// left at each shard's last event, callers re-synchronize), same
/// deterministic result regardless of thread interleaving.
pub(crate) fn run_epochs_optimistic<A: App + Send + Clone>(
    shards: &mut [Network],
    apps: &mut [A],
    deadline: Time,
    lookahead: Time,
    workers: usize,
) -> u64 {
    debug_assert_eq!(apps.len(), shards.len());
    let started: u64 = shards.iter().map(|s| s.sim.dispatched()).sum();
    let nshards = shards.len();
    let epoch = EPOCH_WINDOWS.saturating_mul(lookahead);
    let max_lag = MAX_LAG_WINDOWS.saturating_mul(lookahead);
    let Some(first) = shards.iter().filter_map(|s| s.sim.peek_time()).min() else {
        return 0;
    };
    if first > deadline {
        return 0;
    }

    let nworkers = workers.clamp(1, nshards);
    let barrier = Barrier::new(nworkers);
    let mailboxes: Vec<Mailbox> = (0..nshards).map(|_| Mutex::new(Vec::new())).collect();
    // Published per shard at the end of its Phase 1, stable until its
    // next Phase 1 (one barrier ahead of any reader):
    // local minimum (peek ∧ withheld arrival times) and withheld count.
    let local_mins: Vec<AtomicU64> = shards
        .iter()
        .map(|s| AtomicU64::new(s.sim.peek_time().unwrap_or(u64::MAX)))
        .collect();
    let held_counts: Vec<AtomicU64> = (0..nshards).map(|_| AtomicU64::new(0)).collect();
    // Running maximum of GVT (monotone; GVT itself can regress after a
    // rollback re-publishes replay-territory peeks).
    let committed = AtomicU64::new(0);
    // Earliest round in which a worker panicked (u64::MAX = none); see
    // the conservative engine for the epoch-tagged abort rationale.
    let abort_at = AtomicU64::new(u64::MAX);
    let next_a = AtomicUsize::new(0);
    let next_b = AtomicUsize::new(0);

    let slots: Vec<Mutex<Slot<A>>> = shards
        .iter_mut()
        .zip(apps.iter_mut())
        .map(|(net, app)| {
            let tw = TwState::new(net, app);
            Mutex::new(Slot { net, app, tw })
        })
        .collect();

    std::thread::scope(|scope| {
        for _ in 0..nworkers {
            let slots = &slots;
            let barrier = &barrier;
            let mailboxes = &mailboxes;
            let local_mins = &local_mins;
            let held_counts = &held_counts;
            let committed = &committed;
            let abort_at = &abort_at;
            let next_a = &next_a;
            let next_b = &next_b;
            scope.spawn(move || {
                let mut round: u64 = 0;
                loop {
                    let committed_v = committed.load(Ordering::SeqCst);
                    // Phase 1: speculate (drain mailbox, maybe roll
                    // back, import, execute one epoch, checkpoint,
                    // publish).
                    let ra = catch_unwind(AssertUnwindSafe(|| loop {
                        let c = next_a.fetch_add(1, Ordering::SeqCst);
                        if c >= nshards {
                            break;
                        }
                        let mut slot = slots[c].lock().unwrap();
                        let Slot { net, app, tw } = &mut *slot;
                        let sid = net.shard_id() as usize;

                        let mut batch =
                            std::mem::take(&mut *mailboxes[sid].lock().unwrap());
                        // Stable: preserves per-source generation order.
                        batch.sort_by_key(|(src, _)| *src);
                        let dispatched_start = net.sim.dispatched();
                        let mut rolled_back = false;

                        if let Some(min_at) = batch.iter().map(|(_, m)| m.at).min() {
                            if min_at <= net.sim.now() {
                                // Straggler: restore the newest
                                // checkpoint strictly before it. (One
                                // exists: every import arrives at or
                                // above `committed`, and GC keeps the
                                // newest checkpoint below `committed`.)
                                let t = tw
                                    .ckpts
                                    .iter()
                                    .rposition(|k| k.time < min_at)
                                    .expect("no checkpoint below straggler");
                                // Checkpoints above the target belong
                                // to the invalidated execution line.
                                tw.ckpts.truncate(t + 1);
                                let ck = &tw.ckpts[t];
                                tw.rollbacks += 1;
                                tw.events_replayed +=
                                    net.sim.dispatched() - ck.dispatched;
                                **net = ck.net.clone();
                                **app = ck.app.clone();
                                tw.pos = ck.pos;
                                tw.applied = ck.applied;
                                // Withheld exports the replay will
                                // regenerate; the survivors
                                // (`pos < ck.pos`) are shared history.
                                let floor = ck.pos;
                                tw.held.retain(|h| h.pos < floor);
                                rolled_back = true;
                            }
                        }

                        // Log the new imports, then (re-)apply every
                        // logged entry the live state has not seen —
                        // after a rollback that is the whole suffix
                        // from the restored checkpoint, straggler
                        // included, in canonical order.
                        tw.log.extend(batch);
                        let rel = tw.applied - tw.log_base;
                        if rel < tw.log.len() {
                            net.import_boundary(tw.log[rel..].to_vec());
                            tw.applied = tw.log_base + tw.log.len();
                        }

                        // Execute to the next epoch boundary, bounded
                        // by the speculation cap and the caller's
                        // deadline; drain the outbox per event so each
                        // export carries its generating time.
                        if let Some(peek) = net.sim.peek_time() {
                            let start = peek.max(net.sim.now().saturating_add(1));
                            let d = ((start / epoch) + 1)
                                .saturating_mul(epoch)
                                .saturating_sub(1)
                                .min(committed_v.saturating_add(max_lag))
                                .min(deadline);
                            while let Some((_, ev)) = net.sim.pop_until(d) {
                                net.handle(ev, *app);
                                for (dst, msg) in net.take_outbox() {
                                    if tw.pos < tw.released {
                                        // Regenerating an export that
                                        // was already released (replay
                                        // below the straggler is
                                        // byte-identical): drop it.
                                    } else {
                                        tw.held.push_back(Held {
                                            pos: tw.pos,
                                            gen: net.sim.now(),
                                            dst,
                                            msg,
                                        });
                                    }
                                    tw.pos += 1;
                                }
                            }
                        }

                        if rolled_back || net.sim.dispatched() != dispatched_start {
                            tw.checkpoints_bytes += checkpoint_bytes(net);
                            tw.ckpts.push(Checkpoint {
                                time: net.sim.now(),
                                pos: tw.pos,
                                applied: tw.applied,
                                dispatched: net.sim.dispatched(),
                                net: net.clone(),
                                app: app.clone(),
                            });
                        }

                        let mut lm = net.sim.peek_time().unwrap_or(u64::MAX);
                        for h in &tw.held {
                            lm = lm.min(h.msg.at);
                        }
                        local_mins[sid].store(lm, Ordering::SeqCst);
                        held_counts[sid].store(tw.held.len() as u64, Ordering::SeqCst);
                    }));
                    if ra.is_err() {
                        abort_at.fetch_min(round, Ordering::SeqCst);
                    }
                    if barrier.wait().is_leader() {
                        next_a.store(0, Ordering::SeqCst);
                    }

                    // Phase 2: commit. Every worker derives the same
                    // GVT from the same published local minima, so the
                    // fetch_max settles on the same `committed`
                    // everywhere.
                    let gvt = local_mins
                        .iter()
                        .map(|p| p.load(Ordering::SeqCst))
                        .min()
                        .unwrap_or(u64::MAX);
                    committed.fetch_max(gvt, Ordering::SeqCst);
                    let com = committed.load(Ordering::SeqCst);
                    let healthy = abort_at.load(Ordering::SeqCst) > round;
                    let rb = if ra.is_ok() && healthy {
                        catch_unwind(AssertUnwindSafe(|| loop {
                            let c = next_b.fetch_add(1, Ordering::SeqCst);
                            if c >= nshards {
                                break;
                            }
                            let mut slot = slots[c].lock().unwrap();
                            let Slot { net, tw, .. } = &mut *slot;
                            let sid = net.shard_id();
                            // Release the committed prefix of the
                            // export stream. Strict `<`: an import at
                            // exactly `gen` could still reorder
                            // same-instant dispatch at the generator.
                            while tw.held.front().is_some_and(|h| h.gen < com) {
                                let h = tw.held.pop_front().unwrap();
                                mailboxes[h.dst as usize]
                                    .lock()
                                    .unwrap()
                                    .push((sid, h.msg));
                                tw.released += 1;
                            }
                            // Free checkpoints older than the newest
                            // one below `committed` — that one is the
                            // rollback target for any future
                            // straggler (all arrive ≥ committed).
                            if let Some(keep) =
                                tw.ckpts.iter().rposition(|k| k.time < com)
                            {
                                if keep > 0 {
                                    tw.ckpts.drain(..keep);
                                }
                            }
                            // Prune the import log below the oldest
                            // surviving checkpoint: no rollback can
                            // need it again.
                            let floor =
                                tw.ckpts.first().map_or(tw.applied, |k| k.applied);
                            if floor > tw.log_base {
                                let cut = floor - tw.log_base;
                                tw.log.drain(..cut);
                                tw.log_base = floor;
                            }
                        }))
                    } else {
                        Ok(())
                    };
                    if rb.is_err() {
                        abort_at.fetch_min(round, Ordering::SeqCst);
                    }
                    if barrier.wait().is_leader() {
                        next_b.store(0, Ordering::SeqCst);
                    }
                    if abort_at.load(Ordering::SeqCst) <= round {
                        if let Err(p) = ra {
                            resume_unwind(p);
                        }
                        if let Err(p) = rb {
                            resume_unwind(p);
                        }
                        break;
                    }

                    // Termination: nothing pending below the deadline
                    // and nothing withheld anywhere. The held counts
                    // are pre-release (published in Phase 1), so a
                    // final flush round runs once before exit — by
                    // then `committed > deadline ≥` every withheld
                    // `gen`, so the flush is total.
                    let any_held =
                        held_counts.iter().any(|h| h.load(Ordering::SeqCst) > 0);
                    if (gvt == u64::MAX || gvt > deadline) && !any_held {
                        break;
                    }
                    round += 1;
                }
            });
        }
    });

    // Fold the engine-level counters into shard metrics now that the
    // final state is committed (inside a Network they would have been
    // rolled back with it).
    for slot in slots {
        let s = slot.into_inner().unwrap();
        s.net.metrics.rollbacks += s.tw.rollbacks;
        s.net.metrics.events_replayed += s.tw.events_replayed;
        s.net.metrics.checkpoints_bytes += s.tw.checkpoints_bytes;
    }
    shards.iter().map(|s| s.sim.dispatched()).sum::<u64>() - started
}

#[cfg(test)]
mod tests {
    use crate::config::{SystemConfig, SystemPreset};
    use crate::network::sharded::ShardedNetwork;
    use crate::network::{Network, NullApp};
    use crate::router::{Payload, Proto};
    use crate::topology::NodeId;

    /// A seeded scenario that *must* roll back: shard 3 of an Inc3000
    /// (nodes with y ≥ 9) is kept busy with local traffic and timers
    /// spread over ~30 µs, so it speculates whole epochs ahead; shard 0
    /// injects one cross-mesh packet at t=0 whose release reaches
    /// shard 3 only after a GVT round — by which time shard 3's clock
    /// has passed the arrival time. Byte-identity with the serial
    /// oracle must survive the rollback, and the engine counters must
    /// record it.
    #[test]
    fn seeded_straggler_rolls_back_and_stays_byte_identical() {
        let cfg = SystemConfig::new(SystemPreset::Inc3000);
        let mut serial = Network::new(cfg.clone());
        serial.enable_trace();
        let mut opt = ShardedNetwork::new(cfg, 4);
        opt.set_optimistic(true);
        opt.enable_trace();

        let drive = |send: &mut dyn FnMut(NodeId, NodeId), timer: &mut dyn FnMut(u64, NodeId)| {
            // Local traffic inside shard 3 (y in 9..12).
            for i in 0..24u32 {
                let src = NodeId((2 * 12 + 9 + (i % 3)) * 12 + (i % 12));
                let dst = NodeId((9 + ((i + 1) % 3)) * 12 + ((i * 5) % 12));
                if src != dst {
                    send(src, dst);
                }
            }
            // Timers keep shard 3's queue non-empty deep into the run,
            // so it speculates past the straggler's arrival.
            for k in 0..300u64 {
                timer(k * 100, NodeId(9 * 12 + 3));
            }
            // The straggler source: one packet from shard 0 (y = 0)
            // into the middle of shard 3.
            send(NodeId(0), NodeId(10 * 12 + 6));
        };

        drive(
            &mut |s, d| {
                serial.send_directed(s, d, Proto::Raw { tag: 7 }, Payload::Synthetic(96));
            },
            &mut |t, n| serial.timer_at(t, n, 42),
        );
        drive(
            &mut |s, d| {
                opt.send_directed(s, d, Proto::Raw { tag: 7 }, Payload::Synthetic(96));
            },
            &mut |t, n| opt.timer_at(t, n, 42),
        );

        serial.run_to_quiescence(&mut NullApp);
        opt.run_to_quiescence();

        let mut st = serial.take_trace();
        st.sort_unstable();
        assert_eq!(st, opt.take_trace(), "trace diverged under rollback");
        assert_eq!(serial.metrics.fabric_view(), opt.metrics().fabric_view());
        assert_eq!(serial.now(), opt.now());
        assert_eq!(opt.live_packets(), 0, "arena leak across rollback");

        let m = opt.metrics();
        assert!(m.rollbacks > 0, "scenario is seeded to force a rollback");
        assert!(m.events_replayed > 0);
        assert!(m.checkpoints_bytes > 0);
        // Engine counters stay out of the byte-identity contract.
        assert_eq!(m.fabric_view().rollbacks, 0);
    }

    /// One shard has no boundaries: the optimistic runner degenerates
    /// to epoch-paced serial execution — no rollbacks, still identical.
    #[test]
    fn single_shard_optimistic_matches_serial() {
        let cfg = SystemConfig::card();
        let mut serial = Network::new(cfg.clone());
        serial.enable_trace();
        let mut opt = ShardedNetwork::new(cfg, 1);
        opt.set_optimistic(true);
        opt.enable_trace();
        for i in 0..8u32 {
            let (s, d) = (NodeId(i), NodeId(26 - i));
            serial.send_directed(s, d, Proto::Raw { tag: 0 }, Payload::Synthetic(64));
            opt.send_directed(s, d, Proto::Raw { tag: 0 }, Payload::Synthetic(64));
        }
        serial.run_to_quiescence(&mut NullApp);
        opt.run_to_quiescence();
        let mut st = serial.take_trace();
        st.sort_unstable();
        assert_eq!(st, opt.take_trace());
        assert_eq!(serial.metrics.fabric_view(), opt.metrics().fabric_view());
        assert_eq!(serial.now(), opt.now());
        assert_eq!(opt.metrics().rollbacks, 0, "no boundaries, no stragglers");
        assert!(opt.metrics().checkpoints_bytes > 0, "epochs still checkpoint");
    }

    #[test]
    fn optimistic_empty_run_terminates() {
        let mut opt = ShardedNetwork::new(SystemConfig::card(), 1);
        opt.set_optimistic(true);
        assert_eq!(opt.run_to_quiescence(), 0);
        assert_eq!(opt.now(), 0);
    }
}
