//! Bounded-lag per-cage parallel simulation (`ShardedNetwork`).
//!
//! The INC 9000 stacks four cages of 432 nodes (§2.1, Fig 2a), and all
//! inter-cage traffic is confined to multi-span z links — exactly the
//! partition boundary a conservative parallel discrete-event simulator
//! wants. `ShardedNetwork` runs one [`Network`] per cage (falling back
//! to per-card sharding for `Inc3000`/`Card`, see
//! [`Topology::partition`]); each shard owns its own event wheel,
//! packet arena, link and node state, while the [`Topology`] is shared
//! read-only behind an `Arc`.
//!
//! # Bounded-lag epochs
//!
//! Shards advance in lockstep through windows of `lookahead` ns, where
//! `lookahead` is the minimum latency of *any* cross-boundary event:
//!
//! * an `Arrive` on a boundary link takes `router_latency + ser(bytes)
//!   ≥ router_latency + ser(header)`;
//! * the returning `Credit` takes exactly `router_latency`;
//!
//! so `lookahead = router_latency` (684 ns by default). An event
//! executing in window `k` (`[k·L, (k+1)·L)`) can only schedule
//! cross-boundary work at `≥ (k+1)·L`, i.e. in a later window — shards
//! therefore never see a boundary event "from the past". Between
//! windows, boundary events travel through per-shard mailboxes and are
//! merged in a fixed `(epoch, source shard, generation seq)` order, so
//! the run is deterministic regardless of thread interleaving. Windows
//! with no work are skipped (the next window index is derived from the
//! global minimum pending-event time).
//!
//! # Work-stealing: shard counts beyond core counts
//!
//! Shards and worker threads are independent axes: `--shards 64` on an
//! 8-core machine runs 64 shards on 8 workers. Within each phase of a
//! window, workers *claim* shards off a shared atomic counter instead
//! of walking fixed chunks, so a worker stuck in one shard's heavy
//! window never idles the rest of the fleet behind a static
//! assignment. Determinism is untouched: each shard is claimed by
//! exactly one worker per phase, its per-shard computation depends
//! only on its own state and the peeks published behind the previous
//! barrier (not on *which* thread runs it), and the mailbox merge
//! stays in canonical `(epoch, source shard, seq)` order because phase
//! B sorts every inbox by source shard before importing.
//!
//! # Distance-aware multi-shard epoch batching
//!
//! On sparse traffic the cost is not the windows with work but the
//! *barriers* around them. The lockstep window only exists to bound how
//! far a shard may run before another shard's activity can reach it —
//! and that bound is **per shard pair**, not global: influence crosses
//! the mesh one link per event, and every link crossing (an `Arrive`
//! forward or a `Credit` back) costs at least one router latency, so an
//! event pending on shard `j` at time `t` cannot cause an import into
//! shard `i` before `t + hops(j, i) × router_latency`, where
//! `hops(j, i)` is the minimum link distance between the shards'
//! boundary nodes ([`Topology::shard_hop_matrix`], precomputed at
//! construction).
//!
//! At every epoch each shard therefore derives its **horizon** — the
//! minimum over other shards of their published next-event time plus
//! the pairwise lookahead — and any shard whose horizon clears the
//! lockstep window runs **exclusively** past it: no window deadline, no
//! barriers, until it quiesces or reaches its horizon. Two sharpenings
//! tighten the classic scheme:
//!
//! * **Per-node head bounds.** When a shard's head event provably
//!   cannot reach application code (Drain/Credit — pure link
//!   machinery), the shard publishes the head's node alongside its
//!   peek, and peers bound that event by the *node's* card distance
//!   ([`Topology::card_shard_distances`]) while bounding the rest of
//!   the queue by the second-earliest event time
//!   ([`crate::sim::Sim::peek_second_time_lb`]) at the pair distance.
//!   Interior work then supports longer sprints than the whole-shard
//!   boundary minimum would allow.
//! * **Sprint continuation.** A boundary export does not end a sprint:
//!   every *other* shard's horizon already accounts for it, and only
//!   the exporting shard's own horizon misses the possible bounce-back
//!   — so the sprint continues with its deadline clamped to the
//!   export's timestamp plus the return-trip pair lookahead (see
//!   `Network::run_exclusive`).
//!
//! Several shards can sprint
//! *simultaneously* — traffic local to far-apart partitions proceeds
//! barrier-free in all of them at once. All workers derive every
//! decision from the same published next-event times and the same
//! static matrix, so the schedule is deterministic, and a sprinting
//! shard processes its queue in exactly the order the windowed schedule
//! would have. Coalesced windows are counted per shard in
//! [`Metrics::windows_merged`] — an engine-level counter, excluded from
//! the byte-identity contract via [`Metrics::fabric_view`]. A
//! single-shard "sharded" run has an infinite horizon and degenerates
//! to one long sprint, i.e. to serial execution with two barriers
//! total; a shard that is alone in having pending events (the old
//! "solo sprint" special case) likewise sees an infinite horizon.
//!
//! # Optimistic (Time Warp) execution
//!
//! [`ShardedNetwork::set_optimistic`] swaps the conservative epoch loop
//! for the speculative runner in [`crate::network::timewarp`]: shards
//! checkpoint their state at epoch boundaries, run ahead of any horizon
//! on the live state, and roll back + replay when an import lands in
//! their speculated past. Exports are withheld until a global-virtual-
//! time pass commits them, so mis-speculation never propagates (no
//! anti-messages) and the run stays byte-identical to the serial
//! engine. See the timewarp module docs for the protocol.
//!
//! # Byte-identical to the serial engine
//!
//! The headline property (differential-tested in
//! `tests/sharded_differential.rs`): a sharded run produces the same
//! delivery trace, metrics and final clock as [`Network`] run serially,
//! byte for byte. Three serial-engine design points make this possible
//! (see the "dispatch-order independence" notes in [`crate::network`]):
//! content-keyed same-instant event ordering, per-packet tie-break
//! hashes instead of an RNG stream, and driver-side packet-id
//! assignment (the wrapper APIs here sync one global id cursor into the
//! owning shard around every call). Same-`(time, key)` events whose
//! relative order *can* differ between engines have commuting handlers
//! by construction of the key scheme.
//!
//! # Scope
//!
//! Each shard is a [`Network`] over an **owned-subset state domain**
//! ([`crate::network::Domain`]): its `links`/`nodes`/`failed_links`/NIC
//! vectors hold exactly the owned partition — node state for owned
//! nodes, transmit-side link state for links leaving them — behind
//! O(owned) global↔local index maps, so a k-shard run holds ~1/k of
//! the mesh state per shard with index overhead proportional to the
//! owned counts, not the mesh (the per-shard slices sum to the serial
//! engine's state exactly; [`Metrics::state_bytes`] and the
//! `inc9000_domain` bench rows track the cut — what makes the
//! `Inc27000`/`Inc100k` mega presets affordable at 64 shards). Un-owned state simply
//! does not exist on a shard: indexing it debug-asserts with the shard
//! named, and panics out of bounds in release, instead of silently
//! reading an idle full-mesh copy as the pre-domain engine did.
//! Node-level *registries* (channel tables, endpoint lanes — hash maps,
//! not per-node vectors) still replicate to every shard so send-side
//! checks agree everywhere.
//!
//! Workloads ride the parallel engine through the engine-agnostic
//! [`Fabric`] trait: [`ShardedNetwork::run_app`] splits a
//! [`ShardableApp`] into one partition per shard, each partition sees
//! the callbacks for its shard's nodes in the serial engine's exact
//! order (byte-identity extends to app-originated traffic via per-node
//! packet ids — [`crate::network::Network::app_packet_id`]), and the
//! partitions fold back commutatively at the end of the run. All five
//! traffic classes cross shard boundaries: directed/broadcast/multicast
//! raw, Bridge FIFO, Postmaster, NetTunnel, and internal Ethernet
//! (frames ride inside their packet — `Packet::eth_frame` — so the
//! receive side needs no transmit-side table).
//!
//! [`App`]: crate::network::App
//! [`Fabric`]: crate::network::Fabric
//! [`ShardableApp`]: crate::network::ShardableApp
//! [`Metrics::windows_merged`]: crate::metrics::Metrics::windows_merged
//! [`Metrics::fabric_view`]: crate::metrics::Metrics::fabric_view

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};

use crate::channels::endpoint::{CommMode, Endpoint, Message, MsgId};
use crate::channels::reliable::ReliableParams;
use crate::config::SystemConfig;
use crate::metrics::Metrics;
use crate::network::{
    App, BoundaryMsg, Delivery, Domain, Network, NullApp, ShardCtx, ShardableApp,
};
use crate::router::{Payload, Proto};
use crate::sim::Time;
use crate::topology::{LinkId, NodeId, Topology};

/// Per-shard inbox of boundary events, as (source shard, message).
type Mailbox = Mutex<Vec<(u32, BoundaryMsg)>>;

/// One [`Network`] per cage (or card group), advancing in bounded-lag
/// lockstep. See the module docs.
pub struct ShardedNetwork {
    shards: Vec<Network>,
    /// Owner shard per node (shared with every shard's `ShardCtx`).
    owner: Arc<Vec<u32>>,
    /// The topology all shards reference.
    pub topo: Arc<Topology>,
    /// Epoch window length, ns (= minimum cross-boundary latency).
    lookahead: Time,
    /// Pairwise lookahead, ns: flat `shards × shards` row-major matrix,
    /// entry `[j][i]` = minimum link-hop distance between shards j and
    /// i × router latency — the soonest an event pending on j can cause
    /// an import into i (see the module docs, "Distance-aware
    /// multi-shard epoch batching").
    pair_lookahead: Vec<u64>,
    /// Per-card sharpening of the pair matrix: flat `cards × shards`
    /// hop counts indexed `[card_index * shards + shard]`
    /// ([`Topology::card_shard_distances`]). Lets a peer bound a
    /// shard's *head* event by the head node's own distance instead of
    /// the whole-shard minimum — interior work then supports longer
    /// sprints. `None` when the table would be unreasonably large
    /// (mega meshes at high shard counts); peers fall back to the pair
    /// bound.
    card_hops: Option<Vec<u32>>,
    /// Worker threads driving the shards.
    workers: usize,
    /// Run epochs speculatively (Time Warp) instead of conservatively
    /// (see [`crate::network::timewarp`]).
    optimistic: bool,
    /// Global packet-id cursor, synced into shards around driver calls
    /// so ids match the serial engine exactly.
    next_packet_id: u64,
}

impl ShardedNetwork {
    /// Build a sharded system. `shards` is clamped to the card count of
    /// the preset (16 for `Inc3000`, 64 for `Inc9000`, 1024 for
    /// `Inc27000`, 1 for `Card`); requests at or below the cage count
    /// still partition cage-granular (4 cages for `Inc9000`).
    pub fn new(cfg: SystemConfig, shards: u32) -> Self {
        let topo = Arc::new(Topology::preset(cfg.preset));
        let (owner, count) = topo.partition(shards);
        let owner = Arc::new(owner);
        // The cheapest cross-boundary event is a Credit: exactly one
        // router latency. (An Arrive adds at least ser(header) on top.)
        // Zero lookahead would let boundary events land inside the
        // window that produced them — the serial/sharded byte-identity
        // contract cannot hold, so reject such configs loudly instead
        // of clamping and silently diverging.
        assert!(
            cfg.link.router_latency >= 1,
            "sharded simulation needs link.router_latency >= 1 ns for a \
             positive conservative lookahead"
        );
        let lookahead = cfg.link.router_latency;
        // Distance-aware per-pair lookahead: hops between shard
        // boundary nodes × the per-link-crossing minimum latency.
        let pair_lookahead: Vec<u64> = topo
            .shard_hop_matrix(&owner, count)
            .iter()
            .map(|&h| h as u64 * lookahead)
            .collect();
        // Per-card refinement of the same matrix, gated by size: 8M
        // u32 entries (32 MB) covers every preset through Inc100k at
        // 1024 shards with room to spare; beyond that the pair bound
        // alone is still correct, just less sharp.
        let ncards = topo.cards().len();
        let card_hops = if ncards.saturating_mul(count as usize) <= 8_000_000 {
            Some(topo.card_shard_distances(&owner, count))
        } else {
            None
        };
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let requested = if cfg.sim_threads > 0 { cfg.sim_threads } else { hw };
        let workers = requested.clamp(1, count as usize);
        let shards = (0..count)
            .map(|i| {
                // Each shard holds state for its owned subset only
                // (dense-remapped; see `network::domain`).
                let domain = Arc::new(Domain::owned(&topo, &owner, i));
                let mut net = Network::with_domain(cfg.clone(), topo.clone(), domain);
                net.shard_ctx =
                    Some(ShardCtx { shard: i, owner: owner.clone(), outbox: Vec::new() });
                net
            })
            .collect();
        ShardedNetwork {
            shards,
            owner,
            topo,
            lookahead,
            pair_lookahead,
            card_hops,
            workers,
            optimistic: false,
            next_packet_id: 0,
        }
    }

    /// Switch the epoch runner to optimistic (Time Warp) execution:
    /// shards speculate past the conservative horizon on checkpointed
    /// state and roll back on stragglers (see
    /// [`crate::network::timewarp`]). The result is byte-identical to
    /// the conservative runner — and to the serial engine — either way;
    /// only wall clock and the engine-level counters
    /// ([`Metrics::rollbacks`], [`Metrics::events_replayed`],
    /// [`Metrics::checkpoints_bytes`]) differ.
    ///
    /// [`Metrics::rollbacks`]: crate::metrics::Metrics::rollbacks
    /// [`Metrics::events_replayed`]: crate::metrics::Metrics::events_replayed
    /// [`Metrics::checkpoints_bytes`]: crate::metrics::Metrics::checkpoints_bytes
    pub fn set_optimistic(&mut self, on: bool) {
        self.optimistic = on;
    }

    /// Whether the optimistic (Time Warp) runner is enabled.
    pub fn is_optimistic(&self) -> bool {
        self.optimistic
    }

    /// Natural shard count of a preset (what `new` clamps to).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Worker threads the run loop will use.
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    /// Epoch window length in ns.
    pub fn lookahead(&self) -> Time {
        self.lookahead
    }

    /// The shards themselves (read-only; per-shard inboxes, metrics and
    /// node state live here).
    pub fn shards(&self) -> &[Network] {
        &self.shards
    }

    /// Owning shard of `node`.
    pub fn shard_of(&self, node: NodeId) -> usize {
        self.owner[node.0 as usize] as usize
    }

    /// Mutable access to the shard owning `node` (driver-side state
    /// setup; do not schedule events directly).
    pub fn shard_mut(&mut self, node: NodeId) -> &mut Network {
        let s = self.shard_of(node);
        &mut self.shards[s]
    }

    /// Record a reroute-convergence figure (see
    /// [`crate::network::Fabric::record_reroute_convergence`]). Stored
    /// on shard 0 only: [`Metrics::merge`] combines the field by max,
    /// so the aggregate equals the serial engine's figure instead of
    /// multiplying it by the shard count.
    ///
    /// [`Metrics::merge`]: crate::metrics::Metrics::merge
    pub fn record_reroute_convergence(&mut self, ns: crate::sim::Time) {
        let m = &mut self.shards[0].metrics;
        m.reroute_convergence_ns = m.reroute_convergence_ns.max(ns);
    }

    /// Run `f` against the shard owning `node` with the global
    /// packet-id cursor synced in and back out, so id assignment
    /// matches a serial run call for call.
    fn with_shard<R>(&mut self, node: NodeId, f: impl FnOnce(&mut Network) -> R) -> R {
        let s = self.shard_of(node);
        self.shards[s].set_packet_id_cursor(self.next_packet_id);
        let r = f(&mut self.shards[s]);
        self.next_packet_id = self.shards[s].packet_id_cursor();
        r
    }

    // -----------------------------------------------------------------
    // Driver APIs (mirror `Network`'s, routed to the owning shard)
    // -----------------------------------------------------------------

    /// See [`Network::send_directed`].
    pub fn send_directed(
        &mut self,
        src: NodeId,
        dst: NodeId,
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        self.with_shard(src, |n| n.send_directed(src, dst, proto, payload))
    }

    /// See [`Network::send_broadcast`].
    pub fn send_broadcast(&mut self, src: NodeId, proto: Proto, payload: Payload) -> u64 {
        self.with_shard(src, |n| n.send_broadcast(src, proto, payload))
    }

    /// See [`Network::send_multicast`].
    pub fn send_multicast(
        &mut self,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        self.with_shard(src, |n| n.send_multicast(src, dsts, proto, payload))
    }

    /// See [`Network::app_multicast_at`] (routed to the shard owning
    /// `src`; per-node app ids throughout, so no cursor sync is needed).
    pub fn app_multicast_at(
        &mut self,
        at: Time,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        self.shard_mut(src).app_multicast_at(at, src, dsts, proto, payload)
    }

    /// See [`Network::timer_at`] (scheduled on the shard owning `node`,
    /// where the timer fires; timers carry no packet id, so no cursor
    /// sync is needed).
    pub fn timer_at(&mut self, at: Time, node: NodeId, tag: u64) {
        self.shard_mut(node).timer_at(at, node, tag)
    }

    /// See [`Network::fifo_connect`] (registered on every shard: the
    /// write port is used by the source shard, the read port by the
    /// destination shard).
    pub fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8, width_bits: u8) {
        for sh in &mut self.shards {
            sh.fifo_connect(src, dst, channel, width_bits);
        }
    }

    /// See [`Network::fifo_send`].
    pub fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]) {
        self.with_shard(src, |n| n.fifo_send(src, channel, words));
    }

    /// See [`Network::fifo_read`] (reads the destination shard's port).
    pub fn fifo_read(&mut self, node: NodeId, channel: u8, max: usize) -> Vec<u64> {
        self.shard_mut(node).fifo_read(node, channel, max)
    }

    /// See [`Network::pm_open`] (registered on every shard).
    pub fn pm_open(&mut self, target: NodeId, queue: u8) {
        for sh in &mut self.shards {
            sh.pm_open(target, queue);
        }
    }

    /// See [`Network::pm_send`].
    pub fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>) {
        self.with_shard(src, |n| n.pm_send(src, target, queue, data));
    }

    // -----------------------------------------------------------------
    // The unified Endpoint API (see `channels::endpoint`): node-level
    // registries replicate to every shard (like `pm_open` /
    // `fifo_connect`, so send-side checks and receive-side capture
    // agree everywhere); sends and receives route to the owning shard.
    // Everything uses per-node ids, so no cursor sync is needed and the
    // calls stay byte-identical to the serial engine.
    // -----------------------------------------------------------------

    /// See [`Network::open`] (registered on every shard).
    pub fn open(&mut self, node: NodeId, mode: CommMode) -> Endpoint {
        let mut ep = Endpoint { node, mode };
        for sh in &mut self.shards {
            ep = sh.open(node, mode);
        }
        ep
    }

    /// See [`Network::connect`] (registered on every shard; the
    /// deterministic channel allocation picks the same id everywhere).
    pub fn connect(&mut self, ep: &Endpoint, dst: NodeId) {
        for sh in &mut self.shards {
            sh.connect(ep, dst);
        }
    }

    /// See [`Network::send`].
    pub fn send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        let now = self.now();
        self.send_at(now, ep, dst, msg)
    }

    /// See [`Network::send_at`]. `Nfs` is the one mode routed through
    /// the gateway-aware [`ShardedNetwork::nfs_put`] wrapper (its
    /// transfer state must live on the gateway's shard); everything
    /// else goes straight to the shard owning `ep.node`.
    pub fn send_at(&mut self, at: Time, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        match ep.mode {
            CommMode::Nfs => {
                let seq = self.shard_mut(ep.node).comm_next_msg_seq(ep.node);
                let name = crate::channels::endpoint::comm_nfs_name(ep.node, seq);
                let len = msg.data.len() as u64;
                self.nfs_put(ep.node, &name, len);
                crate::channels::endpoint::comm_msg_id(ep.node, seq)
            }
            _ => self.shard_mut(ep.node).send_at(at, ep, dst, msg),
        }
    }

    /// See [`Network::recv`] (drains the owning shard's inbox).
    pub fn recv(&mut self, ep: &Endpoint) -> Vec<Message> {
        self.shard_mut(ep.node).recv(ep)
    }

    /// See [`Network::open_with_rx_capacity`] (registered on every
    /// shard, like [`ShardedNetwork::open`]).
    pub fn open_with_rx_capacity(&mut self, node: NodeId, mode: CommMode, cap: u32) -> Endpoint {
        let mut ep = Endpoint { node, mode };
        for sh in &mut self.shards {
            ep = sh.open_with_rx_capacity(node, mode, cap);
        }
        ep
    }

    /// See [`Network::reliable_open`] (registered on every shard, like
    /// [`ShardedNetwork::open`]; the transport's *flow* state still
    /// lives only on the owning shard — sends and deliveries all
    /// execute there).
    pub fn reliable_open(
        &mut self,
        node: NodeId,
        mode: CommMode,
        params: ReliableParams,
    ) -> Endpoint {
        let mut ep = Endpoint { node, mode };
        for sh in &mut self.shards {
            ep = sh.reliable_open(node, mode, params);
        }
        ep
    }

    /// See [`Network::reliable_send`].
    pub fn reliable_send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        let now = self.now();
        self.reliable_send_at(now, ep, dst, msg)
    }

    /// See [`Network::reliable_send_at`] (routed to the shard owning
    /// `ep.node`, where the flow's retransmit queue and timers live;
    /// per-node ids throughout, so no cursor sync is needed).
    pub fn reliable_send_at(
        &mut self,
        at: Time,
        ep: &Endpoint,
        dst: NodeId,
        msg: Message,
    ) -> MsgId {
        self.shard_mut(ep.node).reliable_send_at(at, ep, dst, msg)
    }

    /// See [`Network::reliable_watch`].
    pub fn reliable_watch(&mut self, ep: &Endpoint, peer: NodeId, until: Time) {
        self.shard_mut(ep.node).reliable_watch(ep, peer, until)
    }

    /// See [`Network::reliable_is_down`].
    pub fn reliable_is_down(&self, ep: &Endpoint, peer: NodeId) -> bool {
        self.shards[self.shard_of(ep.node)].reliable_is_down(ep, peer)
    }

    /// See [`Network::reliable_take_unacked`].
    pub fn reliable_take_unacked(&mut self, ep: &Endpoint, peer: NodeId) -> Vec<Message> {
        self.shard_mut(ep.node).reliable_take_unacked(ep, peer)
    }

    /// See [`Network::tunnel_write`].
    pub fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64) {
        self.with_shard(src, |n| n.tunnel_write(src, dst, addr, value));
    }

    /// See [`Network::tunnel_read`]. The result lands in the shard
    /// owning `src`; fetch it with [`ShardedNetwork::tunnel_result`].
    pub fn tunnel_read(&mut self, src: NodeId, dst: NodeId, addr: u64) -> u64 {
        self.with_shard(src, |n| n.tunnel_read(src, dst, addr))
    }

    /// See [`Network::tunnel_result`] (checks every shard).
    pub fn tunnel_result(&self, req_id: u64) -> Option<u64> {
        self.shards.iter().find_map(|s| s.tunnel_result(req_id))
    }

    /// See [`Network::fail_link`]. A link's failure flag lives with its
    /// transmit-side state — on the shard owning `src` — and routing
    /// only ever consults it there, so the wrapper routes to exactly
    /// that shard (the owned-subset domains hold nothing else).
    pub fn fail_link(&mut self, l: LinkId) {
        let s = self.shard_of(self.topo.link(l).src);
        self.shards[s].fail_link(l);
    }

    /// See [`Network::repair_link`].
    pub fn repair_link(&mut self, l: LinkId) {
        let s = self.shard_of(self.topo.link(l).src);
        self.shards[s].repair_link(l);
    }

    /// See [`Network::eth_send`] (transmit-side software costs accrue on
    /// the shard owning `src`; the frame crosses boundaries inside its
    /// packet).
    pub fn eth_send(&mut self, src: NodeId, dst: NodeId, bytes: u32, tag: u64) {
        self.with_shard(src, |n| n.eth_send(src, dst, bytes, tag));
    }

    /// See [`Network::eth_send_message`].
    pub fn eth_send_message(&mut self, src: NodeId, dst: NodeId, bytes: u64, tag: u64) -> u32 {
        self.with_shard(src, |n| n.eth_send_message(src, dst, bytes, tag))
    }

    /// See [`Network::nfs_put`]. The transfer's gateway-side progress
    /// state must live where the frames arrive — the shard owning the
    /// gateway — while the frames themselves stream from `node`'s
    /// shard.
    pub fn nfs_put(&mut self, node: NodeId, name: &str, size: u64) {
        let gw = self.gateway();
        if self.shard_of(node) == self.shard_of(gw) {
            self.with_shard(node, |n| n.nfs_put(node, name, size));
        } else {
            let gs = self.shard_of(gw);
            self.shards[gs].nfs_register_put(node, name, size);
            let tag = crate::channels::ethernet::nfs_tag(name);
            self.eth_send_message(node, gw, size, tag);
        }
    }

    /// The gateway node (see [`Network::gateway`]).
    pub fn gateway(&self) -> NodeId {
        self.topo.gateway_node((0, 0, 0))
    }

    /// See [`Network::nat_forward`]: the NAT table lives on the
    /// gateway's shard (ingress frames are created there).
    pub fn nat_forward(&mut self, external_port: u16, node: NodeId, internal_port: u16) {
        let gw = self.gateway();
        self.shard_mut(gw).nat_forward(external_port, node, internal_port);
    }

    /// See [`Network::external_ingress_at`]: runs on the gateway's
    /// shard with the global packet-id cursor synced in and out, so
    /// ingress frames carry the ids a serial run would assign.
    pub fn external_ingress_at(
        &mut self,
        at: Time,
        external_port: u16,
        bytes: u32,
        tag: u64,
    ) -> bool {
        let gw = self.gateway();
        self.with_shard(gw, |n| n.external_ingress_at(at, external_port, bytes, tag))
    }

    /// The external world behind the gateway's physical port (NFS files,
    /// NAT table, egress counters) — it lives on the gateway's shard.
    pub fn eth_external(&self) -> &crate::channels::ethernet::ExternalWorld {
        let gs = self.owner[self.gateway().0 as usize] as usize;
        &self.shards[gs].eth.external
    }

    /// The system configuration (identical on every shard).
    pub fn config(&self) -> &crate::config::SystemConfig {
        &self.shards[0].cfg
    }

    /// Advance every shard's clock to `t` if it is ahead; no-op
    /// otherwise (see [`crate::sim::Sim::catch_up_to`]).
    pub fn advance_to(&mut self, t: Time) {
        for sh in &mut self.shards {
            sh.sim.catch_up_to(t);
        }
    }

    /// Record the delivery trace on every shard (see
    /// [`ShardedNetwork::take_trace`]).
    pub fn enable_trace(&mut self) {
        for sh in &mut self.shards {
            sh.enable_trace();
        }
    }

    // -----------------------------------------------------------------
    // Aggregates
    // -----------------------------------------------------------------

    /// Final clock: the latest event time across shards (equals the
    /// serial engine's quiescence clock).
    pub fn now(&self) -> Time {
        self.shards.iter().map(|s| s.now()).max().unwrap_or(0)
    }

    /// Merged metrics across shards. The *fabric* counters are
    /// byte-identical to a serial run's; engine-level counters
    /// ([`Metrics::windows_merged`]) are nonzero only here, so compare
    /// engines through [`Metrics::fabric_view`].
    pub fn metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        for sh in &self.shards {
            m.merge(&sh.metrics);
        }
        m
    }

    /// Merged delivery trace in the canonical [`Delivery`] order
    /// (byte-identical to a serial run's sorted trace).
    pub fn take_trace(&mut self) -> Vec<Delivery> {
        let mut all = Vec::new();
        for sh in &mut self.shards {
            all.extend(sh.take_trace());
        }
        all.sort_unstable();
        all
    }

    /// Packets currently held in any shard's arena (0 at quiescence).
    pub fn live_packets(&self) -> usize {
        self.shards.iter().map(|s| s.packets.live()).sum()
    }

    /// Resident dynamic-state bytes per shard (see
    /// [`Network::state_bytes`]). With owned-subset domains these sum
    /// to the serial engine's figure; before the domain refactor each
    /// entry *was* the serial figure.
    pub fn state_bytes_per_shard(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.state_bytes()).collect()
    }

    /// Events dispatched so far across all shards.
    pub fn dispatched(&self) -> u64 {
        self.shards.iter().map(|s| s.sim.dispatched()).sum()
    }

    // -----------------------------------------------------------------
    // The epoch runner
    // -----------------------------------------------------------------

    /// Run every shard to global quiescence with a [`NullApp`]
    /// partition per shard (traffic-replay runs). Workload runs use
    /// [`ShardedNetwork::run_app`] (or the [`Fabric`] trait).
    ///
    /// [`Fabric`]: crate::network::Fabric
    pub fn run_to_quiescence(&mut self) -> u64 {
        self.run_app(&mut NullApp)
    }

    /// Run to global quiescence (no pending events and no in-flight
    /// boundary messages) driving `app`: the app splits into one
    /// partition per shard ([`ShardableApp::partition`]), each
    /// partition handles exactly the callbacks of the nodes its shard
    /// owns — in the serial engine's order — and the partitions fold
    /// back at the end ([`ShardableApp::reduce`]). Returns the number
    /// of events dispatched. Deterministic: thread scheduling cannot
    /// affect the result (boundary merges are canonically ordered).
    pub fn run_app<A: ShardableApp>(&mut self, app: &mut A) -> u64 {
        let n = self.drive(app, Time::MAX);
        // Re-synchronize the shard clocks at the global quiescence
        // instant: each shard stopped at its *own* last event, and a
        // driver call between runs must stamp/schedule against the same
        // clock the serial engine would (its single clock sits at the
        // global last event).
        let t = self.now();
        for sh in &mut self.shards {
            sh.sim.advance_to(t);
        }
        n
    }

    /// Parity with [`Network::run_until`]: dispatch everything at or
    /// before `deadline`, then advance every shard's clock to
    /// `deadline` (events past it stay queued). Engine-agnostic
    /// drivers can step either engine through identical deadlines.
    pub fn run_until_app<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64 {
        let n = self.drive(app, deadline);
        for sh in &mut self.shards {
            sh.sim.catch_up_to(deadline);
        }
        n
    }

    /// Parity with [`Network::run_window`]: dispatch everything at or
    /// before `deadline` without advancing the clock past the last
    /// event (the global clock ends at the last dispatched event, as
    /// the serial engine's would).
    pub fn run_window_app<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64 {
        let n = self.drive(app, deadline);
        let t = self.now();
        for sh in &mut self.shards {
            sh.sim.advance_to(t);
        }
        n
    }

    /// Partition `app`, run the bounded epoch loop, reduce.
    fn drive<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64 {
        let owner = self.owner.clone();
        let mut parts: Vec<A> = (0..self.shards.len())
            .map(|i| app.partition(i as u32, owner.as_slice()))
            .collect();
        let n = self.run_epochs(&mut parts, deadline);
        for p in parts {
            app.reduce(p);
        }
        n
    }

    /// The bounded-lag epoch loop: drive `apps[i]` on shard `i` through
    /// lockstep windows — with barrier-free sprints whenever a shard's
    /// distance-aware horizon clears the window (module docs,
    /// "Distance-aware multi-shard epoch batching") — until global
    /// quiescence or `deadline`. Events after `deadline` stay queued;
    /// clocks are left at each shard's last event (callers
    /// re-synchronize).
    fn run_epochs<A: App + Send + Clone>(&mut self, apps: &mut [A], deadline: Time) -> u64 {
        debug_assert_eq!(apps.len(), self.shards.len());
        if self.optimistic {
            return crate::network::timewarp::run_epochs_optimistic(
                &mut self.shards,
                apps,
                deadline,
                self.lookahead,
                self.workers,
            );
        }
        let started: u64 = self.dispatched();
        let nshards = self.shards.len();
        let lookahead = self.lookahead;
        let pair_lookahead: &[u64] = &self.pair_lookahead;
        let card_hops: Option<&[u32]> = self.card_hops.as_deref();
        let topo: &Topology = &self.topo;
        let Some(first) = self.shards.iter().filter_map(|s| s.sim.peek_time()).min() else {
            return 0;
        };
        if first > deadline {
            return 0;
        }
        let init_window = first / lookahead;

        // Work-stealing over shards (module docs): `workers` is clamped
        // to the shard count but may be far below it (`--shards 64` on
        // 8 cores). Each phase, workers claim shard indices off a
        // shared counter until it runs dry, so load imbalance inside a
        // window self-levels instead of stalling a static chunk.
        let nworkers = self.workers;
        let barrier = Barrier::new(nworkers);
        let mailboxes: Vec<Mailbox> = (0..nshards).map(|_| Mutex::new(Vec::new())).collect();
        // Next-pending-event time per shard, pre-filled so the first
        // iteration can already derive sprint horizons. Between the
        // phase-B barrier and the next phase B these are stable (the
        // next store is two barriers ahead of any reader).
        let peeks: Vec<AtomicU64> = self
            .shards
            .iter()
            .map(|s| AtomicU64::new(s.sim.peek_time().unwrap_or(u64::MAX)))
            .collect();
        // Alongside each peek, publish (a) the head event's *bound
        // node* — only when its handler provably cannot reach app code
        // ([`Network::head_bound_node`]), u64::MAX otherwise — and (b)
        // a lower bound on the shard's second-earliest event time. A
        // peer may then bound the head's influence by the head node's
        // own card distance and everything behind it by the second
        // time at the whole-pair distance: strictly longer horizons
        // whenever a shard's head sits away from the shared boundary.
        let heads: Vec<AtomicU64> = self
            .shards
            .iter()
            .map(|s| AtomicU64::new(s.head_bound_node().map_or(u64::MAX, |n| n.0 as u64)))
            .collect();
        let nexts: Vec<AtomicU64> = self
            .shards
            .iter()
            .map(|s| AtomicU64::new(s.sim.peek_second_time_lb().unwrap_or(u64::MAX)))
            .collect();
        // Earliest epoch window in which a worker panicked (u64::MAX =
        // none). Epoch-tagged rather than a plain flag: a fast worker
        // may already be in window k+1 when it panics, and workers
        // still deciding at the end of window k must NOT break early —
        // everyone runs through window k+1's barriers, then stops
        // together (otherwise the panicked worker waits on a barrier
        // its peers already abandoned).
        let abort_at = AtomicU64::new(u64::MAX);

        // Per-shard sprint horizon over the published peeks: the
        // earliest instant any other shard's pending work could cause
        // an import into shard `i` (∞ when nothing else is pending —
        // the old solo-shard case, and the whole run for one shard).
        // Every worker reads the same peeks and the same static matrix,
        // so every worker reaches the same verdicts — no coordination
        // beyond the barriers.
        let horizon = |peeks: &[AtomicU64], heads: &[AtomicU64], nexts: &[AtomicU64], i: usize| -> u64 {
            let mut h = u64::MAX;
            for (j, p) in peeks.iter().enumerate() {
                if j == i {
                    continue;
                }
                let t = p.load(Ordering::SeqCst);
                if t == u64::MAX {
                    continue;
                }
                let pair = t.saturating_add(pair_lookahead[j * nshards + i]);
                let b = match (card_hops, heads[j].load(Ordering::SeqCst)) {
                    (Some(ch), hn) if hn != u64::MAX => {
                        // Per-node sharpening: the head's influence
                        // radiates from its own node's card, the rest
                        // of j's queue from the second event time at
                        // the pair distance. Both bounds are ≥ the
                        // plain pair bound (a card is never closer to
                        // shard i than the shard-pair minimum; the
                        // second time is ≥ the head time), so this
                        // only ever lengthens the horizon.
                        let ci = topo.card_index(NodeId(hn as u32)) as usize;
                        let head_b = t.saturating_add(
                            (ch[ci * nshards + i] as u64).saturating_mul(lookahead),
                        );
                        let next_b = match nexts[j].load(Ordering::SeqCst) {
                            u64::MAX => u64::MAX,
                            nt => nt.saturating_add(pair_lookahead[j * nshards + i]),
                        };
                        head_b.min(next_b)
                    }
                    _ => pair,
                };
                h = h.min(b);
            }
            h
        };

        // One lockable slot per shard. The claim counters below hand
        // each index to exactly one worker per phase, so the mutexes
        // are uncontended — they exist so that *any* worker can legally
        // hold any shard's `&mut` (the old code pinned shards to
        // workers through `split_at_mut` chunks instead).
        let slots: Vec<Mutex<(&mut Network, &mut A)>> = self
            .shards
            .iter_mut()
            .zip(apps.iter_mut())
            .map(|(net, app)| Mutex::new((net, app)))
            .collect();
        // Per-phase claim counters. Reset by the barrier leader right
        // *after* the barrier that ends the phase: every claim of phase
        // X happens before that barrier, and the next use is behind the
        // following barrier, which no worker passes until the leader
        // (who resets first, in program order) arrives.
        let next_a = AtomicUsize::new(0);
        let next_b = AtomicUsize::new(0);

        std::thread::scope(|scope| {
            for _ in 0..nworkers {
                let slots = &slots;
                let barrier = &barrier;
                let mailboxes = &mailboxes;
                let peeks = &peeks;
                let heads = &heads;
                let nexts = &nexts;
                let abort_at = &abort_at;
                let horizon = &horizon;
                let next_a = &next_a;
                let next_b = &next_b;
                scope.spawn(move || {
                    let mut window = init_window;
                    loop {
                        let win_deadline =
                            ((window + 1).saturating_mul(lookahead) - 1).min(deadline);
                        // Phase A: claim shards and advance each through
                        // the window (a shard whose horizon clears the
                        // window sprints past it barrier-free — until
                        // its first boundary export), posting boundary
                        // events to the mailboxes.
                        let ra = catch_unwind(AssertUnwindSafe(|| loop {
                            let c = next_a.fetch_add(1, Ordering::SeqCst);
                            if c >= nshards {
                                break;
                            }
                            let mut slot = slots[c].lock().unwrap();
                            let (net, app) = &mut *slot;
                            let sid = net.shard_id();
                            // Safe sprint bound: strictly before the
                            // earliest possible import (equal-time
                            // events dispatch in content-key order,
                            // so the horizon instant itself must
                            // stay unprocessed).
                            let own_peek = peeks[sid as usize].load(Ordering::SeqCst);
                            let sprint_deadline = horizon(peeks, heads, nexts, sid as usize)
                                .saturating_sub(1)
                                .min(deadline);
                            if sprint_deadline > win_deadline && own_peek <= sprint_deadline {
                                // The return-trip lookahead per export
                                // destination: row `sid` works for the
                                // d→sid direction because the hop
                                // matrix is symmetric.
                                let comeback = &pair_lookahead
                                    [sid as usize * nshards..(sid as usize + 1) * nshards];
                                net.run_exclusive(*app, sprint_deadline, comeback);
                                // Windows the sprint coalesced (its
                                // first event sat in `own_peek`'s
                                // window).
                                let w_end = net.sim.now() / lookahead;
                                net.metrics.windows_merged +=
                                    w_end.saturating_sub(own_peek / lookahead);
                            } else {
                                net.run_window(*app, win_deadline);
                            }
                            for (dst, msg) in net.take_outbox() {
                                mailboxes[dst as usize].lock().unwrap().push((sid, msg));
                            }
                        }));
                        if ra.is_err() {
                            abort_at.fetch_min(window, Ordering::SeqCst);
                        }
                        if barrier.wait().is_leader() {
                            next_a.store(0, Ordering::SeqCst);
                        }
                        // Phase B: claim shards, merge each inbox in
                        // (source shard, generation seq) order, publish
                        // next pending event times. Skipped once this
                        // window is known to be aborting.
                        let healthy = abort_at.load(Ordering::SeqCst) > window;
                        let rb = if ra.is_ok() && healthy {
                            catch_unwind(AssertUnwindSafe(|| loop {
                                let c = next_b.fetch_add(1, Ordering::SeqCst);
                                if c >= nshards {
                                    break;
                                }
                                let mut slot = slots[c].lock().unwrap();
                                let (net, _) = &mut *slot;
                                let sid = net.shard_id() as usize;
                                let mut inbox =
                                    std::mem::take(&mut *mailboxes[sid].lock().unwrap());
                                // Stable: preserves per-source order.
                                inbox.sort_by_key(|(src, _)| *src);
                                net.import_boundary(inbox);
                                peeks[sid].store(
                                    net.sim.peek_time().unwrap_or(u64::MAX),
                                    Ordering::SeqCst,
                                );
                                heads[sid].store(
                                    net.head_bound_node().map_or(u64::MAX, |n| n.0 as u64),
                                    Ordering::SeqCst,
                                );
                                nexts[sid].store(
                                    net.sim.peek_second_time_lb().unwrap_or(u64::MAX),
                                    Ordering::SeqCst,
                                );
                            }))
                        } else {
                            Ok(())
                        };
                        if rb.is_err() {
                            abort_at.fetch_min(window, Ordering::SeqCst);
                        }
                        if barrier.wait().is_leader() {
                            next_b.store(0, Ordering::SeqCst);
                        }
                        if abort_at.load(Ordering::SeqCst) <= window {
                            // Re-raise this worker's own panic (if any);
                            // other workers exit cleanly so the scope
                            // can propagate the original.
                            if let Err(p) = ra {
                                resume_unwind(p);
                            }
                            if let Err(p) = rb {
                                resume_unwind(p);
                            }
                            break;
                        }
                        // Every worker derives the same next window, and
                        // every phase A the same horizons. (peeks are
                        // stable here: the next write happens in the
                        // next phase B, behind the next barrier.)
                        let min = peeks
                            .iter()
                            .map(|p| p.load(Ordering::SeqCst))
                            .min()
                            .unwrap_or(u64::MAX);
                        if min == u64::MAX || min > deadline {
                            break;
                        }
                        window = min / lookahead;
                    }
                });
            }
        });
        drop(slots);
        self.dispatched() - started
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemPreset;
    use crate::topology::Coord;

    /// Serial and sharded runs of the same tiny cross-boundary traffic:
    /// identical trace, metrics and clock.
    fn diff_smoke(preset: SystemPreset, shards: u32) {
        let mut serial = Network::new(SystemConfig::new(preset));
        serial.enable_trace();
        let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), shards);
        sharded.enable_trace();

        let n = serial.topo.node_count() as u32;
        for i in 0..32u32 {
            let src = NodeId((i * 97) % n);
            let dst = NodeId((i * 31 + n / 2) % n);
            if src != dst {
                serial.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Synthetic(128));
                sharded.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Synthetic(128));
            }
        }
        serial.run_to_quiescence(&mut NullApp);
        sharded.run_to_quiescence();

        let mut st = serial.take_trace();
        st.sort_unstable();
        assert_eq!(st, sharded.take_trace(), "delivery traces differ ({preset:?})");
        assert_eq!(
            serial.metrics.fabric_view(),
            sharded.metrics().fabric_view(),
            "metrics differ ({preset:?})"
        );
        assert_eq!(serial.now(), sharded.now(), "final clocks differ ({preset:?})");
        assert_eq!(sharded.live_packets(), 0, "arena leak");
    }

    #[test]
    fn card_single_shard_matches_serial() {
        diff_smoke(SystemPreset::Card, 1);
    }

    #[test]
    fn inc3000_four_shards_match_serial() {
        diff_smoke(SystemPreset::Inc3000, 4);
    }

    #[test]
    fn inc9000_broadcast_crosses_cages_identically() {
        let mut serial = Network::new(SystemConfig::inc9000());
        serial.enable_trace();
        let mut sharded = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        assert_eq!(sharded.shard_count(), 4);
        sharded.enable_trace();
        let src = serial.topo.id(Coord { x: 5, y: 5, z: 0 });
        serial.send_broadcast(src, Proto::Raw { tag: 1 }, Payload::Empty);
        sharded.send_broadcast(src, Proto::Raw { tag: 1 }, Payload::Empty);
        serial.run_to_quiescence(&mut NullApp);
        sharded.run_to_quiescence();
        let mut st = serial.take_trace();
        st.sort_unstable();
        let sh = sharded.take_trace();
        assert_eq!(sh.len(), 1728, "broadcast must reach every node once");
        assert_eq!(st, sh);
        assert_eq!(serial.metrics.fabric_view(), sharded.metrics().fabric_view());
        assert_eq!(serial.now(), sharded.now());
    }

    #[test]
    fn empty_run_terminates() {
        let mut sharded = ShardedNetwork::new(SystemConfig::card(), 1);
        assert_eq!(sharded.run_to_quiescence(), 0);
        assert_eq!(sharded.now(), 0);
    }

    #[test]
    fn single_shard_run_merges_all_windows() {
        // One shard is always solo: the whole run is one exclusive
        // sprint, and every lockstep window past the first is counted
        // as merged.
        let mut net = ShardedNetwork::new(SystemConfig::card(), 1);
        net.send_directed(NodeId(0), NodeId(26), Proto::Raw { tag: 0 }, Payload::Synthetic(64));
        net.run_to_quiescence();
        let merged = net.metrics().windows_merged;
        assert!(merged > 0, "six-hop flight spans several 684 ns windows");
        // The flight takes > merged * lookahead ns by construction.
        assert!(net.now() / net.lookahead() >= merged);
    }

    #[test]
    fn shard_state_vectors_are_owned_sized() {
        // The domain refactor's acceptance: per-shard state vectors are
        // sized by the owned node/link counts, not the full mesh, and
        // the slices partition the mesh exactly.
        let net = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        let topo = net.topo.clone();
        let (owner, s) = topo.partition(4);
        assert_eq!(s as usize, net.shard_count());
        let mut node_total = 0;
        let mut link_total = 0;
        for (i, sh) in net.shards().iter().enumerate() {
            let owned_nodes = owner.iter().filter(|&&o| o == i as u32).count();
            let owned_links = topo
                .links()
                .iter()
                .filter(|l| owner[l.src.0 as usize] == i as u32)
                .count();
            assert!(owned_nodes * 2 < topo.node_count(), "shard {i} holds too much");
            assert_eq!(sh.nodes.len(), owned_nodes, "shard {i} node vector");
            assert_eq!(sh.links.len(), owned_links, "shard {i} link vector");
            assert_eq!(sh.failed_links.len(), owned_links, "shard {i} failure flags");
            assert_eq!(sh.eth.ports.len(), owned_nodes, "shard {i} NIC ports");
            node_total += owned_nodes;
            link_total += owned_links;
        }
        assert_eq!(node_total, topo.node_count());
        assert_eq!(link_total, topo.link_count());
        // Conservation: the per-shard slices sum to the serial engine's
        // state exactly, and each shard holds roughly a quarter.
        let serial = Network::new(SystemConfig::inc9000());
        let per_shard = net.state_bytes_per_shard();
        assert_eq!(per_shard.iter().sum::<u64>(), serial.state_bytes());
        assert_eq!(net.metrics().state_bytes, serial.state_bytes());
        assert!(per_shard.iter().all(|&b| b * 3 < serial.state_bytes()), "{per_shard:?}");
    }

    #[test]
    fn sparse_cross_cage_traffic_merges_windows_and_stays_identical() {
        // A single packet crossing all four cages: the owning shard
        // sprints between boundary hops instead of pacing every 684 ns
        // window, and the result is still byte-identical to serial.
        let mut serial = Network::new(SystemConfig::inc9000());
        serial.enable_trace();
        let mut sharded = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        sharded.enable_trace();
        let (src, dst) = (NodeId(0), NodeId(1727));
        serial.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Synthetic(256));
        sharded.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Synthetic(256));
        serial.run_to_quiescence(&mut NullApp);
        sharded.run_to_quiescence();
        let mut st = serial.take_trace();
        st.sort_unstable();
        assert_eq!(st, sharded.take_trace());
        assert_eq!(serial.metrics.fabric_view(), sharded.metrics().fabric_view());
        assert_eq!(serial.now(), sharded.now());
        assert!(
            sharded.metrics().windows_merged > 0,
            "sparse traffic should coalesce windows"
        );
    }
}
