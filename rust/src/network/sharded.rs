//! Bounded-lag per-cage parallel simulation (`ShardedNetwork`).
//!
//! The INC 9000 stacks four cages of 432 nodes (§2.1, Fig 2a), and all
//! inter-cage traffic is confined to multi-span z links — exactly the
//! partition boundary a conservative parallel discrete-event simulator
//! wants. `ShardedNetwork` runs one [`Network`] per cage (falling back
//! to per-card sharding for `Inc3000`/`Card`, see
//! [`Topology::partition`]); each shard owns its own event wheel,
//! packet arena, link and node state, while the [`Topology`] is shared
//! read-only behind an `Arc`.
//!
//! # Bounded-lag epochs
//!
//! Shards advance in lockstep through windows of `lookahead` ns, where
//! `lookahead` is the minimum latency of *any* cross-boundary event:
//!
//! * an `Arrive` on a boundary link takes `router_latency + ser(bytes)
//!   ≥ router_latency + ser(header)`;
//! * the returning `Credit` takes exactly `router_latency`;
//!
//! so `lookahead = router_latency` (684 ns by default). An event
//! executing in window `k` (`[k·L, (k+1)·L)`) can only schedule
//! cross-boundary work at `≥ (k+1)·L`, i.e. in a later window — shards
//! therefore never see a boundary event "from the past". Between
//! windows, boundary events travel through per-shard mailboxes and are
//! merged in a fixed `(epoch, source shard, generation seq)` order, so
//! the run is deterministic regardless of thread interleaving. Windows
//! with no work are skipped (the next window index is derived from the
//! global minimum pending-event time).
//!
//! # Byte-identical to the serial engine
//!
//! The headline property (differential-tested in
//! `tests/sharded_differential.rs`): a sharded run produces the same
//! delivery trace, metrics and final clock as [`Network`] run serially,
//! byte for byte. Three serial-engine design points make this possible
//! (see the "dispatch-order independence" notes in [`crate::network`]):
//! content-keyed same-instant event ordering, per-packet tie-break
//! hashes instead of an RNG stream, and driver-side packet-id
//! assignment (the wrapper APIs here sync one global id cursor into the
//! owning shard around every call). Same-`(time, key)` events whose
//! relative order *can* differ between engines have commuting handlers
//! by construction of the key scheme.
//!
//! # Scope
//!
//! Each shard is a full [`Network`] over the whole mesh: dynamic state
//! (links, nodes, channel tables) is *allocated* everywhere but only
//! ever *mutated* for the owned partition. That replication is a
//! deliberate simplicity trade — state stays index-compatible with the
//! serial engine at the cost of shard-count× idle memory (a few MB per
//! Inc9000 shard); compacting per-shard state behind an index remap is
//! a noted follow-up (ROADMAP).
//!
//! The sharded runner drives inbox-style workloads (the [`App`]
//! callback surface is per-shard, so runs use [`NullApp`]); traffic is
//! injected up front or between runs through the wrapper APIs. The one
//! channel that cannot cross a shard boundary is internal Ethernet —
//! its in-flight frame table lives on the transmit side — so
//! cross-shard `eth_send` is unsupported (it panics loudly in
//! `eth_deliver`); directed/broadcast/multicast raw traffic, Bridge
//! FIFO, Postmaster and NetTunnel all work across boundaries.
//!
//! [`App`]: crate::network::App

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};

use crate::config::SystemConfig;
use crate::metrics::Metrics;
use crate::network::{BoundaryMsg, Delivery, Network, NullApp, ShardCtx};
use crate::router::{Payload, Proto};
use crate::sim::Time;
use crate::topology::{LinkId, NodeId, Topology};

/// Per-shard inbox of boundary events, as (source shard, message).
type Mailbox = Mutex<Vec<(u32, BoundaryMsg)>>;

/// One [`Network`] per cage (or card group), advancing in bounded-lag
/// lockstep. See the module docs.
pub struct ShardedNetwork {
    shards: Vec<Network>,
    /// Owner shard per node (shared with every shard's `ShardCtx`).
    owner: Arc<Vec<u32>>,
    /// The topology all shards reference.
    pub topo: Arc<Topology>,
    /// Epoch window length, ns (= minimum cross-boundary latency).
    lookahead: Time,
    /// Worker threads driving the shards.
    workers: usize,
    /// Global packet-id cursor, synced into shards around driver calls
    /// so ids match the serial engine exactly.
    next_packet_id: u64,
}

impl ShardedNetwork {
    /// Build a sharded system. `shards` is clamped to the natural unit
    /// count of the preset (4 cages for `Inc9000`, 16 cards for
    /// `Inc3000`, 1 for `Card`).
    pub fn new(cfg: SystemConfig, shards: u32) -> Self {
        let topo = Arc::new(Topology::preset(cfg.preset));
        let (owner, count) = topo.partition(shards);
        let owner = Arc::new(owner);
        // The cheapest cross-boundary event is a Credit: exactly one
        // router latency. (An Arrive adds at least ser(header) on top.)
        // Zero lookahead would let boundary events land inside the
        // window that produced them — the serial/sharded byte-identity
        // contract cannot hold, so reject such configs loudly instead
        // of clamping and silently diverging.
        assert!(
            cfg.link.router_latency >= 1,
            "sharded simulation needs link.router_latency >= 1 ns for a \
             positive conservative lookahead"
        );
        let lookahead = cfg.link.router_latency;
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let requested = if cfg.sim_threads > 0 { cfg.sim_threads } else { hw };
        let workers = requested.clamp(1, count as usize);
        let shards = (0..count)
            .map(|i| {
                let mut net = Network::with_topology(cfg.clone(), topo.clone());
                net.shard_ctx =
                    Some(ShardCtx { shard: i, owner: owner.clone(), outbox: Vec::new() });
                net
            })
            .collect();
        ShardedNetwork { shards, owner, topo, lookahead, workers, next_packet_id: 0 }
    }

    /// Natural shard count of a preset (what `new` clamps to).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Worker threads the run loop will use.
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    /// Epoch window length in ns.
    pub fn lookahead(&self) -> Time {
        self.lookahead
    }

    /// The shards themselves (read-only; per-shard inboxes, metrics and
    /// node state live here).
    pub fn shards(&self) -> &[Network] {
        &self.shards
    }

    /// Owning shard of `node`.
    pub fn shard_of(&self, node: NodeId) -> usize {
        self.owner[node.0 as usize] as usize
    }

    /// Mutable access to the shard owning `node` (driver-side state
    /// setup; do not schedule events directly).
    pub fn shard_mut(&mut self, node: NodeId) -> &mut Network {
        let s = self.shard_of(node);
        &mut self.shards[s]
    }

    /// Run `f` against the shard owning `node` with the global
    /// packet-id cursor synced in and back out, so id assignment
    /// matches a serial run call for call.
    fn with_shard<R>(&mut self, node: NodeId, f: impl FnOnce(&mut Network) -> R) -> R {
        let s = self.shard_of(node);
        self.shards[s].set_packet_id_cursor(self.next_packet_id);
        let r = f(&mut self.shards[s]);
        self.next_packet_id = self.shards[s].packet_id_cursor();
        r
    }

    // -----------------------------------------------------------------
    // Driver APIs (mirror `Network`'s, routed to the owning shard)
    // -----------------------------------------------------------------

    /// See [`Network::send_directed`].
    pub fn send_directed(
        &mut self,
        src: NodeId,
        dst: NodeId,
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        self.with_shard(src, |n| n.send_directed(src, dst, proto, payload))
    }

    /// See [`Network::send_broadcast`].
    pub fn send_broadcast(&mut self, src: NodeId, proto: Proto, payload: Payload) -> u64 {
        self.with_shard(src, |n| n.send_broadcast(src, proto, payload))
    }

    /// See [`Network::send_multicast`].
    pub fn send_multicast(
        &mut self,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        self.with_shard(src, |n| n.send_multicast(src, dsts, proto, payload))
    }

    /// See [`Network::fifo_connect`] (registered on every shard: the
    /// write port is used by the source shard, the read port by the
    /// destination shard).
    pub fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8, width_bits: u8) {
        for sh in &mut self.shards {
            sh.fifo_connect(src, dst, channel, width_bits);
        }
    }

    /// See [`Network::fifo_send`].
    pub fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]) {
        self.with_shard(src, |n| n.fifo_send(src, channel, words));
    }

    /// See [`Network::fifo_read`] (reads the destination shard's port).
    pub fn fifo_read(&mut self, node: NodeId, channel: u8, max: usize) -> Vec<u64> {
        self.shard_mut(node).fifo_read(node, channel, max)
    }

    /// See [`Network::pm_open`] (registered on every shard).
    pub fn pm_open(&mut self, target: NodeId, queue: u8) {
        for sh in &mut self.shards {
            sh.pm_open(target, queue);
        }
    }

    /// See [`Network::pm_send`].
    pub fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>) {
        self.with_shard(src, |n| n.pm_send(src, target, queue, data));
    }

    /// See [`Network::tunnel_write`].
    pub fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64) {
        self.with_shard(src, |n| n.tunnel_write(src, dst, addr, value));
    }

    /// See [`Network::tunnel_read`]. The result lands in the shard
    /// owning `src`; fetch it with [`ShardedNetwork::tunnel_result`].
    pub fn tunnel_read(&mut self, src: NodeId, dst: NodeId, addr: u64) -> u64 {
        self.with_shard(src, |n| n.tunnel_read(src, dst, addr))
    }

    /// See [`Network::tunnel_result`] (checks every shard).
    pub fn tunnel_result(&self, req_id: u64) -> Option<u64> {
        self.shards.iter().find_map(|s| s.tunnel_result(req_id))
    }

    /// See [`Network::fail_link`] (applied to every shard: routing
    /// tables must agree everywhere).
    pub fn fail_link(&mut self, l: LinkId) {
        for sh in &mut self.shards {
            sh.fail_link(l);
        }
    }

    /// See [`Network::repair_link`].
    pub fn repair_link(&mut self, l: LinkId) {
        for sh in &mut self.shards {
            sh.repair_link(l);
        }
    }

    /// Record the delivery trace on every shard (see
    /// [`ShardedNetwork::take_trace`]).
    pub fn enable_trace(&mut self) {
        for sh in &mut self.shards {
            sh.enable_trace();
        }
    }

    // -----------------------------------------------------------------
    // Aggregates
    // -----------------------------------------------------------------

    /// Final clock: the latest event time across shards (equals the
    /// serial engine's quiescence clock).
    pub fn now(&self) -> Time {
        self.shards.iter().map(|s| s.now()).max().unwrap_or(0)
    }

    /// Merged fabric metrics (byte-identical to a serial run's).
    pub fn metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        for sh in &self.shards {
            m.merge(&sh.metrics);
        }
        m
    }

    /// Merged delivery trace in the canonical [`Delivery`] order
    /// (byte-identical to a serial run's sorted trace).
    pub fn take_trace(&mut self) -> Vec<Delivery> {
        let mut all = Vec::new();
        for sh in &mut self.shards {
            all.extend(sh.take_trace());
        }
        all.sort_unstable();
        all
    }

    /// Packets currently held in any shard's arena (0 at quiescence).
    pub fn live_packets(&self) -> usize {
        self.shards.iter().map(|s| s.packets.live()).sum()
    }

    /// Events dispatched so far across all shards.
    pub fn dispatched(&self) -> u64 {
        self.shards.iter().map(|s| s.sim.dispatched()).sum()
    }

    // -----------------------------------------------------------------
    // The epoch runner
    // -----------------------------------------------------------------

    /// Run every shard to global quiescence (no pending events and no
    /// in-flight boundary messages). Returns the number of events
    /// dispatched. Deterministic: thread scheduling cannot affect the
    /// result (boundary merges are canonically ordered).
    pub fn run_to_quiescence(&mut self) -> u64 {
        let started: u64 = self.dispatched();
        let nshards = self.shards.len();
        let lookahead = self.lookahead;
        let Some(first) = self.shards.iter().filter_map(|s| s.sim.peek_time()).min() else {
            return 0;
        };
        let init_window = first / lookahead;

        // Balanced chunks: `workers` is already clamped to the shard
        // count, and the remainder is spread one-per-chunk so exactly
        // `workers` threads run (e.g. 4 shards / 3 workers = 2+1+1).
        let nchunks = self.workers;
        let base = nshards / nchunks;
        let rem = nshards % nchunks;
        let barrier = Barrier::new(nchunks);
        let mailboxes: Vec<Mailbox> = (0..nshards).map(|_| Mutex::new(Vec::new())).collect();
        let peeks: Vec<AtomicU64> = (0..nshards).map(|_| AtomicU64::new(u64::MAX)).collect();
        // Earliest epoch window in which a worker panicked (u64::MAX =
        // none). Epoch-tagged rather than a plain flag: a fast worker
        // may already be in window k+1 when it panics, and workers
        // still deciding at the end of window k must NOT break early —
        // everyone runs through window k+1's barriers, then stops
        // together (otherwise the panicked worker waits on a barrier
        // its peers already abandoned).
        let abort_at = AtomicU64::new(u64::MAX);

        std::thread::scope(|scope| {
            let mut rest: &mut [Network] = &mut self.shards;
            for ci in 0..nchunks {
                let take = base + usize::from(ci < rem);
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take);
                rest = tail;
                let barrier = &barrier;
                let mailboxes = &mailboxes;
                let peeks = &peeks;
                let abort_at = &abort_at;
                scope.spawn(move || {
                    let mut app = NullApp;
                    let mut window = init_window;
                    loop {
                        let deadline = (window + 1) * lookahead - 1;
                        // Phase A: advance own shards through the
                        // window and post boundary events.
                        let ra = catch_unwind(AssertUnwindSafe(|| {
                            for net in chunk.iter_mut() {
                                net.run_window(&mut app, deadline);
                                let sid = net.shard_id();
                                for (dst, msg) in net.take_outbox() {
                                    mailboxes[dst as usize].lock().unwrap().push((sid, msg));
                                }
                            }
                        }));
                        if ra.is_err() {
                            abort_at.fetch_min(window, Ordering::SeqCst);
                        }
                        barrier.wait();
                        // Phase B: merge own inboxes in (source shard,
                        // generation seq) order, publish next pending
                        // event times. Skipped once this window is
                        // known to be aborting.
                        let healthy = abort_at.load(Ordering::SeqCst) > window;
                        let rb = if ra.is_ok() && healthy {
                            catch_unwind(AssertUnwindSafe(|| {
                                for net in chunk.iter_mut() {
                                    let sid = net.shard_id() as usize;
                                    let mut inbox =
                                        std::mem::take(&mut *mailboxes[sid].lock().unwrap());
                                    // Stable: preserves per-source order.
                                    inbox.sort_by_key(|(src, _)| *src);
                                    net.import_boundary(inbox);
                                    peeks[sid].store(
                                        net.sim.peek_time().unwrap_or(u64::MAX),
                                        Ordering::SeqCst,
                                    );
                                }
                            }))
                        } else {
                            Ok(())
                        };
                        if rb.is_err() {
                            abort_at.fetch_min(window, Ordering::SeqCst);
                        }
                        barrier.wait();
                        if abort_at.load(Ordering::SeqCst) <= window {
                            // Re-raise this worker's own panic (if any);
                            // other workers exit cleanly so the scope
                            // can propagate the original.
                            if let Err(p) = ra {
                                resume_unwind(p);
                            }
                            if let Err(p) = rb {
                                resume_unwind(p);
                            }
                            break;
                        }
                        // Every worker derives the same next window.
                        // (peeks are stable here: the next write happens
                        // in the next phase B, behind the next barrier.)
                        let min = peeks
                            .iter()
                            .map(|p| p.load(Ordering::SeqCst))
                            .min()
                            .unwrap_or(u64::MAX);
                        if min == u64::MAX {
                            break;
                        }
                        window = min / lookahead;
                    }
                });
            }
        });
        // Re-synchronize the shard clocks at the global quiescence
        // instant: each shard stopped at its *own* last event, and a
        // driver call between runs must stamp/schedule against the same
        // clock the serial engine would (its single clock sits at the
        // global last event).
        let t = self.now();
        for sh in &mut self.shards {
            sh.sim.advance_to(t);
        }
        self.dispatched() - started
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemPreset;
    use crate::topology::Coord;

    /// Serial and sharded runs of the same tiny cross-boundary traffic:
    /// identical trace, metrics and clock.
    fn diff_smoke(preset: SystemPreset, shards: u32) {
        let mut serial = Network::new(SystemConfig::new(preset));
        serial.enable_trace();
        let mut sharded = ShardedNetwork::new(SystemConfig::new(preset), shards);
        sharded.enable_trace();

        let n = serial.topo.node_count() as u32;
        for i in 0..32u32 {
            let src = NodeId((i * 97) % n);
            let dst = NodeId((i * 31 + n / 2) % n);
            if src != dst {
                serial.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Synthetic(128));
                sharded.send_directed(src, dst, Proto::Raw { tag: 0 }, Payload::Synthetic(128));
            }
        }
        serial.run_to_quiescence(&mut NullApp);
        sharded.run_to_quiescence();

        let mut st = serial.take_trace();
        st.sort_unstable();
        assert_eq!(st, sharded.take_trace(), "delivery traces differ ({preset:?})");
        assert_eq!(serial.metrics, sharded.metrics(), "metrics differ ({preset:?})");
        assert_eq!(serial.now(), sharded.now(), "final clocks differ ({preset:?})");
        assert_eq!(sharded.live_packets(), 0, "arena leak");
    }

    #[test]
    fn card_single_shard_matches_serial() {
        diff_smoke(SystemPreset::Card, 1);
    }

    #[test]
    fn inc3000_four_shards_match_serial() {
        diff_smoke(SystemPreset::Inc3000, 4);
    }

    #[test]
    fn inc9000_broadcast_crosses_cages_identically() {
        let mut serial = Network::new(SystemConfig::inc9000());
        serial.enable_trace();
        let mut sharded = ShardedNetwork::new(SystemConfig::inc9000(), 4);
        assert_eq!(sharded.shard_count(), 4);
        sharded.enable_trace();
        let src = serial.topo.id(Coord { x: 5, y: 5, z: 0 });
        serial.send_broadcast(src, Proto::Raw { tag: 1 }, Payload::Empty);
        sharded.send_broadcast(src, Proto::Raw { tag: 1 }, Payload::Empty);
        serial.run_to_quiescence(&mut NullApp);
        sharded.run_to_quiescence();
        let mut st = serial.take_trace();
        st.sort_unstable();
        let sh = sharded.take_trace();
        assert_eq!(sh.len(), 1728, "broadcast must reach every node once");
        assert_eq!(st, sh);
        assert_eq!(serial.metrics, sharded.metrics());
        assert_eq!(serial.now(), sharded.now());
    }

    #[test]
    fn empty_run_terminates() {
        let mut sharded = ShardedNetwork::new(SystemConfig::card(), 1);
        assert_eq!(sharded.run_to_quiescence(), 0);
        assert_eq!(sharded.now(), 0);
    }
}
