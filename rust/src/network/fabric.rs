//! The engine-agnostic execution API ([`Fabric`]) and the partitionable
//! workload contract ([`ShardableApp`]).
//!
//! Before this layer existed, every workload and coordinator was pinned
//! to the serial [`Network`]: `ShardedNetwork::run_to_quiescence` took
//! no app, so the parallel engine could only replay raw traffic. The
//! [`Fabric`] trait closes that gap — one injection/channel/run/metrics
//! surface implemented by **both** engines, so `learners`, `mcts`,
//! `training` and the ring all-reduce run unmodified on either, with
//! byte-identical traces, metrics (fabric view) and app-level results
//! (differential-tested in `tests/sharded_differential.rs`).
//!
//! # Two id spaces, two contexts
//!
//! *Driver context* (between runs): the global packet-id counter is
//! coherent — the sharded wrappers sync one cursor into the owning
//! shard around every call — so [`Fabric::send_directed`] and friends
//! assign exactly the ids a serial run would.
//!
//! *App context* (inside [`App`] callbacks, which on the sharded engine
//! execute mid-window on one shard): the global counter is **not**
//! coherent, so app-originated traffic uses per-node ids
//! ([`Network::app_packet_id`]) that depend only on the sending node's
//! own sequence. The unified Endpoint sends ([`Fabric::send`] /
//! [`Fabric::send_at`]) are built on that id space, which is valid in
//! both contexts — engine-agnostic workloads use them for *all*
//! traffic they originate, so one code path serves kickoff and
//! callback alike.
//!
//! # Communication modes
//!
//! The virtual channels are a first-class axis: [`Fabric::open`] binds
//! a node to a [`CommMode`], [`Fabric::send`]/[`Fabric::send_at`] move
//! [`Message`]s over it ([`Fabric::connect`] first, where
//! [`ChannelCaps::pair_setup`] demands), and complete messages surface
//! through [`Fabric::recv`] or [`App::on_message`]. The legacy
//! per-channel families (`fifo_*`, `pm_*`, `eth_*`) remain as thin
//! shims over the same per-mode transmit recipes for channel-specific
//! drivers and tests.
//!
//! # Partitioned apps
//!
//! [`ShardableApp`] is how an [`App`] rides the parallel engine: the
//! run splits it into one partition per shard
//! ([`ShardableApp::partition`]), each partition sees exactly the
//! callbacks for nodes its shard owns (in an order byte-identical to
//! the serial engine's restriction to those nodes), and at the end the
//! partitions fold back ([`ShardableApp::reduce`]). Reduction must be
//! commutative across partitions — the fold order is unspecified.
//! State that only one node's callbacks mutate (a leader's search tree,
//! a rank's receive counter) needs no care beyond living in the
//! partition that owns that node; cross-partition aggregates must be
//! sums/maxes/unions.

use std::sync::Arc;

use crate::channels::endpoint::{ChannelCaps, CommMode, Endpoint, Message, MsgId};
use crate::channels::ethernet::{EthFrame, RxMode};
use crate::channels::reliable::ReliableParams;
use crate::channels::postmaster::PmRecord;
use crate::config::SystemConfig;
use crate::metrics::Metrics;
use crate::network::sharded::ShardedNetwork;
use crate::network::{App, Delivery, Network, NullApp};
use crate::router::{Payload, Proto};
use crate::sim::Time;
use crate::topology::{LinkId, NodeId, Topology};

/// An [`App`] that can be partitioned across the sharded engine's
/// shards and reduced back. See the module docs for the contract.
///
/// `Clone` is part of the contract: the optimistic engine
/// ([`crate::network::timewarp`]) checkpoints each partition alongside
/// its shard's `Network` and restores the clone on rollback, so a
/// partition's clone must capture all state its callbacks mutate.
pub trait ShardableApp: App + Send + Sized + Clone {
    /// Build the partition that will run on `shard` (owning the nodes
    /// `n` with `owner[n] == shard`). Called once per shard before the
    /// run; the parent app is not consulted again until reduction.
    fn partition(&self, shard: u32, owner: &[u32]) -> Self;

    /// Fold a finished partition back into the parent. Must be
    /// commutative across partitions.
    fn reduce(&mut self, part: Self);
}

impl ShardableApp for NullApp {
    fn partition(&self, _shard: u32, _owner: &[u32]) -> NullApp {
        NullApp
    }
    fn reduce(&mut self, _part: NullApp) {}
}

/// The engine-agnostic fabric surface: everything a driver or workload
/// needs — traffic injection, the three virtual channels, NetTunnel,
/// execution, tracing and metrics — implemented by the serial
/// [`Network`] and the bounded-lag parallel [`ShardedNetwork`] with
/// identical observable behavior (`tests/sharded_differential.rs`).
///
/// Not object-safe (the run methods are generic over the app);
/// engine-agnostic code is written as `fn f<F: Fabric>(net: &mut F)`.
pub trait Fabric {
    // -- identity / clock -------------------------------------------------

    /// The (shared) static topology.
    fn topo(&self) -> &Arc<Topology>;
    /// The system configuration.
    fn config(&self) -> &SystemConfig;
    /// Current virtual time. On the sharded engine this is the global
    /// clock (shards are re-synchronized after every run).
    fn now(&self) -> Time;
    /// Advance the clock to `t` if it is ahead; no-op otherwise
    /// (deferred-production workloads close a compute window this way).
    fn advance_to(&mut self, t: Time);
    /// Events dispatched so far (summed across shards).
    fn dispatched(&self) -> u64;

    // -- diagnostics ------------------------------------------------------

    /// Aggregated fabric metrics. Engine-level counters (e.g.
    /// `windows_merged`) are included; compare
    /// [`Metrics::fabric_view`]s across engines.
    fn metrics(&self) -> Metrics;
    /// Start recording the delivery trace.
    fn enable_trace(&mut self);
    /// Take the recorded trace in the canonical [`Delivery`] order
    /// (sorted; byte-identical across engines).
    fn take_trace(&mut self) -> Vec<Delivery>;

    // -- driver-context injection (global id space) -----------------------

    /// See [`Network::send_directed`].
    fn send_directed(&mut self, src: NodeId, dst: NodeId, proto: Proto, payload: Payload) -> u64;
    /// See [`Network::send_broadcast`].
    fn send_broadcast(&mut self, src: NodeId, proto: Proto, payload: Payload) -> u64;
    /// See [`Network::send_multicast`].
    fn send_multicast(
        &mut self,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64;
    /// See [`Network::app_multicast_at`]: multicast drawn from the
    /// per-node app id space — valid in driver context *and* from App
    /// callbacks at `src` (spike fan-out sends from `on_timer`).
    fn app_multicast_at(
        &mut self,
        at: Time,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64;
    /// See [`Network::timer_at`]: schedule an
    /// [`App::on_timer`](crate::network::App::on_timer) at `node` at
    /// absolute time `at`. Valid in driver context and from callbacks
    /// at any node the executing partition owns.
    fn timer_at(&mut self, at: Time, node: NodeId, tag: u64);
    /// See [`Network::fail_link`].
    fn fail_link(&mut self, l: LinkId);
    /// See [`Network::repair_link`].
    fn repair_link(&mut self, l: LinkId);
    /// Record a measured worst-case reroute-convergence figure into
    /// [`Metrics::reroute_convergence_ns`] (max-combined with any prior
    /// figure — it is a fabric-wide worst case). Called by the chaos
    /// harness ([`crate::workload::chaos`]) after it reduces per-fault
    /// first-delivery times; a driver-context call, identical on both
    /// engines so the figure participates in the byte-identity
    /// contract.
    fn record_reroute_convergence(&mut self, ns: Time);

    // -- communication modes: the unified Endpoint API --------------------
    //
    // Valid in driver context *and* (except `open`/`connect`/`Nfs`
    // sends) from App callbacks at the endpoint's node: every send
    // draws per-node app packet ids, so both engines assign identical
    // ids (see the module docs).

    /// See [`Network::open`]: bind `node` to a communication mode.
    fn open(&mut self, node: NodeId, mode: CommMode) -> Endpoint;
    /// See [`Network::connect`]: per-pair setup where
    /// [`ChannelCaps::pair_setup`] requires it (driver context).
    fn connect(&mut self, ep: &Endpoint, dst: NodeId);
    /// See [`Network::send`]: send a message over the endpoint's mode.
    fn send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId;
    /// See [`Network::send_at`]: deferred-production send (`at ≥ now`).
    fn send_at(&mut self, at: Time, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId;
    /// See [`Network::recv`]: drain the endpoint's complete messages.
    fn recv(&mut self, ep: &Endpoint) -> Vec<Message>;
    /// Capability descriptor of `mode` under this fabric's config.
    fn caps(&self, mode: CommMode) -> ChannelCaps {
        mode.caps(self.config())
    }
    /// See [`Network::open_with_rx_capacity`]: `open` with a
    /// per-endpoint receive-buffer bound.
    fn open_with_rx_capacity(&mut self, node: NodeId, mode: CommMode, cap: u32) -> Endpoint;

    // -- reliable transport (see `channels::reliable`) --------------------

    /// See [`Network::reliable_open`]: open + register with the
    /// ack/retransmit transport.
    fn reliable_open(&mut self, node: NodeId, mode: CommMode, params: ReliableParams)
        -> Endpoint;
    /// See [`Network::reliable_send`].
    fn reliable_send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId;
    /// See [`Network::reliable_send_at`].
    fn reliable_send_at(&mut self, at: Time, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId;
    /// See [`Network::reliable_watch`]: heartbeat liveness monitoring.
    fn reliable_watch(&mut self, ep: &Endpoint, peer: NodeId, until: Time);
    /// See [`Network::reliable_is_down`].
    fn reliable_is_down(&self, ep: &Endpoint, peer: NodeId) -> bool;
    /// See [`Network::reliable_take_unacked`]: drain undelivered
    /// payloads of a downed peer for re-placement.
    fn reliable_take_unacked(&mut self, ep: &Endpoint, peer: NodeId) -> Vec<Message>;

    // -- virtual channels (legacy per-channel shims) ----------------------

    /// See [`Network::fifo_connect`].
    fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8, width_bits: u8);
    /// See [`Network::fifo_send`].
    fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]);
    /// See [`Network::fifo_read`].
    fn fifo_read(&mut self, node: NodeId, channel: u8, max: usize) -> Vec<u64>;
    /// See [`Network::pm_open`].
    fn pm_open(&mut self, target: NodeId, queue: u8);
    /// See [`Network::pm_send`].
    fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>);
    /// See [`Network::pm_read`].
    fn pm_read(&mut self, node: NodeId, queue: u8) -> Vec<PmRecord>;
    /// See [`Network::eth_set_mode`].
    fn eth_set_mode(&mut self, node: NodeId, mode: RxMode);
    /// See [`Network::eth_send`].
    fn eth_send(&mut self, src: NodeId, dst: NodeId, bytes: u32, tag: u64);
    /// See [`Network::eth_send_message`].
    fn eth_send_message(&mut self, src: NodeId, dst: NodeId, bytes: u64, tag: u64) -> u32;
    /// See [`Network::eth_read`].
    fn eth_read(&mut self, node: NodeId) -> Vec<EthFrame>;
    /// See [`Network::nfs_put`].
    fn nfs_put(&mut self, node: NodeId, name: &str, size: u64);
    /// See [`Network::gateway`]: the node carrying the physical
    /// Ethernet port.
    fn gateway(&self) -> NodeId;
    /// See [`Network::nat_forward`]: install a NAT port-forwarding
    /// entry at the gateway (driver context).
    fn nat_forward(&mut self, external_port: u16, node: NodeId, internal_port: u16);
    /// See [`Network::external_ingress_at`]: schedule an external frame
    /// through the gateway's NAT, reaching the physical port at
    /// absolute time `at` (driver context; open-loop workloads feed a
    /// precomputed arrival schedule through here in ascending order).
    fn external_ingress_at(&mut self, at: Time, external_port: u16, bytes: u32, tag: u64)
        -> bool;
    /// See [`Network::tunnel_write`].
    fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64);
    /// See [`Network::tunnel_read`].
    fn tunnel_read(&mut self, src: NodeId, dst: NodeId, addr: u64) -> u64;
    /// See [`Network::tunnel_result`].
    fn tunnel_result(&self, req_id: u64) -> Option<u64>;

    // -- execution --------------------------------------------------------

    /// Run to quiescence, driving `app`. On the sharded engine the app
    /// is partitioned/reduced per [`ShardableApp`]. Returns events
    /// dispatched.
    fn run<A: ShardableApp>(&mut self, app: &mut A) -> u64;
    /// Run until the queue empties or `deadline` passes, then advance
    /// the clock to `deadline` (see [`Network::run_until`]).
    fn run_until<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64;
    /// Dispatch everything at or before `deadline` without advancing
    /// the clock past the last event (see [`Network::run_window`]).
    fn run_window<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64;
}

impl Fabric for Network {
    fn topo(&self) -> &Arc<Topology> {
        &self.topo
    }
    fn config(&self) -> &SystemConfig {
        &self.cfg
    }
    fn now(&self) -> Time {
        Network::now(self)
    }
    fn advance_to(&mut self, t: Time) {
        self.sim.catch_up_to(t);
    }
    fn dispatched(&self) -> u64 {
        self.sim.dispatched()
    }

    fn metrics(&self) -> Metrics {
        self.metrics.clone()
    }
    fn enable_trace(&mut self) {
        Network::enable_trace(self)
    }
    fn take_trace(&mut self) -> Vec<Delivery> {
        let mut t = Network::take_trace(self);
        t.sort_unstable();
        t
    }

    fn send_directed(&mut self, src: NodeId, dst: NodeId, proto: Proto, payload: Payload) -> u64 {
        Network::send_directed(self, src, dst, proto, payload)
    }
    fn send_broadcast(&mut self, src: NodeId, proto: Proto, payload: Payload) -> u64 {
        Network::send_broadcast(self, src, proto, payload)
    }
    fn send_multicast(
        &mut self,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        Network::send_multicast(self, src, dsts, proto, payload)
    }
    fn app_multicast_at(
        &mut self,
        at: Time,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        Network::app_multicast_at(self, at, src, dsts, proto, payload)
    }
    fn timer_at(&mut self, at: Time, node: NodeId, tag: u64) {
        Network::timer_at(self, at, node, tag)
    }
    fn fail_link(&mut self, l: LinkId) {
        Network::fail_link(self, l)
    }
    fn repair_link(&mut self, l: LinkId) {
        Network::repair_link(self, l)
    }
    fn record_reroute_convergence(&mut self, ns: Time) {
        self.metrics.reroute_convergence_ns = self.metrics.reroute_convergence_ns.max(ns);
    }

    fn open(&mut self, node: NodeId, mode: CommMode) -> Endpoint {
        Network::open(self, node, mode)
    }
    fn connect(&mut self, ep: &Endpoint, dst: NodeId) {
        Network::connect(self, ep, dst)
    }
    fn send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        Network::send(self, ep, dst, msg)
    }
    fn send_at(&mut self, at: Time, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        Network::send_at(self, at, ep, dst, msg)
    }
    fn recv(&mut self, ep: &Endpoint) -> Vec<Message> {
        Network::recv(self, ep)
    }
    fn open_with_rx_capacity(&mut self, node: NodeId, mode: CommMode, cap: u32) -> Endpoint {
        Network::open_with_rx_capacity(self, node, mode, cap)
    }

    fn reliable_open(
        &mut self,
        node: NodeId,
        mode: CommMode,
        params: ReliableParams,
    ) -> Endpoint {
        Network::reliable_open(self, node, mode, params)
    }
    fn reliable_send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        Network::reliable_send(self, ep, dst, msg)
    }
    fn reliable_send_at(&mut self, at: Time, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        Network::reliable_send_at(self, at, ep, dst, msg)
    }
    fn reliable_watch(&mut self, ep: &Endpoint, peer: NodeId, until: Time) {
        Network::reliable_watch(self, ep, peer, until)
    }
    fn reliable_is_down(&self, ep: &Endpoint, peer: NodeId) -> bool {
        Network::reliable_is_down(self, ep, peer)
    }
    fn reliable_take_unacked(&mut self, ep: &Endpoint, peer: NodeId) -> Vec<Message> {
        Network::reliable_take_unacked(self, ep, peer)
    }

    fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8, width_bits: u8) {
        Network::fifo_connect(self, src, dst, channel, width_bits)
    }
    fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]) {
        Network::fifo_send(self, src, channel, words)
    }
    fn fifo_read(&mut self, node: NodeId, channel: u8, max: usize) -> Vec<u64> {
        Network::fifo_read(self, node, channel, max)
    }
    fn pm_open(&mut self, target: NodeId, queue: u8) {
        Network::pm_open(self, target, queue)
    }
    fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>) {
        Network::pm_send(self, src, target, queue, data)
    }
    fn pm_read(&mut self, node: NodeId, queue: u8) -> Vec<PmRecord> {
        Network::pm_read(self, node, queue)
    }
    fn eth_set_mode(&mut self, node: NodeId, mode: RxMode) {
        Network::eth_set_mode(self, node, mode)
    }
    fn eth_send(&mut self, src: NodeId, dst: NodeId, bytes: u32, tag: u64) {
        Network::eth_send(self, src, dst, bytes, tag)
    }
    fn eth_send_message(&mut self, src: NodeId, dst: NodeId, bytes: u64, tag: u64) -> u32 {
        Network::eth_send_message(self, src, dst, bytes, tag)
    }
    fn eth_read(&mut self, node: NodeId) -> Vec<EthFrame> {
        Network::eth_read(self, node)
    }
    fn nfs_put(&mut self, node: NodeId, name: &str, size: u64) {
        Network::nfs_put(self, node, name, size)
    }
    fn gateway(&self) -> NodeId {
        Network::gateway(self)
    }
    fn nat_forward(&mut self, external_port: u16, node: NodeId, internal_port: u16) {
        Network::nat_forward(self, external_port, node, internal_port)
    }
    fn external_ingress_at(
        &mut self,
        at: Time,
        external_port: u16,
        bytes: u32,
        tag: u64,
    ) -> bool {
        Network::external_ingress_at(self, at, external_port, bytes, tag)
    }
    fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64) {
        Network::tunnel_write(self, src, dst, addr, value)
    }
    fn tunnel_read(&mut self, src: NodeId, dst: NodeId, addr: u64) -> u64 {
        Network::tunnel_read(self, src, dst, addr)
    }
    fn tunnel_result(&self, req_id: u64) -> Option<u64> {
        Network::tunnel_result(self, req_id)
    }

    fn run<A: ShardableApp>(&mut self, app: &mut A) -> u64 {
        self.run_to_quiescence(app)
    }
    fn run_until<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64 {
        Network::run_until(self, app, deadline)
    }
    fn run_window<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64 {
        Network::run_window(self, app, deadline)
    }
}

impl Fabric for ShardedNetwork {
    fn topo(&self) -> &Arc<Topology> {
        &self.topo
    }
    fn config(&self) -> &SystemConfig {
        ShardedNetwork::config(self)
    }
    fn now(&self) -> Time {
        ShardedNetwork::now(self)
    }
    fn advance_to(&mut self, t: Time) {
        ShardedNetwork::advance_to(self, t)
    }
    fn dispatched(&self) -> u64 {
        ShardedNetwork::dispatched(self)
    }

    fn metrics(&self) -> Metrics {
        ShardedNetwork::metrics(self)
    }
    fn enable_trace(&mut self) {
        ShardedNetwork::enable_trace(self)
    }
    fn take_trace(&mut self) -> Vec<Delivery> {
        ShardedNetwork::take_trace(self)
    }

    fn send_directed(&mut self, src: NodeId, dst: NodeId, proto: Proto, payload: Payload) -> u64 {
        ShardedNetwork::send_directed(self, src, dst, proto, payload)
    }
    fn send_broadcast(&mut self, src: NodeId, proto: Proto, payload: Payload) -> u64 {
        ShardedNetwork::send_broadcast(self, src, proto, payload)
    }
    fn send_multicast(
        &mut self,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        ShardedNetwork::send_multicast(self, src, dsts, proto, payload)
    }
    fn app_multicast_at(
        &mut self,
        at: Time,
        src: NodeId,
        dsts: &[NodeId],
        proto: Proto,
        payload: Payload,
    ) -> u64 {
        ShardedNetwork::app_multicast_at(self, at, src, dsts, proto, payload)
    }
    fn timer_at(&mut self, at: Time, node: NodeId, tag: u64) {
        ShardedNetwork::timer_at(self, at, node, tag)
    }
    fn fail_link(&mut self, l: LinkId) {
        ShardedNetwork::fail_link(self, l)
    }
    fn repair_link(&mut self, l: LinkId) {
        ShardedNetwork::repair_link(self, l)
    }
    fn record_reroute_convergence(&mut self, ns: Time) {
        ShardedNetwork::record_reroute_convergence(self, ns)
    }

    fn open(&mut self, node: NodeId, mode: CommMode) -> Endpoint {
        ShardedNetwork::open(self, node, mode)
    }
    fn connect(&mut self, ep: &Endpoint, dst: NodeId) {
        ShardedNetwork::connect(self, ep, dst)
    }
    fn send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        ShardedNetwork::send(self, ep, dst, msg)
    }
    fn send_at(&mut self, at: Time, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        ShardedNetwork::send_at(self, at, ep, dst, msg)
    }
    fn recv(&mut self, ep: &Endpoint) -> Vec<Message> {
        ShardedNetwork::recv(self, ep)
    }
    fn open_with_rx_capacity(&mut self, node: NodeId, mode: CommMode, cap: u32) -> Endpoint {
        ShardedNetwork::open_with_rx_capacity(self, node, mode, cap)
    }

    fn reliable_open(
        &mut self,
        node: NodeId,
        mode: CommMode,
        params: ReliableParams,
    ) -> Endpoint {
        ShardedNetwork::reliable_open(self, node, mode, params)
    }
    fn reliable_send(&mut self, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        ShardedNetwork::reliable_send(self, ep, dst, msg)
    }
    fn reliable_send_at(&mut self, at: Time, ep: &Endpoint, dst: NodeId, msg: Message) -> MsgId {
        ShardedNetwork::reliable_send_at(self, at, ep, dst, msg)
    }
    fn reliable_watch(&mut self, ep: &Endpoint, peer: NodeId, until: Time) {
        ShardedNetwork::reliable_watch(self, ep, peer, until)
    }
    fn reliable_is_down(&self, ep: &Endpoint, peer: NodeId) -> bool {
        ShardedNetwork::reliable_is_down(self, ep, peer)
    }
    fn reliable_take_unacked(&mut self, ep: &Endpoint, peer: NodeId) -> Vec<Message> {
        ShardedNetwork::reliable_take_unacked(self, ep, peer)
    }

    fn fifo_connect(&mut self, src: NodeId, dst: NodeId, channel: u8, width_bits: u8) {
        ShardedNetwork::fifo_connect(self, src, dst, channel, width_bits)
    }
    fn fifo_send(&mut self, src: NodeId, channel: u8, words: &[u64]) {
        ShardedNetwork::fifo_send(self, src, channel, words)
    }
    fn fifo_read(&mut self, node: NodeId, channel: u8, max: usize) -> Vec<u64> {
        ShardedNetwork::fifo_read(self, node, channel, max)
    }
    fn pm_open(&mut self, target: NodeId, queue: u8) {
        ShardedNetwork::pm_open(self, target, queue)
    }
    fn pm_send(&mut self, src: NodeId, target: NodeId, queue: u8, data: Vec<u8>) {
        ShardedNetwork::pm_send(self, src, target, queue, data)
    }
    fn pm_read(&mut self, node: NodeId, queue: u8) -> Vec<PmRecord> {
        self.shard_mut(node).pm_read(node, queue)
    }
    fn eth_set_mode(&mut self, node: NodeId, mode: RxMode) {
        self.shard_mut(node).eth_set_mode(node, mode)
    }
    fn eth_send(&mut self, src: NodeId, dst: NodeId, bytes: u32, tag: u64) {
        ShardedNetwork::eth_send(self, src, dst, bytes, tag)
    }
    fn eth_send_message(&mut self, src: NodeId, dst: NodeId, bytes: u64, tag: u64) -> u32 {
        ShardedNetwork::eth_send_message(self, src, dst, bytes, tag)
    }
    fn eth_read(&mut self, node: NodeId) -> Vec<EthFrame> {
        self.shard_mut(node).eth_read(node)
    }
    fn nfs_put(&mut self, node: NodeId, name: &str, size: u64) {
        ShardedNetwork::nfs_put(self, node, name, size)
    }
    fn gateway(&self) -> NodeId {
        ShardedNetwork::gateway(self)
    }
    fn nat_forward(&mut self, external_port: u16, node: NodeId, internal_port: u16) {
        ShardedNetwork::nat_forward(self, external_port, node, internal_port)
    }
    fn external_ingress_at(
        &mut self,
        at: Time,
        external_port: u16,
        bytes: u32,
        tag: u64,
    ) -> bool {
        ShardedNetwork::external_ingress_at(self, at, external_port, bytes, tag)
    }
    fn tunnel_write(&mut self, src: NodeId, dst: NodeId, addr: u64, value: u64) {
        ShardedNetwork::tunnel_write(self, src, dst, addr, value)
    }
    fn tunnel_read(&mut self, src: NodeId, dst: NodeId, addr: u64) -> u64 {
        ShardedNetwork::tunnel_read(self, src, dst, addr)
    }
    fn tunnel_result(&self, req_id: u64) -> Option<u64> {
        ShardedNetwork::tunnel_result(self, req_id)
    }

    fn run<A: ShardableApp>(&mut self, app: &mut A) -> u64 {
        self.run_app(app)
    }
    fn run_until<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64 {
        self.run_until_app(app, deadline)
    }
    fn run_window<A: ShardableApp>(&mut self, app: &mut A, deadline: Time) -> u64 {
        self.run_window_app(app, deadline)
    }
}
