//! Collective operations over the simulated fabric.
//!
//! A ring all-reduce (reduce-scatter + all-gather, 2(k−1) steps of
//! `bytes/k` each) implemented as an event-driven [`App`]: every rank
//! sends its current chunk to its ring successor and advances when the
//! predecessor's chunk lands. The fabric therefore sees the *real*
//! packet pattern (congestion, credit stalls, adaptive routing) while
//! the numeric reduction itself happens in the coordinator on real
//! data.
//!
//! The collective is engine-agnostic **and mode-generic**: chunks
//! travel as unified [`Message`]s over any [`CommMode`]
//! ([`RingAllreduce::with_mode`]) — Postmaster DMA by default, whose
//! per-record payload cap sets the fragment size; over internal
//! Ethernet or Bridge FIFO a chunk rides as one natively-segmented
//! message. The final fragment of a chunk carries a marker with the
//! sender's current forward value, and receipt of the marker advances
//! the receiving rank — the same protocol whichever channel carries it.
//!
//! # The reduced value is real
//!
//! Each rank contributes a deterministic 64-bit value; markers carry a
//! forwarding chain (each rank re-sends the value it last received), so
//! after the k−1 reduce-scatter steps every rank has accumulated every
//! other rank's contribution exactly once — [`RingAllreduce::reduced`]
//! must equal the sum over participating ranks, and the chaos harness
//! checks exactly that ("training completes with the correct result").
//!
//! # Reliable mode: the ring shrinks instead of hanging
//!
//! With [`RingAllreduce::with_mode_reliable`] every rank's endpoint
//! runs the ack/retransmit transport ([`crate::channels::reliable`])
//! and watches its current ring successor's liveness. When a rank dies
//! mid-collective (chaos `drop` scenario), either the transport's retry
//! budget or the heartbeat monitor surfaces
//! [`App::on_peer_down`] at the dead rank's predecessor, which removes
//! the victim from the ring, broadcasts a `RESTART` carrying the dead
//! set to every survivor, and every survivor restarts the collective
//! over the shrunk ring. Restarts are *epoch*-stamped (epoch = number
//! of known-dead ranks): markers from older epochs are ignored, markers
//! from newer epochs are buffered until the local rank catches up, so
//! overlapping restarts converge. The survivors' reduced value is the
//! sum over survivors — degraded membership, correct arithmetic.
//!
//! As a [`ShardableApp`], per-rank receive state lives with the rank's
//! node (so each sharded partition only ever touches its own ranks) and
//! the aggregate stats are sum-reduced. A sharded run is byte-identical
//! to a serial one (all traffic uses the endpoint sends' per-node app
//! id space; see `tests/sharded_differential.rs`).
//!
//! [`App::on_peer_down`]: crate::network::App::on_peer_down

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::channels::reliable::{ReliableParams, RELIABLE_HEADER_BYTES};
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::sim::Time;
use crate::topology::NodeId;
use crate::util::rng::mix64;

/// Outcome of a simulated collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectiveStats {
    /// Virtual time from kickoff to the last rank finishing.
    pub makespan: Time,
    /// Payload bytes handed to the channel (excluding per-mode framing
    /// and packet headers).
    pub bytes_on_wire: u64,
    /// Chunk-messages sent (pre-fragmentation; restarts send more).
    pub messages: u64,
}

/// Fragment-payload markers (first byte of every chunk fragment).
const FRAG_MID: u8 = 0;
/// Final fragment of a chunk: `[1][epoch][forward value: u64 LE]…`.
const FRAG_LAST: u8 = 1;
/// Ring-restart control message: `[2][dead-rank bitmap: u64 LE]`.
const CTL_RESTART: u8 = 2;

/// Event-driven ring all-reduce over `ranks`.
#[derive(Clone)]
pub struct RingAllreduce {
    ranks: Vec<NodeId>,
    /// rank index by node id.
    index: Vec<Option<usize>>,
    /// Deterministic per-rank contribution to the reduction.
    contrib: Vec<u64>,
    /// Chunk-markers received by each rank in its current epoch.
    received: Vec<u32>,
    /// Sum of marker values accumulated during the current epoch's
    /// reduce-scatter phase (the rank's reduced value is `contrib +
    /// acc_recv`; wrapping arithmetic throughout).
    acc_recv: Vec<u64>,
    /// Value each rank forwards in its next marker (the forwarding
    /// chain: initially the rank's own contribution, then whatever it
    /// last received).
    fwd: Vec<u64>,
    /// Each rank's knowledge of dead ranks, as a rank-index bitmap
    /// (reliable mode; epoch = popcount).
    dead: Vec<u64>,
    /// Whether each rank has completed its current epoch.
    done: Vec<bool>,
    /// Markers from future epochs, buffered until the rank restarts
    /// into them: per rank, `(epoch, value)` in arrival order.
    future: Vec<Vec<(u8, u64)>>,
    /// Ranks this instance's shard owns (sharded partitions; the parent
    /// owns every rank). A rank's dynamic state only ever mutates in
    /// callbacks at its own node, so reduction adopts each rank's state
    /// wholesale from its owning partition — stable across repeated
    /// window runs, not just one run-to-quiescence.
    owned: Vec<bool>,
    chunk_bytes: u32,
    /// Fragment size: the mode's max payload — minus the reliable
    /// transport's frame header in reliable mode (chunks over unbounded
    /// modes travel as one message).
    frag_bytes: u32,
    mode: CommMode,
    /// Run over the reliable transport, shrinking the ring on
    /// `PeerDown`.
    reliable: Option<ReliableParams>,
    /// Liveness-watch bound (reliable mode): successors are monitored
    /// until this virtual time.
    watch_until: Time,
    pub stats: CollectiveStats,
}

impl RingAllreduce {
    /// Prepare an all-reduce of `bytes` per rank across `ranks` (on
    /// either engine), over the default Postmaster DMA transport.
    pub fn new<F: Fabric>(net: &mut F, ranks: Vec<NodeId>, bytes: u64) -> Self {
        Self::with_mode(net, ranks, bytes, CommMode::Postmaster { queue: 0 })
    }

    /// Prepare an all-reduce over an explicit communication mode:
    /// endpoints open at every rank, ring-successor pairs connected
    /// where the mode requires per-pair setup.
    pub fn with_mode<F: Fabric>(
        net: &mut F,
        ranks: Vec<NodeId>,
        bytes: u64,
        mode: CommMode,
    ) -> Self {
        Self::build(net, ranks, bytes, mode, None, 0)
    }

    /// Prepare an all-reduce over the **reliable** transport: the mode
    /// must be one the transport supports (unordered, with room for its
    /// frame header — Postmaster or Ethernet), every rank watches its
    /// ring successor's liveness until `watch_until`, and a dead rank
    /// shrinks the ring instead of hanging it (module docs).
    pub fn with_mode_reliable<F: Fabric>(
        net: &mut F,
        ranks: Vec<NodeId>,
        bytes: u64,
        mode: CommMode,
        params: ReliableParams,
        watch_until: Time,
    ) -> Self {
        Self::build(net, ranks, bytes, mode, Some(params), watch_until)
    }

    fn build<F: Fabric>(
        net: &mut F,
        ranks: Vec<NodeId>,
        bytes: u64,
        mode: CommMode,
        reliable: Option<ReliableParams>,
        watch_until: Time,
    ) -> Self {
        assert!(ranks.len() >= 2, "all-reduce needs ≥2 ranks");
        let k = ranks.len();
        let chunk_bytes = (bytes / k as u64).max(1) as u32;
        let caps = net.caps(mode);
        let frag_payload = caps
            .max_payload
            .map(|m| if reliable.is_some() { m - RELIABLE_HEADER_BYTES } else { m });
        let frag_bytes = frag_payload.unwrap_or(chunk_bytes).max(1);
        if reliable.is_some() {
            assert!(k <= 64, "reliable ring membership is a 64-bit rank bitmap");
            assert!(
                chunk_bytes >= 10 && frag_bytes >= 10,
                "reliable ring markers carry an epoch and a value (10 B); \
                 raise bytes or lower the rank count"
            );
        }
        let mut index = vec![None; net.topo().node_count()];
        for (i, r) in ranks.iter().enumerate() {
            index[r.0 as usize] = Some(i);
        }
        for &r in &ranks {
            match reliable {
                Some(p) => {
                    net.reliable_open(r, mode, p);
                }
                None => {
                    net.open(r, mode);
                }
            }
        }
        if caps.pair_setup {
            for (i, &r) in ranks.iter().enumerate() {
                let ep = Endpoint { node: r, mode };
                net.connect(&ep, ranks[(i + 1) % k]);
            }
        }
        RingAllreduce {
            contrib: (0..k).map(|i| mix64(0xC0_11EC_71FE ^ i as u64)).collect(),
            received: vec![0; k],
            acc_recv: vec![0; k],
            fwd: (0..k).map(|i| mix64(0xC0_11EC_71FE ^ i as u64)).collect(),
            dead: vec![0; k],
            done: vec![false; k],
            future: vec![Vec::new(); k],
            owned: vec![true; k],
            ranks,
            index,
            chunk_bytes,
            frag_bytes,
            mode,
            reliable,
            watch_until,
            stats: CollectiveStats { makespan: 0, bytes_on_wire: 0, messages: 0 },
        }
    }

    /// Dead ranks across the *survivors'* views (rank-index bitmap).
    /// A dying rank can mis-declare a live peer dead — its own inbound
    /// links go first under the two-phase chaos death, so acks stop
    /// reaching it while it still retries — so the union is taken in
    /// two passes: first over every rank's view (identifying the exiled
    /// set), then again over only the ranks outside it, discarding the
    /// exiles' poisoned claims.
    pub fn dead_union(&self) -> u64 {
        let raw = self.dead.iter().fold(0, |a, &b| a | b);
        self.dead
            .iter()
            .enumerate()
            .filter(|&(i, _)| raw & (1 << i) == 0)
            .fold(0, |a, (_, &b)| a | b)
    }

    /// `rank`'s reduced value: its contribution plus everything it
    /// accumulated over its final epoch.
    pub fn reduced(&self, rank: usize) -> u64 {
        self.contrib[rank].wrapping_add(self.acc_recv[rank])
    }

    /// The correct reduction over the surviving membership: the sum of
    /// the contributions of every rank not in [`RingAllreduce::dead_union`].
    pub fn expected_sum(&self) -> u64 {
        let dead = self.dead_union();
        (0..self.ranks.len())
            .filter(|&i| dead & (1 << i) == 0)
            .fold(0u64, |a, i| a.wrapping_add(self.contrib[i]))
    }

    /// Whether every surviving rank has completed (its current epoch).
    /// Meaningful on the parent app after the run (sharded partitions
    /// have been reduced back by then).
    pub fn is_complete(&self) -> bool {
        let dead = self.dead_union();
        (0..self.ranks.len()).all(|i| self.done[i] || dead & (1 << i) != 0)
    }

    /// Panic unless every survivor completed **and** holds exactly the
    /// sum over survivors — the chaos-harness acceptance check. (Chunks
    /// under 10 B have no room for a marker value, so the degenerate
    /// tiny-chunk case only checks completion.)
    pub fn assert_reduced(&self) {
        assert!(self.is_complete(), "all-reduce did not complete on every survivor");
        if !self.marker_room() {
            return;
        }
        let dead = self.dead_union();
        let want = self.expected_sum();
        for i in 0..self.ranks.len() {
            if dead & (1 << i) == 0 {
                assert_eq!(
                    self.reduced(i),
                    want,
                    "rank {i} reduced to the wrong value (dead set {dead:#b})"
                );
            }
        }
    }

    /// Kick off every rank's first step (and, in reliable mode, its
    /// successor liveness watch). Driver context; the harness runs the
    /// fabric afterwards (stepped or to quiescence).
    pub fn kickoff<F: Fabric>(&mut self, net: &mut F) {
        let ranks = self.ranks.clone();
        for (i, &r) in ranks.iter().enumerate() {
            if self.reliable.is_some() {
                let ep = Endpoint { node: r, mode: self.mode };
                net.reliable_watch(&ep, ranks[(i + 1) % ranks.len()], self.watch_until);
            }
            self.send_step(net, r);
        }
    }

    /// Kick off and run the fabric to completion. Returns the stats;
    /// the makespan is the virtual-time cost of the all-reduce.
    pub fn run<F: Fabric>(mut self, net: &mut F) -> CollectiveStats {
        let t0 = net.now();
        self.kickoff(net);
        net.run(&mut self);
        self.assert_reduced();
        self.stats.makespan = net.now() - t0;
        self.stats
    }

    /// Whether markers can carry a forward value (10 B of room in the
    /// final fragment): true for every realistic configuration; false
    /// only for sub-10-byte chunks or payload caps (e.g. the 8 B
    /// NetTunnel), where the collective degrades to completion-only.
    fn marker_room(&self) -> bool {
        self.frag_bytes >= 10 && self.chunk_bytes >= 10
    }

    /// `rank`'s current ring successor under its own dead set (`None`
    /// once no other rank is live).
    fn successor(&self, rank: usize) -> Option<NodeId> {
        let k = self.ranks.len();
        let dead = self.dead[rank];
        (1..k)
            .map(|s| (rank + s) % k)
            .find(|&j| dead & (1 << j) == 0)
            .map(|j| self.ranks[j])
    }

    /// Live membership size under `rank`'s own dead set.
    fn live(&self, rank: usize) -> u32 {
        self.ranks.len() as u32 - self.dead[rank].count_ones()
    }

    /// Send rank `node`'s current chunk to its ring successor, as
    /// fragments of at most the mode's max payload; the *last* fragment
    /// carries the step marker — epoch, plus the rank's forward value
    /// when the fragment has room (≥ 10 B; always true in reliable
    /// mode) — and its receipt advances the receiver. Called from
    /// driver context (kickoff) and from `on_message` callbacks at
    /// `node` — the endpoint sends' per-node app ids keep serial and
    /// sharded runs identical.
    fn send_step<F: Fabric>(&mut self, net: &mut F, node: NodeId) {
        let rank = self.index[node.0 as usize].expect("send_step at non-rank");
        let Some(next) = self.successor(rank) else { return };
        let ep = Endpoint { node, mode: self.mode };
        let now = net.now();
        let epoch = self.dead[rank].count_ones() as u8;
        let mut left = self.chunk_bytes;
        while left > 0 {
            let mut take = left.min(self.frag_bytes);
            if self.marker_room() && take < left && left - take < 10 {
                // Never strand a final fragment too small for its
                // marker value: shorten this fragment instead.
                take = left - 10;
            }
            let mut data = vec![0u8; take as usize];
            data[0] = FRAG_MID;
            if take == left {
                data[0] = FRAG_LAST;
                if take >= 10 {
                    data[1] = epoch;
                    data[2..10].copy_from_slice(&self.fwd[rank].to_le_bytes());
                }
            }
            if self.reliable.is_some() {
                net.reliable_send_at(now, &ep, next, Message::new(data));
            } else {
                net.send_at(now, &ep, next, Message::new(data));
            }
            self.stats.bytes_on_wire += take as u64;
            left -= take;
        }
        self.stats.messages += 1;
    }

    /// A step marker landed at `node` (already filtered to this rank's
    /// current epoch).
    fn on_marker<F: Fabric>(&mut self, net: &mut F, node: NodeId, value: u64) {
        let rank = self.index[node.0 as usize].expect("collective message at non-rank");
        let live = self.live(rank);
        let total = 2 * (live - 1);
        self.received[rank] += 1;
        let r = self.received[rank];
        if r > total {
            return;
        }
        // Reduce-scatter phase: the value received at step s is the
        // contribution of the rank s hops back — the first live−1 of
        // them cover every other live rank exactly once. The all-gather
        // phase keeps the traffic pattern but the arithmetic is done.
        if r < live {
            self.acc_recv[rank] = self.acc_recv[rank].wrapping_add(value);
        }
        if r < total {
            self.fwd[rank] = value;
            self.send_step(net, node);
        } else {
            self.done[rank] = true;
        }
    }

    /// Restart `node`'s rank into its current epoch: reset the
    /// arithmetic, re-watch the (possibly new) successor, resend the
    /// first step, then replay any buffered markers that were already
    /// waiting for this epoch.
    fn restart<F: Fabric>(&mut self, net: &mut F, node: NodeId) {
        let rank = self.index[node.0 as usize].expect("restart at non-rank");
        self.received[rank] = 0;
        self.acc_recv[rank] = 0;
        self.fwd[rank] = self.contrib[rank];
        self.done[rank] = false;
        if self.dead[rank] & (1 << rank) != 0 {
            // Exiled: the survivors declared this rank dead (it was
            // unreachable long enough). Stop participating — its value
            // is excluded from the check either way.
            self.done[rank] = true;
            return;
        }
        if self.live(rank) < 2 {
            // A ring of one has nothing to reduce with.
            self.done[rank] = true;
            return;
        }
        let ep = Endpoint { node, mode: self.mode };
        if self.reliable.is_some() {
            let succ = self.successor(rank).expect("live ≥ 2 has a successor");
            net.reliable_watch(&ep, succ, self.watch_until);
        }
        self.send_step(net, node);
        let epoch = self.dead[rank].count_ones() as u8;
        let buffered = std::mem::take(&mut self.future[rank]);
        for (e, v) in buffered {
            match e.cmp(&epoch) {
                std::cmp::Ordering::Equal => self.on_marker(net, node, v),
                std::cmp::Ordering::Greater => self.future[rank].push((e, v)),
                std::cmp::Ordering::Less => {}
            }
        }
    }

    /// `rank` (at `node`) learned of newly dead ranks: merge, tell the
    /// survivors, restart.
    fn on_dead_info<F: Fabric>(&mut self, net: &mut F, node: NodeId, bitmap: u64) {
        let rank = self.index[node.0 as usize].expect("ring control at non-rank");
        let merged = self.dead[rank] | bitmap;
        if merged == self.dead[rank] {
            return;
        }
        self.dead[rank] = merged;
        let ep = Endpoint { node, mode: self.mode };
        let now = net.now();
        let mut ctl = vec![CTL_RESTART];
        ctl.extend_from_slice(&merged.to_le_bytes());
        for (j, &r) in self.ranks.clone().iter().enumerate() {
            if j == rank || merged & (1 << j) != 0 || net.reliable_is_down(&ep, r) {
                continue;
            }
            net.reliable_send_at(now, &ep, r, Message::new(ctl.clone()));
        }
        self.restart(net, node);
    }
}

impl App for RingAllreduce {
    fn on_message(&mut self, net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
        // Every fragment is consumed on delivery, so a run retains only
        // the in-flight window instead of every fragment it ever moved.
        let node = ep.node;
        match msg.data.first() {
            Some(&FRAG_LAST) => {
                let (epoch, value) = if msg.data.len() >= 10 {
                    (
                        msg.data[1],
                        u64::from_le_bytes(msg.data[2..10].try_into().expect("len checked")),
                    )
                } else {
                    (0, 0)
                };
                let rank = self.index[node.0 as usize].expect("collective message at non-rank");
                let mine = self.dead[rank].count_ones() as u8;
                if epoch < mine {
                    // Stale marker from before a restart this rank has
                    // already performed.
                } else if epoch > mine {
                    // The sender knows of deaths this rank hasn't
                    // learned yet; hold the marker until it catches up.
                    self.future[rank].push((epoch, value));
                } else {
                    self.on_marker(net, node, value);
                }
            }
            Some(&CTL_RESTART) if msg.data.len() >= 9 => {
                let bm = u64::from_le_bytes(msg.data[1..9].try_into().expect("len checked"));
                self.on_dead_info(net, node, bm);
            }
            _ => {} // mid-chunk fragment: pure traffic
        }
        true
    }

    fn on_peer_down(&mut self, net: &mut Network, ep: Endpoint, peer: NodeId) {
        let Some(rank) = self.index[ep.node.0 as usize] else { return };
        let Some(pr) = self.index[peer.0 as usize] else { return };
        if self.dead[rank] & (1 << pr) != 0 {
            return;
        }
        // The in-flight chunk to the dead successor is obsolete — the
        // restart regenerates the traffic over the shrunk ring.
        let _ = net.reliable_take_unacked(&ep, peer);
        self.on_dead_info(net, ep.node, self.dead[rank] | (1 << pr));
    }
}

impl ShardableApp for RingAllreduce {
    /// Each partition continues from the parent's full state; a rank's
    /// dynamic state only ever mutates in callbacks at its own node
    /// (exactly one shard), so reduction adopts each rank's state
    /// wholesale from the partition that owns it. Only the stats are
    /// deltas (zeroed per partition, summed back) — this keeps the app
    /// correct across repeated window runs, which the chaos harness
    /// relies on.
    fn partition(&self, shard: u32, owner: &[u32]) -> Self {
        RingAllreduce {
            ranks: self.ranks.clone(),
            index: self.index.clone(),
            contrib: self.contrib.clone(),
            received: self.received.clone(),
            acc_recv: self.acc_recv.clone(),
            fwd: self.fwd.clone(),
            dead: self.dead.clone(),
            done: self.done.clone(),
            future: self.future.clone(),
            owned: self.ranks.iter().map(|r| owner[r.0 as usize] == shard).collect(),
            chunk_bytes: self.chunk_bytes,
            frag_bytes: self.frag_bytes,
            mode: self.mode,
            reliable: self.reliable,
            watch_until: self.watch_until,
            stats: CollectiveStats { makespan: 0, bytes_on_wire: 0, messages: 0 },
        }
    }

    fn reduce(&mut self, part: Self) {
        for i in 0..self.ranks.len() {
            if part.owned[i] {
                self.received[i] = part.received[i];
                self.acc_recv[i] = part.acc_recv[i];
                self.fwd[i] = part.fwd[i];
                self.dead[i] = part.dead[i];
                self.done[i] = part.done[i];
                self.future[i] = part.future[i].clone();
            }
        }
        self.stats.bytes_on_wire += part.stats.bytes_on_wire;
        self.stats.messages += part.stats.messages;
    }
}

/// Numeric helper: element-wise mean across per-rank gradient vectors
/// (the arithmetic half of the all-reduce; the traffic half is
/// [`RingAllreduce`]).
pub fn mean_reduce(mut grads: Vec<Vec<f32>>) -> Vec<f32> {
    assert!(!grads.is_empty());
    let k = grads.len() as f32;
    let mut acc = grads.pop().unwrap();
    for g in &grads {
        assert_eq!(g.len(), acc.len(), "gradient length mismatch");
        for (a, b) in acc.iter_mut().zip(g) {
            *a += *b;
        }
    }
    for a in &mut acc {
        *a /= k;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ethernet::RxMode;
    use crate::config::SystemConfig;
    use crate::coordinator::Placement;

    #[test]
    fn allreduce_completes_and_scales_with_bytes() {
        let mut net = Network::card();
        let ranks = Placement::Block.select(&net.topo, 8);
        let small = RingAllreduce::new(&mut net, ranks.clone(), 64 * 1024).run(&mut net);
        let mut net2 = Network::card();
        let big = RingAllreduce::new(&mut net2, ranks, 1024 * 1024).run(&mut net2);
        assert!(small.makespan > 0);
        assert!(big.makespan > small.makespan);
        assert!(big.bytes_on_wire > small.bytes_on_wire);
    }

    #[test]
    fn allreduce_message_count_is_2k_minus_1_rounds() {
        let mut net = Network::card();
        let ranks = Placement::Block.select(&net.topo, 4);
        let stats = RingAllreduce::new(&mut net, ranks, 4096).run(&mut net);
        // Every rank sends 2(k-1) chunk-messages.
        assert_eq!(stats.messages, 4 * 2 * 3);
    }

    #[test]
    fn allreduce_reduces_to_the_sum_of_contributions() {
        // run() asserts each rank's reduced value equals the sum; this
        // test additionally pins the arithmetic shape down.
        let mut net = Network::card();
        let ranks = Placement::Block.select(&net.topo, 6);
        let ar = RingAllreduce::new(&mut net, ranks, 64 * 1024);
        let want = (0..6).fold(0u64, |a, i| a.wrapping_add(mix64(0xC0_11EC_71FE ^ i)));
        assert_eq!(ar.expected_sum(), want);
        ar.run(&mut net);
    }

    #[test]
    fn allreduce_is_mode_generic() {
        // Same collective over all three modes: same message count,
        // mode-dependent makespan with the software path slowest.
        let run = |mode: CommMode| {
            let mut net = Network::card();
            let ranks = Placement::Block.select(&net.topo, 4);
            RingAllreduce::with_mode(&mut net, ranks, 64 * 1024, mode).run(&mut net)
        };
        let pm = run(CommMode::Postmaster { queue: 0 });
        let fifo = run(CommMode::BridgeFifo { width_bits: 64 });
        let eth = run(CommMode::Ethernet { rx: RxMode::Interrupt });
        assert_eq!(pm.messages, 4 * 2 * 3);
        assert_eq!(fifo.messages, 4 * 2 * 3);
        assert_eq!(eth.messages, 4 * 2 * 3);
        assert_eq!(pm.bytes_on_wire, fifo.bytes_on_wire);
        assert_eq!(pm.bytes_on_wire, eth.bytes_on_wire);
        assert!(pm.makespan < eth.makespan, "pm {} vs eth {}", pm.makespan, eth.makespan);
        assert!(fifo.makespan < eth.makespan, "fifo {} vs eth {}", fifo.makespan, eth.makespan);
    }

    #[test]
    fn reliable_allreduce_matches_raw_result_without_faults() {
        // On a healthy mesh the reliable transport must be invisible to
        // the collective's outcome (same sum), just costlier (acks).
        let mut net = Network::card();
        let ranks = Placement::Block.select(&net.topo, 4);
        let stats = RingAllreduce::with_mode_reliable(
            &mut net,
            ranks,
            64 * 1024,
            CommMode::Postmaster { queue: 0 },
            ReliableParams::default(),
            2_000_000,
        )
        .run(&mut net);
        assert_eq!(stats.messages, 4 * 2 * 3);
        assert!(net.metrics.acks > 0, "reliable mode must have acked data");
        assert_eq!(net.metrics.peers_declared_down, 0);
    }

    #[test]
    fn reliable_allreduce_shrinks_ring_when_a_rank_dies() {
        // Kill one rank mid-collective (inbound first, outbound later —
        // the chaos drop pattern): the survivors must detect it, shrink
        // the ring, and reduce to the survivors' sum.
        let mut cfg = SystemConfig::card();
        cfg.drop_unroutable = true;
        let mut net = Network::new(cfg);
        let ranks = Placement::Block.select(&net.topo, 4);
        let victim = ranks[2];
        let params = ReliableParams {
            rto_ns: 30_000,
            max_retries: 4,
            heartbeat_ns: 50_000,
            liveness_ns: 300_000,
            ..ReliableParams::default()
        };
        let mut ar = RingAllreduce::with_mode_reliable(
            &mut net,
            ranks.clone(),
            64 * 1024,
            CommMode::Postmaster { queue: 0 },
            params,
            20_000_000,
        );
        let t0 = net.now();
        ar.kickoff(&mut net);
        net.run_until(&mut ar, t0 + 10_000);
        for &l in &net.topo.in_links(victim).to_vec() {
            net.fail_link(l);
        }
        net.run_until(&mut ar, t0 + 30_000);
        for &l in &net.topo.out_links(victim).to_vec() {
            net.fail_link(l);
        }
        net.run_to_quiescence(&mut ar);
        let vi = ranks.iter().position(|&r| r == victim).unwrap();
        assert_eq!(ar.dead_union(), 1 << vi, "exactly the victim declared dead");
        ar.assert_reduced();
        assert!(net.metrics.peers_declared_down > 0);
        assert!(net.metrics.retransmits > 0, "detection went through the retry path");
    }

    #[test]
    fn scattered_placement_has_higher_packet_latency_than_block() {
        // Multi-span links flatten the end-to-end makespan (that is their
        // job — §2.3), so the placement ablation shows up in per-packet
        // latency, not necessarily in ring-allreduce completion time.
        let run = |p: Placement| {
            let mut net = Network::inc3000();
            let ranks = p.select(&net.topo, 8);
            RingAllreduce::new(&mut net, ranks, 256 * 1024).run(&mut net);
            net.metrics.latency("postmaster").unwrap().mean()
        };
        let block = run(Placement::Block);
        let scattered = run(Placement::Scattered);
        assert!(
            scattered > block,
            "scattered packet latency {scattered} vs block {block}"
        );
    }

    #[test]
    fn mean_reduce_math() {
        let g = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(mean_reduce(g), vec![2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "≥2 ranks")]
    fn single_rank_rejected() {
        let mut net = Network::card();
        RingAllreduce::new(&mut net, vec![NodeId(0)], 1024);
    }
}
