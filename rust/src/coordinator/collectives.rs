//! Collective operations over the simulated fabric.
//!
//! A ring all-reduce (reduce-scatter + all-gather, 2(k−1) steps of
//! `bytes/k` each) implemented as an event-driven [`App`]: every rank
//! sends its current chunk to its ring successor as `Proto::Raw` traffic
//! and advances when the predecessor's chunk lands. The fabric therefore
//! sees the *real* packet pattern (congestion, credit stalls, adaptive
//! routing) while the numeric reduction itself happens in the
//! coordinator on real data.
//!
//! The collective is engine-agnostic: it is written against
//! [`Fabric`] and is a [`ShardableApp`] — per-rank receive state lives
//! with the rank's node (so each sharded partition only ever touches
//! its own ranks), and the aggregate stats are sum-reduced. A sharded
//! run is byte-identical to a serial one (traffic ids come from the
//! per-node app id space, see `tests/sharded_differential.rs`).

use crate::network::{App, Fabric, Network, ShardableApp};
use crate::router::{Packet, Payload, Proto, RouteKind};
use crate::sim::Time;
use crate::topology::NodeId;

/// Raw-protocol tag used by collective traffic.
pub const COLLECTIVE_TAG: u16 = 0xC0;

/// Outcome of a simulated collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectiveStats {
    /// Virtual time from kickoff to the last rank finishing.
    pub makespan: Time,
    /// Total bytes put on the fabric.
    pub bytes_on_wire: u64,
    /// Messages (packets at the message level, pre-fragmentation).
    pub messages: u64,
}

/// Event-driven ring all-reduce over `ranks`.
pub struct RingAllreduce {
    ranks: Vec<NodeId>,
    /// rank index by node id.
    index: Vec<Option<usize>>,
    /// Chunks received by each rank so far.
    received: Vec<u32>,
    /// Total steps each rank must receive: 2(k−1).
    total_steps: u32,
    chunk_bytes: u32,
    done_ranks: usize,
    pub stats: CollectiveStats,
}

impl RingAllreduce {
    /// Prepare an all-reduce of `bytes` per rank across `ranks` (on
    /// either engine).
    pub fn new<F: Fabric>(net: &F, ranks: Vec<NodeId>, bytes: u64) -> Self {
        assert!(ranks.len() >= 2, "all-reduce needs ≥2 ranks");
        let k = ranks.len() as u64;
        let chunk_bytes = (bytes / k).max(1) as u32;
        let mut index = vec![None; net.topo().node_count()];
        for (i, r) in ranks.iter().enumerate() {
            index[r.0 as usize] = Some(i);
        }
        RingAllreduce {
            total_steps: 2 * (ranks.len() as u32 - 1),
            ranks,
            index,
            received: vec![],
            chunk_bytes,
            done_ranks: 0,
            stats: CollectiveStats { makespan: 0, bytes_on_wire: 0, messages: 0 },
        }
    }

    /// Kick off the first step and run the fabric to completion.
    /// Returns the stats; the makespan is the virtual-time cost of the
    /// all-reduce.
    pub fn run<F: Fabric>(mut self, net: &mut F) -> CollectiveStats {
        let t0 = net.now();
        self.received = vec![0; self.ranks.len()];
        let ranks = self.ranks.clone();
        for (i, &r) in ranks.iter().enumerate() {
            self.send_step(net, i, r);
        }
        net.run(&mut self);
        assert_eq!(self.done_ranks, self.ranks.len(), "all-reduce did not complete");
        self.stats.makespan = net.now() - t0;
        self.stats
    }

    /// Send rank `node`'s current chunk to its ring successor. Called
    /// from driver context (kickoff) and from `on_raw` callbacks at
    /// `node` — both use the per-node app id space, so serial and
    /// sharded runs assign identical packet ids.
    fn send_step<F: Fabric>(&mut self, net: &mut F, rank: usize, node: NodeId) {
        let next = self.ranks[(rank + 1) % self.ranks.len()];
        // Fragment the chunk at the network MTU.
        let mtu = net.config().link.mtu - crate::router::HEADER_BYTES;
        let mut left = self.chunk_bytes;
        while left > 0 {
            let take = left.min(mtu);
            // The *last* fragment of the chunk carries the step marker;
            // receipt of it advances the receiver.
            let marker = if take == left { 1u64 } else { 0 };
            let id = net.app_packet_id(node);
            // Model `take` bytes on the wire (Synthetic: the chunk's
            // size occupies wire/buffer space, no content carried).
            let mut pkt = Packet::new(
                id,
                node,
                next,
                RouteKind::Directed,
                Proto::Raw { tag: COLLECTIVE_TAG },
                Payload::Synthetic(take),
                net.now(),
            );
            pkt.seq = marker;
            net.inject(pkt);
            self.stats.bytes_on_wire += (crate::router::HEADER_BYTES + take) as u64;
            left -= take;
        }
        self.stats.messages += 1;
    }
}

impl App for RingAllreduce {
    fn on_raw(&mut self, net: &mut Network, node: NodeId, packet: &Packet) {
        if packet.proto != (Proto::Raw { tag: COLLECTIVE_TAG }) {
            return;
        }
        if packet.seq != 1 {
            return; // mid-chunk fragment
        }
        let rank = self.index[node.0 as usize].expect("collective packet at non-rank");
        self.received[rank] += 1;
        let r = self.received[rank];
        if r < self.total_steps {
            self.send_step(net, rank, node);
        } else if r == self.total_steps {
            self.done_ranks += 1;
        }
    }
}

impl ShardableApp for RingAllreduce {
    /// Partitions carry *deltas*: per-rank receive counters restart at
    /// zero (a rank's counter is only ever advanced by callbacks at
    /// that rank's node, i.e. on exactly one shard) and the stats
    /// accumulated so far — the kickoff sends — stay with the parent.
    fn partition(&self, _shard: u32, _owner: &[u32]) -> Self {
        RingAllreduce {
            ranks: self.ranks.clone(),
            index: self.index.clone(),
            received: vec![0; self.ranks.len()],
            total_steps: self.total_steps,
            chunk_bytes: self.chunk_bytes,
            done_ranks: 0,
            stats: CollectiveStats { makespan: 0, bytes_on_wire: 0, messages: 0 },
        }
    }

    fn reduce(&mut self, part: Self) {
        for (a, b) in self.received.iter_mut().zip(&part.received) {
            *a += *b;
        }
        self.done_ranks += part.done_ranks;
        self.stats.bytes_on_wire += part.stats.bytes_on_wire;
        self.stats.messages += part.stats.messages;
    }
}

/// Numeric helper: element-wise mean across per-rank gradient vectors
/// (the arithmetic half of the all-reduce; the traffic half is
/// [`RingAllreduce`]).
pub fn mean_reduce(mut grads: Vec<Vec<f32>>) -> Vec<f32> {
    assert!(!grads.is_empty());
    let k = grads.len() as f32;
    let mut acc = grads.pop().unwrap();
    for g in &grads {
        assert_eq!(g.len(), acc.len(), "gradient length mismatch");
        for (a, b) in acc.iter_mut().zip(g) {
            *a += *b;
        }
    }
    for a in &mut acc {
        *a /= k;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Placement;

    #[test]
    fn allreduce_completes_and_scales_with_bytes() {
        let mut net = Network::card();
        let ranks = Placement::Block.select(&net.topo, 8);
        let small = RingAllreduce::new(&net, ranks.clone(), 64 * 1024).run(&mut net);
        let mut net2 = Network::card();
        let big = RingAllreduce::new(&net2, ranks, 1024 * 1024).run(&mut net2);
        assert!(small.makespan > 0);
        assert!(big.makespan > small.makespan);
        assert!(big.bytes_on_wire > small.bytes_on_wire);
    }

    #[test]
    fn allreduce_message_count_is_2k_minus_1_rounds() {
        let mut net = Network::card();
        let ranks = Placement::Block.select(&net.topo, 4);
        let stats = RingAllreduce::new(&net, ranks, 4096).run(&mut net);
        // Every rank sends 2(k-1) chunk-messages.
        assert_eq!(stats.messages, 4 * 2 * 3);
    }

    #[test]
    fn scattered_placement_has_higher_packet_latency_than_block() {
        // Multi-span links flatten the end-to-end makespan (that is their
        // job — §2.3), so the placement ablation shows up in per-packet
        // latency, not necessarily in ring-allreduce completion time.
        let run = |p: Placement| {
            let mut net = Network::inc3000();
            let ranks = p.select(&net.topo, 8);
            RingAllreduce::new(&net, ranks, 256 * 1024).run(&mut net);
            net.metrics.latency("raw").unwrap().mean()
        };
        let block = run(Placement::Block);
        let scattered = run(Placement::Scattered);
        assert!(
            scattered > block,
            "scattered packet latency {scattered} vs block {block}"
        );
    }

    #[test]
    fn mean_reduce_math() {
        let g = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(mean_reduce(g), vec![2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "≥2 ranks")]
    fn single_rank_rejected() {
        let net = Network::card();
        RingAllreduce::new(&net, vec![NodeId(0)], 1024);
    }
}
