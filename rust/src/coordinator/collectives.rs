//! Collective operations over the simulated fabric.
//!
//! A ring all-reduce (reduce-scatter + all-gather, 2(k−1) steps of
//! `bytes/k` each) implemented as an event-driven [`App`]: every rank
//! sends its current chunk to its ring successor and advances when the
//! predecessor's chunk lands. The fabric therefore sees the *real*
//! packet pattern (congestion, credit stalls, adaptive routing) while
//! the numeric reduction itself happens in the coordinator on real
//! data.
//!
//! The collective is engine-agnostic **and mode-generic**: chunks
//! travel as unified [`Message`]s over any [`CommMode`]
//! ([`RingAllreduce::with_mode`]) — Postmaster DMA by default, whose
//! per-record payload cap sets the fragment size; over internal
//! Ethernet or Bridge FIFO a chunk rides as one natively-segmented
//! message. The final fragment of a chunk carries a one-byte marker,
//! and receipt of the marker advances the receiving rank — the same
//! protocol whichever channel carries it. (Unlike the old
//! `Payload::Synthetic` raw-packet transport, fragments carry real
//! bytes — the price of mode genericity; the app *consumes* every
//! message in its `on_message` callback, so a run retains only the
//! in-flight window instead of filling the recv inboxes.)
//!
//! As a [`ShardableApp`], per-rank receive state lives with the rank's
//! node (so each sharded partition only ever touches its own ranks) and
//! the aggregate stats are sum-reduced. A sharded run is byte-identical
//! to a serial one (all traffic uses the endpoint sends' per-node app
//! id space; see `tests/sharded_differential.rs`).

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::sim::Time;
use crate::topology::NodeId;

/// Outcome of a simulated collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectiveStats {
    /// Virtual time from kickoff to the last rank finishing.
    pub makespan: Time,
    /// Payload bytes handed to the channel (excluding per-mode framing
    /// and packet headers).
    pub bytes_on_wire: u64,
    /// Chunk-messages sent (pre-fragmentation).
    pub messages: u64,
}

/// Event-driven ring all-reduce over `ranks`.
pub struct RingAllreduce {
    ranks: Vec<NodeId>,
    /// rank index by node id.
    index: Vec<Option<usize>>,
    /// Chunks received by each rank so far.
    received: Vec<u32>,
    /// Total steps each rank must receive: 2(k−1).
    total_steps: u32,
    chunk_bytes: u32,
    /// Fragment size: the mode's max payload (chunks over unbounded
    /// modes travel as one message).
    frag_bytes: u32,
    mode: CommMode,
    done_ranks: usize,
    pub stats: CollectiveStats,
}

impl RingAllreduce {
    /// Prepare an all-reduce of `bytes` per rank across `ranks` (on
    /// either engine), over the default Postmaster DMA transport.
    pub fn new<F: Fabric>(net: &mut F, ranks: Vec<NodeId>, bytes: u64) -> Self {
        Self::with_mode(net, ranks, bytes, CommMode::Postmaster { queue: 0 })
    }

    /// Prepare an all-reduce over an explicit communication mode:
    /// endpoints open at every rank, ring-successor pairs connected
    /// where the mode requires per-pair setup.
    pub fn with_mode<F: Fabric>(
        net: &mut F,
        ranks: Vec<NodeId>,
        bytes: u64,
        mode: CommMode,
    ) -> Self {
        assert!(ranks.len() >= 2, "all-reduce needs ≥2 ranks");
        let k = ranks.len() as u64;
        let chunk_bytes = (bytes / k).max(1) as u32;
        let caps = net.caps(mode);
        let frag_bytes = caps.max_payload.unwrap_or(chunk_bytes).max(1);
        let mut index = vec![None; net.topo().node_count()];
        for (i, r) in ranks.iter().enumerate() {
            index[r.0 as usize] = Some(i);
        }
        let eps: Vec<Endpoint> = ranks.iter().map(|&r| net.open(r, mode)).collect();
        if caps.pair_setup {
            for (i, ep) in eps.iter().enumerate() {
                net.connect(ep, ranks[(i + 1) % ranks.len()]);
            }
        }
        RingAllreduce {
            total_steps: 2 * (ranks.len() as u32 - 1),
            ranks,
            index,
            received: vec![],
            chunk_bytes,
            frag_bytes,
            mode,
            done_ranks: 0,
            stats: CollectiveStats { makespan: 0, bytes_on_wire: 0, messages: 0 },
        }
    }

    /// Kick off the first step and run the fabric to completion.
    /// Returns the stats; the makespan is the virtual-time cost of the
    /// all-reduce.
    pub fn run<F: Fabric>(mut self, net: &mut F) -> CollectiveStats {
        let t0 = net.now();
        self.received = vec![0; self.ranks.len()];
        let ranks = self.ranks.clone();
        for &r in &ranks {
            self.send_step(net, r);
        }
        net.run(&mut self);
        assert_eq!(self.done_ranks, self.ranks.len(), "all-reduce did not complete");
        self.stats.makespan = net.now() - t0;
        self.stats
    }

    /// Send rank `node`'s current chunk to its ring successor, as
    /// fragments of at most the mode's max payload; the *last* fragment
    /// carries the one-byte step marker, and its receipt advances the
    /// receiver. Called from driver context (kickoff) and from
    /// `on_message` callbacks at `node` — the endpoint sends' per-node
    /// app ids keep serial and sharded runs identical.
    fn send_step<F: Fabric>(&mut self, net: &mut F, node: NodeId) {
        let rank = self.index[node.0 as usize].expect("send_step at non-rank");
        let next = self.ranks[(rank + 1) % self.ranks.len()];
        let ep = Endpoint { node, mode: self.mode };
        let now = net.now();
        let mut left = self.chunk_bytes;
        while left > 0 {
            let take = left.min(self.frag_bytes);
            let mut data = vec![0u8; take as usize];
            if take == left {
                data[0] = 1; // final fragment of this chunk
            }
            net.send_at(now, &ep, next, Message::new(data));
            self.stats.bytes_on_wire += take as u64;
            left -= take;
        }
        self.stats.messages += 1;
    }
}

impl App for RingAllreduce {
    fn on_message(&mut self, net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
        // Every fragment is consumed on delivery, so a run retains only
        // the in-flight window instead of every fragment it ever moved.
        if msg.data.first() != Some(&1) {
            return true; // mid-chunk fragment
        }
        let node = ep.node;
        let rank = self.index[node.0 as usize].expect("collective message at non-rank");
        self.received[rank] += 1;
        let r = self.received[rank];
        if r < self.total_steps {
            self.send_step(net, node);
        } else if r == self.total_steps {
            self.done_ranks += 1;
        }
        true
    }
}

impl ShardableApp for RingAllreduce {
    /// Partitions carry *deltas*: per-rank receive counters restart at
    /// zero (a rank's counter is only ever advanced by callbacks at
    /// that rank's node, i.e. on exactly one shard) and the stats
    /// accumulated so far — the kickoff sends — stay with the parent.
    fn partition(&self, _shard: u32, _owner: &[u32]) -> Self {
        RingAllreduce {
            ranks: self.ranks.clone(),
            index: self.index.clone(),
            received: vec![0; self.ranks.len()],
            total_steps: self.total_steps,
            chunk_bytes: self.chunk_bytes,
            frag_bytes: self.frag_bytes,
            mode: self.mode,
            done_ranks: 0,
            stats: CollectiveStats { makespan: 0, bytes_on_wire: 0, messages: 0 },
        }
    }

    fn reduce(&mut self, part: Self) {
        for (a, b) in self.received.iter_mut().zip(&part.received) {
            *a += *b;
        }
        self.done_ranks += part.done_ranks;
        self.stats.bytes_on_wire += part.stats.bytes_on_wire;
        self.stats.messages += part.stats.messages;
    }
}

/// Numeric helper: element-wise mean across per-rank gradient vectors
/// (the arithmetic half of the all-reduce; the traffic half is
/// [`RingAllreduce`]).
pub fn mean_reduce(mut grads: Vec<Vec<f32>>) -> Vec<f32> {
    assert!(!grads.is_empty());
    let k = grads.len() as f32;
    let mut acc = grads.pop().unwrap();
    for g in &grads {
        assert_eq!(g.len(), acc.len(), "gradient length mismatch");
        for (a, b) in acc.iter_mut().zip(g) {
            *a += *b;
        }
    }
    for a in &mut acc {
        *a /= k;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ethernet::RxMode;
    use crate::coordinator::Placement;

    #[test]
    fn allreduce_completes_and_scales_with_bytes() {
        let mut net = Network::card();
        let ranks = Placement::Block.select(&net.topo, 8);
        let small = RingAllreduce::new(&mut net, ranks.clone(), 64 * 1024).run(&mut net);
        let mut net2 = Network::card();
        let big = RingAllreduce::new(&mut net2, ranks, 1024 * 1024).run(&mut net2);
        assert!(small.makespan > 0);
        assert!(big.makespan > small.makespan);
        assert!(big.bytes_on_wire > small.bytes_on_wire);
    }

    #[test]
    fn allreduce_message_count_is_2k_minus_1_rounds() {
        let mut net = Network::card();
        let ranks = Placement::Block.select(&net.topo, 4);
        let stats = RingAllreduce::new(&mut net, ranks, 4096).run(&mut net);
        // Every rank sends 2(k-1) chunk-messages.
        assert_eq!(stats.messages, 4 * 2 * 3);
    }

    #[test]
    fn allreduce_is_mode_generic() {
        // Same collective over all three modes: same message count,
        // mode-dependent makespan with the software path slowest.
        let run = |mode: CommMode| {
            let mut net = Network::card();
            let ranks = Placement::Block.select(&net.topo, 4);
            RingAllreduce::with_mode(&mut net, ranks, 64 * 1024, mode).run(&mut net)
        };
        let pm = run(CommMode::Postmaster { queue: 0 });
        let fifo = run(CommMode::BridgeFifo { width_bits: 64 });
        let eth = run(CommMode::Ethernet { rx: RxMode::Interrupt });
        assert_eq!(pm.messages, 4 * 2 * 3);
        assert_eq!(fifo.messages, 4 * 2 * 3);
        assert_eq!(eth.messages, 4 * 2 * 3);
        assert_eq!(pm.bytes_on_wire, fifo.bytes_on_wire);
        assert_eq!(pm.bytes_on_wire, eth.bytes_on_wire);
        assert!(pm.makespan < eth.makespan, "pm {} vs eth {}", pm.makespan, eth.makespan);
        assert!(fifo.makespan < eth.makespan, "fifo {} vs eth {}", fifo.makespan, eth.makespan);
    }

    #[test]
    fn scattered_placement_has_higher_packet_latency_than_block() {
        // Multi-span links flatten the end-to-end makespan (that is their
        // job — §2.3), so the placement ablation shows up in per-packet
        // latency, not necessarily in ring-allreduce completion time.
        let run = |p: Placement| {
            let mut net = Network::inc3000();
            let ranks = p.select(&net.topo, 8);
            RingAllreduce::new(&mut net, ranks, 256 * 1024).run(&mut net);
            net.metrics.latency("postmaster").unwrap().mean()
        };
        let block = run(Placement::Block);
        let scattered = run(Placement::Scattered);
        assert!(
            scattered > block,
            "scattered packet latency {scattered} vs block {block}"
        );
    }

    #[test]
    fn mean_reduce_math() {
        let g = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(mean_reduce(g), vec![2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "≥2 ranks")]
    fn single_rank_rejected() {
        let mut net = Network::card();
        RingAllreduce::new(&mut net, vec![NodeId(0)], 1024);
    }
}
