//! Job placement onto mesh nodes.

use crate::topology::{NodeId, Topology};

/// How a job's ranks map to nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// A compact axis-ordered block starting at the origin: best for
    /// neighbor-heavy traffic.
    Block,
    /// One whole card (27 nodes) by card coordinate.
    Card(u32, u32, u32),
    /// Maximally spread out (stride over the node list): worst-case
    /// communication placement, used by ablation benches.
    Scattered,
}

impl Placement {
    /// Pick `k` nodes for a job.
    pub fn select(self, topo: &Topology, k: usize) -> Vec<NodeId> {
        match self {
            Placement::Block => topo.nodes().take(k).collect(),
            Placement::Card(x, y, z) => {
                let nodes = topo.card_nodes((x, y, z));
                assert!(k <= nodes.len(), "a card has 27 nodes, requested {k}");
                nodes.into_iter().take(k).collect()
            }
            Placement::Scattered => {
                let n = topo.node_count();
                assert!(k <= n);
                let stride = (n / k).max(1);
                (0..k).map(|i| NodeId((i * stride) as u32)).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemPreset;

    #[test]
    fn block_takes_prefix() {
        let t = Topology::preset(SystemPreset::Card);
        let v = Placement::Block.select(&t, 4);
        assert_eq!(v, vec![NodeId(0), NodeId(1), NodeId(2), NodeId(3)]);
    }

    #[test]
    fn card_selects_card_nodes() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let v = Placement::Card(1, 0, 0).select(&t, 27);
        assert_eq!(v.len(), 27);
        for n in &v {
            assert_eq!(t.card_of(*n), (1, 0, 0));
        }
    }

    #[test]
    fn scattered_spreads() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let v = Placement::Scattered.select(&t, 4);
        assert_eq!(v.len(), 4);
        // Average pairwise hops must exceed the block placement's.
        let avg = |v: &[NodeId]| {
            let mut s = 0u32;
            let mut c = 0u32;
            for i in 0..v.len() {
                for j in i + 1..v.len() {
                    s += t.min_hops(v[i], v[j]);
                    c += 1;
                }
            }
            s as f64 / c as f64
        };
        let b = Placement::Block.select(&t, 4);
        assert!(avg(&v) > avg(&b));
    }
}
