//! Workload coordination: placement, collectives, timestep scheduling.
//!
//! The INC has no MPI runtime of its own — the paper's position is that
//! communication layers are *designed per application* on top of the
//! packet router. This module provides the coordination layer our
//! machine-intelligence workloads (`crate::workload`) share:
//!
//! * [`placement`] — mapping jobs onto mesh nodes (blocks, scattered,
//!   whole cards).
//! * [`collectives`] — ring all-reduce, tree reduce and broadcast built
//!   from `Proto::Raw` packets, with the traffic simulated on the fabric
//!   (the real numerics live in XLA artifacts; the fabric carries
//!   modeled bytes). Engine-agnostic: collectives run on the serial or
//!   the sharded engine through [`crate::network::Fabric`].

pub mod collectives;
pub mod placement;

pub use collectives::{CollectiveStats, RingAllreduce};
pub use placement::Placement;
