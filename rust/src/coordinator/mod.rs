//! Workload coordination: placement, collectives, timestep scheduling.
//!
//! The INC has no MPI runtime of its own — the paper's position is that
//! communication layers are *designed per application* on top of the
//! packet router. This module provides the coordination layer our
//! machine-intelligence workloads (`crate::workload`) share:
//!
//! * [`placement`] — mapping jobs onto mesh nodes (blocks, scattered,
//!   whole cards).
//! * [`collectives`] — ring all-reduce built from unified endpoint
//!   [`crate::channels::Message`]s, with the traffic simulated on the
//!   fabric (the real numerics live in XLA artifacts; the fabric
//!   carries modeled bytes). Engine-agnostic **and** mode-generic:
//!   collectives run on the serial or the sharded engine through
//!   [`crate::network::Fabric`], over any
//!   [`crate::channels::CommMode`].

pub mod collectives;
pub mod placement;

pub use collectives::{CollectiveStats, RingAllreduce};
pub use placement::Placement;
