//! Distributed Monte Carlo Tree Search (intro, experiment E9).
//!
//! The paper's introduction names MCTS (AlphaGo) as "one of the prime
//! examples of an algorithm which is not well matched to SIMD
//! architecture": control-heavy, latency-sensitive, trivially
//! node-parallel. On INC it maps naturally: a leader node owns the tree
//! (UCB1 selection/expansion/backup); worker nodes run rollouts on their
//! FPGA fabric; tasks and results travel as small messages — by default
//! over Postmaster DMA, exactly the pattern §3.2 is built for, but the
//! channel is a [`CommMode`] parameter ([`DistributedMcts::with_mode`],
//! `repro mcts --comm pm|eth|fifo`): the search is latency-bound, so
//! the mode choice shows up directly in rollout throughput.
//!
//! The game is a synthetic but non-trivial bandit tree: depth-`d`,
//! branching-`b`, with leaf payoffs from a seeded hash so every run is
//! deterministic and the optimum is known — the search must actually
//! find it (tested below).
//!
//! # Reliable mode: rollout re-dispatch
//!
//! With [`DistributedMcts::with_mode_reliable`] tasks and results ride
//! the ack/retransmit transport and the leader heartbeat-watches every
//! worker. A dead worker (chaos `drop`) surfaces as
//! [`crate::network::App::on_peer_down`] at the leader — via retry
//! exhaustion when tasks were in flight, via the liveness watch when
//! the worker died *between* accepting a task and replying — and the
//! leader re-dispatches all of that worker's outstanding rollouts to
//! live workers (same nonce, same tree position). A transport-level ack
//! is not rollout completion, so a nonce can briefly race its own
//! re-dispatch; the leader's pending-map removal is the exactly-once
//! gate and late duplicates are dropped. Every decision uses
//! leader-local state only, so serial and sharded runs stay
//! byte-identical.

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::channels::reliable::ReliableParams;
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::sim::Time;
use crate::topology::NodeId;

/// Synthetic game: payoff of a leaf = hash of its action path, with a
/// planted optimum down the all-zeros path.
#[derive(Debug, Clone, Copy)]
pub struct Game {
    pub depth: u32,
    pub branching: u32,
    pub seed: u64,
}

impl Game {
    /// Expected payoff of the leaf reached by `path` (0..1): a noisy
    /// hash base plus a leading-zeros gradient, with the all-zeros path
    /// planted as the unique optimum (payoff 1.0). The gradient makes
    /// the game *searchable* — UCB can climb it — while the hash noise
    /// keeps every other branch non-trivial.
    pub fn payoff(&self, path: &[u32]) -> f64 {
        debug_assert_eq!(path.len() as u32, self.depth);
        let lead = path.iter().take_while(|&&a| a == 0).count();
        if lead as u32 == self.depth {
            return 1.0;
        }
        let mut h = self.seed ^ 0x9E3779B97F4A7C15;
        for &a in path {
            h ^= a as u64;
            h = h.wrapping_mul(0xBF58476D1CE4E5B9);
            h ^= h >> 27;
        }
        // Bonus ≤ 0.4 (strictly below it for non-planted paths) + noise ≤ 0.4.
        0.4 * lead as f64 / self.depth as f64 + (h % 400) as f64 / 1000.0
    }

    /// A noisy rollout estimate from a partial path: complete the path
    /// pseudo-randomly (seeded by `nonce`) and return the leaf payoff.
    pub fn rollout(&self, prefix: &[u32], nonce: u64) -> f64 {
        let mut path = prefix.to_vec();
        let mut h = nonce.wrapping_mul(0x2545F4914F6CDD1D) ^ self.seed;
        while (path.len() as u32) < self.depth {
            h ^= h << 13;
            h ^= h >> 7;
            h ^= h << 17;
            path.push((h % self.branching as u64) as u32);
        }
        self.payoff(&path)
    }
}

/// UCB1 tree node.
#[derive(Debug, Default, Clone)]
struct TreeNode {
    visits: u64,
    value_sum: f64,
    children: Vec<usize>, // indices into the arena; empty = unexpanded
}

/// Leader + worker state for the distributed search.
///
/// As a [`ShardableApp`], the state is leader-owned: the tree, the
/// pending-task map and the rollout counter only ever mutate in
/// callbacks at the leader node, so exactly one sharded partition (the
/// one owning the leader) carries them and reduction adopts that
/// partition wholesale. Worker callbacks are pure functions of the
/// task record plus the read-only `game`.
#[derive(Clone)]
pub struct DistributedMcts {
    pub game: Game,
    leader: NodeId,
    workers: Vec<NodeId>,
    arena: Vec<TreeNode>,
    paths: Vec<Vec<u32>>, // action path of each arena node
    /// Rollout tasks in flight per worker.
    inflight: Vec<u32>,
    /// Pending (arena index) for each outstanding task nonce.
    pending: std::collections::HashMap<u64, usize>,
    /// Nonces currently assigned to each worker, in issue order — what
    /// the leader re-dispatches when that worker dies.
    outstanding: Vec<Vec<u64>>,
    /// Workers the leader has declared dead (leader-local knowledge).
    dead_workers: Vec<bool>,
    next_nonce: u64,
    pub rollouts_done: u64,
    rollouts_target: u64,
    /// Virtual time of the most recent completed rollout (the reliable
    /// mode's makespan endpoint — quiescence there includes the liveness
    /// watch horizon).
    last_done_at: Time,
    /// Rollout compute time on a worker's FPGA, ns.
    pub rollout_ns: Time,
    /// Max outstanding tasks per worker.
    pub pipeline_depth: u32,
    /// The channel tasks and results travel over.
    mode: CommMode,
    /// Run over the reliable transport, re-dispatching a dead worker's
    /// rollouts (module docs).
    reliable: Option<ReliableParams>,
    /// Liveness-watch bound for the leader's worker watches.
    watch_until: Time,
    /// Whether this instance (or partition) owns the leader's state —
    /// true for the parent app; among sharded partitions, true exactly
    /// for the shard owning the leader node.
    owns_leader: bool,
}

/// Result of a search run.
#[derive(Debug, Clone)]
pub struct MctsResult {
    pub best_path: Vec<u32>,
    pub best_value: f64,
    pub rollouts: u64,
    pub makespan: Time,
    /// Rollouts per virtual second.
    pub throughput: f64,
}

impl DistributedMcts {
    /// Default transport: Postmaster DMA (§3.2's small-message channel).
    pub fn new<F: Fabric>(net: &mut F, game: Game, leader: NodeId, workers: Vec<NodeId>) -> Self {
        Self::with_mode(net, game, leader, workers, CommMode::Postmaster { queue: 1 })
    }

    /// Build the search over an explicit communication mode: endpoints
    /// open at the leader and every worker, with per-pair setup in both
    /// directions where the mode requires it.
    pub fn with_mode<F: Fabric>(
        net: &mut F,
        game: Game,
        leader: NodeId,
        workers: Vec<NodeId>,
        mode: CommMode,
    ) -> Self {
        Self::build(net, game, leader, workers, mode, None, 0)
    }

    /// Build the search over the **reliable** transport: the mode must
    /// be one the transport accepts (Postmaster or Ethernet), and the
    /// leader watches every worker's liveness until `watch_until` so a
    /// worker dying between task and reply still gets detected.
    pub fn with_mode_reliable<F: Fabric>(
        net: &mut F,
        game: Game,
        leader: NodeId,
        workers: Vec<NodeId>,
        mode: CommMode,
        params: ReliableParams,
        watch_until: Time,
    ) -> Self {
        Self::build(net, game, leader, workers, mode, Some(params), watch_until)
    }

    fn build<F: Fabric>(
        net: &mut F,
        game: Game,
        leader: NodeId,
        workers: Vec<NodeId>,
        mode: CommMode,
        reliable: Option<ReliableParams>,
        watch_until: Time,
    ) -> Self {
        assert!(!workers.is_empty());
        // Messages dispatch on node identity (leader = result, anything
        // else = task), so the leader cannot double as a worker.
        assert!(!workers.contains(&leader), "leader cannot be one of the workers");
        let pair_setup = net.caps(mode).pair_setup;
        let open = |net: &mut F, n: NodeId| match reliable {
            Some(p) => net.reliable_open(n, mode, p),
            None => net.open(n, mode),
        };
        let lep = open(net, leader);
        for &w in &workers {
            let wep = open(net, w);
            if pair_setup {
                net.connect(&lep, w);
                net.connect(&wep, leader);
            }
        }
        DistributedMcts {
            game,
            leader,
            inflight: vec![0; workers.len()],
            outstanding: vec![Vec::new(); workers.len()],
            dead_workers: vec![false; workers.len()],
            workers,
            arena: vec![TreeNode::default()],
            paths: vec![vec![]],
            pending: std::collections::HashMap::new(),
            next_nonce: 1,
            rollouts_done: 0,
            rollouts_target: 0,
            last_done_at: 0,
            rollout_ns: 20_000,
            pipeline_depth: 4,
            mode,
            reliable,
            watch_until,
            owns_leader: true,
        }
    }

    /// Run `rollouts` rollouts (on either engine) and return the best
    /// action path found.
    pub fn search<F: Fabric>(mut self, net: &mut F, rollouts: u64) -> MctsResult {
        let t0 = net.now();
        self.kickoff(net, rollouts);
        net.run(&mut self);
        assert_eq!(self.rollouts_done, rollouts, "lost rollouts");
        // Extract the visit-greedy path.
        let mut best_path = Vec::new();
        let mut idx = 0usize;
        while !self.arena[idx].children.is_empty() {
            let (k, &c) = self.arena[idx]
                .children
                .iter()
                .enumerate()
                .max_by_key(|(_, &c)| self.arena[c].visits)
                .unwrap();
            best_path.push(k as u32);
            idx = c;
        }
        // With a liveness watch, quiescence includes the watch horizon;
        // the search itself ends at the last completed rollout.
        let makespan = if self.reliable.is_some() {
            self.last_done_at.max(t0) - t0
        } else {
            net.now() - t0
        };
        let root = &self.arena[0];
        MctsResult {
            best_value: root.value_sum / root.visits.max(1) as f64,
            best_path,
            rollouts,
            makespan,
            throughput: rollouts as f64 / (makespan as f64 / 1e9),
        }
    }

    /// Set the rollout target, watch worker liveness (reliable mode)
    /// and prime every worker's pipeline. Driver context; the caller
    /// runs the fabric (stepped or to quiescence).
    pub fn kickoff<F: Fabric>(&mut self, net: &mut F, rollouts: u64) {
        self.rollouts_target = rollouts;
        if self.reliable.is_some() {
            let lep = Endpoint { node: self.leader, mode: self.mode };
            for &w in &self.workers.clone() {
                net.reliable_watch(&lep, w, self.watch_until);
            }
        }
        for w in 0..self.workers.len() {
            for _ in 0..self.pipeline_depth {
                if self.issued() < self.rollouts_target {
                    self.dispatch(net, w);
                }
            }
        }
    }

    /// Whether the search hit its rollout target (meaningful on the
    /// parent app after the run).
    pub fn is_complete(&self) -> bool {
        self.rollouts_done >= self.rollouts_target
    }

    /// Workers the leader declared dead, by index.
    pub fn dead_workers(&self) -> &[bool] {
        &self.dead_workers
    }

    fn issued(&self) -> u64 {
        self.rollouts_done + self.pending.len() as u64
    }

    /// UCB1 selection from the root, expanding one node; returns the
    /// arena index whose prefix the rollout should start from.
    fn select_expand(&mut self) -> usize {
        let mut idx = 0usize;
        loop {
            if (self.paths[idx].len() as u32) == self.game.depth {
                return idx;
            }
            if self.arena[idx].children.is_empty() {
                // Expand all children at once.
                for a in 0..self.game.branching {
                    let mut p = self.paths[idx].clone();
                    p.push(a);
                    self.arena.push(TreeNode::default());
                    self.paths.push(p);
                    let c = self.arena.len() - 1;
                    self.arena[idx].children.push(c);
                }
                let c = self.arena[idx].children[0];
                return c;
            }
            let ln = (self.arena[idx].visits.max(1) as f64).ln();
            idx = *self.arena[idx]
                .children
                .iter()
                .max_by(|&&a, &&b| {
                    let ucb = |n: &TreeNode| {
                        if n.visits == 0 {
                            f64::INFINITY
                        } else {
                            n.value_sum / n.visits as f64
                                + 1.4 * (ln / n.visits as f64).sqrt()
                        }
                    };
                    ucb(&self.arena[a]).partial_cmp(&ucb(&self.arena[b])).unwrap()
                })
                .unwrap();
        }
    }

    /// Issue one rollout task to worker `w` over the configured mode.
    /// Called at kickoff (driver context) and from result callbacks at
    /// the leader (app context); the endpoint sends' per-node ids make
    /// both engine-agnostic.
    fn dispatch<F: Fabric>(&mut self, net: &mut F, w: usize) {
        let idx = self.select_expand();
        let nonce = self.next_nonce;
        self.next_nonce += 1;
        self.pending.insert(nonce, idx);
        self.send_task(net, w, nonce, idx);
    }

    /// Emit the task message for `nonce` (tree position `idx`) to
    /// worker `w`: `[nonce, worker idx, path...]` — small by design.
    /// Used both for fresh dispatches and for re-dispatching a dead
    /// worker's outstanding rollouts.
    fn send_task<F: Fabric>(&mut self, net: &mut F, w: usize, nonce: u64, idx: usize) {
        self.inflight[w] += 1;
        self.outstanding[w].push(nonce);
        let mut data = nonce.to_le_bytes().to_vec();
        data.extend((w as u64).to_le_bytes());
        data.extend(self.paths[idx].iter().flat_map(|a| a.to_le_bytes()));
        let now = net.now();
        let ep = Endpoint { node: self.leader, mode: self.mode };
        let msg = Message::new(data);
        if self.reliable.is_some() {
            net.reliable_send_at(now, &ep, self.workers[w], msg);
        } else {
            net.send_at(now, &ep, self.workers[w], msg);
        }
    }

    fn backup(&mut self, idx: usize, value: f64) {
        // Walk ancestors by path prefix (arena is a tree: recompute the
        // chain from the root).
        let path = self.paths[idx].clone();
        let mut node = 0usize;
        self.arena[0].visits += 1;
        self.arena[0].value_sum += value;
        for &a in &path {
            node = self.arena[node].children[a as usize];
            self.arena[node].visits += 1;
            self.arena[node].value_sum += value;
        }
    }
}

impl App for DistributedMcts {
    /// One handler for both directions: a message arriving at the
    /// leader is a rollout result, a message arriving anywhere else is
    /// a task at that worker. (Mode-generic: whichever channel carries
    /// the message, the payload layout is the same.)
    fn on_message(&mut self, net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
        let node = ep.node;
        if node != self.leader {
            // Worker: run the rollout on the FPGA (modeled compute
            // time), then return the value.
            let nonce = u64::from_le_bytes(msg.data[0..8].try_into().unwrap());
            let widx = u64::from_le_bytes(msg.data[8..16].try_into().unwrap());
            let path: Vec<u32> = msg.data[16..]
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            let value = self.game.rollout(&path, nonce);
            // Result message: [nonce, widx, value bits].
            let mut data = nonce.to_le_bytes().to_vec();
            data.extend(widx.to_le_bytes());
            data.extend(value.to_bits().to_le_bytes());
            // Reply after the rollout compute window.
            let leader = self.leader;
            let at = net.now() + self.rollout_ns;
            let ep = Endpoint { node, mode: self.mode };
            let reply = Message::new(data);
            if self.reliable.is_some() {
                net.reliable_send_at(at, &ep, leader, reply);
            } else {
                net.send_at(at, &ep, leader, reply);
            }
        } else {
            // Leader: backup + keep the worker's pipeline full.
            let nonce = u64::from_le_bytes(msg.data[0..8].try_into().unwrap());
            let widx = u64::from_le_bytes(msg.data[8..16].try_into().unwrap()) as usize;
            let value =
                f64::from_bits(u64::from_le_bytes(msg.data[16..24].try_into().unwrap()));
            // The pending-map removal is the exactly-once gate: a
            // re-dispatched rollout can race the original's late reply,
            // and whichever lands second is dropped here.
            let Some(idx) = self.pending.remove(&nonce) else {
                assert!(self.reliable.is_some(), "unknown rollout result");
                return true;
            };
            // Late replies from a since-declared-dead worker have had
            // their bookkeeping zeroed already.
            if self.inflight[widx] > 0 {
                self.inflight[widx] -= 1;
            }
            self.outstanding[widx].retain(|&n| n != nonce);
            self.rollouts_done += 1;
            self.last_done_at = net.now();
            self.backup(idx, value);
            if self.issued() < self.rollouts_target && !self.dead_workers[widx] {
                self.dispatch(net, widx);
            }
        }
        // Consumed: tasks and results never enter the recv inboxes.
        true
    }

    /// A worker died (retry exhaustion or missed heartbeats at the
    /// leader's endpoint): re-dispatch everything it still owed to the
    /// remaining live workers, round-robin. Leader-local state only —
    /// both engines decide identically.
    fn on_peer_down(&mut self, net: &mut Network, ep: Endpoint, peer: NodeId) {
        if ep.node != self.leader {
            // A dying worker may "detect" the leader with its own dead
            // uplink; only the leader re-places work.
            return;
        }
        let Some(w) = self.workers.iter().position(|&n| n == peer) else { return };
        if self.dead_workers[w] {
            return;
        }
        self.dead_workers[w] = true;
        // Undelivered task frames are re-generated below.
        let _ = net.reliable_take_unacked(&ep, peer);
        let owed = std::mem::take(&mut self.outstanding[w]);
        self.inflight[w] = 0;
        let live: Vec<usize> =
            (0..self.workers.len()).filter(|&j| !self.dead_workers[j]).collect();
        for (i, nonce) in owed.into_iter().enumerate() {
            // Replies that landed before the declaration already
            // cleared their nonce from pending.
            let Some(&idx) = self.pending.get(&nonce) else { continue };
            if let Some(&tgt) = live.get(i % live.len().max(1)) {
                self.send_task(net, tgt, nonce, idx);
            } else {
                // No workers left: the leader runs the rollout itself.
                let value = self.game.rollout(&self.paths[idx].clone(), nonce);
                self.pending.remove(&nonce);
                self.rollouts_done += 1;
                self.last_done_at = net.now();
                self.backup(idx, value);
            }
        }
    }
}

impl ShardableApp for DistributedMcts {
    fn partition(&self, shard: u32, owner: &[u32]) -> Self {
        DistributedMcts {
            game: self.game,
            leader: self.leader,
            workers: self.workers.clone(),
            arena: self.arena.clone(),
            paths: self.paths.clone(),
            inflight: self.inflight.clone(),
            outstanding: self.outstanding.clone(),
            dead_workers: self.dead_workers.clone(),
            pending: self.pending.clone(),
            next_nonce: self.next_nonce,
            rollouts_done: self.rollouts_done,
            rollouts_target: self.rollouts_target,
            last_done_at: self.last_done_at,
            rollout_ns: self.rollout_ns,
            pipeline_depth: self.pipeline_depth,
            mode: self.mode,
            reliable: self.reliable,
            watch_until: self.watch_until,
            owns_leader: owner[self.leader.0 as usize] == shard,
        }
    }

    fn reduce(&mut self, part: Self) {
        // Leader-owned state: exactly one partition carried it forward;
        // adopt that one, drop the rest (their clones never mutated —
        // worker callbacks are stateless). Commutative by uniqueness.
        if part.owns_leader {
            self.arena = part.arena;
            self.paths = part.paths;
            self.inflight = part.inflight;
            self.outstanding = part.outstanding;
            self.dead_workers = part.dead_workers;
            self.pending = part.pending;
            self.next_nonce = part.next_nonce;
            self.rollouts_done = part.rollouts_done;
            self.last_done_at = part.last_done_at;
        }
    }
}

/// Convenience: run a search with `k` workers on a fresh card.
pub fn run_card_search(workers: usize, rollouts: u64) -> MctsResult {
    let mut net = Network::card();
    let leader = NodeId(0);
    let ws: Vec<NodeId> = (1..=workers as u32).map(NodeId).collect();
    let game = Game { depth: 6, branching: 3, seed: 42 };
    let mcts = DistributedMcts::new(&mut net, game, leader, ws);
    mcts.search(&mut net, rollouts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_planted_optimum() {
        let r = run_card_search(8, 3000);
        assert_eq!(r.rollouts, 3000);
        assert_eq!(
            r.best_path,
            vec![0; 6],
            "search should find the planted all-zeros optimum"
        );
    }

    #[test]
    fn throughput_scales_with_workers() {
        let r2 = run_card_search(2, 600);
        let r8 = run_card_search(8, 600);
        assert!(
            r8.throughput > r2.throughput * 2.0,
            "8 workers ({:.0}/s) should beat 2 workers ({:.0}/s) by >2x",
            r8.throughput,
            r2.throughput
        );
    }

    #[test]
    fn search_is_mode_generic() {
        // The same search over Bridge FIFO and internal Ethernet: the
        // channel changes the makespan, never the answer.
        use crate::channels::endpoint::CommMode;
        use crate::channels::ethernet::RxMode;
        let run = |mode: CommMode| {
            let mut net = Network::card();
            let ws: Vec<NodeId> = (1..=6).map(NodeId).collect();
            let game = Game { depth: 4, branching: 3, seed: 42 };
            let mcts = DistributedMcts::with_mode(&mut net, game, NodeId(0), ws, mode);
            mcts.search(&mut net, 600)
        };
        let fifo = run(CommMode::BridgeFifo { width_bits: 64 });
        let eth = run(CommMode::Ethernet { rx: RxMode::Interrupt });
        assert_eq!(fifo.rollouts, 600);
        assert_eq!(eth.rollouts, 600);
        assert_eq!(fifo.best_path, vec![0; 4]);
        assert_eq!(eth.best_path, vec![0; 4]);
        assert!(
            fifo.makespan < eth.makespan,
            "latency-bound search: fifo {} should beat eth {}",
            fifo.makespan,
            eth.makespan
        );
    }

    #[test]
    fn reliable_search_matches_raw_answer_without_faults() {
        let run = |reliable: bool| {
            let mut net = Network::card();
            let ws: Vec<NodeId> = (1..=6).map(NodeId).collect();
            let game = Game { depth: 4, branching: 3, seed: 42 };
            let mode = CommMode::Postmaster { queue: 1 };
            let mcts = if reliable {
                DistributedMcts::with_mode_reliable(
                    &mut net,
                    game,
                    NodeId(0),
                    ws,
                    mode,
                    ReliableParams::default(),
                    50_000_000,
                )
            } else {
                DistributedMcts::with_mode(&mut net, game, NodeId(0), ws, mode)
            };
            mcts.search(&mut net, 600)
        };
        let raw = run(false);
        let rel = run(true);
        assert_eq!(rel.rollouts, 600);
        assert_eq!(rel.best_path, raw.best_path, "transport must not change the answer");
    }

    #[test]
    fn dead_worker_rollouts_are_redispatched() {
        use crate::config::SystemConfig;
        let mut cfg = SystemConfig::card();
        cfg.drop_unroutable = true;
        let mut net = Network::new(cfg);
        let ws: Vec<NodeId> = (1..=6).map(NodeId).collect();
        let victim = ws[2];
        let game = Game { depth: 4, branching: 3, seed: 42 };
        let params = ReliableParams {
            rto_ns: 30_000,
            max_retries: 3,
            heartbeat_ns: 50_000,
            liveness_ns: 400_000,
            ..ReliableParams::default()
        };
        let mut mcts = DistributedMcts::with_mode_reliable(
            &mut net,
            game,
            NodeId(0),
            ws,
            CommMode::Postmaster { queue: 1 },
            params,
            200_000_000,
        );
        mcts.kickoff(&mut net, 400);
        // Two-phase death mid-search.
        net.run_until(&mut mcts, 150_000);
        for &l in &net.topo.in_links(victim).to_vec() {
            net.fail_link(l);
        }
        net.run_until(&mut mcts, 152_000);
        for &l in &net.topo.out_links(victim).to_vec() {
            net.fail_link(l);
        }
        net.run_to_quiescence(&mut mcts);
        assert!(mcts.is_complete(), "search must survive a dead worker");
        assert_eq!(mcts.rollouts_done, 400, "exactly-once rollout accounting");
        assert!(mcts.dead_workers()[2], "the victim must be declared dead");
        assert!(net.metrics.peers_declared_down > 0);
    }

    #[test]
    fn game_is_deterministic() {
        let g = Game { depth: 4, branching: 3, seed: 7 };
        assert_eq!(g.payoff(&[0, 0, 0, 0]), 1.0);
        assert_eq!(g.rollout(&[1], 5), g.rollout(&[1], 5));
        assert!(g.payoff(&[1, 2, 0, 1]) < 1.0);
    }
}
