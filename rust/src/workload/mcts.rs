//! Distributed Monte Carlo Tree Search (intro, experiment E9).
//!
//! The paper's introduction names MCTS (AlphaGo) as "one of the prime
//! examples of an algorithm which is not well matched to SIMD
//! architecture": control-heavy, latency-sensitive, trivially
//! node-parallel. On INC it maps naturally: a leader node owns the tree
//! (UCB1 selection/expansion/backup); worker nodes run rollouts on their
//! FPGA fabric; tasks and results travel as small messages — by default
//! over Postmaster DMA, exactly the pattern §3.2 is built for, but the
//! channel is a [`CommMode`] parameter ([`DistributedMcts::with_mode`],
//! `repro mcts --comm pm|eth|fifo`): the search is latency-bound, so
//! the mode choice shows up directly in rollout throughput.
//!
//! The game is a synthetic but non-trivial bandit tree: depth-`d`,
//! branching-`b`, with leaf payoffs from a seeded hash so every run is
//! deterministic and the optimum is known — the search must actually
//! find it (tested below).

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::sim::Time;
use crate::topology::NodeId;

/// Synthetic game: payoff of a leaf = hash of its action path, with a
/// planted optimum down the all-zeros path.
#[derive(Debug, Clone, Copy)]
pub struct Game {
    pub depth: u32,
    pub branching: u32,
    pub seed: u64,
}

impl Game {
    /// Expected payoff of the leaf reached by `path` (0..1): a noisy
    /// hash base plus a leading-zeros gradient, with the all-zeros path
    /// planted as the unique optimum (payoff 1.0). The gradient makes
    /// the game *searchable* — UCB can climb it — while the hash noise
    /// keeps every other branch non-trivial.
    pub fn payoff(&self, path: &[u32]) -> f64 {
        debug_assert_eq!(path.len() as u32, self.depth);
        let lead = path.iter().take_while(|&&a| a == 0).count();
        if lead as u32 == self.depth {
            return 1.0;
        }
        let mut h = self.seed ^ 0x9E3779B97F4A7C15;
        for &a in path {
            h ^= a as u64;
            h = h.wrapping_mul(0xBF58476D1CE4E5B9);
            h ^= h >> 27;
        }
        // Bonus ≤ 0.4 (strictly below it for non-planted paths) + noise ≤ 0.4.
        0.4 * lead as f64 / self.depth as f64 + (h % 400) as f64 / 1000.0
    }

    /// A noisy rollout estimate from a partial path: complete the path
    /// pseudo-randomly (seeded by `nonce`) and return the leaf payoff.
    pub fn rollout(&self, prefix: &[u32], nonce: u64) -> f64 {
        let mut path = prefix.to_vec();
        let mut h = nonce.wrapping_mul(0x2545F4914F6CDD1D) ^ self.seed;
        while (path.len() as u32) < self.depth {
            h ^= h << 13;
            h ^= h >> 7;
            h ^= h << 17;
            path.push((h % self.branching as u64) as u32);
        }
        self.payoff(&path)
    }
}

/// UCB1 tree node.
#[derive(Debug, Default, Clone)]
struct TreeNode {
    visits: u64,
    value_sum: f64,
    children: Vec<usize>, // indices into the arena; empty = unexpanded
}

/// Leader + worker state for the distributed search.
///
/// As a [`ShardableApp`], the state is leader-owned: the tree, the
/// pending-task map and the rollout counter only ever mutate in
/// callbacks at the leader node, so exactly one sharded partition (the
/// one owning the leader) carries them and reduction adopts that
/// partition wholesale. Worker callbacks are pure functions of the
/// task record plus the read-only `game`.
pub struct DistributedMcts {
    pub game: Game,
    leader: NodeId,
    workers: Vec<NodeId>,
    arena: Vec<TreeNode>,
    paths: Vec<Vec<u32>>, // action path of each arena node
    /// Rollout tasks in flight per worker.
    inflight: Vec<u32>,
    /// Pending (arena index) for each outstanding task nonce.
    pending: std::collections::HashMap<u64, usize>,
    next_nonce: u64,
    pub rollouts_done: u64,
    rollouts_target: u64,
    /// Rollout compute time on a worker's FPGA, ns.
    pub rollout_ns: Time,
    /// Max outstanding tasks per worker.
    pub pipeline_depth: u32,
    /// The channel tasks and results travel over.
    mode: CommMode,
    /// Whether this instance (or partition) owns the leader's state —
    /// true for the parent app; among sharded partitions, true exactly
    /// for the shard owning the leader node.
    owns_leader: bool,
}

/// Result of a search run.
#[derive(Debug, Clone)]
pub struct MctsResult {
    pub best_path: Vec<u32>,
    pub best_value: f64,
    pub rollouts: u64,
    pub makespan: Time,
    /// Rollouts per virtual second.
    pub throughput: f64,
}

impl DistributedMcts {
    /// Default transport: Postmaster DMA (§3.2's small-message channel).
    pub fn new<F: Fabric>(net: &mut F, game: Game, leader: NodeId, workers: Vec<NodeId>) -> Self {
        Self::with_mode(net, game, leader, workers, CommMode::Postmaster { queue: 1 })
    }

    /// Build the search over an explicit communication mode: endpoints
    /// open at the leader and every worker, with per-pair setup in both
    /// directions where the mode requires it.
    pub fn with_mode<F: Fabric>(
        net: &mut F,
        game: Game,
        leader: NodeId,
        workers: Vec<NodeId>,
        mode: CommMode,
    ) -> Self {
        assert!(!workers.is_empty());
        // Messages dispatch on node identity (leader = result, anything
        // else = task), so the leader cannot double as a worker.
        assert!(!workers.contains(&leader), "leader cannot be one of the workers");
        let pair_setup = net.caps(mode).pair_setup;
        let lep = net.open(leader, mode);
        for &w in &workers {
            let wep = net.open(w, mode);
            if pair_setup {
                net.connect(&lep, w);
                net.connect(&wep, leader);
            }
        }
        DistributedMcts {
            game,
            leader,
            inflight: vec![0; workers.len()],
            workers,
            arena: vec![TreeNode::default()],
            paths: vec![vec![]],
            pending: std::collections::HashMap::new(),
            next_nonce: 1,
            rollouts_done: 0,
            rollouts_target: 0,
            rollout_ns: 20_000,
            pipeline_depth: 4,
            mode,
            owns_leader: true,
        }
    }

    /// Run `rollouts` rollouts (on either engine) and return the best
    /// action path found.
    pub fn search<F: Fabric>(mut self, net: &mut F, rollouts: u64) -> MctsResult {
        let t0 = net.now();
        self.rollouts_target = rollouts;
        // Prime every worker's pipeline.
        for w in 0..self.workers.len() {
            for _ in 0..self.pipeline_depth {
                if self.issued() < self.rollouts_target {
                    self.dispatch(net, w);
                }
            }
        }
        net.run(&mut self);
        assert_eq!(self.rollouts_done, rollouts, "lost rollouts");
        // Extract the visit-greedy path.
        let mut best_path = Vec::new();
        let mut idx = 0usize;
        while !self.arena[idx].children.is_empty() {
            let (k, &c) = self.arena[idx]
                .children
                .iter()
                .enumerate()
                .max_by_key(|(_, &c)| self.arena[c].visits)
                .unwrap();
            best_path.push(k as u32);
            idx = c;
        }
        let makespan = net.now() - t0;
        let root = &self.arena[0];
        MctsResult {
            best_value: root.value_sum / root.visits.max(1) as f64,
            best_path,
            rollouts,
            makespan,
            throughput: rollouts as f64 / (makespan as f64 / 1e9),
        }
    }

    fn issued(&self) -> u64 {
        self.rollouts_done + self.pending.len() as u64
    }

    /// UCB1 selection from the root, expanding one node; returns the
    /// arena index whose prefix the rollout should start from.
    fn select_expand(&mut self) -> usize {
        let mut idx = 0usize;
        loop {
            if (self.paths[idx].len() as u32) == self.game.depth {
                return idx;
            }
            if self.arena[idx].children.is_empty() {
                // Expand all children at once.
                for a in 0..self.game.branching {
                    let mut p = self.paths[idx].clone();
                    p.push(a);
                    self.arena.push(TreeNode::default());
                    self.paths.push(p);
                    let c = self.arena.len() - 1;
                    self.arena[idx].children.push(c);
                }
                let c = self.arena[idx].children[0];
                return c;
            }
            let ln = (self.arena[idx].visits.max(1) as f64).ln();
            idx = *self.arena[idx]
                .children
                .iter()
                .max_by(|&&a, &&b| {
                    let ucb = |n: &TreeNode| {
                        if n.visits == 0 {
                            f64::INFINITY
                        } else {
                            n.value_sum / n.visits as f64
                                + 1.4 * (ln / n.visits as f64).sqrt()
                        }
                    };
                    ucb(&self.arena[a]).partial_cmp(&ucb(&self.arena[b])).unwrap()
                })
                .unwrap();
        }
    }

    /// Issue one rollout task to worker `w` over the configured mode.
    /// Called at kickoff (driver context) and from result callbacks at
    /// the leader (app context); the endpoint sends' per-node ids make
    /// both engine-agnostic.
    fn dispatch<F: Fabric>(&mut self, net: &mut F, w: usize) {
        let idx = self.select_expand();
        let nonce = self.next_nonce;
        self.next_nonce += 1;
        self.pending.insert(nonce, idx);
        self.inflight[w] += 1;
        // Task message: [nonce, worker idx, path...] — small by design.
        let mut data = nonce.to_le_bytes().to_vec();
        data.extend((w as u64).to_le_bytes());
        data.extend(self.paths[idx].iter().flat_map(|a| a.to_le_bytes()));
        let now = net.now();
        let ep = Endpoint { node: self.leader, mode: self.mode };
        net.send_at(now, &ep, self.workers[w], Message::new(data));
    }

    fn backup(&mut self, idx: usize, value: f64) {
        // Walk ancestors by path prefix (arena is a tree: recompute the
        // chain from the root).
        let path = self.paths[idx].clone();
        let mut node = 0usize;
        self.arena[0].visits += 1;
        self.arena[0].value_sum += value;
        for &a in &path {
            node = self.arena[node].children[a as usize];
            self.arena[node].visits += 1;
            self.arena[node].value_sum += value;
        }
    }
}

impl App for DistributedMcts {
    /// One handler for both directions: a message arriving at the
    /// leader is a rollout result, a message arriving anywhere else is
    /// a task at that worker. (Mode-generic: whichever channel carries
    /// the message, the payload layout is the same.)
    fn on_message(&mut self, net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
        let node = ep.node;
        if node != self.leader {
            // Worker: run the rollout on the FPGA (modeled compute
            // time), then return the value.
            let nonce = u64::from_le_bytes(msg.data[0..8].try_into().unwrap());
            let widx = u64::from_le_bytes(msg.data[8..16].try_into().unwrap());
            let path: Vec<u32> = msg.data[16..]
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            let value = self.game.rollout(&path, nonce);
            // Result message: [nonce, widx, value bits].
            let mut data = nonce.to_le_bytes().to_vec();
            data.extend(widx.to_le_bytes());
            data.extend(value.to_bits().to_le_bytes());
            // Reply after the rollout compute window.
            let leader = self.leader;
            let at = net.now() + self.rollout_ns;
            net.send_at(at, &Endpoint { node, mode: self.mode }, leader, Message::new(data));
        } else {
            // Leader: backup + keep the worker's pipeline full.
            let nonce = u64::from_le_bytes(msg.data[0..8].try_into().unwrap());
            let widx = u64::from_le_bytes(msg.data[8..16].try_into().unwrap()) as usize;
            let value =
                f64::from_bits(u64::from_le_bytes(msg.data[16..24].try_into().unwrap()));
            let idx = self.pending.remove(&nonce).expect("unknown rollout result");
            self.inflight[widx] -= 1;
            self.rollouts_done += 1;
            self.backup(idx, value);
            if self.issued() < self.rollouts_target {
                self.dispatch(net, widx);
            }
        }
        // Consumed: tasks and results never enter the recv inboxes.
        true
    }
}

impl ShardableApp for DistributedMcts {
    fn partition(&self, shard: u32, owner: &[u32]) -> Self {
        DistributedMcts {
            game: self.game,
            leader: self.leader,
            workers: self.workers.clone(),
            arena: self.arena.clone(),
            paths: self.paths.clone(),
            inflight: self.inflight.clone(),
            pending: self.pending.clone(),
            next_nonce: self.next_nonce,
            rollouts_done: self.rollouts_done,
            rollouts_target: self.rollouts_target,
            rollout_ns: self.rollout_ns,
            pipeline_depth: self.pipeline_depth,
            mode: self.mode,
            owns_leader: owner[self.leader.0 as usize] == shard,
        }
    }

    fn reduce(&mut self, part: Self) {
        // Leader-owned state: exactly one partition carried it forward;
        // adopt that one, drop the rest (their clones never mutated —
        // worker callbacks are stateless). Commutative by uniqueness.
        if part.owns_leader {
            self.arena = part.arena;
            self.paths = part.paths;
            self.inflight = part.inflight;
            self.pending = part.pending;
            self.next_nonce = part.next_nonce;
            self.rollouts_done = part.rollouts_done;
        }
    }
}

/// Convenience: run a search with `k` workers on a fresh card.
pub fn run_card_search(workers: usize, rollouts: u64) -> MctsResult {
    let mut net = Network::card();
    let leader = NodeId(0);
    let ws: Vec<NodeId> = (1..=workers as u32).map(NodeId).collect();
    let game = Game { depth: 6, branching: 3, seed: 42 };
    let mcts = DistributedMcts::new(&mut net, game, leader, ws);
    mcts.search(&mut net, rollouts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_planted_optimum() {
        let r = run_card_search(8, 3000);
        assert_eq!(r.rollouts, 3000);
        assert_eq!(
            r.best_path,
            vec![0; 6],
            "search should find the planted all-zeros optimum"
        );
    }

    #[test]
    fn throughput_scales_with_workers() {
        let r2 = run_card_search(2, 600);
        let r8 = run_card_search(8, 600);
        assert!(
            r8.throughput > r2.throughput * 2.0,
            "8 workers ({:.0}/s) should beat 2 workers ({:.0}/s) by >2x",
            r8.throughput,
            r2.throughput
        );
    }

    #[test]
    fn search_is_mode_generic() {
        // The same search over Bridge FIFO and internal Ethernet: the
        // channel changes the makespan, never the answer.
        use crate::channels::endpoint::CommMode;
        use crate::channels::ethernet::RxMode;
        let run = |mode: CommMode| {
            let mut net = Network::card();
            let ws: Vec<NodeId> = (1..=6).map(NodeId).collect();
            let game = Game { depth: 4, branching: 3, seed: 42 };
            let mcts = DistributedMcts::with_mode(&mut net, game, NodeId(0), ws, mode);
            mcts.search(&mut net, 600)
        };
        let fifo = run(CommMode::BridgeFifo { width_bits: 64 });
        let eth = run(CommMode::Ethernet { rx: RxMode::Interrupt });
        assert_eq!(fifo.rollouts, 600);
        assert_eq!(eth.rollouts, 600);
        assert_eq!(fifo.best_path, vec![0; 4]);
        assert_eq!(eth.best_path, vec![0; 4]);
        assert!(
            fifo.makespan < eth.makespan,
            "latency-bound search: fifo {} should beat eth {}",
            fifo.makespan,
            eth.makespan
        );
    }

    #[test]
    fn game_is_deterministic() {
        let g = Game { depth: 4, branching: 3, seed: 7 };
        assert_eq!(g.payoff(&[0, 0, 0, 0]), 1.0);
        assert_eq!(g.rollout(&[1], 5), g.rollout(&[1], 5));
        assert!(g.payoff(&[1, 2, 0, 1]) < 1.0);
    }
}
