//! Distributed learners (§3.2, experiment E8) — mode-generic.
//!
//! "Regions or learners are distributed across multiple nodes, and each
//! node generates multiple small outputs during each time step which
//! become the inputs in the next time step. The function of Postmaster
//! is to allow the node to send those outputs to their intended targets
//! *as they are generated* rather than collect them and send them out as
//! a larger transmission at the end of the time step … this approach
//! also allows much more overlap of computation and communication."
//!
//! We reproduce exactly that comparison: a grid of learners, each
//! producing `outputs_per_step` small records per step for its mesh
//! neighbors; strategy `Streamed` emits each record when it is produced
//! (uniformly through the compute window), `Aggregated` emits everything
//! at the end. The measured quantity is the makespan of a time step:
//! compute + residual communication tail.
//!
//! The channel itself is a parameter ([`LearnerConfig::comm`]): the
//! records ride the unified [`Endpoint`] API, so the same workload runs
//! over Postmaster DMA (the paper's recommendation), internal Ethernet
//! or Bridge FIFO — `repro learners --comm pm|eth|fifo` — and the
//! per-mode makespans quantify *why* §3.2 recommends Postmaster.
//!
//! # Reliable mode: work re-placement
//!
//! With [`LearnerConfig::reliable`] set, records ride the
//! ack/retransmit transport ([`crate::channels::reliable`]). When a
//! learner dies (chaos `drop`), each sender discovers it independently
//! — its retry budget for that peer exhausts — and *re-places* the
//! undelivered records ([`crate::network::Network::reliable_take_unacked`])
//! on the next live learner. The chaos two-phase node death makes
//! "unacked" coincide exactly with "undelivered", so every record from
//! a live learner is processed exactly once, just possibly elsewhere.
//! Re-placement targets are chosen from *node-local* transport state
//! ([`crate::network::Network::reliable_is_down`] at the declaring
//! endpoint), never from globally-merged knowledge — the serial and
//! sharded engines see identical locals, keeping runs byte-identical.

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::channels::reliable::ReliableParams;
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::sim::Time;
use crate::topology::NodeId;

/// When outputs leave the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SendStrategy {
    /// As generated: k-th output at `compute_ns * (k+1) / n` (§3.2's
    /// recommended pattern — overlaps communication with compute).
    Streamed,
    /// All at the end of the compute window.
    Aggregated,
}

/// Workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct LearnerConfig {
    pub learners: usize,
    /// Small records each learner emits per step.
    pub outputs_per_step: usize,
    /// Bytes per record (small by design).
    pub record_bytes: usize,
    /// Compute window per step (FPGA time), ns.
    pub compute_ns: Time,
    pub steps: u32,
    /// Node-index stride when selecting learners (1 = the first
    /// `learners` nodes). A stride spreads the grid across cards and
    /// cages, which is how the workload exercises the sharded engine's
    /// cross-boundary path.
    pub stride: usize,
    /// The virtual channel the records travel over.
    pub comm: CommMode,
    /// Run over the reliable transport (module docs); the mode must be
    /// one the transport accepts (Postmaster or Ethernet).
    pub reliable: Option<ReliableParams>,
}

impl Default for LearnerConfig {
    fn default() -> Self {
        LearnerConfig {
            learners: 27,
            outputs_per_step: 16,
            record_bytes: 64,
            compute_ns: 50_000,
            steps: 4,
            stride: 1,
            comm: CommMode::Postmaster { queue: 0 },
            reliable: None,
        }
    }
}

/// Per-step result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepStats {
    pub makespan: Time,
    pub records: u64,
}

/// The receive/re-place half of the workload: counts landed records
/// and, in reliable mode, re-places a dead peer's undelivered ones.
#[derive(Clone)]
pub struct LearnerApp {
    pub expected: u64,
    pub received: u64,
    /// Records re-sent to a different learner after their original
    /// target died.
    pub replaced: u64,
    /// Learners some sender has declared dead (reporting only — never
    /// consulted for traffic decisions; see module docs).
    pub dead: Vec<bool>,
    nodes: Vec<NodeId>,
}

impl LearnerApp {
    fn new(nodes: Vec<NodeId>, expected: u64) -> Self {
        LearnerApp {
            expected,
            received: 0,
            replaced: 0,
            dead: vec![false; nodes.len()],
            nodes,
        }
    }

    /// Whether any learner was declared dead during the step.
    pub fn any_dead(&self) -> bool {
        self.dead.iter().any(|&d| d)
    }
}

impl App for LearnerApp {
    fn on_message(&mut self, _net: &mut Network, _ep: Endpoint, _msg: &Message) -> bool {
        self.received += 1;
        // Consumed: the record never enters the recv inbox.
        true
    }

    fn on_peer_down(&mut self, net: &mut Network, ep: Endpoint, peer: NodeId) {
        let Some(pi) = self.nodes.iter().position(|&n| n == peer) else { return };
        self.dead[pi] = true;
        let msgs = net.reliable_take_unacked(&ep, peer);
        if msgs.is_empty() {
            return;
        }
        // Next learner after the dead one that *this endpoint* still
        // believes live — node-local state, identical on both engines.
        let k = self.nodes.len();
        let target = (1..k)
            .map(|s| self.nodes[(pi + s) % k])
            .find(|&c| c != ep.node && !net.reliable_is_down(&ep, c));
        match target {
            Some(t) => {
                let now = net.now();
                for m in msgs {
                    net.reliable_send_at(now, &ep, t, m);
                    self.replaced += 1;
                }
            }
            None => {
                // Everyone else is gone: process the work locally.
                for _ in msgs {
                    self.received += 1;
                    self.replaced += 1;
                }
            }
        }
    }
}

impl ShardableApp for LearnerApp {
    fn partition(&self, _shard: u32, _owner: &[u32]) -> Self {
        LearnerApp::new(self.nodes.clone(), 0)
    }
    fn reduce(&mut self, part: Self) {
        self.received += part.received;
        self.replaced += part.replaced;
        for (d, p) in self.dead.iter_mut().zip(&part.dead) {
            *d |= p;
        }
    }
}

/// The k-th output's destination for learner `i` (round-robin over the
/// other learners; never `i` itself).
fn dst_of(nodes: &[NodeId], i: usize, k: usize) -> NodeId {
    let dst = nodes[(i + 1 + k % (nodes.len() - 1)) % nodes.len()];
    if dst == nodes[i] {
        nodes[(i + 1) % nodes.len()]
    } else {
        dst
    }
}

/// A placed learner grid with open endpoints: the setup half of
/// [`run`], split out so harnesses (chaos) can interleave fault
/// injection with stepped execution.
pub struct Learners {
    pub cfg: LearnerConfig,
    pub nodes: Vec<NodeId>,
}

impl Learners {
    /// Select nodes and open (plain or reliable) endpoints at each.
    pub fn setup<F: Fabric>(net: &mut F, cfg: LearnerConfig) -> Self {
        let nodes: Vec<NodeId> =
            net.topo().nodes().step_by(cfg.stride.max(1)).take(cfg.learners).collect();
        assert!(nodes.len() >= 2, "need at least two learners");
        for &n in &nodes {
            match cfg.reliable {
                Some(p) => {
                    net.reliable_open(n, cfg.comm, p);
                }
                None => {
                    net.open(n, cfg.comm);
                }
            }
        }
        if net.caps(cfg.comm).pair_setup {
            // Pre-establish exactly the pairs the schedule uses.
            for i in 0..nodes.len() {
                let ep = Endpoint { node: nodes[i], mode: cfg.comm };
                for k in 0..cfg.outputs_per_step {
                    net.connect(&ep, dst_of(&nodes, i, k));
                }
            }
        }
        Learners { cfg, nodes }
    }

    /// Schedule one step's record sends (each at its production time)
    /// and return the app that counts them down. The caller runs the
    /// fabric — to quiescence, or in windows with faults in between.
    pub fn schedule_step<F: Fabric>(&self, net: &mut F, strategy: SendStrategy) -> LearnerApp {
        let t0 = net.now();
        let records = self.schedule_step_at(net, t0, strategy, &[]);
        LearnerApp::new(self.nodes.clone(), records)
    }

    /// Schedule one step's sends on an *explicit* step origin `t0`
    /// (must be ≥ the fabric clock). Harnesses that drive steps on a
    /// tick grid (workload chaos) call this per tick and keep one
    /// accumulated [`LearnerApp`]; returns the records scheduled.
    ///
    /// `skip` names learners that have stopped producing (the chaos
    /// script's dead nodes — driver knowledge, identical on both
    /// engines): a crashed FPGA emits no records. In reliable mode a
    /// *live* producer also re-places, at production time, any record
    /// whose target it has already declared dead — the same node-local
    /// next-live rule the `on_peer_down` hook uses, so engines stay
    /// byte-identical.
    pub fn schedule_step_at<F: Fabric>(
        &self,
        net: &mut F,
        t0: Time,
        strategy: SendStrategy,
        skip: &[NodeId],
    ) -> u64 {
        let cfg = &self.cfg;
        let kn = self.nodes.len();
        let mut records = 0u64;
        for i in 0..kn {
            if skip.contains(&self.nodes[i]) {
                continue;
            }
            let ep = Endpoint { node: self.nodes[i], mode: cfg.comm };
            for k in 0..cfg.outputs_per_step {
                let want = dst_of(&self.nodes, i, k);
                let dst = if cfg.reliable.is_some() {
                    let pi = self
                        .nodes
                        .iter()
                        .position(|&n| n == want)
                        .expect("record target is a learner");
                    (0..kn)
                        .map(|s| self.nodes[(pi + s) % kn])
                        .find(|&c| c != ep.node && !net.reliable_is_down(&ep, c))
                } else {
                    Some(want)
                };
                let Some(dst) = dst else { continue };
                let at = match strategy {
                    SendStrategy::Streamed => {
                        t0 + cfg.compute_ns * (k as Time + 1) / cfg.outputs_per_step as Time
                    }
                    SendStrategy::Aggregated => t0 + cfg.compute_ns,
                };
                let msg = Message::new(vec![k as u8; cfg.record_bytes]);
                if cfg.reliable.is_some() {
                    net.reliable_send_at(at, &ep, dst, msg);
                } else {
                    net.send_at(at, &ep, dst, msg);
                }
                records += 1;
            }
        }
        records
    }

    /// The app sized for `steps` scheduled steps (workload-chaos
    /// harness: one app across the whole grid of steps).
    pub fn app_for(&self, records: u64) -> LearnerApp {
        LearnerApp::new(self.nodes.clone(), records)
    }
}

/// Run the workload on either engine; returns per-step stats.
pub fn run<F: Fabric>(
    net: &mut F,
    cfg: LearnerConfig,
    strategy: SendStrategy,
) -> Vec<StepStats> {
    let grid = Learners::setup(net, cfg);
    let mut out = Vec::with_capacity(cfg.steps as usize);
    for _step in 0..cfg.steps {
        let t0 = net.now();
        let mut app = grid.schedule_step(net, strategy);
        net.run(&mut app);
        if app.any_dead() {
            // Peers died mid-step: every record either landed (possibly
            // re-placed) or originated at a dead learner.
            assert!(app.received <= app.expected, "duplicated learner records");
        } else {
            assert_eq!(app.received, app.expected, "lost learner records");
        }
        // The step ends when compute is done AND all records landed.
        let end = net.now().max(t0 + cfg.compute_ns);
        net.advance_to(end);
        out.push(StepStats { makespan: end - t0, records: app.expected });
    }
    out
}

/// Paper-shape check: streamed beats aggregated, and the advantage is
/// the communication tail hidden under compute.
pub fn overlap_advantage<F: Fabric>(net_factory: impl Fn() -> F, cfg: LearnerConfig) -> (f64, f64) {
    let mut a = net_factory();
    let streamed = run(&mut a, cfg, SendStrategy::Streamed);
    let mut b = net_factory();
    let aggregated = run(&mut b, cfg, SendStrategy::Aggregated);
    let mean = |v: &[StepStats]| {
        v.iter().map(|s| s.makespan as f64).sum::<f64>() / v.len() as f64
    };
    (mean(&streamed), mean(&aggregated))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ethernet::RxMode;
    use crate::config::SystemConfig;

    #[test]
    fn streamed_overlaps_and_wins() {
        let cfg = LearnerConfig { steps: 2, ..Default::default() };
        let (streamed, aggregated) = overlap_advantage(Network::card, cfg);
        assert!(
            streamed < aggregated,
            "streamed {streamed} should beat aggregated {aggregated}"
        );
    }

    #[test]
    fn all_records_delivered() {
        let mut net = Network::card();
        let cfg = LearnerConfig { steps: 1, ..Default::default() };
        let stats = run(&mut net, cfg, SendStrategy::Streamed);
        assert_eq!(stats[0].records, 27 * 16);
    }

    #[test]
    fn makespan_at_least_compute_window() {
        let mut net = Network::card();
        let cfg = LearnerConfig { steps: 1, compute_ns: 200_000, ..Default::default() };
        let stats = run(&mut net, cfg, SendStrategy::Streamed);
        assert!(stats[0].makespan >= 200_000);
    }

    #[test]
    fn every_mode_carries_the_workload() {
        // The mode axis: identical record schedule over all three
        // channels, all records delivered; the per-mode makespans obey
        // the paper's overhead ordering (fifo ≤ pm ≪ eth).
        let go = |comm: CommMode| {
            let mut net = Network::card();
            let cfg = LearnerConfig { steps: 1, outputs_per_step: 4, comm, ..Default::default() };
            let stats = run(&mut net, cfg, SendStrategy::Aggregated);
            assert_eq!(stats[0].records, 27 * 4);
            let t = net.metrics.mode_traffic[comm.name()];
            assert_eq!(t.messages, 27 * 4, "per-mode accounting ({})", comm.name());
            stats[0].makespan
        };
        let fifo = go(CommMode::BridgeFifo { width_bits: 64 });
        let pm = go(CommMode::Postmaster { queue: 0 });
        let eth = go(CommMode::Ethernet { rx: RxMode::Interrupt });
        // The §3.1-vs-§3.2 claim: the software-path mode is the slow one.
        assert!(pm < eth, "pm {pm} vs eth {eth}");
        assert!(fifo < eth, "fifo {fifo} vs eth {eth}");
    }

    #[test]
    fn reliable_mode_is_lossless_without_faults() {
        let mut net = Network::card();
        let cfg = LearnerConfig {
            steps: 2,
            reliable: Some(ReliableParams::default()),
            ..Default::default()
        };
        let stats = run(&mut net, cfg, SendStrategy::Streamed);
        assert_eq!(stats[0].records, 27 * 16);
        assert!(net.metrics.acks > 0);
        assert_eq!(net.metrics.peers_declared_down, 0);
    }

    #[test]
    fn dead_learner_work_is_replaced() {
        // Kill one learner mid-step: its senders' retry budgets exhaust,
        // the undelivered records re-place onto live learners, and the
        // step still closes with every live record delivered once.
        let mut cfg_sys = SystemConfig::card();
        cfg_sys.drop_unroutable = true;
        let mut net = Network::new(cfg_sys);
        let cfg = LearnerConfig {
            learners: 8,
            steps: 1,
            reliable: Some(ReliableParams {
                rto_ns: 30_000,
                max_retries: 3,
                ..ReliableParams::default()
            }),
            ..Default::default()
        };
        let grid = Learners::setup(&mut net, cfg);
        let victim = grid.nodes[3];
        let mut app = grid.schedule_step(&mut net, SendStrategy::Aggregated);
        // Two-phase death right as the aggregated burst launches.
        net.run_until(&mut app, cfg.compute_ns + 5_000);
        for &l in &net.topo.in_links(victim).to_vec() {
            net.fail_link(l);
        }
        net.run_until(&mut app, cfg.compute_ns + 6_000);
        for &l in &net.topo.out_links(victim).to_vec() {
            net.fail_link(l);
        }
        net.run_to_quiescence(&mut app);
        assert!(app.dead[3], "the victim must be declared dead");
        assert!(app.replaced > 0, "undelivered records must be re-placed");
        assert!(net.metrics.retransmits > 0);
        assert!(
            app.received <= app.expected,
            "exactly-once violated: {} > {}",
            app.received,
            app.expected
        );
        // Everything a live learner sent arrived somewhere: only the
        // victim's own outputs can be missing.
        let per = cfg.outputs_per_step as u64;
        assert!(app.received >= app.expected - per, "lost live records");
    }
}
