//! Distributed learners (§3.2, experiment E8) — mode-generic.
//!
//! "Regions or learners are distributed across multiple nodes, and each
//! node generates multiple small outputs during each time step which
//! become the inputs in the next time step. The function of Postmaster
//! is to allow the node to send those outputs to their intended targets
//! *as they are generated* rather than collect them and send them out as
//! a larger transmission at the end of the time step … this approach
//! also allows much more overlap of computation and communication."
//!
//! We reproduce exactly that comparison: a grid of learners, each
//! producing `outputs_per_step` small records per step for its mesh
//! neighbors; strategy `Streamed` emits each record when it is produced
//! (uniformly through the compute window), `Aggregated` emits everything
//! at the end. The measured quantity is the makespan of a time step:
//! compute + residual communication tail.
//!
//! The channel itself is a parameter ([`LearnerConfig::comm`]): the
//! records ride the unified [`Endpoint`] API, so the same workload runs
//! over Postmaster DMA (the paper's recommendation), internal Ethernet
//! or Bridge FIFO — `repro learners --comm pm|eth|fifo` — and the
//! per-mode makespans quantify *why* §3.2 recommends Postmaster.

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::sim::Time;
use crate::topology::NodeId;

/// When outputs leave the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SendStrategy {
    /// As generated: k-th output at `compute_ns * (k+1) / n` (§3.2's
    /// recommended pattern — overlaps communication with compute).
    Streamed,
    /// All at the end of the compute window.
    Aggregated,
}

/// Workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct LearnerConfig {
    pub learners: usize,
    /// Small records each learner emits per step.
    pub outputs_per_step: usize,
    /// Bytes per record (small by design).
    pub record_bytes: usize,
    /// Compute window per step (FPGA time), ns.
    pub compute_ns: Time,
    pub steps: u32,
    /// Node-index stride when selecting learners (1 = the first
    /// `learners` nodes). A stride spreads the grid across cards and
    /// cages, which is how the workload exercises the sharded engine's
    /// cross-boundary path.
    pub stride: usize,
    /// The virtual channel the records travel over.
    pub comm: CommMode,
}

impl Default for LearnerConfig {
    fn default() -> Self {
        LearnerConfig {
            learners: 27,
            outputs_per_step: 16,
            record_bytes: 64,
            compute_ns: 50_000,
            steps: 4,
            stride: 1,
            comm: CommMode::Postmaster { queue: 0 },
        }
    }
}

/// Per-step result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepStats {
    pub makespan: Time,
    pub records: u64,
}

struct LearnerApp {
    expected: u64,
    received: u64,
}

impl App for LearnerApp {
    fn on_message(&mut self, _net: &mut Network, _ep: Endpoint, _msg: &Message) -> bool {
        self.received += 1;
        // Consumed: the record never enters the recv inbox.
        true
    }
}

impl ShardableApp for LearnerApp {
    fn partition(&self, _shard: u32, _owner: &[u32]) -> Self {
        LearnerApp { expected: 0, received: 0 }
    }
    fn reduce(&mut self, part: Self) {
        self.received += part.received;
    }
}

/// The k-th output's destination for learner `i` (round-robin over the
/// other learners; never `i` itself).
fn dst_of(nodes: &[NodeId], i: usize, k: usize) -> NodeId {
    let dst = nodes[(i + 1 + k % (nodes.len() - 1)) % nodes.len()];
    if dst == nodes[i] {
        nodes[(i + 1) % nodes.len()]
    } else {
        dst
    }
}

/// Run the workload on either engine; returns per-step stats.
pub fn run<F: Fabric>(
    net: &mut F,
    cfg: LearnerConfig,
    strategy: SendStrategy,
) -> Vec<StepStats> {
    let nodes: Vec<NodeId> =
        net.topo().nodes().step_by(cfg.stride.max(1)).take(cfg.learners).collect();
    assert!(nodes.len() >= 2, "need at least two learners");
    let eps: Vec<Endpoint> = nodes.iter().map(|&n| net.open(n, cfg.comm)).collect();
    if net.caps(cfg.comm).pair_setup {
        // Pre-establish exactly the pairs the schedule uses.
        for i in 0..nodes.len() {
            for k in 0..cfg.outputs_per_step {
                net.connect(&eps[i], dst_of(&nodes, i, k));
            }
        }
    }
    let mut out = Vec::with_capacity(cfg.steps as usize);
    for _step in 0..cfg.steps {
        let t0 = net.now();
        // Each learner sends `outputs_per_step` records round-robin to
        // the other learners, each produced at its production time.
        let mut records = 0u64;
        for i in 0..nodes.len() {
            for k in 0..cfg.outputs_per_step {
                let dst = dst_of(&nodes, i, k);
                let at = match strategy {
                    SendStrategy::Streamed => {
                        t0 + cfg.compute_ns * (k as Time + 1) / cfg.outputs_per_step as Time
                    }
                    SendStrategy::Aggregated => t0 + cfg.compute_ns,
                };
                net.send_at(at, &eps[i], dst, Message::new(vec![k as u8; cfg.record_bytes]));
                records += 1;
            }
        }
        let mut app = LearnerApp { expected: records, received: 0 };
        net.run(&mut app);
        assert_eq!(app.received, app.expected, "lost learner records");
        // The step ends when compute is done AND all records landed.
        let end = net.now().max(t0 + cfg.compute_ns);
        net.advance_to(end);
        out.push(StepStats { makespan: end - t0, records });
    }
    out
}

/// Paper-shape check: streamed beats aggregated, and the advantage is
/// the communication tail hidden under compute.
pub fn overlap_advantage<F: Fabric>(net_factory: impl Fn() -> F, cfg: LearnerConfig) -> (f64, f64) {
    let mut a = net_factory();
    let streamed = run(&mut a, cfg, SendStrategy::Streamed);
    let mut b = net_factory();
    let aggregated = run(&mut b, cfg, SendStrategy::Aggregated);
    let mean = |v: &[StepStats]| {
        v.iter().map(|s| s.makespan as f64).sum::<f64>() / v.len() as f64
    };
    (mean(&streamed), mean(&aggregated))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ethernet::RxMode;

    #[test]
    fn streamed_overlaps_and_wins() {
        let cfg = LearnerConfig { steps: 2, ..Default::default() };
        let (streamed, aggregated) = overlap_advantage(Network::card, cfg);
        assert!(
            streamed < aggregated,
            "streamed {streamed} should beat aggregated {aggregated}"
        );
    }

    #[test]
    fn all_records_delivered() {
        let mut net = Network::card();
        let cfg = LearnerConfig { steps: 1, ..Default::default() };
        let stats = run(&mut net, cfg, SendStrategy::Streamed);
        assert_eq!(stats[0].records, 27 * 16);
    }

    #[test]
    fn makespan_at_least_compute_window() {
        let mut net = Network::card();
        let cfg = LearnerConfig { steps: 1, compute_ns: 200_000, ..Default::default() };
        let stats = run(&mut net, cfg, SendStrategy::Streamed);
        assert!(stats[0].makespan >= 200_000);
    }

    #[test]
    fn every_mode_carries_the_workload() {
        // The mode axis: identical record schedule over all three
        // channels, all records delivered; the per-mode makespans obey
        // the paper's overhead ordering (fifo ≤ pm ≪ eth).
        let go = |comm: CommMode| {
            let mut net = Network::card();
            let cfg = LearnerConfig { steps: 1, outputs_per_step: 4, comm, ..Default::default() };
            let stats = run(&mut net, cfg, SendStrategy::Aggregated);
            assert_eq!(stats[0].records, 27 * 4);
            let t = net.metrics.mode_traffic[comm.name()];
            assert_eq!(t.messages, 27 * 4, "per-mode accounting ({})", comm.name());
            stats[0].makespan
        };
        let fifo = go(CommMode::BridgeFifo { width_bits: 64 });
        let pm = go(CommMode::Postmaster { queue: 0 });
        let eth = go(CommMode::Ethernet { rx: RxMode::Interrupt });
        // The §3.1-vs-§3.2 claim: the software-path mode is the slow one.
        assert!(pm < eth, "pm {pm} vs eth {eth}");
        assert!(fifo < eth, "fifo {fifo} vs eth {eth}");
    }
}
