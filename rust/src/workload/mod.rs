//! The machine-intelligence workloads the paper motivates.
//!
//! * [`training`] — data-parallel training of the JAX/Pallas transformer
//!   LM: real numerics through the PJRT runtime, gradient exchange as a
//!   ring all-reduce whose traffic runs on the simulated fabric, and
//!   per-node compute time from the FPGA-offload cost model. This is
//!   the end-to-end driver (`examples/train_distributed.rs`, E10).
//! * [`learners`] — the §3.2 distributed-learners pattern: every node
//!   emits many small outputs per time step that are the next step's
//!   inputs elsewhere; compares send-as-generated (Postmaster overlap)
//!   against aggregate-then-send (E8).
//! * [`mcts`] — distributed Monte Carlo Tree Search, the intro's example
//!   of an algorithm ill-suited to SIMD hardware: a leader node expands
//!   a UCB tree and farms rollouts to workers over Postmaster (E9).
//! * [`serving`] — open-loop inference serving (E15): external clients
//!   reach the mesh through the gateway NAT with Poisson / bursty /
//!   diurnal arrival schedules; frontends fan requests out to workers
//!   and the harness reports p50/p99/p999 latency (measured from the
//!   scheduled arrival — no coordinated omission) plus saturation
//!   throughput from an offered-rate sweep.
//! * [`snn`] — event-driven spiking neural network (E16), the traffic
//!   class the INC was built for: leaky integrate-and-fire neurons in
//!   fixed-point integer math, seeded synapse tables re-derived at both
//!   ends of every axon, spikes as multicast (or unicast) raw packets,
//!   per-synapse delays on the timing wheel, and a spike-rate ×
//!   mesh-size × shard-count ablation sweep.
//! * [`chaos`] — the resilience suite (E13): seeded deterministic fault
//!   scripts (failure storms, NIC flaps, partition-and-heal, node
//!   drops, hot-spot congestion) composed with background traffic and
//!   graded against per-scenario SLOs — delivered throughput, p50/p99
//!   latency, reroute convergence, bounded-buffer drop/stall counts.
//!
//! Every workload is written against the engine-agnostic
//! [`crate::network::Fabric`] trait and implements
//! [`crate::network::ShardableApp`], so it runs unmodified — and
//! byte-identically — on the serial engine or the bounded-lag parallel
//! engine (`repro <workload> --shards K`;
//! `tests/sharded_differential.rs`). Their traffic rides the unified
//! Endpoint API, so the virtual channel is itself a parameter
//! ([`crate::channels::CommMode`]; `repro learners|mcts --comm
//! pm|eth|fifo`) rather than baked into the call sites.

pub mod chaos;
pub mod learners;
pub mod mcts;
pub mod serving;
pub mod snn;
pub mod training;

/// FPGA-offload compute model: effective throughput of one node's fabric
/// at dense f32 math, FLOP/ns. Zynq-7000 class fabric ≈ 20 GFLOP/s
/// (DESIGN.md §5 substitution table).
pub const NODE_FLOP_PER_NS: f64 = 20.0;
