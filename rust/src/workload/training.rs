//! Data-parallel LM training over the simulated INC (experiment E10).
//!
//! The end-to-end composition of all three layers:
//!
//! * **numerics** — the AOT-compiled JAX/Pallas transformer
//!   (`artifacts/`): `init` → parameters, `grad` → (loss, gradients),
//!   `apply` → SGD update. Executed via PJRT from Rust; Python is not
//!   running.
//! * **compute time** — each rank's grad step is charged to its node's
//!   FPGA at [`super::NODE_FLOP_PER_NS`].
//! * **communication** — gradients all-reduce over the simulated mesh as
//!   a [`RingAllreduce`] (real packets, credits, adaptive routing).
//!
//! The synthetic task is next-token prediction on a deterministic
//! shift-register stream: learnable well below the uniform baseline, so
//! the loss curve is a real signal that the whole stack composes.

use anyhow::Result;

use crate::channels::endpoint::CommMode;
use crate::channels::reliable::ReliableParams;
use crate::coordinator::collectives::{mean_reduce, RingAllreduce};
use crate::coordinator::Placement;
use crate::network::Fabric;
use crate::runtime::Runtime;
use crate::sim::Time;
use crate::topology::NodeId;

/// Training run parameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Data-parallel ranks (nodes).
    pub ranks: usize,
    pub steps: u32,
    pub lr: f32,
    pub seed: u64,
    pub placement: Placement,
    /// Log every `log_every` steps.
    pub log_every: u32,
    /// The virtual channel the gradient all-reduce travels over
    /// (`repro train --comm pm|eth|fifo`): the §3 mode choice as a
    /// training-time ablation. Postmaster by default.
    pub comm: CommMode,
    /// Run the gradient all-reduce over the ack/retransmit transport
    /// (`repro train --reliable`): the E14 overhead ablation — same
    /// answer, plus the transport's framing and ack traffic.
    pub reliable: Option<ReliableParams>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            ranks: 4,
            steps: 200,
            lr: 0.25,
            seed: 7,
            placement: Placement::Block,
            log_every: 10,
            comm: CommMode::Postmaster { queue: 0 },
            reliable: None,
        }
    }
}

/// One logged point of the loss curve.
#[derive(Debug, Clone, Copy)]
pub struct LossPoint {
    pub step: u32,
    pub loss: f32,
    /// Virtual time at the end of the step.
    pub vtime: Time,
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub curve: Vec<LossPoint>,
    pub first_loss: f32,
    pub final_loss: f32,
    /// Virtual time total and its split.
    pub vtime_total: Time,
    pub vtime_compute: Time,
    pub vtime_comm: Time,
    pub grad_bytes: u64,
    pub params: usize,
}

/// Deterministic synthetic batch: token stream from a per-(rank, step)
/// LCG where the next token is a fixed permutation of the current one —
/// exactly learnable by a small LM.
pub fn gen_batch(
    vocab: usize,
    batch: usize,
    seq: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut x = Vec::with_capacity(batch * seq);
    let mut y = Vec::with_capacity(batch * seq);
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    let next_tok = |t: usize| (t * 31 + 17) % vocab; // the permutation to learn
    for _ in 0..batch {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let mut tok = (state >> 33) as usize % vocab;
        for _ in 0..seq {
            x.push(tok as f32);
            tok = next_tok(tok);
            y.push(tok as f32);
        }
    }
    (x, y)
}

/// One training step's *fabric* side: close the compute window (all
/// ranks compute in parallel), then all-reduce `grad_bytes` over the
/// mesh on the configured communication mode. Shared by [`train`] and
/// [`train_comm`]; returns the step's communication makespan.
fn step_comm<F: Fabric>(
    net: &mut F,
    ranks: &[NodeId],
    grad_bytes: u64,
    compute_ns: Time,
    comm: CommMode,
    reliable: Option<ReliableParams>,
) -> Time {
    let t_compute_done = net.now() + compute_ns;
    net.advance_to(t_compute_done);
    if ranks.len() >= 2 {
        // Liveness watching stays off (`watch_until` 0): training trusts
        // the driver for membership; the transport contributes framing,
        // acks and retransmit cover only.
        let ar = match reliable {
            Some(p) => {
                RingAllreduce::with_mode_reliable(net, ranks.to_vec(), grad_bytes, comm, p, 0)
            }
            None => RingAllreduce::with_mode(net, ranks.to_vec(), grad_bytes, comm),
        };
        ar.run(net).makespan
    } else {
        0
    }
}

/// The communication/time shape of a training run, with the numerics
/// replaced by fixed sizes — runnable on the stub runtime, on either
/// engine. This is what the serial↔sharded training differential and
/// the app-workload bench exercise; [`train`] layers the real PJRT
/// numerics on the same per-step fabric path.
#[derive(Debug, Clone)]
pub struct CommShape {
    pub ranks: usize,
    pub steps: u32,
    pub grad_bytes: u64,
    /// Per-rank compute window per step, ns.
    pub compute_ns: Time,
    pub placement: Placement,
    /// The virtual channel the gradient all-reduce rides.
    pub comm: CommMode,
}

/// Result of a [`train_comm`] run (virtual-time split only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommReport {
    pub vtime_total: Time,
    pub vtime_compute: Time,
    pub vtime_comm: Time,
}

/// Run the training communication shape (no numerics; see
/// [`CommShape`]).
pub fn train_comm<F: Fabric>(net: &mut F, shape: &CommShape) -> CommReport {
    let ranks: Vec<NodeId> = shape.placement.select(net.topo(), shape.ranks);
    let t_start = net.now();
    let mut vtime_comm: Time = 0;
    for _ in 0..shape.steps {
        vtime_comm +=
            step_comm(net, &ranks, shape.grad_bytes, shape.compute_ns, shape.comm, None);
    }
    CommReport {
        vtime_total: net.now() - t_start,
        vtime_compute: shape.compute_ns * shape.steps as Time,
        vtime_comm,
    }
}

/// Run data-parallel training; `rt` must contain `init`/`grad`/`apply`
/// entry points (see `python/compile/aot.py`).
pub fn train<F: Fabric>(net: &mut F, rt: &Runtime, cfg: &TrainConfig) -> Result<TrainReport> {
    let ranks: Vec<NodeId> = cfg.placement.select(net.topo(), cfg.ranks);
    let grad_ep = rt.entry("grad")?.clone();
    // Input layout of `grad`: params..., x, y. Outputs: loss, grads...
    let n_params = grad_ep.inputs.len() - 2;
    let (batch, seq) = {
        let x = &grad_ep.inputs[n_params];
        (x.shape[0], x.shape[1])
    };
    let vocab = rt
        .manifest
        .model
        .split("-v")
        .nth(1)
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(64);

    // init: no inputs, outputs = params.
    let mut params = rt.execute_f32("init", &[])?;
    assert_eq!(params.len(), n_params);
    let param_elems: usize = params.iter().map(|p| p.len()).sum();
    let grad_bytes = 4 * param_elems as u64;

    // FLOPs per rank-step ≈ 6 × params × tokens (fwd+bwd dense math).
    let flops = 6.0 * param_elems as f64 * (batch * seq) as f64;
    let compute_ns = (flops / super::NODE_FLOP_PER_NS) as Time;

    let mut curve = Vec::new();
    let mut first_loss = f32::NAN;
    let mut vtime_compute: Time = 0;
    let mut vtime_comm: Time = 0;
    let t_start = net.now();

    for step in 0..cfg.steps {
        // 1. Every rank computes its gradient on its own shard (real
        //    numerics; modeled FPGA time, all ranks in parallel).
        let mut losses = Vec::with_capacity(ranks.len());
        let mut grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(ranks.len());
        for (r, _node) in ranks.iter().enumerate() {
            let (x, y) = gen_batch(
                vocab,
                batch,
                seq,
                cfg.seed ^ (step as u64) << 20 ^ r as u64,
            );
            let mut inputs: Vec<Vec<f32>> = params.clone();
            inputs.push(x);
            inputs.push(y);
            let mut out = rt.execute_f32("grad", &inputs)?;
            losses.push(out.remove(0)[0]);
            grads.push(out);
        }
        // 2. All-reduce the gradients: arithmetic here, traffic on the
        //    fabric (after the compute window closes).
        let mut mean_grads = Vec::with_capacity(n_params);
        for p in 0..n_params {
            let per_rank: Vec<Vec<f32>> = grads.iter().map(|g| g[p].clone()).collect();
            mean_grads.push(mean_reduce(per_rank));
        }
        vtime_compute += compute_ns;
        vtime_comm += step_comm(net, &ranks, grad_bytes, compute_ns, cfg.comm, cfg.reliable);

        // 3. Replicated SGD update.
        let mut inputs = params;
        inputs.extend(mean_grads);
        inputs.push(vec![cfg.lr]);
        params = rt.execute_f32("apply", &inputs)?;

        let loss = losses.iter().sum::<f32>() / losses.len() as f32;
        if step == 0 {
            first_loss = loss;
        }
        if step % cfg.log_every == 0 || step == cfg.steps - 1 {
            curve.push(LossPoint { step, loss, vtime: net.now() - t_start });
        }
    }

    let final_loss = curve.last().map(|p| p.loss).unwrap_or(f32::NAN);
    Ok(TrainReport {
        curve,
        first_loss,
        final_loss,
        vtime_total: net.now() - t_start,
        vtime_compute,
        vtime_comm,
        grad_bytes,
        params: param_elems,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_deterministic_and_shifted() {
        let (x1, y1) = gen_batch(64, 2, 8, 9);
        let (x2, y2) = gen_batch(64, 2, 8, 9);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        // y is the permuted successor of x.
        for (a, b) in x1.iter().zip(&y1) {
            assert_eq!(*b as usize, ((*a as usize) * 31 + 17) % 64);
        }
        // Different seeds differ.
        let (x3, _) = gen_batch(64, 2, 8, 10);
        assert_ne!(x1, x3);
    }

    // Full training integration lives in rust/tests/train_e2e.rs (needs
    // `make artifacts`).
}
