//! Open-loop inference serving (E15): external clients vs the mesh.
//!
//! The paper's system target is neuromorphic/ML inference served to the
//! outside world through the gateway's physical Ethernet port (§3.1).
//! This workload models exactly that shape: simulated external clients
//! issue requests through the gateway's NAT
//! ([`crate::network::Fabric::external_ingress_at`]) to a set of
//! *frontend* nodes; each frontend fans the request out to `fanout`
//! *worker* nodes over the unified Endpoint API, the workers compute
//! for a fixed service time and reply, and the request completes when
//! the last reply lands back at its frontend.
//!
//! # Open loop, by construction
//!
//! The arrival schedule is precomputed in driver context from the
//! config seed ([`arrival_schedule`]) and fed to the fabric before the
//! run — arrivals do **not** wait for completions. Latency is measured
//! from the *scheduled* arrival instant, not from whenever the frame
//! cleared the (possibly backed-up) physical port, so queueing delay
//! under overload is charged to the request: the classic
//! coordinated-omission trap of closed-loop harnesses does not apply.
//! Three arrival processes are modeled ([`ArrivalProcess`]): Poisson
//! (independent clients), bursty (synchronized batch front-ends), and
//! diurnal (a sinusoidally modulated rate — one "day" across the run).
//!
//! # Percentiles and saturation
//!
//! Latencies land in a [`LatencyHist`] (log-2 buckets); p50/p99/p999
//! are bucket upper bounds — exact min/max/mean ride alongside.
//! Saturation throughput is measured by an offered-rate sweep
//! ([`saturation_sweep`]): the highest *achieved* completion rate over
//! the sweep. Under overload an open-loop system's achieved rate tops
//! out while its latency grows without bound; the knee is visible in
//! the per-rate reports.
//!
//! # Determinism
//!
//! The schedule is a pure function of the config and seed; request
//! state lives at the owning frontend (a request's `on_eth` and all of
//! its reply `on_message`s fire at that one node), workers reply to
//! `msg.from` — so the workload is a well-formed [`ShardableApp`] and
//! runs byte-identically on the serial and sharded engines
//! (`tests/sharded_differential.rs`).

use std::sync::Arc;

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::metrics::LatencyHist;
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::sim::Time;
use crate::topology::NodeId;
use crate::util::{FxHashMap, SplitMix64};

/// How external request arrivals are spaced in time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Exponential inter-arrival gaps (independent clients).
    Poisson,
    /// `burst` simultaneous arrivals, bursts spaced so the mean rate
    /// matches the configured rate (synchronized batch front-ends).
    Bursty { burst: u32 },
    /// Poisson with a sinusoidally modulated rate — one full cycle
    /// ("day") across the run, peak ≈ 1.8×, trough ≈ 0.2× the mean.
    Diurnal,
}

impl ArrivalProcess {
    /// Parse a CLI name: `poisson | burst | diurnal`.
    pub fn parse(s: &str) -> Option<ArrivalProcess> {
        match s {
            "poisson" => Some(ArrivalProcess::Poisson),
            "burst" | "bursty" => Some(ArrivalProcess::Bursty { burst: 32 }),
            "diurnal" => Some(ArrivalProcess::Diurnal),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty { .. } => "burst",
            ArrivalProcess::Diurnal => "diurnal",
        }
    }
}

/// Workload parameters.
#[derive(Debug, Clone, Copy)]
pub struct ServingConfig {
    /// NAT-forwarded frontend nodes (each owns one external port).
    pub frontends: usize,
    /// Worker pool size (disjoint from the frontends).
    pub workers: usize,
    /// Workers consulted per request (model-parallel fan-out).
    pub fanout: usize,
    /// Total requests issued (open loop: all are scheduled up front).
    pub requests: u64,
    /// Mean offered rate, requests per second.
    pub rate_per_s: f64,
    pub arrivals: ArrivalProcess,
    /// External request frame payload (also the fan-out message size).
    pub request_bytes: u32,
    /// Worker reply message size.
    pub reply_bytes: u32,
    /// Fixed per-request service time at each worker. Workers overlap
    /// requests freely (FPGA offload — an infinite-server station);
    /// contention shows up on the fabric, not in a CPU queue.
    pub work_ns: Time,
    /// The virtual channel the fan-out and replies travel over.
    pub comm: CommMode,
    /// Node-index stride when placing frontends/workers (spreads the
    /// pools across cards and cages — the cross-shard traffic source).
    pub stride: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            frontends: 4,
            workers: 16,
            fanout: 3,
            requests: 200,
            rate_per_s: 50_000.0,
            arrivals: ArrivalProcess::Poisson,
            request_bytes: 256,
            reply_bytes: 128,
            work_ns: 20_000,
            comm: CommMode::Postmaster { queue: 0 },
            stride: 1,
        }
    }
}

/// Serving message kinds (first payload byte).
const KIND_REQUEST: u8 = 1;
const KIND_REPLY: u8 = 2;

/// Encode `(kind, request id)` into a `bytes`-sized payload.
fn encode(kind: u8, id: u64, bytes: u32) -> Vec<u8> {
    let mut v = vec![0u8; (bytes as usize).max(9)];
    v[0] = kind;
    v[1..9].copy_from_slice(&id.to_le_bytes());
    v
}

/// Decode a serving payload back into `(kind, request id)`.
fn decode(data: &[u8]) -> Option<(u8, u64)> {
    if data.len() < 9 {
        return None;
    }
    let mut id = [0u8; 8];
    id.copy_from_slice(&data[1..9]);
    Some((data[0], u64::from_le_bytes(id)))
}

/// Exponential gap with the given mean (inverse-CDF; `1 - u ∈ (0, 1]`
/// keeps the log finite).
fn exp_gap(rng: &mut SplitMix64, mean_ns: f64) -> f64 {
    -(1.0 - rng.gen_f64()).ln() * mean_ns
}

/// Precompute the arrival instant of every request: a pure function of
/// the config and `seed`, so serial and sharded runs (and re-runs) see
/// the identical schedule. Non-decreasing by construction.
pub fn arrival_schedule(cfg: &ServingConfig, seed: u64) -> Vec<Time> {
    let mut rng = SplitMix64::new(seed ^ 0x0A5E_11A7_E5EE_D001);
    let mean_gap = 1e9 / cfg.rate_per_s;
    let n = cfg.requests as usize;
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    match cfg.arrivals {
        ArrivalProcess::Poisson => {
            for _ in 0..n {
                t += exp_gap(&mut rng, mean_gap);
                out.push(t as Time);
            }
        }
        ArrivalProcess::Bursty { burst } => {
            let b = burst.max(1) as usize;
            for i in 0..n {
                if i > 0 && i % b == 0 {
                    t += mean_gap * b as f64;
                }
                out.push(t as Time);
            }
        }
        ArrivalProcess::Diurnal => {
            // One sinusoidal cycle across the nominal run span; the
            // instantaneous rate scales the exponential gap.
            let period = mean_gap * n as f64;
            for _ in 0..n {
                let phase = t / period * std::f64::consts::TAU;
                let scale = (1.0 + 0.8 * phase.sin()).max(0.05);
                t += exp_gap(&mut rng, mean_gap) / scale;
                out.push(t as Time);
            }
        }
    }
    out
}

/// The per-run serving state machine: request registration at the
/// frontends, fan-out, worker replies, completion accounting. One
/// request's callbacks all fire at its frontend (registration and
/// replies) or at its workers (service) — see the module docs — so the
/// app partitions cleanly. Drive it to quiescence in a **single**
/// [`Fabric::run`] call: in-flight request state lives in the shard
/// partitions and does not survive a mid-flight reduce.
#[derive(Clone)]
pub struct ServingApp {
    comm: CommMode,
    fanout: usize,
    frontends: Arc<Vec<NodeId>>,
    workers: Arc<Vec<NodeId>>,
    /// Request id → scheduled arrival instant (shared, read-only).
    schedule: Arc<Vec<Time>>,
    request_bytes: u32,
    reply_bytes: u32,
    work_ns: Time,
    /// Requests issued (root app only; partitions carry 0).
    pub issued: u64,
    /// Requests whose last reply landed.
    pub completed: u64,
    /// Completion instant of the latest request (max-merged).
    pub last_done: Time,
    /// Request latency: completion − scheduled arrival.
    pub hist: LatencyHist,
    /// Outstanding replies per in-flight request id.
    pending: FxHashMap<u64, u32>,
}

impl ServingApp {
    /// Requests still in flight (0 after a run to quiescence).
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }
}

impl App for ServingApp {
    fn on_eth(
        &mut self,
        net: &mut Network,
        node: NodeId,
        frame: &crate::channels::ethernet::EthFrame,
    ) {
        // Gateway-NAT ingress at a frontend: register the request and
        // fan it out. (The contains check also skips stray frames at
        // worker nodes, which this workload never produces.)
        if !self.frontends.contains(&node) {
            return;
        }
        let id = frame.tag;
        if id as usize >= self.schedule.len() {
            return;
        }
        self.pending.insert(id, self.fanout as u32);
        let ep = Endpoint { node, mode: self.comm };
        let nw = self.workers.len();
        for j in 0..self.fanout {
            // Pure function of the request id: both engines consult the
            // same workers.
            let w = self.workers[(id as usize * self.fanout + j) % nw];
            net.send(&ep, w, Message::new(encode(KIND_REQUEST, id, self.request_bytes)));
        }
    }

    fn on_message(&mut self, net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
        let Some((kind, id)) = decode(&msg.data) else { return false };
        match kind {
            KIND_REQUEST => {
                // Worker: serve after the fixed service time, reply to
                // the frontend that asked.
                let at = net.now() + self.work_ns;
                net.send_at(at, &ep, msg.from, Message::new(encode(KIND_REPLY, id, self.reply_bytes)));
                true
            }
            KIND_REPLY => {
                // Frontend: count the reply down; the last one
                // completes the request.
                if let Some(left) = self.pending.get_mut(&id) {
                    *left -= 1;
                    if *left == 0 {
                        self.pending.remove(&id);
                        self.completed += 1;
                        let now = net.now();
                        self.last_done = self.last_done.max(now);
                        self.hist.record(now.saturating_sub(self.schedule[id as usize]));
                    }
                }
                true
            }
            _ => false,
        }
    }
}

impl ShardableApp for ServingApp {
    fn partition(&self, _shard: u32, _owner: &[u32]) -> Self {
        ServingApp {
            comm: self.comm,
            fanout: self.fanout,
            frontends: self.frontends.clone(),
            workers: self.workers.clone(),
            schedule: self.schedule.clone(),
            request_bytes: self.request_bytes,
            reply_bytes: self.reply_bytes,
            work_ns: self.work_ns,
            issued: 0,
            completed: 0,
            last_done: 0,
            hist: LatencyHist::new(),
            pending: FxHashMap::default(),
        }
    }

    fn reduce(&mut self, part: Self) {
        self.completed += part.completed;
        self.last_done = self.last_done.max(part.last_done);
        self.hist.merge(&part.hist);
        // Request ids are owned by one frontend each, so the maps are
        // disjoint; anything still here was in flight at the reduce.
        self.pending.extend(part.pending);
    }
}

/// A placed serving deployment: frontends NAT-forwarded, endpoints
/// open, the arrival schedule computed. Split from [`run`] so harnesses
/// can issue and drive explicitly.
pub struct Serving {
    pub cfg: ServingConfig,
    pub frontends: Arc<Vec<NodeId>>,
    pub workers: Arc<Vec<NodeId>>,
    pub schedule: Arc<Vec<Time>>,
}

impl Serving {
    /// Place the pools (skipping the gateway — it forwards, it does not
    /// serve), open endpoints, connect pairs where the mode demands,
    /// install the NAT entries, and compute the schedule.
    pub fn setup<F: Fabric>(net: &mut F, cfg: ServingConfig) -> Serving {
        assert!(cfg.frontends > 0 && cfg.workers > 0 && cfg.fanout > 0, "empty serving pool");
        assert!(cfg.frontends <= u16::MAX as usize, "one external port per frontend");
        assert!(cfg.fanout <= cfg.workers, "fanout exceeds the worker pool");
        let gw = net.gateway();
        let nodes: Vec<NodeId> = net
            .topo()
            .nodes()
            .step_by(cfg.stride.max(1))
            .filter(|&n| n != gw)
            .take(cfg.frontends + cfg.workers)
            .collect();
        assert_eq!(
            nodes.len(),
            cfg.frontends + cfg.workers,
            "preset too small for {} frontends + {} workers at stride {}",
            cfg.frontends,
            cfg.workers,
            cfg.stride
        );
        let frontends: Vec<NodeId> = nodes[..cfg.frontends].to_vec();
        let workers: Vec<NodeId> = nodes[cfg.frontends..].to_vec();
        for &n in &nodes {
            net.open(n, cfg.comm);
        }
        if net.caps(cfg.comm).pair_setup {
            for &f in &frontends {
                let ep = Endpoint { node: f, mode: cfg.comm };
                for &w in &workers {
                    net.connect(&ep, w);
                }
            }
            for &w in &workers {
                let ep = Endpoint { node: w, mode: cfg.comm };
                for &f in &frontends {
                    net.connect(&ep, f);
                }
            }
        }
        for (i, &f) in frontends.iter().enumerate() {
            net.nat_forward(i as u16, f, 0);
        }
        let schedule = arrival_schedule(&cfg, net.config().seed);
        Serving {
            cfg,
            frontends: Arc::new(frontends),
            workers: Arc::new(workers),
            schedule: Arc::new(schedule),
        }
    }

    /// Feed the whole arrival schedule through the gateway NAT
    /// (ascending order — the physical port serializes bursts exactly
    /// as the real 1 GbE would). Returns the requests issued.
    pub fn issue<F: Fabric>(&self, net: &mut F) -> u64 {
        let nf = self.frontends.len();
        for (i, &at) in self.schedule.iter().enumerate() {
            let ok = net.external_ingress_at(at, (i % nf) as u16, self.cfg.request_bytes, i as u64);
            debug_assert!(ok, "request {i} hit an unmapped NAT port");
        }
        self.schedule.len() as u64
    }

    /// The root app for this deployment, sized for the full schedule.
    pub fn app(&self) -> ServingApp {
        ServingApp {
            comm: self.cfg.comm,
            fanout: self.cfg.fanout,
            frontends: self.frontends.clone(),
            workers: self.workers.clone(),
            schedule: self.schedule.clone(),
            request_bytes: self.cfg.request_bytes,
            reply_bytes: self.cfg.reply_bytes,
            work_ns: self.cfg.work_ns,
            issued: self.schedule.len() as u64,
            completed: 0,
            last_done: 0,
            hist: LatencyHist::new(),
            pending: FxHashMap::default(),
        }
    }
}

/// One offered-rate point's results.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingReport {
    pub issued: u64,
    pub completed: u64,
    /// Latency percentiles (log-2 bucket upper bounds) and exact
    /// mean/max, ns; measured from the *scheduled* arrival.
    pub p50_ns: Time,
    pub p99_ns: Time,
    pub p999_ns: Time,
    pub mean_ns: f64,
    pub max_ns: Time,
    /// First scheduled arrival → last completion.
    pub makespan_ns: Time,
    /// The configured open-loop arrival rate.
    pub offered_rps: f64,
    /// Achieved completion rate over the makespan.
    pub throughput_rps: f64,
}

impl ServingReport {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"issued\":{},\"completed\":{},\"p50_ns\":{},\"p99_ns\":{},\"p999_ns\":{},\
             \"mean_ns\":{:.1},\"max_ns\":{},\"makespan_ns\":{},\"offered_rps\":{:.0},\
             \"throughput_rps\":{:.0}}}",
            self.issued,
            self.completed,
            self.p50_ns,
            self.p99_ns,
            self.p999_ns,
            self.mean_ns,
            self.max_ns,
            self.makespan_ns,
            self.offered_rps,
            self.throughput_rps,
        )
    }
}

/// Run the full open-loop workload on either engine and report.
pub fn run<F: Fabric>(net: &mut F, cfg: ServingConfig) -> ServingReport {
    let sv = Serving::setup(net, cfg);
    sv.issue(net);
    let mut app = sv.app();
    net.run(&mut app);
    assert_eq!(app.in_flight(), 0, "requests still pending at quiescence");
    assert_eq!(app.completed, app.issued, "lost serving requests");
    let first = sv.schedule.first().copied().unwrap_or(0);
    let makespan = app.last_done.saturating_sub(first);
    let throughput =
        if makespan > 0 { app.completed as f64 * 1e9 / makespan as f64 } else { 0.0 };
    ServingReport {
        issued: app.issued,
        completed: app.completed,
        p50_ns: app.hist.percentile(0.50),
        p99_ns: app.hist.percentile(0.99),
        p999_ns: app.hist.percentile(0.999),
        mean_ns: app.hist.mean(),
        max_ns: app.hist.max(),
        makespan_ns: makespan,
        offered_rps: cfg.rate_per_s,
        throughput_rps: throughput,
    }
}

/// Offered-rate sweep on fresh fabrics: returns the saturation
/// throughput (highest achieved completion rate) and the per-rate
/// reports, in sweep order.
pub fn saturation_sweep<F: Fabric>(
    make: impl Fn() -> F,
    base: ServingConfig,
    rates: &[f64],
) -> (f64, Vec<ServingReport>) {
    let mut reports = Vec::with_capacity(rates.len());
    let mut sat = 0.0f64;
    for &r in rates {
        let mut net = make();
        let rep = run(&mut net, ServingConfig { rate_per_s: r, ..base });
        sat = sat.max(rep.throughput_rps);
        reports.push(rep);
    }
    (sat, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Network;

    #[test]
    fn poisson_schedule_is_monotone_at_the_configured_rate() {
        let cfg = ServingConfig { requests: 2000, rate_per_s: 100_000.0, ..Default::default() };
        let s = arrival_schedule(&cfg, 42);
        assert_eq!(s.len(), 2000);
        assert!(s.windows(2).all(|w| w[0] <= w[1]), "schedule must be non-decreasing");
        // Mean gap within 15% of the 10µs target over 2000 samples.
        let mean = s.last().unwrap() / (s.len() as u64);
        assert!((7_000..13_000).contains(&mean), "mean gap {mean}ns off a 10µs target");
        // Pure function of the seed.
        assert_eq!(s, arrival_schedule(&cfg, 42));
        assert_ne!(s, arrival_schedule(&cfg, 43));
    }

    #[test]
    fn burst_schedule_groups_arrivals() {
        let cfg = ServingConfig {
            requests: 96,
            arrivals: ArrivalProcess::Bursty { burst: 32 },
            ..Default::default()
        };
        let s = arrival_schedule(&cfg, 1);
        assert_eq!(s[0], s[31], "a burst arrives simultaneously");
        assert!(s[32] > s[31], "bursts are spaced apart");
        assert_eq!(s[32], s[63]);
    }

    #[test]
    fn arrival_process_parse_round_trips() {
        for (s, p) in [
            ("poisson", ArrivalProcess::Poisson),
            ("burst", ArrivalProcess::Bursty { burst: 32 }),
            ("diurnal", ArrivalProcess::Diurnal),
        ] {
            assert_eq!(ArrivalProcess::parse(s), Some(p));
            assert_eq!(ArrivalProcess::parse(p.name()), Some(p));
        }
        assert_eq!(ArrivalProcess::parse("uniform"), None);
    }

    #[test]
    fn all_requests_complete_on_card() {
        let mut net = Network::card();
        let cfg = ServingConfig { requests: 60, ..Default::default() };
        let rep = run(&mut net, cfg);
        assert_eq!(rep.completed, 60);
        assert!(rep.p50_ns > 0 && rep.p99_ns >= rep.p50_ns && rep.p999_ns >= rep.p99_ns);
        assert!(rep.mean_ns >= cfg.work_ns as f64, "latency includes the service time");
        assert!(rep.throughput_rps > 0.0);
        let j = rep.to_json();
        assert!(j.contains("\"completed\":60") && j.contains("throughput_rps"));
    }

    #[test]
    fn every_arrival_process_serves_cleanly() {
        for arrivals in [
            ArrivalProcess::Poisson,
            ArrivalProcess::Bursty { burst: 16 },
            ArrivalProcess::Diurnal,
        ] {
            let mut net = Network::card();
            let cfg = ServingConfig { requests: 48, arrivals, ..Default::default() };
            let rep = run(&mut net, cfg);
            assert_eq!(rep.completed, 48, "{} lost requests", arrivals.name());
        }
    }

    #[test]
    fn saturation_sweep_reports_every_rate() {
        let base = ServingConfig { requests: 30, ..Default::default() };
        let rates = [20_000.0, 200_000.0];
        let (sat, reports) = saturation_sweep(Network::card, base, &rates);
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.completed == 30));
        assert!(sat >= reports[0].throughput_rps && sat >= reports[1].throughput_rps);
        assert!(sat > 0.0);
    }
}
