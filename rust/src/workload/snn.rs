//! Event-driven spiking neural network (E16): the traffic class the
//! INC was built for.
//!
//! The paper's opening claim is that a 3D-mesh FPGA fabric suits
//! event-driven, sparse, irregular-fan-out computation "not well suited
//! to the matrix manipulation/SIMD libraries that GPUs are optimized
//! for" (§1). Every other workload in this repo is request/response or
//! collective traffic; this one is the neuromorphic shape itself: a
//! population of leaky integrate-and-fire (LIF) neurons spread across
//! the mesh, spikes carried as tiny packets through the spanning-tree
//! multicast router (or unicast over any [`CommMode`]), and per-synapse
//! axonal delays scheduled on the timing wheel.
//!
//! # LIF update rule (fixed point)
//!
//! Membrane potentials are Q16.16 fixed-point `i64` — no floats, so
//! serial and sharded runs are bit-exact. Per neuron per tick:
//!
//! ```text
//! v  = (v * decay_q16) >> 16        // leak (arithmetic shift)
//! v += drained synaptic input       // weights landed since last tick
//! v += input_q16  if background_hit // seeded Bernoulli input drive
//! fire iff tick >= refractory_until && v >= threshold_q16
//!   on fire: v = 0; refractory_until = tick + 1 + refractory_ticks
//! ```
//!
//! # Seed discipline
//!
//! There is **no RNG stream**. Synapse tables ([`synapse`]) and the
//! background input process ([`background_hit`]) are pure [`mix64`]
//! functions of `(SnnConfig, seed, indices)`: a receiver re-derives the
//! *sender's* fan-out table from the spike's `(node, neuron)` identity
//! alone, so spike packets carry no synapse payload and no state is
//! shared across nodes. Both engines — and both ends of every synapse —
//! compute identical tables by construction.
//!
//! # Event scheme
//!
//! Two timer kinds ride [`crate::network::Fabric::timer_at`], selected
//! by tag bits 60..63 (safely below the reliable transport's reserved
//! bit 63 mark):
//!
//! * **tick** — one per population node per simulation tick; bit 23 of
//!   the tag is set so the keyed event queue orders same-instant
//!   synapse events *before* the tick that drains them.
//! * **syn** — one per synapse per spike, at `arrival + delay_ticks ×
//!   tick_ns`; the tag carries the Q16.16 weight (bits 24..56, two's
//!   complement i32) and the target neuron (bits 0..23), so the event
//!   needs no side-table lookup.
//!
//! Same-(time, key) collisions fall back to insertion order at the
//! owning node, and weight accumulation commutes, so the schedule is
//! byte-identical across engines (`tests/sharded_differential.rs`).
//!
//! # Conservation
//!
//! Every fire bumps `expected_deliveries` by the fan-out; every syn
//! event bumps `spikes_delivered`. On a healthy fabric the two are
//! equal at quiescence — [`run`] asserts it, and
//! `prop_snn_spike_conservation` sweeps it across seeds.

use std::sync::Arc;

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::router::{Packet, Payload, Proto, RouteKind};
use crate::sim::Time;
use crate::topology::NodeId;
use crate::util::{mix64, FxHashMap};

/// Workload parameters. Dynamics are integer-only; every field
/// participates in the pure synapse/background derivations, so two runs
/// with equal configs and seeds are identical in every observable.
#[derive(Debug, Clone, Copy)]
pub struct SnnConfig {
    /// Population size: nodes hosting neurons (strided placement,
    /// skipping the gateway).
    pub nodes: usize,
    pub neurons_per_node: u32,
    /// Synapses per neuron (axonal fan-out; targets are always remote).
    pub fanout: u32,
    /// Simulation ticks (the membrane-update grid).
    pub ticks: u32,
    /// Tick pitch, ns of virtual time.
    pub tick_ns: Time,
    /// Fire threshold, Q16.16.
    pub threshold_q16: i64,
    /// Per-tick membrane retention, Q16.16 (e.g. 55706 ≈ 0.85).
    pub decay_q16: i64,
    /// Background input amplitude, Q16.16.
    pub input_q16: i64,
    /// Synaptic weight magnitude, Q16.16 (sign per synapse). Must fit
    /// an i32 — it rides inside the syn timer tag.
    pub weight_q16: i64,
    /// Background input probability per neuron-tick, parts per million.
    pub rate_ppm: u64,
    /// Fraction of synapses that are inhibitory, parts per million.
    pub inhibit_ppm: u64,
    /// Ticks a neuron stays silent after firing.
    pub refractory_ticks: u32,
    /// Synaptic delay bounds, ticks. `min >= 1`: a zero-delay synapse
    /// would schedule an event at the current instant.
    pub min_delay_ticks: u32,
    pub max_delay_ticks: u32,
    /// `None` — spike fan-out rides the spanning-tree multicast router
    /// as one `Proto::Raw` packet per spike. `Some(mode)` — unicast
    /// datagrams over the endpoint mode (the ablation's transport axis;
    /// `CommMode::Raw` is the natural fit for 8-byte spikes).
    pub comm: Option<CommMode>,
    /// Node-index stride when placing the population across the mesh.
    pub stride: usize,
    /// Record every fire as `(tick, pop index, neuron)` — the property
    /// tests' refractory witness. Off by default (it grows with spikes).
    pub record_fires: bool,
}

impl Default for SnnConfig {
    fn default() -> Self {
        SnnConfig {
            nodes: 16,
            neurons_per_node: 8,
            fanout: 4,
            ticks: 20,
            tick_ns: 50_000,
            threshold_q16: 90 << 16,
            decay_q16: 55_706, // 0.85 in Q16.16
            input_q16: 60 << 16,
            weight_q16: 45 << 16,
            rate_ppm: 80_000,
            inhibit_ppm: 150_000,
            refractory_ticks: 2,
            min_delay_ticks: 1,
            max_delay_ticks: 4,
            comm: None,
            stride: 1,
            record_fires: false,
        }
    }
}

// -- timer tags -------------------------------------------------------
//
// Kind in bits 60..63 — below RELIABLE_TIMER_MARK (bit 63), so SNN
// timers always reach `App::on_timer`. The event queue keys on the low
// 24 tag bits (`key_timer`): tick tags set bit 23, syn tags keep the
// neuron index below it, so at one (node, instant) synapse arrivals
// drain before the membrane update that integrates them.

const KIND_SHIFT: u32 = 60;
const KIND_TICK: u64 = 1;
const KIND_SYN: u64 = 2;
/// Bit 23 of the truncated event key: orders ticks after syn events.
const TICK_KEY_BIT: u64 = 0x80_0000;
/// `Proto::Raw` tag marking a multicast spike packet.
const SPIKE_TAG: u16 = 0xA5;

fn tick_tag(tick: u32) -> u64 {
    debug_assert!((tick as u64) < TICK_KEY_BIT);
    (KIND_TICK << KIND_SHIFT) | TICK_KEY_BIT | tick as u64
}

fn syn_tag(weight_q16: i64, neuron: u32) -> u64 {
    debug_assert!((neuron as u64) < TICK_KEY_BIT);
    let w = weight_q16 as i32 as u32 as u64;
    (KIND_SYN << KIND_SHIFT) | (w << 24) | neuron as u64
}

fn syn_tag_decode(tag: u64) -> (i64, u32) {
    (((tag >> 24) as u32) as i32 as i64, (tag & (TICK_KEY_BIT - 1)) as u32)
}

// -- pure derivations -------------------------------------------------

/// One synapse of a neuron's axonal fan-out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Synapse {
    /// Target population index (never the source node).
    pub target: u32,
    /// Target neuron within the target node.
    pub neuron: u32,
    /// Axonal delay, ticks (within the configured bounds).
    pub delay_ticks: u32,
    /// Signed Q16.16 weight (±`weight_q16` per `inhibit_ppm`).
    pub weight_q16: i64,
}

/// Synapse `j` of neuron `(src, neuron)`: a pure function of
/// `(cfg, seed, src, neuron, j)` — sender and receiver derive the same
/// table independently, so spike packets carry identity only.
pub fn synapse(cfg: &SnnConfig, seed: u64, src: u32, neuron: u32, j: u32) -> Synapse {
    debug_assert!(cfg.nodes >= 2);
    let h = mix64(
        seed ^ 0x5EED_5CA1_AB1E_0001 ^ ((src as u64) << 40) ^ ((neuron as u64) << 16) ^ j as u64,
    );
    // Skip-self target draw: uniform over the other population nodes,
    // so every spike crosses the fabric.
    let mut target = (h % (cfg.nodes as u64 - 1)) as u32;
    if target >= src {
        target += 1;
    }
    let span = (cfg.max_delay_ticks - cfg.min_delay_ticks + 1) as u64;
    let inhibitory = mix64(h) % 1_000_000 < cfg.inhibit_ppm;
    Synapse {
        target,
        neuron: ((h >> 24) % cfg.neurons_per_node as u64) as u32,
        delay_ticks: cfg.min_delay_ticks + ((h >> 44) % span) as u32,
        weight_q16: if inhibitory { -cfg.weight_q16 } else { cfg.weight_q16 },
    }
}

/// Did neuron `(src, neuron)` receive background input at `tick`? A
/// seeded Bernoulli draw with no stream state — the input process is
/// identical however callbacks interleave.
pub fn background_hit(cfg: &SnnConfig, seed: u64, src: u32, neuron: u32, tick: u32) -> bool {
    let h = mix64(
        seed ^ 0x5EED_BAC6_0000_0002
            ^ ((src as u64) << 46)
            ^ ((neuron as u64) << 23)
            ^ tick as u64,
    );
    h % 1_000_000 < cfg.rate_ppm
}

fn spike_bytes(src: NodeId, neuron: u32) -> Vec<u8> {
    let mut v = Vec::with_capacity(8);
    v.extend_from_slice(&src.0.to_le_bytes());
    v.extend_from_slice(&neuron.to_le_bytes());
    v
}

// -- the app ----------------------------------------------------------

/// Per-neuron dynamic state. `syn_in` accumulates weights landed since
/// the last tick; `refractory_until` is the first tick the neuron may
/// fire again.
#[derive(Debug, Clone, Copy, Default)]
struct Neuron {
    v: i64,
    syn_in: i64,
    refractory_until: u32,
}

/// The SNN state machine: membrane updates at tick timers, spike
/// fan-out at fires, weight accumulation at syn timers. All state is
/// keyed by the node whose callbacks mutate it, so the app partitions
/// cleanly ([`ShardableApp`]). Drive it to quiescence in a **single**
/// [`Fabric::run`] call.
#[derive(Clone)]
pub struct SnnApp {
    cfg: SnnConfig,
    seed: u64,
    /// Population placement (shared, read-only).
    pop: Arc<Vec<NodeId>>,
    /// node id → population index.
    idx: Arc<FxHashMap<u32, u32>>,
    /// (population index, neuron) → state. Keys are disjoint across
    /// partitions (a neuron's events all fire at its node).
    state: FxHashMap<(u32, u32), Neuron>,
    /// Fires observed (spike packets sent).
    pub spikes_emitted: u64,
    /// Synaptic deliveries owed: fan-out per fire.
    pub expected_deliveries: u64,
    /// Syn timer firings (weight landed at its target neuron).
    pub spikes_delivered: u64,
    pub syn_events: u64,
    pub tick_events: u64,
    /// Peak timing-wheel occupancy sampled at tick events. Engine-level:
    /// a shard's wheel holds only its own events, so serial and sharded
    /// peaks differ by construction (normalized out of report identity).
    pub wheel_peak: u64,
    /// `(tick, pop index, neuron)` per fire, when `record_fires`.
    pub fires: Vec<(u32, u32, u32)>,
}

impl SnnApp {
    fn on_tick(&mut self, net: &mut Network, node: NodeId, tick: u32) {
        self.tick_events += 1;
        self.wheel_peak = self.wheel_peak.max(net.sim.pending() as u64);
        let src = self.idx[&node.0];
        let mut fired: Vec<u32> = Vec::new();
        for i in 0..self.cfg.neurons_per_node {
            let n = self.state.entry((src, i)).or_default();
            n.v = (n.v * self.cfg.decay_q16) >> 16;
            n.v += n.syn_in;
            n.syn_in = 0;
            if background_hit(&self.cfg, self.seed, src, i, tick) {
                n.v += self.cfg.input_q16;
            }
            if tick >= n.refractory_until && n.v >= self.cfg.threshold_q16 {
                n.v = 0;
                n.refractory_until = tick + 1 + self.cfg.refractory_ticks;
                fired.push(i);
            }
        }
        for &i in &fired {
            self.spikes_emitted += 1;
            self.expected_deliveries += self.cfg.fanout as u64;
            if self.cfg.record_fires {
                self.fires.push((tick, src, i));
            }
            self.emit_spike(net, node, src, i);
        }
        if tick + 1 < self.cfg.ticks {
            net.timer_at(net.now() + self.cfg.tick_ns, node, tick_tag(tick + 1));
        }
    }

    /// Send one spike's fan-out: the distinct target *nodes* (several
    /// synapses may share one), as a single multicast packet or as
    /// unicast datagrams — receivers re-derive which synapses they host.
    fn emit_spike(&mut self, net: &mut Network, node: NodeId, src: u32, neuron: u32) {
        let now = net.now();
        let mut dsts: Vec<NodeId> = Vec::with_capacity(self.cfg.fanout as usize);
        for j in 0..self.cfg.fanout {
            let d = self.pop[synapse(&self.cfg, self.seed, src, neuron, j).target as usize];
            if !dsts.contains(&d) {
                dsts.push(d);
            }
        }
        match self.cfg.comm {
            None => {
                net.app_multicast_at(
                    now,
                    node,
                    &dsts,
                    Proto::Raw { tag: SPIKE_TAG },
                    Payload::U64s([node.0 as u64, neuron as u64, 0, 0]),
                );
            }
            Some(mode) => {
                let ep = Endpoint { node, mode };
                for d in dsts {
                    net.send_at(now, &ep, d, Message::new(spike_bytes(node, neuron)));
                }
            }
        }
    }

    /// A spike from `(src_node, src_neuron)` arrived at `here`: schedule
    /// a syn timer per local synapse of the sender's (re-derived) table.
    fn on_spike(&mut self, net: &mut Network, here: NodeId, src_node: u32, src_neuron: u32) {
        let Some(&src) = self.idx.get(&src_node) else { return };
        let here_idx = self.idx[&here.0];
        let now = net.now();
        for j in 0..self.cfg.fanout {
            let syn = synapse(&self.cfg, self.seed, src, src_neuron, j);
            if syn.target == here_idx {
                let at = now + syn.delay_ticks as Time * self.cfg.tick_ns;
                net.timer_at(at, here, syn_tag(syn.weight_q16, syn.neuron));
            }
        }
    }
}

impl App for SnnApp {
    fn on_timer(&mut self, net: &mut Network, node: NodeId, tag: u64) {
        match tag >> KIND_SHIFT {
            KIND_TICK => self.on_tick(net, node, (tag & (TICK_KEY_BIT - 1)) as u32),
            KIND_SYN => {
                let (w, neuron) = syn_tag_decode(tag);
                let src = self.idx[&node.0];
                self.state.entry((src, neuron)).or_default().syn_in += w;
                self.syn_events += 1;
                self.spikes_delivered += 1;
            }
            _ => debug_assert!(false, "unknown snn timer tag {tag:#x}"),
        }
    }

    fn on_raw(&mut self, net: &mut Network, node: NodeId, packet: &Packet) {
        // Multicast spike fan-out; anything else (there is nothing else
        // in this workload) is ignored.
        if !matches!(packet.route, RouteKind::Multicast)
            || !matches!(packet.proto, Proto::Raw { tag: SPIKE_TAG })
        {
            return;
        }
        let Payload::U64s(w) = &packet.payload else { return };
        self.on_spike(net, node, w[0] as u32, w[1] as u32);
    }

    fn on_message(&mut self, net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
        // Unicast spike datagram (8 bytes: src node, src neuron).
        if msg.data.len() != 8 {
            return false;
        }
        let src = u32::from_le_bytes(msg.data[0..4].try_into().unwrap());
        let neuron = u32::from_le_bytes(msg.data[4..8].try_into().unwrap());
        self.on_spike(net, ep.node, src, neuron);
        true
    }
}

impl ShardableApp for SnnApp {
    fn partition(&self, _shard: u32, _owner: &[u32]) -> Self {
        SnnApp {
            cfg: self.cfg,
            seed: self.seed,
            pop: self.pop.clone(),
            idx: self.idx.clone(),
            state: FxHashMap::default(),
            spikes_emitted: 0,
            expected_deliveries: 0,
            spikes_delivered: 0,
            syn_events: 0,
            tick_events: 0,
            wheel_peak: 0,
            fires: Vec::new(),
        }
    }

    fn reduce(&mut self, part: Self) {
        self.spikes_emitted += part.spikes_emitted;
        self.expected_deliveries += part.expected_deliveries;
        self.spikes_delivered += part.spikes_delivered;
        self.syn_events += part.syn_events;
        self.tick_events += part.tick_events;
        self.wheel_peak = self.wheel_peak.max(part.wheel_peak);
        // Neuron state and fires are keyed by owned nodes — disjoint.
        self.state.extend(part.state);
        self.fires.extend(part.fires);
    }
}

// -- deployment -------------------------------------------------------

/// A placed SNN: population strided across the mesh, endpoints open
/// where the transport needs them, tick-0 timers armed. Split from
/// [`run`] so harnesses (and the property tests) can drive explicitly.
pub struct Snn {
    pub cfg: SnnConfig,
    pub seed: u64,
    pub pop: Arc<Vec<NodeId>>,
    idx: Arc<FxHashMap<u32, u32>>,
}

impl Snn {
    pub fn setup<F: Fabric>(net: &mut F, cfg: SnnConfig) -> Snn {
        assert!(cfg.nodes >= 2, "population needs at least two nodes");
        assert!(cfg.neurons_per_node >= 1 && cfg.fanout >= 1 && cfg.ticks >= 1);
        assert!(
            (cfg.ticks as u64) < TICK_KEY_BIT && (cfg.neurons_per_node as u64) <= TICK_KEY_BIT,
            "tick/neuron indices must fit the 23-bit tag fields"
        );
        assert!(
            cfg.min_delay_ticks >= 1 && cfg.min_delay_ticks <= cfg.max_delay_ticks,
            "synaptic delays need 1 <= min <= max"
        );
        assert!(
            cfg.weight_q16 >= 0 && cfg.weight_q16 <= i32::MAX as i64,
            "weight must fit the tag's i32 field"
        );
        let gw = net.gateway();
        let pop: Vec<NodeId> = net
            .topo()
            .nodes()
            .step_by(cfg.stride.max(1))
            .filter(|&n| n != gw)
            .take(cfg.nodes)
            .collect();
        assert_eq!(
            pop.len(),
            cfg.nodes,
            "preset too small for {} population nodes at stride {}",
            cfg.nodes,
            cfg.stride
        );
        if let Some(mode) = cfg.comm {
            for &n in &pop {
                net.open(n, mode);
            }
            if net.caps(mode).pair_setup {
                // Fan-out targets are hash-drawn, so connect all pairs.
                for &a in &pop {
                    let ep = Endpoint { node: a, mode };
                    for &b in &pop {
                        if a != b {
                            net.connect(&ep, b);
                        }
                    }
                }
            }
        }
        for &n in &pop {
            net.timer_at(0, n, tick_tag(0));
        }
        let idx = pop.iter().enumerate().map(|(i, &n)| (n.0, i as u32)).collect();
        Snn { cfg, seed: net.config().seed, pop: Arc::new(pop), idx: Arc::new(idx) }
    }

    /// The root app for this deployment.
    pub fn app(&self) -> SnnApp {
        SnnApp {
            cfg: self.cfg,
            seed: self.seed,
            pop: self.pop.clone(),
            idx: self.idx.clone(),
            state: FxHashMap::default(),
            spikes_emitted: 0,
            expected_deliveries: 0,
            spikes_delivered: 0,
            syn_events: 0,
            tick_events: 0,
            wheel_peak: 0,
            fires: Vec::new(),
        }
    }
}

// -- report -----------------------------------------------------------

/// One run's results. Everything except the engine-level fields
/// ([`SnnReport::normalized`]) is part of the byte-identity contract.
#[derive(Debug, Clone, PartialEq)]
pub struct SnnReport {
    pub nodes: usize,
    pub neurons: u64,
    pub ticks: u32,
    pub spikes_emitted: u64,
    /// Syn events landed; equals `fanout × spikes_emitted` on a healthy
    /// fabric ([`run`] asserts it).
    pub spikes_delivered: u64,
    pub syn_events: u64,
    pub tick_events: u64,
    /// Final virtual clock (last syn delivery).
    pub virtual_ns: Time,
    /// Emission rate over virtual time.
    pub spikes_per_s: f64,
    /// Events dispatched by the engine — engine-level (a sharded run
    /// dispatches per-shard bookkeeping the serial engine does not).
    pub events_dispatched: u64,
    /// Peak timing-wheel occupancy — engine-level (per-shard wheels).
    pub wheel_peak: u64,
    /// Per-mode `(name, messages, bytes)` from the fabric metrics, in
    /// BTreeMap order (empty for the multicast transport, which rides
    /// below the endpoint layer).
    pub mode_traffic: Vec<(String, u64, u64)>,
}

impl SnnReport {
    /// The report with engine-level fields zeroed — the cross-engine
    /// comparison form (chaos precedent: presentation fields are
    /// overwritten before `==`).
    pub fn normalized(&self) -> SnnReport {
        let mut r = self.clone();
        r.events_dispatched = 0;
        r.wheel_peak = 0;
        r
    }

    pub fn to_json(&self) -> String {
        let traffic: Vec<String> = self
            .mode_traffic
            .iter()
            .map(|(m, n, b)| format!("{{\"mode\":\"{m}\",\"messages\":{n},\"bytes\":{b}}}"))
            .collect();
        format!(
            "{{\"nodes\":{},\"neurons\":{},\"ticks\":{},\"spikes_emitted\":{},\
             \"spikes_delivered\":{},\"syn_events\":{},\"tick_events\":{},\
             \"virtual_ns\":{},\"spikes_per_s\":{:.1},\"events_dispatched\":{},\
             \"wheel_peak\":{},\"mode_traffic\":[{}]}}",
            self.nodes,
            self.neurons,
            self.ticks,
            self.spikes_emitted,
            self.spikes_delivered,
            self.syn_events,
            self.tick_events,
            self.virtual_ns,
            self.spikes_per_s,
            self.events_dispatched,
            self.wheel_peak,
            traffic.join(",")
        )
    }
}

/// Run the SNN to quiescence on either engine and report. Asserts spike
/// conservation: every emitted spike's full fan-out landed.
pub fn run<F: Fabric>(net: &mut F, cfg: SnnConfig) -> SnnReport {
    let snn = Snn::setup(net, cfg);
    let mut app = snn.app();
    let events = net.run(&mut app);
    assert_eq!(
        app.spikes_delivered, app.expected_deliveries,
        "spike conservation violated: {} of {} synaptic deliveries landed",
        app.spikes_delivered, app.expected_deliveries
    );
    assert_eq!(app.tick_events, cfg.nodes as u64 * cfg.ticks as u64, "missed membrane ticks");
    let now = net.now();
    let m = net.metrics();
    SnnReport {
        nodes: cfg.nodes,
        neurons: cfg.nodes as u64 * cfg.neurons_per_node as u64,
        ticks: cfg.ticks,
        spikes_emitted: app.spikes_emitted,
        spikes_delivered: app.spikes_delivered,
        syn_events: app.syn_events,
        tick_events: app.tick_events,
        virtual_ns: now,
        spikes_per_s: if now > 0 { app.spikes_emitted as f64 * 1e9 / now as f64 } else { 0.0 },
        events_dispatched: events,
        wheel_peak: app.wheel_peak,
        mode_traffic: m
            .mode_traffic
            .iter()
            .map(|(k, v)| (k.to_string(), v.messages, v.bytes))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Network;

    #[test]
    fn synapse_tables_are_pure_and_bounded() {
        let cfg = SnnConfig::default();
        let mut distinct = false;
        for src in 0..cfg.nodes as u32 {
            for neuron in 0..cfg.neurons_per_node {
                for j in 0..cfg.fanout {
                    let a = synapse(&cfg, 42, src, neuron, j);
                    assert_eq!(a, synapse(&cfg, 42, src, neuron, j), "table must be pure");
                    assert_ne!(a.target, src, "synapses never target their own node");
                    assert!((a.target as usize) < cfg.nodes);
                    assert!(a.neuron < cfg.neurons_per_node);
                    assert!(
                        (cfg.min_delay_ticks..=cfg.max_delay_ticks).contains(&a.delay_ticks)
                    );
                    assert!(a.weight_q16.unsigned_abs() == cfg.weight_q16 as u64);
                    if a != synapse(&cfg, 43, src, neuron, j) {
                        distinct = true;
                    }
                }
            }
        }
        assert!(distinct, "different seeds must draw different tables");
    }

    #[test]
    fn background_process_tracks_rate() {
        let cfg = SnnConfig { rate_ppm: 250_000, ..Default::default() };
        let mut hits = 0u64;
        let trials = 20_000u64;
        for t in 0..trials {
            if background_hit(&cfg, 7, (t % 16) as u32, (t % 8) as u32, (t / 16) as u32) {
                hits += 1;
            }
        }
        let got_ppm = hits * 1_000_000 / trials;
        assert!(
            (200_000..300_000).contains(&got_ppm),
            "background rate {got_ppm} ppm far from 250000"
        );
    }

    #[test]
    fn syn_tag_round_trips_signed_weights() {
        for w in [45i64 << 16, -(45i64 << 16), 1, -1, i32::MAX as i64, i32::MIN as i64] {
            for n in [0u32, 7, 0x7F_FFFE] {
                let (dw, dn) = syn_tag_decode(syn_tag(w, n));
                assert_eq!((dw, dn), (w, n));
            }
        }
        // Kinds are distinct and below the reliable transport's mark.
        let t = tick_tag(5);
        let s = syn_tag(-(45i64 << 16), 3);
        assert_ne!(t >> KIND_SHIFT, s >> KIND_SHIFT);
        assert_eq!(t & crate::channels::reliable::RELIABLE_TIMER_MARK, 0);
        assert_eq!(s & crate::channels::reliable::RELIABLE_TIMER_MARK, 0);
    }

    #[test]
    fn card_run_conserves_spikes_over_multicast() {
        let mut net = Network::card();
        let cfg = SnnConfig { rate_ppm: 200_000, ..Default::default() };
        let rep = run(&mut net, cfg);
        assert!(rep.spikes_emitted > 0, "default config must produce activity");
        assert_eq!(rep.spikes_delivered, rep.spikes_emitted * cfg.fanout as u64);
        assert_eq!(rep.tick_events, cfg.nodes as u64 * cfg.ticks as u64);
        assert!(rep.virtual_ns > 0 && rep.spikes_per_s > 0.0);
        assert!(rep.wheel_peak > 0, "tick events must observe a loaded wheel");
        assert!(rep.mode_traffic.is_empty(), "multicast rides below the endpoint layer");
        let j = rep.to_json();
        assert!(j.contains("\"spikes_emitted\"") && j.contains("\"wheel_peak\""));
    }

    #[test]
    fn unicast_raw_transport_conserves_and_records_traffic() {
        let mut net = Network::card();
        let cfg =
            SnnConfig { rate_ppm: 200_000, comm: Some(CommMode::Raw), ..Default::default() };
        let rep = run(&mut net, cfg);
        assert!(rep.spikes_emitted > 0);
        assert_eq!(rep.spikes_delivered, rep.spikes_emitted * cfg.fanout as u64);
        let raw = rep.mode_traffic.iter().find(|(m, _, _)| m == "raw");
        let (_, msgs, bytes) = raw.expect("raw traffic accounted");
        assert!(*msgs > 0 && *bytes == *msgs * 8, "8-byte spike datagrams");
    }

    #[test]
    fn refractory_window_is_respected_on_card() {
        let mut net = Network::card();
        let cfg = SnnConfig { rate_ppm: 400_000, record_fires: true, ..Default::default() };
        let snn = Snn::setup(&mut net, cfg);
        let mut app = snn.app();
        net.run_to_quiescence(&mut app);
        assert!(app.spikes_emitted > 0);
        let mut fires = app.fires.clone();
        assert_eq!(fires.len() as u64, app.spikes_emitted);
        fires.sort_unstable_by_key(|&(t, n, i)| (n, i, t));
        for w in fires.windows(2) {
            let ((t0, n0, i0), (t1, n1, i1)) = (w[0], w[1]);
            if (n0, i0) == (n1, i1) {
                assert!(
                    t1 >= t0 + 1 + cfg.refractory_ticks,
                    "neuron ({n0},{i0}) fired at ticks {t0} and {t1} inside refractory"
                );
            }
        }
    }

    #[test]
    fn normalized_report_drops_engine_fields_only() {
        let mut net = Network::card();
        let rep = run(&mut net, SnnConfig { rate_ppm: 200_000, ..Default::default() });
        let n = rep.normalized();
        assert_eq!(n.events_dispatched, 0);
        assert_eq!(n.wheel_peak, 0);
        assert_eq!(n.spikes_emitted, rep.spikes_emitted);
        assert_eq!(n.virtual_ns, rep.virtual_ns);
    }
}
