//! Deterministic fault scripts: the scenario registry of the chaos
//! harness (EXPERIMENTS.md E13).
//!
//! A scenario compiles, from `(topology, seed, tick grid)` alone, into
//! a [`FaultScript`]: a time-sorted list of `fail_link`/`repair_link`
//! events plus the traffic constraints the script imposes (nodes that
//! must not source/sink traffic, the partition membership during a
//! cut). Scripts are pure functions of their inputs — no RNG stream is
//! consumed at run time — so the serial and sharded engines replay the
//! *identical* fault sequence at the identical virtual instants, and a
//! `(scenario, seed)` pair names one reproducible experiment forever
//! (the seed discipline of E13).
//!
//! Every script is compiled connectivity-safe: the builder tracks the
//! union of links it has scripted to fail and skips any fault that
//! could disconnect the mesh (minus deliberately dropped nodes) at any
//! instant. The adaptive router panics on a fully-unreachable
//! destination by design — chaos measures degradation, not undefined
//! behavior. Node drops are two-phase for the same reason: inbound
//! links are severed two ticks before outbound ones, so a packet
//! already committed toward the dying node can still transit out.

use std::sync::Arc;

use crate::sim::Time;
use crate::topology::{LinkId, NodeId, Topology};
use crate::util::mix64;

/// One scripted fault, applied by the harness at the tick boundary
/// `at` (scripts emit tick-aligned times only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub at: Time,
    pub kind: FaultKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Fail(LinkId),
    Repair(LinkId),
}

/// A compiled scenario: the fault timeline plus traffic constraints.
#[derive(Debug, Clone)]
pub struct FaultScript {
    /// Fault events, sorted by `at` (ties in script order).
    pub events: Vec<FaultEvent>,
    /// Nodes that must never source or sink harness traffic (dropped
    /// nodes: a packet addressed to a severed node could never be
    /// delivered).
    pub excluded: Vec<NodeId>,
    /// Partition side per node and the heal instant, if the scenario
    /// splits the mesh: `(side[], heal_at)`. Traffic pairs stay
    /// same-side until `heal_at` (conservative: also before the cut, so
    /// no cross-cut packet can be in flight when the plane goes down).
    pub cut: Option<(Vec<u8>, Time)>,
    /// Hot-spot sink every sender aims at, if the scenario congests one.
    pub hotspot: Option<NodeId>,
}

impl FaultScript {
    /// Last scripted instant (0 when the script is fault-free).
    pub fn horizon(&self) -> Time {
        self.events.last().map_or(0, |e| e.at)
    }
}

/// The scenario registry (`repro chaos --scenario <name>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Correlated failure storm: bursts of link losses clustered on
    /// seeded cards (one card's power/clock domain failing takes
    /// several of its links at once), staggered over the run and
    /// repaired a quarter-run later.
    Storm,
    /// NIC flaps: seeded nodes cyclically lose all but their first
    /// link pair, then recover — connectivity degrades, never severs.
    Flap,
    /// Partition-and-heal: every link crossing a seeded x-plane fails
    /// at once, the mesh runs split, then heals.
    Partition,
    /// Node drop: seeded nodes die permanently (two-phase: inbound
    /// links first, outbound two ticks later); their traffic is
    /// excluded and the rest of the mesh routes around the holes.
    Drop,
    /// Hot-spot congestion: no link faults — every sender aims at one
    /// seeded sink whose bounded inbox backpressures or drops.
    Hotspot,
    /// Seeded random packet loss: no scripted faults at all — the
    /// harness raises the fabric-level
    /// [`crate::config::SystemConfig::drop_probability`] instead, so
    /// every link hand-off rolls a deterministic per-(packet, link)
    /// hash and the reliable transport must recover the drops
    /// ([`crate::metrics::Metrics::link_loss`] counts them).
    Loss,
}

impl Scenario {
    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "storm" => Some(Scenario::Storm),
            "flap" => Some(Scenario::Flap),
            "partition" => Some(Scenario::Partition),
            "drop" => Some(Scenario::Drop),
            "hotspot" => Some(Scenario::Hotspot),
            "loss" => Some(Scenario::Loss),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Storm => "storm",
            Scenario::Flap => "flap",
            Scenario::Partition => "partition",
            Scenario::Drop => "drop",
            Scenario::Hotspot => "hotspot",
            Scenario::Loss => "loss",
        }
    }

    pub const ALL: [Scenario; 6] = [
        Scenario::Storm,
        Scenario::Flap,
        Scenario::Partition,
        Scenario::Drop,
        Scenario::Hotspot,
        Scenario::Loss,
    ];

    /// The fabric-level seeded loss rate the scenario runs under (only
    /// [`Scenario::Loss`] asks for one; `repro chaos --loss P`
    /// overrides it).
    pub fn suggested_drop_probability(&self) -> f64 {
        match self {
            Scenario::Loss => 0.01,
            _ => 0.0,
        }
    }

    /// Compile the scenario into a fault script on `topo`. `ticks` ×
    /// `tick_ns` is the traffic window the faults are staggered over;
    /// all event times are tick-aligned so both engines apply them at
    /// identical driver-context instants.
    pub fn script(
        &self,
        topo: &Arc<Topology>,
        seed: u64,
        ticks: u64,
        tick_ns: Time,
    ) -> FaultScript {
        let h = |k: u64| mix64(seed ^ self.ordinal() ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let ticks = ticks.max(8);
        let span = ticks * tick_ns;
        let align = |t: Time| (t / tick_ns).clamp(1, ticks - 1) * tick_ns;
        let empty = FaultScript { events: vec![], excluded: vec![], cut: None, hotspot: None };
        match self {
            Scenario::Storm => {
                let cards = topo.cards();
                // A quarter of the cards (at least one) storm, each
                // burst taking up to 6 of the card's links at once. The
                // tracker accumulates across bursts (it never replays
                // the scripted repairs), so the mesh stays connected
                // under *any* overlap of burst windows — connectivity
                // is monotone in the live-link set.
                let bursts = (cards.len() / 4).max(1) as u64;
                let mut live = LiveLinks::new(topo);
                let mut events = Vec::new();
                for b in 0..bursts {
                    let card = cards[(h(b) % cards.len() as u64) as usize];
                    let fail_at = align(span * (1 + b) / (bursts + 2));
                    let heal_at = align(fail_at + span / 4);
                    for (i, &n) in topo.card_nodes(card).iter().enumerate() {
                        let out = topo.out_links(n);
                        let l = out[(h(b ^ ((i as u64) << 32)) % out.len() as u64) as usize];
                        if live.fail_count_since(fail_at) < 6 && live.fail_if_safe(topo, l, fail_at)
                        {
                            events.push(FaultEvent { at: fail_at, kind: FaultKind::Fail(l) });
                            events.push(FaultEvent { at: heal_at, kind: FaultKind::Repair(l) });
                        }
                    }
                }
                finish(events, empty)
            }
            Scenario::Flap => {
                let n = topo.node_count() as u64;
                let cycles = 2u64;
                // Non-overlapping down-windows (3 ticks apart, 2 ticks
                // down), so two flappers can never be dark at once and
                // each flap alone keeps the mesh connected (one link
                // pair stays up; the mesh minus one node is connected).
                let flappers = (n / 64).max(2).min(((ticks.saturating_sub(2)) / (3 * cycles)).max(1));
                let mut events = Vec::new();
                let mut slot = 0u64;
                for f in 0..flappers {
                    let node = NodeId((h(f) % n) as u32);
                    let down: Vec<LinkId> = topo.out_links(node)[1..]
                        .iter()
                        .flat_map(|&l| [l, reverse(topo, l)])
                        .collect();
                    for _ in 0..cycles {
                        let t0 = align((2 + 3 * slot) * tick_ns);
                        let t1 = align(t0 + 2 * tick_ns);
                        slot += 1;
                        for &l in &down {
                            events.push(FaultEvent { at: t0, kind: FaultKind::Fail(l) });
                            events.push(FaultEvent { at: t1, kind: FaultKind::Repair(l) });
                        }
                    }
                }
                finish(events, empty)
            }
            Scenario::Partition => {
                let (dx, _, _) = topo.dims();
                // Cut plane strictly inside the mesh: left = x < cut_x.
                let cut_x = 1 + (h(1) % (dx as u64 - 1)) as u32;
                let cut_at = align(span / 3);
                let heal_at = align(2 * span / 3);
                let mut events = Vec::new();
                for l in topo.links() {
                    let (a, b) = (topo.coord(l.src).x < cut_x, topo.coord(l.dst).x < cut_x);
                    if a != b {
                        events.push(FaultEvent { at: cut_at, kind: FaultKind::Fail(l.id) });
                        events.push(FaultEvent { at: heal_at, kind: FaultKind::Repair(l.id) });
                    }
                }
                let side: Vec<u8> =
                    topo.nodes().map(|x| u8::from(topo.coord(x).x < cut_x)).collect();
                finish(events, FaultScript { cut: Some((side, heal_at)), ..empty })
            }
            Scenario::Drop => {
                let n = topo.node_count() as u64;
                let drops = (n / 216).max(1);
                let mut live = LiveLinks::new(topo);
                let mut events = Vec::new();
                let mut excluded = Vec::new();
                for d in 0..drops {
                    let victim = NodeId((h(d ^ 0xDEAD) % n) as u32);
                    if excluded.contains(&victim) {
                        continue;
                    }
                    let at = align(span * (1 + d) / (drops + 2));
                    let ins: Vec<LinkId> = topo.in_links(victim).to_vec();
                    let outs: Vec<LinkId> = topo.out_links(victim).to_vec();
                    let all: Vec<LinkId> = ins.iter().chain(outs.iter()).copied().collect();
                    // Sever only if the *rest* of the mesh stays whole.
                    if live.fail_all_if_safe(topo, &all, &[&excluded[..], &[victim]].concat()) {
                        excluded.push(victim);
                        for &l in &ins {
                            events.push(FaultEvent { at, kind: FaultKind::Fail(l) });
                        }
                        // Outbound two ticks later: packets already
                        // committed inward can still transit out.
                        let at2 = align(at + 2 * tick_ns).max(at);
                        for &l in &outs {
                            events.push(FaultEvent { at: at2, kind: FaultKind::Fail(l) });
                        }
                    }
                }
                finish(events, FaultScript { excluded, ..empty })
            }
            Scenario::Hotspot => {
                let sink = NodeId((h(7) % topo.node_count() as u64) as u32);
                FaultScript { hotspot: Some(sink), ..empty }
            }
            // Loss scripts nothing: the faults live in the fabric's
            // per-hand-off hash, not on the timeline.
            Scenario::Loss => empty,
        }
    }

    fn ordinal(&self) -> u64 {
        match self {
            Scenario::Storm => 0x5701,
            Scenario::Flap => 0xF1A2,
            Scenario::Partition => 0x9A37,
            Scenario::Drop => 0xD009,
            Scenario::Hotspot => 0x0407,
            Scenario::Loss => 0x1055,
        }
    }
}

fn finish(mut events: Vec<FaultEvent>, mut script: FaultScript) -> FaultScript {
    events.sort_by_key(|e| e.at);
    script.events = events;
    script
}

/// Compile a drop script for *chosen* victims — workload chaos, where
/// the dying node must be a specific rank/learner/worker rather than a
/// seeded bystander. Deaths are two-phase like [`Scenario::Drop`]
/// (inbound at `at`, outbound two ticks later) and connectivity-checked
/// the same way: a victim whose removal would disconnect the surviving
/// mesh is silently skipped, so callers must take the victim set from
/// the returned `excluded`, not from their request.
pub fn targeted_drop(
    topo: &Arc<Topology>,
    victims: &[NodeId],
    at: Time,
    tick_ns: Time,
) -> FaultScript {
    let mut live = LiveLinks::new(topo);
    let mut events = Vec::new();
    let mut excluded: Vec<NodeId> = Vec::new();
    for &victim in victims {
        if excluded.contains(&victim) {
            continue;
        }
        let ins: Vec<LinkId> = topo.in_links(victim).to_vec();
        let outs: Vec<LinkId> = topo.out_links(victim).to_vec();
        let all: Vec<LinkId> = ins.iter().chain(outs.iter()).copied().collect();
        if live.fail_all_if_safe(topo, &all, &[&excluded[..], &[victim]].concat()) {
            excluded.push(victim);
            for &l in &ins {
                events.push(FaultEvent { at, kind: FaultKind::Fail(l) });
            }
            for &l in &outs {
                events.push(FaultEvent { at: at + 2 * tick_ns, kind: FaultKind::Fail(l) });
            }
        }
    }
    finish(events, FaultScript { events: vec![], excluded, cut: None, hotspot: None })
}

/// The reverse twin of `l` (every mesh link has one; a topology
/// invariant tested in `tests/properties.rs`).
pub fn reverse(topo: &Topology, l: LinkId) -> LinkId {
    let info = topo.link(l);
    topo.out_links(info.dst)
        .iter()
        .copied()
        .find(|&r| {
            let ri = topo.link(r);
            ri.dst == info.src && ri.span == info.span
        })
        .expect("mesh link without a reverse twin")
}

/// Is the mesh connected over live links, ignoring the deliberately
/// severed `skip` nodes? BFS from the lowest non-skipped node.
pub fn connected(topo: &Topology, failed: &[bool], skip: &[NodeId]) -> bool {
    let n = topo.node_count();
    let mut seen = vec![false; n];
    for &s in skip {
        seen[s.0 as usize] = true; // never enter severed nodes
    }
    let Some(start) = (0..n as u32).map(NodeId).find(|x| !skip.contains(x)) else {
        return true;
    };
    let mut stack = vec![start];
    seen[start.0 as usize] = true;
    let mut count = 1usize;
    while let Some(x) = stack.pop() {
        for &l in topo.out_links(x) {
            if failed[l.0 as usize] {
                continue;
            }
            let d = topo.link(l).dst;
            if !seen[d.0 as usize] {
                seen[d.0 as usize] = true;
                count += 1;
                stack.push(d);
            }
        }
    }
    count == n - skip.len()
}

/// Script-compile-time connectivity tracker: admits candidate faults
/// only while the mesh (minus deliberate victims) stays connected
/// under the *union* of everything admitted so far.
struct LiveLinks {
    failed: Vec<bool>,
    stamps: Vec<Time>,
}

impl LiveLinks {
    fn new(topo: &Topology) -> Self {
        LiveLinks { failed: vec![false; topo.link_count()], stamps: Vec::new() }
    }

    /// How many admitted faults carry stamp `at` (per-burst size cap).
    fn fail_count_since(&self, at: Time) -> usize {
        self.stamps.iter().filter(|&&s| s == at).count()
    }

    /// Fail `l` iff the mesh stays connected *and* `l`'s source keeps a
    /// live out-link; report whether it did. The out-degree guard
    /// matters because failures are per-direction: a node can stay
    /// BFS-reachable (live in-links) with every out-link dead, and a
    /// packet arriving there would have nowhere to go.
    fn fail_if_safe(&mut self, topo: &Topology, l: LinkId, stamp: Time) -> bool {
        if self.failed[l.0 as usize] {
            return false;
        }
        self.failed[l.0 as usize] = true;
        let src = topo.link(l).src;
        let src_alive = topo.out_links(src).iter().any(|&o| !self.failed[o.0 as usize]);
        if src_alive && connected(topo, &self.failed, &[]) {
            self.stamps.push(stamp);
            true
        } else {
            self.failed[l.0 as usize] = false;
            false
        }
    }

    /// Fail the whole set iff the mesh minus `skip` stays connected.
    fn fail_all_if_safe(&mut self, topo: &Topology, ls: &[LinkId], skip: &[NodeId]) -> bool {
        for &l in ls {
            self.failed[l.0 as usize] = true;
        }
        if connected(topo, &self.failed, skip) {
            true
        } else {
            for &l in ls {
                self.failed[l.0 as usize] = false;
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemPreset;

    #[test]
    fn scripts_are_pure_functions_of_their_inputs() {
        let topo = Arc::new(Topology::preset(SystemPreset::Inc3000));
        for sc in Scenario::ALL {
            let a = sc.script(&topo, 42, 30, 50_000);
            let b = sc.script(&topo, 42, 30, 50_000);
            assert_eq!(a.events, b.events, "{}", sc.name());
            assert_eq!(a.excluded, b.excluded, "{}", sc.name());
            assert_eq!(a.hotspot, b.hotspot, "{}", sc.name());
        }
    }

    #[test]
    fn scripted_storms_never_disconnect_the_mesh() {
        // Replay every scenario's fault timeline instant by instant and
        // assert live connectivity throughout — the property the
        // builders promise the router.
        for preset in [SystemPreset::Card, SystemPreset::Inc3000] {
            let topo = Arc::new(Topology::preset(preset));
            for sc in Scenario::ALL {
                for seed in [1u64, 7, 42] {
                    let s = sc.script(&topo, seed, 30, 50_000);
                    let mut failed = vec![false; topo.link_count()];
                    let skip = if sc == Scenario::Partition { None } else { Some(&s.excluded) };
                    let mut i = 0;
                    while i < s.events.len() {
                        let t = s.events[i].at;
                        while i < s.events.len() && s.events[i].at == t {
                            match s.events[i].kind {
                                FaultKind::Fail(l) => failed[l.0 as usize] = true,
                                FaultKind::Repair(l) => failed[l.0 as usize] = false,
                            }
                            i += 1;
                        }
                        if let Some(excl) = skip {
                            assert!(
                                connected(&topo, &failed, excl),
                                "{preset:?} {} seed {seed}: mesh disconnected at t={t}",
                                sc.name()
                            );
                            // Directed-failure trap: no surviving node
                            // may ever be left without a live out-link.
                            for x in topo.nodes().filter(|x| !excl.contains(x)) {
                                assert!(
                                    topo.out_links(x)
                                        .iter()
                                        .any(|&l| !failed[l.0 as usize]),
                                    "{preset:?} {} seed {seed}: {x:?} has no live \
                                     out-link at t={t}",
                                    sc.name()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn partition_script_cuts_exactly_the_plane_and_heals() {
        let topo = Arc::new(Topology::preset(SystemPreset::Card));
        let s = Scenario::Partition.script(&topo, 5, 30, 50_000);
        let (side, heal_at) = s.cut.clone().expect("partition publishes its cut");
        assert!(s.events.iter().all(|e| match e.kind {
            FaultKind::Fail(l) | FaultKind::Repair(l) => {
                let li = topo.link(l);
                side[li.src.0 as usize] != side[li.dst.0 as usize]
            }
        }));
        let fails = s.events.iter().filter(|e| matches!(e.kind, FaultKind::Fail(_))).count();
        let repairs = s.events.iter().filter(|e| matches!(e.kind, FaultKind::Repair(_))).count();
        assert_eq!(fails, repairs, "every cut link heals");
        assert!(fails > 0);
        assert!(s.events.iter().all(|e| e.at <= heal_at));
        // Both sides non-empty.
        assert!(side.iter().any(|&s| s == 0) && side.iter().any(|&s| s == 1));
    }

    #[test]
    fn drop_script_severs_victims_in_two_phases() {
        let topo = Arc::new(Topology::preset(SystemPreset::Inc3000));
        let s = Scenario::Drop.script(&topo, 9, 30, 50_000);
        assert!(!s.excluded.is_empty());
        for &v in &s.excluded {
            let in_t: Vec<Time> = s
                .events
                .iter()
                .filter(|e| matches!(e.kind, FaultKind::Fail(l) if topo.link(l).dst == v))
                .map(|e| e.at)
                .collect();
            let out_t: Vec<Time> = s
                .events
                .iter()
                .filter(|e| matches!(e.kind, FaultKind::Fail(l) if topo.link(l).src == v))
                .map(|e| e.at)
                .collect();
            assert_eq!(in_t.len(), topo.in_links(v).len());
            assert_eq!(out_t.len(), topo.out_links(v).len());
            let in_max = in_t.iter().max().unwrap();
            let out_min = out_t.iter().min().unwrap();
            assert!(in_max < out_min, "inbound severed strictly before outbound");
        }
    }

    #[test]
    fn targeted_drop_severs_the_requested_victims_in_two_phases() {
        let topo = Arc::new(Topology::preset(SystemPreset::Card));
        let victims = [NodeId(5), NodeId(13)];
        let s = targeted_drop(&topo, &victims, 200_000, 50_000);
        assert_eq!(s.excluded, victims.to_vec(), "Card survives losing two nodes");
        for &v in &s.excluded {
            let in_t: Vec<Time> = s
                .events
                .iter()
                .filter(|e| matches!(e.kind, FaultKind::Fail(l) if topo.link(l).dst == v))
                .map(|e| e.at)
                .collect();
            let out_t: Vec<Time> = s
                .events
                .iter()
                .filter(|e| matches!(e.kind, FaultKind::Fail(l) if topo.link(l).src == v))
                .map(|e| e.at)
                .collect();
            assert_eq!(in_t.len(), topo.in_links(v).len());
            assert_eq!(out_t.len(), topo.out_links(v).len());
            assert!(in_t.iter().all(|&t| t == 200_000));
            assert!(out_t.iter().all(|&t| t == 300_000));
        }
        // Replayed, the survivors stay connected.
        let mut failed = vec![false; topo.link_count()];
        for e in &s.events {
            if let FaultKind::Fail(l) = e.kind {
                failed[l.0 as usize] = true;
            }
        }
        assert!(connected(&topo, &failed, &s.excluded));
    }

    #[test]
    fn events_are_tick_aligned_and_sorted() {
        let topo = Arc::new(Topology::preset(SystemPreset::Inc3000));
        let tick = 50_000;
        for sc in Scenario::ALL {
            let s = sc.script(&topo, 3, 30, tick);
            assert!(s.events.windows(2).all(|w| w[0].at <= w[1].at), "{}", sc.name());
            assert!(s.events.iter().all(|e| e.at % tick == 0), "{}", sc.name());
        }
    }
}
