//! Workload chaos: the *real* workloads — streamed learners, the ring
//! all-reduce, distributed MCTS — run over the reliable transport while
//! a scripted fault scenario tears at the fabric (EXPERIMENTS.md E14).
//!
//! Where the background-traffic harness ([`super`]) measures the
//! *fabric* (latency, convergence, backpressure), this one grades
//! end-to-end application guarantees: the workload completes, its
//! answer is correct for the surviving membership, the recovery
//! machinery actually engaged (retransmits, failure declarations), and
//! it never misfired (no false peer deaths under storm or partition).
//! Runs are byte-identical across engines and shard counts
//! (`tests/sharded_differential.rs`).
//!
//! Scenario → workload contract:
//! * `storm` — link bursts reroute traffic; the run must stay lossless
//!   with **zero** failure declarations.
//! * `partition` — the mesh splits for ~⅓ of the run; cross-cut flows
//!   stall, retransmit and recover after the heal. The per-scenario
//!   [`ReliableParams`] keep the liveness threshold above the cut span,
//!   so a temporarily unreachable peer is never declared dead.
//! * `drop` — a scripted *participant* dies two-phase mid-run
//!   ([`targeted_drop`]); the survivors must detect it, re-place or
//!   shrink, and still finish with the right answer.

use std::sync::Arc;

use crate::channels::endpoint::CommMode;
use crate::channels::reliable::ReliableParams;
use crate::config::SystemConfig;
use crate::coordinator::collectives::RingAllreduce;
use crate::network::{Fabric, ShardableApp};
use crate::sim::Time;
use crate::topology::{NodeId, Topology};
use crate::workload::learners::{LearnerConfig, Learners, SendStrategy};
use crate::workload::mcts::{DistributedMcts, Game};

use super::scenario::{targeted_drop, FaultKind, FaultScript, Scenario};

/// Which workload rides the storm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosWorkload {
    Learners,
    Allreduce,
    Mcts,
}

impl ChaosWorkload {
    pub const ALL: [ChaosWorkload; 3] =
        [ChaosWorkload::Learners, ChaosWorkload::Allreduce, ChaosWorkload::Mcts];

    pub fn parse(s: &str) -> Option<ChaosWorkload> {
        match s.to_ascii_lowercase().as_str() {
            "learners" => Some(ChaosWorkload::Learners),
            "allreduce" => Some(ChaosWorkload::Allreduce),
            "mcts" => Some(ChaosWorkload::Mcts),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ChaosWorkload::Learners => "learners",
            ChaosWorkload::Allreduce => "allreduce",
            ChaosWorkload::Mcts => "mcts",
        }
    }
}

/// The scenarios a workload runs under. `hotspot` and `flap` stay
/// background-traffic-only: the hotspot sink's drain cadence and the
/// flappers' NIC-local droughts don't compose with a workload's own
/// schedule.
pub const WORKLOAD_SCENARIOS: [Scenario; 3] =
    [Scenario::Storm, Scenario::Partition, Scenario::Drop];

/// One workload-chaos experiment's identity: everything that shapes
/// placement, schedule, faults or transport tuning. Equal configs on a
/// fresh fabric produce byte-identical [`WorkloadReport`]s.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadChaosConfig {
    pub workload: ChaosWorkload,
    pub scenario: Scenario,
    pub seed: u64,
    /// Fault/tick grid the scenario script is staggered over.
    pub ticks: u64,
    pub tick_ns: Time,
}

impl WorkloadChaosConfig {
    pub fn new(workload: ChaosWorkload, scenario: Scenario, seed: u64) -> Self {
        assert!(
            WORKLOAD_SCENARIOS.contains(&scenario),
            "workload chaos supports storm|partition|drop, not {}",
            scenario.name()
        );
        WorkloadChaosConfig { workload, scenario, seed, ticks: 24, tick_ns: 50_000 }
    }

    /// Per-scenario transport tuning (recorded with the seed — part of
    /// the run's identity, EXPERIMENTS.md §Reliable transport). A
    /// partition must not look like a death: its liveness threshold
    /// exceeds the cut span (~⅓ of the run) with margin, and the
    /// default retry budget's cumulative backoff (~9.5 ms) dwarfs the
    /// outage. The drop scenario tightens both so detection lands well
    /// inside the run.
    pub fn reliable_params(&self) -> ReliableParams {
        match self.scenario {
            Scenario::Partition => {
                ReliableParams { liveness_ns: 2_500_000, ..ReliableParams::default() }
            }
            Scenario::Drop => ReliableParams {
                rto_ns: 30_000,
                max_retries: 4,
                heartbeat_ns: 50_000,
                liveness_ns: 300_000,
                ..ReliableParams::default()
            },
            _ => ReliableParams::default(),
        }
    }

    /// The system a workload-chaos run wants: Card preset with
    /// `drop_unroutable` — node deaths and partition cuts strand
    /// packets, and the transport (not a panic) is the recovery path.
    pub fn system_config(&self) -> SystemConfig {
        let mut cfg = SystemConfig::card();
        cfg.drop_unroutable = true;
        cfg
    }
}

/// The graded outcome of one workload-chaos run; field-for-field
/// deterministic, so differential tests compare engines with `==`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadReport {
    pub workload: &'static str,
    pub scenario: &'static str,
    pub seed: u64,
    pub shards: u32,
    /// The workload ran to completion on the surviving membership.
    pub completed: bool,
    /// The completed answer was right — per-workload: every live record
    /// delivered exactly once (learners), every survivor holding the
    /// survivors' sum (all-reduce), every rollout accounted for (MCTS) —
    /// with exactly the scripted membership change and no other.
    pub correct: bool,
    /// Work units expected / observed (records, surviving ranks,
    /// rollouts).
    pub expected: u64,
    pub delivered: u64,
    /// Records re-placed onto a live peer after a death (learners; the
    /// other workloads re-place internally).
    pub replaced: u64,
    pub elapsed_ns: Time,
    pub retransmits: u64,
    pub acks: u64,
    pub duplicates_dropped: u64,
    pub peers_declared_down: u64,
    pub dropped: u64,
    /// The scenario is supposed to force retransmission.
    pub expect_retransmits: bool,
    /// The scenario is supposed to kill a participant.
    pub expect_peers_down: bool,
}

impl WorkloadReport {
    pub fn violations(&self) -> Vec<String> {
        let mut v = Vec::new();
        if !self.completed {
            v.push(format!(
                "workload did not complete ({} of {} units)",
                self.delivered, self.expected
            ));
        }
        if self.completed && !self.correct {
            v.push("workload completed with a wrong answer or membership".into());
        }
        if self.acks == 0 {
            v.push("reliable transport saw no acks (workload bypassed it?)".into());
        }
        if self.expect_retransmits && self.retransmits == 0 {
            v.push("scenario scripted loss but nothing was retransmitted".into());
        }
        if self.expect_peers_down && self.peers_declared_down == 0 {
            v.push("scripted death was never detected".into());
        }
        if !self.expect_peers_down && self.peers_declared_down > 0 {
            v.push(format!(
                "false failure detection: {} peer(s) declared down",
                self.peers_declared_down
            ));
        }
        v
    }

    pub fn passed(&self) -> bool {
        self.violations().is_empty()
    }

    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"workload\": \"{}\",\n  \"scenario\": \"{}\",\n  \"seed\": {},\n  \
             \"shards\": {},\n  \"completed\": {},\n  \"correct\": {},\n  \
             \"expected\": {},\n  \"delivered\": {},\n  \"replaced\": {},\n  \
             \"elapsed_ns\": {},\n  \"retransmits\": {},\n  \"acks\": {},\n  \
             \"duplicates_dropped\": {},\n  \"peers_declared_down\": {},\n  \
             \"dropped\": {},\n  \"violations\": [{}],\n  \"passed\": {}\n}}",
            self.workload,
            self.scenario,
            self.seed,
            self.shards,
            self.completed,
            self.correct,
            self.expected,
            self.delivered,
            self.replaced,
            self.elapsed_ns,
            self.retransmits,
            self.acks,
            self.duplicates_dropped,
            self.peers_declared_down,
            self.dropped,
            self.violations()
                .iter()
                .map(|v| format!("\"{v}\""))
                .collect::<Vec<_>>()
                .join(", "),
            self.passed(),
        )
    }
}

/// The scenario's fault script, with the drop victim chosen *by the
/// workload* (its placement decides who dies) instead of seeded.
fn script_for(
    cfg: &WorkloadChaosConfig,
    topo: &Arc<Topology>,
    victim: NodeId,
    death_tick: u64,
) -> FaultScript {
    match cfg.scenario {
        Scenario::Drop => {
            let s = targeted_drop(topo, &[victim], death_tick * cfg.tick_ns, cfg.tick_ns);
            assert_eq!(s.excluded, vec![victim], "drop victim must be severable");
            s
        }
        sc => sc.script(topo, cfg.seed, cfg.ticks, cfg.tick_ns),
    }
}

/// Apply the script at tick boundaries (driver context: both engines'
/// clocks sit exactly on the boundary) while the workload runs in
/// `run_until` windows. `on_tick` fires right after the boundary's
/// faults land — production scheduling goes there. The final
/// run-to-quiescence drains retransmit tails, re-placements and the
/// liveness watches' bounded horizon.
fn drive<F: Fabric, A: ShardableApp>(
    net: &mut F,
    app: &mut A,
    script: &FaultScript,
    ticks: u64,
    tick_ns: Time,
    mut on_tick: impl FnMut(&mut F, u64),
) {
    let run_ticks = ticks.max(script.horizon() / tick_ns + 2);
    let mut next = 0usize;
    for tick in 0..run_ticks {
        let t0 = tick * tick_ns;
        while next < script.events.len() && script.events[next].at <= t0 {
            match script.events[next].kind {
                FaultKind::Fail(l) => net.fail_link(l),
                FaultKind::Repair(l) => net.repair_link(l),
            }
            next += 1;
        }
        on_tick(net, tick);
        net.run_until(app, t0 + tick_ns);
    }
    net.run(app);
}

/// Run one workload-chaos experiment on a **fresh** fabric (clock 0,
/// empty metrics; `drop_unroutable` must be set — see
/// [`WorkloadChaosConfig::system_config`]) and grade it.
pub fn run_workload<F: Fabric>(
    net: &mut F,
    cfg: &WorkloadChaosConfig,
    shards: u32,
) -> WorkloadReport {
    assert!(
        net.config().drop_unroutable,
        "workload chaos needs drop_unroutable (WorkloadChaosConfig::system_config)"
    );
    let topo = net.topo().clone();
    let params = cfg.reliable_params();
    // Liveness watches outlive the scripted window with slack, so a
    // death after the last scheduled send still gets detected.
    let watch_until = cfg.ticks * cfg.tick_ns + 4_000_000;
    let (completed, correct, expected, delivered, replaced) = match cfg.workload {
        ChaosWorkload::Learners => run_learners(net, cfg, &topo, params),
        ChaosWorkload::Allreduce => run_allreduce(net, cfg, &topo, params, watch_until),
        ChaosWorkload::Mcts => run_mcts(net, cfg, &topo, params, watch_until),
    };
    let m = net.metrics();
    WorkloadReport {
        workload: cfg.workload.name(),
        scenario: cfg.scenario.name(),
        seed: cfg.seed,
        shards,
        completed,
        correct,
        expected,
        delivered,
        replaced,
        elapsed_ns: net.now(),
        retransmits: m.retransmits,
        acks: m.acks,
        duplicates_dropped: m.duplicates_dropped,
        peers_declared_down: m.peers_declared_down,
        dropped: m.dropped,
        // The all-reduce can finish before a partition's cut lands, so
        // only the continuously-producing workloads must retransmit
        // there; a drop always strands something.
        expect_retransmits: matches!(
            (cfg.scenario, cfg.workload),
            (Scenario::Drop, _)
                | (Scenario::Partition, ChaosWorkload::Learners | ChaosWorkload::Mcts)
        ),
        expect_peers_down: cfg.scenario == Scenario::Drop,
    }
}

/// Streamed learners (E8's grid) producing a step per tick; under
/// `drop`, learner 3 dies at tick 8 and its senders re-place.
fn run_learners<F: Fabric>(
    net: &mut F,
    cfg: &WorkloadChaosConfig,
    topo: &Arc<Topology>,
    params: ReliableParams,
) -> (bool, bool, u64, u64, u64) {
    let lcfg = LearnerConfig {
        learners: 8,
        outputs_per_step: 8,
        record_bytes: 64,
        compute_ns: cfg.tick_ns,
        steps: 20,
        // Stride 2 spreads the grid across x-planes (Card: x = id mod
        // 3), so a partition cut always separates some learner pairs.
        stride: 2,
        comm: CommMode::Postmaster { queue: 0 },
        reliable: Some(params),
    };
    let grid = Learners::setup(net, lcfg);
    let victim_idx = 3;
    let death_tick = 8u64;
    let script = script_for(cfg, topo, grid.nodes[victim_idx], death_tick);
    let mut app = grid.app_for(0);
    let mut scheduled = 0u64;
    drive(net, &mut app, &script, cfg.ticks, cfg.tick_ns, |net, tick| {
        if tick >= lcfg.steps as u64 {
            return;
        }
        // A dead learner stops producing (driver knowledge: the script
        // says when the node crashes). It stops two ticks *early* so
        // the acks for its final step return before its inbound links
        // die — otherwise its delivered-but-unacked records would be
        // re-placed as duplicates, which no protocol can distinguish.
        let skip: &[NodeId] = if cfg.scenario == Scenario::Drop && tick + 2 > death_tick {
            &script.excluded
        } else {
            &[]
        };
        scheduled +=
            grid.schedule_step_at(net, tick * cfg.tick_ns, SendStrategy::Streamed, skip);
    });
    app.expected = scheduled;
    // Exactly-once: every scheduled record lands precisely once — the
    // two-phase death makes unacked ⟺ undelivered, so re-placement
    // neither loses nor duplicates.
    let completed = app.received == app.expected;
    let correct = match cfg.scenario {
        Scenario::Drop => completed && app.dead[victim_idx] && app.replaced > 0,
        _ => completed && !app.any_dead() && app.replaced == 0,
    };
    (completed, correct, app.expected, app.received, app.replaced)
}

/// Ring all-reduce (1 MiB over 4 ranks straddling every cut plane);
/// under `drop`, rank 2 dies at tick 1 and the ring must shrink.
fn run_allreduce<F: Fabric>(
    net: &mut F,
    cfg: &WorkloadChaosConfig,
    topo: &Arc<Topology>,
    params: ReliableParams,
    watch_until: Time,
) -> (bool, bool, u64, u64, u64) {
    // Card corners: x = 0, 2, 0, 2 — on both sides of any x-plane cut.
    let ranks = vec![NodeId(0), NodeId(2), NodeId(24), NodeId(26)];
    let victim_idx = 2usize;
    let mut ar = RingAllreduce::with_mode_reliable(
        net,
        ranks.clone(),
        1 << 20,
        CommMode::Postmaster { queue: 0 },
        params,
        watch_until,
    );
    let script = script_for(cfg, topo, ranks[victim_idx], 1);
    ar.kickoff(net);
    drive(net, &mut ar, &script, cfg.ticks, cfg.tick_ns, |_, _| {});
    let dead = ar.dead_union();
    let completed = ar.is_complete();
    let want = ar.expected_sum();
    let survivors: Vec<usize> =
        (0..ranks.len()).filter(|&i| dead & (1 << i) == 0).collect();
    let delivered = survivors.iter().filter(|&&i| ar.reduced(i) == want).count() as u64;
    let expected = survivors.len() as u64;
    let membership_right = match cfg.scenario {
        Scenario::Drop => dead == 1 << victim_idx,
        _ => dead == 0,
    };
    let correct = completed && membership_right && delivered == expected;
    (completed, correct, expected, delivered, 0)
}

/// Distributed MCTS (240 rollouts, 6 workers around a leader); under
/// `drop`, worker 2 dies at tick 8 and the leader re-dispatches.
fn run_mcts<F: Fabric>(
    net: &mut F,
    cfg: &WorkloadChaosConfig,
    topo: &Arc<Topology>,
    params: ReliableParams,
    watch_until: Time,
) -> (bool, bool, u64, u64, u64) {
    let leader = NodeId(0);
    let workers: Vec<NodeId> = (1..=6).map(NodeId).collect();
    let victim_idx = 2usize;
    let rollouts = 240u64;
    let game = Game { depth: 6, branching: 3, seed: 42 };
    let mut mcts = DistributedMcts::with_mode_reliable(
        net,
        game,
        leader,
        workers.clone(),
        CommMode::Postmaster { queue: 1 },
        params,
        watch_until,
    );
    let script = script_for(cfg, topo, workers[victim_idx], 8);
    mcts.kickoff(net, rollouts);
    drive(net, &mut mcts, &script, cfg.ticks, cfg.tick_ns, |_, _| {});
    let completed = mcts.is_complete();
    let delivered = mcts.rollouts_done;
    let deaths = mcts.dead_workers().iter().filter(|&&d| d).count();
    let membership_right = match cfg.scenario {
        Scenario::Drop => deaths == 1 && mcts.dead_workers()[victim_idx],
        _ => deaths == 0,
    };
    let correct = completed && delivered == rollouts && membership_right;
    (completed, correct, rollouts, delivered, 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::Network;

    fn run_one(w: ChaosWorkload, sc: Scenario, seed: u64) -> WorkloadReport {
        let cfg = WorkloadChaosConfig::new(w, sc, seed);
        let mut net = Network::new(cfg.system_config());
        run_workload(&mut net, &cfg, 1)
    }

    #[test]
    fn every_workload_survives_every_scenario() {
        for w in ChaosWorkload::ALL {
            for sc in WORKLOAD_SCENARIOS {
                let r = run_one(w, sc, 7);
                assert!(
                    r.passed(),
                    "{}/{}: {:?}",
                    r.workload,
                    r.scenario,
                    r.violations()
                );
            }
        }
    }

    #[test]
    fn drop_scenario_engages_the_recovery_machinery() {
        let r = run_one(ChaosWorkload::Learners, Scenario::Drop, 3);
        assert!(r.peers_declared_down > 0, "the death was never detected");
        assert!(r.retransmits > 0, "stranded records were never retried");
        assert!(r.replaced > 0, "undelivered records were never re-placed");
        assert_eq!(r.delivered, r.expected, "exactly-once violated");
    }

    #[test]
    fn allreduce_shrinks_instead_of_hanging() {
        let r = run_one(ChaosWorkload::Allreduce, Scenario::Drop, 11);
        assert!(r.passed(), "{:?}", r.violations());
        assert_eq!(r.expected, 3, "the ring shrank to the three survivors");
        assert_eq!(r.delivered, 3, "every survivor holds the survivors' sum");
    }

    #[test]
    fn reports_are_pure_functions_of_their_config() {
        let a = run_one(ChaosWorkload::Mcts, Scenario::Partition, 9);
        let b = run_one(ChaosWorkload::Mcts, Scenario::Partition, 9);
        assert_eq!(a, b, "workload chaos is not a pure function of its seed");
    }

    #[test]
    fn report_json_carries_the_verdict() {
        let r = run_one(ChaosWorkload::Allreduce, Scenario::Storm, 5);
        let json = r.to_json();
        assert!(json.contains("\"workload\": \"allreduce\""), "{json}");
        assert!(json.contains("\"passed\": true"), "{json}");
    }
}
