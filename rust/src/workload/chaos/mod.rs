//! Chaos & resilience harness (EXPERIMENTS.md E13).
//!
//! `repro chaos --scenario storm|flap|partition|drop|hotspot|loss --seed S`
//! composes a deterministic fault script ([`scenario`]) with a seeded
//! background traffic schedule over any preset, any communication mode
//! and either engine, and grades the outcome against per-scenario SLOs:
//! delivered throughput, p50/p99 packet latency, worst-case reroute
//! convergence, drop and stall counts.
//!
//! # Determinism (the whole point)
//!
//! Every input to the run — fault script, traffic pair set, per-tick
//! send instants, payloads — is a pure function of
//! `(preset, scenario, seed, config)`. Faults are applied at tick
//! boundaries in *driver context* (between [`Fabric::run_until`]
//! windows), where both engines' clocks sit on exactly the same
//! instant, so the serial and sharded engines replay byte-identical
//! experiments: same delivery trace, same [`Metrics::fabric_view`],
//! same [`SloReport`] (`tests/sharded_differential.rs`). A chaos run is
//! therefore *reproducible evidence*: quote `(scenario, seed)` and
//! anyone can replay the identical failure storm.
//!
//! # What convergence means here
//!
//! [`Metrics::reroute_convergence_ns`] is measured at the workload
//! layer: for every scripted fault instant, the gap until the *first
//! message delivery anywhere in the fabric* after it. It is a liveness
//! figure — "after a fault, how long until the fabric demonstrably
//! delivers again" — not a per-flow path-repair time. The app records
//! first-delivery times per fault with a monotone covered-pointer
//! (cheap: O(1) amortized per delivery), partitions reduce by
//! elementwise minimum, and the harness folds the worst case into the
//! metrics block via [`Fabric::record_reroute_convergence`], inside the
//! byte-identity contract.
//!
//! # Backpressure coupling
//!
//! The app deliberately leaves messages *unconsumed* (`on_message`
//! returns `false`), so every delivery lands in the endpoint's bounded
//! receive buffer ([`crate::channels::ChannelCaps::rx_capacity`]) and
//! the per-mode full-buffer semantics engage for real: the `hotspot`
//! scenario aims all senders at one sink and drains it only every few
//! ticks, so a small `rx_capacity` (see
//! [`ChaosConfig::suggested_rx_capacity`]) produces non-zero
//! [`Metrics::dropped`] (Ethernet) or [`Metrics::stalled_ns`]
//! (Postmaster / Bridge-FIFO) — asserted by the `expect_backpressure`
//! SLO.

pub mod scenario;
pub mod workloads;

use std::sync::Arc;

use crate::channels::endpoint::{CommMode, Endpoint, Message};
use crate::channels::ethernet::RxMode;
use crate::metrics::LatencyHist;
use crate::network::{App, Fabric, Network, ShardableApp};
use crate::sim::Time;
use crate::topology::NodeId;
use crate::util::{mix64, SplitMix64};

pub use scenario::{FaultEvent, FaultKind, FaultScript, Scenario};

/// Per-scenario service-level objectives the run is graded against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SloSpec {
    /// Worst acceptable reroute convergence (ns): first delivery
    /// *anywhere* after each fault.
    pub max_convergence_ns: Time,
    /// Worst acceptable per-flow convergence (ns): for every traffic
    /// pair, the first delivery *on that pair* after each fault. Much
    /// looser than the global figure — one healthy flow cannot mask a
    /// stalled one, but a flow that is deliberately silent (cross-cut
    /// during a partition) legitimately takes until the heal.
    pub max_pair_convergence_ns: Time,
    /// Minimum app-level delivery ratio, in permille (1000 = every
    /// message the harness sent was seen by the app).
    pub min_delivery_permille: u32,
    /// Worst acceptable p99 end-to-end packet latency (ns).
    pub max_p99_ns: Time,
    /// The scenario is *supposed* to trip the bounded receive buffers:
    /// pass requires `dropped > 0 || stalled_ns > 0`.
    pub expect_backpressure: bool,
}

impl SloSpec {
    /// Default objectives for `scenario` on a `ticks` × `tick_ns` grid:
    /// the fabric must demonstrably deliver within 4 ticks of any fault
    /// (and every individual flow within 8 — except under a partition,
    /// where cross-cut flows legitimately wait out the cut, roughly a
    /// third of the run), lose nothing at app level, and keep p99 under
    /// 2^18 ns.
    pub fn default_for(sc: Scenario, ticks: u64, tick_ns: Time) -> Self {
        let span = ticks.max(8) * tick_ns;
        SloSpec {
            max_convergence_ns: 4 * tick_ns,
            max_pair_convergence_ns: if sc == Scenario::Partition {
                span / 3 + 8 * tick_ns
            } else {
                8 * tick_ns
            },
            // Under seeded packet loss the best-effort channel loses
            // what the hash says it loses: grade delivery at ≥ 90%
            // instead of exactly-once (a ~1% per-hop rate compounds
            // over multi-hop routes to a few percent of messages).
            min_delivery_permille: if sc == Scenario::Loss { 900 } else { 1000 },
            max_p99_ns: 1 << 18,
            expect_backpressure: sc == Scenario::Hotspot,
        }
    }
}

/// Chaos run parameters. Everything that shapes traffic or faults is
/// part of the experiment's identity — two runs with equal configs and
/// seeds are byte-identical.
#[derive(Debug, Clone, Copy)]
pub struct ChaosConfig {
    pub scenario: Scenario,
    pub seed: u64,
    /// The virtual channel background traffic rides.
    pub comm: CommMode,
    /// Traffic window in ticks (faults are staggered inside it).
    pub ticks: u64,
    /// Tick width, ns: the fault-application and drain cadence.
    pub tick_ns: Time,
    /// Seeded (src, dst) pairs sending each tick (hotspot uses
    /// [`ChaosConfig::HOTSPOT_SENDERS`] instead).
    pub pairs: usize,
    /// Messages per pair per tick, spread inside the tick.
    pub msgs_per_tick: usize,
    pub payload_bytes: usize,
    /// Hotspot only: the sink is drained every this many ticks (every
    /// tick for the other scenarios), letting its inbox actually fill.
    pub drain_every: u64,
    pub slo: SloSpec,
}

impl ChaosConfig {
    /// Sender pairs during `hotspot` (kept small so the sink's backlog
    /// stays under the runaway-backlog debug assertion while still
    /// overflowing a small `rx_capacity`).
    pub const HOTSPOT_SENDERS: usize = 4;

    pub fn new(scenario: Scenario, seed: u64) -> Self {
        let tick_ns = 50_000;
        ChaosConfig {
            scenario,
            seed,
            // Seeded loss runs over the best-effort channel: dropping a
            // guaranteed-mode packet (data or credit return) would
            // stall the Postmaster protocol rather than lose a message,
            // which is a different experiment.
            comm: if scenario == Scenario::Loss {
                CommMode::Ethernet { rx: RxMode::Interrupt }
            } else {
                CommMode::Postmaster { queue: 0 }
            },
            ticks: 30,
            tick_ns,
            pairs: 24,
            msgs_per_tick: 2,
            payload_bytes: 64,
            drain_every: 4,
            slo: SloSpec::default_for(scenario, 30, tick_ns),
        }
    }

    /// The receive-buffer bound that makes this scenario interesting:
    /// tiny for `hotspot` (so the sink overflows), the system default
    /// otherwise. Drivers apply this to `SystemConfig::rx_capacity`
    /// before building the engines.
    pub fn suggested_rx_capacity(&self) -> u32 {
        if self.scenario == Scenario::Hotspot {
            8
        } else {
            65_536
        }
    }
}

/// The background-traffic app: counts app-level deliveries and records
/// **per-flow** per-fault first-delivery times — for every traffic
/// pair, its own monotone covered-pointer over the fault instants (so a
/// healthy flow cannot mask a stalled one; see the module docs).
/// Messages are left unconsumed so the bounded receive buffers see
/// every delivery.
#[derive(Clone)]
pub struct ChaosApp {
    /// Distinct scripted fault instants, ascending (shared, immutable).
    fault_at: Arc<Vec<Time>>,
    /// The traffic pair set (shared, immutable); a delivery is mapped
    /// to its pair by `(msg.from, ep.node)`.
    pairs: Arc<Vec<(NodeId, NodeId)>>,
    /// Per pair: first delivery observed at or after each fault
    /// instant, with its monotone covered-pointer.
    first_after: Vec<Vec<Option<Time>>>,
    covered: Vec<usize>,
    pub received: u64,
    pub bytes: u64,
}

impl ChaosApp {
    pub fn new(fault_at: Arc<Vec<Time>>, pairs: Arc<Vec<(NodeId, NodeId)>>) -> Self {
        let n = fault_at.len();
        let p = pairs.len();
        ChaosApp {
            fault_at,
            pairs,
            first_after: vec![vec![None; n]; p],
            covered: vec![0; p],
            received: 0,
            bytes: 0,
        }
    }

    /// Per-pair worst-case gap between a fault and the first delivery
    /// on that pair after it; faults with no delivery observed count up
    /// to `end` (both engines finish on the same clock, so this stays
    /// byte-identical). One entry per traffic pair.
    pub fn pair_convergence_ns(&self, end: Time) -> Vec<Time> {
        self.first_after
            .iter()
            .map(|per_fault| {
                self.fault_at
                    .iter()
                    .zip(per_fault)
                    .map(|(&at, first)| first.unwrap_or(end).saturating_sub(at))
                    .max()
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Global convergence: first delivery *anywhere* after each fault,
    /// worst case over faults. Derived exactly from the per-pair data
    /// (elementwise minimum over pairs), since every delivery belongs
    /// to a pair.
    pub fn convergence_ns(&self, end: Time) -> Time {
        (0..self.fault_at.len())
            .map(|f| {
                let first = self
                    .first_after
                    .iter()
                    .filter_map(|per_fault| per_fault[f])
                    .min()
                    .unwrap_or(end);
                first.saturating_sub(self.fault_at[f])
            })
            .max()
            .unwrap_or(0)
    }
}

impl App for ChaosApp {
    fn on_message(&mut self, net: &mut Network, ep: Endpoint, msg: &Message) -> bool {
        self.received += 1;
        self.bytes += msg.data.len() as u64;
        if let Some(p) = self.pairs.iter().position(|&(s, d)| s == msg.from && d == ep.node) {
            let now = net.now();
            while self.covered[p] < self.fault_at.len() && self.fault_at[self.covered[p]] <= now
            {
                self.first_after[p][self.covered[p]] = Some(now);
                self.covered[p] += 1;
            }
        }
        // Not consumed: the message proceeds into the endpoint's
        // bounded inbox, so backpressure semantics stay live.
        false
    }
}

impl ShardableApp for ChaosApp {
    fn partition(&self, _shard: u32, _owner: &[u32]) -> Self {
        ChaosApp::new(self.fault_at.clone(), self.pairs.clone())
    }

    fn reduce(&mut self, part: Self) {
        self.received += part.received;
        self.bytes += part.bytes;
        for (p, theirs) in part.first_after.into_iter().enumerate() {
            for (mine, other) in self.first_after[p].iter_mut().zip(theirs) {
                *mine = match (*mine, other) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
            }
            self.covered[p] = self.first_after[p].iter().take_while(|f| f.is_some()).count();
        }
    }
}

/// The graded outcome of one chaos run. Every field is deterministic,
/// so differential tests compare two engines' reports with `==`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SloReport {
    pub scenario: &'static str,
    pub seed: u64,
    pub shards: u32,
    /// Messages the harness scheduled (after cut/exclusion filtering).
    pub sent: u64,
    /// App-level deliveries observed.
    pub delivered: u64,
    pub bytes_delivered: u64,
    /// Final virtual clock (the run starts at 0 on a fresh fabric).
    pub elapsed_ns: Time,
    pub p50_ns: Time,
    pub p99_ns: Time,
    pub convergence_ns: Time,
    /// Worst per-flow convergence: the slowest (src, dst) pair's worst
    /// fault-to-first-delivery gap.
    pub worst_pair_convergence_ns: Time,
    /// p99 across the pairs' convergence figures.
    pub p99_pair_convergence_ns: Time,
    pub dropped: u64,
    pub stalled_ns: u64,
    pub slo: SloSpec,
}

impl SloReport {
    /// Delivered messages per virtual second.
    pub fn throughput_msgs_per_s(&self) -> f64 {
        if self.elapsed_ns == 0 {
            0.0
        } else {
            self.delivered as f64 * 1e9 / self.elapsed_ns as f64
        }
    }

    /// All SLO violations, empty when the run passes.
    pub fn violations(&self) -> Vec<String> {
        let mut v = Vec::new();
        if self.convergence_ns > self.slo.max_convergence_ns {
            v.push(format!(
                "reroute convergence {}ns exceeds SLO {}ns",
                self.convergence_ns, self.slo.max_convergence_ns
            ));
        }
        if self.delivered * 1000 < self.sent * self.slo.min_delivery_permille as u64 {
            v.push(format!(
                "delivered {}/{} below SLO {}permille",
                self.delivered, self.sent, self.slo.min_delivery_permille
            ));
        }
        if self.worst_pair_convergence_ns > self.slo.max_pair_convergence_ns {
            v.push(format!(
                "worst pair convergence {}ns exceeds SLO {}ns",
                self.worst_pair_convergence_ns, self.slo.max_pair_convergence_ns
            ));
        }
        if self.p99_ns > self.slo.max_p99_ns {
            v.push(format!("p99 {}ns exceeds SLO {}ns", self.p99_ns, self.slo.max_p99_ns));
        }
        if self.slo.expect_backpressure && self.dropped == 0 && self.stalled_ns == 0 {
            v.push("expected bounded-buffer backpressure, saw none".into());
        }
        v
    }

    pub fn passed(&self) -> bool {
        self.violations().is_empty()
    }

    /// Hand-built JSON (same idiom as `benches/`), one object per run —
    /// CI uploads this next to `BENCH_sim.json`.
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"scenario\": \"{}\",\n  \"seed\": {},\n  \"shards\": {},\n  \
             \"sent\": {},\n  \"delivered\": {},\n  \"bytes_delivered\": {},\n  \
             \"elapsed_ns\": {},\n  \"throughput_msgs_per_s\": {:.1},\n  \
             \"p50_ns\": {},\n  \"p99_ns\": {},\n  \"convergence_ns\": {},\n  \
             \"worst_pair_convergence_ns\": {},\n  \"p99_pair_convergence_ns\": {},\n  \
             \"dropped\": {},\n  \"stalled_ns\": {},\n  \
             \"slo\": {{\"max_convergence_ns\": {}, \"max_pair_convergence_ns\": {}, \
             \"min_delivery_permille\": {}, \
             \"max_p99_ns\": {}, \"expect_backpressure\": {}}},\n  \
             \"violations\": [{}],\n  \"passed\": {}\n}}\n",
            self.scenario,
            self.seed,
            self.shards,
            self.sent,
            self.delivered,
            self.bytes_delivered,
            self.elapsed_ns,
            self.throughput_msgs_per_s(),
            self.p50_ns,
            self.p99_ns,
            self.convergence_ns,
            self.worst_pair_convergence_ns,
            self.p99_pair_convergence_ns,
            self.dropped,
            self.stalled_ns,
            self.slo.max_convergence_ns,
            self.slo.max_pair_convergence_ns,
            self.slo.min_delivery_permille,
            self.slo.max_p99_ns,
            self.slo.expect_backpressure,
            self.violations()
                .iter()
                .map(|v| format!("\"{v}\""))
                .collect::<Vec<_>>()
                .join(", "),
            self.passed(),
        )
    }
}

/// Seeded traffic pair set: distinct `(src, dst)` pairs drawn from the
/// non-excluded nodes; during `hotspot` every destination is the sink.
fn traffic_pairs(
    nodes: &[NodeId],
    script: &FaultScript,
    cfg: &ChaosConfig,
) -> Vec<(NodeId, NodeId)> {
    let want = if script.hotspot.is_some() { ChaosConfig::HOTSPOT_SENDERS } else { cfg.pairs };
    let mut rng = SplitMix64::new(mix64(cfg.seed ^ 0xC4A0_5EED));
    let mut pairs: Vec<(NodeId, NodeId)> = Vec::with_capacity(want);
    for _ in 0..want * 32 {
        if pairs.len() == want {
            break;
        }
        let src = nodes[rng.gen_range(nodes.len())];
        let dst = match script.hotspot {
            Some(sink) => sink,
            None => nodes[rng.gen_range(nodes.len())],
        };
        if src != dst && !pairs.contains(&(src, dst)) {
            pairs.push((src, dst));
        }
    }
    assert!(pairs.len() >= 2, "could not seed a traffic pair set");
    pairs
}

/// Run the chaos scenario on either engine and grade it. The fabric
/// must be fresh (clock at 0, empty metrics): a chaos run *is* the
/// experiment, not a phase of one.
pub fn run<F: Fabric>(net: &mut F, cfg: &ChaosConfig, shards: u32) -> SloReport {
    let topo = net.topo().clone();
    let script = cfg.scenario.script(&topo, cfg.seed, cfg.ticks, cfg.tick_ns);
    let cut = script.cut.clone();

    // Candidate traffic nodes: everything but dropped victims and the
    // hotspot sink (the sink only receives).
    let nodes: Vec<NodeId> = topo
        .nodes()
        .filter(|n| !script.excluded.contains(n) && script.hotspot != Some(*n))
        .collect();
    let pairs = Arc::new(traffic_pairs(&nodes, &script, cfg));

    // One endpoint per participating node (sources send, destinations
    // are drained); pair-setup modes connect exactly the pairs used.
    let mut eps: std::collections::BTreeMap<u32, Endpoint> = std::collections::BTreeMap::new();
    for &(src, dst) in pairs.iter() {
        eps.entry(src.0).or_insert_with(|| net.open(src, cfg.comm));
        eps.entry(dst.0).or_insert_with(|| net.open(dst, cfg.comm));
    }
    if let Some(sink) = script.hotspot {
        eps.entry(sink.0).or_insert_with(|| net.open(sink, cfg.comm));
    }
    if net.caps(cfg.comm).pair_setup {
        for &(src, dst) in pairs.iter() {
            net.connect(&eps[&src.0], dst);
        }
    }

    let fault_at: Arc<Vec<Time>> = Arc::new({
        let mut ts: Vec<Time> = script.events.iter().map(|e| e.at).collect();
        ts.dedup(); // already sorted
        ts
    });
    let mut app = ChaosApp::new(fault_at.clone(), pairs.clone());

    // Run at least two ticks past the last scripted fault so every
    // fault has post-fault traffic to converge on.
    let last_event_tick = script.horizon() / cfg.tick_ns;
    let run_ticks = cfg.ticks.max(last_event_tick + 2);
    let dests: Vec<NodeId> = pairs.iter().map(|&(_, d)| d).collect();

    let mut sent = 0u64;
    let mut next_event = 0usize;
    let mut payload_rng = SplitMix64::new(mix64(cfg.seed ^ 0x7AFF_1C5E));
    for tick in 0..run_ticks {
        let t0 = tick * cfg.tick_ns;
        // Apply scripted faults due at this boundary (driver context:
        // both engines' clocks sit exactly on t0 here).
        let due_end = script.events[next_event..]
            .iter()
            .take_while(|e| e.at <= t0)
            .count()
            + next_event;
        // A partition cut must land on a quiet fabric: an in-flight
        // packet can *overshoot* the plane via a multi-span (3-hop)
        // link while still making minimal progress, and once every
        // cross link is down it would be stranded on the wrong side.
        // Quiescing first (identically on both engines) removes that
        // class; the connected scenarios need no guard — the router
        // detours in-flight packets around any connectivity-safe
        // script.
        if cut.is_some()
            && script.events[next_event..due_end]
                .iter()
                .any(|e| matches!(e.kind, FaultKind::Fail(_)))
        {
            net.run(&mut app);
        }
        for e in &script.events[next_event..due_end] {
            match e.kind {
                FaultKind::Fail(l) => net.fail_link(l),
                FaultKind::Repair(l) => net.repair_link(l),
            }
        }
        next_event = due_end;
        // Seeded sends, spread inside the tick. Cross-cut pairs stay
        // silent until the partition heals (conservatively from t=0,
        // so no cross-cut packet is ever in flight when the plane
        // drops).
        for (src, dst) in pairs.iter() {
            if let Some((side, heal_at)) = &cut {
                if side[src.0 as usize] != side[dst.0 as usize] && t0 < *heal_at {
                    continue;
                }
            }
            for k in 0..cfg.msgs_per_tick {
                let at = t0 + cfg.tick_ns * (k as Time + 1) / (cfg.msgs_per_tick as Time + 1);
                let fill = (payload_rng.next_u64() & 0xFF) as u8;
                net.send_at(at, &eps[&src.0], *dst, Message::new(vec![fill; cfg.payload_bytes]));
                sent += 1;
            }
        }
        net.run_until(&mut app, t0 + cfg.tick_ns);
        // Drain destinations — except the hotspot sink, which is only
        // drained every `drain_every` ticks so its bounded inbox fills.
        let drain_sink = script.hotspot.is_none() || (tick + 1) % cfg.drain_every == 0;
        for dst in &dests {
            if script.hotspot == Some(*dst) && !drain_sink {
                continue;
            }
            net.recv(&eps[&dst.0]);
        }
    }
    // Let in-flight traffic land, then drain everything.
    net.run(&mut app);
    for dst in &dests {
        net.recv(&eps[&dst.0]);
    }

    let end = net.now();
    let convergence = app.convergence_ns(end);
    net.record_reroute_convergence(convergence);

    // Per-pair convergence: how long until *each* (src, dst) pair saw
    // post-fault traffic again, graded at the worst pair and p99-pair.
    let mut pair_conv = app.pair_convergence_ns(end);
    pair_conv.sort_unstable();
    let worst_pair = pair_conv.last().copied().unwrap_or(0);
    let p99_pair = pair_conv[((pair_conv.len() * 99).div_ceil(100)).saturating_sub(1)];

    let m = net.metrics();
    let mut all = LatencyHist::new();
    for h in m.packet_latency.values() {
        all.merge(h);
    }
    SloReport {
        scenario: cfg.scenario.name(),
        seed: cfg.seed,
        shards,
        sent,
        delivered: app.received,
        bytes_delivered: app.bytes,
        elapsed_ns: end,
        p50_ns: all.percentile(0.50),
        p99_ns: all.percentile(0.99),
        convergence_ns: convergence,
        worst_pair_convergence_ns: worst_pair,
        p99_pair_convergence_ns: p99_pair,
        dropped: m.dropped,
        stalled_ns: m.stalled_ns,
        slo: cfg.slo,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ethernet::RxMode;
    use crate::config::{SystemConfig, SystemPreset};

    fn net_with_rx(preset: SystemPreset, rx: u32) -> Network {
        let mut cfg = SystemConfig::new(preset);
        cfg.rx_capacity = rx;
        Network::new(cfg)
    }

    #[test]
    fn storm_converges_and_delivers_everything() {
        let cfg = ChaosConfig::new(Scenario::Storm, 42);
        let mut net = net_with_rx(SystemPreset::Card, cfg.suggested_rx_capacity());
        let report = run(&mut net, &cfg, 1);
        assert_eq!(report.delivered, report.sent, "app-level loss under storm");
        assert!(report.passed(), "storm violated SLOs: {:?}", report.violations());
        assert!(report.convergence_ns > 0, "storm scripted no measurable fault");
        // Per-pair convergence brackets the aggregate: the worst pair is
        // at least as slow as the slowest fault's fastest pair, and the
        // p99 pair never exceeds the worst.
        assert!(report.worst_pair_convergence_ns >= report.convergence_ns);
        assert!(report.p99_pair_convergence_ns <= report.worst_pair_convergence_ns);
        assert!(report.p99_pair_convergence_ns > 0);
    }

    #[test]
    fn hotspot_trips_backpressure_per_mode() {
        // Postmaster: guaranteed mode — the full sink inbox withholds
        // sender credits (stall accounting), drops nothing.
        let cfg = ChaosConfig::new(Scenario::Hotspot, 7);
        let mut net = net_with_rx(SystemPreset::Card, cfg.suggested_rx_capacity());
        let pm = run(&mut net, &cfg, 1);
        assert!(pm.stalled_ns > 0, "bounded PM inbox never stalled a sender");
        assert_eq!(pm.dropped, 0, "guaranteed mode must not drop");
        assert!(pm.passed(), "hotspot(pm) violated SLOs: {:?}", pm.violations());

        // Ethernet: best-effort — the full sink inbox drops frames and
        // counts them; the app still observed every message.
        let mut cfg_eth = ChaosConfig::new(Scenario::Hotspot, 7);
        cfg_eth.comm = CommMode::Ethernet { rx: RxMode::Interrupt };
        let mut net = net_with_rx(SystemPreset::Card, cfg_eth.suggested_rx_capacity());
        let eth = run(&mut net, &cfg_eth, 1);
        assert!(eth.dropped > 0, "bounded Ethernet inbox never dropped");
        assert_eq!(eth.stalled_ns, 0, "best-effort mode must not stall");
        assert_eq!(eth.delivered, eth.sent, "drops are post-delivery (NIC ring overflow)");
    }

    #[test]
    fn partition_heals_within_slo() {
        let cfg = ChaosConfig::new(Scenario::Partition, 3);
        let mut net = net_with_rx(SystemPreset::Card, cfg.suggested_rx_capacity());
        let report = run(&mut net, &cfg, 1);
        assert_eq!(report.delivered, report.sent);
        assert!(report.passed(), "partition violated SLOs: {:?}", report.violations());
    }

    #[test]
    fn every_scenario_produces_a_graded_report() {
        for sc in Scenario::ALL {
            let cfg = ChaosConfig::new(sc, 11);
            let mut net = net_with_rx(SystemPreset::Card, cfg.suggested_rx_capacity());
            let report = run(&mut net, &cfg, 1);
            assert!(report.sent > 0, "{}: no traffic", sc.name());
            assert_eq!(report.delivered, report.sent, "{}: app-level loss", sc.name());
            assert!(report.passed(), "{}: {:?}", sc.name(), report.violations());
            let json = report.to_json();
            assert!(json.contains(&format!("\"scenario\": \"{}\"", sc.name())), "{json}");
            assert!(json.contains("\"passed\": true"), "{json}");
        }
    }

    #[test]
    fn seeded_loss_degrades_delivery_within_slo() {
        let cfg = ChaosConfig::new(Scenario::Loss, 42);
        let mut sys = SystemConfig::new(SystemPreset::Card);
        sys.rx_capacity = cfg.suggested_rx_capacity();
        sys.drop_probability = cfg.scenario.suggested_drop_probability();
        let mut net = Network::new(sys);
        let report = run(&mut net, &cfg, 1);
        assert!(report.sent > 0);
        assert!(
            net.metrics().link_loss > 0,
            "1% per-hand-off loss over a whole run must drop something"
        );
        assert!(
            report.delivered < report.sent,
            "every link drop kills a best-effort message, yet none went missing"
        );
        assert!(report.passed(), "loss violated SLOs: {:?}", report.violations());
        // Same seed, same losses: the experiment replays exactly.
        let mut sys2 = SystemConfig::new(SystemPreset::Card);
        sys2.rx_capacity = cfg.suggested_rx_capacity();
        sys2.drop_probability = cfg.scenario.suggested_drop_probability();
        let mut net2 = Network::new(sys2);
        assert_eq!(run(&mut net2, &cfg, 1), report);
    }

    #[test]
    fn identical_seeds_reproduce_identical_reports() {
        let cfg = ChaosConfig::new(Scenario::Flap, 9);
        let mut a = net_with_rx(SystemPreset::Card, cfg.suggested_rx_capacity());
        let mut b = net_with_rx(SystemPreset::Card, cfg.suggested_rx_capacity());
        let ra = run(&mut a, &cfg, 1);
        let rb = run(&mut b, &cfg, 1);
        assert_eq!(ra, rb, "chaos run is not a pure function of its seed");
    }
}
