//! Node coordinates, ids and mesh directions.


/// Dense node index (row-major over (z, y, x)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// A position in the global 3D mesh. The paper labels nodes on a card by
/// the digit string XYZ (Fig 1), e.g. node (100) is x=1, y=0, z=0.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Coord {
    pub x: u32,
    pub y: u32,
    pub z: u32,
}

impl std::fmt::Display for Coord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{},{})", self.x, self.y, self.z)
    }
}

impl Coord {
    #[inline]
    pub fn id(self, dims: (u32, u32, u32)) -> NodeId {
        debug_assert!(self.x < dims.0 && self.y < dims.1 && self.z < dims.2);
        NodeId((self.z * dims.1 + self.y) * dims.0 + self.x)
    }

    #[inline]
    pub fn from_id(id: NodeId, dims: (u32, u32, u32)) -> Coord {
        let x = id.0 % dims.0;
        let y = (id.0 / dims.0) % dims.1;
        let z = id.0 / (dims.0 * dims.1);
        Coord { x, y, z }
    }

    /// Component along `axis` (0 = x, 1 = y, 2 = z).
    #[inline]
    pub fn get(self, axis: usize) -> u32 {
        match axis {
            0 => self.x,
            1 => self.y,
            _ => self.z,
        }
    }

    #[inline]
    pub fn set(mut self, axis: usize, v: u32) -> Coord {
        match axis {
            0 => self.x = v,
            1 => self.y = v,
            _ => self.z = v,
        }
        self
    }

    /// Step `dist` nodes in `dir`; `None` if it leaves the mesh.
    pub fn step(self, dir: Dir, dist: u32, dims: (u32, u32, u32)) -> Option<Coord> {
        let axis = dir.axis();
        let cur = self.get(axis) as i64;
        let next = cur + dir.sign() as i64 * dist as i64;
        let limit = [dims.0, dims.1, dims.2][axis] as i64;
        if next < 0 || next >= limit {
            None
        } else {
            Some(self.set(axis, next as u32))
        }
    }

    /// The paper's per-card node label, e.g. "(100)" (Fig 1).
    pub fn card_label(self) -> String {
        format!("{}{}{}", self.x % 3, self.y % 3, self.z % 3)
    }
}

/// One of the six mesh directions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    XPlus,
    XMinus,
    YPlus,
    YMinus,
    ZPlus,
    ZMinus,
}

/// All six directions, in deterministic order.
pub const ALL_DIRS: [Dir; 6] =
    [Dir::XPlus, Dir::XMinus, Dir::YPlus, Dir::YMinus, Dir::ZPlus, Dir::ZMinus];

impl Dir {
    /// 0 = x, 1 = y, 2 = z.
    #[inline]
    pub fn axis(self) -> usize {
        match self {
            Dir::XPlus | Dir::XMinus => 0,
            Dir::YPlus | Dir::YMinus => 1,
            Dir::ZPlus | Dir::ZMinus => 2,
        }
    }

    #[inline]
    pub fn sign(self) -> i32 {
        match self {
            Dir::XPlus | Dir::YPlus | Dir::ZPlus => 1,
            _ => -1,
        }
    }

    #[inline]
    pub fn opposite(self) -> Dir {
        match self {
            Dir::XPlus => Dir::XMinus,
            Dir::XMinus => Dir::XPlus,
            Dir::YPlus => Dir::YMinus,
            Dir::YMinus => Dir::YPlus,
            Dir::ZPlus => Dir::ZMinus,
            Dir::ZMinus => Dir::ZPlus,
        }
    }

    /// Direction moving `from → to` along one axis (they must differ on
    /// exactly that axis for the result to be meaningful).
    pub fn towards(axis: usize, from: u32, to: u32) -> Dir {
        match (axis, to > from) {
            (0, true) => Dir::XPlus,
            (0, false) => Dir::XMinus,
            (1, true) => Dir::YPlus,
            (1, false) => Dir::YMinus,
            (2, true) => Dir::ZPlus,
            (2, false) => Dir::ZMinus,
            _ => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: (u32, u32, u32) = (12, 12, 3);

    #[test]
    fn id_roundtrip() {
        for z in 0..DIMS.2 {
            for y in 0..DIMS.1 {
                for x in 0..DIMS.0 {
                    let c = Coord { x, y, z };
                    assert_eq!(Coord::from_id(c.id(DIMS), DIMS), c);
                }
            }
        }
    }

    #[test]
    fn step_bounds() {
        let c = Coord { x: 0, y: 5, z: 2 };
        assert_eq!(c.step(Dir::XMinus, 1, DIMS), None);
        assert_eq!(c.step(Dir::XPlus, 3, DIMS), Some(Coord { x: 3, y: 5, z: 2 }));
        assert_eq!(c.step(Dir::ZPlus, 1, DIMS), None);
        assert_eq!(c.step(Dir::ZMinus, 1, DIMS), Some(Coord { x: 0, y: 5, z: 1 }));
    }

    #[test]
    fn opposite_is_involution() {
        for d in ALL_DIRS {
            assert_eq!(d.opposite().opposite(), d);
            assert_eq!(d.axis(), d.opposite().axis());
            assert_eq!(d.sign(), -d.opposite().sign());
        }
    }

    #[test]
    fn card_labels_match_fig1() {
        assert_eq!(Coord { x: 1, y: 0, z: 0 }.card_label(), "100");
        assert_eq!(Coord { x: 4, y: 3, z: 0 }.card_label(), "100");
        assert_eq!(Coord { x: 1, y: 1, z: 1 }.card_label(), "111");
    }
}
