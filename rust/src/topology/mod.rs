//! 3D mesh topology: cards, backplanes, cages (paper §2.1–2.3, Figs 1–2).
//!
//! * A **card** is a 3×3×3 cube of 27 nodes. Node (100) carries the
//!   external Ethernet gateway; (000) is the controller node with the
//!   PCIe host interface and serial console; (200) can also carry PCIe.
//! * A **backplane** arranges 16 cards into 12×12×3 (INC 3000). Cards
//!   tile the x/y plane; each card occupies the full z extent of a cage.
//! * Four **cages** stack vertically into 12×12×12 (INC 9000).
//!
//! Links (§2.3):
//! * **Single-span** links join orthogonal nearest neighbors. In the
//!   z direction they exist only within a cage (cards are one cage tall;
//!   the inter-cage backplane connectors carry multi-span links).
//! * **Multi-span** links join nodes exactly 3 apart in one orthogonal
//!   direction and always begin and terminate on different cards (a card
//!   is 3 nodes wide, so a span of 3 necessarily leaves it).
//!
//! This reproduces the paper's link censuses: 432 unidirectional SERDES
//! connections leaving/entering a fully-connected card (⇒ 432 GB/s), a
//! 288 GB/s bisection for INC 3000 and 864 GB/s for INC 9000 (see
//! `bisection` and the tests below; EXPERIMENTS.md E2/E3).

mod coord;
mod links;

pub use coord::{Coord, Dir, NodeId, ALL_DIRS};
pub use links::{LinkId, LinkInfo, Span};

use crate::config::SystemPreset;

/// The assembled mesh: node coordinate maps plus the link tables used by
/// the router ([`crate::network::Network`] owns the dynamic link state).
#[derive(Debug, Clone)]
pub struct Topology {
    dims: (u32, u32, u32),
    /// Outgoing links per node, indexed by `NodeId`.
    out_links: Vec<Vec<LinkId>>,
    /// Incoming links per node, indexed by `NodeId`.
    in_links: Vec<Vec<LinkId>>,
    /// All unidirectional links.
    links: Vec<LinkInfo>,
}

impl Topology {
    /// Build a mesh of the given dimensions with INC link rules.
    pub fn new(dims: (u32, u32, u32)) -> Self {
        let n = (dims.0 * dims.1 * dims.2) as usize;
        let mut links = Vec::new();
        let mut out_links = vec![Vec::new(); n];
        let mut in_links = vec![Vec::new(); n];

        let add = |links: &mut Vec<LinkInfo>,
                       out_links: &mut Vec<Vec<LinkId>>,
                       in_links: &mut Vec<Vec<LinkId>>,
                       src: Coord,
                       dst: Coord,
                       span: Span,
                       dir: Dir| {
            let id = LinkId(links.len() as u32);
            let s = src.id(dims);
            let d = dst.id(dims);
            links.push(LinkInfo { id, src: s, dst: d, span, dir });
            out_links[s.0 as usize].push(id);
            in_links[d.0 as usize].push(id);
        };

        for z in 0..dims.2 {
            for y in 0..dims.1 {
                for x in 0..dims.0 {
                    let c = Coord { x, y, z };
                    for dir in ALL_DIRS {
                        // Single-span: nearest orthogonal neighbor. In z,
                        // only within a cage (see module docs).
                        if let Some(nb) = c.step(dir, 1, dims) {
                            let crosses_cage =
                                dir.axis() == 2 && (c.z / 3) != (nb.z / 3);
                            if !crosses_cage {
                                add(
                                    &mut links,
                                    &mut out_links,
                                    &mut in_links,
                                    c,
                                    nb,
                                    Span::Single,
                                    dir,
                                );
                            }
                        }
                        // Multi-span: exactly 3 apart; always inter-card.
                        if let Some(nb) = c.step(dir, 3, dims) {
                            add(
                                &mut links,
                                &mut out_links,
                                &mut in_links,
                                c,
                                nb,
                                Span::Multi,
                                dir,
                            );
                        }
                    }
                }
            }
        }

        Topology { dims, out_links, in_links, links }
    }

    pub fn preset(p: SystemPreset) -> Self {
        Self::new(p.dims())
    }

    #[inline]
    pub fn dims(&self) -> (u32, u32, u32) {
        self.dims
    }

    #[inline]
    pub fn node_count(&self) -> usize {
        self.out_links.len()
    }

    #[inline]
    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    #[inline]
    pub fn link(&self, id: LinkId) -> &LinkInfo {
        &self.links[id.0 as usize]
    }

    #[inline]
    pub fn links(&self) -> &[LinkInfo] {
        &self.links
    }

    #[inline]
    pub fn out_links(&self, n: NodeId) -> &[LinkId] {
        &self.out_links[n.0 as usize]
    }

    #[inline]
    pub fn in_links(&self, n: NodeId) -> &[LinkId] {
        &self.in_links[n.0 as usize]
    }

    #[inline]
    pub fn coord(&self, n: NodeId) -> Coord {
        Coord::from_id(n, self.dims)
    }

    #[inline]
    pub fn id(&self, c: Coord) -> NodeId {
        c.id(self.dims)
    }

    /// Iterate over all node ids.
    pub fn nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.node_count() as u32).map(NodeId)
    }

    /// The card (3×3×3 block) a node belongs to, as card coordinates.
    pub fn card_of(&self, n: NodeId) -> (u32, u32, u32) {
        let c = self.coord(n);
        (c.x / 3, c.y / 3, c.z / 3)
    }

    /// The cage (3-node-tall z slab) a node belongs to. INC 9000 stacks
    /// four of these (Fig 2a); smaller systems have exactly one.
    #[inline]
    pub fn cage_of(&self, n: NodeId) -> u32 {
        self.coord(n).z / 3
    }

    /// Number of cages (z extent / 3).
    #[inline]
    pub fn cage_count(&self) -> u32 {
        self.dims.2 / 3
    }

    /// Dense index of a node's card in [`Topology::cards`] order.
    pub fn card_index(&self, n: NodeId) -> u32 {
        let (cx, cy, cz) = self.card_of(n);
        (cz * (self.dims.1 / 3) + cy) * (self.dims.0 / 3) + cx
    }

    /// Partition the mesh into `shards` contiguous groups of *natural
    /// units* for parallel simulation: cages when the system has more
    /// than one and they suffice (INC 9000 — inter-cage traffic is
    /// confined to multi-span z links, the cheapest boundary), falling
    /// back to cards when the request exceeds the cage count (single-
    /// cage systems, or mega meshes where `--shards 64` must not clamp
    /// to 16 cages). Returns the owner shard per node plus the actual
    /// shard count (`shards` is clamped to `[1, unit count]`). Either
    /// way, whole units — and therefore whole cards — map to one shard.
    pub fn partition(&self, shards: u32) -> (Vec<u32>, u32) {
        let by_cage = self.cage_count() > 1 && shards <= self.cage_count();
        let nunits =
            if by_cage { self.cage_count() } else { self.cards().len() as u32 };
        let s = shards.clamp(1, nunits);
        let owner = (0..self.node_count() as u32)
            .map(|n| {
                let unit = if by_cage {
                    self.cage_of(NodeId(n))
                } else {
                    self.card_index(NodeId(n))
                };
                // Contiguous unit ranges per shard (balanced to ±1 unit).
                (unit as u64 * s as u64 / nunits as u64) as u32
            })
            .collect();
        (owner, s)
    }

    /// All nodes of one card, in node-number order (Fig 1 numbering).
    pub fn card_nodes(&self, card: (u32, u32, u32)) -> Vec<NodeId> {
        let mut v = Vec::with_capacity(27);
        for z in 0..3 {
            for y in 0..3 {
                for x in 0..3 {
                    v.push(self.id(Coord {
                        x: card.0 * 3 + x,
                        y: card.1 * 3 + y,
                        z: card.2 * 3 + z,
                    }));
                }
            }
        }
        v
    }

    /// All card coordinates in the system.
    pub fn cards(&self) -> Vec<(u32, u32, u32)> {
        let mut v = Vec::new();
        for cz in 0..self.dims.2 / 3 {
            for cy in 0..self.dims.1 / 3 {
                for cx in 0..self.dims.0 / 3 {
                    v.push((cx, cy, cz));
                }
            }
        }
        v
    }

    /// Gateway node (100) of a card: carries the external Ethernet port.
    pub fn gateway_node(&self, card: (u32, u32, u32)) -> NodeId {
        self.id(Coord { x: card.0 * 3 + 1, y: card.1 * 3, z: card.2 * 3 })
    }

    /// Controller node (000) of a card: PCIe host interface + console.
    pub fn controller_node(&self, card: (u32, u32, u32)) -> NodeId {
        self.id(Coord { x: card.0 * 3, y: card.1 * 3, z: card.2 * 3 })
    }

    /// Secondary PCIe-capable node (200) of a card.
    pub fn pcie2_node(&self, card: (u32, u32, u32)) -> NodeId {
        self.id(Coord { x: card.0 * 3 + 2, y: card.1 * 3, z: card.2 * 3 })
    }

    /// Minimal hop count between two nodes using single- and multi-span
    /// links. Along x and y, distance `d` costs `d/3 + d%3` hops
    /// (multi-span covers 3, single-span covers 1, both exist at every
    /// offset). Along z the cage structure matters — see
    /// [`Topology::z_hops`].
    pub fn min_hops(&self, a: NodeId, b: NodeId) -> u32 {
        let (ca, cb) = (self.coord(a), self.coord(b));
        let mut hops = 0;
        for axis in 0..2 {
            let d = ca.get(axis).abs_diff(cb.get(axis));
            hops += d / 3 + d % 3;
        }
        hops + Self::z_hops(ca.z, cb.z)
    }

    /// Minimal hops between two z coordinates. Single-span z links never
    /// cross a cage (§2.1: the inter-cage backplane connectors carry
    /// multi-span links only) and multi-span links jump exactly one cage
    /// while preserving the intra-cage offset, so crossing cages costs
    /// one multi-span hop per cage boundary plus single-span hops for
    /// the offset difference. Within one cage it is plain distance.
    /// (Note `d/3 + d%3` would *under*-count here: z = 2 → 3 is
    /// distance 1 but needs 3 hops — jump 2→5, then fill 5→4→3.)
    pub fn z_hops(az: u32, bz: u32) -> u32 {
        let (ac, bc) = (az / 3, bz / 3);
        if ac == bc {
            az.abs_diff(bz)
        } else {
            ac.abs_diff(bc) + (az % 3).abs_diff(bz % 3)
        }
    }

    /// Nodes of `shard` that touch another shard: incident (as source
    /// or destination) to at least one link whose other end a different
    /// shard owns. Any minimal path between two shards enters and
    /// leaves through boundary nodes, so pairwise shard distances can
    /// be computed over boundary sets alone (see
    /// [`Topology::shard_hop_matrix`]).
    pub fn boundary_nodes(&self, owner: &[u32], shard: u32) -> Vec<NodeId> {
        self.nodes()
            .filter(|&n| {
                owner[n.0 as usize] == shard
                    && (self
                        .out_links(n)
                        .iter()
                        .any(|&l| owner[self.link(l).dst.0 as usize] != shard)
                        || self
                            .in_links(n)
                            .iter()
                            .any(|&l| owner[self.link(l).src.0 as usize] != shard))
            })
            .collect()
    }

    /// Pairwise minimum link-hop distance between shards, as a flat
    /// `shards × shards` row-major matrix: entry `[i * shards + j]` is
    /// the minimum [`Topology::min_hops`] over (boundary node of `i`,
    /// boundary node of `j`) pairs — the fewest links any causal chain
    /// must cross to carry influence from shard `i` into shard `j`
    /// (0 on the diagonal). Every fabric event crossing one link costs
    /// at least one router latency, so `distance × router_latency` is a
    /// sound per-pair lookahead for the sharded engine's multi-shard
    /// epoch batching (see `network::sharded`).
    ///
    /// Computed over *cards*, not nodes: partitions are card-aligned
    /// ([`Topology::partition`] assigns whole units), cards are 3×3×3
    /// product sets (per-axis choices are independent), and the
    /// per-axis hop minimum between two 3-wide card intervals `k`
    /// cards apart is exactly `k` (x/y: `min f(d), d ∈ [3k−2, 3k+2]`
    /// with `f(d) = d/3 + d%3` is attained at `d = 3k`; z: the
    /// intra-cage offsets align freely, leaving one multi-span hop per
    /// cage boundary — [`Topology::z_hops`]). So the boundary-pair
    /// minimum equals the card-coordinate Manhattan distance minimum —
    /// a card-count-squared scan instead of a node-count-squared one,
    /// which is what keeps mega-mesh engine construction cheap.
    pub fn shard_hop_matrix(&self, owner: &[u32], shards: u32) -> Vec<u32> {
        let s = shards as usize;
        let mut cards: Vec<Vec<(u32, u32, u32)>> = vec![Vec::new(); s];
        for card in self.cards() {
            let anchor =
                self.id(Coord { x: card.0 * 3, y: card.1 * 3, z: card.2 * 3 });
            debug_assert!(
                self.card_nodes(card)
                    .iter()
                    .all(|&n| owner[n.0 as usize] == owner[anchor.0 as usize]),
                "partition splits card {card:?} across shards"
            );
            cards[owner[anchor.0 as usize] as usize].push(card);
        }
        let mut m = vec![0u32; s * s];
        for i in 0..s {
            for j in (i + 1)..s {
                let mut best = u32::MAX;
                for &a in &cards[i] {
                    for &b in &cards[j] {
                        let d = a.0.abs_diff(b.0)
                            + a.1.abs_diff(b.1)
                            + a.2.abs_diff(b.2);
                        best = best.min(d);
                    }
                }
                m[i * s + j] = best;
                m[j * s + i] = best;
            }
        }
        m
    }

    /// Minimum link-hop distance from every *card* to every shard, as a
    /// flat `cards × shards` matrix indexed
    /// `[card_index * shards + shard]` (0 for the card's own shard).
    /// The per-node sharpening of [`Topology::shard_hop_matrix`]: a
    /// node's distance to shard `j` is its card's distance (cards are
    /// never split across shards), and the card-coordinate Manhattan
    /// distance equals the true per-axis hop minimum by the same
    /// argument as the pairwise matrix. Interior cards of a large shard
    /// sit strictly farther from every neighbor than the shard-pair
    /// minimum, which is what buys the sharded engine a longer horizon
    /// when a shard's head event lives away from its boundary.
    pub fn card_shard_distances(&self, owner: &[u32], shards: u32) -> Vec<u32> {
        let s = shards as usize;
        let all = self.cards();
        let mut by_shard: Vec<Vec<(u32, u32, u32)>> = vec![Vec::new(); s];
        for &card in &all {
            let anchor =
                self.id(Coord { x: card.0 * 3, y: card.1 * 3, z: card.2 * 3 });
            by_shard[owner[anchor.0 as usize] as usize].push(card);
        }
        let mut m = vec![0u32; all.len() * s];
        for (ci, &a) in all.iter().enumerate() {
            for j in 0..s {
                let mut best = u32::MAX;
                for &b in &by_shard[j] {
                    let d = a.0.abs_diff(b.0)
                        + a.1.abs_diff(b.1)
                        + a.2.abs_diff(b.2);
                    best = best.min(d);
                }
                m[ci * s + j] = best;
            }
        }
        m
    }

    /// Number of unidirectional links a card presents to the rest of the
    /// system *by design* (its connector capacity): every node face link
    /// plus every multi-span link, regardless of whether a neighbor card
    /// is present. The paper: "a total of 432 links leaving or entering
    /// the card" ⇒ 432 GB/s (§2.3).
    pub fn card_port_capacity() -> u32 {
        // Single-span: 6 faces × 9 nodes, two unidirectional each.
        let single = 6 * 9 * 2;
        // Multi-span: 27 nodes × 6 directions × 2 unidirectional / 2
        // (each bidirectional link counted once per endpoint) — i.e. every
        // node terminates 6 bidirectional multi-span links, all off-card.
        let multi = 27 * 6 * 2;
        single + multi
    }

    /// Count unidirectional links crossing the plane `axis = cut + 0.5`
    /// (both directions). With 1 GB/s links this is the cut bandwidth in
    /// GB/s; minimized over the middle cuts it is the bisection bandwidth.
    pub fn cut_links(&self, axis: usize, cut: u32) -> u32 {
        self.links
            .iter()
            .filter(|l| {
                let (a, b) = (
                    self.coord(l.src).get(axis),
                    self.coord(l.dst).get(axis),
                );
                (a <= cut && b > cut) || (b <= cut && a > cut)
            })
            .count() as u32
    }

    /// Bisection bandwidth in GB/s (1 link = 1 GB/s): the minimum over
    /// all axis-aligned mid-plane cuts that split the machine in half.
    pub fn bisection_gbps(&self) -> u32 {
        let dims = [self.dims.0, self.dims.1, self.dims.2];
        let mut best = u32::MAX;
        for axis in 0..3 {
            if dims[axis] % 2 != 0 {
                continue; // cannot split this axis evenly
            }
            let cut = dims[axis] / 2 - 1;
            best = best.min(self.cut_links(axis, cut));
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_and_link_counts() {
        let card = Topology::preset(SystemPreset::Card);
        assert_eq!(card.node_count(), 27);
        let inc3000 = Topology::preset(SystemPreset::Inc3000);
        assert_eq!(inc3000.node_count(), 432);
        let inc9000 = Topology::preset(SystemPreset::Inc9000);
        assert_eq!(inc9000.node_count(), 1728);
    }

    #[test]
    fn single_card_has_no_multispan_and_54_single_links() {
        // On an isolated 3×3×3 card, multi-span links (span exactly 3)
        // cannot exist; single-span: 3 axes × (2 planes of 9 adjacent
        // pairs... ) = 54 bidirectional = 108 unidirectional.
        let t = Topology::preset(SystemPreset::Card);
        assert!(t.links().iter().all(|l| l.span == Span::Single));
        assert_eq!(t.link_count(), 108);
    }

    #[test]
    fn every_node_has_six_single_span_links_in_the_interior() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let center = t.id(Coord { x: 6, y: 6, z: 1 });
        let singles = t
            .out_links(center)
            .iter()
            .filter(|&&l| t.link(l).span == Span::Single)
            .count();
        assert_eq!(singles, 6);
        let multis = t
            .out_links(center)
            .iter()
            .filter(|&&l| t.link(l).span == Span::Multi)
            .count();
        // x: ±3 both exist (6±3 in 0..12); y same; z: 12.. only 3 tall, none.
        assert_eq!(multis, 4);
    }

    #[test]
    fn inc9000_interior_node_has_six_multispan() {
        let t = Topology::preset(SystemPreset::Inc9000);
        let center = t.id(Coord { x: 6, y: 6, z: 6 });
        let multis = t
            .out_links(center)
            .iter()
            .filter(|&&l| t.link(l).span == Span::Multi)
            .count();
        assert_eq!(multis, 6);
    }

    #[test]
    fn card_shard_distances_refine_pair_matrix() {
        let t = Topology::preset(SystemPreset::Inc9000);
        let (owner, s) = t.partition(4);
        let pair = t.shard_hop_matrix(&owner, s);
        let per_card = t.card_shard_distances(&owner, s);
        for n in t.nodes() {
            let ci = t.card_index(n) as usize;
            let i = owner[n.0 as usize] as usize;
            assert_eq!(per_card[ci * s as usize + i], 0);
            for j in 0..s as usize {
                // A node is never closer to shard j than the
                // shard-pair minimum — per-node bounds only lengthen
                // the horizon, never shorten it.
                assert!(per_card[ci * s as usize + j] >= pair[i * s as usize + j]);
            }
        }
        // Some interior card must sit strictly farther from another
        // shard than the pair minimum, or the sharpening buys nothing.
        assert!(t.nodes().any(|n| {
            let ci = t.card_index(n) as usize;
            let i = owner[n.0 as usize] as usize;
            (0..s as usize).any(|j| {
                j != i
                    && per_card[ci * s as usize + j] > pair[i * s as usize + j]
            })
        }));
    }

    #[test]
    fn card_port_capacity_is_432() {
        assert_eq!(Topology::card_port_capacity(), 432);
    }

    #[test]
    fn bisection_matches_paper() {
        // §2.3: 288 GB/s for INC 3000, 864 GB/s for INC 9000.
        assert_eq!(Topology::preset(SystemPreset::Inc3000).bisection_gbps(), 288);
        assert_eq!(Topology::preset(SystemPreset::Inc9000).bisection_gbps(), 864);
    }

    #[test]
    fn z_single_span_does_not_cross_cages() {
        let t = Topology::preset(SystemPreset::Inc9000);
        for l in t.links() {
            if l.span == Span::Single {
                let (a, b) = (t.coord(l.src), t.coord(l.dst));
                assert_eq!(a.z / 3, b.z / 3, "single-span z crossing cages");
            }
        }
    }

    #[test]
    fn multi_span_always_intercard() {
        for preset in [SystemPreset::Inc3000, SystemPreset::Inc9000] {
            let t = Topology::preset(preset);
            for l in t.links() {
                if l.span == Span::Multi {
                    assert_ne!(
                        t.card_of(l.src),
                        t.card_of(l.dst),
                        "multi-span link within one card"
                    );
                }
            }
        }
    }

    #[test]
    fn special_nodes_fig1() {
        let t = Topology::preset(SystemPreset::Card);
        assert_eq!(t.coord(t.controller_node((0, 0, 0))), Coord { x: 0, y: 0, z: 0 });
        assert_eq!(t.coord(t.gateway_node((0, 0, 0))), Coord { x: 1, y: 0, z: 0 });
        assert_eq!(t.coord(t.pcie2_node((0, 0, 0))), Coord { x: 2, y: 0, z: 0 });
    }

    #[test]
    fn z_hops_respects_cage_boundaries() {
        // Same cage: plain single-span distance.
        assert_eq!(Topology::z_hops(0, 2), 2);
        assert_eq!(Topology::z_hops(4, 4), 0);
        // Aligned offsets: one multi-span jump per cage boundary.
        assert_eq!(Topology::z_hops(2, 5), 1);
        assert_eq!(Topology::z_hops(0, 9), 3);
        // Misaligned: jump + intra-cage fill. z = 2 → 3 is coordinate
        // distance 1 but needs 3 hops (2→5 multi, then 5→4→3).
        assert_eq!(Topology::z_hops(2, 3), 3);
        assert_eq!(Topology::z_hops(3, 2), 3);
        assert_eq!(Topology::z_hops(2, 6), 4);
        assert_eq!(Topology::z_hops(1, 11), 4);
    }

    #[test]
    fn min_hops_examples() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let a = t.id(Coord { x: 0, y: 0, z: 0 });
        // Distance 1.
        assert_eq!(t.min_hops(a, t.id(Coord { x: 1, y: 0, z: 0 })), 1);
        // Distance 3: one multi-span hop.
        assert_eq!(t.min_hops(a, t.id(Coord { x: 3, y: 0, z: 0 })), 1);
        // Distance 11 = 3×3 + 2: 3 multi + 2 single.
        assert_eq!(t.min_hops(a, t.id(Coord { x: 11, y: 0, z: 0 })), 5);
        // Mixed axes add up.
        assert_eq!(t.min_hops(a, t.id(Coord { x: 4, y: 2, z: 1 })), 2 + 2 + 1);
        // Same node.
        assert_eq!(t.min_hops(a, a), 0);
    }

    #[test]
    fn partition_by_cage_on_inc9000() {
        let t = Topology::preset(SystemPreset::Inc9000);
        assert_eq!(t.cage_count(), 4);
        let (owner, s) = t.partition(4);
        assert_eq!(s, 4);
        for n in t.nodes() {
            assert_eq!(owner[n.0 as usize], t.cage_of(n), "cage == shard at 4 shards");
        }
        // Every inter-shard link is a multi-span z link (the inter-cage
        // backplane connectors), never a single-span one.
        for l in t.links() {
            let (a, b) = (owner[l.src.0 as usize], owner[l.dst.0 as usize]);
            if a != b {
                assert_eq!(l.span, Span::Multi);
                assert_eq!(l.dir.axis(), 2);
            }
        }
        // Two shards: cages pair up contiguously.
        let (owner2, s2) = t.partition(2);
        assert_eq!(s2, 2);
        for n in t.nodes() {
            assert_eq!(owner2[n.0 as usize], t.cage_of(n) / 2);
        }
    }

    #[test]
    fn partition_by_card_on_small_systems() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let (owner, s) = t.partition(16);
        assert_eq!(s, 16, "one shard per card");
        for n in t.nodes() {
            assert_eq!(owner[n.0 as usize], t.card_index(n));
        }
        // Requests beyond the unit count clamp.
        let (_, s) = t.partition(99);
        assert_eq!(s, 16);
        let card = Topology::preset(SystemPreset::Card);
        let (owner, s) = card.partition(4);
        assert_eq!(s, 1, "a single card cannot shard further");
        assert!(owner.iter().all(|&o| o == 0));
    }

    #[test]
    fn partition_is_contiguous_and_balanced() {
        // (preset, shards, expected nodes per shard): 16 cards over 4
        // shards = 108 nodes; the mega presets split evenly at 64
        // shards (Inc27000: 1024 cards / 64 = 16 cards = 432 nodes;
        // Inc100k: 4096 / 64 = 64 cards = 1728 nodes).
        let cases = [
            (SystemPreset::Inc3000, 4u32, 108u32),
            (SystemPreset::Inc27000, 64, 432),
            (SystemPreset::Inc100k, 64, 1728),
        ];
        for (preset, shards, per) in cases {
            let t = Topology::preset(preset);
            let (owner, s) = t.partition(shards);
            assert_eq!(s, shards, "{preset:?}");
            let mut per_shard = vec![0u32; s as usize];
            for n in t.nodes() {
                per_shard[owner[n.0 as usize] as usize] += 1;
            }
            assert!(
                per_shard.iter().all(|&c| c == per),
                "{preset:?}: {:?} ...",
                &per_shard[..4.min(per_shard.len())]
            );
            // Contiguous in card-index order: owners never decrease.
            let mut prev = 0;
            for card in t.cards() {
                let o = owner[t.gateway_node(card).0 as usize];
                assert!(o >= prev, "{preset:?}: owner regressed at {card:?}");
                prev = o;
            }
        }
    }

    #[test]
    fn partition_beyond_cage_count_falls_back_to_cards() {
        // A mega mesh has 16 cages but must honor `--shards 64`: the
        // unit granularity drops from cages to cards instead of
        // clamping (work-stealing keeps shards > cores busy).
        let t = Topology::preset(SystemPreset::Inc27000);
        assert_eq!(t.cage_count(), 16);
        let (owner, s) = t.partition(64);
        assert_eq!(s, 64);
        for n in t.nodes() {
            assert_eq!(owner[n.0 as usize], t.card_index(n) * 64 / 1024);
        }
        // Same on Inc9000: 16 shards exceed its 4 cages, so the 64
        // cards split 4-per-shard rather than clamping to 4 cages.
        let t9 = Topology::preset(SystemPreset::Inc9000);
        let (owner9, s9) = t9.partition(16);
        assert_eq!(s9, 16);
        for n in t9.nodes() {
            assert_eq!(owner9[n.0 as usize], t9.card_index(n) / 4);
        }
        // At or below the cage count the cage boundary stays preferred.
        let (owner4, s4) = t9.partition(4);
        assert_eq!(s4, 4);
        for n in t9.nodes() {
            assert_eq!(owner4[n.0 as usize], t9.cage_of(n));
        }
    }

    #[test]
    fn shard_hop_matrix_counts_cage_distances() {
        // Inc9000, one shard per cage: adjacent cages are one multi-span
        // z hop apart, and distance grows by one per cage boundary.
        let t = Topology::preset(SystemPreset::Inc9000);
        let (owner, s) = t.partition(4);
        let m = t.shard_hop_matrix(&owner, s);
        let d = |i: usize, j: usize| m[i * s as usize + j];
        for i in 0..4 {
            assert_eq!(d(i, i), 0);
            for j in 0..4 {
                if i != j {
                    assert_eq!(d(i, j), (i as u32).abs_diff(j as u32), "cages {i}->{j}");
                    assert_eq!(d(i, j), d(j, i), "symmetric");
                }
            }
        }
        // Inc3000 per-card sharding: opposite corner cards of the 4x4
        // card grid are 3 + 3 multi/single hops apart.
        let t3 = Topology::preset(SystemPreset::Inc3000);
        let (owner3, s3) = t3.partition(16);
        let m3 = t3.shard_hop_matrix(&owner3, s3);
        assert_eq!(m3[15], 6, "card (0,0) -> card (3,3)");
        assert_eq!(m3[1], 1, "adjacent cards touch");
        // Every off-diagonal distance is at least one link.
        for i in 0..s3 as usize {
            for j in 0..s3 as usize {
                assert_eq!(m3[i * 16 + j] == 0, i == j);
            }
        }
    }

    #[test]
    fn shard_hop_matrix_card_scan_matches_node_scan() {
        // The card-Manhattan shortcut must reproduce the brute-force
        // minimum over boundary-node pairs exactly (the doc-comment
        // argument, checked): cage partitions and card partitions,
        // even and uneven shard counts.
        for (preset, shards) in [
            (SystemPreset::Inc3000, 4u32),
            (SystemPreset::Inc3000, 7),
            (SystemPreset::Inc9000, 3),
            (SystemPreset::Inc9000, 16),
        ] {
            let t = Topology::preset(preset);
            let (owner, s) = t.partition(shards);
            let fast = t.shard_hop_matrix(&owner, s);
            let boundary: Vec<Vec<NodeId>> =
                (0..s).map(|i| t.boundary_nodes(&owner, i)).collect();
            for i in 0..s as usize {
                for j in (i + 1)..s as usize {
                    let mut best = u32::MAX;
                    for &a in &boundary[i] {
                        for &b in &boundary[j] {
                            best = best.min(t.min_hops(a, b));
                        }
                    }
                    assert_eq!(
                        fast[i * s as usize + j],
                        best,
                        "{preset:?} shards={s} pair ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn boundary_nodes_touch_other_shards() {
        let t = Topology::preset(SystemPreset::Inc9000);
        let (owner, _) = t.partition(4);
        let b0 = t.boundary_nodes(&owner, 0);
        assert!(!b0.is_empty());
        for n in b0 {
            assert_eq!(owner[n.0 as usize], 0);
            let crosses = t
                .out_links(n)
                .iter()
                .any(|&l| owner[t.link(l).dst.0 as usize] != 0)
                || t.in_links(n).iter().any(|&l| owner[t.link(l).src.0 as usize] != 0);
            assert!(crosses, "{n} listed as boundary without a crossing link");
        }
    }

    #[test]
    fn cards_enumeration() {
        let t = Topology::preset(SystemPreset::Inc3000);
        assert_eq!(t.cards().len(), 16);
        let t9 = Topology::preset(SystemPreset::Inc9000);
        assert_eq!(t9.cards().len(), 64);
        for card in t.cards() {
            assert_eq!(t.card_nodes(card).len(), 27);
        }
    }
}
