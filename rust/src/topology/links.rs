//! Static link descriptions (dynamic state lives in [`crate::link`]).


use super::{Dir, NodeId};

/// Index into [`crate::topology::Topology::links`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub u32);

impl std::fmt::Display for LinkId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "l{}", self.0)
    }
}

/// Single-span (nearest neighbor) vs multi-span (3 apart, inter-card).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Span {
    Single,
    Multi,
}

impl Span {
    /// How many mesh positions the link covers along its axis.
    #[inline]
    pub fn distance(self) -> u32 {
        match self {
            Span::Single => 1,
            Span::Multi => 3,
        }
    }
}

/// One unidirectional SERDES connection (§2.3: links are pairs of these;
/// we model each direction separately, which is also how the credit
/// protocol works — credits for a receiver travel on the paired reverse
/// connection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkInfo {
    pub id: LinkId,
    pub src: NodeId,
    pub dst: NodeId,
    pub span: Span,
    /// Mesh direction of travel (src → dst).
    pub dir: Dir,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_distance() {
        assert_eq!(Span::Single.distance(), 1);
        assert_eq!(Span::Multi.distance(), 3);
    }

    #[test]
    fn display_forms() {
        assert_eq!(LinkId(3).to_string(), "l3");
        assert_eq!(NodeId(7).to_string(), "n7");
    }
}
