//! Multicast + network-defect avoidance — the two §2.4 extensions the
//! paper lists as "being considered at the time of writing … can be
//! included based on application or hardware needs". We include both.
//!
//! **Multicast**: one packet, a set of destinations, delivered along a
//! spanning tree: at every node the remaining destination set is
//! partitioned by each target's *deterministic dimension-ordered* next
//! hop (x, then y, then z; multi-span preferred), and one copy forwards
//! per occupied output link. Each destination receives exactly one copy
//! and shared path prefixes are traversed once — the bandwidth win over
//! repeated directed sends (tested below).
//!
//! **Defect avoidance**: links can be marked failed
//! ([`crate::network::Network::fail_link`]). Directed routing drops
//! failed links from its productive set; when *every* productive link is
//! dead the packet takes a lateral escape (any live link) and re-routes
//! from there, with a hop budget guarding against livelock. Multicast
//! partitioning likewise avoids failed links when a sibling productive
//! link survives.

use crate::topology::{Dir, LinkId, NodeId, Span, Topology};

/// The deterministic dimension-ordered next link towards `dst` from
/// `here`: correct the x distance first (multi-span when ≥ 3), then y,
/// then z. Unlike the adaptive chooser this is path-stable, which is
/// what makes the multicast partition a tree. Failed links are skipped
/// where a productive alternative exists on the same axis.
///
/// The z axis is cage-aware (§2.1): single-span z links never cross a
/// cage and multi-span jumps preserve the intra-cage offset, so when
/// the destination lies in another cage the rule aligns the offset
/// *first* (single-span steps inside the current cage), then jumps
/// cage by cage — every step reduces [`Topology::z_hops`] by one, so
/// the walk is monotone and lands exactly.
/// `failed` is a link-failure predicate rather than a slice: the
/// caller's failure flags are domain-indexed (shard-local state — see
/// `network::domain`), so the router asks instead of indexing.
pub fn dimension_ordered_next(
    topo: &Topology,
    here: NodeId,
    dst: NodeId,
    failed: &impl Fn(LinkId) -> bool,
) -> Option<LinkId> {
    let hc = topo.coord(here);
    let dc = topo.coord(dst);
    for axis in 0..3 {
        let cur = hc.get(axis);
        let tgt = dc.get(axis);
        if cur == tgt {
            continue;
        }
        let d = cur.abs_diff(tgt);
        // Candidate (dir, span) moves for this axis, preferred first.
        // Every listed candidate strictly reduces the axis cost, so any
        // fallback taken on a failed link keeps the walk monotone
        // (non-monotone fallbacks could oscillate and clone copies
        // forever — multicast has no hop budget).
        let mut cands = [(Dir::XPlus, Span::Single); 2];
        let ncands;
        if axis == 2 && cur / 3 != tgt / 3 {
            let (co, to) = (cur % 3, tgt % 3);
            if co != to {
                // Align the intra-cage offset first (stays in-cage);
                // the cage-ward jump also reduces z_hops, so it is a
                // sound fallback — note its direction is the *cage*
                // direction, which can oppose the offset direction.
                cands[0] = (Dir::towards(axis, co, to), Span::Single);
                cands[1] = (Dir::towards(axis, cur, tgt), Span::Multi);
                ncands = 2;
            } else {
                // Offsets aligned: only the jump reduces z_hops (a
                // single-span step would un-align the offset).
                cands[0] = (Dir::towards(axis, cur, tgt), Span::Multi);
                ncands = 1;
            }
        } else {
            let dir = Dir::towards(axis, cur, tgt);
            let want = if d >= 3 { Span::Multi } else { Span::Single };
            cands[0] = (dir, want);
            // The other span as a live fallback, unless it overshoots.
            if other(want) == Span::Multi && d < 3 {
                ncands = 1;
            } else {
                cands[1] = (dir, other(want));
                ncands = 2;
            }
        }
        for &(dir, span) in &cands[..ncands] {
            if let Some(l) = topo
                .out_links(here)
                .iter()
                .copied()
                .find(|&l| {
                    let info = topo.link(l);
                    info.dir == dir && info.span == span && !failed(l)
                })
            {
                return Some(l);
            }
        }
    }
    None
}

fn other(s: Span) -> Span {
    match s {
        Span::Single => Span::Multi,
        Span::Multi => Span::Single,
    }
}

/// Partition `dsts` (excluding `here` itself) by their next link from
/// `here`. Returns (link, destinations routed through it) groups plus
/// whether `here` is itself a destination.
pub fn multicast_partition(
    topo: &Topology,
    here: NodeId,
    dsts: &[NodeId],
    failed: &impl Fn(LinkId) -> bool,
) -> (bool, Vec<(LinkId, Vec<NodeId>)>) {
    let mut local = false;
    let mut groups: Vec<(LinkId, Vec<NodeId>)> = Vec::new();
    for &d in dsts {
        if d == here {
            local = true;
            continue;
        }
        let l = dimension_ordered_next(topo, here, d, failed)
            .expect("multicast destination unreachable (all axis links failed)");
        match groups.iter_mut().find(|(g, _)| *g == l) {
            Some((_, v)) => v.push(d),
            None => groups.push((l, vec![d])),
        }
    }
    (local, groups)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemPreset;
    use crate::topology::Coord;

    fn no_fail(_l: LinkId) -> bool {
        false
    }

    #[test]
    fn dimension_order_is_x_then_y_then_z() {
        let t = Topology::preset(SystemPreset::Card);
        let here = t.id(Coord { x: 0, y: 0, z: 0 });
        let dst = t.id(Coord { x: 1, y: 2, z: 1 });
        let failed = no_fail;
        let l = dimension_ordered_next(&t, here, dst, &failed).unwrap();
        assert_eq!(t.link(l).dir, Dir::XPlus, "x corrected first");
    }

    #[test]
    fn prefers_multispan_for_long_hauls() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let here = t.id(Coord { x: 0, y: 0, z: 0 });
        let dst = t.id(Coord { x: 7, y: 0, z: 0 });
        let failed = no_fail;
        let l = dimension_ordered_next(&t, here, dst, &failed).unwrap();
        assert_eq!(t.link(l).span, Span::Multi);
    }

    #[test]
    fn dimension_order_crosses_cages_offset_first() {
        let t = Topology::preset(SystemPreset::Inc9000);
        let failed = no_fail;
        // z = 2 → z = 3: different cages, offsets 2 vs 0. No direct
        // link exists; the rule aligns the offset first (backwards!).
        let here = t.id(Coord { x: 0, y: 0, z: 2 });
        let dst = t.id(Coord { x: 0, y: 0, z: 3 });
        let l = dimension_ordered_next(&t, here, dst, &failed).unwrap();
        assert_eq!(t.link(l).dir, Dir::ZMinus);
        assert_eq!(t.link(l).span, Span::Single);
        // The walk lands exactly, monotonically in z_hops: 2→1→0→3.
        let mut cur = here;
        let mut steps = 0;
        while cur != dst {
            let before = Topology::z_hops(t.coord(cur).z, t.coord(dst).z);
            let l = dimension_ordered_next(&t, cur, dst, &failed).unwrap();
            cur = t.link(l).dst;
            assert_eq!(
                Topology::z_hops(t.coord(cur).z, t.coord(dst).z),
                before - 1,
                "non-monotone step at {cur}"
            );
            steps += 1;
            assert!(steps <= 10, "walk must terminate");
        }
        assert_eq!(steps, 3);
    }

    #[test]
    fn partition_shares_prefixes() {
        let t = Topology::preset(SystemPreset::Card);
        let here = t.id(Coord { x: 0, y: 0, z: 0 });
        // Two destinations both east: one copy on the +x link.
        let d1 = t.id(Coord { x: 2, y: 0, z: 0 });
        let d2 = t.id(Coord { x: 2, y: 1, z: 0 });
        let failed = no_fail;
        let (local, groups) = multicast_partition(&t, here, &[d1, d2], &failed);
        assert!(!local);
        assert_eq!(groups.len(), 1, "shared prefix must use one copy");
        assert_eq!(groups[0].1.len(), 2);
    }

    #[test]
    fn failed_link_falls_back_to_surviving_span() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let here = t.id(Coord { x: 0, y: 0, z: 0 });
        let dst = t.id(Coord { x: 6, y: 0, z: 0 });
        let pref = dimension_ordered_next(&t, here, dst, &no_fail).unwrap();
        assert_eq!(t.link(pref).span, Span::Multi);
        let alt =
            dimension_ordered_next(&t, here, dst, &|l: LinkId| l == pref).unwrap();
        assert_ne!(alt, pref);
        assert_eq!(t.link(alt).dir, Dir::XPlus);
        assert_eq!(t.link(alt).span, Span::Single);
    }
}
