//! Multicast + network-defect avoidance — the two §2.4 extensions the
//! paper lists as "being considered at the time of writing … can be
//! included based on application or hardware needs". We include both.
//!
//! **Multicast**: one packet, a set of destinations, delivered along a
//! spanning tree: at every node the remaining destination set is
//! partitioned by each target's *deterministic dimension-ordered* next
//! hop (x, then y, then z; multi-span preferred), and one copy forwards
//! per occupied output link. Each destination receives exactly one copy
//! and shared path prefixes are traversed once — the bandwidth win over
//! repeated directed sends (tested below).
//!
//! **Defect avoidance**: links can be marked failed
//! ([`crate::network::Network::fail_link`]). Directed routing drops
//! failed links from its productive set; when *every* productive link is
//! dead the packet takes a lateral escape (any live link) and re-routes
//! from there, with a hop budget guarding against livelock. Multicast
//! partitioning likewise avoids failed links when a sibling productive
//! link survives.

use crate::topology::{Dir, LinkId, NodeId, Span, Topology};

/// The deterministic dimension-ordered next link towards `dst` from
/// `here`: correct the x distance first (multi-span when ≥ 3), then y,
/// then z. Unlike the adaptive chooser this is path-stable, which is
/// what makes the multicast partition a tree. Failed links are skipped
/// where a productive alternative exists on the same axis.
pub fn dimension_ordered_next(
    topo: &Topology,
    here: NodeId,
    dst: NodeId,
    failed: &[bool],
) -> Option<LinkId> {
    let hc = topo.coord(here);
    let dc = topo.coord(dst);
    for axis in 0..3 {
        let cur = hc.get(axis);
        let tgt = dc.get(axis);
        if cur == tgt {
            continue;
        }
        let d = cur.abs_diff(tgt);
        let dir = Dir::towards(axis, cur, tgt);
        let want_span = if d >= 3 { Span::Multi } else { Span::Single };
        // Preferred span first, then the other as a live fallback.
        for span in [want_span, other(want_span)] {
            if span == Span::Multi && d < 3 {
                continue; // would overshoot
            }
            if let Some(l) = topo
                .out_links(here)
                .iter()
                .copied()
                .find(|&l| {
                    let info = topo.link(l);
                    info.dir == dir && info.span == span && !failed[l.0 as usize]
                })
            {
                return Some(l);
            }
        }
    }
    None
}

fn other(s: Span) -> Span {
    match s {
        Span::Single => Span::Multi,
        Span::Multi => Span::Single,
    }
}

/// Partition `dsts` (excluding `here` itself) by their next link from
/// `here`. Returns (link, destinations routed through it) groups plus
/// whether `here` is itself a destination.
pub fn multicast_partition(
    topo: &Topology,
    here: NodeId,
    dsts: &[NodeId],
    failed: &[bool],
) -> (bool, Vec<(LinkId, Vec<NodeId>)>) {
    let mut local = false;
    let mut groups: Vec<(LinkId, Vec<NodeId>)> = Vec::new();
    for &d in dsts {
        if d == here {
            local = true;
            continue;
        }
        let l = dimension_ordered_next(topo, here, d, failed)
            .expect("multicast destination unreachable (all axis links failed)");
        match groups.iter_mut().find(|(g, _)| *g == l) {
            Some((_, v)) => v.push(d),
            None => groups.push((l, vec![d])),
        }
    }
    (local, groups)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemPreset;
    use crate::topology::Coord;

    fn no_fail(t: &Topology) -> Vec<bool> {
        vec![false; t.link_count()]
    }

    #[test]
    fn dimension_order_is_x_then_y_then_z() {
        let t = Topology::preset(SystemPreset::Card);
        let here = t.id(Coord { x: 0, y: 0, z: 0 });
        let dst = t.id(Coord { x: 1, y: 2, z: 1 });
        let failed = no_fail(&t);
        let l = dimension_ordered_next(&t, here, dst, &failed).unwrap();
        assert_eq!(t.link(l).dir, Dir::XPlus, "x corrected first");
    }

    #[test]
    fn prefers_multispan_for_long_hauls() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let here = t.id(Coord { x: 0, y: 0, z: 0 });
        let dst = t.id(Coord { x: 7, y: 0, z: 0 });
        let failed = no_fail(&t);
        let l = dimension_ordered_next(&t, here, dst, &failed).unwrap();
        assert_eq!(t.link(l).span, Span::Multi);
    }

    #[test]
    fn partition_shares_prefixes() {
        let t = Topology::preset(SystemPreset::Card);
        let here = t.id(Coord { x: 0, y: 0, z: 0 });
        // Two destinations both east: one copy on the +x link.
        let d1 = t.id(Coord { x: 2, y: 0, z: 0 });
        let d2 = t.id(Coord { x: 2, y: 1, z: 0 });
        let failed = no_fail(&t);
        let (local, groups) = multicast_partition(&t, here, &[d1, d2], &failed);
        assert!(!local);
        assert_eq!(groups.len(), 1, "shared prefix must use one copy");
        assert_eq!(groups[0].1.len(), 2);
    }

    #[test]
    fn failed_link_falls_back_to_surviving_span() {
        let t = Topology::preset(SystemPreset::Inc3000);
        let here = t.id(Coord { x: 0, y: 0, z: 0 });
        let dst = t.id(Coord { x: 6, y: 0, z: 0 });
        let mut failed = no_fail(&t);
        let pref = dimension_ordered_next(&t, here, dst, &failed).unwrap();
        assert_eq!(t.link(pref).span, Span::Multi);
        failed[pref.0 as usize] = true;
        let alt = dimension_ordered_next(&t, here, dst, &failed).unwrap();
        assert_ne!(alt, pref);
        assert_eq!(t.link(alt).dir, Dir::XPlus);
        assert_eq!(t.link(alt).span, Span::Single);
    }
}
