//! Packet formats and routing policy (paper §2.4).
//!
//! Two routing schemes are implemented, exactly as the paper describes:
//!
//! * **Directed**: a packet is routed to a single destination with a
//!   minimal number of hops, using both single- and multi-span links.
//!   The path is *not* deterministic — at every node, any productive
//!   output link (one that reduces the remaining minimal hop count by
//!   one) may be chosen based on which links happen to be idle, so
//!   in-order delivery is not guaranteed (§2.4, footnote 1).
//! * **Broadcast**: the packet radiates out from the source and every
//!   node receives **exactly one copy**. Forwarding follows a
//!   dimension-ordered flood (x-travellers spawn y and z branches,
//!   y-travellers spawn z branches, z-travellers only continue), which
//!   realizes the paper's "forward to all / a subset / stop" rule table.
//!   Broadcast uses single-span links; crossing cage boundaries in the
//!   z dimension (INC 9000 only — inter-cage connectors carry multi-span
//!   links) uses a documented jump-then-fill extension (DESIGN.md §5).

mod packet;
pub mod multicast;

pub use packet::{
    MemTarget, Packet, PacketId, Payload, Proto, RouteKind, ZMode, HEADER_BYTES,
};

use crate::topology::{Dir, LinkId, NodeId, Span, Topology};

/// All productive output links for a directed packet at `here`:
/// links whose traversal reduces `Topology::min_hops(here, dst)` by one.
/// Allocation-free hot-path variant: fills `out` (≤ 2 productive links
/// per axis) and returns the count.
pub fn productive_links_buf(
    topo: &Topology,
    here: NodeId,
    dst: NodeId,
    out: &mut [LinkId; 6],
) -> usize {
    let hc = topo.coord(here);
    let dc = topo.coord(dst);
    let mut n = 0;
    for &lid in topo.out_links(here) {
        let l = topo.link(lid);
        let axis = l.dir.axis();
        let cur = hc.get(axis);
        let tgt = dc.get(axis);
        if cur == tgt {
            continue;
        }
        let step = l.span.distance();
        let productive = if axis == 2 {
            // z: cage-aware cost ([`Topology::z_hops`]). Minimal moves
            // can point *away* from the target coordinate here — e.g.
            // z = 2 → 3 jumps forward to 5 (or steps back to 1) first —
            // so every z link reducing the cost by one qualifies. The
            // link exists, so the arithmetic stays in bounds.
            let next = if l.dir.sign() > 0 { cur + step } else { cur - step };
            Topology::z_hops(next, tgt) + 1 == Topology::z_hops(cur, tgt)
        } else {
            // x/y: multi-span links exist at every offset, so minimal
            // paths move toward the target and never overshoot.
            let d = cur.abs_diff(tgt);
            if l.dir != Dir::towards(axis, cur, tgt) || step > d {
                false
            } else {
                // Hop economy along this axis: cost(d) = d/3 + d%3.
                let cost = |d: u32| d / 3 + d % 3;
                cost(d - step) + 1 == cost(d)
            }
        };
        if productive {
            out[n] = lid;
            n += 1;
        }
    }
    n
}

/// Vec-returning convenience wrapper (tests / non-hot-path callers).
pub fn productive_links(topo: &Topology, here: NodeId, dst: NodeId) -> Vec<LinkId> {
    let mut buf = [LinkId(0); 6];
    let n = productive_links_buf(topo, here, dst, &mut buf);
    buf[..n].to_vec()
}

/// Pick one productive link adaptively: prefer an idle link with credits;
/// break ties with `tie` (a well-mixed hash of the packet's identity —
/// see [`crate::util::mix64`]); if none is idle, pick the one that frees
/// up earliest (falls back to queueing on it).
///
/// `tie` deliberately replaces a stateful RNG stream: the choice is a
/// pure function of the candidate set and the packet, independent of how
/// many routing decisions were made before it, so a partitioned
/// simulation ([`crate::network::sharded`]) reproduces the serial
/// engine's paths exactly.
pub fn pick_adaptive(
    candidates: &[LinkId],
    idle: impl Fn(LinkId) -> bool,
    free_at: impl Fn(LinkId) -> u64,
    tie: u64,
) -> Option<LinkId> {
    if candidates.is_empty() {
        return None;
    }
    // Allocation-free: count idle candidates, then pick the k-th.
    let idle_count = candidates.iter().filter(|&&l| idle(l)).count();
    if idle_count > 0 {
        let k = (tie % idle_count as u64) as usize;
        return candidates.iter().copied().filter(|&l| idle(l)).nth(k);
    }
    candidates.iter().copied().min_by_key(|&l| free_at(l))
}

/// Where a broadcast packet must be forwarded from `here`.
///
/// `arrived` is `None` at the source. Returns (link, new RouteKind) pairs.
pub fn broadcast_forwards(
    topo: &Topology,
    here: NodeId,
    arrived: Option<(Dir, Span, ZMode)>,
) -> Vec<(LinkId, RouteKind)> {
    let mut out = Vec::new();
    match arrived {
        None => {
            // Source: spawn ±x, ±y and ±z lines.
            spawn_axis(topo, here, 0, &mut out);
            spawn_axis(topo, here, 1, &mut out);
            spawn_z(topo, here, 1, &mut out);
            spawn_z(topo, here, -1, &mut out);
        }
        Some((dir, span, zmode)) => match dir.axis() {
            0 => {
                // x-traveller: continue x, spawn y and z.
                continue_line(topo, here, dir, &mut out);
                spawn_axis(topo, here, 1, &mut out);
                spawn_z(topo, here, 1, &mut out);
                spawn_z(topo, here, -1, &mut out);
            }
            1 => {
                // y-traveller: continue y, spawn z.
                continue_line(topo, here, dir, &mut out);
                spawn_z(topo, here, 1, &mut out);
                spawn_z(topo, here, -1, &mut out);
            }
            _ => {
                // z-traveller.
                let sign = dir.sign();
                match (span, zmode) {
                    (Span::Multi, _) => {
                        // Just jumped a cage: fill backwards within this
                        // cage, and continue jumping forwards.
                        fill_z(topo, here, -sign, &mut out);
                        jump_z(topo, here, sign, &mut out);
                    }
                    (Span::Single, ZMode::Fill) => {
                        fill_z(topo, here, sign, &mut out);
                    }
                    (Span::Single, ZMode::Line) => {
                        continue_z(topo, here, sign, &mut out);
                    }
                }
            }
        },
    }
    out
}

fn single_link(topo: &Topology, here: NodeId, dir: Dir) -> Option<LinkId> {
    topo.out_links(here)
        .iter()
        .copied()
        .find(|&l| topo.link(l).dir == dir && topo.link(l).span == Span::Single)
}

fn multi_link(topo: &Topology, here: NodeId, dir: Dir) -> Option<LinkId> {
    topo.out_links(here)
        .iter()
        .copied()
        .find(|&l| topo.link(l).dir == dir && topo.link(l).span == Span::Multi)
}

fn spawn_axis(topo: &Topology, here: NodeId, axis: usize, out: &mut Vec<(LinkId, RouteKind)>) {
    for sign in [1i32, -1] {
        let dir = dir_of(axis, sign);
        if let Some(l) = single_link(topo, here, dir) {
            out.push((l, RouteKind::Broadcast { zmode: ZMode::Line }));
        }
    }
}

fn continue_line(topo: &Topology, here: NodeId, dir: Dir, out: &mut Vec<(LinkId, RouteKind)>) {
    if let Some(l) = single_link(topo, here, dir) {
        out.push((l, RouteKind::Broadcast { zmode: ZMode::Line }));
    }
}

/// Start or continue a z line in direction `sign` from `here`.
fn spawn_z(topo: &Topology, here: NodeId, sign: i32, out: &mut Vec<(LinkId, RouteKind)>) {
    continue_z(topo, here, sign, out)
}

fn continue_z(topo: &Topology, here: NodeId, sign: i32, out: &mut Vec<(LinkId, RouteKind)>) {
    let dir = dir_of(2, sign);
    if let Some(l) = single_link(topo, here, dir) {
        out.push((l, RouteKind::Broadcast { zmode: ZMode::Line }));
    } else {
        // Cage boundary (or mesh edge): jump if a multi-span exists.
        jump_z(topo, here, sign, out);
    }
}

fn jump_z(topo: &Topology, here: NodeId, sign: i32, out: &mut Vec<(LinkId, RouteKind)>) {
    let dir = dir_of(2, sign);
    // Only jump from a cage-boundary row so the fill pattern tiles cages
    // exactly (see module docs); multi-span z always crosses cages.
    if let Some(l) = multi_link(topo, here, dir) {
        let c = topo.coord(here);
        let at_boundary = if sign > 0 { c.z % 3 == 2 } else { c.z % 3 == 0 };
        if at_boundary {
            out.push((l, RouteKind::Broadcast { zmode: ZMode::Line }));
        }
    }
}

fn fill_z(topo: &Topology, here: NodeId, sign: i32, out: &mut Vec<(LinkId, RouteKind)>) {
    let c = topo.coord(here);
    let within_cage = if sign > 0 { c.z % 3 != 2 } else { c.z % 3 != 0 };
    if !within_cage {
        return;
    }
    let dir = dir_of(2, sign);
    if let Some(l) = single_link(topo, here, dir) {
        out.push((l, RouteKind::Broadcast { zmode: ZMode::Fill }));
    }
}

fn dir_of(axis: usize, sign: i32) -> Dir {
    match (axis, sign > 0) {
        (0, true) => Dir::XPlus,
        (0, false) => Dir::XMinus,
        (1, true) => Dir::YPlus,
        (1, false) => Dir::YMinus,
        (2, true) => Dir::ZPlus,
        _ => Dir::ZMinus,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemPreset;

    fn topo3000() -> Topology {
        Topology::preset(SystemPreset::Inc3000)
    }

    #[test]
    fn productive_links_reduce_min_hops() {
        let t = topo3000();
        let src = t.id(crate::topology::Coord { x: 0, y: 0, z: 0 });
        let dst = t.id(crate::topology::Coord { x: 7, y: 2, z: 2 });
        let cands = productive_links(&t, src, dst);
        assert!(!cands.is_empty());
        let h0 = t.min_hops(src, dst);
        for l in cands {
            let nxt = t.link(l).dst;
            assert_eq!(t.min_hops(nxt, dst), h0 - 1, "link {l} not productive");
        }
    }

    #[test]
    fn directed_walk_always_terminates_in_min_hops() {
        let t = topo3000();
        for (a, b) in [(0u32, 431u32), (5, 211), (100, 101), (17, 17)] {
            let (src, dst) = (NodeId(a), NodeId(b));
            let mut here = src;
            let mut hops = 0u32;
            while here != dst {
                let cands = productive_links(&t, here, dst);
                let tie = crate::util::mix64(a as u64 ^ (hops as u64) << 32);
                let l = pick_adaptive(&cands, |_| true, |_| 0, tie).unwrap();
                here = t.link(l).dst;
                hops += 1;
                assert!(hops <= t.min_hops(src, dst));
            }
            assert_eq!(hops, t.min_hops(src, dst));
        }
    }

    #[test]
    fn productive_links_cross_cage_z_boundary() {
        // z = 2 → z = 3: adjacent coordinates in different cages. No
        // direct link exists (single-span z stays inside a cage), so
        // the minimal first moves are the multi-span jump to z = 5 or
        // the backward fill step to z = 1 — both must be offered, and
        // both must reduce min_hops (which is 3 here, not 1).
        let t = Topology::preset(crate::config::SystemPreset::Inc9000);
        let src = t.id(crate::topology::Coord { x: 0, y: 0, z: 2 });
        let dst = t.id(crate::topology::Coord { x: 0, y: 0, z: 3 });
        assert_eq!(t.min_hops(src, dst), 3);
        let cands = productive_links(&t, src, dst);
        assert_eq!(cands.len(), 2, "jump-forward and fill-backward");
        for l in cands {
            assert_eq!(t.min_hops(t.link(l).dst, dst), 2, "link {l}");
        }
    }

    #[test]
    fn directed_walk_terminates_across_cages() {
        let t = Topology::preset(crate::config::SystemPreset::Inc9000);
        let pairs = [
            ((0, 0, 2), (0, 0, 3)),   // the pathological off-by-one cage hop
            ((5, 5, 0), (5, 5, 11)),  // full z sweep
            ((0, 0, 1), (11, 11, 10)),
            ((3, 7, 4), (3, 7, 8)),
        ];
        for (a, b) in pairs {
            let src = t.id(crate::topology::Coord { x: a.0, y: a.1, z: a.2 });
            let dst = t.id(crate::topology::Coord { x: b.0, y: b.1, z: b.2 });
            let mut here = src;
            let mut hops = 0u32;
            while here != dst {
                let cands = productive_links(&t, here, dst);
                assert!(!cands.is_empty(), "stuck at {here} towards {dst}");
                let tie = crate::util::mix64(here.0 as u64 ^ ((hops as u64) << 40));
                let l = pick_adaptive(&cands, |_| true, |_| 0, tie).unwrap();
                here = t.link(l).dst;
                hops += 1;
                assert!(hops <= t.min_hops(src, dst), "non-minimal walk");
            }
            assert_eq!(hops, t.min_hops(src, dst));
        }
    }

    /// Simulate the broadcast forwarding rules abstractly (no timing) and
    /// check the exactly-once property the paper claims (§2.4).
    fn check_exactly_once(t: &Topology, src: NodeId) {
        let mut copies = vec![0u32; t.node_count()];
        // (node, arrived)
        let mut frontier: Vec<(NodeId, Option<(Dir, Span, ZMode)>)> = vec![(src, None)];
        while let Some((here, arrived)) = frontier.pop() {
            copies[here.0 as usize] += 1;
            for (lid, rk) in broadcast_forwards(t, here, arrived) {
                let l = t.link(lid);
                let zmode = match rk {
                    RouteKind::Broadcast { zmode } => zmode,
                    _ => unreachable!(),
                };
                frontier.push((l.dst, Some((l.dir, l.span, zmode))));
            }
        }
        for n in t.nodes() {
            assert_eq!(
                copies[n.0 as usize], 1,
                "node {} got {} copies (src {})",
                n, copies[n.0 as usize], src
            );
        }
    }

    #[test]
    fn broadcast_exactly_once_card() {
        let t = Topology::preset(SystemPreset::Card);
        for n in t.nodes() {
            check_exactly_once(&t, n);
        }
    }

    #[test]
    fn broadcast_exactly_once_inc3000_sample() {
        let t = topo3000();
        for n in [0u32, 1, 100, 215, 431, 300, 77] {
            check_exactly_once(&t, NodeId(n));
        }
    }

    #[test]
    fn broadcast_exactly_once_inc9000_crosses_cages() {
        let t = Topology::preset(SystemPreset::Inc9000);
        for n in [0u32, 860, 1727, 432, 1000] {
            check_exactly_once(&t, NodeId(n));
        }
    }

    #[test]
    fn adaptive_prefers_idle_links() {
        let cands = vec![LinkId(0), LinkId(1), LinkId(2)];
        // Only link 1 idle.
        let got = pick_adaptive(&cands, |l| l == LinkId(1), |_| 0, 7);
        assert_eq!(got, Some(LinkId(1)));
        // None idle: earliest-free wins.
        let got = pick_adaptive(&cands, |_| false, |l| 10 - l.0 as u64, 7);
        assert_eq!(got, Some(LinkId(2)));
        // Empty.
        assert_eq!(pick_adaptive(&[], |_| true, |_| 0, 7), None);
    }

    #[test]
    fn adaptive_choice_is_a_pure_function_of_tie() {
        // Same candidates + same tie → same pick, regardless of how many
        // earlier decisions happened (there is no hidden stream state).
        let cands = vec![LinkId(3), LinkId(5), LinkId(9)];
        for tie in 0..32u64 {
            let a = pick_adaptive(&cands, |_| true, |_| 0, tie);
            let b = pick_adaptive(&cands, |_| true, |_| 0, tie);
            assert_eq!(a, b);
            assert_eq!(a, Some(cands[(tie % 3) as usize]));
        }
    }
}
